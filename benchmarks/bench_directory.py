"""``fig17/directory/*`` bench rows: the queueing-coupled directory
model (two-level max-plus recurrence, docs/simulator.md) on the
streaming banked engine tier.

One cold end-to-end run of ``scenarios.directory_mega_grid`` (2 592
cells full mode, a shrunken smoke under ``--quick`` /
``RECXL_BENCH_QUICK=1``) through ``run_sweep(engine="stream")``, plus a
directory-loaded ``recovery_sweep``. Rows record:

* the per-load geomean slowdowns of the **baseline** configuration over
  the in-grid ``directory_load=0.0`` cells (bit-identical to the
  axis-off semantics -- the normalization baseline) and
  ``slowdown_monotone`` asserting they are non-decreasing in offered
  load. Baseline pays the shard's M/D/1 wait serially per store;
  ``proactive_hides_load`` reports the same corner under proactive,
  whose decoupled drain chain absorbs the w-side delay -- the
  capacity-vs-resilience headline of the coupling;
* that the coupled mega-grid still runs on the streaming banked data
  plane with a handful of compiled programs (``engine_compiles``) and
  scan-lane dedup active (``scan_lanes`` < ``cells``: load-0 cells
  dedup across CN counts, coupled cells sharing a resolved
  ``DirectoryParams`` + max-plus row are one lane);
* ``sharer_pool`` -- the directory-derived census (16-CN, N_r=3) that
  replaces the fixed ``contention.SHARER_POOL`` binomial;
* ``oracle_bitident`` -- sampled cells re-run through BOTH serial
  references (the jitted ``simulate_spec`` oracle and the pure-Python
  ``contention.serial_oracle`` pre-collapse loop, which routes through
  ``_prepare_cell`` and therefore folds the identical level-2 epoch
  delays) and checked ``==``;
* ``downtime_load_over_base`` -- the recovery coupling: the directory
  walk of Algorithm 1 dilated by the shard's background utilization.

Registered by benchmarks/run.py (kept out of protocol_benches.py's
import graph); the ``low-memory`` CI job asserts the
``oracle_bitident`` row in ``--quick`` mode.
"""

from __future__ import annotations

import os
import time
from typing import Dict, List

import numpy as np

QUICK = os.environ.get("RECXL_BENCH_QUICK", "") not in ("", "0")
#: Store count for the directory mega-grid rows (paper-scale traces by
#: default; the quick smoke shrinks them so CI still exercises the
#: tier). Shares the megagrid override knob.
STORES = int(os.environ.get("RECXL_BENCH_MEGA_STORES",
                            "2000" if QUICK else "30000"))

#: Offered-load axis of the slowdown rows; 0.0 is the in-grid
#: normalization baseline (bit-identical to ``directory_load=None``).
LOADS = (0.0, 0.2, 0.4, 0.7)


def bench_directory() -> List[Dict]:
    from repro.core import engine as E
    from repro.core.contention import serial_oracle
    from repro.core.directory import sharer_pool
    from repro.core.scenarios import (
        directory_mega_grid,
        recovery_sweep,
        run_sweep,
    )
    from repro.core.simulator import (
        ScenarioSpec,
        clear_sim_caches,
        simulate_spec,
    )

    if QUICK:
        workloads = ("ycsb", "canneal", "streamcluster")
        specs = directory_mega_grid(
            workloads=workloads, configs=("baseline", "proactive"),
            seeds=(0,), replicas=(3,), cn_counts=(16, 4),
            loads=LOADS, sb_sizes=(72,))
    else:
        specs = directory_mega_grid(loads=LOADS)
        workloads = tuple(dict.fromkeys(s.workload for s in specs))
    n = len(specs)

    clear_sim_caches()
    traces0 = E.trace_count()
    t0 = time.perf_counter()
    # engine forced to "stream" so the quick smoke exercises the same
    # banked streaming tier the full grid auto-selects (>= 2048 cells)
    res = run_sweep(specs, n_stores=STORES, engine="stream")
    engine_s = time.perf_counter() - t0
    compiles = E.trace_count() - traces0
    stats = E.bank_stats()
    by = {s: r for s, r in zip(specs, res)}

    rows: List[Dict] = [
        {"name": "fig17/directory/cells", "us_per_call": 0.0, "derived": n},
        {"name": "fig17/directory/stores_per_cell", "us_per_call": 0.0,
         "derived": STORES},
        {"name": "fig17/directory/engine_s",
         "us_per_call": engine_s * 1e6 / n, "derived": round(engine_s, 2)},
        {"name": "fig17/directory/engine_compiles", "us_per_call": 0.0,
         "derived": compiles},
        {"name": "fig17/directory/scan_lanes", "us_per_call": 0.0,
         "derived": stats["scan_lanes"]},
        {"name": "fig17/directory/lane_dedup_ratio", "us_per_call": 0.0,
         "derived": round(n / max(stats["scan_lanes"], 1), 2)},
        {"name": "fig17/directory/bank_rows", "us_per_call": 0.0,
         "derived": f"{stats['trace_rows']}trace+{stats['wv_rows']}wv"},
        {"name": "fig17/directory/h2d_mb", "us_per_call": 0.0,
         "derived": round(stats["h2d_bytes"] / (1 << 20), 1)},
        {"name": "fig17/directory/sharer_pool", "us_per_call": 0.0,
         "derived": sharer_pool(16, 3)},
    ]

    # --- per-load geomean slowdown over the in-grid load-0 baseline ---
    # Baseline config: the shard wait lands on the serial commit chain,
    # so slowdown must grow with offered load. (Proactive's drain chain
    # absorbs it -- reported separately, never asserted monotone.)
    def cell(w: str, config: str, load: float) -> ScenarioSpec:
        return ScenarioSpec(w, config, seed=0, n_replicas=3, n_cns=16,
                            sb_size=72, directory_load=load)

    geomeans = []
    for load in LOADS[1:]:
        sds = [by[cell(w, "baseline", load)].exec_time_ns
               / by[cell(w, "baseline", 0.0)].exec_time_ns
               for w in workloads]
        gm = float(np.exp(np.mean(np.log(sds))))
        geomeans.append(gm)
        rows.append({"name": f"fig17/directory/load{load}_geomean_slowdown",
                     "us_per_call": 0.0, "derived": round(gm, 3)})
    monotone = all(b >= a for a, b in zip([1.0] + geomeans, geomeans))
    rows.append({"name": "fig17/directory/slowdown_monotone",
                 "us_per_call": 0.0, "derived": int(monotone)})
    w0 = workloads[0]
    rows.append({"name": f"fig17/directory/{w0}/proactive_hides_load",
                 "us_per_call": 0.0,
                 "derived": round(
                     by[cell(w0, "proactive", LOADS[-1])].exec_time_ns
                     / by[cell(w0, "proactive", 0.0)].exec_time_ns, 3)})

    # --- oracle bit-identity on sampled cells (both serial references) -
    ident = True
    for i in list(range(0, n, max(1, n // 4)))[:5]:
        s = specs[i]
        rs = simulate_spec(s, n_stores=STORES)
        ro = serial_oracle(s, n_stores=STORES)
        ident = ident and all(
            getattr(res[i], f) == getattr(rs, f) == getattr(ro, f)
            for f in ("exec_time_ns", "repl_at_head_frac", "sb_full_frac"))
    rows.append({"name": "fig17/directory/oracle_bitident",
                 "us_per_call": 0.0, "derived": int(ident)})

    # --- recovery coupling: directory walk dilated by background load -
    base_sweep = recovery_sweep(workloads=("ycsb",), cn_counts=(16,))
    load_sweep = recovery_sweep(workloads=("ycsb",), cn_counts=(16,),
                                directory_load=0.6)
    t_mid = base_sweep.fail_times_ms[1]
    rows.append({"name": "fig17/directory/downtime_load_over_base",
                 "us_per_call": 0.0,
                 "derived": round(load_sweep.total_ms("ycsb", t_mid, 16)
                                  / base_sweep.total_ms("ycsb", t_mid, 16),
                                  3)})
    return rows
