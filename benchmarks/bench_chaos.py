"""``serve/chaos/*`` bench rows: the fault-injection + recovery tier
(``repro.core.chaos``, docs/resilience.md).

Each row measures one leg of the PR-9 resilience contract on the
streaming engine and the serving daemon:

* ``recovered_bitident`` -- EVERY recovered run in this bench (engine
  spare-replacement, engine degraded-mesh, server mid-stream loss,
  server journal rebuild, corrupt-row re-place, upload retries) is
  re-checked ``==`` against the fault-free oracle; must be 1;
* ``steady_compiles`` -- tile programs traced by steady-state re-runs
  AFTER spare-path recovery (engine and server summed; must be 0: the
  rebuilt rows are re-placed into the same shapes/shardings);
* ``detection_ms`` -- gather-path CRC sampling latency from fault
  injection to :class:`IntegrityError` on a corrupted bank row;
* ``recovery_ms`` / ``server_recovery_ms`` / ``journal_recovery_ms`` --
  wall-clock of one spare-replacement recovery: engine replica rebuild,
  server replica rebuild mid-query-stream, and the 1-shard server's
  Logging-Unit journal path;
* ``degraded_qps_ratio`` -- throughput of the degraded-mesh
  configuration (one fewer shard, bank replicated -- what a recovered
  run keeps serving on when no spare exists) over the healthy mesh;
* ``replica_byte_overhead`` -- measured resident device bytes of the
  ``k_replicas=2`` placement over the plain ``k=1`` sub-bank (~2x the
  stacks; arrivals stay replicated either way);
* ``upload_retries`` -- injected h2d failures absorbed by the bounded
  retry policy without surfacing.

Registered by benchmarks/run.py; the ``chaos`` CI job runs this in
``--quick`` mode and asserts ``recovered_bitident==1`` and
``steady_compiles==0``.
"""

from __future__ import annotations

import os
import time
from typing import Dict, List

QUICK = os.environ.get("RECXL_BENCH_QUICK", "") not in ("", "0")
STORES = int(os.environ.get("RECXL_BENCH_CHAOS_STORES",
                            "2000" if QUICK else "10000"))


def bench_chaos() -> List[Dict]:
    import jax

    from repro.core import chaos
    from repro.core import engine as E
    from repro.core.chaos import ChaosConfig
    from repro.core.scenarios import chaos_grid, sweep_grid
    from repro.core.serving import ScenarioServer
    from repro.core.simulator import clear_sim_caches, simulate_batch

    n_shards = min(8, len(jax.devices()))
    grid = (chaos_grid(replicas=(None, 2), bandwidths=(None,)) if QUICK
            else chaos_grid())

    def bitident(got, want):
        return len(got) == len(want) and all(a == b
                                             for a, b in zip(got, want))

    clear_sim_caches()
    oracle = simulate_batch(grid, n_stores=STORES)

    # healthy baseline (k=1): timing + resident bytes for the ratios
    clear_sim_caches()
    t0 = time.perf_counter()
    base = E.run_grid(grid, n_stores=STORES, tile_cells=16,
                      n_shards=n_shards)
    base_s = time.perf_counter() - t0
    ident = bitident(base, oracle)
    k1_bytes = E.bank_stats()["bank_dev_bytes"]

    # spare replacement: shard lost mid-grid, rebuilt from the replica
    # block, re-placed into the same shapes -- then a steady-state
    # re-run that must trace nothing new
    steady_compiles = 0
    with chaos.inject(ChaosConfig(lose_shard=n_shards - 1,
                                  lose_at_dispatch=2)) as cs:
        clear_sim_caches()
        rec = E.run_grid(grid, n_stores=STORES, tile_cells=16,
                         n_shards=n_shards)
        ident = ident and bitident(rec, oracle)
        k2_bytes = E.bank_stats()["bank_dev_bytes"]
        tc0 = E.trace_count()
        again = E.run_grid(grid, n_stores=STORES, tile_cells=16,
                           n_shards=n_shards)
        steady_compiles += E.trace_count() - tc0
        ident = ident and bitident(again, oracle)
        rep = cs.report()
    recovery_ms = rep["recoveries"][0]["ms"] if rep["recoveries"] else -1.0
    recovery_source = (rep["recoveries"][0]["source"]
                       if rep["recoveries"] else "none")

    # detection latency: corrupted resident row caught by gather-path
    # CRC sampling, recovered by a full re-place from the host truth
    with chaos.inject(ChaosConfig(corrupt_wv_row=0)) as cs:
        clear_sim_caches()
        det = E.run_grid(grid, n_stores=STORES, tile_cells=16,
                         n_shards=n_shards)
        ident = ident and bitident(det, oracle)
        detection_ms = cs.report()["detection_ms"]

    # failed h2d uploads absorbed by the bounded retry policy
    with chaos.inject(ChaosConfig(upload_failures=2)) as cs:
        clear_sim_caches()
        up = E.run_grid(grid, n_stores=STORES, tile_cells=16,
                        n_shards=n_shards)
        ident = ident and bitident(up, oracle)
        upload_retries = cs.report()["upload_retries"]

    # degraded mesh: the configuration a spare-less recovery keeps
    # serving on (one fewer shard, bank replicated) -- measure its
    # throughput against the healthy mesh, and run one actual
    # degraded-recovery pass for bit-identity
    degraded_ratio = 1.0
    if n_shards > 1:
        clear_sim_caches()
        t0 = time.perf_counter()
        deg = E.run_grid(grid, n_stores=STORES, tile_cells=16,
                         n_shards=n_shards - 1,
                         bank_partition="replicated")
        deg_s = time.perf_counter() - t0
        ident = ident and bitident(deg, oracle)
        degraded_ratio = (len(grid) / deg_s) / (len(grid) / base_s)
        with chaos.inject(ChaosConfig(lose_shard=0, lose_at_dispatch=1,
                                      recovery="degraded")):
            clear_sim_caches()
            drec = E.run_grid(grid, n_stores=STORES, tile_cells=16,
                              n_shards=n_shards)
            ident = ident and bitident(drec, oracle)
            ident = ident and E.bank_stats()["degraded"] is True

    # serving daemon: shard loss mid-query-stream (replica rebuild,
    # capacity kept, zero recompiles), then the 1-shard journal path
    warm_grid = sweep_grid(workloads=("ycsb", "raytrace"))
    novel = sweep_grid(workloads=("barnes",),
                       configs=("baseline", "proactive"),
                       n_replicas=(2, 3))
    clear_sim_caches()
    novel_oracle = simulate_batch(novel, n_stores=STORES)

    with chaos.inject(ChaosConfig(lose_shard=max(n_shards - 1, 0),
                                  lose_at_dispatch=2)) as cs:
        clear_sim_caches()
        with ScenarioServer(n_stores=STORES, n_shards=n_shards,
                            batch_cells=16) as srv:
            srv.warm(warm_grid)
            srv.reset_stats()
            got = srv.query_batch(novel)
            ident = ident and bitident(got, novel_oracle)
            steady_compiles += srv.stats()["compiled_programs"]
            again = srv.query_batch(novel)
            ident = ident and bitident(again, novel_oracle)
            steady_compiles += srv.stats()["compiled_programs"]
        rep = cs.report()
    server_recovery_ms = (rep["recoveries"][0]["ms"]
                          if rep["recoveries"] else -1.0)

    with chaos.inject(ChaosConfig(lose_shard=0, lose_at_dispatch=2)) as cs:
        clear_sim_caches()
        with ScenarioServer(n_stores=STORES, batch_cells=16) as srv:
            srv.warm(warm_grid)
            got = srv.query_batch(novel)
            ident = ident and bitident(got, novel_oracle)
        rep = cs.report()
    journal_recovery_ms = (rep["recoveries"][0]["ms"]
                           if rep["recoveries"] else -1.0)
    journal_source = (rep["recoveries"][0]["source"]
                      if rep["recoveries"] else "none")

    return [
        {"name": "serve/chaos/cells", "us_per_call": 0.0,
         "derived": len(grid)},
        {"name": "serve/chaos/n_shards", "us_per_call": 0.0,
         "derived": n_shards},
        {"name": "serve/chaos/recovered_bitident", "us_per_call": 0.0,
         "derived": int(ident)},
        {"name": "serve/chaos/steady_compiles", "us_per_call": 0.0,
         "derived": steady_compiles},
        {"name": "serve/chaos/detection_ms",
         "us_per_call": detection_ms * 1e3,
         "derived": round(detection_ms, 2)},
        {"name": "serve/chaos/recovery_ms",
         "us_per_call": recovery_ms * 1e3,
         "derived": round(recovery_ms, 2)},
        {"name": "serve/chaos/recovery_source", "us_per_call": 0.0,
         "derived": recovery_source},
        {"name": "serve/chaos/server_recovery_ms",
         "us_per_call": server_recovery_ms * 1e3,
         "derived": round(server_recovery_ms, 2)},
        {"name": "serve/chaos/journal_recovery_ms",
         "us_per_call": journal_recovery_ms * 1e3,
         "derived": round(journal_recovery_ms, 2)},
        {"name": "serve/chaos/journal_source", "us_per_call": 0.0,
         "derived": journal_source},
        {"name": "serve/chaos/degraded_qps_ratio", "us_per_call": 0.0,
         "derived": round(degraded_ratio, 3)},
        {"name": "serve/chaos/replica_byte_overhead", "us_per_call": 0.0,
         "derived": round(k2_bytes / max(k1_bytes, 1), 3)},
        {"name": "serve/chaos/upload_retries", "us_per_call": 0.0,
         "derived": upload_retries},
    ]
