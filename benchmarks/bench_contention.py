"""``fig17/contention/*`` bench rows: the contention & crash-consistency
scenario subsystem (repro.core.contention, docs/contention.md) on the
streaming banked engine tier.

One cold end-to-end run of ``scenarios.contention_mega_grid`` (2 592
cells full mode, a shrunken smoke under ``--quick`` /
``RECXL_BENCH_QUICK=1``) through ``run_sweep(engine="stream")``, plus a
contended ``recovery_sweep``. Rows record:

* the contended-regime slowdowns the new axes model (per-workload and
  geomean: heavy contention -- conflict_rate=0.5, read_share=0.6,
  eager persist ordering -- over the in-grid neutral cells, which are
  bit-identical to the uncontended semantics);
* that the contended mega-grid still runs on the streaming banked data
  plane with a handful of compiled programs (``engine_compiles`` -- the
  acceptance bound is <= 3) and scan-lane dedup active (``scan_lanes``
  < ``cells``: the CN axis shares lanes because contention keys
  deliberately exclude ``n_cns``);
* ``oracle_bitident`` -- sampled cells re-run through BOTH serial
  references (the jitted ``simulate_spec`` oracle and the pure-Python
  ``contention.serial_oracle`` pre-collapse loop) and checked ``==``,
  so the subsystem's rows can never quietly come from drifting
  arithmetic;
* ``downtime_conflict_over_base`` -- the SS VII-E recovery coupling:
  estimated downtime under heavy conflict vs the uncontended model.

Registered by benchmarks/run.py (kept out of protocol_benches.py's
import graph); the ``docs`` and ``low-memory`` CI jobs assert the
``oracle_bitident`` row in ``--quick`` mode.
"""

from __future__ import annotations

import os
import time
from typing import Dict, List

import numpy as np

QUICK = os.environ.get("RECXL_BENCH_QUICK", "") not in ("", "0")
#: Store count for the contention mega-grid rows (paper-scale traces by
#: default; the quick smoke shrinks them so CI still exercises the
#: tier). Shares the megagrid override knob.
STORES = int(os.environ.get("RECXL_BENCH_MEGA_STORES",
                            "2000" if QUICK else "30000"))

#: The heavy-contention corner reported by the slowdown rows (must be
#: present in both the quick and full grids).
HOT = dict(conflict_rate=0.5, read_share=0.6, consistency_schedule="eager")
#: The in-grid neutral corner (bit-identical to the uncontended
#: semantics -- the normalization baseline).
BASE = dict(conflict_rate=0.0, read_share=0.0, consistency_schedule="lazy")


def bench_contention() -> List[Dict]:
    from repro.core import engine as E
    from repro.core.contention import serial_oracle
    from repro.core.scenarios import (
        contention_mega_grid,
        recovery_sweep,
        run_sweep,
    )
    from repro.core.simulator import (
        ScenarioSpec,
        clear_sim_caches,
        simulate_spec,
    )

    if QUICK:
        workloads = ("ycsb", "canneal", "streamcluster")
        specs = contention_mega_grid(
            workloads=workloads, seeds=(0,), replicas=(1,),
            cn_counts=(16, 8), conflict_rates=(0.0, 0.5),
            read_shares=(0.0, 0.6), schedules=("lazy", "eager"))
    else:
        specs = contention_mega_grid()
        workloads = tuple(dict.fromkeys(s.workload for s in specs))
    n = len(specs)

    clear_sim_caches()
    traces0 = E.trace_count()
    t0 = time.perf_counter()
    # engine forced to "stream" so the quick smoke exercises the same
    # banked streaming tier the full grid auto-selects (>= 2048 cells)
    res = run_sweep(specs, n_stores=STORES, engine="stream")
    engine_s = time.perf_counter() - t0
    compiles = E.trace_count() - traces0
    stats = E.bank_stats()
    by = {s: r for s, r in zip(specs, res)}

    # --- contended-regime slowdowns (hot corner over in-grid neutral) --
    def cell(w: str, **axes) -> ScenarioSpec:
        return ScenarioSpec(w, "proactive", seed=0, n_replicas=1,
                            n_cns=16, **axes)

    rows: List[Dict] = [
        {"name": "fig17/contention/cells", "us_per_call": 0.0, "derived": n},
        {"name": "fig17/contention/stores_per_cell", "us_per_call": 0.0,
         "derived": STORES},
        {"name": "fig17/contention/engine_s",
         "us_per_call": engine_s * 1e6 / n, "derived": round(engine_s, 2)},
        {"name": "fig17/contention/engine_compiles", "us_per_call": 0.0,
         "derived": compiles},
        {"name": "fig17/contention/scan_lanes", "us_per_call": 0.0,
         "derived": stats["scan_lanes"]},
        {"name": "fig17/contention/lane_dedup_ratio", "us_per_call": 0.0,
         "derived": round(n / max(stats["scan_lanes"], 1), 2)},
        {"name": "fig17/contention/bank_rows", "us_per_call": 0.0,
         "derived": f"{stats['trace_rows']}trace+{stats['wv_rows']}wv"},
        {"name": "fig17/contention/h2d_mb", "us_per_call": 0.0,
         "derived": round(stats["h2d_bytes"] / (1 << 20), 1)},
    ]
    slowdowns = []
    for w in workloads:
        hot = by[cell(w, **HOT)].exec_time_ns
        base = by[cell(w, **BASE)].exec_time_ns
        slowdowns.append(hot / base)
    for w, sd in list(zip(workloads, slowdowns))[:3]:
        rows.append({"name": f"fig17/contention/{w}/hot_over_base",
                     "us_per_call": 0.0, "derived": round(sd, 3)})
    rows.append({"name": "fig17/contention/geomean_hot_over_base",
                 "us_per_call": 0.0,
                 "derived": round(float(np.exp(np.mean(np.log(slowdowns)))),
                                  3)})

    # --- conflict-only and schedule-only regimes (full grid has both) --
    mid = by.get(cell(workloads[0], conflict_rate=0.5, read_share=0.0,
                      consistency_schedule="lazy"))
    if mid is not None:
        base = by[cell(workloads[0], **BASE)].exec_time_ns
        rows.append({
            "name": f"fig17/contention/{workloads[0]}/conflict_only",
            "us_per_call": 0.0,
            "derived": round(mid.exec_time_ns / base, 3)})

    # --- oracle bit-identity on sampled cells (both serial references) -
    ident = True
    for i in list(range(0, n, max(1, n // 4)))[:5]:
        s = specs[i]
        rs = simulate_spec(s, n_stores=STORES)
        ro = serial_oracle(s, n_stores=STORES)
        ident = ident and all(
            getattr(res[i], f) == getattr(rs, f) == getattr(ro, f)
            for f in ("exec_time_ns", "repl_at_head_frac", "sb_full_frac"))
    rows.append({"name": "fig17/contention/oracle_bitident",
                 "us_per_call": 0.0, "derived": int(ident)})

    # --- recovery coupling: downtime varies with the contention regime -
    base_sweep = recovery_sweep(workloads=("ycsb",), cn_counts=(16,))
    hot_sweep = recovery_sweep(workloads=("ycsb",), cn_counts=(16,),
                               conflict_rate=0.5)
    eager_sweep = recovery_sweep(workloads=("ycsb",), cn_counts=(16,),
                                 consistency_schedule="eager")
    t_mid = base_sweep.fail_times_ms[1]
    base_ms = base_sweep.total_ms("ycsb", t_mid, 16)
    rows.append({"name": "fig17/contention/downtime_conflict_over_base",
                 "us_per_call": 0.0,
                 "derived": round(hot_sweep.total_ms("ycsb", t_mid, 16)
                                  / base_ms, 3)})
    rows.append({"name": "fig17/contention/downtime_eager_over_base",
                 "us_per_call": 0.0,
                 "derived": round(eager_sweep.total_ms("ycsb", t_mid, 16)
                                  / base_ms, 3)})
    return rows
