"""Benchmarks backed by the protocol simulator -- one per paper figure.

Every function returns a list of row dicts with at least
(name, us_per_call, derived); run.py renders them as CSV.

All grids run through the engine tier selector (``scenarios.run_sweep``
-> ``repro.core.engine``): one call per figure instead of a serial
Python loop per cell. ``bench_batch_speedup`` keeps the serial oracle
and both batched engines (blocked default vs PR-1 per-step) honest by
timing all paths on the full Fig. 10 grid; ``bench_megagrid`` times the
streaming sharded tier against the one-shot blocked paths on the
>=10^4-cell sensitivity cross-product. ``clear_sim_caches()`` runs
between engines so no path's timing rides on caches another warmed; all
speedups land in the ``BENCH_protocol.json`` trajectory.
``bench_recovery`` adds the SS VII-E downtime model rows
(``fig9/recovery/*``) from one batched failure-time x node sweep.

See README.md (in this directory) for the bench-row schema.

Quick smoke mode for CI: set ``RECXL_BENCH_QUICK=1`` (shrinks the store
count) -- or override the store count directly with
``RECXL_BENCH_STORES=<n>``.
"""

from __future__ import annotations

import os
import time
from typing import Dict, List, Sequence

from repro.configs.recxl_paper import PAPER_CLAIMS, WORKLOADS
from repro.core.scenarios import fig16_grid, fig17_grid, fig18_grid, run_sweep
from repro.core.simulator import (
    CONFIGS,
    ScenarioSpec,
    SimResult,
    clear_sim_caches,
    geomean_slowdowns,
    simulate,
    simulate_batch,
    slowdowns_from_results,
)

QUICK = os.environ.get("RECXL_BENCH_QUICK", "") not in ("", "0")
N_STORES = int(os.environ.get("RECXL_BENCH_STORES",
                              "5000" if QUICK else "30000"))
#: Store count for the mega-grid rows (paper-scale traces by default;
#: the quick smoke shrinks them so CI still exercises the tier).
MEGA_STORES = int(os.environ.get("RECXL_BENCH_MEGA_STORES",
                                 "2000" if QUICK else "30000"))


def _available_memory_bytes():
    """MemAvailable from /proc/meminfo, or None where unavailable."""
    try:
        with open("/proc/meminfo") as f:
            for line in f:
                if line.startswith("MemAvailable:"):
                    return int(line.split()[1]) * 1024
    except OSError:
        pass
    return None


def _run(specs: Sequence[ScenarioSpec]) -> Dict[tuple, SimResult]:
    """One sweep through the engine tier selector; results keyed by the
    spec itself (figure grids are small, so this resolves to the
    one-shot blocked batch)."""
    res = run_sweep(specs, n_stores=N_STORES)
    return {s: r for s, r in zip(specs, res)}


def bench_wb_wt() -> List[Dict]:
    """Fig. 2: WB vs WT execution time (normalized to WB)."""
    specs = [ScenarioSpec(w, c) for w in WORKLOADS for c in ("wb", "wt")]
    by = _run(specs)
    rows = []
    for w in WORKLOADS:
        wb = by[ScenarioSpec(w, "wb")]
        wt = by[ScenarioSpec(w, "wt")]
        rows.append({
            "name": f"fig2/{w}/wt_over_wb",
            "us_per_call": wt.exec_time_ns / 1e3,
            "derived": round(wt.exec_time_ns / wb.exec_time_ns, 3),
        })
    return rows


def bench_protocols() -> List[Dict]:
    """Fig. 10: the five configurations; headline validation vs. paper."""
    specs = [ScenarioSpec(w, c) for w in WORKLOADS for c in CONFIGS]
    by = _run(specs)
    table = slowdowns_from_results(by.values())
    gm = geomean_slowdowns(table)
    rows = []
    for w, row in table.items():
        for c in CONFIGS:
            rows.append({"name": f"fig10/{w}/{c}",
                         "us_per_call": by[ScenarioSpec(w, c)].exec_time_ns / 1e3,
                         "derived": round(row[c], 3)})
    for c, target_key in [("wt", "wt_slowdown_geomean"),
                          ("baseline", "baseline_slowdown_geomean"),
                          ("proactive", "proactive_slowdown_geomean")]:
        rows.append({
            "name": f"fig10/geomean/{c}",
            "us_per_call": 0.0,
            "derived": round(gm[c], 3),
            "paper_claim": PAPER_CLAIMS[target_key],
        })
    return rows


def bench_batch_speedup() -> List[Dict]:
    """Engine wall-clock comparison on the full Fig. 10 grid (45 cells).

    Four paths: the serial per-cell oracle loop; the PR-1 batched path
    (per-step scan, host prep re-done every call -- exactly what PR 1
    shipped, reproduced by clearing every simulator cache); the
    per-step engine with cached inputs; and the blocked engine (the
    ``simulate_batch`` default). Steady-state rows are warmed so they
    track sweep throughput, not XLA compile time; the cold blocked time
    is its own row since a CI smoke run pays it. ``clear_sim_caches``
    runs between engines so no path's timing rides on caches another
    path warmed.
    """
    specs = [ScenarioSpec(w, c) for w in WORKLOADS for c in CONFIGS]

    clear_sim_caches()
    t0 = time.perf_counter()
    simulate_batch(specs, n_stores=N_STORES)
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    simulate_batch(specs, n_stores=N_STORES)
    blocked_s = time.perf_counter() - t0

    simulate_batch(specs, n_stores=N_STORES, chunk_size=0)   # warm per-step
    t0 = time.perf_counter()
    simulate_batch(specs, n_stores=N_STORES, chunk_size=0)
    perstep_s = time.perf_counter() - t0

    t0 = time.perf_counter()                                 # PR-1 path
    clear_sim_caches()
    simulate_batch(specs, n_stores=N_STORES, chunk_size=0)
    pr1_s = time.perf_counter() - t0

    for s in specs[:5]:                     # warm the per-config serial jits
        simulate(s.workload, s.config, n_stores=N_STORES)
    t0 = time.perf_counter()
    for s in specs:
        simulate(s.workload, s.config, n_stores=N_STORES)
    serial_s = time.perf_counter() - t0

    n = len(specs)
    return [
        {"name": "fig10/sweep/serial_ms", "us_per_call": serial_s * 1e6 / n,
         "derived": round(serial_s * 1e3, 2)},
        {"name": "fig10/sweep/pr1_perstep_uncached_ms",
         "us_per_call": pr1_s * 1e6 / n, "derived": round(pr1_s * 1e3, 2)},
        {"name": "fig10/sweep/perstep_ms", "us_per_call": perstep_s * 1e6 / n,
         "derived": round(perstep_s * 1e3, 2)},
        {"name": "fig10/sweep/batched_ms", "us_per_call": blocked_s * 1e6 / n,
         "derived": round(blocked_s * 1e3, 2)},
        {"name": "fig10/sweep/batched_cold_ms", "us_per_call": cold_s * 1e6 / n,
         "derived": round(cold_s * 1e3, 2)},
        {"name": "fig10/sweep/speedup_serial_over_batched",
         "us_per_call": 0.0,
         "derived": round(serial_s / max(blocked_s, 1e-9), 2)},
        {"name": "fig10/sweep/speedup_pr1_over_blocked",
         "us_per_call": 0.0,
         "derived": round(pr1_s / max(blocked_s, 1e-9), 2)},
        {"name": "fig10/sweep/speedup_perstep_over_blocked",
         "us_per_call": 0.0,
         "derived": round(perstep_s / max(blocked_s, 1e-9), 2)},
    ]


def bench_megagrid() -> List[Dict]:
    """``fig10/megagrid/*``: the streaming sharded engine tier vs the
    one-shot blocked path on the full sensitivity cross-product
    (``scenarios.mega_grid``: 12 960 cells full mode, a shrunken smoke
    under ``--quick``).

    Four cold end-to-end runs, with ``clear_sim_caches()`` before each
    so every path pays its own prep/compile:

    * ``engine_s``    -- :func:`repro.core.engine.run_grid` (tiled,
      cell-sharded over the local devices, double-buffered host prep,
      columnar **bank** data plane: one device-resident dedup'd bank,
      tiles ship int32 row indices, the kernel gathers);
    * ``pr3_stacked_s`` -- the same engine on the PR-3 **stacked**
      plane (full per-cell array copies per tile);
    * ``blocked_s``   -- the current one-shot blocked batch (auto
      chunk, banked plane);
    * ``pr2_blocked_s`` -- the PR-2 path faithfully: one-shot batch at
      the old default ``chunk_size=128``, stacked plane, with the
      reduced-key cell-array sharing disabled (PR 2 derived every
      cell's arrays from scratch).

    Data-plane rows (from ``engine.bank_stats()``) record each engine
    run's H2D bytes, bank rows, dedup ratio, the engine-accounted
    device-memory high-water mark, and (PR 8) the MEASURED resident
    bank device bytes of the per-shard sub-bank partition --
    per-shard, fleet total, the replicated baseline, and their cut
    ratio -- so the ``BENCH_protocol.json`` trajectory captures the
    bank win across PRs.

    ``oracle_bitident`` re-runs a handful of sampled cells through the
    serial oracle and checks ``==``, so the speedup rows can never
    quietly come from drifting arithmetic.
    """
    import jax

    from repro.core import engine as E
    from repro.core.simulator import _CELL_ARRAY_CACHE, DEFAULT_CHUNK_SIZE
    from repro.core.scenarios import mega_grid

    if QUICK:
        specs = mega_grid(seeds=(0,), replicas=(1, 3),
                          bandwidths=(160.0, 40.0), cn_counts=(16,),
                          sb_sizes=(72, 48))
    else:
        specs = mega_grid()
    n = len(specs)

    clear_sim_caches()
    traces0 = E.trace_count()
    t0 = time.perf_counter()
    res_e = E.run_grid(specs, n_stores=MEGA_STORES)
    engine_s = time.perf_counter() - t0
    compiles = E.trace_count() - traces0
    shards = res_e[0].meta["n_shards"]
    bank = E.bank_stats()

    clear_sim_caches()
    t0 = time.perf_counter()
    res_p3 = E.run_grid(specs, n_stores=MEGA_STORES, data_plane="stacked")
    pr3_s = time.perf_counter() - t0
    stacked = E.bank_stats()
    plane_ident = all(a.exec_time_ns == b.exec_time_ns
                      and a.sb_full_frac == b.sb_full_frac
                      for a, b in zip(res_e, res_p3))
    del res_p3
    clear_sim_caches()

    # the one-shot comparison rows materialize the WHOLE grid as one
    # batch (the wall the streaming tier exists to avoid): ~17 bytes
    # per cell-store on device plus a host staging copy. Skip them --
    # engine rows still stand -- rather than swap/OOM a small machine.
    oneshot_bytes = 2 * 17 * MEGA_STORES * (n + 8)
    budget = _available_memory_bytes()
    oneshot_ok = budget is None or oneshot_bytes < 0.6 * budget

    blocked_s = pr2_s = None
    res_b = None
    if oneshot_ok:
        clear_sim_caches()
        t0 = time.perf_counter()
        res_b = simulate_batch(specs, n_stores=MEGA_STORES)
        blocked_s = time.perf_counter() - t0

        clear_sim_caches()
        old_bound = _CELL_ARRAY_CACHE.maxsize
        _CELL_ARRAY_CACHE.maxsize = 0    # PR 2: no cross-cell sharing
        try:
            t0 = time.perf_counter()
            simulate_batch(specs, n_stores=MEGA_STORES,
                           chunk_size=DEFAULT_CHUNK_SIZE,
                           data_plane="stacked")   # PR 2 predates the bank
            pr2_s = time.perf_counter() - t0
        finally:
            _CELL_ARRAY_CACHE.maxsize = old_bound
            clear_sim_caches()

    ident = plane_ident and (res_b is None or all(
        a.exec_time_ns == b.exec_time_ns
        and a.sb_full_frac == b.sb_full_frac
        for a, b in zip(res_e, res_b)))
    for i in list(range(0, n, max(1, n // 5)))[:6]:     # sampled cells
        s = specs[i]
        rs = simulate(s.workload, s.config, n_stores=MEGA_STORES,
                      seed=s.seed, n_replicas=s.n_replicas,
                      link_bw_gbps=s.link_bw_gbps, n_cns=s.n_cns,
                      sb_size=s.sb_size, coalescing=s.coalescing)
        ident = ident and (res_e[i].exec_time_ns == rs.exec_time_ns
                           and res_e[i].repl_at_head_frac ==
                           rs.repl_at_head_frac)

    skipped = f"skipped(needs~{oneshot_bytes >> 30}GiB)"
    mb = 1.0 / (1 << 20)
    rows = [
        {"name": "fig10/megagrid/cells", "us_per_call": 0.0, "derived": n},
        {"name": "fig10/megagrid/stores_per_cell", "us_per_call": 0.0,
         "derived": MEGA_STORES},
        {"name": "fig10/megagrid/engine_s",
         "us_per_call": engine_s * 1e6 / n, "derived": round(engine_s, 2)},
        {"name": "fig10/megagrid/engine_cells_per_s", "us_per_call": 0.0,
         "derived": round(n / engine_s, 1)},
        {"name": "fig10/megagrid/engine_compiles", "us_per_call": 0.0,
         "derived": compiles},
        {"name": "fig10/megagrid/engine_shards", "us_per_call": 0.0,
         "derived": f"{shards}/{len(jax.devices())}dev"},
        # data-plane rows: the columnar bank vs the PR-3 stacked copies
        {"name": "fig10/megagrid/bank_rows", "us_per_call": 0.0,
         "derived": f"{bank['trace_rows']}trace+{bank['wv_rows']}wv"},
        {"name": "fig10/megagrid/h2d_bank_mb", "us_per_call": 0.0,
         "derived": round(bank["h2d_bytes"] * mb, 1)},
        {"name": "fig10/megagrid/h2d_stacked_mb", "us_per_call": 0.0,
         "derived": round(stacked["h2d_bytes"] * mb, 1)},
        {"name": "fig10/megagrid/h2d_ratio", "us_per_call": 0.0,
         "derived": round(stacked["h2d_bytes"]
                          / max(bank["h2d_bytes"], 1), 2)},
        # replication of staged arrays to the other shards is
        # device-to-device traffic, not host bandwidth: the whole bank
        # under "replicated", only the arrivals column under "sub"
        {"name": "fig10/megagrid/bank_fabric_mb", "us_per_call": 0.0,
         "derived": round(bank["bank_fabric_bytes"] * mb, 1)},
        # resident-bank device bytes, MEASURED from the live buffers
        # (engine._measured_device_bytes). The run uses the per-shard
        # sub-bank partition (PR 8 default): one copy of each max-plus
        # row fleet-wide, arrivals replicated, so the per-shard bytes
        # drop to ~1/n_shards of the replicated PR-4 layout -- whose
        # cost is exactly bank_mb x n_shards (pinned == measured by
        # tests/test_engine.py), the cut_ratio baseline below.
        {"name": "fig10/megagrid/bank_partition", "us_per_call": 0.0,
         "derived": str(bank["bank_partition"])},
        {"name": "fig10/megagrid/bank_mb", "us_per_call": 0.0,
         "derived": round(bank["bank_bytes"] * mb, 1)},
        {"name": "fig10/megagrid/bank_dev_mb_per_shard", "us_per_call": 0.0,
         "derived": round(bank["bank_dev_bytes_per_shard"] * mb, 1)},
        {"name": "fig10/megagrid/bank_dev_total_mb", "us_per_call": 0.0,
         "derived": round(bank["bank_dev_bytes"] * mb, 1)},
        {"name": "fig10/megagrid/bank_dev_replicated_mb", "us_per_call": 0.0,
         "derived": round(bank["bank_bytes"] * shards * mb, 1)},
        {"name": "fig10/megagrid/bank_dev_cut_ratio", "us_per_call": 0.0,
         "derived": round(bank["bank_bytes"] * shards
                          / max(bank["bank_dev_bytes"], 1), 2)},
        {"name": "fig10/megagrid/bank_dev_shard_ratio", "us_per_call": 0.0,
         "derived": round(bank["bank_dev_bytes_per_shard"]
                          / max(bank["bank_bytes"] / max(shards, 1), 1),
                          3)},
        {"name": "fig10/megagrid/dedup_ratio", "us_per_call": 0.0,
         "derived": round(bank["dedup_ratio"], 2)},
        {"name": "fig10/megagrid/dev_mem_hwm_mb", "us_per_call": 0.0,
         "derived": round(bank["dev_mem_hwm_bytes"] * mb, 1)},
        {"name": "fig10/megagrid/pr3_stacked_s",
         "us_per_call": pr3_s * 1e6 / n, "derived": round(pr3_s, 2)},
        {"name": "fig10/megagrid/speedup_bank_over_stacked",
         "us_per_call": 0.0,
         "derived": round(pr3_s / max(engine_s, 1e-9), 2)},
        {"name": "fig10/megagrid/blocked_s",
         "us_per_call": (blocked_s or 0.0) * 1e6 / n,
         "derived": round(blocked_s, 2) if blocked_s else skipped},
        {"name": "fig10/megagrid/pr2_blocked_s",
         "us_per_call": (pr2_s or 0.0) * 1e6 / n,
         "derived": round(pr2_s, 2) if pr2_s else skipped},
        {"name": "fig10/megagrid/oracle_bitident", "us_per_call": 0.0,
         "derived": int(ident)},
    ]
    if blocked_s:
        rows.insert(-1, {"name": "fig10/megagrid/speedup_engine_over_blocked",
                         "us_per_call": 0.0,
                         "derived": round(blocked_s / max(engine_s, 1e-9), 2)})
    if pr2_s:
        rows.insert(-1, {"name": "fig10/megagrid/speedup_engine_over_pr2",
                         "us_per_call": 0.0,
                         "derived": round(pr2_s / max(engine_s, 1e-9), 2)})
    return rows


def bench_repl_timing() -> List[Dict]:
    """Fig. 11: fraction of REPLs sent at the SB head under proactive."""
    specs = [ScenarioSpec(w, "proactive") for w in WORKLOADS]
    by = _run(specs)
    return [{"name": f"fig11/{s.workload}/repl_at_head",
             "us_per_call": by[s].exec_time_ns / 1e3,
             "derived": round(by[s].repl_at_head_frac, 4)}
            for s in specs]


def bench_coalescing() -> List[Dict]:
    """Fig. 12: proactive speedup from supporting coalescing."""
    specs = [ScenarioSpec(w, "proactive", coalescing=co)
             for w in WORKLOADS for co in (True, False)]
    by = _run(specs)
    rows = []
    for w in WORKLOADS:
        on = by[ScenarioSpec(w, "proactive", coalescing=True)]
        off = by[ScenarioSpec(w, "proactive", coalescing=False)]
        rows.append({"name": f"fig12/{w}/coalescing_speedup",
                     "us_per_call": on.exec_time_ns / 1e3,
                     "derived": round(off.exec_time_ns / on.exec_time_ns, 4)})
    return rows


def bench_log_size() -> List[Dict]:
    """Fig. 13: max DRAM log bytes per CN per dump period."""
    specs = [ScenarioSpec(w, "proactive") for w in WORKLOADS]
    by = _run(specs)
    return [{"name": f"fig13/{s.workload}/log_mb",
             "us_per_call": by[s].exec_time_ns / 1e3,
             "derived": round(by[s].max_log_bytes / 1e6, 3)}
            for s in specs]


def bench_bandwidth() -> List[Dict]:
    """Fig. 14: CXL bandwidth split (memory traffic vs log dumps)."""
    specs = [ScenarioSpec(w, "proactive") for w in WORKLOADS]
    by = _run(specs)
    rows = []
    for s in specs:
        r = by[s]
        rows.append({"name": f"fig14/{s.workload}/mem_bw_gbps",
                     "us_per_call": r.exec_time_ns / 1e3,
                     "derived": round(r.cxl_mem_bw_gbps, 2)})
        rows.append({"name": f"fig14/{s.workload}/dump_bw_gbps",
                     "us_per_call": 0.0,
                     "derived": round(r.log_dump_bw_gbps, 3)})
    return rows


def bench_owned_lines() -> List[Dict]:
    """Fig. 15: owned (dirty/exclusive) lines of a crashed CN. The
    simulator's working-set profile supplies the line census; the
    framework's ShardDirectory supplies the shard census."""
    from repro.core.directory import ShardDirectory
    rows = []
    for w, prof in WORKLOADS.items():
        owned = min(prof.working_lines, 163_000)
        rows.append({"name": f"fig15/{w}/owned_lines",
                     "us_per_call": 0.0,
                     "derived": owned})
    d = ShardDirectory(n_nodes=16, n_buckets=8, n_replicas=3)
    s = d.stats(0)
    rows.append({"name": "fig15/framework/owned_shards",
                 "us_per_call": 0.0, "derived": s["owned"]})
    rows.append({"name": "fig15/framework/replica_entries",
                 "us_per_call": 0.0, "derived": s["shared"]})
    return rows


def bench_link_bw() -> List[Dict]:
    """Fig. 16: sensitivity to CXL link bandwidth (160 -> 20 GB/s)."""
    grid = fig16_grid()
    by = _run(grid)
    rows = []
    for w in ("ycsb", "canneal", "streamcluster"):
        base = by[ScenarioSpec(w, "wb", link_bw_gbps=160.0)].exec_time_ns
        for bw in (160.0, 80.0, 40.0, 20.0):
            for cfg in ("wb", "proactive"):
                t = by[ScenarioSpec(w, cfg, link_bw_gbps=bw)]
                rows.append({
                    "name": f"fig16/{w}/{cfg}/bw{int(bw)}",
                    "us_per_call": t.exec_time_ns / 1e3,
                    "derived": round(t.exec_time_ns / base, 3)})
    return rows


def bench_replication_factor() -> List[Dict]:
    """Fig. 17: execution time vs N_r (normalized to N_r=3)."""
    grid = fig17_grid()
    by = _run(grid)
    rows = []
    for w in WORKLOADS:
        t3 = by[ScenarioSpec(w, "proactive", n_replicas=3)].exec_time_ns
        for nr in (1, 2, 3, 4):
            t = by[ScenarioSpec(w, "proactive", n_replicas=nr)]
            rows.append({"name": f"fig17/{w}/nr{nr}",
                         "us_per_call": t.exec_time_ns / 1e3,
                         "derived": round(t.exec_time_ns / t3, 4)})
    return rows


def bench_num_nodes() -> List[Dict]:
    """Fig. 18: execution time vs CN count (normalized to 16)."""
    grid = fig18_grid()
    by = _run(grid)
    rows = []
    for w in ("barnes", "ycsb", "bodytrack"):
        t16 = {c: by[ScenarioSpec(w, c, n_cns=16)].exec_time_ns
               for c in ("wb", "proactive")}
        for ncn in (4, 8, 16):
            for c in ("wb", "proactive"):
                t = by[ScenarioSpec(w, c, n_cns=ncn)]
                rows.append({"name": f"fig18/{w}/{c}/cn{ncn}",
                             "us_per_call": t.exec_time_ns / 1e3,
                             "derived": round(t.exec_time_ns / t16[c], 3)})
    return rows


def bench_recovery() -> List[Dict]:
    """SS VII-E / Fig. 9: estimated downtime after a CN fail-stop.

    One jitted ``recovery_sweep`` call covers the whole (workload x
    failure-time x node-count) grid; rows report per-workload downtime
    at mid-interval on 16 CNs, the worst-case/best-case ratio across
    the failure-time axis (the undumped log grows until the next dump),
    the 4-CN over 16-CN ratio (fewer nodes -> bigger shards to replay),
    and the batched sweep's wall-clock.
    """
    from repro.core.scenarios import recovery_sweep

    sweep = recovery_sweep()                       # warm the jit
    t0 = time.perf_counter()
    sweep = recovery_sweep()
    wall_s = time.perf_counter() - t0

    t_lo, t_mid, t_hi = sweep.fail_times_ms
    rows = []
    for w in sweep.workloads:
        rows.append({"name": f"fig9/recovery/{w}/downtime_ms",
                     "us_per_call": sweep.total_ms(w, t_mid, 16) * 1e3,
                     "derived": round(sweep.total_ms(w, t_mid, 16), 4)})
    iw = sweep.workloads.index("ycsb")
    late = sweep.total_ns[iw, sweep.fail_times_ms.index(t_hi),
                          sweep.cn_counts.index(16)]
    early = sweep.total_ns[iw, sweep.fail_times_ms.index(t_lo),
                           sweep.cn_counts.index(16)]
    rows.append({"name": "fig9/recovery/ycsb/late_over_early_fail",
                 "us_per_call": 0.0, "derived": round(float(late / early), 3)})
    cn4 = sweep.total_ns[iw, sweep.fail_times_ms.index(t_mid),
                         sweep.cn_counts.index(4)]
    cn16 = sweep.total_ns[iw, sweep.fail_times_ms.index(t_mid),
                          sweep.cn_counts.index(16)]
    rows.append({"name": "fig9/recovery/ycsb/cn4_over_cn16",
                 "us_per_call": 0.0, "derived": round(float(cn4 / cn16), 3)})
    n_cells = sweep.total_ns.size
    rows.append({"name": "fig9/recovery/sweep_ms",
                 "us_per_call": wall_s * 1e6 / n_cells,
                 "derived": round(wall_s * 1e3, 3)})
    return rows


ALL_PROTOCOL_BENCHES = [
    bench_wb_wt, bench_protocols, bench_batch_speedup, bench_megagrid,
    bench_repl_timing, bench_coalescing, bench_log_size, bench_bandwidth,
    bench_owned_lines, bench_link_bw, bench_replication_factor,
    bench_num_nodes, bench_recovery,
]
