"""Benchmarks backed by the protocol simulator -- one per paper figure.

Every function returns a list of row dicts with at least
(name, us_per_call, derived); run.py renders them as CSV.

All grids run through the batched sweep engine (``simulate_batch`` /
``core.scenarios`` grid builders): one jitted call per figure instead of
a serial Python loop per cell. ``bench_batch_speedup`` keeps the serial
oracle and both batched engines (blocked default vs PR-1 per-step)
honest by timing all paths on the full Fig. 10 grid and reporting the
wall-clock ratios, so the speedups are tracked in the ``BENCH_*.json``
history. ``bench_recovery`` adds the SS VII-E downtime model rows
(``fig9/recovery/*``) from one batched failure-time x node sweep.

See README.md (in this directory) for the bench-row schema.

Quick smoke mode for CI: set ``RECXL_BENCH_QUICK=1`` (shrinks the store
count) -- or override the store count directly with
``RECXL_BENCH_STORES=<n>``.
"""

from __future__ import annotations

import os
import time
from typing import Dict, List, Sequence

from repro.configs.recxl_paper import PAPER_CLAIMS, WORKLOADS
from repro.core.scenarios import fig16_grid, fig17_grid, fig18_grid
from repro.core.simulator import (
    CONFIGS,
    ScenarioSpec,
    SimResult,
    geomean_slowdowns,
    simulate,
    simulate_batch,
    slowdowns_from_results,
)

QUICK = os.environ.get("RECXL_BENCH_QUICK", "") not in ("", "0")
N_STORES = int(os.environ.get("RECXL_BENCH_STORES",
                              "5000" if QUICK else "30000"))


def _run(specs: Sequence[ScenarioSpec]) -> Dict[tuple, SimResult]:
    """One batched call; results keyed by the spec itself."""
    res = simulate_batch(specs, n_stores=N_STORES)
    return {s: r for s, r in zip(specs, res)}


def bench_wb_wt() -> List[Dict]:
    """Fig. 2: WB vs WT execution time (normalized to WB)."""
    specs = [ScenarioSpec(w, c) for w in WORKLOADS for c in ("wb", "wt")]
    by = _run(specs)
    rows = []
    for w in WORKLOADS:
        wb = by[ScenarioSpec(w, "wb")]
        wt = by[ScenarioSpec(w, "wt")]
        rows.append({
            "name": f"fig2/{w}/wt_over_wb",
            "us_per_call": wt.exec_time_ns / 1e3,
            "derived": round(wt.exec_time_ns / wb.exec_time_ns, 3),
        })
    return rows


def bench_protocols() -> List[Dict]:
    """Fig. 10: the five configurations; headline validation vs. paper."""
    specs = [ScenarioSpec(w, c) for w in WORKLOADS for c in CONFIGS]
    by = _run(specs)
    table = slowdowns_from_results(by.values())
    gm = geomean_slowdowns(table)
    rows = []
    for w, row in table.items():
        for c in CONFIGS:
            rows.append({"name": f"fig10/{w}/{c}",
                         "us_per_call": by[ScenarioSpec(w, c)].exec_time_ns / 1e3,
                         "derived": round(row[c], 3)})
    for c, target_key in [("wt", "wt_slowdown_geomean"),
                          ("baseline", "baseline_slowdown_geomean"),
                          ("proactive", "proactive_slowdown_geomean")]:
        rows.append({
            "name": f"fig10/geomean/{c}",
            "us_per_call": 0.0,
            "derived": round(gm[c], 3),
            "paper_claim": PAPER_CLAIMS[target_key],
        })
    return rows


def bench_batch_speedup() -> List[Dict]:
    """Engine wall-clock comparison on the full Fig. 10 grid (45 cells).

    Four paths: the serial per-cell oracle loop; the PR-1 batched path
    (per-step scan, host prep re-done every call -- exactly what PR 1
    shipped, reproduced by clearing the input caches); the per-step
    engine with cached inputs; and the blocked engine (the
    ``simulate_batch`` default). Steady-state rows are warmed so they
    track sweep throughput, not XLA compile time; the cold blocked time
    is its own row since a CI smoke run pays it.
    """
    from repro.core.simulator import _batch_inputs, _trace_cached

    specs = [ScenarioSpec(w, c) for w in WORKLOADS for c in CONFIGS]

    t0 = time.perf_counter()
    simulate_batch(specs, n_stores=N_STORES)
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    simulate_batch(specs, n_stores=N_STORES)
    blocked_s = time.perf_counter() - t0

    simulate_batch(specs, n_stores=N_STORES, chunk_size=0)   # warm per-step
    t0 = time.perf_counter()
    simulate_batch(specs, n_stores=N_STORES, chunk_size=0)
    perstep_s = time.perf_counter() - t0

    t0 = time.perf_counter()                                 # PR-1 path
    _batch_inputs.cache_clear()
    _trace_cached.cache_clear()
    simulate_batch(specs, n_stores=N_STORES, chunk_size=0)
    pr1_s = time.perf_counter() - t0

    for s in specs[:5]:                     # warm the per-config serial jits
        simulate(s.workload, s.config, n_stores=N_STORES)
    t0 = time.perf_counter()
    for s in specs:
        simulate(s.workload, s.config, n_stores=N_STORES)
    serial_s = time.perf_counter() - t0

    n = len(specs)
    return [
        {"name": "fig10/sweep/serial_ms", "us_per_call": serial_s * 1e6 / n,
         "derived": round(serial_s * 1e3, 2)},
        {"name": "fig10/sweep/pr1_perstep_uncached_ms",
         "us_per_call": pr1_s * 1e6 / n, "derived": round(pr1_s * 1e3, 2)},
        {"name": "fig10/sweep/perstep_ms", "us_per_call": perstep_s * 1e6 / n,
         "derived": round(perstep_s * 1e3, 2)},
        {"name": "fig10/sweep/batched_ms", "us_per_call": blocked_s * 1e6 / n,
         "derived": round(blocked_s * 1e3, 2)},
        {"name": "fig10/sweep/batched_cold_ms", "us_per_call": cold_s * 1e6 / n,
         "derived": round(cold_s * 1e3, 2)},
        {"name": "fig10/sweep/speedup_serial_over_batched",
         "us_per_call": 0.0,
         "derived": round(serial_s / max(blocked_s, 1e-9), 2)},
        {"name": "fig10/sweep/speedup_pr1_over_blocked",
         "us_per_call": 0.0,
         "derived": round(pr1_s / max(blocked_s, 1e-9), 2)},
        {"name": "fig10/sweep/speedup_perstep_over_blocked",
         "us_per_call": 0.0,
         "derived": round(perstep_s / max(blocked_s, 1e-9), 2)},
    ]


def bench_repl_timing() -> List[Dict]:
    """Fig. 11: fraction of REPLs sent at the SB head under proactive."""
    specs = [ScenarioSpec(w, "proactive") for w in WORKLOADS]
    by = _run(specs)
    return [{"name": f"fig11/{s.workload}/repl_at_head",
             "us_per_call": by[s].exec_time_ns / 1e3,
             "derived": round(by[s].repl_at_head_frac, 4)}
            for s in specs]


def bench_coalescing() -> List[Dict]:
    """Fig. 12: proactive speedup from supporting coalescing."""
    specs = [ScenarioSpec(w, "proactive", coalescing=co)
             for w in WORKLOADS for co in (True, False)]
    by = _run(specs)
    rows = []
    for w in WORKLOADS:
        on = by[ScenarioSpec(w, "proactive", coalescing=True)]
        off = by[ScenarioSpec(w, "proactive", coalescing=False)]
        rows.append({"name": f"fig12/{w}/coalescing_speedup",
                     "us_per_call": on.exec_time_ns / 1e3,
                     "derived": round(off.exec_time_ns / on.exec_time_ns, 4)})
    return rows


def bench_log_size() -> List[Dict]:
    """Fig. 13: max DRAM log bytes per CN per dump period."""
    specs = [ScenarioSpec(w, "proactive") for w in WORKLOADS]
    by = _run(specs)
    return [{"name": f"fig13/{s.workload}/log_mb",
             "us_per_call": by[s].exec_time_ns / 1e3,
             "derived": round(by[s].max_log_bytes / 1e6, 3)}
            for s in specs]


def bench_bandwidth() -> List[Dict]:
    """Fig. 14: CXL bandwidth split (memory traffic vs log dumps)."""
    specs = [ScenarioSpec(w, "proactive") for w in WORKLOADS]
    by = _run(specs)
    rows = []
    for s in specs:
        r = by[s]
        rows.append({"name": f"fig14/{s.workload}/mem_bw_gbps",
                     "us_per_call": r.exec_time_ns / 1e3,
                     "derived": round(r.cxl_mem_bw_gbps, 2)})
        rows.append({"name": f"fig14/{s.workload}/dump_bw_gbps",
                     "us_per_call": 0.0,
                     "derived": round(r.log_dump_bw_gbps, 3)})
    return rows


def bench_owned_lines() -> List[Dict]:
    """Fig. 15: owned (dirty/exclusive) lines of a crashed CN. The
    simulator's working-set profile supplies the line census; the
    framework's ShardDirectory supplies the shard census."""
    from repro.core.directory import ShardDirectory
    rows = []
    for w, prof in WORKLOADS.items():
        owned = min(prof.working_lines, 163_000)
        rows.append({"name": f"fig15/{w}/owned_lines",
                     "us_per_call": 0.0,
                     "derived": owned})
    d = ShardDirectory(n_nodes=16, n_buckets=8, n_replicas=3)
    s = d.stats(0)
    rows.append({"name": "fig15/framework/owned_shards",
                 "us_per_call": 0.0, "derived": s["owned"]})
    rows.append({"name": "fig15/framework/replica_entries",
                 "us_per_call": 0.0, "derived": s["shared"]})
    return rows


def bench_link_bw() -> List[Dict]:
    """Fig. 16: sensitivity to CXL link bandwidth (160 -> 20 GB/s)."""
    grid = fig16_grid()
    by = _run(grid)
    rows = []
    for w in ("ycsb", "canneal", "streamcluster"):
        base = by[ScenarioSpec(w, "wb", link_bw_gbps=160.0)].exec_time_ns
        for bw in (160.0, 80.0, 40.0, 20.0):
            for cfg in ("wb", "proactive"):
                t = by[ScenarioSpec(w, cfg, link_bw_gbps=bw)]
                rows.append({
                    "name": f"fig16/{w}/{cfg}/bw{int(bw)}",
                    "us_per_call": t.exec_time_ns / 1e3,
                    "derived": round(t.exec_time_ns / base, 3)})
    return rows


def bench_replication_factor() -> List[Dict]:
    """Fig. 17: execution time vs N_r (normalized to N_r=3)."""
    grid = fig17_grid()
    by = _run(grid)
    rows = []
    for w in WORKLOADS:
        t3 = by[ScenarioSpec(w, "proactive", n_replicas=3)].exec_time_ns
        for nr in (1, 2, 3, 4):
            t = by[ScenarioSpec(w, "proactive", n_replicas=nr)]
            rows.append({"name": f"fig17/{w}/nr{nr}",
                         "us_per_call": t.exec_time_ns / 1e3,
                         "derived": round(t.exec_time_ns / t3, 4)})
    return rows


def bench_num_nodes() -> List[Dict]:
    """Fig. 18: execution time vs CN count (normalized to 16)."""
    grid = fig18_grid()
    by = _run(grid)
    rows = []
    for w in ("barnes", "ycsb", "bodytrack"):
        t16 = {c: by[ScenarioSpec(w, c, n_cns=16)].exec_time_ns
               for c in ("wb", "proactive")}
        for ncn in (4, 8, 16):
            for c in ("wb", "proactive"):
                t = by[ScenarioSpec(w, c, n_cns=ncn)]
                rows.append({"name": f"fig18/{w}/{c}/cn{ncn}",
                             "us_per_call": t.exec_time_ns / 1e3,
                             "derived": round(t.exec_time_ns / t16[c], 3)})
    return rows


def bench_recovery() -> List[Dict]:
    """SS VII-E / Fig. 9: estimated downtime after a CN fail-stop.

    One jitted ``recovery_sweep`` call covers the whole (workload x
    failure-time x node-count) grid; rows report per-workload downtime
    at mid-interval on 16 CNs, the worst-case/best-case ratio across
    the failure-time axis (the undumped log grows until the next dump),
    the 4-CN over 16-CN ratio (fewer nodes -> bigger shards to replay),
    and the batched sweep's wall-clock.
    """
    from repro.core.scenarios import recovery_sweep

    sweep = recovery_sweep()                       # warm the jit
    t0 = time.perf_counter()
    sweep = recovery_sweep()
    wall_s = time.perf_counter() - t0

    t_lo, t_mid, t_hi = sweep.fail_times_ms
    rows = []
    for w in sweep.workloads:
        rows.append({"name": f"fig9/recovery/{w}/downtime_ms",
                     "us_per_call": sweep.total_ms(w, t_mid, 16) * 1e3,
                     "derived": round(sweep.total_ms(w, t_mid, 16), 4)})
    iw = sweep.workloads.index("ycsb")
    late = sweep.total_ns[iw, sweep.fail_times_ms.index(t_hi),
                          sweep.cn_counts.index(16)]
    early = sweep.total_ns[iw, sweep.fail_times_ms.index(t_lo),
                           sweep.cn_counts.index(16)]
    rows.append({"name": "fig9/recovery/ycsb/late_over_early_fail",
                 "us_per_call": 0.0, "derived": round(float(late / early), 3)})
    cn4 = sweep.total_ns[iw, sweep.fail_times_ms.index(t_mid),
                         sweep.cn_counts.index(4)]
    cn16 = sweep.total_ns[iw, sweep.fail_times_ms.index(t_mid),
                          sweep.cn_counts.index(16)]
    rows.append({"name": "fig9/recovery/ycsb/cn4_over_cn16",
                 "us_per_call": 0.0, "derived": round(float(cn4 / cn16), 3)})
    n_cells = sweep.total_ns.size
    rows.append({"name": "fig9/recovery/sweep_ms",
                 "us_per_call": wall_s * 1e6 / n_cells,
                 "derived": round(wall_s * 1e3, 3)})
    return rows


ALL_PROTOCOL_BENCHES = [
    bench_wb_wt, bench_protocols, bench_batch_speedup, bench_repl_timing,
    bench_coalescing, bench_log_size, bench_bandwidth, bench_owned_lines,
    bench_link_bw, bench_replication_factor, bench_num_nodes,
    bench_recovery,
]
