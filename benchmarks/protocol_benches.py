"""Benchmarks backed by the protocol simulator -- one per paper figure.

Every function returns a list of row dicts with at least
(name, us_per_call, derived); run.py renders them as CSV.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.configs.recxl_paper import PAPER_CLAIMS, WORKLOADS
from repro.core.simulator import (
    CONFIGS,
    geomean_slowdowns,
    simulate,
    slowdown_table,
)

N_STORES = 30_000


def bench_wb_wt() -> List[Dict]:
    """Fig. 2: WB vs WT execution time (normalized to WB)."""
    rows = []
    for w in WORKLOADS:
        wb = simulate(w, "wb", n_stores=N_STORES)
        wt = simulate(w, "wt", n_stores=N_STORES)
        rows.append({
            "name": f"fig2/{w}/wt_over_wb",
            "us_per_call": wt.exec_time_ns / 1e3,
            "derived": round(wt.exec_time_ns / wb.exec_time_ns, 3),
        })
    return rows


def bench_protocols() -> List[Dict]:
    """Fig. 10: the five configurations; headline validation vs. paper."""
    table = slowdown_table(n_stores=N_STORES)
    gm = geomean_slowdowns(table)
    rows = []
    for w, row in table.items():
        for c in CONFIGS:
            t = simulate(w, c, n_stores=N_STORES)
            rows.append({"name": f"fig10/{w}/{c}",
                         "us_per_call": t.exec_time_ns / 1e3,
                         "derived": round(row[c], 3)})
    for c, target_key in [("wt", "wt_slowdown_geomean"),
                          ("baseline", "baseline_slowdown_geomean"),
                          ("proactive", "proactive_slowdown_geomean")]:
        rows.append({
            "name": f"fig10/geomean/{c}",
            "us_per_call": 0.0,
            "derived": round(gm[c], 3),
            "paper_claim": PAPER_CLAIMS[target_key],
        })
    return rows


def bench_repl_timing() -> List[Dict]:
    """Fig. 11: fraction of REPLs sent at the SB head under proactive."""
    rows = []
    for w in WORKLOADS:
        r = simulate(w, "proactive", n_stores=N_STORES)
        rows.append({"name": f"fig11/{w}/repl_at_head",
                     "us_per_call": r.exec_time_ns / 1e3,
                     "derived": round(r.repl_at_head_frac, 4)})
    return rows


def bench_coalescing() -> List[Dict]:
    """Fig. 12: proactive speedup from supporting coalescing."""
    rows = []
    for w in WORKLOADS:
        on = simulate(w, "proactive", n_stores=N_STORES, coalescing=True)
        off = simulate(w, "proactive", n_stores=N_STORES, coalescing=False)
        rows.append({"name": f"fig12/{w}/coalescing_speedup",
                     "us_per_call": on.exec_time_ns / 1e3,
                     "derived": round(off.exec_time_ns / on.exec_time_ns, 4)})
    return rows


def bench_log_size() -> List[Dict]:
    """Fig. 13: max DRAM log bytes per CN per dump period."""
    rows = []
    for w in WORKLOADS:
        r = simulate(w, "proactive", n_stores=N_STORES)
        rows.append({"name": f"fig13/{w}/log_mb",
                     "us_per_call": r.exec_time_ns / 1e3,
                     "derived": round(r.max_log_bytes / 1e6, 3)})
    return rows


def bench_bandwidth() -> List[Dict]:
    """Fig. 14: CXL bandwidth split (memory traffic vs log dumps)."""
    rows = []
    for w in WORKLOADS:
        r = simulate(w, "proactive", n_stores=N_STORES)
        rows.append({"name": f"fig14/{w}/mem_bw_gbps",
                     "us_per_call": r.exec_time_ns / 1e3,
                     "derived": round(r.cxl_mem_bw_gbps, 2)})
        rows.append({"name": f"fig14/{w}/dump_bw_gbps",
                     "us_per_call": 0.0,
                     "derived": round(r.log_dump_bw_gbps, 3)})
    return rows


def bench_owned_lines() -> List[Dict]:
    """Fig. 15: owned (dirty/exclusive) lines of a crashed CN. The
    simulator's working-set profile supplies the line census; the
    framework's ShardDirectory supplies the shard census."""
    from repro.core.directory import ShardDirectory
    rows = []
    for w, prof in WORKLOADS.items():
        owned = min(prof.working_lines, 163_000)
        rows.append({"name": f"fig15/{w}/owned_lines",
                     "us_per_call": 0.0,
                     "derived": owned})
    d = ShardDirectory(n_nodes=16, n_buckets=8, n_replicas=3)
    s = d.stats(0)
    rows.append({"name": "fig15/framework/owned_shards",
                 "us_per_call": 0.0, "derived": s["owned"]})
    rows.append({"name": "fig15/framework/replica_entries",
                 "us_per_call": 0.0, "derived": s["shared"]})
    return rows


def bench_link_bw() -> List[Dict]:
    """Fig. 16: sensitivity to CXL link bandwidth (160 -> 20 GB/s)."""
    rows = []
    for w in ("ycsb", "canneal", "streamcluster"):
        base = simulate(w, "wb", n_stores=N_STORES,
                        link_bw_gbps=160).exec_time_ns
        for bw in (160, 80, 40, 20):
            for cfg in ("wb", "proactive"):
                t = simulate(w, cfg, n_stores=N_STORES, link_bw_gbps=bw)
                rows.append({
                    "name": f"fig16/{w}/{cfg}/bw{bw}",
                    "us_per_call": t.exec_time_ns / 1e3,
                    "derived": round(t.exec_time_ns / base, 3)})
    return rows


def bench_replication_factor() -> List[Dict]:
    """Fig. 17: execution time vs N_r (normalized to N_r=3)."""
    rows = []
    for w in WORKLOADS:
        t3 = simulate(w, "proactive", n_stores=N_STORES,
                      n_replicas=3).exec_time_ns
        for nr in (1, 2, 3, 4):
            t = simulate(w, "proactive", n_stores=N_STORES, n_replicas=nr)
            rows.append({"name": f"fig17/{w}/nr{nr}",
                         "us_per_call": t.exec_time_ns / 1e3,
                         "derived": round(t.exec_time_ns / t3, 4)})
    return rows


def bench_num_nodes() -> List[Dict]:
    """Fig. 18: execution time vs CN count (normalized to 16)."""
    rows = []
    for w in ("barnes", "ycsb", "bodytrack"):
        t16 = {c: simulate(w, c, n_stores=N_STORES, n_cns=16).exec_time_ns
               for c in ("wb", "proactive")}
        for ncn in (4, 8, 16):
            for c in ("wb", "proactive"):
                t = simulate(w, c, n_stores=N_STORES, n_cns=ncn)
                rows.append({"name": f"fig18/{w}/{c}/cn{ncn}",
                             "us_per_call": t.exec_time_ns / 1e3,
                             "derived": round(t.exec_time_ns / t16[c], 3)})
    return rows


ALL_PROTOCOL_BENCHES = [
    bench_wb_wt, bench_protocols, bench_repl_timing, bench_coalescing,
    bench_log_size, bench_bandwidth, bench_owned_lines, bench_link_bw,
    bench_replication_factor, bench_num_nodes,
]
