"""``serve/telemetry/*`` bench rows: the flight recorder measured on
the tiers it instruments (``repro.core.telemetry``, docs/observability.md).

Four claims, each a row family:

* **Per-stage breakdown of the streaming mega-grid.** One traced
  ``run_grid`` over ``scenarios.mega_grid`` (12 960 cells full mode)
  attributes wall time to the pipeline stages -- ``prep_frac`` (host
  tile prep, prefetch thread), ``h2d_frac`` (tile payload + bank
  placement), ``compute_frac`` (async program dispatch) and
  ``d2h_frac`` (the drain wait: device compute completion + outputs
  back to host -- with async dispatch the compute wall lands here).
  Fractions are of the summed stage time, so they sum to exactly 1.

* **Telemetry overhead.** The same warmed grid is re-run ``_REPS``
  interleaved off/on timing pairs (best-of each leg):
  ``telemetry_overhead_ratio`` = traced / untraced wall and must stay
  <= 1.05 (the near-zero-cost contract the CI ``telemetry`` job greps).
  ``oracle_bitident`` asserts the traced results ``==`` the untraced
  run AND the serial oracle on sampled cells -- recording never
  changes a number.

* **Serving p50/p99 from telemetry histograms.** A warmed
  :class:`ScenarioServer` serves a 70/30 hit/miss stream; the
  ``serve/query_ms`` histogram's p50/p99 must land within 20% of the
  bench-harness percentiles measured around the same calls
  (``p50_agree`` / ``p99_agree``), so latency SLOs no longer need an
  external harness. A submit() burst also exercises the queue-wait /
  batching-window histograms.

* **Chaos recovery timeline.** A mid-grid shard loss under
  ``chaos.inject`` yields the named nested spans
  detection -> rollback -> rebuild -> re-place -> re-dispatch;
  their durations are recorded as rows and ``recover_span_order``
  asserts the order.

``trace_events`` / ``trace_valid`` round-trip the traced mega-grid
through ``export_chrome`` + ``validate_chrome_trace`` (the same schema
check CI runs on the launcher's ``--trace-out`` file).
"""

from __future__ import annotations

import os
import tempfile
import time
from typing import Dict, List

import numpy as np

QUICK = os.environ.get("RECXL_BENCH_QUICK", "") not in ("", "0")
#: Same knob as the fig10 megagrid rows: paper-scale traces by default,
#: shrunken smoke under --quick.
MEGA_STORES = int(os.environ.get("RECXL_BENCH_MEGA_STORES",
                                 "2000" if QUICK else "30000"))
SERVE_STORES = int(os.environ.get("RECXL_BENCH_SERVE_STORES",
                                  "2000" if QUICK else "10000"))
N_QUERIES = 60 if QUICK else 300
#: Timed repetitions per (off, on) overhead leg, interleaved
#: off/on/off/on and taken best-of: host scheduler noise on a warm
#: full-grid run is several times the recorder's actual cost, so the
#: ratio must be a min-vs-min of alternating samples, not two
#: back-to-back walls.
_REPS = 5


def _row(name: str, derived, us: float = 0.0) -> Dict:
    return {"name": f"serve/telemetry/{name}", "us_per_call": us,
            "derived": derived}


def bench_telemetry() -> List[Dict]:
    from repro.core import chaos
    from repro.core import engine as E
    from repro.core import telemetry
    from repro.core.scenarios import (
        chaos_grid,
        grid_delta,
        mega_grid,
        sweep_grid,
    )
    from repro.core.serving import ScenarioServer
    from repro.core.simulator import clear_sim_caches, simulate_spec

    rows: List[Dict] = []

    # ---- traced mega-grid: per-stage breakdown + overhead ratio -------
    if QUICK:
        specs = mega_grid(seeds=(0,), replicas=(1, 3),
                          bandwidths=(160.0, 40.0), cn_counts=(16,),
                          sb_sizes=(72, 48))
    else:
        specs = mega_grid()
    n = len(specs)

    clear_sim_caches()
    E.run_grid(specs, n_stores=MEGA_STORES)       # warm compiles + memos

    # one traced run feeds the per-stage breakdown, protocol counters
    # and the Chrome-trace round-trip
    with telemetry.recording() as rec:
        res_on = E.run_grid(specs, n_stores=MEGA_STORES)
        summ = rec.summary()
        trace_path = os.path.join(
            tempfile.gettempdir(), f"recxl_bench_trace_{os.getpid()}.jsonl")
        n_events = rec.export_chrome(trace_path)

    res_off = E.run_grid(specs, n_stores=MEGA_STORES)
    t_off = t_on = float("inf")
    for _ in range(_REPS):
        t_off = min(t_off, _timed(
            lambda: E.run_grid(specs, n_stores=MEGA_STORES))[0])
        with telemetry.recording():
            t_on = min(t_on, _timed(
                lambda: E.run_grid(specs, n_stores=MEGA_STORES))[0])
    try:
        telemetry.validate_chrome_trace(trace_path)
        trace_valid = 1
    except ValueError:
        trace_valid = 0
    finally:
        try:
            os.unlink(trace_path)
        except OSError:
            pass

    spans = summ["spans"]

    def _total(*names: str) -> float:
        return sum(spans[s]["total"] for s in names if s in spans) / 1e3

    prep_s = _total("tile/prep")
    h2d_s = _total("tile/h2d", "bank/place")
    compute_s = _total("tile/dispatch")
    d2h_s = _total("tile/drain")
    stage_s = max(prep_s + h2d_s + compute_s + d2h_s, 1e-12)

    sample = list(range(0, n, max(1, n // 6)))[:6]
    ident = all(res_off[i] == res_on[i] for i in range(n))
    ident = ident and all(
        res_on[i] == simulate_spec(specs[i], n_stores=MEGA_STORES)
        for i in sample)

    counters = summ["counters"]
    rows += [
        _row("grid_cells", n),
        _row("stores_per_cell", MEGA_STORES),
        _row("prep_frac", round(prep_s / stage_s, 4)),
        _row("h2d_frac", round(h2d_s / stage_s, 4)),
        _row("compute_frac", round(compute_s / stage_s, 4)),
        _row("d2h_frac", round(d2h_s / stage_s, 4)),
        _row("frac_sum", round((prep_s + h2d_s + compute_s + d2h_s)
                               / stage_s, 4)),
        _row("stage_total_s", round(stage_s, 3),
             us=stage_s * 1e6 / max(n, 1)),
        _row("telemetry_overhead_ratio", round(t_on / t_off, 3),
             us=t_on * 1e6 / max(n, 1)),
        _row("proto_repl_msgs", int(counters.get("proto/repl_msgs", 0))),
        _row("proto_log_unit_mb",
             round(counters.get("proto/log_unit_bytes", 0.0)
                   / (1 << 20), 1)),
        _row("trace_events", n_events),
        _row("trace_valid", trace_valid),
    ]

    # ---- serving: telemetry histogram p50/p99 vs the bench harness ----
    warm_grid = sweep_grid(seeds=(0, 1), n_replicas=(None, 2, 4),
                           sb_sizes=(None, 48))
    novel = grid_delta(warm_grid,
                       workloads=("ycsb", "canneal", "barnes"),
                       configs=("proactive", "baseline"),
                       n_replicas=(3,), sb_sizes=(None, 48), seeds=(0, 2))
    rng = np.random.default_rng(0)
    stream = [warm_grid[rng.integers(len(warm_grid))]
              if rng.random() < 0.7
              else novel[rng.integers(len(novel))]
              for _ in range(N_QUERIES)]

    clear_sim_caches()
    with ScenarioServer(n_stores=SERVE_STORES, batch_cells=32) as srv:
        srv.warm(warm_grid)
        with telemetry.recording() as rec:
            lat = np.empty(len(stream))
            for i, spec in enumerate(stream):
                t1 = time.perf_counter()
                srv.query(spec)
                lat[i] = time.perf_counter() - t1
            # snapshot the query histogram BEFORE the submit burst so
            # the telemetry percentiles cover exactly the same samples
            # the harness timed; the burst only feeds the queue-wait /
            # batching-window histograms
            ssumm = rec.summary()
            for f in [srv.submit(s) for s in stream[:16]]:
                f.result()
            wsumm = rec.summary()
    lat_ms = np.sort(lat) * 1e3
    p50_h = float(lat_ms[len(lat_ms) // 2])
    p99_h = float(lat_ms[int(len(lat_ms) * 0.99)])
    q = ssumm["dists"]["serve/query_ms"]
    p50_t, p99_t = q["p50"], q["p99"]
    waits = wsumm["dists"].get("serve/queue_wait_ms", {})
    rows += [
        _row("p50_ms_telemetry", round(p50_t, 3)),
        _row("p50_ms_harness", round(p50_h, 3)),
        _row("p50_agree", int(abs(p50_t - p50_h) <= 0.2 * p50_h)),
        _row("p99_ms_telemetry", round(p99_t, 3)),
        _row("p99_ms_harness", round(p99_h, 3)),
        _row("p99_agree", int(abs(p99_t - p99_h) <= 0.2 * p99_h)),
        _row("queue_wait_p50_ms", round(waits.get("p50", 0.0), 3)),
    ]

    # ---- chaos: recovery timeline with named span durations -----------
    import jax
    n_sh = min(2, len(jax.devices()))
    cg = chaos_grid()[:24]
    c_stores = 500 if QUICK else 5000
    base = E.run_grid(cg, n_stores=c_stores, tile_cells=8, n_shards=n_sh)
    with chaos.inject(chaos.ChaosConfig(lose_shard=n_sh - 1,
                                        lose_at_dispatch=2)):
        with telemetry.recording() as rec:
            res_c = E.run_grid(cg, n_stores=c_stores, tile_cells=8,
                               n_shards=n_sh)
            evs = rec.span_events("recover")
            csumm = rec.summary()
    order = [nm for ph, _t, nm, _tid in evs if ph == "B"]
    want = ["recover", "recover/detect", "recover/rollback",
            "recover/rebuild", "recover/replace", "recover/redispatch"]
    order_ok = int(order == want and all(a == b
                                         for a, b in zip(res_c, base)))
    cs = csumm["spans"]

    def _ms(name: str) -> float:
        return round(cs.get(name, {}).get("total", 0.0), 3)

    rows += [
        _row("recover_detect_ms", _ms("recover/detect")),
        _row("recover_rollback_ms", _ms("recover/rollback")),
        _row("recover_rebuild_ms", _ms("recover/rebuild")),
        _row("recover_replace_ms", _ms("recover/replace")),
        _row("recover_redispatch_ms", _ms("recover/redispatch")),
        _row("recover_total_ms", _ms("recover")),
        _row("recover_span_order", order_ok),
        _row("oracle_bitident", int(ident and order_ok)),
    ]
    return rows


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out
