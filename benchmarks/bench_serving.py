"""``serve/latency/*`` bench rows: the scenario-serving daemon
(``repro.core.serving`` -- persistent engine service, incremental bank
diffs, canonical query batching).

One :class:`ScenarioServer` is warmed on a mixed-SB sweep grid, then a
seeded query stream (70% lane-cache hits against the warm grid, 30%
novel diff-upload cells) is served one query at a time -- the
latency-SLO shape of the ROADMAP's "engine as a service" goal. Rows
record:

* ``p50_ms`` / ``p99_ms`` per-query latency and ``qps`` throughput of
  the steady-state stream (p99 is dominated by the miss flushes --
  one serve-tile scan each; p50 is the pure host-math hit path);
* ``cache_hit_ratio`` -- lane-cache hits over queries (the scan-lane
  dedup working as an answer cache);
* ``steady_compiles`` -- tile programs traced DURING the stream
  (must be 0: serving reuses the warmed canonical signatures);
* ``h2d_per_query_b`` -- marginal host->device bytes per query, and
  ``single_miss_h2d_frac`` -- the marginal bytes of ONE warm novel
  single-cell query over a cold full-bank upload (the incremental-diff
  headline: row-scale, not bank-scale; asserted <= 1%);
* ``bank_partition`` / ``sharded_steady_compiles`` /
  ``bank_dev_mb_per_shard`` -- a second daemon at > 1 shard holds the
  capacity bank PARTITIONED (per-shard sub-banks, PR 8): steady-state
  serving must still trace 0 programs there, and the measured
  per-shard resident bytes are recorded;
* ``oracle_bitident`` -- every streamed answer re-checked ``==``
  against the cold blocked-batch oracle (sharded answers included).

Registered by benchmarks/run.py; the ``serving`` CI job asserts the
``oracle_bitident`` and ``cache_hit_ratio`` rows in ``--quick`` mode.
"""

from __future__ import annotations

import os
import time
from typing import Dict, List

import numpy as np

QUICK = os.environ.get("RECXL_BENCH_QUICK", "") not in ("", "0")
#: Stores per timeline for the serving rows (the daemon's sweet spot is
#: many small queries, so this stays below the mega-grid store counts).
STORES = int(os.environ.get("RECXL_BENCH_SERVE_STORES",
                            "2000" if QUICK else "10000"))
#: Live queries in the steady-state stream.
N_QUERIES = 60 if QUICK else 400


def bench_serving() -> List[Dict]:
    from repro.core import engine as E
    from repro.core.scenarios import grid_delta, sweep_grid
    from repro.core.serving import ScenarioServer
    from repro.core.simulator import clear_sim_caches, simulate_batch

    # a rich warm bank (hundreds of wv rows) so the single-miss probe's
    # one-row diff is measured against a realistically sized platform
    warm_grid = sweep_grid(seeds=(0, 1), n_replicas=(None, 2, 4),
                           sb_sizes=(None, 48),
                           link_bw_gbps=(None, 40.0))
    novel = grid_delta(warm_grid,
                       workloads=("ycsb", "canneal", "barnes", "raytrace"),
                       configs=("proactive", "baseline", "parallel"),
                       n_replicas=(3,), sb_sizes=(None, 48),
                       seeds=(0, 2))
    rng = np.random.default_rng(0)
    stream = [warm_grid[rng.integers(len(warm_grid))]
              if rng.random() < 0.7
              else novel[rng.integers(len(novel))]
              for _ in range(N_QUERIES)]

    clear_sim_caches()
    rows: List[Dict] = []
    with ScenarioServer(n_stores=STORES, batch_cells=32) as srv:
        t0 = time.perf_counter()
        srv.warm(warm_grid)
        warm_s = time.perf_counter() - t0
        warm_stats = srv.stats()

        srv.reset_stats()
        tc0 = E.trace_count()
        lat = np.empty(len(stream))
        t0 = time.perf_counter()
        served = []
        for i, spec in enumerate(stream):
            t1 = time.perf_counter()
            served.append(srv.query(spec))
            lat[i] = time.perf_counter() - t1
        wall = time.perf_counter() - t0
        steady_compiles = E.trace_count() - tc0
        st = srv.stats()
        lat_ms = np.sort(lat) * 1e3

        # marginal diff upload of ONE warm novel single-cell query,
        # against what a cold engine would ship for its bank; a fresh
        # seed forces both a new trace row and a new (w, v) row
        probe = grid_delta(warm_grid + stream,
                           workloads=("bodytrack",),
                           configs=("proactive",), seeds=(2,))
        srv.reset_stats()
        served_probe = srv.query_batch(probe)
        probe_h2d = srv.stats()["h2d_bytes"]
        full_upload = srv.stats()["bank_bytes"]

    # partitioned capacity bank (PR 8): a sharded daemon holds the
    # capacity sub-bank partitioned over the cells mesh -- steady-state
    # compiles must STILL be 0 with owner-scheduled serve tiles, and
    # stats() reports the measured per-shard resident bytes
    import jax
    n_sh = min(2, len(jax.devices()))
    with ScenarioServer(n_stores=STORES, batch_cells=32,
                        n_shards=n_sh) as ssrv:
        ssrv.warm(warm_grid)
        tc0 = E.trace_count()
        sh_served = [ssrv.query(s) for s in stream[:24]]
        sharded_compiles = E.trace_count() - tc0
        sh_stats = ssrv.stats()

    # cold oracle for every answer the daemon produced (fresh caches:
    # the oracle must not ride the daemon's bank or memos)
    clear_sim_caches()
    oracle = simulate_batch(stream + probe, n_stores=STORES)
    ident = all(a == b for a, b in zip(served + served_probe, oracle))
    ident = ident and all(a == b for a, b in zip(sh_served, oracle))

    rows += [
        {"name": "serve/latency/queries", "us_per_call": 0.0,
         "derived": len(stream)},
        {"name": "serve/latency/stores_per_cell", "us_per_call": 0.0,
         "derived": STORES},
        {"name": "serve/latency/warm_s",
         "us_per_call": warm_s * 1e6 / max(len(warm_grid), 1),
         "derived": round(warm_s, 2)},
        {"name": "serve/latency/warm_bank_rows", "us_per_call": 0.0,
         "derived": warm_stats["bank_rows"]},
        {"name": "serve/latency/p50_ms",
         "us_per_call": float(lat_ms[len(lat_ms) // 2]) * 1e3,
         "derived": round(float(lat_ms[len(lat_ms) // 2]), 3)},
        {"name": "serve/latency/p99_ms",
         "us_per_call": float(lat_ms[int(len(lat_ms) * 0.99)]) * 1e3,
         "derived": round(float(lat_ms[int(len(lat_ms) * 0.99)]), 3)},
        {"name": "serve/latency/qps", "us_per_call": wall * 1e6 / len(stream),
         "derived": round(len(stream) / wall, 1)},
        {"name": "serve/latency/cache_hit_ratio", "us_per_call": 0.0,
         "derived": round(st["hit_ratio"], 3)},
        {"name": "serve/latency/steady_compiles", "us_per_call": 0.0,
         "derived": steady_compiles},
        {"name": "serve/latency/h2d_per_query_b", "us_per_call": 0.0,
         "derived": round(st["h2d_bytes"] / len(stream), 1)},
        {"name": "serve/latency/single_miss_h2d_frac", "us_per_call": 0.0,
         "derived": round(probe_h2d / max(full_upload, 1), 5)},
        {"name": "serve/latency/bank_partition", "us_per_call": 0.0,
         "derived": str(sh_stats["bank_partition"])},
        {"name": "serve/latency/sharded_steady_compiles", "us_per_call": 0.0,
         "derived": sharded_compiles},
        {"name": "serve/latency/bank_dev_mb_per_shard", "us_per_call": 0.0,
         "derived": round(sh_stats["bank_dev_bytes_per_shard"] / (1 << 20),
                          3)},
        {"name": "serve/latency/oracle_bitident", "us_per_call": 0.0,
         "derived": int(ident)},
    ]
    return rows
