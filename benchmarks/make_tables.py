"""Regenerate the generated sections of EXPERIMENTS.md from the dry-run
artifacts (roofline table + dry-run summary).

    PYTHONPATH=src python -m benchmarks.make_tables
"""

import glob
import json
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline import full_table, markdown_table  # noqa: E402

EXP = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
ART = os.path.join(os.path.dirname(__file__), "artifacts")


def dryrun_summary() -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, "dryrun_*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("tag"):
            continue
        if r["status"] == "ok":
            mem = (r["memory"]["temp_size_bytes"] or 0) / 1e9
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                f"{r['compile_s']}s | {mem:.2f} GB | "
                f"{r['cost']['flops_global'] / r['n_devices']:.2e} | "
                f"{r['collectives'].get('total_bytes_bf16adj', 0):.2e} |")
        elif r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"skip-by-design | -- | -- | -- | -- |")
        else:
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR | -- | -- | -- | -- |")
    hdr = ("### Dry-run summary (all cells, both meshes)\n\n"
           "| arch | shape | mesh | status | compile | temp/dev | "
           "FLOPs/dev | coll B/dev (bf16adj) |\n"
           "|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def main() -> None:
    with open(EXP) as f:
        doc = f.read()
    table = markdown_table(mesh="16x16")
    # replace marker..next-heading with marker + fresh table
    doc = re.sub(
        r"<!-- ROOFLINE_TABLE -->.*?(?=\n## )",
        "<!-- ROOFLINE_TABLE -->\n\n" + table + "\n",
        doc, flags=re.S)
    # dry-run summary: everything after its marker is generated
    doc = doc.split("<!-- DRYRUN_SUMMARY -->")[0] \
        + "<!-- DRYRUN_SUMMARY -->\n\n" + dryrun_summary() + "\n"
    with open(EXP, "w") as f:
        f.write(doc)
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    main()
