"""Framework-level benchmarks (wall time on the local backend).

* train-step wall time per ReCXL variant on a reduced config over the
  local 8-device mesh -- the framework twin of Fig. 10 (CPU timings are
  not TPU projections; the roofline table covers the production mesh).
* Logging-Unit op latencies and log-compressor throughput.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.config import (
    MeshConfig,
    ReplicationConfig,
    RunConfig,
    ShapeConfig,
    TrainConfig,
)
from repro.core import logging_unit as lu
from repro.distributed.context import make_context, make_mesh, mesh_context
from repro.distributed.sharding import named_shardings, param_specs
from repro.kernels.log_compress import compress, decompress
from repro.models import build_model
from repro.models.model_zoo import make_batch
from repro.training.steps import init_train_state, make_train_step
from repro.core.replication import ReplicationEngine


def _local_mesh():
    n = jax.device_count()
    mp = 2 if n % 2 == 0 else 1
    return make_mesh((n // mp, mp), ("data", "model"))


def _time(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def bench_variant_step_time() -> List[Dict]:
    """Framework Fig. 10 analogue: jitted train-step wall time per
    variant, reduced qwen3, local mesh."""
    mesh = _local_mesh()
    ctx = make_context(mesh)
    cfg = repro.get_reduced_config("qwen3-0.6b")
    shape = ShapeConfig("bench", seq_len=64, global_batch=8, kind="train")
    rows = []
    base_us = None
    n_data = mesh.shape["data"]
    variants = ("none", "baseline", "parallel", "proactive")
    if n_data < 2:
        # replication needs peers; benches run on the default device
        # count by design (the dry-run owns the 512-device override)
        return [{"name": f"framework/train_step/{v}", "us_per_call": 0.0,
                 "derived": ("skipped: needs >=2 data ranks; rerun with "
                             "XLA_FLAGS=--xla_force_host_platform_"
                             "device_count=8")} for v in variants[1:]]
    for variant in variants:
        rep = ReplicationConfig(
            variant=variant, n_replicas=min(2, n_data - 1), n_buckets=4,
            log_capacity=2, log_dtype="bfloat16")
        run = RunConfig(model=cfg, shape=shape,
                        mesh=MeshConfig(tuple(mesh.devices.shape),
                                        ("data", "model")),
                        replication=rep, train=TrainConfig())
        model = build_model(cfg)
        with mesh_context(ctx):
            key = jax.random.PRNGKey(0)
            p_struct = jax.eval_shape(model.init, key)
            specs = param_specs(p_struct, cfg, ctx)
            engine = (ReplicationEngine(rep, ctx, specs, p_struct)
                      if rep.is_replicating else None)
            state = init_train_state(run, model, key, engine)
            state = state._replace(params=jax.tree.map(
                jax.device_put, state.params,
                named_shardings(state.params, cfg, ctx)))
            step = jax.jit(make_train_step(run, model, engine))
            batch = make_batch(cfg, shape)
            batch["labels"] = batch["tokens"]
            dt, (state2, _) = _time(lambda s, b: step(s, b), state, batch)
        us = dt * 1e6
        if variant == "none":
            base_us = us
        rows.append({"name": f"framework/train_step/{variant}",
                     "us_per_call": round(us, 1),
                     "derived": round(us / base_us, 3)})
    return rows


def bench_logging_unit_ops() -> List[Dict]:
    """Latency of the jitted Logging-Unit operations."""
    state = lu.init_state(256, 1024, 16, 8)
    repl = jax.jit(lu.receive_repl)
    val = jax.jit(lu.receive_val)
    drain = jax.jit(lambda s: lu.drain(s, 8))
    v = jnp.ones((8,), jnp.float32)
    dt_r, state = _time(lambda s: repl(s, 1, 42, v), state, iters=20)
    state = val(state, 1, 42, 0)
    dt_v, _ = _time(lambda s: val(s, 1, 43, 1), state, iters=20)
    dt_d, _ = _time(drain, state, iters=20)
    return [
        {"name": "framework/log_unit/receive_repl",
         "us_per_call": round(dt_r * 1e6, 1), "derived": ""},
        {"name": "framework/log_unit/receive_val",
         "us_per_call": round(dt_v * 1e6, 1), "derived": ""},
        {"name": "framework/log_unit/drain8",
         "us_per_call": round(dt_d * 1e6, 1), "derived": ""},
    ]


def bench_log_compressor() -> List[Dict]:
    """Throughput + achieved factor of the dump compressor (paper: gzip-9
    5.8x; ours is fixed-rate -- DESIGN.md S7)."""
    rng = np.random.default_rng(0)
    n = 1 << 20
    vals = jnp.asarray(rng.standard_normal(n), jnp.float32)
    base = vals + jnp.asarray(rng.standard_normal(n) * 0.01, jnp.float32)
    rows = []
    for bits in (8, 4):
        dt, (codes, scales) = _time(
            lambda v, b: compress(v, b, bits=bits), vals, base)
        in_bytes = n * 4
        out_bytes = codes.size * 1 + scales.size * 4
        rows.append({
            "name": f"framework/log_compress/int{bits}",
            "us_per_call": round(dt * 1e6, 1),
            "derived": (f"factor={in_bytes/out_bytes:.2f};"
                        f"GBps={in_bytes/dt/1e9:.2f};paper_gzip=5.8"),
        })
    return rows


ALL_FRAMEWORK_BENCHES = [bench_variant_step_time, bench_logging_unit_ops,
                         bench_log_compressor]
