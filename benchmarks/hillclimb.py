"""Perf hillclimb driver (assignment SS Perf).

Runs tagged dry-run variants of the three chosen cells and prints the
roofline terms so each hypothesis -> change -> measure cycle is one
invocation. Tagged artifacts land next to the baselines in
benchmarks/artifacts/ and EXPERIMENTS.md SSPerf records the log.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell train --iter sp
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.launch.dryrun import run_cell  # noqa: E402

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

# (cell-name) -> (arch, shape, iteration-name -> overrides)
ITERATIONS = {
    "train": ("deepseek-67b", "train_4k", {
        "baseline": {},
        "sp": {"act_policy": "seq_model"},
        "mp4": {"mesh_shape": (64, 4)},
        "fsdp": {"mesh_shape": (256, 1)},
        "fsdp_flash": {"mesh_shape": (256, 1), "flash_accounting": True},
        "fsdp_flash_sel": {"mesh_shape": (256, 1), "flash_accounting": True,
                           "train_overrides": {"remat": "selective"}},
        "fsdp_flash_nobucket": {"mesh_shape": (256, 1),
                                "flash_accounting": True,
                                "rep_overrides": {"n_buckets": 1,
                                                  "coalescing": True}},
        "final": {"mesh_shape": (256, 1), "flash_accounting": True,
                  "blockwise_threshold": 2048,
                  "train_overrides": {"remat": "selective"}},
    }),
    "decode": ("grok-1-314b", "decode_32k", {
        "baseline": {},
        "mp64": {"mesh_shape": (4, 64)},
        "mp256": {"mesh_shape": (1, 256)},
        "mp64_ep": {"mesh_shape": (32, 8)},
    }),
    "prefill": ("deepseek-67b", "prefill_32k", {
        "baseline": {},
        "flash": {"flash_accounting": True},
        "flash_mp8": {"flash_accounting": True, "mesh_shape": (32, 8)},
        "flash_mp4": {"flash_accounting": True, "mesh_shape": (64, 4)},
        "flash_fsdp": {"flash_accounting": True, "mesh_shape": (256, 1)},
        "final": {"flash_accounting": True, "mesh_shape": (32, 8)},
    }),
}


def terms(r):
    n = r["n_devices"]
    t_c = r["cost"]["flops_global"] / n / PEAK_FLOPS
    t_m = r["cost"]["bytes_global"] / n / HBM_BW
    t_x = r["collectives"].get("total_bytes_bf16adj",
                               r["collectives"]["total_bytes"]) / ICI_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda p: p[1])
    return t_c, t_m, t_x, dom[0]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(ITERATIONS))
    ap.add_argument("--iter", required=True)
    args = ap.parse_args()
    arch, shape, iters = ITERATIONS[args.cell]
    if args.iter == "all":
        names = list(iters)
    else:
        names = [args.iter]
    for name in names:
        ov = dict(iters[name])
        tag = "" if name == "baseline" else name
        r = run_cell(arch, shape, multi_pod=False, tag=tag, **ov)
        if r["status"] != "ok":
            print(f"[{name}] ERROR: {r.get('error')}")
            continue
        t_c, t_m, t_x, dom = terms(r)
        print(f"[{name:18s}] compute={t_c:8.3f}s memory={t_m:8.3f}s "
              f"collective={t_x:8.3f}s dominant={dom:10s} "
              f"(compile {r['compile_s']}s)", flush=True)


if __name__ == "__main__":
    main()
