"""Benchmark driver: one function per paper table/figure + the framework
and roofline benches. Prints ``name,us_per_call,derived`` CSV and
appends the run to the ``BENCH_protocol.json`` trajectory.

Sections:
  fig2/*        WB vs WT (paper Fig. 2)
  fig10/*       five configurations + geomeans vs paper claims (Fig. 10),
                plus fig10/sweep/* engine wall-clock tracking (serial
                oracle vs PR-1 per-step scan vs blocked scan) and
                fig10/megagrid/* (streaming sharded tier vs one-shot
                blocked on the full sensitivity cross-product)
  fig9/recovery/*  SS VII-E downtime estimates from the batched
                failure-time x node recovery sweep
  fig11..18/*   characterization + sensitivity (Figs. 11-18)
  fig17/contention/*  contention & crash-consistency axes on the
                streaming banked tier (scenarios.contention_mega_grid;
                see benchmarks/bench_contention.py + docs/contention.md)
  fig17/directory/*  queueing-coupled directory model (two-level
                max-plus recurrence): geomean slowdown vs offered load,
                oracle bit-identity and lane dedup on the streaming
                directory mega-grid (benchmarks/bench_directory.py)
  serve/telemetry/*  flight-recorder observability tier
                (repro.core.telemetry): per-stage time breakdown of the
                streaming mega-grid, serving p50/p99 reproduced from
                telemetry histograms, chaos recovery span timeline and
                the telemetry-off/on overhead ratio
                (benchmarks/bench_telemetry.py; docs/observability.md)
  serve/latency/*  scenario-serving daemon (repro.core.serving):
                p50/p99 query latency, throughput, lane-cache hit
                ratio, steady-state compile count (must be 0) and the
                marginal h2d bytes of incremental bank diffs vs a cold
                full-bank upload (benchmarks/bench_serving.py;
                see docs/serving.md)
  framework/*   jitted step wall times per ReCXL variant, Logging-Unit op
                latencies, log-compressor throughput
  roofline/*    per (arch x shape) single-pod roofline terms from the
                dry-run artifacts (see benchmarks/roofline.py; requires
                `python -m repro.launch.dryrun` to have produced
                benchmarks/artifacts/)

``--quick`` (or RECXL_BENCH_QUICK=1) is the CI smoke mode: protocol
benches only, at a reduced store count (including a shrunken megagrid
smoke so the shard_map tier cannot rot).

``--trace`` enables the flight recorder (``repro.core.telemetry``) for
the whole run and appends its merged summary -- per-stage span
histograms, simulated protocol counters, gauges -- to the history entry
as a ``"telemetry"`` key (docs/observability.md); pass
``--trace-out <path.jsonl>`` too to also export the Chrome trace-event
JSONL for Perfetto.

Perf history: every run appends ``{ts, quick, argv, rows}`` to
``benchmarks/BENCH_protocol.json`` (override the path with
``RECXL_BENCH_HISTORY=<path>``, disable with ``RECXL_BENCH_HISTORY=off``),
so engine speedups are comparable across PRs. Row schema in
benchmarks/README.md.
"""

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

HISTORY_DEFAULT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                               "BENCH_protocol.json")


def _load_history(path: str) -> list:
    """Best-effort read of the existing trajectory. A missing, truncated
    or concurrently-rewritten file degrades to an empty/partial list --
    corrupt *entries* (non-dict items from an interrupted writer) are
    skipped with a stderr warning instead of poisoning the append."""
    try:
        with open(path) as f:
            hist = json.load(f)
    except FileNotFoundError:
        return []
    except (OSError, ValueError) as e:
        print(f"# bench history unreadable, restarting ({path}: {e})",
              file=sys.stderr)
        return []
    if not isinstance(hist, list):
        print(f"# bench history malformed (not a list), restarting ({path})",
              file=sys.stderr)
        return []
    kept = [e for e in hist if isinstance(e, dict)]
    if len(kept) != len(hist):
        print(f"# bench history: skipped {len(hist) - len(kept)} corrupt "
              f"entr(ies) in {path}", file=sys.stderr)
    return kept


def append_history(rows, quick: bool, telemetry=None) -> str:
    """Append one run's rows to the JSON trajectory; returns the path
    ('' when disabled or unwritable). The file is a list of run
    entries, oldest first. History is best-effort telemetry: an
    unreadable/corrupt file is restarted, corrupt entries are skipped
    with a warning, and an unwritable path is reported on stderr --
    neither may fail a bench run that already completed. The rewrite
    goes through a same-directory tmp file + ``os.replace`` so a
    concurrent reader (or a crash mid-write) never observes a
    truncated trajectory."""
    path = os.environ.get("RECXL_BENCH_HISTORY", HISTORY_DEFAULT)
    if path.lower() in ("", "0", "off", "none"):
        return ""
    hist = _load_history(path)
    entry = {
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "quick": quick,
        "argv": sys.argv[1:],
        "rows": rows,
    }
    if telemetry:
        entry["telemetry"] = telemetry
    hist.append(entry)
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "w") as f:
            json.dump(hist, f, indent=1, sort_keys=True, default=str)
            f.write("\n")
        os.replace(tmp, path)
    except OSError as e:
        print(f"# bench history not written ({path}: {e})", file=sys.stderr)
        try:
            os.unlink(tmp)
        except OSError:
            pass
        return ""
    return path


def main() -> None:
    if "--quick" in sys.argv[1:]:
        os.environ["RECXL_BENCH_QUICK"] = "1"
    quick = os.environ.get("RECXL_BENCH_QUICK", "") not in ("", "0")
    traced = "--trace" in sys.argv[1:]
    trace_out = None
    if "--trace-out" in sys.argv[1:]:
        traced = True
        trace_out = sys.argv[sys.argv.index("--trace-out") + 1]
    if traced:
        from repro.core import telemetry
        telemetry.enable()

    from benchmarks.bench_chaos import bench_chaos
    from benchmarks.bench_contention import bench_contention
    from benchmarks.bench_directory import bench_directory
    from benchmarks.bench_serving import bench_serving
    from benchmarks.bench_telemetry import bench_telemetry
    from benchmarks.protocol_benches import ALL_PROTOCOL_BENCHES

    benches = list(ALL_PROTOCOL_BENCHES) + [bench_contention,
                                            bench_directory,
                                            bench_serving,
                                            bench_chaos,
                                            bench_telemetry]
    if not quick:
        from benchmarks.framework_benches import ALL_FRAMEWORK_BENCHES
        benches += ALL_FRAMEWORK_BENCHES

    print("name,us_per_call,derived")
    rows = []
    for bench in benches:
        try:
            rows.extend(bench())
        except Exception as e:  # noqa: BLE001
            rows.append({"name": f"ERROR/{bench.__name__}",
                         "us_per_call": 0.0,
                         "derived": f"{type(e).__name__}:{e}"})
    if not quick:
        from benchmarks.roofline import bench_roofline
        try:
            rows.extend(bench_roofline())
        except Exception as e:  # noqa: BLE001
            rows.append({"name": "ERROR/bench_roofline", "us_per_call": 0.0,
                         "derived": f"{type(e).__name__}:{e}"})

    for r in rows:
        extra = f",paper={r['paper_claim']}" if "paper_claim" in r else ""
        derived = str(r["derived"]).replace(",", ";")
        print(f"{r['name']},{r['us_per_call']},{derived}{extra}")

    summ = None
    if traced:
        from repro.core import telemetry
        summ = telemetry.summary()
        if trace_out:
            n = telemetry.export_chrome(trace_out)
            print(f"# wrote {n} trace events to {trace_out}",
                  file=sys.stderr)
    path = append_history(rows, quick, telemetry=summ)
    if path:
        print(f"# appended {len(rows)} rows to {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
