"""Benchmark driver: one function per paper table/figure + the framework
and roofline benches. Prints ``name,us_per_call,derived`` CSV.

Sections:
  fig2/*        WB vs WT (paper Fig. 2)
  fig10/*       five configurations + geomeans vs paper claims (Fig. 10),
                plus fig10/sweep/* engine wall-clock tracking (serial
                oracle vs PR-1 per-step scan vs blocked scan)
  fig9/recovery/*  SS VII-E downtime estimates from the batched
                failure-time x node recovery sweep
  fig11..18/*   characterization + sensitivity (Figs. 11-18)
  framework/*   jitted step wall times per ReCXL variant, Logging-Unit op
                latencies, log-compressor throughput
  roofline/*    per (arch x shape) single-pod roofline terms from the
                dry-run artifacts (see benchmarks/roofline.py; requires
                `python -m repro.launch.dryrun` to have produced
                benchmarks/artifacts/)

``--quick`` (or RECXL_BENCH_QUICK=1) is the CI smoke mode: protocol
benches only, at a reduced store count.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    if "--quick" in sys.argv[1:]:
        os.environ["RECXL_BENCH_QUICK"] = "1"
    quick = os.environ.get("RECXL_BENCH_QUICK", "") not in ("", "0")

    from benchmarks.protocol_benches import ALL_PROTOCOL_BENCHES

    benches = list(ALL_PROTOCOL_BENCHES)
    if not quick:
        from benchmarks.framework_benches import ALL_FRAMEWORK_BENCHES
        benches += ALL_FRAMEWORK_BENCHES

    print("name,us_per_call,derived")
    rows = []
    for bench in benches:
        try:
            rows.extend(bench())
        except Exception as e:  # noqa: BLE001
            rows.append({"name": f"ERROR/{bench.__name__}",
                         "us_per_call": 0.0,
                         "derived": f"{type(e).__name__}:{e}"})
    if not quick:
        from benchmarks.roofline import bench_roofline
        try:
            rows.extend(bench_roofline())
        except Exception as e:  # noqa: BLE001
            rows.append({"name": "ERROR/bench_roofline", "us_per_call": 0.0,
                         "derived": f"{type(e).__name__}:{e}"})

    for r in rows:
        extra = f",paper={r['paper_claim']}" if "paper_claim" in r else ""
        derived = str(r["derived"]).replace(",", ";")
        print(f"{r['name']},{r['us_per_call']},{derived}{extra}")


if __name__ == "__main__":
    main()
