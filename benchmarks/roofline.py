"""Roofline analysis over the dry-run artifacts (assignment SS Roofline).

For each (arch x shape x mesh) JSON record produced by
``repro.launch.dryrun``, derive the three per-step roofline terms
(seconds):

    compute    = FLOPs_per_device / PEAK_FLOPS          (197 TF bf16)
    memory     = HBM_bytes_per_device / HBM_BW          (819 GB/s)
    collective = link_bytes_per_device / ICI_BW         (~50 GB/s/link)

plus MODEL_FLOPS = 6*N*D (6*N_active*D for MoE), the useful-compute
ratio, the dominant term, and the roofline fraction
(dominant-term-bound / achievable-step-time under perfect overlap).

FLOPs/bytes come from the trip-count-corrected jaxpr walk and collective
bytes from the while-aware HLO parse (launch/costing.py) -- XLA's raw
cost_analysis undercounts scan bodies and is recorded for reference only.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")


def load_records(mesh: Optional[str] = None,
                 tag: str = "") -> List[Dict[str, Any]]:
    out = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, "dryrun_*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        if (r.get("tag") or "") != tag:
            continue
        out.append(r)
    return out


def roofline_terms(rec: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    """The three terms + diagnostics for one dry-run record."""
    if rec.get("status") != "ok":
        return None
    n = rec["n_devices"]
    flops_dev = rec["cost"]["flops_global"] / n
    bytes_dev = rec["cost"]["bytes_global"] / n
    # bf16-adjusted when available (CPU backend promotes bf16 collectives
    # to f32; the TPU target runs them native -- launch/costing.py)
    coll_dev = rec["collectives"].get("total_bytes_bf16adj",
                                      rec["collectives"]["total_bytes"])
    repl_dev = rec["collectives"]["replication_bytes"]

    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    tokens = rec["tokens"]
    n_active = rec["active_params"]
    mult = 3 if rec["shape"] == "train_4k" else 1   # fwd+bwd
    model_flops = 2 * mult * n_active * tokens      # 6ND train / 2ND serve
    useful = model_flops / max(rec["cost"]["flops_global"], 1.0)

    # perfect-overlap achievable step time vs. dominant-term bound
    t_step = max(terms.values())
    frac = terms[dominant] / t_step if t_step else 0.0

    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "variant": rec.get("variant"), "tag": rec.get("tag", ""),
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "t_replication_s": repl_dev / ICI_BW,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_flops_ratio": useful,
        "step_bound_s": t_step,
        # MFU-at-bound: useful model FLOPs over the chips' peak during the
        # bound step time (the score if the dominant term is fully busy)
        "mfu_at_bound": model_flops / (n * PEAK_FLOPS * t_step)
        if t_step else 0.0,
        "hbm_gb_per_device": (rec["memory"]["temp_size_bytes"] or 0) / 1e9,
    }


def full_table(mesh: Optional[str] = None, tag: str = "") -> List[Dict[str, Any]]:
    rows = []
    for rec in load_records(mesh, tag):
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "dominant": "SKIPPED",
                         "reason": rec["reason"][:60]})
            continue
        t = roofline_terms(rec)
        if t:
            rows.append(t)
    return rows


def bench_roofline() -> List[Dict[str, Any]]:
    """CSV rows for run.py: one per single-pod cell (the roofline table
    is single-pod per the assignment; multi-pod proves the pod axis)."""
    rows = []
    for t in full_table(mesh="16x16"):
        if t.get("dominant") == "SKIPPED":
            rows.append({"name": f"roofline/{t['arch']}/{t['shape']}",
                         "us_per_call": 0.0, "derived": "skipped-by-design"})
            continue
        rows.append({
            "name": f"roofline/{t['arch']}/{t['shape']}",
            "us_per_call": t["step_bound_s"] * 1e6,
            "derived": (f"dom={t['dominant']};"
                        f"comp={t['t_compute_s']:.4f}s;"
                        f"mem={t['t_memory_s']:.4f}s;"
                        f"coll={t['t_collective_s']:.4f}s;"
                        f"useful={t['useful_flops_ratio']:.3f};"
                        f"mfu_bound={t['mfu_at_bound']:.3f}"),
        })
    return rows


def markdown_table(mesh: str = "16x16", tag: str = "") -> str:
    rows = full_table(mesh, tag)
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | 6ND/HLO | MFU@bound |\n"
           "|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for t in rows:
        if t.get("dominant") == "SKIPPED":
            lines.append(f"| {t['arch']} | {t['shape']} | -- | -- | -- | "
                         f"skip ({t['reason'][:40]}...) | -- | -- |")
            continue
        lines.append(
            f"| {t['arch']} | {t['shape']} | {t['t_compute_s']:.4f} | "
            f"{t['t_memory_s']:.4f} | {t['t_collective_s']:.4f} | "
            f"**{t['dominant']}** | {t['useful_flops_ratio']:.3f} | "
            f"{t['mfu_at_bound']:.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
