"""Shared fixtures + environment bootstrap.

NOTE: tests intentionally do NOT set XLA_FLAGS device-count overrides
globally (the dry-run launcher owns that); multi-device tests spawn their
mesh from a session-scoped 8-device override ONLY if no jax backend has
been initialized yet.

Two compat layers are installed here, before any test module imports:

* ``src`` goes on ``sys.path`` so plain ``pytest`` works without the
  ``PYTHONPATH=src`` prefix;
* when the real ``hypothesis`` package is missing, the deterministic
  fallback from ``repro.testing`` is registered so the property-test
  modules still collect and run (see hypothesis_compat.py).
"""

import os
import sys

# 8 host devices for the distributed tests; set before any jax import.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if os.path.isdir(_SRC) and os.path.abspath(_SRC) not in map(
        os.path.abspath, sys.path):
    sys.path.insert(0, _SRC)

from repro.testing import install_hypothesis_shim

install_hypothesis_shim()

import jax
import pytest

from repro.distributed.context import make_mesh


@pytest.fixture(scope="session")
def mesh8():
    """(4 data x 2 model) mesh over 8 host devices."""
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices (XLA_FLAGS was already consumed)")
    return make_mesh((4, 2), ("data", "model"))


@pytest.fixture(scope="session")
def pod_mesh8():
    """(2 pod x 2 data x 2 model) mesh over 8 host devices."""
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices")
    return make_mesh((2, 2, 2), ("pod", "data", "model"))
