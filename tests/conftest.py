"""Shared fixtures.

NOTE: tests intentionally do NOT set XLA_FLAGS device-count overrides
globally (the dry-run launcher owns that); multi-device tests spawn their
mesh from a session-scoped 8-device override ONLY if no jax backend has
been initialized yet.
"""

import os

# 8 host devices for the distributed tests; set before any jax import.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import pytest


@pytest.fixture(scope="session")
def mesh8():
    """(4 data x 2 model) mesh over 8 host devices."""
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices (XLA_FLAGS was already consumed)")
    return jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


@pytest.fixture(scope="session")
def pod_mesh8():
    """(2 pod x 2 data x 2 model) mesh over 8 host devices."""
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices")
    return jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
