"""Differential + concurrency harness for the scenario-serving daemon.

The serving contract (``repro.core.serving``): every answer the daemon
produces -- lane-cache hit or diff-upload miss, any batching, any data
plane on the oracle side, any interleaving with ``clear_sim_caches()``
-- is bit-identical (``==``) to the cold batch oracle for the same
spec, and steady-state serving compiles nothing. These tests pin all
of it, plus the ``_plane_keys`` bank-geometry invariants PRs 4-6
relied on implicitly.
"""

import threading

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax

from repro.core import engine as E
from repro.core import simulator as S
from repro.core.scenarios import (
    contention_mega_grid,
    directory_mega_grid,
    downtime_query,
    grid_delta,
    mega_grid,
    recovery_sweep,
    sweep_grid,
)
from repro.core.serving import ScenarioServer, _row_capacity
from repro.core.simulator import (
    CONFIGS,
    PAPER_CLUSTER,
    ScenarioSpec,
    bank_row_maps,
    clear_sim_caches,
    simulate_batch,
)

N = 700
WORKLOAD_POOL = ("ycsb", "canneal", "barnes", "raytrace", "ocean_ncp")
FLOAT_FIELDS = ("exec_time_ns", "repl_at_head_frac", "sb_full_frac",
                "max_log_bytes", "cxl_mem_bw_gbps", "log_dump_bw_gbps")

#: The warm grid every deterministic test heats the daemon with: mixed
#: SB depths, two configs on each side of the replicate/local split.
WARM_GRID = sweep_grid(workloads=("ycsb", "canneal"),
                       configs=("wb", "proactive"),
                       sb_sizes=(None, 48), n_replicas=(None, 3))


def _spec_pool(draw):
    """One random spec over the pooled serve axes (a superset of
    WARM_GRID's axes, so draws mix hits and misses)."""
    return ScenarioSpec(
        draw(st.sampled_from(WORKLOAD_POOL)),
        draw(st.sampled_from(CONFIGS)),
        seed=draw(st.integers(min_value=0, max_value=2)),
        n_replicas=draw(st.sampled_from((None, 2, 3))),
        link_bw_gbps=draw(st.sampled_from((None, 40.0))),
        n_cns=draw(st.sampled_from((None, 8))),
        sb_size=draw(st.sampled_from((None, 16, 48))),
        coalescing=draw(st.booleans()))


@st.composite
def query_streams(draw):
    """A ragged mixed-SB query stream: WARM_GRID cells (lane-cache
    hits) interleaved with novel pool cells (diff-upload misses),
    duplicates and all."""
    n = draw(st.integers(min_value=1, max_value=20))
    stream = []
    for _ in range(n):
        if draw(st.booleans()):
            stream.append(WARM_GRID[draw(st.integers(
                min_value=0, max_value=len(WARM_GRID) - 1))])
        else:
            stream.append(_spec_pool(draw))
    return stream


def lane_count(specs, cluster=PAPER_CLUSTER):
    """Unique scan lanes of a grid: the (SB, trace, wv) dedup the
    engine and the daemon both key on."""
    lanes = set()
    for s in specs:
        sb = s.sb_size if s.sb_size is not None else cluster.store_buffer
        lanes.add((sb,) + S._plane_keys(s, cluster))
    return len(lanes)


# ---------------------------------------------------------------------------
# Differential: daemon answers == cold oracle, hit and miss paths
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(query_streams())
def test_daemon_bitident_to_cold_oracle_on_random_streams(stream):
    with ScenarioServer(n_stores=N, batch_cells=8) as srv:
        srv.warm(WARM_GRID)
        warm_again = srv.query_batch(WARM_GRID)     # pure hit path
        served = srv.query_batch(stream)            # mixed hit/miss
        served_again = srv.query_batch(stream)      # now pure hits
        st_ = srv.stats()
    # the daemon's flush tiles gather from the capacity-padded device
    # bank; the oracle builds its own grid from scratch on BOTH planes
    clear_sim_caches()
    oracle_banked = simulate_batch(WARM_GRID + stream, n_stores=N)
    clear_sim_caches()
    oracle_stacked = simulate_batch(WARM_GRID + stream, n_stores=N,
                                    data_plane="stacked")
    for got, a, b in zip(warm_again + served,
                         oracle_banked, oracle_stacked):
        for f in FLOAT_FIELDS:
            assert getattr(got, f) == getattr(a, f), (got.meta, f)
            assert getattr(got, f) == getattr(b, f), (got.meta, f)
    # the re-served stream is answered from the lane cache, identically
    for x, y in zip(served, served_again):
        assert x == y
        assert y.meta["cache"] == "hit"
    assert st_["lane_hits"] >= len(WARM_GRID) + len(stream)
    assert st_["bank_builds"] == 1


def test_hit_and_miss_paths_and_meta_provenance():
    with ScenarioServer(n_stores=N, batch_cells=8) as srv:
        srv.warm(WARM_GRID)
        srv.reset_stats()

        hit = srv.query(WARM_GRID[0])
        assert hit.meta["cache"] == "hit"
        assert hit.meta["h2d_bytes"] == 0           # nothing crossed
        assert srv.stats()["appended_trace_rows"] == 0

        novel = ScenarioSpec("bodytrack", "proactive", n_replicas=4)
        miss = srv.query(novel)
        assert miss.meta["cache"] == "miss"
        assert miss.meta["h2d_bytes"] > 0           # rows + index diff
        st_ = srv.stats()
        assert st_["appended_trace_rows"] == 1
        assert st_["appended_wv_rows"] == 1
        # marginal bytes of one novel cell are row-scale, not bank-scale
        assert st_["h2d_bytes"] < st_["bank_bytes"]

        again = srv.query(novel)
        assert again.meta["cache"] == "hit"
        assert again.meta["h2d_bytes"] == 0
        assert again == miss
    oracle = simulate_batch([WARM_GRID[0], novel], n_stores=N)
    assert hit == oracle[0]
    assert miss == oracle[1]


def test_sharded_serving_matches_oracle():
    n_shards = min(2, len(jax.devices()))
    with ScenarioServer(n_stores=N, batch_cells=8,
                        n_shards=n_shards) as srv:
        served = srv.query_batch(WARM_GRID)
        novel = [ScenarioSpec("barnes", "proactive", seed=2)]
        served += srv.query_batch(novel)
    oracle = simulate_batch(WARM_GRID + novel, n_stores=N)
    for a, b in zip(served, oracle):
        assert a == b, (a.meta, b.meta)


def test_capacity_growth_reuploads_and_stays_bitident():
    """Appends past the device capacity trigger a (rare) full re-upload
    at the grown shape -- answers must stay bit-identical across the
    capacity step and resident rows must survive it."""
    base = [ScenarioSpec("ycsb", "proactive", n_replicas=r)
            for r in (1, 2)]
    with ScenarioServer(n_stores=N, batch_cells=8, row_pad=4) as srv:
        first = srv.query_batch(base)
        assert srv.stats()["bank_uploads"] == 1
        cap0 = srv.stats()["bank_capacity"]
        # 6 novel wv rows blow through the 4-row quantum
        grow = [ScenarioSpec(w, "proactive", n_replicas=4)
                for w in WORKLOAD_POOL] + \
               [ScenarioSpec("ycsb", "baseline", link_bw_gbps=40.0)]
        grown = srv.query_batch(grow)
        st_ = srv.stats()
        assert st_["bank_uploads"] == 2
        assert st_["bank_capacity"][1] > cap0[1]
        assert _row_capacity(st_["bank_rows"], 4) >= st_["bank_capacity"][1] \
            or st_["bank_capacity"][1] > st_["dev_rows"][1]
        recheck = srv.query_batch(base)             # old lanes still hit
        assert all(r.meta["cache"] == "hit" for r in recheck)
    oracle = simulate_batch(base + grow, n_stores=N)
    for a, b in zip(first + grown, oracle):
        assert a == b


# ---------------------------------------------------------------------------
# Compile-count regression: steady-state serving compiles nothing
# ---------------------------------------------------------------------------


def test_steady_state_serving_compiles_zero_programs():
    """After warmup, 100 mixed queries (hits, novel in-capacity misses,
    batches, singles) trace zero new tile programs."""
    with ScenarioServer(n_stores=N, batch_cells=8) as srv:
        srv.warm(WARM_GRID)
        tc0 = E.trace_count()
        rng = np.random.default_rng(7)
        novel = sweep_grid(workloads=WORKLOAD_POOL,
                           configs=("proactive", "baseline"),
                           seeds=(0, 1, 2), n_replicas=(2,),
                           sb_sizes=(None, 48))
        queries = [WARM_GRID[rng.integers(len(WARM_GRID))]
                   if rng.random() < 0.5
                   else novel[rng.integers(len(novel))]
                   for _ in range(100)]
        for q in queries[:50]:
            srv.query(q)                            # single-cell flushes
        srv.query_batch(queries[50:])               # one batched flush
        st_ = srv.stats()
        assert E.trace_count() == tc0, \
            f"steady-state serving traced {E.trace_count() - tc0} programs"
        assert st_["compiled_programs"] == 0
        assert st_["lane_misses"] > 0               # misses really ran
        assert st_["lane_hits"] > 0


# ---------------------------------------------------------------------------
# Bank-key stability pins (the _plane_keys contract of PRs 4-6)
# ---------------------------------------------------------------------------


def test_bank_key_stability_pins():
    """The serving refactor must not move a single bank row or lane:
    mega_grid keeps its 27 + 1298 rows (and 2 700 scan lanes), and the
    coupled mega-grids keep their lane counts."""
    mega = mega_grid()
    trace_map, wv_map = bank_row_maps(mega)
    assert len(trace_map) == 27
    assert len(wv_map) == 1298
    assert lane_count(mega) == 2700
    assert lane_count(contention_mega_grid()) == 990
    assert lane_count(directory_mega_grid()) == 2160


def test_bank_bytes_stable_across_serving_refactor():
    """Byte-level pin on a materialized sub-grid: the extend-capable
    bank builds the same columns (same bytes, same row order) as the
    pre-refactor from-scratch path, and serving a grid does not perturb
    the memoized bank another engine would resolve."""
    sub = mega_grid(seeds=(0,), replicas=(1, 3), bandwidths=(160.0, 40.0),
                    cn_counts=(16,), sb_sizes=(72, 48))
    scratch = S._make_trace_bank(tuple(sub), N, PAPER_CLUSTER)
    with ScenarioServer(n_stores=N, batch_cells=8) as srv:
        srv.warm(sub, populate=False)
        srv.query_batch(sub[: len(sub) // 2])
        bank = srv._bank
        assert bank.trace_row == scratch.trace_row
        assert bank.wv_row == scratch.wv_row
        assert bank.arrivals.tobytes() == scratch.arrivals.tobytes()
        assert bank.w.tobytes() == scratch.w.tobytes()
        assert bank.v.tobytes() == scratch.v.tobytes()
        assert bank.pr_nc.tobytes() == scratch.pr_nc.tobytes()


# ---------------------------------------------------------------------------
# LRU bounds: lane eviction + bank compaction (PR-8 satellite)
# ---------------------------------------------------------------------------


def test_lru_lane_eviction_reask_bitident():
    """With max_lanes set, the least-recently-asked lanes are evicted
    past the bound; an evicted-then-reasked query takes the miss path
    again and stays bit-identical to its first answer and the oracle."""
    grid = sweep_grid(workloads=WORKLOAD_POOL, configs=("proactive", "wb"),
                      n_replicas=(None, 2))
    assert lane_count(grid) > 6
    with ScenarioServer(n_stores=N, batch_cells=8, max_lanes=6) as srv:
        first = srv.query_batch(grid)
        st_ = srv.stats()
        assert st_["lanes_cached"] == 6
        assert st_["lane_evictions"] == lane_count(grid) - 6
        # grid[0]'s lane was served earliest -> evicted -> a miss again
        re0 = srv.query(grid[0])
        assert re0.meta["cache"] == "miss"
        assert re0 == first[0]
        # ...and the most recent lanes are still resident hits
        re_last = srv.query(grid[-1])
        assert re_last.meta["cache"] == "hit"
        assert re_last == first[-1]
        # hammering one hot lane never evicts it (move_to_end on hit)
        for _ in range(4):
            srv.query_batch([grid[-1], grid[0]])
        assert srv.query(grid[0]).meta["cache"] == "hit"
    oracle = simulate_batch(grid, n_stores=N)
    for a, b in zip(first, oracle):
        assert a == b


def test_bank_compaction_bounds_rows_and_stays_bitident():
    """max_bank_rows compacts the append-only bank down to the live
    cached lanes' rows; answers before, across, and after compactions
    all == the oracle, and the compaction counter advances."""
    grid = sweep_grid(workloads=WORKLOAD_POOL,
                      configs=("proactive", "wb", "baseline"),
                      n_replicas=(None, 2, 3))
    with ScenarioServer(n_stores=N, batch_cells=8, row_pad=4,
                        max_lanes=4, max_bank_rows=12) as srv:
        served = [srv.query(s) for s in grid]
        st_ = srv.stats()
        assert st_["bank_compactions"] >= 1
        assert st_["lane_evictions"] > 0
        # the live bank tracks the bounded lane set, not query history
        assert st_["bank_rows"] < lane_count(grid) * 2
        again = [srv.query(s) for s in grid]
    oracle = simulate_batch(grid, n_stores=N)
    for a, b, c in zip(served, again, oracle):
        assert a == c and b == c
    with pytest.raises(ValueError):
        ScenarioServer(n_stores=N, max_lanes=0)
    with pytest.raises(ValueError):
        ScenarioServer(n_stores=N, max_bank_rows=1)


# ---------------------------------------------------------------------------
# Query translation: grid deltas and downtime requests
# ---------------------------------------------------------------------------


def test_grid_delta_translation():
    axes = dict(workloads=("ycsb", "canneal"), configs=("wb", "proactive"),
                sb_sizes=(None, 48), n_replicas=(None, 3, 4))
    delta = grid_delta(WARM_GRID, **axes)
    full = sweep_grid(**axes)
    assert delta == [s for s in full if s not in set(WARM_GRID)]
    assert all(s.n_replicas == 4 for s in delta)    # only the new axis val
    assert grid_delta(full, **axes) == []
    with ScenarioServer(n_stores=N, batch_cells=8) as srv:
        srv.warm(WARM_GRID)
        srv.reset_stats()
        served = srv.query_grid(**axes)
        st_ = srv.stats()
    assert st_["lane_hits"] >= len(full) - len(delta)
    oracle = simulate_batch(full, n_stores=N)
    for a, b in zip(served, oracle):
        assert a == b


def test_downtime_queries_match_recovery_model():
    est = downtime_query("ycsb", 50.0, n_cns=8)
    sweep = recovery_sweep(workloads=("ycsb",), fail_times_ms=(50.0,),
                           cn_counts=(8,))
    assert np.isclose(est.total_ns, float(sweep.total_ns[0, 0, 0]),
                      rtol=1e-9)
    # coupling axes move the estimate the same direction as the sweep's
    loaded = downtime_query("ycsb", 50.0, n_cns=8, directory_load=0.5)
    assert loaded.directory_ns > est.directory_ns
    with ScenarioServer(n_stores=N) as srv:
        got = srv.query_downtime("ycsb", 50.0, n_cns=8)
        assert got == est
        assert srv.stats()["downtime_queries"] == 1


# ---------------------------------------------------------------------------
# Async batching + threaded stress vs clear_sim_caches()
# ---------------------------------------------------------------------------


def test_submit_futures_batch_and_resolve():
    with ScenarioServer(n_stores=N, batch_cells=64,
                        batch_window_ms=100.0) as srv:
        srv.warm(WARM_GRID)
        srv.reset_stats()
        futs = [srv.submit(s) for s in WARM_GRID + WARM_GRID]
        got = [f.result(timeout=120) for f in futs]
        st_ = srv.stats()
        assert st_["queries"] == 2 * len(WARM_GRID)
        # the window coalesced concurrent submissions into few flushes
        assert 1 <= st_["batches"] <= 8
    oracle = simulate_batch(WARM_GRID, n_stores=N)
    for a, b in zip(got, oracle + oracle):
        assert a == b
    with pytest.raises(RuntimeError):
        srv.submit(WARM_GRID[0])                    # closed


def test_concurrent_queries_race_cache_clears_bitident():
    """N threads hammer the daemon (sync + async paths) while another
    thread repeatedly drops every host/compile cache: no deadlock, no
    bank double-build, every answer still == the oracle."""
    oracle = simulate_batch(WARM_GRID, n_stores=N)
    novel = [ScenarioSpec(w, "proactive", seed=2, n_replicas=2)
             for w in WORKLOAD_POOL]
    novel_oracle = simulate_batch(novel, n_stores=N)
    want = {s: r for s, r in zip(WARM_GRID + novel,
                                 list(oracle) + list(novel_oracle))}

    with ScenarioServer(n_stores=N, batch_cells=8,
                        batch_window_ms=1.0) as srv:
        srv.warm(WARM_GRID)
        stop = threading.Event()
        errors = []

        def clearer():
            while not stop.is_set():
                clear_sim_caches()

        def worker(seed):
            rng = np.random.default_rng(seed)
            pool = WARM_GRID + novel
            try:
                for _ in range(6):
                    picks = [pool[rng.integers(len(pool))]
                             for _ in range(4)]
                    if rng.random() < 0.5:
                        got = srv.query_batch(picks)
                    else:
                        got = [f.result(timeout=120)
                               for f in map(srv.submit, picks)]
                    for s, r in zip(picks, got):
                        if r != want[s]:
                            errors.append((s, r, want[s]))
            except Exception as e:                  # pragma: no cover
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(6)]
        clr = threading.Thread(target=clearer)
        clr.start()
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
        stop.set()
        clr.join(timeout=60)
        alive = [t for t in threads + [clr] if t.is_alive()]
        assert not alive, f"deadlocked threads: {alive}"
        assert not errors, errors[:3]
        st_ = srv.stats()
        assert st_["bank_builds"] == 1, "bank was rebuilt under the race"


def test_submit_timeout_fails_future_with_diagnostic():
    """A submit() deadline expiring -- queued OR mid-flush -- fails
    that future with a TimeoutError diagnostic instead of blocking the
    caller forever (the watchdog satellite of PR 9)."""
    with ScenarioServer(n_stores=N, batch_cells=8) as srv:
        srv._lock.acquire()                 # wedge the flush path
        try:
            fut = srv.submit(WARM_GRID[0], timeout_ms=50)
            with pytest.raises(TimeoutError, match="timed out"):
                fut.result(timeout=30)
        finally:
            srv._lock.release()
        assert srv.stats()["submit_timeouts"] >= 1
        # the daemon is still healthy afterwards
        ok = srv.submit(WARM_GRID[0], timeout_ms=60_000).result(timeout=120)
        assert ok == simulate_batch([WARM_GRID[0]], n_stores=N)[0]
    with pytest.raises(ValueError):
        ScenarioServer(n_stores=N, submit_timeout_ms=0)


def test_watchdog_fails_wedged_flush():
    """watchdog_ms bounds a wedged daemon flush: every future of the
    stuck batch fails with a diagnostic naming the watchdog."""
    with ScenarioServer(n_stores=N, batch_cells=8,
                        watchdog_ms=100) as srv:
        srv._lock.acquire()
        try:
            futs = [srv.submit(s) for s in WARM_GRID[:2]]
            for f in futs:
                with pytest.raises(TimeoutError, match="watchdog"):
                    f.result(timeout=30)
        finally:
            srv._lock.release()
        assert srv.stats()["watchdog_flush_failures"] >= 1


def test_close_drains_or_fails_pending_deterministically():
    """close() under concurrent submitters: every outstanding future is
    either resolved (flushed during the drain) or failed with a
    RuntimeError -- never left pending."""
    srv = ScenarioServer(n_stores=N, batch_cells=8)
    srv._lock.acquire()                     # hold the daemon mid-flush
    fut = srv.submit(WARM_GRID[0])
    closer = threading.Thread(target=srv.close)
    closer.start()
    srv._lock.release()
    closer.join(timeout=120)
    assert not closer.is_alive(), "close() hung on a pending queue"
    try:
        res = fut.result(timeout=30)        # drained during close
        assert res == simulate_batch([WARM_GRID[0]], n_stores=N)[0]
    except RuntimeError:
        pass                                # or failed deterministically
    with pytest.raises(RuntimeError):
        srv.submit(WARM_GRID[0])


def test_stats_snapshot_is_deep_copied_and_consistent():
    """stats() is a deep-copied snapshot taken under the server lock
    (the PR-10 race regression): a reader hammering it while another
    thread serves never observes a half-updated counter set -- in every
    snapshot lane_hits + lane_misses == queries exactly -- and a
    captured snapshot is frozen, i.e. later queries (and caller-side
    mutation) never alter it or the live counters."""
    with ScenarioServer(n_stores=N, batch_cells=8) as srv:
        srv.warm(WARM_GRID)
        srv.reset_stats()
        snaps = []
        errors = []
        stop = threading.Event()

        def reader():
            while not stop.is_set():
                st_ = srv.stats()
                if st_["lane_hits"] + st_["lane_misses"] != st_["queries"]:
                    errors.append(st_)
                snaps.append(st_)

        rd = threading.Thread(target=reader)
        rd.start()
        rng = np.random.default_rng(7)
        for _ in range(40):
            picks = [WARM_GRID[rng.integers(len(WARM_GRID))]
                     for _ in range(3)]
            srv.query_batch(picks)
        stop.set()
        rd.join(timeout=60)
        assert not rd.is_alive(), "stats() reader deadlocked"
        assert not errors, f"torn snapshot(s): {errors[:2]}"
        assert snaps and snaps[-1]["queries"] <= 120

        # frozen: later traffic + caller mutation leave the capture and
        # the live counters untouched
        frozen = srv.stats()
        before = frozen["queries"]
        srv.query_batch([WARM_GRID[0]])
        assert frozen["queries"] == before
        frozen["lane_hits"] = -1
        frozen["bank_capacity"] = None
        live = srv.stats()
        assert live["queries"] == before + 1
        assert live["lane_hits"] >= 0 and live["bank_capacity"] is not None
