"""Property tests for the scenario engine (core/scenarios.py).

Under arbitrary fail-stop schedules the ReCXL design guarantees that
recovery replay is deterministic and idempotent, that the repaired
directory never references a failed node, and that the recovered memory
equals the live truth. The batched sweep side must keep the paper's
headline geomeans inside the PAPER_CLAIMS acceptance bands.
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.recxl_paper import PAPER_CLAIMS, WORKLOADS
from repro.core.failures import FailureEvent
from repro.core.scenarios import (
    FaultScenario,
    directory_references,
    enumerate_fault_scenarios,
    fig10_grid,
    fig16_grid,
    fig17_grid,
    fig18_grid,
    run_fault_scenario,
    sweep_grid,
)
from repro.core.simulator import (CONFIGS, geomean_slowdowns,
                                  simulate_batch, slowdowns_from_results)

needs_devices = pytest.mark.skipif(jax.device_count() < 4,
                                   reason="needs >= 4 devices")


# ---------------------------------------------------------------------------
# Sweep grids
# ---------------------------------------------------------------------------

def test_grid_builders_shapes():
    assert len(fig10_grid()) == len(WORKLOADS) * len(CONFIGS)
    assert len(fig16_grid()) == 3 * 2 * 4
    assert len(fig17_grid()) == len(WORKLOADS) * 4
    assert len(fig18_grid()) == 3 * 2 * 3
    assert all(s.config == "proactive" for s in fig17_grid())
    grid = sweep_grid(workloads=("ycsb",), configs=("wb",), seeds=(0, 1),
                      sb_sizes=(36, 72))
    assert len(grid) == 4


@pytest.fixture(scope="module")
def fig10_results():
    return simulate_batch(fig10_grid(), n_stores=20_000)


def test_fig10_geomeans_inside_paper_bands(fig10_results):
    """The batched sweep must reproduce the paper's headline geomeans
    (same acceptance bands as the serial tests in test_simulator.py)."""
    table = slowdowns_from_results(fig10_results)
    gm = geomean_slowdowns(table)
    assert 6.0 <= gm["wt"] <= 9.5, gm
    assert 2.3 <= gm["baseline"] <= 3.5, gm
    assert 1.1 <= gm["proactive"] <= 1.55, gm
    gain = 1.0 - gm["parallel"] / gm["baseline"]
    assert 0.0 <= gain <= 0.10, gm


def test_fig17_nr_overhead_band():
    """N_r=4 stays within a few percent of N_r=3 (paper Fig. 17)."""
    grid = fig17_grid(replicas=(3, 4), workloads=("bodytrack", "canneal",
                                                  "ycsb"))
    res = simulate_batch(grid, n_stores=20_000)
    t = {(r.workload, s.n_replicas): r.exec_time_ns
         for r, s in zip(res, grid)}
    ratios = [t[(w, 4)] / t[(w, 3)] for w in ("bodytrack", "canneal",
                                              "ycsb")]
    assert 0.99 <= float(np.mean(ratios)) <= 1.15


# ---------------------------------------------------------------------------
# Fault scenarios
# ---------------------------------------------------------------------------

def test_enumerate_fault_scenarios_cover_all_nodes_and_variants():
    scns = enumerate_fault_scenarios(n_nodes=4, n_steps=6)
    assert len(scns) == 3 * (4 * 4 + 1)
    for v in ("baseline", "parallel", "proactive"):
        nodes = {e.node for s in scns if s.variant == v for e in s.events}
        assert nodes == {0, 1, 2, 3}


def test_fault_scenario_validation():
    with pytest.raises(ValueError):
        FaultScenario(name="bad", events=(), variant="nosuch").validate()
    with pytest.raises(ValueError):
        FaultScenario(name="bad", events=(FailureEvent(step=1, node=9),)
                      ).validate()
    with pytest.raises(ValueError):
        FaultScenario(name="bad", events=(), n_replicas=4,
                      n_nodes=4).validate()


@st.composite
def fail_stop_schedules(draw):
    """1-2 fail-stop events at arbitrary steps on distinct nodes."""
    n = draw(st.integers(1, 2))
    steps = draw(st.lists(st.integers(1, 4), min_size=n, max_size=n))
    nodes = draw(st.lists(st.integers(0, 3), min_size=n, max_size=n,
                          unique=True))
    return tuple(FailureEvent(step=s, node=node)
                 for s, node in zip(sorted(steps), nodes))


@needs_devices
@given(fail_stop_schedules(),
       st.sampled_from(["baseline", "parallel", "proactive"]))
@settings(max_examples=4, deadline=None)
def test_recovery_invariants_under_arbitrary_schedules(events, variant):
    scn = FaultScenario(name="prop", events=events, variant=variant,
                        n_steps=6)
    out = run_fault_scenario(scn)
    assert out.failed_nodes == tuple(sorted({e.node for e in events}))
    assert len(out.checks) == len(out.failed_nodes)
    for c in out.checks:
        assert c.unrecoverable == 0, c
        assert c.replay_idempotent, c
        assert c.directory_consistent, c
        assert c.exact, c
        assert c.newest_ts == c.step       # newest validated version wins
        assert c.downtime_ns > 0, c        # SS VII-E estimate attached
    assert not directory_references(out.directory, set(out.failed_nodes))
    assert out.resumed
    assert out.total_downtime_ns > 0


@needs_devices
def test_coalescing_and_capacity_wrap_recovery():
    """Ring wrap (n_steps > log_capacity) + coalesced REPLs still recover
    the newest version."""
    scn = FaultScenario(name="wrap", events=(FailureEvent(step=5, node=2),),
                        n_steps=7, coalescing=True, log_capacity=2)
    out = run_fault_scenario(scn)
    assert out.all_invariants_hold
    assert out.checks[0].newest_ts == 5


@needs_devices
def test_fault_scenario_contention_scales_downtime():
    """The same fail-stop schedule yields contention-dependent downtime:
    conflicted ownership churn inflates the crash-exposed volumes, an
    eager persist schedule shrinks them (docs/contention.md)."""
    ev = (FailureEvent(step=2, node=1),)
    base = run_fault_scenario(FaultScenario(name="base", events=ev))
    hot = run_fault_scenario(FaultScenario(name="hot", events=ev,
                                           conflict_rate=0.6))
    eager = run_fault_scenario(FaultScenario(
        name="eager", events=ev, consistency_schedule="eager"))
    assert base.all_invariants_hold and hot.all_invariants_hold
    assert hot.total_downtime_ns > base.total_downtime_ns
    assert eager.total_downtime_ns < base.total_downtime_ns
    with pytest.raises(ValueError):
        FaultScenario(name="bad", events=ev, conflict_rate=3.0).validate()


@needs_devices
def test_straggler_events_recorded_not_failed():
    scn = FaultScenario(
        name="straggler",
        events=(FailureEvent(step=1, node=3, kind="straggler", delay_s=0.5),
                FailureEvent(step=3, node=1)),
        n_steps=5)
    out = run_fault_scenario(scn)
    assert out.failed_nodes == (1,)
    assert out.stragglers == {3: 0.5}
    assert out.all_invariants_hold
