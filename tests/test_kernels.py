"""Per-kernel shape/dtype sweeps vs. the pure-jnp oracles (interpret
mode on CPU), plus hypothesis property tests on the compressor."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attn.ops import flash_attention
from repro.kernels.flash_attn.ref import attention_ref
from repro.kernels.log_compress import compress, decompress, compression_factor
from repro.kernels.log_compress.ref import compress_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (b, sq, skv, h, kh, d, causal, dtype)
    (2, 256, 256, 4, 2, 64, True, jnp.float32),
    (1, 128, 128, 8, 8, 32, True, jnp.float32),     # MHA
    (1, 128, 128, 8, 1, 64, True, jnp.float32),     # MQA
    (2, 192, 192, 6, 2, 64, True, jnp.bfloat16),    # bf16 + unaligned
    (1, 64, 320, 4, 2, 64, True, jnp.float32),      # kv longer (decode-ish)
    (1, 256, 256, 4, 4, 128, False, jnp.float32),   # non-causal
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("path", ["pallas_interpret", "jnp"])
def test_flash_attention_vs_ref(case, path):
    b, sq, skv, h, kh, d, causal, dt = case
    q = jnp.asarray(RNG.standard_normal((b, sq, h, d)), dt)
    k = jnp.asarray(RNG.standard_normal((b, skv, kh, d)), dt)
    v = jnp.asarray(RNG.standard_normal((b, skv, kh, d)), dt)
    ref = attention_ref(q, k, v, causal).astype(jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          force=path).astype(jnp.float32)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out, ref, atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # (b, l, h, p, n, chunk, dtype)
    (2, 128, 4, 16, 32, 32, jnp.float32),
    (1, 96, 2, 64, 128, 32, jnp.float32),   # unaligned l
    (2, 64, 3, 32, 16, 64, jnp.float32),
    (1, 128, 2, 32, 32, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("path", ["pallas_interpret", "jnp"])
def test_ssd_scan_vs_ref(case, path):
    b, l, h, p, n, chunk, dt = case
    x = jnp.asarray(RNG.standard_normal((b, l, h, p)) * 0.5, dt)
    dtt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, l, h)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(RNG.standard_normal((b, l, n)) * 0.3, dt)
    C = jnp.asarray(RNG.standard_normal((b, l, n)) * 0.3, dt)
    y_ref, s_ref = ssd_ref(x, dtt, A, B, C)
    y, s = ssd_scan(x, dtt, A, B, C, chunk=chunk, force=path)
    scale = float(jnp.max(jnp.abs(y_ref.astype(jnp.float32)))) + 1e-9
    tol = 3e-2 if dt == jnp.bfloat16 else 1e-5
    assert float(jnp.max(jnp.abs(
        y.astype(jnp.float32) - y_ref.astype(jnp.float32)))) / scale < tol
    assert float(jnp.max(jnp.abs(
        s.astype(jnp.float32) - s_ref.astype(jnp.float32)))) < tol * 10


# ---------------------------------------------------------------------------
# log compressor
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [256, 1000, 4096, 12345])
@pytest.mark.parametrize("bits", [8, 4])
def test_compress_roundtrip_error_bound(n, bits):
    vals = jnp.asarray(RNG.standard_normal(n), jnp.float32)
    base = vals + jnp.asarray(RNG.standard_normal(n) * 0.02, jnp.float32)
    codes, scales = compress(vals, base, bits=bits)
    rec = decompress(codes, scales, base, n)
    # error bounded by half a quantization step per block
    bound = float(jnp.max(scales)) * 0.51
    assert float(jnp.max(jnp.abs(rec - vals))) <= bound


def test_compress_pallas_matches_ref_bitexact():
    n = 8 * 256 * 3
    vals = jnp.asarray(RNG.standard_normal(n), jnp.float32).reshape(-1, 256)
    base = jnp.zeros_like(vals)
    codes_k, scales_k = compress(vals.reshape(-1), base.reshape(-1))
    codes_r, scales_r = compress_ref(vals, base)
    assert bool(jnp.all(codes_k == codes_r))
    np.testing.assert_allclose(scales_k, scales_r, rtol=1e-7)


def test_compression_factor_reported():
    assert 3.5 < compression_factor(8) < 4.0
    assert 7.0 < compression_factor(4) < 8.0


@given(st.integers(1, 2000), st.floats(0.0, 10.0))
@settings(max_examples=20, deadline=None)
def test_property_compress_zero_delta(n, basefill):
    """values == base => all codes zero, perfect reconstruction."""
    vals = jnp.full((n,), basefill, jnp.float32)
    codes, scales = compress(vals, vals)
    assert bool(jnp.all(codes == 0))
    rec = decompress(codes, scales, vals, n)
    np.testing.assert_allclose(rec, vals)
