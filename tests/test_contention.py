"""Contention & crash-consistency subsystem (repro.core.contention).

The contract (docs/contention.md):

* contended timelines are **bit-identical** (``==``) across the
  pure-Python pre-collapse oracle, the jitted serial oracle, the
  blocked batch (both data planes) and the banked streaming engine, on
  ragged mixed-SB grids;
* all-``None`` contention axes are inert -- outputs AND bank dedup
  keys reproduce today's bit-exactly (no row churn on legacy grids:
  the 12 960-cell mega-grid keeps its 27+1298 bank rows);
* neutral axis values (0.0 / 0.0 / "lazy") yield bit-identical
  *outputs* while occupying their own bank row (the in-grid
  normalization cell);
* slowdown is monotone in the contention knobs, and the SS VII-E
  downtime model now varies with the contention regime;
* the contention memo caches are dropped by ``clear_sim_caches()``.
"""

import gc
import weakref

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.recxl_paper import WORKLOADS
from repro.core import contention as C
from repro.core import engine as E
from repro.core import simulator as S
from repro.core.contention import (
    CONSISTENCY_SCHEDULES,
    ContentionParams,
    dirty_line_scale,
    resolve_contention,
    serial_oracle,
    undumped_log_scale,
)
from repro.core.scenarios import (
    contention_grid,
    contention_mega_grid,
    mega_grid,
    recovery_sweep,
)
from repro.core.simulator import (
    ScenarioSpec,
    bank_row_maps,
    clear_sim_caches,
    simulate_batch,
    simulate_spec,
)

N = 700                                  # N % 72 != 0: ragged store tail
FLOAT_FIELDS = ("exec_time_ns", "repl_at_head_frac", "sb_full_frac",
                "max_log_bytes", "cxl_mem_bw_gbps", "log_dump_bw_gbps")
WORKLOAD_POOL = ("ycsb", "canneal", "barnes", "raytrace")


def _assert_identical(a, b, ctx):
    assert a.n_repl_msgs == b.n_repl_msgs, ctx
    for f in FLOAT_FIELDS:
        assert getattr(a, f) == getattr(b, f), (ctx, f)


# ---------------------------------------------------------------------------
# Axis resolution + validation
# ---------------------------------------------------------------------------

def test_resolve_contention_none_and_partial():
    assert resolve_contention(None, None, None) is None
    p = resolve_contention(None, 0.3, None)
    assert p == ContentionParams(read_share=0.0, conflict_rate=0.3,
                                 schedule="lazy")
    p = resolve_contention(0.5, None, "eager")
    assert p.schedule == "eager" and p.conflict_rate == 0.0


def test_contention_validation_rejected():
    for bad in (ScenarioSpec("ycsb", "proactive", conflict_rate=1.0),
                ScenarioSpec("ycsb", "proactive", conflict_rate=-0.1),
                ScenarioSpec("ycsb", "proactive", read_share=1.5),
                ScenarioSpec("ycsb", "proactive",
                             consistency_schedule="nosuch")):
        with pytest.raises(ValueError):
            simulate_batch([bad], n_stores=N)
    with pytest.raises(ValueError):
        C.schedule_flush_ns("nosuch", 8, S.PAPER_CLUSTER)


# ---------------------------------------------------------------------------
# Differential bit-identity across every path (the oracle discipline)
# ---------------------------------------------------------------------------

@st.composite
def contended_grids(draw):
    """Ragged mixed-SB grids spanning every contention axis."""
    n = draw(st.integers(min_value=1, max_value=10))
    specs = []
    for _ in range(n):
        specs.append(ScenarioSpec(
            draw(st.sampled_from(WORKLOAD_POOL)),
            draw(st.sampled_from(S.CONFIGS)),
            seed=draw(st.integers(min_value=0, max_value=1)),
            n_replicas=draw(st.sampled_from((None, 4))),
            n_cns=draw(st.sampled_from((None, 8))),
            sb_size=draw(st.sampled_from((None, 16, 24))),
            read_share=draw(st.sampled_from((None, 0.0, 0.4, 0.8))),
            conflict_rate=draw(st.sampled_from((None, 0.0, 0.25, 0.6))),
            consistency_schedule=draw(st.sampled_from(
                (None,) + CONSISTENCY_SCHEDULES))))
    return specs


@settings(max_examples=6, deadline=None)
@given(contended_grids())
def test_contended_paths_bit_identical(specs):
    banked = simulate_batch(specs, n_stores=N)
    stacked = simulate_batch(specs, n_stores=N, data_plane="stacked")
    stream = E.run_grid(specs, n_stores=N, tile_cells=16)
    for i, s in enumerate(specs):
        serial = simulate_spec(s, n_stores=N)
        oracle = serial_oracle(s, n_stores=N)
        _assert_identical(oracle, serial, (s, "oracle-vs-serial"))
        _assert_identical(banked[i], serial, (s, "banked-vs-serial"))
        _assert_identical(stacked[i], serial, (s, "stacked-vs-serial"))
        _assert_identical(stream[i], serial, (s, "stream-vs-serial"))


def test_neutral_axes_reproduce_legacy_bits_in_new_row():
    """(0.0, 0.0, "lazy") must equal the axes-off cell bit-for-bit --
    the delays are exactly zero -- while occupying its own bank row."""
    legacy = ScenarioSpec("ycsb", "proactive")
    neutral = ScenarioSpec("ycsb", "proactive", read_share=0.0,
                           conflict_rate=0.0, consistency_schedule="lazy")
    a, b = simulate_batch([legacy, neutral], n_stores=N)
    _assert_identical(a, b, "neutral-vs-legacy")
    bank = S.get_trace_bank([legacy, neutral], N)
    assert bank.rows_for(legacy)[1] != bank.rows_for(neutral)[1]
    assert bank.rows_for(legacy)[0] == bank.rows_for(neutral)[0]  # trace


def test_wb_wt_rows_stay_constant_under_contention():
    """WB/WT commit locally: contention never perturbs them, so their
    constant bank rows (and the WB normalization baseline) survive a
    contended grid."""
    specs = [ScenarioSpec("ycsb", c, conflict_rate=cr)
             for c in ("wb", "wt") for cr in (None, 0.6)]
    bank = S.get_trace_bank(specs, N)
    assert bank.wv_rows == 2
    res = simulate_batch(specs, n_stores=N)
    _assert_identical(res[0], res[1], "wb-contended")
    _assert_identical(res[2], res[3], "wt-contended")


# ---------------------------------------------------------------------------
# No bank-key churn for legacy grids
# ---------------------------------------------------------------------------

def test_legacy_plane_keys_unchanged():
    """Axes-off specs must produce the exact PR-4 key format (no
    appended contention component)."""
    tk, wk = S._plane_keys(ScenarioSpec("ycsb", "proactive"),
                           S.PAPER_CLUSTER)
    assert tk == ("ycsb", 0)
    assert wk == ("proactive", "ycsb", 0, 3, 160.0, True)
    _, wk = S._plane_keys(ScenarioSpec("ycsb", "wb", conflict_rate=0.5),
                          S.PAPER_CLUSTER)
    assert wk == ("wb",)
    _, wk = S._plane_keys(
        ScenarioSpec("ycsb", "proactive", conflict_rate=0.5),
        S.PAPER_CLUSTER)
    assert len(wk) == 7 and isinstance(wk[6], ContentionParams)


def test_mega_grid_bank_rows_unchanged():
    """The 12 960-cell legacy mega-grid keeps its PR-4 dedup: 27 trace
    rows (workload x seed) + 1 298 max-plus rows (2 constants + the
    replicating cross-product) -- contention axes add zero churn."""
    specs = mega_grid()
    assert len(specs) == 12_960
    trace_map, wv_map = bank_row_maps(specs)
    w = len(WORKLOADS)
    assert len(trace_map) == w * 3
    assert len(wv_map) == 2 + 3 * w * 3 * 4 * 4
    assert (len(trace_map), len(wv_map)) == (27, 1298)


# ---------------------------------------------------------------------------
# Semantics: monotone slowdowns, schedule ordering, lane sharing
# ---------------------------------------------------------------------------

def test_slowdown_monotone_in_conflict_rate():
    rates = (0.0, 0.25, 0.6)
    specs = [ScenarioSpec("ycsb", "proactive", conflict_rate=r)
             for r in rates]
    t = [r.exec_time_ns for r in simulate_batch(specs, n_stores=N)]
    assert t[0] < t[1] < t[2], t


def test_schedule_ordering_and_epoch_barriers():
    specs = [ScenarioSpec("ycsb", "proactive", consistency_schedule=sc)
             for sc in CONSISTENCY_SCHEDULES]
    t = {sc: r.exec_time_ns
         for sc, r in zip(CONSISTENCY_SCHEDULES,
                          simulate_batch(specs, n_stores=N))}
    assert t["lazy"] < t["epoch"] < t["eager"], t
    flush = C.schedule_flush_ns("epoch", 3 * C.EPOCH_LEN, S.PAPER_CLUSTER)
    assert np.count_nonzero(flush) == 3
    assert C.schedule_flush_ns("lazy", 16, S.PAPER_CLUSTER).any() == False  # noqa: E712


def test_cn_axis_shares_contended_lanes():
    """Contention keys exclude n_cns, so the CN weak-scaling axis still
    collapses to one scan lane per contended regime."""
    specs = [ScenarioSpec("ycsb", "proactive", n_cns=ncn,
                          conflict_rate=0.4, consistency_schedule="epoch")
             for ncn in (16, 8, 4, 2)]
    res = simulate_batch(specs, n_stores=N)
    assert res[0].meta["scan_lanes"] == 1
    E.run_grid(specs, n_stores=N, tile_cells=16)
    assert E.bank_stats()["scan_lanes"] == 1


def test_contention_grid_builders():
    assert len(contention_grid()) == 3 * 2 * 3 * 2 * 3
    specs = contention_mega_grid()
    assert len(specs) == len(WORKLOADS) * 2 * 2 * 2 * 2 * 3 * 2 * 3
    assert len(specs) >= E.STREAM_THRESHOLD   # auto-routes to streaming
    assert any(s.conflict_rate == 0.5 for s in specs)
    # the neutral normalization corner is present
    assert any(s.conflict_rate == 0.0 and s.read_share == 0.0
               and s.consistency_schedule == "lazy" for s in specs)


def test_contended_streaming_compiles_and_dedup():
    """A contended multi-regime grid still runs on a handful of
    compiled tile programs with scan-lane dedup active."""
    clear_sim_caches()
    specs = contention_mega_grid(
        workloads=("ycsb", "canneal"), seeds=(0,), replicas=(1,),
        cn_counts=(16, 8), conflict_rates=(0.0, 0.5),
        read_shares=(0.0,), schedules=("lazy", "eager"))
    t0 = E.trace_count()
    E.run_grid(specs, n_stores=N, tile_cells=32)
    assert E.trace_count() - t0 <= 3
    stats = E.bank_stats()
    assert stats["scan_lanes"] < stats["cells"] == len(specs)
    assert stats["data_plane"] == "bank"


# ---------------------------------------------------------------------------
# Recovery coupling (conflict-dependent dirty lines -> downtime)
# ---------------------------------------------------------------------------

def test_dirty_line_scales_monotone():
    base = ContentionParams()
    assert dirty_line_scale(base) == 1.0
    assert undumped_log_scale(base) == 1.0
    hot = ContentionParams(conflict_rate=0.6)
    assert dirty_line_scale(hot) > 1.0
    assert undumped_log_scale(hot) > 1.0
    ready = ContentionParams(read_share=0.8)
    assert dirty_line_scale(ready) < 1.0
    eager = ContentionParams(schedule="eager")
    epoch = ContentionParams(schedule="epoch")
    assert dirty_line_scale(eager) < dirty_line_scale(epoch) < 1.0
    assert undumped_log_scale(eager) < undumped_log_scale(epoch) < 1.0


def test_recovery_sweep_varies_with_contention():
    base = recovery_sweep(workloads=("ycsb",), cn_counts=(16,))
    hot = recovery_sweep(workloads=("ycsb",), cn_counts=(16,),
                         conflict_rate=0.6)
    eager = recovery_sweep(workloads=("ycsb",), cn_counts=(16,),
                           consistency_schedule="eager")
    t_mid = base.fail_times_ms[1]
    assert hot.total_ms("ycsb", t_mid, 16) > base.total_ms("ycsb", t_mid, 16)
    assert eager.total_ms("ycsb", t_mid, 16) < base.total_ms("ycsb", t_mid,
                                                             16)
    with pytest.raises(ValueError):
        recovery_sweep(workloads=("ycsb",), conflict_rate=2.0)


# ---------------------------------------------------------------------------
# Cache lifecycle (same discipline as the _BANK_CACHE tests)
# ---------------------------------------------------------------------------

def test_clear_sim_caches_drops_contention_memos():
    clear_sim_caches()
    spec = ScenarioSpec("ycsb", "proactive", conflict_rate=0.4,
                        read_share=0.3)
    simulate_batch([spec], n_stores=N)
    draws, delays = C.contention_cache_sizes()
    assert draws > 0 and delays > 0
    d = C.conflict_draws(N, 0, 0.4, 0.3)       # cache hit
    ref = weakref.ref(d["retries"])
    del d
    clear_sim_caches()
    gc.collect()
    assert C.contention_cache_sizes() == (0, 0)
    assert ref() is None, "contention draw arrays leaked past cache clear"
