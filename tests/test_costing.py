"""Dry-run cost accounting: jaxpr walker trip-count math and the
while-aware HLO collective parser (launch/costing.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.costing import (
    collective_bytes,
    computation_multipliers,
    jaxpr_cost,
)


def test_dot_flops_exact():
    a = jnp.zeros((8, 32), jnp.float32)
    b = jnp.zeros((32, 16), jnp.float32)
    c = jaxpr_cost(lambda a, b: a @ b, (a, b), mesh_size=1)
    assert c["flops"] == 2 * 8 * 32 * 16


def test_scan_trip_count_multiplies():
    w = jnp.zeros((16, 16), jnp.float32)
    x = jnp.zeros((4, 16), jnp.float32)

    def f(w, x):
        def body(h, _):
            return h @ w, None
        h, _ = jax.lax.scan(body, x, None, length=7)
        return h

    c = jaxpr_cost(f, (w, x), mesh_size=1)
    assert c["flops"] == 7 * 2 * 4 * 16 * 16


def test_nested_scan_multiplies():
    w = jnp.zeros((8, 8), jnp.float32)

    def f(w):
        def outer(h, _):
            def inner(h2, _):
                return h2 @ w, None
            h2, _ = jax.lax.scan(inner, h, None, length=3)
            return h2, None
        h, _ = jax.lax.scan(outer, jnp.zeros((2, 8)), None, length=5)
        return h

    c = jaxpr_cost(f, (w,), mesh_size=1)
    assert c["flops"] == 5 * 3 * 2 * 2 * 8 * 8


def test_remat_counts_recompute():
    w = jnp.zeros((16, 16), jnp.float32)
    x = jnp.zeros((4, 16), jnp.float32)

    def loss(w, x):
        @jax.checkpoint
        def block(x):
            return jnp.tanh(x @ w)
        return jnp.sum(block(block(x)))

    plain = jaxpr_cost(lambda w, x: jnp.sum(jnp.tanh(jnp.tanh(x @ w) @ w)),
                       (w, x), mesh_size=1)
    g = jaxpr_cost(lambda w, x: jax.grad(loss)(w, x), (w, x), mesh_size=1)
    # grad-of-remat >= 3x the fwd matmul flops (fwd + recompute + bwd dots)
    assert g["flops"] >= 3 * plain["flops"] * 0.9


def test_vmem_scan_suppresses_bytes_not_flops():
    w = jnp.zeros((64, 64), jnp.float32)
    x = jnp.zeros((8, 64), jnp.float32)

    def f(w, x):
        def body(h, _):
            return h @ w, None
        h, _ = jax.lax.scan(body, x, None, length=11)
        return h

    c_hbm = jaxpr_cost(f, (w, x), mesh_size=1)
    c_vmem = jaxpr_cost(f, (w, x), mesh_size=1,
                        vmem_scan_lengths=frozenset({11}))
    assert c_vmem["flops"] == c_hbm["flops"]
    assert c_vmem["bytes"] < c_hbm["bytes"] * 0.2


def test_shard_map_multiplies_by_devices(mesh8):
    from jax.sharding import PartitionSpec as P

    from repro.distributed.context import shard_map

    w = jnp.zeros((8, 16, 16), jnp.float32)

    def f(w):
        def inner(wl):
            return wl[0] @ wl[0]
        return shard_map(inner, mesh=mesh8,
                         in_specs=P(("data", "model")),
                         out_specs=P(("data", "model")))(w)

    c = jaxpr_cost(f, (w,), mesh_size=8)
    assert c["flops"] == 8 * 2 * 16 * 16 * 16


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

FAKE_HLO = """\
HloModule test

%cond.1 (arg.1: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(28)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body.1 (arg.2: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p2 = (s32[], f32[4]) parameter(0)
  %x = f32[4]{0} get-tuple-element(%p2), index=1
  %ar = f32[4]{0} all-reduce(%x), replica_groups=[16,16]<=[256], to_apply=%sum
  ROOT %t = (s32[], f32[4]) tuple(%x, %ar)
}

ENTRY %main (a: f32[4]) -> f32[4] {
  %a = f32[4]{0} parameter(0)
  %ag = bf16[32]{0} all-gather(%a), replica_groups=[16,16]<=[256], dimensions={0}
  %w = (s32[], f32[4]) while(%init), condition=%cond.1, body=%body.1
  ROOT %r = f32[4]{0} get-tuple-element(%w), index=1
}
"""


def test_multipliers_from_while_condition():
    mult = computation_multipliers(FAKE_HLO)
    assert mult["__entry__"] == 1.0
    assert mult["body.1"] == 28.0


def test_collective_bytes_trip_corrected():
    out = collective_bytes(FAKE_HLO, total_devices=256)
    # the in-loop f32[4] all-reduce counts 28 times: 16B * 2*(15/16) * 28
    ar = out["per_kind_bytes"]["all-reduce"]
    assert abs(ar - 16 * 2 * 15 / 16 * 28) < 1e-6
    # the bf16 all-gather counts once: 64B out * 15/16
    ag = out["per_kind_bytes"]["all-gather"]
    assert abs(ag - 64 * 15 / 16) < 1e-6
    # f32 promotion adjustment: only the AR payload is f32-wide
    assert out["f32_bytes"] == pytest.approx(ar)
    assert out["total_bytes_bf16adj"] == pytest.approx(ar / 2 + ag)
