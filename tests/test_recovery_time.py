"""Property tests for the SS VII-E recovery-time (downtime) model.

The model's contract: estimated downtime is strictly monotone
*increasing* in the log-replay volume (owned lines and undumped log
bytes) and strictly monotone *decreasing* in the CXL link bandwidth;
the batched sweep applies the same arithmetic as the scalar model; and
fault-scenario outcomes carry per-event estimates fed by the volumes the
replay actually moved.
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.recxl_paper import PAPER_CLUSTER, WORKLOADS
from repro.core.failures import FailureEvent
from repro.core.recovery import (
    DEFAULT_RECOVERY_PARAMS,
    estimate_recovery_time,
    recovery_time_batch,
    workload_recovery_inputs,
)
from repro.core.scenarios import (
    DEFAULT_FAIL_FRACS,
    FaultScenario,
    recovery_sweep,
    run_fault_scenario,
)

needs_devices = pytest.mark.skipif(jax.device_count() < 4,
                                   reason="needs >= 4 devices")

owned_st = st.floats(min_value=1.0, max_value=1e7)
bytes_st = st.floats(min_value=0.0, max_value=1e9)
bw_st = st.floats(min_value=1.0, max_value=512.0)
factor_st = st.floats(min_value=1.1, max_value=16.0)


# ---------------------------------------------------------------------------
# Scalar model properties
# ---------------------------------------------------------------------------

@given(owned_st, bytes_st, bw_st, factor_st)
@settings(max_examples=20, deadline=None)
def test_downtime_monotone_in_replay_volume(owned, undumped, bw, factor):
    base = estimate_recovery_time(owned, undumped, link_bw_gbps=bw)
    more_log = estimate_recovery_time(owned, undumped * factor + 1.0,
                                      link_bw_gbps=bw)
    more_owned = estimate_recovery_time(owned * factor, undumped,
                                        link_bw_gbps=bw)
    assert more_log.total_ns > base.total_ns
    assert more_log.replay_bytes > base.replay_bytes
    assert more_owned.total_ns > base.total_ns
    assert more_owned.replay_bytes > base.replay_bytes


@given(owned_st, bytes_st, bw_st, factor_st)
@settings(max_examples=20, deadline=None)
def test_downtime_inverse_monotone_in_bandwidth(owned, undumped, bw, factor):
    slow = estimate_recovery_time(owned, undumped, link_bw_gbps=bw)
    fast = estimate_recovery_time(owned, undumped, link_bw_gbps=bw * factor)
    assert fast.total_ns < slow.total_ns
    # bandwidth only affects the transfer phases
    assert fast.log_scan_ns == slow.log_scan_ns
    assert fast.directory_ns == slow.directory_ns
    assert fast.replay_bytes == slow.replay_bytes


def test_estimate_phases_sum_and_validation():
    est = estimate_recovery_time(1000.0, 1e6)
    total = (est.detect_ns + est.quiesce_ns + est.directory_ns +
             est.log_scan_ns + est.fetch_ns + est.writeback_ns +
             est.resume_ns)
    assert est.total_ns == total
    assert est.total_ms == est.total_ns / 1e6
    with pytest.raises(ValueError):
        estimate_recovery_time(1000.0, 1e6, link_bw_gbps=0.0)
    with pytest.raises(ValueError):
        estimate_recovery_time(-1.0, 1e6)


def test_workload_inputs_periodic_in_dump_interval():
    """The dump resets the pending log: undumped volume is periodic in
    the dump period and grows within it; owned lines do not depend on
    the failure time."""
    period = PAPER_CLUSTER.dump_period_ms
    o_early, u_early = workload_recovery_inputs("ycsb", 0.1 * period)
    o_late, u_late = workload_recovery_inputs("ycsb", 0.9 * period)
    o_wrap, u_wrap = workload_recovery_inputs("ycsb", 2.1 * period)
    assert o_early == o_late == o_wrap
    assert u_late > u_early
    np.testing.assert_allclose(u_wrap, u_early, rtol=1e-9)


def test_workload_inputs_scale_with_cluster_shrink():
    """Weak scaling: 4 CNs run 4x the per-node work of 16 CNs, so both
    the owned census and the pending log quadruple."""
    o16, u16 = workload_recovery_inputs("barnes", 1.0, n_cns=16)
    o4, u4 = workload_recovery_inputs("barnes", 1.0, n_cns=4)
    np.testing.assert_allclose(o4, 4.0 * o16, rtol=1e-9)
    np.testing.assert_allclose(u4, 4.0 * u16, rtol=1e-9)
    with pytest.raises(ValueError):
        workload_recovery_inputs("barnes", 1.0, n_cns=0)


# ---------------------------------------------------------------------------
# Batched model vs scalar model
# ---------------------------------------------------------------------------

def test_batched_matches_scalar():
    rng = np.random.default_rng(0)
    owned = rng.uniform(1.0, 1e6, (4, 3))
    undumped = rng.uniform(0.0, 1e8, (4, 3))
    bw = rng.uniform(10.0, 160.0, (4, 3))
    out = recovery_time_batch(owned, undumped, bw)
    assert out["total_ns"].shape == (4, 3)
    for i in range(4):
        for j in range(3):
            est = estimate_recovery_time(owned[i, j], undumped[i, j],
                                         link_bw_gbps=bw[i, j])
            np.testing.assert_allclose(float(out["total_ns"][i, j]),
                                       est.total_ns, rtol=1e-5)
            np.testing.assert_allclose(float(out["replay_bytes"][i, j]),
                                       est.replay_bytes, rtol=1e-5)


# ---------------------------------------------------------------------------
# Failure-time x node sweep
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sweep():
    return recovery_sweep(workloads=("ycsb", "canneal", "streamcluster"),
                          cn_counts=(4, 8, 16))


def test_sweep_shape_and_axes(sweep):
    assert sweep.total_ns.shape == (3, len(DEFAULT_FAIL_FRACS), 3)
    assert set(sweep.components) >= {"fetch_ns", "log_scan_ns",
                                     "replay_bytes"}
    assert all(v.shape == sweep.total_ns.shape
               for v in sweep.components.values())


def test_sweep_monotone_axes(sweep):
    """Downtime grows within the dump interval (failure-time axis) and
    as the cluster shrinks (node axis, larger per-node shards)."""
    t = sweep.total_ns
    assert (np.diff(t, axis=1) > 0).all()       # later failure -> worse
    assert (np.diff(t, axis=2) < 0).all()       # more CNs -> better
    mid = sweep.fail_times_ms[1]
    assert sweep.total_ms("ycsb", mid, 4) > sweep.total_ms("ycsb", mid, 16)


def test_sweep_bandwidth_sensitivity():
    base = recovery_sweep(workloads=("ycsb",), cn_counts=(16,))
    slow = recovery_sweep(workloads=("ycsb",), cn_counts=(16,),
                          link_bw_gbps=PAPER_CLUSTER.cxl_link_bw_gbps / 4)
    assert (slow.total_ns > base.total_ns).all()
    with pytest.raises(ValueError):
        recovery_sweep(workloads=("ycsb",), link_bw_gbps=0.0)


def test_recovery_bench_rows():
    """The fig9/recovery/* rows the CI smoke run publishes."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.protocol_benches import bench_recovery
    rows = bench_recovery()
    names = [r["name"] for r in rows]
    assert all(n.startswith("fig9/recovery/") for n in names)
    for w in WORKLOADS:
        assert f"fig9/recovery/{w}/downtime_ms" in names
    by = {r["name"]: r["derived"] for r in rows}
    assert by["fig9/recovery/ycsb/late_over_early_fail"] > 1.0
    assert by["fig9/recovery/ycsb/cn4_over_cn16"] > 1.0


# ---------------------------------------------------------------------------
# Fault-scenario integration
# ---------------------------------------------------------------------------

@needs_devices
def test_fault_scenario_reports_downtime():
    scn = FaultScenario(name="dt", events=(FailureEvent(step=1, node=0),
                                           FailureEvent(step=3, node=2)),
                        n_steps=5)
    out = run_fault_scenario(scn)
    assert out.all_invariants_hold
    assert len(out.checks) == 2
    for c in out.checks:
        assert c.downtime is not None
        assert c.downtime_ns == c.downtime.total_ns > 0
        assert c.downtime.replay_bytes > 0
    assert out.total_downtime_ns == sum(c.downtime_ns for c in out.checks)
