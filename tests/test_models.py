"""Model-internals tests: blockwise-vs-full attention equivalence, RoPE,
SSD chunked-vs-sequential, MoE dispatch invariants, sharding rules."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

import repro
from repro.config import ShapeConfig
from repro.distributed.context import make_context, make_mesh, mesh_context
from repro.distributed.sharding import param_specs, sanitize_spec
from repro.models import attention as attn
from repro.models import build_model
from repro.models.layers import apply_rope, cross_entropy_loss, rmsnorm
from repro.models.model_zoo import make_batch
from repro.models.moe import _dispatch_and_compute, moe_init
from repro.models.ssm import ssd_chunked
from repro.kernels.ssd_scan.ref import ssd_ref

RNG = np.random.default_rng(3)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sq,skv", [(128, 128), (96, 96), (64, 256)])
def test_blockwise_equals_full(sq, skv):
    q = jnp.asarray(RNG.standard_normal((2, sq, 4, 32)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, skv, 2, 32)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, skv, 2, 32)), jnp.float32)
    full = attn._full_attention(q, k, v, causal=True)
    blk = attn._blockwise_attention(q, k, v, causal=True, q_block=32,
                                    kv_block=32)
    np.testing.assert_allclose(np.asarray(blk), np.asarray(full),
                               atol=2e-5, rtol=2e-5)


def test_blockwise_pair_count_exact_causal():
    """The static pair walk must enumerate exactly the causal lower
    triangle -- compiled FLOPs equal the true causal cost."""
    import repro.models.attention as A
    # nq = nk = 4 -> 10 lower-triangle pairs
    q = jnp.zeros((1, 128, 2, 16))
    k = jnp.zeros((1, 128, 2, 16))
    jaxpr = jax.make_jaxpr(
        lambda q, k, v: A._blockwise_attention(q, k, v, True, 32, 32)
    )(q, k, q)
    scan_eqn = [e for e in jaxpr.eqns if e.primitive.name == "scan"][0]
    assert scan_eqn.params["length"] == 10


def test_decode_attention_masks_beyond_len():
    q = jnp.asarray(RNG.standard_normal((1, 1, 2, 16)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 8, 2, 16)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 8, 2, 16)), jnp.float32)
    o4 = attn._decode_attention(q, k, v, jnp.int32(4))
    k2 = k.at[:, 4:].set(999.0)
    v2 = v.at[:, 4:].set(999.0)
    o4b = attn._decode_attention(q, k2, v2, jnp.int32(4))
    np.testing.assert_allclose(np.asarray(o4), np.asarray(o4b))


def test_rope_relative_shift_invariance():
    """RoPE scores depend only on relative positions."""
    d = 32
    q = jnp.asarray(RNG.standard_normal((1, 4, 1, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 4, 1, d)), jnp.float32)
    pos = jnp.arange(4)[None, :]
    q1 = apply_rope(q, pos, 10_000.0)
    k1 = apply_rope(k, pos, 10_000.0)
    q2 = apply_rope(q, pos + 17, 10_000.0)
    k2 = apply_rope(k, pos + 17, 10_000.0)
    s1 = jnp.einsum("bqhd,bkhd->bqk", q1, k1)
    s2 = jnp.einsum("bqhd,bkhd->bqk", q2, k2)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

def test_ssd_chunked_matches_sequential():
    b, l, h, p, n = 2, 96, 2, 16, 24
    x = jnp.asarray(RNG.standard_normal((b, l, h, p)) * 0.5, jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, l, h)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(RNG.standard_normal((b, l, n)) * 0.3, jnp.float32)
    C = jnp.asarray(RNG.standard_normal((b, l, n)) * 0.3, jnp.float32)
    y_ref, s_ref = ssd_ref(x, dt, A, B, C)
    for chunk in (16, 32, 96):
        y, s = ssd_chunked(x, dt, A, B, C, chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=2e-5, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                                   atol=2e-5, rtol=2e-4)


def test_ssd_init_state_continuation():
    """Splitting a sequence across two calls with state carry must equal
    one full-sequence call (prefill->decode contract)."""
    b, l, h, p, n = 1, 64, 2, 8, 16
    x = jnp.asarray(RNG.standard_normal((b, l, h, p)) * 0.5, jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.1, (b, l, h)), jnp.float32)
    A = -jnp.ones((h,), jnp.float32)
    B = jnp.asarray(RNG.standard_normal((b, l, n)) * 0.3, jnp.float32)
    C = jnp.asarray(RNG.standard_normal((b, l, n)) * 0.3, jnp.float32)
    y_full, s_full = ssd_chunked(x, dt, A, B, C, 16)
    y1, s1 = ssd_chunked(x[:, :32], dt[:, :32], A, B[:, :32], C[:, :32], 16)
    y2, s2 = ssd_chunked(x[:, 32:], dt[:, 32:], A, B[:, 32:], C[:, 32:], 16,
                         init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=3e-5, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=3e-5, rtol=3e-4)


# ---------------------------------------------------------------------------
# MoE dispatch invariants
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000), st.integers(8, 64),
       st.sampled_from([2, 4, 8]))
@settings(max_examples=15, deadline=None)
def test_moe_dispatch_capacity_respected(seed, T, E):
    cfg = dataclasses.replace(
        repro.get_reduced_config("grok-1-314b"), n_experts=E, top_k=2,
        capacity_factor=1.0)
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    params = moe_init(key, cfg)
    x = jnp.asarray(rng.standard_normal((T, cfg.d_model)) * 0.1,
                    jnp.bfloat16)
    out, aux = _dispatch_and_compute(
        x, params, cfg, 0, E, params.get("w_gate"), params["w_up"],
        params["w_down"])
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))
    assert float(aux) >= 0.99   # load-balance loss >= 1 at init-ish


def test_moe_no_drop_equals_dense_mixture():
    """With capacity >= all tokens, MoE output == explicit weighted sum of
    per-expert MLPs (the semantic ground truth)."""
    cfg = dataclasses.replace(repro.get_reduced_config("grok-1-314b"),
                              capacity_factor=64.0)
    key = jax.random.PRNGKey(0)
    params = moe_init(key, cfg)
    T, d, E, K = 16, cfg.d_model, cfg.n_experts, cfg.top_k
    x = jnp.asarray(RNG.standard_normal((T, d)) * 0.2, jnp.float32)
    out, _ = _dispatch_and_compute(
        x, params, cfg, 0, E, params.get("w_gate"), params["w_up"],
        params["w_down"])
    # ground truth
    logits = (x @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, K)
    gate = gate / jnp.sum(gate, -1, keepdims=True)
    truth = jnp.zeros_like(x)
    for t in range(T):
        for j in range(K):
            e = int(idx[t, j])
            h = x[t]
            act = jax.nn.silu(h @ params["w_gate"][e]) * (h @ params["w_up"][e])
            truth = truth.at[t].add(gate[t, j] * (act @ params["w_down"][e]))
    np.testing.assert_allclose(np.asarray(out), np.asarray(truth),
                               atol=2e-3, rtol=2e-2)


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

def test_sanitize_spec_prefix():
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices for the (2, 2, 2) mesh")
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    # 12 divides (model, pod) = 4 but not (model, pod, data) = 8:
    # the longest dividing prefix survives
    s = sanitize_spec(P(("model", "pod", "data")), (12,), mesh)
    assert tuple(s) == (("model", "pod"),)
    s6 = sanitize_spec(P(("model", "pod", "data")), (6,), mesh)
    assert tuple(s6) == ("model",)
    s2 = sanitize_spec(P("model", "data"), (5, 4), mesh)
    assert tuple(s2) == (None, "data")


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "hymba-1.5b",
                                  "moonshot-v1-16b-a3b", "whisper-medium"])
def test_param_specs_cover_all_leaves(mesh8, arch):
    cfg = repro.get_reduced_config(arch)
    model = build_model(cfg)
    ctx = make_context(mesh8)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_specs(params, cfg, ctx)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        assert len(tuple(spec)) <= leaf.ndim
        # every sharded dim divides
        for d, ax in enumerate(tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = int(np.prod([mesh8.shape[a] for a in axes]))
            assert leaf.shape[d] % n == 0


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def test_cross_entropy_masking():
    logits = jnp.asarray(RNG.standard_normal((2, 4, 8)), jnp.float32)
    labels = jnp.zeros((2, 4), jnp.int32)
    mask = jnp.asarray([[1, 1, 0, 0], [1, 1, 1, 1]], jnp.float32)
    l_masked = cross_entropy_loss(logits, labels, mask)
    l_manual = (cross_entropy_loss(logits[:1, :2], labels[:1, :2]) * 2
                + cross_entropy_loss(logits[1:], labels[1:]) * 4) / 6
    np.testing.assert_allclose(float(l_masked), float(l_manual), rtol=1e-6)
