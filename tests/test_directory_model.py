"""Queueing-coupled directory model (the two-level max-plus recurrence).

The contract (docs/simulator.md, docs/contention.md):

* directory-coupled timelines are **bit-identical** (``==``) across the
  pure-Python pre-collapse oracle, the jitted serial oracle, the
  blocked batch (both data planes) and the banked streaming engine, on
  ragged mixed-SB grids that also span the contention axes;
* ``directory_load=None`` is inert -- outputs AND bank dedup keys
  reproduce the PR-5 bits exactly (zero row churn on legacy grids);
* ``directory_load=0.0`` yields bit-identical *outputs* while
  occupying its own bank row, and its canonical (pool-free) params
  dedup the normalization cell across CN counts;
* the sharer census is directory-derived: clamped to ``n_cns - 1``
  instead of ``contention.SHARER_POOL``'s fixed 15-peer binomial;
* baseline slowdown is strictly monotone in offered load, proactive
  only weakly (its decoupled drain chain absorbs the w-side wait);
* the SS VII-E downtime model dilates its directory walk with load.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import contention as C
from repro.core import engine as E
from repro.core import simulator as S
from repro.core.contention import ContentionParams, serial_oracle
from repro.core.directory import (
    DirectoryParams,
    directory_service_scale,
    resolve_directory_load,
    sharer_pool,
)
from repro.core.scenarios import (
    directory_mega_grid,
    mega_grid,
    recovery_sweep,
)
from repro.core.simulator import (
    ScenarioSpec,
    bank_row_maps,
    simulate_batch,
    simulate_spec,
)

N = 700                                  # N % 72 != 0: ragged store tail
FLOAT_FIELDS = ("exec_time_ns", "repl_at_head_frac", "sb_full_frac",
                "max_log_bytes", "cxl_mem_bw_gbps", "log_dump_bw_gbps")
WORKLOAD_POOL = ("ycsb", "canneal", "barnes", "raytrace")


def _assert_identical(a, b, ctx):
    assert a.n_repl_msgs == b.n_repl_msgs, ctx
    for f in FLOAT_FIELDS:
        assert getattr(a, f) == getattr(b, f), (ctx, f)


# ---------------------------------------------------------------------------
# Axis resolution, census clamp, validation
# ---------------------------------------------------------------------------

def test_resolve_directory_load():
    assert resolve_directory_load(None, 16, 3) is None
    zero = resolve_directory_load(0.0, 16, 3)
    assert zero == DirectoryParams(sharer_pool=0, rho_bg=0.0)
    # canonical zero-load params are CN-independent (cross-CN dedup)
    assert zero == resolve_directory_load(0.0, 4, 3)
    p = resolve_directory_load(0.4, 16, 3)
    assert p.sharer_pool == sharer_pool(16, 3) and p.rho_bg > 0.0
    for bad in (1.0, 1.5, -0.1):
        with pytest.raises(ValueError):
            resolve_directory_load(bad, 16, 3)
    with pytest.raises(ValueError):
        simulate_batch([ScenarioSpec("ycsb", "proactive",
                                     directory_load=1.0)], n_stores=N)


def test_sharer_pool_clamped_to_cluster():
    assert sharer_pool(16, 3) == C.SHARER_POOL == 15
    assert sharer_pool(4, 3) == 3      # not 15 phantom peers
    assert sharer_pool(2, 3) == 1
    assert sharer_pool(1, 3) == 0      # nobody to invalidate
    for ncn in (2, 3, 4, 8, 16, 32):
        assert sharer_pool(ncn, 3) <= ncn - 1


def test_contention_census_directory_derived():
    """Resolved coupling replaces the fixed binomial pool with the real
    replica-set census on small clusters (the overcount bugfix)."""
    spec = ScenarioSpec("ycsb", "proactive", n_cns=4, read_share=0.8,
                        conflict_rate=0.4)
    con, _ = S._resolve_coupling(spec, S.PAPER_CLUSTER)
    assert con.sharer_pool == 3
    con16, _ = S._resolve_coupling(
        ScenarioSpec("ycsb", "proactive", read_share=0.8,
                     conflict_rate=0.4), S.PAPER_CLUSTER)
    assert con16.sharer_pool == C.SHARER_POOL
    # read_share == 0: the binomial is identically zero, so the pool is
    # canonicalized to 0 -- keeps the CN axis on one lane (and one key)
    con0, _ = S._resolve_coupling(
        ScenarioSpec("ycsb", "proactive", n_cns=4, conflict_rate=0.4),
        S.PAPER_CLUSTER)
    assert con0.sharer_pool == 0


def test_small_cluster_census_shrinks_invalidations():
    """The clamped 4-CN pool draws strictly fewer sharer invalidations
    than the fixed 15-peer binomial did for the same regime (the CN
    axis also rescales work, so the comparison is at the draw level)."""
    d3 = C.conflict_draws(N, 0, 0.4, 0.8, pool=3)
    d15 = C.conflict_draws(N, 0, 0.4, 0.8, pool=15)
    assert int(d3["sharers"].sum()) < int(d15["sharers"].sum())
    assert int(d3["sharers"].max()) <= 3
    # identical episode structure: the census is the LAST rng draw
    np.testing.assert_array_equal(d3["retries"], d15["retries"])


# ---------------------------------------------------------------------------
# Differential bit-identity across every path (the oracle discipline)
# ---------------------------------------------------------------------------

@st.composite
def coupled_grids(draw):
    """Ragged mixed-SB grids spanning the directory AND contention axes."""
    n = draw(st.integers(min_value=1, max_value=10))
    specs = []
    for _ in range(n):
        specs.append(ScenarioSpec(
            draw(st.sampled_from(WORKLOAD_POOL)),
            draw(st.sampled_from(S.CONFIGS)),
            seed=draw(st.integers(min_value=0, max_value=1)),
            n_replicas=draw(st.sampled_from((None, 4))),
            n_cns=draw(st.sampled_from((None, 8, 4))),
            sb_size=draw(st.sampled_from((None, 16, 24))),
            read_share=draw(st.sampled_from((None, 0.0, 0.4))),
            conflict_rate=draw(st.sampled_from((None, 0.25))),
            directory_load=draw(st.sampled_from((None, 0.0, 0.3, 0.7)))))
    return specs


@settings(max_examples=6, deadline=None)
@given(coupled_grids())
def test_coupled_paths_bit_identical(specs):
    banked = simulate_batch(specs, n_stores=N)
    stacked = simulate_batch(specs, n_stores=N, data_plane="stacked")
    stream = E.run_grid(specs, n_stores=N, tile_cells=16)
    for i, s in enumerate(specs):
        serial = simulate_spec(s, n_stores=N)
        oracle = serial_oracle(s, n_stores=N)
        _assert_identical(oracle, serial, (s, "oracle-vs-serial"))
        _assert_identical(banked[i], serial, (s, "banked-vs-serial"))
        _assert_identical(stacked[i], serial, (s, "stacked-vs-serial"))
        _assert_identical(stream[i], serial, (s, "stream-vs-serial"))


def test_load_zero_reproduces_legacy_bits_in_new_row():
    """``directory_load=0.0`` must equal the axis-off cell bit-for-bit
    -- the epoch delays are exactly zero -- while occupying its own
    bank row (the in-grid normalization cell)."""
    legacy = ScenarioSpec("ycsb", "proactive")
    zero = ScenarioSpec("ycsb", "proactive", directory_load=0.0)
    a, b = simulate_batch([legacy, zero], n_stores=N)
    _assert_identical(a, b, "zero-load-vs-legacy")
    bank = S.get_trace_bank([legacy, zero], N)
    assert bank.rows_for(legacy)[1] != bank.rows_for(zero)[1]
    assert bank.rows_for(legacy)[0] == bank.rows_for(zero)[0]  # trace


def test_wb_wt_rows_stay_constant_under_directory_load():
    """WB/WT commit locally and never consult the directory: their
    constant bank rows survive a coupled grid bit-for-bit."""
    specs = [ScenarioSpec("ycsb", c, directory_load=dl)
             for c in ("wb", "wt") for dl in (None, 0.7)]
    bank = S.get_trace_bank(specs, N)
    assert bank.wv_rows == 2
    res = simulate_batch(specs, n_stores=N)
    _assert_identical(res[0], res[1], "wb-coupled")
    _assert_identical(res[2], res[3], "wt-coupled")


# ---------------------------------------------------------------------------
# No bank-key churn for legacy grids; coupled keys extend the tail
# ---------------------------------------------------------------------------

def test_legacy_plane_keys_unchanged_by_directory_axis():
    """Axis-off specs keep the exact PR-4/PR-5 key format; coupled
    specs append typed params in fixed (contention, directory) order."""
    tk, wk = S._plane_keys(ScenarioSpec("ycsb", "proactive"),
                           S.PAPER_CLUSTER)
    assert tk == ("ycsb", 0)
    assert wk == ("proactive", "ycsb", 0, 3, 160.0, True)
    _, wk = S._plane_keys(ScenarioSpec("ycsb", "wb", directory_load=0.5),
                          S.PAPER_CLUSTER)
    assert wk == ("wb",)
    _, wk = S._plane_keys(
        ScenarioSpec("ycsb", "proactive", directory_load=0.5),
        S.PAPER_CLUSTER)
    assert len(wk) == 7 and isinstance(wk[6], DirectoryParams)
    _, wk = S._plane_keys(
        ScenarioSpec("ycsb", "proactive", conflict_rate=0.5,
                     directory_load=0.5), S.PAPER_CLUSTER)
    assert len(wk) == 8
    assert isinstance(wk[6], ContentionParams)
    assert isinstance(wk[7], DirectoryParams)


def test_mega_grid_bank_rows_unchanged_by_directory_axis():
    """The 12 960-cell legacy mega-grid keeps its PR-4 dedup (27 trace
    + 1 298 max-plus rows): the directory axis adds zero churn."""
    specs = mega_grid()
    trace_map, wv_map = bank_row_maps(specs)
    assert (len(trace_map), len(wv_map)) == (27, 1298)


def test_load_zero_cells_share_one_lane_across_cn_counts():
    """The canonical zero-load params carry no pool, so the CN axis of
    the normalization column collapses to one scan lane."""
    specs = [ScenarioSpec("ycsb", "proactive", n_cns=ncn,
                          directory_load=0.0)
             for ncn in (16, 8, 4, 2)]
    res = simulate_batch(specs, n_stores=N)
    assert res[0].meta["scan_lanes"] == 1
    # loaded cells at different CN counts resolve different rho_bg and
    # must NOT share a lane
    keys = {S._plane_keys(ScenarioSpec("ycsb", "proactive", n_cns=ncn,
                                       directory_load=0.4),
                          S.PAPER_CLUSTER)[1] for ncn in (16, 4)}
    assert len(keys) == 2


# ---------------------------------------------------------------------------
# Semantics: monotone slowdown (baseline), absorption (proactive)
# ---------------------------------------------------------------------------

def test_baseline_slowdown_strictly_monotone_in_load():
    loads = (0.0, 0.3, 0.7)
    t = [simulate_spec(ScenarioSpec("ycsb", "baseline",
                                    directory_load=dl),
                       n_stores=N).exec_time_ns for dl in loads]
    assert t[0] < t[1] < t[2], t


def test_proactive_absorbs_directory_wait():
    """Proactive's decoupled drain chain dominates the collapse, so the
    w-side epoch delays may vanish entirely -- only weak monotonicity
    holds (the capacity-vs-resilience contrast the bench reports)."""
    loads = (0.0, 0.3, 0.7)
    t = [simulate_spec(ScenarioSpec("ycsb", "proactive",
                                    directory_load=dl),
                       n_stores=N).exec_time_ns for dl in loads]
    assert t[0] <= t[1] <= t[2], t
    base = [simulate_spec(ScenarioSpec("ycsb", "baseline",
                                       directory_load=dl),
                          n_stores=N).exec_time_ns for dl in loads]
    # proactive hides strictly more of the wait than baseline does
    assert t[2] / t[0] < base[2] / base[0]


def test_directory_mega_grid_builder():
    specs = directory_mega_grid()
    assert len(specs) == 2592
    assert len(specs) >= E.STREAM_THRESHOLD   # auto-routes to streaming
    assert any(s.directory_load == 0.0 for s in specs)   # normalization
    assert any(s.n_cns == 4 for s in specs)              # clamp exercise
    assert {s.config for s in specs} >= {"baseline", "proactive"}


# ---------------------------------------------------------------------------
# Recovery coupling (background load dilates the directory walk)
# ---------------------------------------------------------------------------

def test_directory_service_scale():
    assert directory_service_scale(None) == 1.0
    assert directory_service_scale(resolve_directory_load(0.0, 16, 3)) \
        == 1.0
    s3 = directory_service_scale(resolve_directory_load(0.3, 16, 3))
    s7 = directory_service_scale(resolve_directory_load(0.7, 16, 3))
    assert 1.0 < s3 < s7 <= 1.0 / (1.0 - 0.95) + 1e-6


def test_recovery_sweep_monotone_in_directory_load():
    base = recovery_sweep(workloads=("ycsb",), cn_counts=(16,))
    mid = recovery_sweep(workloads=("ycsb",), cn_counts=(16,),
                         directory_load=0.3)
    hot = recovery_sweep(workloads=("ycsb",), cn_counts=(16,),
                         directory_load=0.7)
    t_mid = base.fail_times_ms[1]
    b, m, h = (s.total_ms("ycsb", t_mid, 16) for s in (base, mid, hot))
    assert b < m < h, (b, m, h)
    with pytest.raises(ValueError):
        recovery_sweep(workloads=("ycsb",), directory_load=1.5)
