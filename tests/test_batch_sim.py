"""Differential tests: ``simulate_batch`` vs the serial ``simulate()``
oracle, over a grid spanning every config and every sensitivity knob.

The contract (simulator.py module docstring): both batched engines --
the blocked scan (default; uniform-SB fast path and general mixed-SB
path) and the PR-1 per-step scan (``chunk_size=0``) -- share trace
synthesis + cost derivation with the serial oracle and apply identical
f32 arithmetic, so all paths agree **bit-for-bit**, for every chunk
size including ragged tails. The exactness tests below assert ``==``;
the older grid tests keep the (looser) documented 1e-5 band.
"""

import numpy as np
import pytest

from repro.core.simulator import (
    CONFIGS,
    DEFAULT_CHUNK_SIZE,
    ScenarioSpec,
    geomean_slowdowns,
    simulate,
    simulate_batch,
    slowdown_table,
)

N = 6_000
RTOL = 1e-5
FLOAT_FIELDS = ("exec_time_ns", "repl_at_head_frac", "sb_full_frac",
                "max_log_bytes", "cxl_mem_bw_gbps", "log_dump_bw_gbps")

# every config x a workload spread, plus one cell per sensitivity knob
GRID = (
    [ScenarioSpec(w, c)
     for w in ("ycsb", "raytrace", "ocean_ncp", "streamcluster")
     for c in CONFIGS]
    + [
        ScenarioSpec("canneal", "proactive", seed=7),
        ScenarioSpec("barnes", "proactive", n_replicas=4),
        ScenarioSpec("bodytrack", "baseline", link_bw_gbps=20.0),
        ScenarioSpec("fluidanimate", "proactive", n_cns=4),
        ScenarioSpec("ycsb", "parallel", sb_size=16),
        ScenarioSpec("ocean_cp", "proactive", coalescing=False),
        ScenarioSpec("ycsb", "wt", seed=2),
    ]
)


def _serial(spec: ScenarioSpec):
    return simulate(spec.workload, spec.config, n_stores=N, seed=spec.seed,
                    n_replicas=spec.n_replicas,
                    link_bw_gbps=spec.link_bw_gbps, n_cns=spec.n_cns,
                    sb_size=spec.sb_size, coalescing=spec.coalescing)


@pytest.fixture(scope="module")
def batch_results():
    return simulate_batch(GRID, n_stores=N)


def test_batch_matches_serial_on_grid(batch_results):
    assert len(batch_results) == len(GRID)
    for spec, rb in zip(GRID, batch_results):
        rs = _serial(spec)
        assert rb.workload == spec.workload and rb.config == spec.config
        assert rb.n_stores == rs.n_stores == N
        assert rb.n_repl_msgs == rs.n_repl_msgs, spec
        for f in FLOAT_FIELDS:
            a, b = getattr(rs, f), getattr(rb, f)
            np.testing.assert_allclose(b, a, rtol=RTOL, err_msg=f"{spec} {f}")


def test_batch_results_preserve_spec_order(batch_results):
    for spec, r in zip(GRID, batch_results):
        assert (r.workload, r.config) == (spec.workload, spec.config)


def test_batch_deterministic(batch_results):
    again = simulate_batch(GRID, n_stores=N)
    for a, b in zip(batch_results, again):
        assert a.exec_time_ns == b.exec_time_ns
        assert a.repl_at_head_frac == b.repl_at_head_frac


def test_odd_batch_sizes_padded_correctly():
    """Non-multiple-of-8 batches must pad internally without leaking
    padding cells into the output."""
    specs = [ScenarioSpec("ycsb", "proactive"),
             ScenarioSpec("raytrace", "wb"),
             ScenarioSpec("barnes", "wt", seed=1)]
    out = simulate_batch(specs, n_stores=N)
    assert len(out) == 3
    for spec, rb in zip(specs, out):
        rs = _serial(spec)
        np.testing.assert_allclose(rb.exec_time_ns, rs.exec_time_ns,
                                   rtol=RTOL)


def test_single_cell_batch_matches_serial():
    spec = ScenarioSpec("ocean_ncp", "proactive", sb_size=24)
    (rb,) = simulate_batch([spec], n_stores=N)
    rs = _serial(spec)
    np.testing.assert_allclose(rb.exec_time_ns, rs.exec_time_ns, rtol=RTOL)
    np.testing.assert_allclose(rb.sb_full_frac, rs.sb_full_frac, rtol=RTOL)


def test_empty_batch():
    assert simulate_batch([], n_stores=N) == []


def test_invalid_specs_rejected():
    with pytest.raises(ValueError):
        simulate_batch([ScenarioSpec("ycsb", "nosuch")], n_stores=N)
    with pytest.raises(ValueError):
        simulate_batch([ScenarioSpec("nosuch", "wb")], n_stores=N)
    with pytest.raises(ValueError):
        simulate_batch([ScenarioSpec("ycsb", "wb", sb_size=0)], n_stores=N)
    with pytest.raises(ValueError):
        simulate_batch([ScenarioSpec("ycsb", "wb", n_replicas=0)], n_stores=N)
    with pytest.raises(ValueError):
        simulate_batch([ScenarioSpec("ycsb", "wb", n_cns=0)], n_stores=N)
    with pytest.raises(ValueError):
        simulate_batch([ScenarioSpec("ycsb", "wb", link_bw_gbps=0.0)],
                       n_stores=N)


# ---------------------------------------------------------------------------
# Blocked-scan differential tests: blocked vs per-step vs serial oracle,
# bit-identical across chunk sizes (ragged tails included)
# ---------------------------------------------------------------------------

# uniform SB -> tuple-history fast path; N % 72 != 0 exercises the tail
UNIFORM_GRID = [ScenarioSpec(w, c)
                for w in ("ycsb", "raytrace", "ocean_ncp")
                for c in CONFIGS] + [ScenarioSpec("canneal", "proactive",
                                                  seed=3)]
# mixed SB depths -> general gather path (chunk clamps to min sb = 16)
MIXED_GRID = UNIFORM_GRID[:6] + [
    ScenarioSpec("ycsb", "parallel", sb_size=16),
    ScenarioSpec("barnes", "proactive", sb_size=24),
    ScenarioSpec("bodytrack", "proactive", n_replicas=4),
]


@pytest.fixture(scope="module")
def serial_by_spec():
    cache = {}

    def get(spec, n=N):
        key = (spec, n)
        if key not in cache:
            cache[key] = simulate(
                spec.workload, spec.config, n_stores=n, seed=spec.seed,
                n_replicas=spec.n_replicas, link_bw_gbps=spec.link_bw_gbps,
                n_cns=spec.n_cns, sb_size=spec.sb_size,
                coalescing=spec.coalescing)
        return cache[key]

    return get


def _assert_bit_identical(specs, batch, oracle, ctx):
    for spec, rb in zip(specs, batch):
        rs = oracle(spec)
        assert rb.n_repl_msgs == rs.n_repl_msgs, (ctx, spec)
        for f in FLOAT_FIELDS:
            assert getattr(rb, f) == getattr(rs, f), (ctx, spec, f)


@pytest.mark.parametrize("chunk", [0, 1, 7, 72, 4 * DEFAULT_CHUNK_SIZE])
def test_uniform_sb_engines_bit_identical(chunk, serial_by_spec):
    """Fast path (and per-step engine at chunk=0) vs serial, ``==``.

    chunk=72 divides nothing evenly at N=6000 (83 blocks + 24-store
    tail); chunk > sb clamps to the SB depth; chunk=1 degenerates to
    per-store blocks.
    """
    out = simulate_batch(UNIFORM_GRID, n_stores=N, chunk_size=chunk)
    _assert_bit_identical(UNIFORM_GRID, out, serial_by_spec, f"chunk={chunk}")


@pytest.mark.parametrize("chunk", [0, 1, 7, 64])
def test_mixed_sb_engines_bit_identical(chunk, serial_by_spec):
    """General gather path (per-cell SB depths) vs serial, ``==``."""
    out = simulate_batch(MIXED_GRID, n_stores=N, chunk_size=chunk)
    _assert_bit_identical(MIXED_GRID, out, serial_by_spec, f"chunk={chunk}")


def test_short_trace_edge_cases(serial_by_spec):
    """n_stores below / barely above the SB depth: the block clamp and
    the tail-only path must still be exact."""
    specs = [ScenarioSpec("ycsb", "proactive"),
             ScenarioSpec("raytrace", "baseline")]
    for n in (50, 100):
        out = simulate_batch(specs, n_stores=n)
        for spec, rb in zip(specs, out):
            rs = serial_by_spec(spec, n)
            for f in FLOAT_FIELDS:
                assert getattr(rb, f) == getattr(rs, f), (n, spec, f)


def test_blocked_chunk_size_validation():
    with pytest.raises(ValueError):
        simulate_batch([ScenarioSpec("ycsb", "wb")], n_stores=N,
                       chunk_size=-1)


def test_slowdown_table_batched_matches_serial():
    workloads = ("ycsb", "raytrace")
    t_batched = slowdown_table(workloads=workloads, n_stores=N, batched=True)
    t_serial = slowdown_table(workloads=workloads, n_stores=N, batched=False)
    assert set(t_batched) == set(t_serial)
    for w in workloads:
        for c in CONFIGS:
            np.testing.assert_allclose(t_batched[w][c], t_serial[w][c],
                                       rtol=RTOL, err_msg=f"{w}/{c}")
    gm_b = geomean_slowdowns(t_batched)
    gm_s = geomean_slowdowns(t_serial)
    for c in CONFIGS:
        np.testing.assert_allclose(gm_b[c], gm_s[c], rtol=RTOL)
