"""Per-shard sub-bank partitioning: differential + layout tests.

The sub-bank contract (PR 8, ``engine.run_grid(bank_partition="sub")``
-- the default): the three max-plus bank planes are partitioned over
the ``cells`` mesh (wv row ``r`` owned by shard ``r % n_shards`` at
local index ``r // n_shards``), scan lanes are scheduled into their
owner shard's slot block by ``plan_tiles(owners=...)``, and the in-jit
gather runs against shard-resident rows only -- while every answer
stays bit-identical (``==``) to the replicated layout, the blocked
batch, and the serial oracle, for ragged mixed-SB grids with the
contention and directory axes on. Measured resident device bytes
(``bank_stats()``) must actually drop to ~1/n_shards.
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import engine as E
from repro.core.scenarios import mega_grid
from repro.core.simulator import (
    CONFIGS,
    PAPER_CLUSTER,
    ScenarioSpec,
    bank_row_maps,
    clear_sim_caches,
    simulate_batch,
    sub_bank_rows,
)

N = 700
WORKLOAD_POOL = ("ycsb", "canneal", "barnes", "raytrace", "ocean_ncp")
FLOAT_FIELDS = ("exec_time_ns", "repl_at_head_frac", "sb_full_frac",
                "max_log_bytes", "cxl_mem_bw_gbps", "log_dump_bw_gbps")

SHARD_COUNTS = sorted({1, min(8, jax.device_count())})


def _assert_bit_identical(got, want, ctx):
    assert len(got) == len(want)
    for a, b in zip(got, want):
        for f in FLOAT_FIELDS:
            assert getattr(a, f) == getattr(b, f), (ctx, a.meta, f)


@st.composite
def ragged_grids(draw):
    """Ragged mixed-SB grids over every serve axis, including the
    PR-5 contention and PR-6 directory knobs (which add bank rows of
    their own, so ownership interleaves non-trivially)."""
    n = draw(st.integers(min_value=1, max_value=14))
    specs = []
    for _ in range(n):
        specs.append(ScenarioSpec(
            draw(st.sampled_from(WORKLOAD_POOL)),
            draw(st.sampled_from(CONFIGS)),
            seed=draw(st.integers(min_value=0, max_value=2)),
            n_replicas=draw(st.sampled_from((None, 2, 3))),
            link_bw_gbps=draw(st.sampled_from((None, 40.0))),
            sb_size=draw(st.sampled_from((None, 16, 48))),
            coalescing=draw(st.booleans()),
            read_share=draw(st.sampled_from((None, 0.3))),
            conflict_rate=draw(st.sampled_from((None, 0.05))),
            directory_load=draw(st.sampled_from((None, 0.5)))))
    return specs


@settings(max_examples=6, deadline=None)
@given(ragged_grids())
def test_sub_bank_bitident_across_shards_planes_partitions(grid):
    """Differential core: sub vs replicated vs stacked vs the blocked
    oracle, at 1 and 8 shards, on ragged contention/directory grids."""
    oracle = simulate_batch(grid, n_stores=N)
    for n_shards in SHARD_COUNTS:
        sub = E.run_grid(grid, n_stores=N, tile_cells=16,
                         n_shards=n_shards)
        assert E.bank_stats()["bank_partition"] == "sub"
        _assert_bit_identical(sub, oracle, ("sub", n_shards))
        rep = E.run_grid(grid, n_stores=N, tile_cells=16,
                         n_shards=n_shards, bank_partition="replicated")
        _assert_bit_identical(rep, oracle, ("replicated", n_shards))
        stacked = E.run_grid(grid, n_stores=N, tile_cells=16,
                             n_shards=n_shards, data_plane="stacked")
        _assert_bit_identical(stacked, oracle, ("stacked", n_shards))


def test_plan_tiles_owner_partitioning():
    """The owner-aware scheduler must place every lane exactly once, in
    its owning shard's slot block, with per-tile padded shapes still
    canonical (b_pad divisible by n_shards)."""
    n_shards = 4
    specs = [ScenarioSpec(w, c, seed=s)
             for w in WORKLOAD_POOL for c in CONFIGS for s in (0, 1)]
    rng = np.random.default_rng(0)
    owners = [int(rng.integers(n_shards)) for _ in specs]
    tiles = E.plan_tiles(specs, n_stores=N, tile_cells=16,
                         n_shards=n_shards, small_pad=False, owners=owners)
    seen = sorted(i for t in tiles for i in t.indices)
    assert seen == list(range(len(specs)))
    for t in tiles:
        assert t.slots is not None
        assert len(t.slots) == len(t.indices) == len(t.specs)
        assert len(set(t.slots)) == len(t.slots)          # no collisions
        assert t.sig.b_pad % n_shards == 0
        per = t.sig.b_pad // n_shards
        for i, pos in zip(t.indices, t.slots):
            assert 0 <= pos < t.sig.b_pad
            # the slot block index IS the owning shard
            assert pos // per == owners[i], (i, pos, per)
    # owners=None (or one shard) keeps the legacy identity layout
    legacy = E.plan_tiles(specs, n_stores=N, tile_cells=16,
                          n_shards=n_shards, small_pad=False)
    assert all(t.slots is None for t in legacy)
    single = E.plan_tiles(specs, n_stores=N, tile_cells=16, n_shards=1,
                          small_pad=False, owners=[0] * len(specs))
    assert all(t.slots is None for t in single)


def test_sub_bank_rows_and_host_layout():
    """sub_bank_rows / TraceBank.sub_bank_host: ceil-divided local
    count (floored at one row), owner ``r % n``, local ``r // n``,
    zero-padded ragged tails -- the layout every shard gathers from."""
    assert sub_bank_rows(8, 4) == 2
    assert sub_bank_rows(9, 4) == 3
    assert sub_bank_rows(1, 8) == 1
    assert sub_bank_rows(0, 8) == 1               # never an empty plane
    from repro.core.simulator import get_trace_bank
    specs = [ScenarioSpec(w, c) for w in WORKLOAD_POOL for c in CONFIGS]
    bank = get_trace_bank(specs, N, PAPER_CLUSTER)
    n = 4
    a, w, v, p = bank.sub_bank_host(n)
    assert a is bank.arrivals                     # replicated, not copied
    p_loc = sub_bank_rows(bank.wv_rows, n)
    assert w.shape == v.shape == p.shape == (n, p_loc, N)
    for r in range(bank.wv_rows):
        assert np.array_equal(w[r % n, r // n], bank.w[r])
        assert np.array_equal(v[r % n, r // n], bank.v[r])
        assert np.array_equal(p[r % n, r // n], bank.pr_nc[r])
    # ragged tail rows stay zero
    for s in range(n):
        local = len(bank.w[s::n])
        assert not w[s, local:].any()


def test_measured_sub_bytes_cut_vs_replicated():
    """The point of the PR: measured per-shard resident bytes under the
    sub partition stay within ~1.1x of bank/n_shards + the replicated
    arrivals, and the fleet total is ~flat instead of x n_shards."""
    n_shards = min(8, jax.device_count())
    if n_shards < 2:
        pytest.skip("needs >= 2 devices to partition")
    grid = [ScenarioSpec(w, c, seed=s, n_replicas=r)
            for w in WORKLOAD_POOL for c in CONFIGS
            for s in (0, 1) for r in (None, 2, 3)]
    clear_sim_caches()
    E.run_grid(grid, n_stores=N, tile_cells=16, n_shards=n_shards)
    sub = E.bank_stats()
    clear_sim_caches()
    E.run_grid(grid, n_stores=N, tile_cells=16, n_shards=n_shards,
               bank_partition="replicated")
    rep = E.bank_stats()
    assert sub["bank_bytes"] == rep["bank_bytes"] > 0
    # replicated pins the exact products; sub must genuinely partition
    assert rep["bank_dev_bytes"] == rep["bank_bytes"] * n_shards
    assert rep["bank_dev_bytes_per_shard"] == rep["bank_bytes"]
    bank = E.get_trace_bank(grid, N)
    a, w, v, p = bank.sub_bank_host(n_shards)
    stacks = w.nbytes + v.nbytes + p.nbytes       # padded, one fleet copy
    assert sub["bank_dev_bytes"] == n_shards * a.nbytes + stacks
    assert sub["bank_dev_bytes"] < rep["bank_dev_bytes"]
    # per-shard: its stack slice + the replicated arrivals, nothing more
    assert 0 < sub["bank_dev_bytes_per_shard"] \
        <= a.nbytes + stacks // n_shards
    # only arrivals replicate over the fabric under sub
    assert sub["bank_fabric_bytes"] == a.nbytes * (n_shards - 1)
    assert rep["bank_fabric_bytes"] == \
        rep["bank_bytes"] * (n_shards - 1)


def test_mega_grid_bank_keys_and_lanes_unchanged():
    """Partitioning must not move a single bank row or lane: the
    12 960-cell mega-grid keeps its 27 + 1298 rows and 2 700 lanes."""
    mega = mega_grid()
    trace_map, wv_map = bank_row_maps(mega)
    assert len(trace_map) == 27
    assert len(wv_map) == 1298
    from repro.core.simulator import _plane_keys
    lanes = {(s.sb_size if s.sb_size is not None
              else PAPER_CLUSTER.store_buffer,)
             + _plane_keys(s, PAPER_CLUSTER) for s in mega}
    assert len(lanes) == 2700
    # local row counts cover every wv row exactly once at 8 shards
    owners = [r % 8 for r in wv_map.values()]
    assert sub_bank_rows(len(wv_map), 8) == -(-len(wv_map) // 8)
    assert sum(owners.count(s) for s in range(8)) == len(wv_map)
