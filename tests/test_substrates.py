"""Optimizers, schedules, data pipeline, checkpoint manager, directory,
failure detector."""

import os
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro
from repro.checkpoint import CheckpointManager
from repro.config import ShapeConfig, TrainConfig
from repro.core.directory import ShardDirectory, ShardState
from repro.core.failures import FailureDetector
from repro.data import SyntheticTokenPipeline
from repro.optim import make_optimizer, make_schedule
from repro.optim.optimizers import clip_by_global_norm, global_norm


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

def _quad_problem():
    params = {"w": jnp.asarray([3.0, -2.0, 1.0]), "b": jnp.asarray([0.5])}

    def loss(p):
        return jnp.sum(jnp.square(p["w"])) + jnp.sum(jnp.square(p["b"]))

    return params, loss


@pytest.mark.parametrize("opt", ["adamw", "adafactor", "sgd"])
def test_optimizers_descend(opt):
    cfg = TrainConfig(optimizer=opt, learning_rate=0.05, weight_decay=0.0,
                      total_steps=100, warmup_steps=1)
    params, loss = _quad_problem()
    init, update = make_optimizer(cfg)
    state = init(params)
    l0 = float(loss(params))
    for i in range(60):
        g = jax.grad(loss)(params)
        params, state = update(g, state, params, jnp.float32(0.05))
    assert float(loss(params)) < l0 * 0.25


def test_adamw_master_copy_kept():
    cfg = TrainConfig(optimizer="adamw", master_dtype="float32",
                      param_dtype="bfloat16")
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    init, update = make_optimizer(cfg)
    state = init(params)
    assert "master" in state
    assert state["master"]["w"].dtype == jnp.float32
    g = {"w": jnp.full((4,), 0.1, jnp.bfloat16)}
    p2, s2 = update(g, state, params, jnp.float32(1e-3))
    assert p2["w"].dtype == jnp.bfloat16
    assert s2["master"]["w"].dtype == jnp.float32


def test_grad_clip():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 1.0


def test_schedules():
    for kind in ("cosine", "linear", "constant"):
        cfg = TrainConfig(schedule=kind, learning_rate=1e-3,
                          warmup_steps=10, total_steps=100)
        f = make_schedule(cfg)
        assert float(f(jnp.int32(0))) == 0.0
        assert abs(float(f(jnp.int32(10))) - 1e-3) < 1e-9
        if kind != "constant":
            assert float(f(jnp.int32(100))) < 1e-4


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    cfg = repro.get_reduced_config("qwen3-0.6b")
    shape = ShapeConfig("t", seq_len=16, global_batch=2, kind="train")
    p1 = SyntheticTokenPipeline(cfg, shape, seed=7)
    batches = [p1.next() for _ in range(5)]
    p2 = SyntheticTokenPipeline(cfg, shape, seed=7)
    p2.seek(3)
    b3 = p2.next()
    np.testing.assert_array_equal(b3["tokens"], batches[3]["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(batches[0]["labels"][:, :-1],
                                  batches[0]["tokens"][:, 1:])


def test_pipeline_prefetch_thread():
    cfg = repro.get_reduced_config("qwen3-0.6b")
    shape = ShapeConfig("t", seq_len=16, global_batch=2, kind="train")
    p = SyntheticTokenPipeline(cfg, shape, seed=0)
    p.start()
    try:
        a = p.next()
        b = p.next()
        assert a["tokens"].shape == (2, 16)
        assert not np.array_equal(a["tokens"], b["tokens"])
    finally:
        p.stop()


# ---------------------------------------------------------------------------
# Checkpoint manager (MN tier)
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc():
    d = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(d, keep=2)
        state = {"a": jnp.arange(6.0).reshape(2, 3),
                 "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
        for step in (5, 11, 17):
            mgr.save(step, state, extra={"x": step}, blocking=True)
        assert mgr.steps() == [11, 17]          # gc keeps 2
        restored, extra = mgr.restore(state)
        assert extra["x"] == 17
        np.testing.assert_allclose(restored["a"], np.asarray(state["a"]))
        assert restored["nested"]["b"].dtype == np.asarray(
            state["nested"]["b"]).dtype
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_checkpoint_async():
    d = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(d)
        mgr.save(3, {"a": jnp.zeros((8,))}, blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 3
    finally:
        shutil.rmtree(d, ignore_errors=True)


# ---------------------------------------------------------------------------
# Directory
# ---------------------------------------------------------------------------

def test_directory_algorithm1_bookkeeping():
    d = ShardDirectory(n_nodes=8, n_buckets=4, n_replicas=3)
    owned = d.owned_by(2)
    assert len(owned) == 4
    cleared = d.remove_failed_replica(2)
    assert cleared > 0
    for (node, b) in d.entries:
        assert 2 not in d.entries[(node, b)].replicas
    d.reassign(2, 0, 5)
    e = d.entry(2, 0)
    assert e.owner == 5 and e.state == ShardState.UNOWNED
    assert len(e.replicas) == 3


def test_directory_serialization():
    d = ShardDirectory(4, 2, 2)
    d.record_commit(9)
    d.record_dump(5)
    blob = d.to_json()
    d2 = ShardDirectory.from_json(blob, 4, 2, 2)
    assert d2.entry(1, 1).commit_step == 9
    assert d2.entry(1, 1).dump_step == 5


def test_directory_stats_fig15():
    d = ShardDirectory(16, 8, 3)
    s = d.stats(0)
    assert s["owned"] == 8
    assert s["shared"] == 8 * 3 // 16 * 16 // 16 * 2 or s["shared"] >= 0


# ---------------------------------------------------------------------------
# Failure detector
# ---------------------------------------------------------------------------

def test_detector_lease_expiry():
    det = FailureDetector(4, lease_s=0.05)
    t0 = time.monotonic()
    for n in range(4):
        det.heartbeat(n, now=t0)
    det.heartbeat(0, now=t0 + 0.1)
    newly = det.check(now=t0 + 0.1)
    assert set(newly) == {1, 2, 3}
    assert det.configuration_manager() == 0


def test_detector_failed_stays_failed():
    det = FailureDetector(2, lease_s=10)
    det.mark_failed(1)
    det.heartbeat(1)                  # fail-stop: no resurrection
    assert det.failed_nodes == [1]
