"""Differential tests for the fused bank-gather + scan kernel.

Three implementations must agree **bit-for-bit** on real bank columns:
the Pallas kernel (CPU interpreter mode -- the same kernel the TPU
path compiles), the self-contained pure-jax ``ref.py`` oracle, and the
simulator's banked blocked scan (``_timeline_banked``). Chunk sizes
sweep ragged tails, chunk == sb, and chunk 1.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.simulator import (
    CONFIGS,
    PAPER_CLUSTER,
    ScenarioSpec,
    _banked_inputs,
    _timeline_banked,
    get_trace_bank,
)
from repro.kernels.bank_scan import bank_scan, bank_scan_backend
from repro.kernels.bank_scan.ref import bank_scan_ref

N = 500                                  # ragged vs every chunk below
SB = 24


@pytest.fixture(scope="module")
def banked_grid():
    specs = tuple(ScenarioSpec(w, c, seed=s, sb_size=SB)
                  for w in ("ycsb", "canneal", "barnes")
                  for c in CONFIGS for s in (0, 1))
    (cells, cell_lane, n_lanes, tr, wv, sb_arr, sb_max, _,
     sb_uniform) = _banked_inputs(specs, N, PAPER_CLUSTER)
    bank = get_trace_bank(specs, N, PAPER_CLUSTER)
    assert sb_uniform == SB
    assert n_lanes == len(specs)         # all-distinct lanes in this grid
    args = tuple(jnp.asarray(x) for x in
                 (bank.arrivals, bank.w, bank.v, bank.pr_nc))
    return args, jnp.asarray(tr), jnp.asarray(wv), jnp.asarray(sb_arr), sb_max


def _assert_tuple_identical(got, want, ctx):
    for g, w, name in zip(got, want, ("exec", "at_head", "sb_full")):
        assert np.array_equal(np.asarray(g), np.asarray(w)), (ctx, name)


@pytest.mark.parametrize("chunk", [1, 7, SB])
def test_pallas_interpret_matches_ref(banked_grid, chunk):
    args, tr, wv, _, _ = banked_grid
    ref = bank_scan_ref(*args, tr, wv, chunk=chunk, sb=SB)
    pal = bank_scan(*args, tr, wv, chunk=chunk, sb=SB,
                    force="pallas_interpret")
    _assert_tuple_identical(pal, ref, f"chunk={chunk}")


@pytest.mark.parametrize("chunk", [7, SB])
def test_ref_matches_simulator_banked_scan(banked_grid, chunk):
    args, tr, wv, sb_arr, sb_max = banked_grid
    ref = bank_scan_ref(*args, tr, wv, chunk=chunk, sb=SB)
    sim = _timeline_banked(*args, tr, wv, sb_arr, sb_max, chunk, SB)
    _assert_tuple_identical(ref, sim, f"chunk={chunk}")


def test_chunk_clamped_to_sb_and_trace(banked_grid):
    args, tr, wv, _, _ = banked_grid
    # chunk > sb clamps to sb; chunk > n clamps to the trace
    a = bank_scan_ref(*args, tr, wv, chunk=4 * SB, sb=SB)
    b = bank_scan_ref(*args, tr, wv, chunk=SB, sb=SB)
    _assert_tuple_identical(a, b, "clamp")


def test_backend_selection(monkeypatch):
    monkeypatch.delenv("RECXL_BANK_SCAN", raising=False)
    want = "pallas" if jax.default_backend() == "tpu" else "jax"
    assert bank_scan_backend() == want
    monkeypatch.setenv("RECXL_BANK_SCAN", "pallas")
    assert bank_scan_backend() == "pallas"
    monkeypatch.setenv("RECXL_BANK_SCAN", "jax")
    assert bank_scan_backend() == "jax"
