"""Logging Unit (paper SS IV.B-C): allocation, validation, in-order drain.

Includes hypothesis property tests: under arbitrary cross-source /
cross-address message reordering (with per-(src, addr) point-to-point
order preserved -- the protocol's well-definedness assumption), the DRAM
log commits every source's entries in logical-timestamp (program) order
and never loses a validated entry.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import logging_unit as lu


def _mk(sram=16, dram=64, sources=4, width=1):
    return lu.init_state(sram, dram, sources, width)


def test_repl_allocates_entry():
    s = _mk()
    s = lu.receive_repl(s, 1, 42, jnp.asarray([7.0]))
    assert int(jnp.sum(s.sram_src != lu.EMPTY)) == 1
    assert int(s.dropped) == 0


def test_val_before_drain_required():
    s = _mk()
    s = lu.receive_repl(s, 1, 42, jnp.asarray([7.0]))
    s = lu.drain(s, 4)
    assert int(s.dram_ptr) == 0          # unvalidated entries never drain
    s = lu.receive_val(s, 1, 42, 0)
    s = lu.drain(s, 4)
    assert int(s.dram_ptr) == 1
    assert int(s.dram_addr[0]) == 42
    assert float(s.dram_val[0, 0]) == 7.0


def test_out_of_order_vals_commit_in_ts_order():
    """Fabric reorders two VALs from one source: ts=1 arrives before ts=0.
    The DRAM log must still commit ts=0 first."""
    s = _mk()
    s = lu.receive_repl(s, 2, 10, jnp.asarray([1.0]))   # will get ts=0
    s = lu.receive_repl(s, 2, 11, jnp.asarray([2.0]))   # will get ts=1
    s = lu.receive_val(s, 2, 11, 1)                      # reordered!
    s = lu.drain(s, 4)
    assert int(s.dram_ptr) == 0          # ts=1 must wait for ts=0
    s = lu.receive_val(s, 2, 10, 0)
    s = lu.drain(s, 4)
    assert int(s.dram_ptr) == 2
    assert int(s.dram_ts[0]) == 0 and int(s.dram_ts[1]) == 1


def test_same_address_two_inflight_stores():
    """Proactive can have two same-(src, addr) REPLs outstanding; VALs must
    pair FIFO with allocation order."""
    s = _mk()
    s = lu.receive_repl(s, 0, 5, jnp.asarray([1.0]))
    s = lu.receive_repl(s, 0, 5, jnp.asarray([2.0]))
    s = lu.receive_val(s, 0, 5, 0)       # validates the OLDER entry
    s = lu.receive_val(s, 0, 5, 1)
    s = lu.drain(s, 4)
    assert int(s.dram_ptr) == 2
    assert float(s.dram_val[0, 0]) == 1.0
    assert float(s.dram_val[1, 0]) == 2.0


def test_sram_full_drops_counted():
    s = _mk(sram=2)
    for i in range(3):
        s = lu.receive_repl(s, 0, i, jnp.asarray([float(i)]))
    assert int(s.dropped) == 1


def test_latest_version_query():
    s = _mk()
    for ts, val in [(0, 1.0), (1, 2.0), (2, 3.0)]:
        s = lu.receive_repl(s, 1, 99, jnp.asarray([val]))
        s = lu.receive_val(s, 1, 99, ts)
    s = lu.drain(s, 8)
    found, ts, val = lu.latest_version(s, 1, 99)
    assert bool(found) and int(ts) == 2 and float(val[0]) == 3.0


def test_clear_dram():
    s = _mk()
    s = lu.receive_repl(s, 0, 1, jnp.asarray([5.0]))
    s = lu.receive_val(s, 0, 1, 0)
    s = lu.drain(s, 2)
    s = lu.clear_dram(s)
    assert int(s.dram_ptr) == 0
    found, _, _ = lu.latest_version(s, 0, 1)
    assert not bool(found)


# ---------------------------------------------------------------------------
# Property tests
# ---------------------------------------------------------------------------

@st.composite
def message_schedule(draw):
    """A set of stores + an interleaving preserving causality (VAL after
    its REPL) and per-(src, addr) point-to-point order."""
    n_src = draw(st.integers(2, 3))
    stores = []
    for src in range(n_src):
        n = draw(st.integers(1, 5))
        addrs = draw(st.lists(st.integers(0, 3), min_size=n, max_size=n))
        for ts, addr in enumerate(addrs):
            stores.append((src, addr, ts))
    # events: (kind, src, addr, ts); REPL must precede its VAL; same
    # (src, addr) REPLs keep relative order, same for VALs.
    events = []
    for (src, addr, ts) in stores:
        events.append(("repl", src, addr, ts))
        events.append(("val", src, addr, ts))
    perm = draw(st.permutations(events))
    # repair causality + per-(src, addr) FIFO by stable-sorting within keys
    fixed = []
    pending = {}
    by_key_r = {}
    by_key_v = {}
    for ev in perm:
        k = (ev[1], ev[2])
        if ev[0] == "repl":
            by_key_r.setdefault(k, []).append(ev)
        else:
            by_key_v.setdefault(k, []).append(ev)
    for k in by_key_r:
        by_key_r[k].sort(key=lambda e: e[3])
    for k in by_key_v:
        by_key_v[k].sort(key=lambda e: e[3])
    # now re-walk the permutation emitting the next-in-order event per key
    ri = {k: 0 for k in by_key_r}
    vi = {k: 0 for k in by_key_v}
    seen_repl = set()
    deferred = []
    for ev in perm:
        k = (ev[1], ev[2])
        if ev[0] == "repl":
            e = by_key_r[k][ri[k]]
            ri[k] += 1
            fixed.append(e)
            seen_repl.add((k, e[3]))
        else:
            e = by_key_v[k][vi[k]]
            vi[k] += 1
            if (k, e[3]) in seen_repl:
                fixed.append(e)
            else:
                deferred.append(e)
    fixed.extend(sorted(deferred, key=lambda e: (e[1], e[2], e[3])))
    return n_src, stores, fixed


@given(message_schedule())
@settings(max_examples=30, deadline=None)
def test_property_commit_order_and_no_loss(sched):
    n_src, stores, events = sched
    s = lu.init_state(64, 128, n_src, 1)
    for (kind, src, addr, ts) in events:
        if kind == "repl":
            s = lu.receive_repl(s, src, addr,
                                jnp.asarray([src * 100.0 + ts]))
        else:
            s = lu.receive_val(s, src, addr, ts)
        s = lu.drain(s, 4)
    s = lu.drain(s, 64)
    # no loss
    assert int(s.dropped) == 0
    n = int(s.dram_ptr)
    assert n == len(stores)
    # per-source: timestamps strictly increasing in DRAM order
    srcs = np.asarray(s.dram_src[:n])
    tss = np.asarray(s.dram_ts[:n])
    for src in range(n_src):
        seq = tss[srcs == src]
        assert list(seq) == sorted(seq)
        assert list(seq) == list(range(len(seq)))
