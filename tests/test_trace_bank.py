"""Property tests for the columnar trace-bank data plane.

The bank contract (simulator.py "columnar trace-bank data plane"):
gathering a cell's columns out of the bank must reconstruct the stacked
per-cell inputs **bit-exactly** -- arrivals verbatim, and the host-
precollapsed ``(w, v, pr_nc)`` columns equal to the device
``_blocked_precompute`` of the stacked arrays -- for arbitrary ragged
mixed-SB grids; and ``clear_sim_caches()`` must drop the bank cache
including its device placements (no leaked device buffers across
engine switches).
"""

import gc
import weakref

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core import engine as E
from repro.core import simulator as S
from repro.core.simulator import (
    CONFIGS,
    PAPER_CLUSTER,
    ScenarioSpec,
    clear_sim_caches,
    get_trace_bank,
    simulate_batch,
)

N = 700                                 # N % 72 != 0: ragged store tail
WORKLOAD_POOL = ("ycsb", "canneal", "barnes", "raytrace", "ocean_ncp")
FLOAT_FIELDS = ("exec_time_ns", "repl_at_head_frac", "sb_full_frac",
                "max_log_bytes", "cxl_mem_bw_gbps", "log_dump_bw_gbps")


@st.composite
def ragged_grids(draw):
    """Random mixed-SB grids spanning every dedup axis of the bank."""
    n = draw(st.integers(min_value=1, max_value=24))
    specs = []
    for _ in range(n):
        specs.append(ScenarioSpec(
            draw(st.sampled_from(WORKLOAD_POOL)),
            draw(st.sampled_from(CONFIGS)),
            seed=draw(st.integers(min_value=0, max_value=2)),
            n_replicas=draw(st.sampled_from((None, 2, 4))),
            link_bw_gbps=draw(st.sampled_from((None, 40.0))),
            n_cns=draw(st.sampled_from((None, 8))),
            sb_size=draw(st.sampled_from((None, 16, 24))),
            coalescing=draw(st.booleans())))
    return specs


@settings(max_examples=10, deadline=None)
@given(ragged_grids())
def test_bank_gather_reconstructs_stacked_inputs(specs):
    cells = [S._prepare_cell(
        s, S._trace_cached(s.workload, N, s.seed, PAPER_CLUSTER), N,
        PAPER_CLUSTER) for s in specs]
    np_args, _, _, _ = S._stack_cells(cells)
    arrivals, coalesce, exposed, t_repl_i, svc_i, config_idx, _ = np_args
    costs = S._commit_cost_ns("proactive", PAPER_CLUSTER)
    w_dev, v_dev, p_dev = S._blocked_precompute(
        jnp.asarray(coalesce), jnp.asarray(exposed), jnp.asarray(t_repl_i),
        jnp.asarray(svc_i), jnp.asarray(config_idx),
        costs["t_l1"], costs["t_wt"])

    bank = get_trace_bank(specs, N)
    n_pad = S._pad_len(len(cells))
    padded = cells + [cells[0]] * (n_pad - len(cells))
    rows = [bank.rows_for(c.spec) for c in padded]
    tr = np.asarray([r[0] for r in rows])
    wv = np.asarray([r[1] for r in rows])

    # arrivals verbatim; w/v/pr_nc: host precollapse == device precompute
    # (stacked arrays are time-major (n, B); bank rows store-contiguous)
    assert np.array_equal(bank.arrivals[tr], arrivals.T)
    assert np.array_equal(bank.w[wv], np.asarray(w_dev).T)
    assert np.array_equal(bank.v[wv], np.asarray(v_dev).T)
    assert np.array_equal(bank.pr_nc[wv], np.asarray(p_dev).T)
    # dedup is real: never more columns than cells, usually far fewer
    assert bank.trace_rows <= len(specs)
    assert bank.wv_rows <= len(specs)


@settings(max_examples=6, deadline=None)
@given(ragged_grids())
def test_banked_engines_match_stacked_on_random_grids(specs):
    want = simulate_batch(specs, n_stores=N, data_plane="stacked")
    got_batch = simulate_batch(specs, n_stores=N)            # banked
    got_stream = E.run_grid(specs, n_stores=N, tile_cells=16)  # banked
    for a, b, c in zip(got_batch, got_stream, want):
        for f in FLOAT_FIELDS:
            assert getattr(a, f) == getattr(c, f), (a.meta, f)
            assert getattr(b, f) == getattr(c, f), (b.meta, f)


def test_clear_sim_caches_drops_bank_device_buffers():
    specs = [ScenarioSpec(w, c) for w in WORKLOAD_POOL for c in CONFIGS]
    E.run_grid(specs, n_stores=N, tile_cells=16)      # uploads the bank
    assert len(S._BANK_CACHE) > 0
    bank = get_trace_bank(specs, N)                   # cache hit
    assert bank._device, "engine run should leave the bank device-resident"
    key = next(iter(bank._device))
    entry = bank._device[key]
    # sub placements memoize (rows, arrays); flat placements just arrays
    arrays = entry[1] if isinstance(entry[0], tuple) else entry
    buf_ref = weakref.ref(arrays[0])
    host_ref = weakref.ref(bank)
    del bank, entry, arrays
    clear_sim_caches()
    gc.collect()
    assert len(S._BANK_CACHE) == 0
    assert len(S._BANKED_INPUT_CACHE) == 0
    assert len(S._WV_ROW_CACHE) == 0
    assert buf_ref() is None, "bank device buffer leaked past cache clear"
    assert host_ref() is None, "bank host columns leaked past cache clear"


def test_bank_rows_are_shared_across_engines():
    """simulate_batch and run_grid on the same grid must resolve ONE
    bank object (the digest-keyed memo -- one upload per placement)."""
    specs = [ScenarioSpec("ycsb", c, seed=s) for c in CONFIGS
             for s in (0, 1)]
    simulate_batch(specs, n_stores=N)
    bank_a = get_trace_bank(specs, N)
    E.run_grid(specs, n_stores=N, tile_cells=16)
    assert get_trace_bank(specs, N) is bank_a


def test_oneshot_lane_dedup_drops_h2d_and_gather_width():
    """The one-shot banked tier no longer gathers the full (n_stores, B)
    batch: cells sharing a (SB, trace, max-plus row) lane are scanned
    once (here the whole CN axis collapses to 2 lanes for 20 cells), so
    the shipped index bytes -- and the device gather/scan width -- drop
    from padded cells to padded lanes, bit-identically."""
    specs = [ScenarioSpec("ycsb", c, n_cns=ncn)
             for c in ("wb", "proactive")
             for ncn in (16, 12, 8, 6, 4, 3, 2, 1, 24, 32)]
    out = simulate_batch(specs, n_stores=N)
    want = simulate_batch(specs, n_stores=N, data_plane="stacked")
    for a, b in zip(out, want):
        for f in FLOAT_FIELDS:
            assert getattr(a, f) == getattr(b, f), f
    meta = out[0].meta
    assert meta["scan_lanes"] == 2                 # one per config
    bank = get_trace_bank(specs, N)
    # pre-dedup accounting: 3 int32 vectors over the padded CELL count
    old_h2d = bank.nbytes + 3 * 4 * S._pad_len(len(specs))
    new_h2d = bank.nbytes + 3 * 4 * S._pad_len(2)
    assert meta["h2d_bytes"] == new_h2d < old_h2d
    # a grid with all-distinct lanes keeps lane count == cell count
    uniq = [ScenarioSpec(w, "proactive", seed=s)
            for w in WORKLOAD_POOL for s in (0, 1)]
    (r, *_) = simulate_batch(uniq, n_stores=N)
    assert r.meta["scan_lanes"] == len(uniq)


@settings(max_examples=10, deadline=None)
@given(ragged_grids(), ragged_grids())
def test_bank_extend_matches_from_scratch_merged_build(base, delta):
    """Append-only extension is byte-identical to a from-scratch build
    of the merged grid (the serving daemon's incremental-diff
    contract): same row maps, same column bytes, old indices intact."""
    bank = S._make_trace_bank(tuple(base), N, PAPER_CLUSTER)
    t0, p0 = bank.trace_rows, bank.wv_rows
    old_rows = {s: bank.rows_for(s) for s in base}
    nt, nw = bank.extend(delta)
    merged = S._make_trace_bank(tuple(base) + tuple(delta), N, PAPER_CLUSTER)
    assert (nt, nw) == (merged.trace_rows - t0, merged.wv_rows - p0)
    assert bank.trace_row == merged.trace_row
    assert bank.wv_row == merged.wv_row
    assert bank.arrivals.tobytes() == merged.arrivals.tobytes()
    assert bank.w.tobytes() == merged.w.tobytes()
    assert bank.v.tobytes() == merged.v.tobytes()
    assert bank.pr_nc.tobytes() == merged.pr_nc.tobytes()
    # indices handed out before the extension stay valid forever
    assert all(bank.rows_for(s) == r for s, r in old_rows.items())
    # idempotent: re-extending with the same specs appends nothing
    assert bank.extend(delta) == (0, 0)
    assert bank.arrivals.tobytes() == merged.arrivals.tobytes()


def test_bank_device_diff_upload_ships_only_new_rows():
    """A resident placement is refreshed incrementally after extend():
    only the appended rows cross host->device, and the refreshed device
    arrays equal the full (merged) host columns."""
    base = [ScenarioSpec("ycsb", c) for c in CONFIGS]
    bank = S._make_trace_bank(tuple(base), N, PAPER_CLUSTER)
    up0, _ = bank.device_args("serve")
    assert up0 == bank.nbytes                       # cold: full upload
    assert bank.device_args("serve")[0] == 0        # resident: no bytes
    nbytes0 = bank.nbytes
    delta = [ScenarioSpec("barnes", "proactive", seed=2),
             ScenarioSpec("ycsb", "proactive", n_replicas=4)]
    nt, nw = bank.extend(delta)
    assert nt == 1 and nw == 2
    up1, dev = bank.device_args("serve")
    assert up1 == bank.nbytes - nbytes0 > 0         # just the diff
    assert np.array_equal(np.asarray(dev[0]), bank.arrivals)
    assert np.array_equal(np.asarray(dev[1]), bank.w)
    assert np.array_equal(np.asarray(dev[2]), bank.v)
    assert np.array_equal(np.asarray(dev[3]), bank.pr_nc)
    assert bank.device_args("serve")[0] == 0        # resident again


def test_wb_wt_rows_collapse_to_constants():
    """Every WB (and WT) cell of a grid shares one constant column."""
    specs = [ScenarioSpec(w, c, seed=s, n_replicas=nr)
             for w in WORKLOAD_POOL for c in ("wb", "wt")
             for s in (0, 1) for nr in (None, 4)]
    bank = get_trace_bank(specs, N)
    assert bank.wv_rows == 2
    rows = {bank.rows_for(s)[1] for s in specs}
    assert len(rows) == 2
    with pytest.raises(KeyError):      # cells outside the build grid
        bank.rows_for(ScenarioSpec("ycsb", "proactive"))
