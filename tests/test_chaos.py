"""Fault-injection differential suite (``repro.core.chaos``).

The resilience contract (PR 9, mirroring the paper's §VI-VII recovery
argument): any single injected fault -- shard loss, corrupted bank row,
failed h2d upload, worker-thread death -- detected mid-grid or
mid-query-stream is recovered IN PLACE, and the recovered results are
bit-identical (``==``) to the fault-free oracle.  The spare-replacement
path re-places the rebuilt rows into the same shapes/shardings, so it
adds ZERO compiles; the two rebuild sources (surviving replica block,
Logging-Unit journal replay) produce byte-identical rows.  With chaos
off, ``k_replicas`` resolves to 1 and every placement key, byte count
and compile count is untouched (the PR-8 zero-churn pin).
"""

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import chaos
from repro.core import engine as E
from repro.core.chaos import ChaosConfig, IntegrityError
from repro.core.retry import (
    PLACEMENT_RETRY,
    RetryExhausted,
    RetryPolicy,
    backoff_delays,
    retry_call,
)
from repro.core.scenarios import chaos_grid, sweep_grid
from repro.core.serving import ScenarioServer
from repro.core.simulator import (
    CONFIGS,
    PAPER_CLUSTER,
    ScenarioSpec,
    clear_sim_caches,
    get_trace_bank,
    simulate_batch,
    sub_bank_rows,
)

N = 700
WORKLOAD_POOL = ("ycsb", "canneal", "barnes", "raytrace", "ocean_ncp")
FLOAT_FIELDS = ("exec_time_ns", "repl_at_head_frac", "sb_full_frac",
                "max_log_bytes", "cxl_mem_bw_gbps", "log_dump_bw_gbps")
SHARD_COUNTS = sorted({1, min(8, jax.device_count())})
FAULT_KINDS = ("shard-loss", "corrupt-row", "upload-failure",
               "kill-prefetch", "kill-warm")


def _assert_bit_identical(got, want, ctx):
    assert len(got) == len(want)
    for a, b in zip(got, want):
        for f in FLOAT_FIELDS:
            assert getattr(a, f) == getattr(b, f), (ctx, a.meta, f)


def _fault_cfg(kind, n_shards, **kw):
    """One-fault ChaosConfig per differential axis value."""
    if kind == "shard-loss":
        return ChaosConfig(lose_shard=n_shards - 1, lose_at_dispatch=1, **kw)
    if kind == "corrupt-row":
        return ChaosConfig(corrupt_wv_row=0, **kw)
    if kind == "upload-failure":
        return ChaosConfig(upload_failures=2, **kw)
    if kind == "kill-prefetch":
        return ChaosConfig(kill_thread="prefetch", **kw)
    if kind == "kill-warm":
        return ChaosConfig(kill_thread="warm", **kw)
    raise AssertionError(kind)


@st.composite
def ragged_grids(draw):
    """Small ragged mixed-SB grids (multiple tile signatures, so a
    mid-grid fault lands between differently-shaped tiles)."""
    n = draw(st.integers(min_value=2, max_value=8))
    specs = []
    for _ in range(n):
        specs.append(ScenarioSpec(
            draw(st.sampled_from(WORKLOAD_POOL)),
            draw(st.sampled_from(CONFIGS)),
            seed=draw(st.integers(min_value=0, max_value=1)),
            n_replicas=draw(st.sampled_from((None, 2, 3))),
            link_bw_gbps=draw(st.sampled_from((None, 40.0))),
            sb_size=draw(st.sampled_from((None, 48)))))
    return specs


# ---------------------------------------------------------------------------
# Engine: every fault x both data planes x 1 and 8 shards
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(ragged_grids(),
       st.sampled_from(FAULT_KINDS),
       st.sampled_from(SHARD_COUNTS),
       st.sampled_from(("bank", "stacked")))
def test_engine_faults_recover_bit_identical(grid, kind, n_shards, plane):
    """The headline differential: a fault injected mid-grid recovers to
    results ``==`` the fault-free oracle on every plane/shard combo."""
    oracle = simulate_batch(grid, n_stores=N)
    with chaos.inject(_fault_cfg(kind, n_shards)) as cs:
        got = E.run_grid(grid, n_stores=N, tile_cells=16,
                         n_shards=n_shards, data_plane=plane)
    _assert_bit_identical(got, oracle, (kind, n_shards, plane))
    rep = cs.report()
    if kind == "shard-loss":
        assert rep["recoveries"], (kind, n_shards, plane)
        assert rep["recoveries"][0]["shard"] == n_shards - 1
    if kind == "upload-failure":
        assert rep["upload_retries"] == 2
    if kind.startswith("kill"):
        assert rep["threads_killed"]


def test_engine_shard_loss_zero_recompiles_on_spare_path():
    """Spare replacement re-places the SAME shapes: the recovery itself
    must not trace a single new tile program, and a steady-state re-run
    after recovery stays at 0 compiles too."""
    n_shards = min(8, jax.device_count())
    if n_shards < 2:
        pytest.skip("needs >= 2 shards for a surviving replica")
    grid = chaos_grid()
    clear_sim_caches()
    oracle = simulate_batch(grid, n_stores=N)
    with chaos.inject(ChaosConfig(lose_shard=2, lose_at_dispatch=2)) as cs:
        warm = E.run_grid(grid, n_stores=N, tile_cells=16,
                          n_shards=n_shards)
        _assert_bit_identical(warm, oracle, "warmup-with-loss")
        assert cs.report()["recoveries"][0]["source"] == "replica"
        stats = E.bank_stats()
        assert stats["k_replicas"] == 2
        tc0 = E.trace_count()
        again = E.run_grid(grid, n_stores=N, tile_cells=16,
                           n_shards=n_shards)
        _assert_bit_identical(again, oracle, "steady-after-recovery")
        assert E.trace_count() == tc0          # zero new compiles
    rec = cs.report()["recoveries"]
    assert len(rec) == 1 and rec[0]["mode"] == "spare"


def test_engine_degraded_mesh_recovery():
    """No spare: the unfinished cells are re-run on a mesh shrunk by
    one shard with the bank replicated -- one recompile, results still
    bit-identical, and ``bank_stats()`` reports the degraded run."""
    n_shards = min(8, jax.device_count())
    if n_shards < 2:
        pytest.skip("cannot shrink a single-shard mesh")
    grid = sweep_grid(workloads=("ycsb", "barnes"),
                      configs=("wb", "proactive"), n_replicas=(None, 2))
    oracle = simulate_batch(grid, n_stores=N)
    with chaos.inject(ChaosConfig(lose_shard=0, lose_at_dispatch=1,
                                  recovery="degraded")) as cs:
        got = E.run_grid(grid, n_stores=N, tile_cells=16,
                         n_shards=n_shards)
    _assert_bit_identical(got, oracle, "degraded")
    assert E.bank_stats()["degraded"] is True
    rec = cs.report()["recoveries"]
    assert rec and rec[0]["mode"] == "degraded" \
        and rec[0]["source"] == "degraded-mesh"


def test_poisoned_tile_surfaces_with_context(monkeypatch):
    """Satellite bugfix pin: a genuine (non-injected) prefetch failure
    surfaces promptly as :class:`EngineWorkerError` naming the stage
    and tile -- not as a hang or an opaque error tiles later."""
    grid = [ScenarioSpec(w, c) for w in ("ycsb", "barnes")
            for c in ("wb", "proactive")]
    clear_sim_caches()
    real = E._prepare_cell

    def poisoned(spec, *a, **kw):
        if spec.workload == "barnes":
            raise ValueError("poisoned tile input")
        return real(spec, *a, **kw)

    monkeypatch.setattr(E, "_prepare_cell", poisoned)
    with pytest.raises(E.EngineWorkerError) as ei:
        E.run_grid(grid, n_stores=N, tile_cells=16, n_shards=1)
    assert ei.value.stage == "prefetch"
    assert ei.value.tile_no is not None
    assert "poisoned tile input" in str(ei.value)
    # the run fails promptly AND cleanly: the engine serves the same
    # grid fine immediately afterwards
    monkeypatch.setattr(E, "_prepare_cell", real)
    clear_sim_caches()
    _assert_bit_identical(E.run_grid(grid, n_stores=N, tile_cells=16,
                                     n_shards=1),
                          simulate_batch(grid, n_stores=N), "after-poison")


# ---------------------------------------------------------------------------
# Rebuild sources: replica block vs Logging-Unit journal
# ---------------------------------------------------------------------------


def test_journal_replay_equals_replica_rebuild():
    """The two rebuild sources are interchangeable: for every shard,
    the rows read back from the surviving replica block are
    byte-identical to the journal/host rebuild, and both pass
    ``verify_rebuild``'s digests."""
    n_shards = min(8, jax.device_count())
    if n_shards < 2:
        pytest.skip("replica rebuild needs >= 2 shards")
    base = sweep_grid(workloads=("ycsb", "canneal"), configs=CONFIGS)
    delta = sweep_grid(workloads=("barnes",), configs=("wb", "proactive"),
                       n_replicas=(2, 3))
    clear_sim_caches()
    bank = get_trace_bank(base, N, PAPER_CLUSTER)
    bank.enable_journal()
    bank.extend(delta)                    # journaled, un-acked diffs
    assert bank.journal_entries > 0
    _, dev = bank.sub_device_args(n_shards, k_replicas=2)
    local_cap = sub_bank_rows(bank.wv_rows, n_shards)
    for lost in range(n_shards):
        via_replica = chaos.replica_rebuild(
            dev, lost, n_shards=n_shards, k_replicas=2,
            local_cap=local_cap, wv_rows=bank.wv_rows)
        via_journal = chaos.journal_rebuild(bank, lost, n_shards)
        for name in ("w", "v", "pr_nc"):
            assert np.array_equal(via_replica[name], via_journal[name]), \
                (lost, name)
        chaos.verify_rebuild(bank, via_replica, lost, n_shards)
        chaos.verify_rebuild(bank, via_journal, lost, n_shards)
    # a corrupted rebuild must NOT pass the digests
    bad = {k: v.copy() for k, v in via_journal.items()}
    bad["w"][0, 0] += 1.0
    with pytest.raises(IntegrityError):
        chaos.verify_rebuild(bank, bad, n_shards - 1, n_shards)


def test_replica_layout_and_integrity_detection():
    """Replica-block geometry: block ``j`` of shard ``s`` holds the
    rows owned by ``(s - j) % n``; ``fetch_wv_row`` reads identical
    bytes off either block; ``verify_rows`` catches a tampered row."""
    n_shards = min(8, jax.device_count())
    if n_shards < 2:
        pytest.skip("needs >= 2 shards")
    grid = sweep_grid(workloads=("ycsb", "raytrace"), configs=CONFIGS)
    clear_sim_caches()
    bank = get_trace_bank(grid, N, PAPER_CLUSTER)
    k = 2
    a, w, v, p = bank.sub_bank_host(n_shards, k)
    p_loc = sub_bank_rows(bank.wv_rows, n_shards)
    assert w.shape == (n_shards, k * p_loc, N)
    for r in range(bank.wv_rows):
        owner, loc = r % n_shards, r // n_shards
        for j in range(k):
            s = (owner + j) % n_shards
            assert np.array_equal(w[s, j * p_loc + loc], bank.w[r]), (r, j)
    # byte cost: the replicated layout is exactly k stacked copies
    a1, w1, v1, p1 = bank.sub_bank_host(n_shards, 1)
    assert w.nbytes == k * w1.nbytes
    # device path: both resident copies digest-match the host truth
    _, dev = bank.sub_device_args(n_shards, k_replicas=k)
    for r in (0, bank.wv_rows - 1):
        for j in range(k):
            got = chaos.fetch_wv_row(dev, r, n_shards=n_shards,
                                     local_cap=p_loc, block=j)
            assert chaos.row_digest(got[0]) == chaos.row_digest(bank.w[r])
    chaos.verify_rows(bank, dev, range(bank.wv_rows),
                      n_shards=n_shards, local_cap=p_loc)
    with chaos.inject(ChaosConfig(corrupt_wv_row=1)) as cs:
        tampered = cs.tamper_bank(dev, n_shards=n_shards, k_replicas=k,
                                  local_cap=p_loc, wv_rows=bank.wv_rows)
        with pytest.raises(IntegrityError) as ei:
            chaos.verify_rows(bank, tampered, range(bank.wv_rows),
                              n_shards=n_shards, local_cap=p_loc)
        assert ei.value.rows == (1,)


# ---------------------------------------------------------------------------
# Chaos off: the PR-8 zero-churn pin
# ---------------------------------------------------------------------------


def test_chaos_off_zero_churn():
    """With no chaos scope, ``k_replicas`` resolves to 1 and the
    placement keys, resident bytes and compile counts are the PR-8
    ones bit-for-bit -- resilience costs nothing until requested."""
    assert chaos.active() is None
    n_shards = min(8, jax.device_count())
    assert chaos.resolve_k_replicas(None, n_shards) == 1
    assert chaos.resolve_k_replicas(3, n_shards) == min(3, n_shards)
    with chaos.inject(ChaosConfig()):
        assert chaos.resolve_k_replicas(None, n_shards) == \
            min(2, n_shards)
        assert chaos.resolve_k_replicas(None, 1) == 1     # clamped
    grid = sweep_grid(workloads=("ycsb", "canneal"), configs=CONFIGS)
    clear_sim_caches()
    E.run_grid(grid, n_stores=N, tile_cells=16, n_shards=n_shards)
    stats = E.bank_stats()
    assert stats["k_replicas"] == 1
    assert stats["chaos"] is None
    assert stats["degraded"] is False
    bank = get_trace_bank(grid, N)
    # the k=1 placement memo key is EXACTLY the PR-8 key (pinned by
    # test_trace_bank.py too): resilient placements use a distinct key
    assert ("sub", n_shards) in bank._device
    assert ("sub", n_shards, 2) not in bank._device
    # measured bytes match the k=1 host stacks exactly
    a, w, v, p = bank.sub_bank_host(n_shards, 1)
    assert stats["bank_dev_bytes"] == \
        n_shards * a.nbytes + w.nbytes + v.nbytes + p.nbytes
    # journal off by default: no diff copies retained
    assert bank.journal_entries == 0


# ---------------------------------------------------------------------------
# Bounded retry (core.retry)
# ---------------------------------------------------------------------------


def test_retry_backoff_deterministic_and_capped():
    pol = RetryPolicy(max_attempts=5, base_delay_s=0.010,
                      max_delay_s=0.025, jitter=0.5, seed=0)
    d1 = list(backoff_delays(pol, "x"))
    d2 = list(backoff_delays(pol, "x"))
    assert d1 == d2                              # seeded by describe
    assert d1 != list(backoff_delays(pol, "y"))
    assert len(d1) == pol.max_attempts - 1
    assert all(0 < d <= pol.max_delay_s * (1 + pol.jitter) for d in d1)


def test_retry_call_recovers_and_exhausts():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise chaos.UploadError("transient")
        return "ok"

    retries = []
    assert retry_call(flaky, policy=PLACEMENT_RETRY,
                      retryable=(chaos.UploadError,), describe="flaky",
                      on_retry=lambda n, e, d: retries.append(e)) == "ok"
    assert calls["n"] == 3 and len(retries) == 2

    def dead():
        raise chaos.UploadError("always")

    with pytest.raises(RetryExhausted) as ei:
        retry_call(dead, policy=PLACEMENT_RETRY,
                   retryable=(chaos.UploadError,), describe="dead-path")
    assert ei.value.attempts == PLACEMENT_RETRY.max_attempts
    assert "dead-path" in str(ei.value)
    assert isinstance(ei.value.last, chaos.UploadError)

    # non-retryable errors pass straight through on attempt 1
    def bug():
        raise ValueError("logic bug")

    with pytest.raises(ValueError):
        retry_call(bug, policy=PLACEMENT_RETRY,
                   retryable=(chaos.UploadError,), describe="bug")


# ---------------------------------------------------------------------------
# Serving daemon: faults mid-query-stream
# ---------------------------------------------------------------------------


SERVE_WARM = sweep_grid(workloads=("ycsb", "raytrace"), configs=CONFIGS)
SERVE_NOVEL = sweep_grid(workloads=("barnes",),
                         configs=("baseline", "proactive"),
                         n_replicas=(2, 3))


@pytest.mark.parametrize("kind", ("shard-loss", "corrupt-row",
                                  "upload-failure", "kill-daemon"))
def test_server_faults_recover_bit_identical(kind):
    """Mid-query-stream faults: the server detects, recovers in place
    (keeping its padded capacity, so ZERO recompiles), and every answer
    stays ``==`` the cold oracle."""
    n_shards = min(8, jax.device_count())
    clear_sim_caches()
    oracle = simulate_batch(SERVE_NOVEL, n_stores=N)
    cfg = (ChaosConfig(kill_thread="daemon") if kind == "kill-daemon"
           else ChaosConfig(lose_shard=n_shards - 1, lose_at_dispatch=2)
           if kind == "shard-loss" else _fault_cfg(kind, n_shards))
    with chaos.inject(cfg) as cs:
        with ScenarioServer(n_stores=N, n_shards=n_shards,
                            batch_cells=16,
                            submit_timeout_ms=60_000) as srv:
            assert srv.k_replicas == min(2, n_shards)
            srv.warm(SERVE_WARM)
            srv.reset_stats()
            if kind == "kill-daemon":
                futs = [srv.submit(s) for s in SERVE_NOVEL]
                got = [f.result(timeout=120) for f in futs]
            else:
                got = srv.query_batch(SERVE_NOVEL)
            _assert_bit_identical(got, oracle, kind)
            stats = srv.stats()
            assert stats["compiled_programs"] == 0, kind
            if kind == "shard-loss":
                assert stats["recoveries"] == 1
                assert cs.report()["recoveries"][0]["source"] == \
                    ("replica" if n_shards > 1 else "journal")
                # post-recovery steady state: all hits, still 0 compiles
                again = srv.query_batch(SERVE_NOVEL)
                _assert_bit_identical(again, oracle, "steady")
                assert srv.stats()["compiled_programs"] == 0
            if kind == "kill-daemon":
                assert stats["worker_restarts"] >= 1


def test_server_journal_acked_after_flush():
    """The Logging Unit retains un-dumped diffs only until the device
    dump is acknowledged at the end of a successful flush."""
    with chaos.inject(ChaosConfig()):
        clear_sim_caches()
        n_shards = min(2, jax.device_count())
        with ScenarioServer(n_stores=N, n_shards=n_shards,
                            batch_cells=16) as srv:
            srv.warm(SERVE_WARM)
            assert srv.stats()["journal_entries"] == 0   # acked by warm
            srv.query_batch(SERVE_NOVEL)
            assert srv.stats()["journal_entries"] == 0
