"""Replication engine: variants, coalescing, recovery exactness, and the
replica-group invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ReplicationConfig
from repro.core import recovery as R
from repro.core import replica_groups as rg
from repro.core.directory import ShardDirectory
from repro.core.replication import ReplicationEngine
from repro.distributed.context import make_context, mesh_context


# ---------------------------------------------------------------------------
# Replica groups
# ---------------------------------------------------------------------------

@given(st.integers(0, 1000), st.integers(1, 4), st.integers(4, 64))
@settings(max_examples=50, deadline=None)
def test_replica_offsets_invariants(bucket, n_rep, n_nodes):
    if n_rep >= n_nodes:
        n_rep = n_nodes - 1
    offs = rg.replica_offsets(bucket, n_rep, n_nodes)
    assert len(set(offs)) == n_rep
    assert all(1 <= o < n_nodes for o in offs)


@given(st.integers(0, 100), st.integers(4, 32))
@settings(max_examples=30, deadline=None)
def test_targets_sources_inverse(bucket, n_nodes):
    n_rep = 3 if n_nodes > 3 else n_nodes - 1
    for node in range(n_nodes):
        for t in rg.replica_targets(node, bucket, n_rep, n_nodes):
            assert node in rg.replica_sources(t, bucket, n_rep, n_nodes)


def test_balanced_load():
    """Every node logs for exactly N_r sources per bucket."""
    n, r = 16, 3
    for bucket in range(8):
        counts = {i: 0 for i in range(n)}
        for node in range(n):
            for t in rg.replica_targets(node, bucket, r, n):
                counts[t] += 1
        assert all(c == r for c in counts.values())


def test_line_replicas_address_determined():
    a = rg.line_replicas(1234, 3, 16)
    b = rg.line_replicas(1234, 3, 16)
    assert a == b and len(set(a)) == 3


# ---------------------------------------------------------------------------
# Engine end-to-end on a mesh
# ---------------------------------------------------------------------------

def _setup(mesh, variant, coalescing, n_buckets=2, cap=3):
    ctx = make_context(mesh)
    params = {
        "w1": jnp.arange(48, dtype=jnp.float32).reshape(8, 6),
        "w2": jnp.arange(32, dtype=jnp.float32).reshape(4, 8) * 0.5,
        "scale": jnp.ones((6,), jnp.float32),
    }
    specs = {"w1": P("data", "model"), "w2": P("model", "data"),
             "scale": P(None)}
    params = {k: jax.device_put(v, NamedSharding(mesh, specs[k]))
              for k, v in params.items()}
    rep = ReplicationConfig(variant=variant, n_replicas=2,
                            n_buckets=n_buckets, log_capacity=cap,
                            coalescing=coalescing, log_dtype="float32")
    eng = ReplicationEngine(rep, ctx, specs, params)
    return ctx, params, specs, eng


@pytest.mark.parametrize("variant", ["baseline", "parallel", "proactive"])
@pytest.mark.parametrize("coalescing", [True, False])
def test_recover_exact_all_variants(mesh8, variant, coalescing):
    ctx, params, specs, eng = _setup(mesh8, variant, coalescing)
    logs = eng.init_logs()

    @jax.jit
    def step(params, logs, step_no):
        new_params = jax.tree.map(lambda x: x * 1.5 + 1.0, params)
        logs, committed = eng.replicate(new_params, logs, step_no, new_params)
        return committed, logs

    with mesh_context(ctx):
        p, l = params, logs
        for i in range(3):
            p, l = step(p, l, jnp.int32(i))

    directory = ShardDirectory(4, eng.layout.n_buckets, 2)
    for failed in range(4):
        res = R.recover_node(eng, l, directory if failed == 0 else
                             ShardDirectory(4, eng.layout.n_buckets, 2),
                             failed_coord=(failed,))
        assert res.stats.unrecoverable == 0
        per_model = R.reassemble_shard(eng, res)
        for m in range(2):
            leaves = per_model[m]
            w1_true = np.asarray(p["w1"])[2 * failed:2 * failed + 2,
                                          3 * m:3 * m + 3]
            w2_true = np.asarray(p["w2"])[2 * m:2 * m + 2,
                                          2 * failed:2 * failed + 2]
            tree = eng.unflatten(leaves)
            np.testing.assert_allclose(tree["w1"], w1_true)
            np.testing.assert_allclose(tree["w2"], w2_true)
            np.testing.assert_allclose(tree["scale"], np.asarray(p["scale"]))


def test_latest_version_wins(mesh8):
    """Recovery must return the newest validated step, not an older one."""
    ctx, params, specs, eng = _setup(mesh8, "proactive", False, cap=2)
    logs = eng.init_logs()

    @jax.jit
    def step(params, logs, step_no):
        new_params = jax.tree.map(lambda x: x + 1.0, params)
        logs, committed = eng.replicate(new_params, logs, step_no, new_params)
        return committed, logs

    with mesh_context(ctx):
        p, l = params, logs
        for i in range(5):   # wraps the capacity-2 ring twice
            p, l = step(p, l, jnp.int32(i))

    res = R.recover_node(eng, l, ShardDirectory(4, eng.layout.n_buckets, 2),
                         failed_coord=(1,))
    for b, shard in res.shards.items():
        assert shard.ts == 4          # newest step


def test_log_memory_layout(mesh8):
    ctx, params, specs, eng = _setup(mesh8, "proactive", True, n_buckets=2)
    st_ = eng.log_struct()
    # (data, model, N_r, capacity, n_buckets, bucket_len)
    assert st_["values"].shape[:2] == (4, 2)
    assert st_["values"].shape[2] == 2       # N_r
    assert st_["ts"].shape == st_["valid"].shape


def test_writethrough_and_none_noop(mesh8):
    ctx = make_context(mesh8)
    for variant in ("none", "writethrough"):
        rep = ReplicationConfig(variant=variant)
        assert not rep.is_replicating


@pytest.mark.parametrize("failed", [0, 2, 3])
def test_parity_mode_recovery_exact(mesh8, failed):
    """Beyond-paper erasure-coded logs: lost shard = parity - survivors.
    One parity shard per group of G nodes => N_r x less log memory."""
    ctx = make_context(mesh8)
    params = {
        "w1": jnp.arange(48, dtype=jnp.float32).reshape(8, 6),
        "w2": jnp.arange(32, dtype=jnp.float32).reshape(4, 8) * 0.5,
    }
    specs = {"w1": P("data", "model"), "w2": P("model", "data")}
    params = {k: jax.device_put(v, NamedSharding(mesh8, specs[k]))
              for k, v in params.items()}
    rep = ReplicationConfig(variant="proactive", n_replicas=1, n_buckets=2,
                            log_capacity=2, mode="parity", parity_group=2,
                            log_dtype="float32")
    eng = ReplicationEngine(rep, ctx, specs, params)
    logs = eng.init_logs()
    assert eng.log_struct()["values"].shape[2] == 1   # one parity shard

    @jax.jit
    def step(params, logs, step_no):
        new_params = jax.tree.map(lambda x: x * 1.25 + 0.5, params)
        logs, committed = eng.replicate(new_params, logs, step_no,
                                        new_params)
        return committed, logs

    with mesh_context(ctx):
        p, l = params, logs
        for i in range(3):
            p, l = step(p, l, jnp.int32(i))

    res = R.recover_node_parity(eng, l, p, specs, failed_coord=(failed,))
    assert res.stats.unrecoverable == 0
    per_model = R.reassemble_shard(eng, res)
    for m in range(2):
        tree = eng.unflatten(per_model[m])
        w1_true = np.asarray(p["w1"])[2 * failed:2 * failed + 2,
                                      3 * m:3 * m + 3]
        w2_true = np.asarray(p["w2"])[2 * m:2 * m + 2,
                                      2 * failed:2 * failed + 2]
        np.testing.assert_allclose(tree["w1"], w1_true, atol=1e-4)
        np.testing.assert_allclose(tree["w2"], w2_true, atol=1e-4)


def test_parity_holder_outside_group(mesh8):
    ctx = make_context(mesh8)
    params = {"w": jnp.zeros((8, 8), jnp.float32)}
    specs = {"w": P("data", "model")}
    rep = ReplicationConfig(variant="proactive", n_replicas=1,
                            mode="parity", parity_group=2, n_buckets=4)
    eng = ReplicationEngine(rep, ctx, specs, params)
    for g in range(2):
        for b in range(eng.layout.n_buckets):
            h = eng.parity_holder(g, b)
            assert h // 2 != g            # never inside its own group


def test_bucket_pack_unpack_roundtrip(mesh8):
    ctx, params, specs, eng = _setup(mesh8, "proactive", False, n_buckets=3)
    lay = eng.layout
    rng = np.random.default_rng(0)
    leaves = [jnp.asarray(rng.standard_normal(s), jnp.float32)
              for s in lay.local_shapes]
    buckets = jnp.stack([eng.pack_bucket(leaves, b)
                         for b in range(lay.n_buckets)])
    out = eng.unpack(buckets)
    for a, b in zip(leaves, out):
        np.testing.assert_allclose(a, b)
