"""Per-architecture smoke tests (assignment requirement): reduced
same-family config, one forward/train step on CPU, output shapes + no
NaNs; plus prefill/decode agreement on every family."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

import repro
from repro.config import ShapeConfig
from repro.configs import ASSIGNED_ARCHS
from repro.models import build_model
from repro.models.model_zoo import make_batch

SMOKE = ShapeConfig("smoke", seq_len=48, global_batch=2, kind="train")


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_smoke(arch):
    cfg = repro.get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, SMOKE)
    batch["labels"] = batch["tokens"]

    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (2, 48, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p: model.loss_fn(p, batch), has_aux=True)
    )(params)
    assert jnp.isfinite(loss)
    gnorm = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_agreement(arch):
    """decode(prefill(t[:-1]), t[-1]) == prefill(t)[-1] -- per family.
    MoE archs use a no-drop capacity so routing is identical."""
    cfg = repro.get_reduced_config(arch)
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = make_batch(cfg, SMOKE)
    batch.pop("labels", None)

    full, _ = jax.jit(lambda p, b: model.prefill(p, b, max_len=64))(
        params, batch)
    short = dict(batch)
    short["tokens"] = batch["tokens"][:, :-1]
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, max_len=64))(
        params, short)
    dec, _ = jax.jit(model.decode_step)(params, cache,
                                        batch["tokens"][:, -1])
    err = float(jnp.max(jnp.abs(dec - full[:, -1, :])))
    # bf16 recurrence recompute tolerance (ssm/hybrid slightly looser)
    tol = 0.12 if cfg.family in ("ssm", "hybrid") else 0.05
    assert err <= tol, f"{arch}: decode/prefill mismatch {err}"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_full_config_registered(arch):
    cfg = repro.get_model_config(arch)
    assert cfg.param_count() > 0
    red = repro.get_reduced_config(arch)
    assert red.family == cfg.family
    assert red.is_moe == cfg.is_moe
    assert red.is_encdec == cfg.is_encdec
    assert red.param_count() < 1e6 * 5   # CPU-sized


def test_param_counts_match_published():
    """Total parameter counts must match the published sizes (+-15%)."""
    expected = {
        "qwen3-0.6b": 0.6e9, "deepseek-67b": 67e9, "stablelm-12b": 12.1e9,
        "starcoder2-15b": 16e9, "mamba2-2.7b": 2.7e9, "grok-1-314b": 314e9,
        "whisper-medium": 0.77e9, "hymba-1.5b": 1.5e9,
    }
    for arch, n in expected.items():
        got = repro.get_model_config(arch).param_count()
        assert abs(got - n) / n < 0.20, f"{arch}: {got/1e9:.2f}B vs {n/1e9}B"
    # moonshot: the ASSIGNED spec (48L) is deeper than the HF release
    # (27L); the derived count must match the assigned spec, and the MoE
    # active/total ratio must reflect 64e top-6 + 2 shared.
    ms = repro.get_model_config("moonshot-v1-16b-a3b")
    assert abs(ms.param_count() - 28.9e9) / 28.9e9 < 0.05
    assert 0.1 < ms.active_param_count() / ms.param_count() < 0.25
    # internvl2-26b models the LM backbone only (InternViT is stubbed)
    iv = repro.get_model_config("internvl2-26b")
    assert abs(iv.param_count() - 19.9e9) / 19.9e9 < 0.05
