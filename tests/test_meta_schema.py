"""``SimResult.meta`` provenance schema, pinned across every tier.

The observability contract (docs/observability.md): any result, from
any engine tier or serving path, must say where it came from --
``meta["engine"]``, ``meta["data_plane"]`` and ``meta["bank_partition"]``
are always present, with the tier's documented values.  PRs 1-9 grew
the tiers one at a time and the earlier ones predate the bank plane;
this test is the single place that keeps the schema from drifting as
new tiers land.
"""

import pytest

from repro.core import engine as E
from repro.core.scenarios import sweep_grid
from repro.core.simulator import simulate, simulate_batch

N = 500
GRID = sweep_grid(workloads=("ycsb",), configs=("wb", "proactive"),
                  sb_sizes=(None, 48))


def _serial():
    return [simulate("ycsb", "wb", n_stores=N).meta]


def _blocked_bank():
    return [r.meta for r in simulate_batch(GRID, n_stores=N)]


def _blocked_stacked():
    return [r.meta
            for r in simulate_batch(GRID, n_stores=N,
                                    data_plane="stacked")]


def _perstep():
    return [r.meta for r in simulate_batch(GRID, n_stores=N,
                                           chunk_size=0)]


def _streamed():
    return [r.meta for r in E.run_grid(GRID, n_stores=N, n_shards=1)]


def _sharded():
    # n_shards=1 would report engine="streamed", so this tier needs a
    # real second device (the CI tier-1 matrix also runs host_devices=1)
    import jax
    if len(jax.devices()) < 2:
        pytest.skip("sharded tier needs >= 2 devices")
    return [r.meta for r in E.run_grid(GRID, n_stores=N, n_shards=2)]


def _serving():
    from repro.core.serving import ScenarioServer
    with ScenarioServer(n_stores=N, batch_cells=8) as srv:
        return [r.meta for r in srv.query_batch(GRID)]


TIERS = {
    "serial": (_serial, "serial", "stacked", None),
    "blocked-bank": (_blocked_bank, "blocked", "bank", None),
    "blocked-stacked": (_blocked_stacked, "blocked", "stacked", None),
    "perstep": (_perstep, "perstep", "stacked", None),
    "streamed": (_streamed, "streamed", "bank", "sub"),
    "sharded": (_sharded, "sharded", "bank", "sub"),
    "serving": (_serving, "serving", "bank", "sub"),
}


@pytest.mark.parametrize("tier", sorted(TIERS))
def test_meta_provenance_schema(tier):
    run, engine, plane, partition = TIERS[tier]
    metas = run()
    assert metas, tier
    for m in metas:
        assert m is not None, tier
        # the three provenance keys are unconditionally present
        for key in ("engine", "data_plane", "bank_partition"):
            assert key in m, (tier, key, sorted(m))
        assert m["engine"] == engine, (tier, m)
        assert m["data_plane"] == plane, (tier, m)
        assert m["bank_partition"] == partition, (tier, m)


@pytest.mark.parametrize("tier", sorted(TIERS))
def test_meta_is_per_result_not_aliased(tier):
    """Annotating one result's meta must not leak into its batch
    siblings (frozen dataclass, mutable dict -- aliasing would)."""
    metas = TIERS[tier][0]()
    if len(metas) < 2:
        pytest.skip("single-result tier")
    metas[0]["__scratch__"] = 1
    assert "__scratch__" not in metas[1]
