"""Thread-safety of the shared host-side memo (hostcache.BoundedCache).

The streaming engine mutates the simulator / contention memos from its
prefetch and compile-warm worker threads concurrently with the caller's
thread. The contract:

* ``get_or_put`` builds each key's value EXACTLY once, no matter how
  many threads race on it (device-resident values must not be built
  twice, and a torn ``OrderedDict`` corrupts every later lookup);
* the LRU bound holds under concurrent inserts;
* ``clear()`` racing ``get_or_put`` never corrupts the dict (values may
  be rebuilt after a clear -- that is the point of clearing);
* nested get_or_put across two caches (cell arrays pull trace rows)
  and same-cache re-entrancy (RLock) both work from worker threads.

Run under ``PYTHONDEVMODE=1`` in the CI thread-safety job.
"""

import threading
from concurrent.futures import ThreadPoolExecutor

from repro.core.hostcache import BoundedCache


def test_single_make_per_key_under_contention():
    cache = BoundedCache(maxsize=256)
    calls = []
    barrier = threading.Barrier(8)

    def worker(tid):
        barrier.wait()
        out = []
        for rep in range(200):
            key = rep % 32
            val = cache.get_or_put(key, lambda k=key: calls.append(k)
                                   or ("value", k))
            out.append((key, val))
        return out

    with ThreadPoolExecutor(max_workers=8) as ex:
        results = [f.result() for f in
                   [ex.submit(worker, t) for t in range(8)]]

    assert len(calls) == 32, "make() ran more than once for some key"
    assert sorted(calls) == list(range(32))
    for out in results:
        for key, val in out:
            assert val == ("value", key), "corrupted value under races"
    assert len(cache) == 32
    assert cache.misses == 32
    assert cache.hits == 8 * 200 - 32


def test_lru_bound_holds_under_concurrent_inserts():
    cache = BoundedCache(maxsize=16)

    def worker(tid):
        for i in range(500):
            cache.get_or_put((tid, i), lambda: i)

    with ThreadPoolExecutor(max_workers=8) as ex:
        for f in [ex.submit(worker, t) for t in range(8)]:
            f.result()
    assert len(cache) <= 16


def test_clear_races_get_or_put():
    cache = BoundedCache(maxsize=64)
    stop = threading.Event()
    errors = []

    def churn():
        i = 0
        try:
            while not stop.is_set():
                v = cache.get_or_put(i % 40, lambda k=i % 40: ("v", k))
                assert v == ("v", i % 40)
                i += 1
        except Exception as e:        # pragma: no cover - failure path
            errors.append(e)

    def clearer():
        try:
            while not stop.is_set():
                cache.clear()
        except Exception as e:        # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=churn) for _ in range(4)]
    threads.append(threading.Thread(target=clearer))
    for t in threads:
        t.start()
    stop_timer = threading.Timer(0.5, stop.set)
    stop_timer.start()
    for t in threads:
        t.join()
    stop_timer.cancel()
    assert not errors, errors
    assert len(cache) <= 64


def test_nested_and_reentrant_get_or_put():
    outer = BoundedCache(maxsize=8)
    inner = BoundedCache(maxsize=8)

    def make_outer(key):
        # cross-cache nesting: cell arrays pull trace rows
        row = inner.get_or_put(("trace", key), lambda: key * 2)
        # same-cache re-entrancy: RLock must not deadlock
        base = outer.get_or_put(("base",), lambda: 100)
        return row + base

    with ThreadPoolExecutor(max_workers=4) as ex:
        vals = [f.result() for f in
                [ex.submit(lambda k=k: outer.get_or_put(
                    k, lambda: make_outer(k))) for k in range(4)]]
    assert vals == [100, 102, 104, 106]
    assert len(inner) == 4


def test_sim_caches_are_bounded_caches():
    """The simulator / contention memos actually use this primitive
    (the engine's worker threads rely on it)."""
    from repro.core import contention as C
    from repro.core import simulator as S
    for cache in (S._CELL_ARRAY_CACHE, S._WV_ROW_CACHE, S._BANK_CACHE,
                  C._DRAW_CACHE, C._DELAY_CACHE):
        assert isinstance(cache, BoundedCache)
        assert cache._lock is not None
