"""Sharded streaming engine tier: differential + scheduling tests.

The contract (engine.py module docstring): ``run_grid`` -- tiled,
cell-sharded over the local devices (8 host devices here, set up by
conftest.py), double-buffered -- must be **bit-identical** (``==``) to
the one-shot blocked batch and to the serial ``simulate()`` oracle, for
ragged grids whose cell count divides neither the device count nor the
tile size, and must reuse one compiled program per
:class:`TileSignature` across all tiles (the compile cache is observed
through ``trace_count()``).
"""

import jax
import numpy as np
import pytest

from repro.core import engine as E
from repro.core.simulator import (
    AUTO_CHUNK_WIDE_CELLS,
    CONFIGS,
    DEFAULT_CHUNK_SIZE,
    ScenarioSpec,
    auto_chunk,
    clear_sim_caches,
    simulate,
    simulate_batch,
)

N = 2500                       # N % chunk != 0 -> ragged store tail too
FLOAT_FIELDS = ("exec_time_ns", "repl_at_head_frac", "sb_full_frac",
                "max_log_bytes", "cxl_mem_bw_gbps", "log_dump_bw_gbps")

# 37 cells: not a multiple of the 8 host devices, the tile size used
# below, or the canonical pad sizes; mixed SB depths force two schedule
# groups; seeds/knobs exercise the reduced-key prep sharing.
RAGGED_GRID = (
    [ScenarioSpec(w, c, seed=s)
     for w in ("ycsb", "raytrace", "canneal") for c in CONFIGS
     for s in (0, 1)]
    + [
        ScenarioSpec("barnes", "proactive", sb_size=16),
        ScenarioSpec("ycsb", "parallel", sb_size=16),
        ScenarioSpec("bodytrack", "baseline"),
        ScenarioSpec("ocean_cp", "wt"),
        ScenarioSpec("fluidanimate", "proactive", n_replicas=4),
        ScenarioSpec("streamcluster", "wb"),
        ScenarioSpec("ocean_ncp", "proactive", coalescing=False),
    ]
)


def _assert_bit_identical(specs, got, want, ctx):
    assert len(got) == len(want) == len(specs)
    for spec, a, b in zip(specs, got, want):
        assert (a.workload, a.config) == (spec.workload, spec.config), ctx
        assert a.n_repl_msgs == b.n_repl_msgs, (ctx, spec)
        for f in FLOAT_FIELDS:
            assert getattr(a, f) == getattr(b, f), (ctx, spec, f)


@pytest.fixture(scope="module")
def blocked_results():
    return simulate_batch(RAGGED_GRID, n_stores=N)


def test_stream_bit_identical_to_blocked_and_serial(blocked_results):
    out = E.run_grid(RAGGED_GRID, n_stores=N, tile_cells=16)
    _assert_bit_identical(RAGGED_GRID, out, blocked_results, "stream-vs-blocked")
    # spot-check straight against the serial oracle as well
    for i in (0, 7, 17, 30, 36):
        s = RAGGED_GRID[i]
        rs = simulate(s.workload, s.config, n_stores=N, seed=s.seed,
                      n_replicas=s.n_replicas, link_bw_gbps=s.link_bw_gbps,
                      n_cns=s.n_cns, sb_size=s.sb_size,
                      coalescing=s.coalescing)
        for f in FLOAT_FIELDS:
            assert getattr(out[i], f) == getattr(rs, f), (s, f)


def test_stream_single_shard_matches_sharded(blocked_results):
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices for a sharded run")
    sharded = E.run_grid(RAGGED_GRID, n_stores=N, tile_cells=16,
                         n_shards=min(8, jax.device_count()))
    single = E.run_grid(RAGGED_GRID, n_stores=N, tile_cells=16, n_shards=1)
    _assert_bit_identical(RAGGED_GRID, sharded, single, "sharded-vs-single")
    _assert_bit_identical(RAGGED_GRID, single, blocked_results,
                          "single-vs-blocked")
    assert sharded[0].meta["engine"] == "sharded"
    assert sharded[0].meta["n_shards"] > 1
    assert single[0].meta["engine"] == "streamed"
    assert single[0].meta["n_shards"] == 1


def test_compile_cache_hits_across_tiles():
    """One signature's program must be traced at most once however many
    tiles reuse it, and a second grid with the same shapes must not
    trace at all."""
    clear_sim_caches()           # drop compiled tile programs -> cold
    grid_a = [ScenarioSpec(w, c, seed=s)
              for w in ("ycsb", "raytrace") for c in CONFIGS
              for s in range(8)]                      # 80 cells, one SB
    t0 = E.trace_count()
    E.run_grid(grid_a, n_stores=N, tile_cells=16)     # 5 tiles, 1 sig
    first = E.trace_count() - t0
    assert first <= 2, f"expected one-ish trace for one signature, got {first}"

    # different specs, same tile shapes -> pure cache hits
    grid_b = [ScenarioSpec(w, c, seed=s)
              for w in ("barnes", "canneal") for c in CONFIGS
              for s in range(8, 16)]
    t1 = E.trace_count()
    E.run_grid(grid_b, n_stores=N, tile_cells=16)
    assert E.trace_count() - t1 == 0, "same-signature tiles re-traced"


def test_plan_tiles_partitions_and_canonical_shapes():
    tiles = E.plan_tiles(RAGGED_GRID, n_stores=N, tile_cells=16, n_shards=8)
    # every original index exactly once
    seen = sorted(i for t in tiles for i in t.indices)
    assert seen == list(range(len(RAGGED_GRID)))
    sigs = {t.sig for t in tiles}
    # canonical padding: at most two pad sizes per SB group
    for sb in {t.sig.sb_uniform for t in tiles}:
        pads = {t.sig.b_pad for t in tiles if t.sig.sb_uniform == sb}
        assert len(pads) <= 2, (sb, pads)
    for t in tiles:
        assert len(t.specs) <= t.sig.b_pad
        assert t.sig.b_pad % 8 == 0 and t.sig.b_pad % t.sig.n_shards == 0
        # tiles are SB-uniform by construction
        for s in t.specs:
            sb = s.sb_size if s.sb_size is not None else 72
            assert sb == t.sig.sb_uniform
        assert t.sig.chunk <= t.sig.sb_uniform
    # mixed-SB grid -> one signature set per depth, still a handful
    assert 2 <= len(sigs) <= 4


def test_clear_sim_caches_resets_engine_and_results_stable():
    before = E.run_grid(RAGGED_GRID[:10], n_stores=N, tile_cells=16)
    assert len(E._TILE_FNS) > 0
    clear_sim_caches()
    assert len(E._TILE_FNS) == 0
    after = E.run_grid(RAGGED_GRID[:10], n_stores=N, tile_cells=16)
    _assert_bit_identical(RAGGED_GRID[:10], after, before, "post-clear")


def test_auto_chunk_heuristic_and_meta():
    # wide regime (n_cells=None or >= 256): capped, divisor-preferring
    assert auto_chunk(50_000, 72) == 40        # largest divisor <= 48
    assert auto_chunk(30_000, 72) == 48        # 48 divides 30000
    assert auto_chunk(1 <<  14, 72) == 32      # 32 divides 2^14
    assert auto_chunk(50_000, 16) == 16        # clamped by SB depth
    assert auto_chunk(10, 72) == 10            # clamped by trace length
    assert auto_chunk(0, 72) == 1
    for n_cells in (1, 8, AUTO_CHUNK_WIDE_CELLS - 1):
        # narrow regime: deepest legal block (scan steps dominate)
        assert auto_chunk(50_000, 72, n_cells) == 72
        assert auto_chunk(50_000, 200, n_cells) == DEFAULT_CHUNK_SIZE
    assert auto_chunk(50_000, 72, AUTO_CHUNK_WIDE_CELLS) == 40

    specs = [ScenarioSpec("ycsb", "proactive")]
    (r,) = simulate_batch(specs, n_stores=N)
    want = {"engine": "blocked", "chunk": auto_chunk(N, 72, 8),
            "auto_chunk": True, "data_plane": "bank"}
    assert want.items() <= r.meta.items()
    assert r.meta["bank_rows"] == 2 and r.meta["h2d_bytes"] > 0
    (r,) = simulate_batch(specs, n_stores=N, chunk_size=7)
    assert {"engine": "blocked", "chunk": 7,
            "auto_chunk": False}.items() <= r.meta.items()
    (r,) = simulate_batch(specs, n_stores=N, chunk_size=0)
    assert r.meta["engine"] == "perstep"
    assert r.meta["data_plane"] == "stacked"
    assert simulate("ycsb", "proactive", n_stores=N).meta == {
        "engine": "serial", "data_plane": "stacked",
        "bank_partition": None}
    # the narrow-SB cell bounds the auto chunk of the whole batch
    (r, _) = simulate_batch([ScenarioSpec("ycsb", "proactive", sb_size=8),
                             ScenarioSpec("ycsb", "wb")], n_stores=N)
    assert r.meta["chunk"] == 8
    # ...but the streaming tier groups by SB, so the wide group keeps
    # its own chunk
    out = E.run_grid([ScenarioSpec("ycsb", "proactive", sb_size=8),
                      ScenarioSpec("ycsb", "wb")], n_stores=N, tile_cells=16)
    assert out[0].meta["chunk"] == 8
    assert out[1].meta["chunk"] == auto_chunk(N, 72, 16)


def test_tier_selection_and_validation():
    small = RAGGED_GRID[:4]
    out = E.simulate_grid(small, n_stores=N)
    assert out[0].meta["engine"] == "blocked"
    out = E.simulate_grid(small, n_stores=N, engine="stream", tile_cells=16)
    assert out[0].meta["engine"] in ("sharded", "streamed")
    out = E.simulate_grid(small, n_stores=N, engine="serial")
    assert out[0].meta["engine"] == "serial"
    assert E.simulate_grid([], n_stores=N) == []
    with pytest.raises(ValueError):
        E.simulate_grid(small, n_stores=N, engine="nosuch")
    with pytest.raises(ValueError):
        E.run_grid(small, n_stores=N, chunk_size=0)   # no per-step tier
    with pytest.raises(ValueError):
        E.run_grid(small, n_stores=N, n_shards=jax.device_count() + 1)
    with pytest.raises(ValueError):
        E.run_grid([ScenarioSpec("ycsb", "nosuch")], n_stores=N)


def test_run_sweep_routes_through_engine():
    from repro.core.scenarios import run_sweep

    specs = RAGGED_GRID[:6]
    got = run_sweep(specs, n_stores=N)
    want = simulate_batch(specs, n_stores=N)
    _assert_bit_identical(specs, got, want, "run_sweep")
    got = run_sweep(specs, n_stores=N, engine="stream", tile_cells=16)
    _assert_bit_identical(specs, got, want, "run_sweep-stream")


def test_stacked_plane_bit_identical_and_observable(blocked_results):
    """The PR-3 stacked plane stays available (``data_plane="stacked"``)
    and bit-identical to the banked default, for both the streaming and
    one-shot tiers; meta + bank_stats() record which plane ran."""
    out = E.run_grid(RAGGED_GRID, n_stores=N, tile_cells=16,
                     data_plane="stacked")
    _assert_bit_identical(RAGGED_GRID, out, blocked_results,
                          "stacked-vs-banked")
    assert out[0].meta["data_plane"] == "stacked"
    assert out[0].meta["bank_rows"] == 0
    stats = E.bank_stats()
    assert stats["data_plane"] == "stacked"
    assert stats["dedup_ratio"] == 1.0
    assert stats["h2d_bytes"] == stats["stacked_h2d_bytes"]

    one_shot = simulate_batch(RAGGED_GRID, n_stores=N, data_plane="stacked")
    _assert_bit_identical(RAGGED_GRID, one_shot, blocked_results,
                          "oneshot-stacked-vs-banked")
    assert one_shot[0].meta["data_plane"] == "stacked"

    with pytest.raises(ValueError):
        E.run_grid(RAGGED_GRID[:2], n_stores=N, data_plane="nosuch")
    with pytest.raises(ValueError):
        simulate_batch(RAGGED_GRID[:2], n_stores=N, data_plane="nosuch")
    with pytest.raises(ValueError):    # the per-step engine has no bank
        simulate_batch(RAGGED_GRID[:2], n_stores=N, chunk_size=0,
                       data_plane="bank")


def test_bank_stats_and_meta_on_banked_run():
    """bank_stats() reports the last run's data-plane accounting --
    MEASURED resident device bytes from the live buffers, sub vs
    replicated -- and the banked plane ships measurably fewer H2D bytes
    than stacking."""
    out = E.run_grid(RAGGED_GRID, n_stores=N, tile_cells=16)
    meta = out[0].meta
    assert meta["data_plane"] == "bank"
    assert meta["bank_partition"] == "sub"
    stats = E.bank_stats()
    n_shards = stats["n_shards"]
    assert stats["cells"] == len(RAGGED_GRID)
    assert stats["bank_partition"] == "sub"
    assert stats["bank_rows"] == stats["trace_rows"] + stats["wv_rows"]
    assert meta["bank_rows"] == stats["bank_rows"] > 0
    assert meta["h2d_bytes"] == stats["h2d_bytes"] > 0
    # dedup: 37 cells share 12 traces / far fewer wv rows than cells
    assert stats["h2d_bytes"] < stats["stacked_h2d_bytes"]
    assert stats["dedup_ratio"] > 1.0
    # measured sub-bank residency: arrivals replicated + one padded
    # copy of each max-plus row fleet-wide. Bound per-shard bytes by
    # arrivals + padded wv share, total by n_shards x that.
    bank = E.get_trace_bank(RAGGED_GRID, N)
    a, w, v, p = bank.sub_bank_host(n_shards)
    per_shard_cap = a.nbytes + (w.nbytes + v.nbytes + p.nbytes) // n_shards
    assert 0 < stats["bank_dev_bytes_per_shard"] <= per_shard_cap
    assert stats["bank_dev_bytes"] == \
        n_shards * a.nbytes + w.nbytes + v.nbytes + p.nbytes
    assert stats["bank_dev_bytes"] < stats["bank_bytes"] * n_shards \
        or n_shards == 1
    # only the arrivals staging replicates over the fabric
    assert stats["bank_fabric_bytes"] == a.nbytes * (n_shards - 1)
    assert stats["dev_mem_hwm_bytes"] >= stats["bank_dev_bytes"]

    # replicated baseline: measured bytes really are ~bank x n_shards
    clear_sim_caches()
    out = E.run_grid(RAGGED_GRID, n_stores=N, tile_cells=16,
                     bank_partition="replicated")
    rep = E.bank_stats()
    assert rep["bank_partition"] == "replicated"
    assert out[0].meta["bank_partition"] == "replicated"
    assert rep["bank_dev_bytes"] == rep["bank_bytes"] * n_shards
    assert rep["bank_dev_bytes_per_shard"] == rep["bank_bytes"]
    assert rep["bank_fabric_bytes"] == rep["bank_bytes"] * (n_shards - 1)
    with pytest.raises(ValueError):
        E.run_grid(RAGGED_GRID[:2], n_stores=N, bank_partition="nosuch")
    with pytest.raises(ValueError):   # partition is a stream-tier knob
        E.simulate_grid(RAGGED_GRID[:2], n_stores=N, engine="blocked",
                        bank_partition="sub")


def test_stream_threshold_routes_large_grids():
    """simulate_grid(auto) must stream at or above the threshold; checked
    via meta on a synthetic just-over-threshold grid of tiny traces."""
    n_cells = E.STREAM_THRESHOLD
    specs = [ScenarioSpec("ycsb", CONFIGS[i % len(CONFIGS)], seed=i % 4)
             for i in range(n_cells)]
    out = E.simulate_grid(specs, n_stores=64, tile_cells=512)
    assert out[0].meta["engine"] in ("sharded", "streamed")
    assert len(out) == n_cells
    # sampled cells against the one-shot blocked path
    sample = [0, 7, n_cells - 1]
    want = simulate_batch([specs[i] for i in sample], n_stores=64)
    for i, w in zip(sample, want):
        assert out[i].exec_time_ns == w.exec_time_ns
