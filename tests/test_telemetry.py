"""Flight-recorder contract tests (``repro.core.telemetry``,
docs/observability.md).

Two families:

* **Recorder semantics** -- nested spans, counters, gauges,
  distribution percentiles, ring-buffer wrap (aggregates survive event
  drops), the disabled-path noop singleton, and Chrome trace-event
  export round-tripping through :func:`validate_chrome_trace`.

* **Zero-churn pins** -- recording must never change a number: traced
  ``run_grid`` results ``==`` untraced, the host memo keys
  (``_plane_keys`` / ``_specs_key``) and the resident bank bytes are
  byte-identical, and a traced re-run of a warm grid compiles 0 extra
  programs.  Plus the span taxonomy the docs promise: prefetch /
  compile-warm / daemon threads each carry balanced B/E spans, and a
  chaos-injected shard loss emits the
  detection -> rollback -> rebuild -> re-place -> re-dispatch timeline
  in exactly that order.
"""

import json

import pytest

import jax

from repro.core import chaos
from repro.core import engine as E
from repro.core import telemetry as tm
from repro.core.scenarios import chaos_grid, sweep_grid
from repro.core.serving import ScenarioServer
from repro.core.simulator import (
    PAPER_CLUSTER,
    _plane_keys,
    _specs_key,
    clear_sim_caches,
    get_trace_bank,
)

N = 600
GRID = sweep_grid(workloads=("ycsb", "canneal"),
                  configs=("wb", "proactive"),
                  sb_sizes=(None, 48), n_replicas=(None, 3))


@pytest.fixture(autouse=True)
def _no_recorder_leaks():
    """Every test starts and ends with the recorder disabled."""
    tm.disable()
    yield
    tm.disable()


# ---------------------------------------------------------------- recorder

def test_nested_spans_counters_gauges_and_summary():
    with tm.recording() as rec:
        with tm.span("outer", tag=1):
            with tm.span("outer/inner"):
                tm.count("hits")
                tm.count("hits", 4)
            tm.gauge("depth", 3)
            tm.gauge("depth", 7)          # latest wins
        for v in (1.0, 2.0, 3.0, 4.0):
            tm.observe("lat_ms", v)
        summ = rec.summary()
    assert summ["counters"]["hits"] == 5
    assert summ["gauges"]["depth"] == 7
    assert summ["spans"]["outer"]["count"] == 1
    assert summ["spans"]["outer/inner"]["count"] == 1
    # the inner span is contained in the outer one
    assert summ["spans"]["outer"]["total"] >= \
        summ["spans"]["outer/inner"]["total"]
    d = summ["dists"]["lat_ms"]
    assert d["count"] == 4 and d["max"] == 4.0
    assert summ["threads"] == 1 and summ["events_dropped"] == 0


def test_distribution_percentiles_nearest_rank():
    with tm.recording() as rec:
        for v in range(1, 101):
            tm.observe("x", float(v))
        d = rec.summary()["dists"]["x"]
    assert d["p50"] in (50.0, 51.0)
    assert d["p99"] in (99.0, 100.0)
    assert d["max"] == 100.0 and d["count"] == 100


def test_ring_wrap_drops_events_but_keeps_aggregates():
    with tm.recording(ring_events=64) as rec:
        for i in range(500):
            with tm.span("tick"):
                tm.count("n")
        summ = rec.summary()
    assert summ["counters"]["n"] == 500
    assert summ["spans"]["tick"]["count"] == 500
    assert summ["events_dropped"] > 0
    assert summ["events"] <= 64


def test_disabled_path_is_a_shared_noop():
    assert not tm.enabled() and tm.active() is None
    s1, s2 = tm.span("a", big=1), tm.span("b")
    assert s1 is s2 is tm._NOOP_SPAN          # no per-call allocation
    with s1:
        tm.count("never")
        tm.gauge("never", 1)
        tm.observe("never", 1.0)
    assert tm.summary() == {}


def test_recording_scope_restores_previous_recorder():
    tm.enable()
    outer = tm.active()
    with tm.recording() as rec:
        assert tm.active() is rec and rec is not outer
    assert tm.active() is outer
    tm.disable()
    assert tm.active() is None


def test_export_chrome_roundtrips_validation(tmp_path):
    import threading

    def other():
        with tm.span("worker/job"):
            tm.count("jobs")

    path = tmp_path / "trace.jsonl"
    with tm.recording() as rec:
        with tm.span("main/outer"):
            t = threading.Thread(target=other)
            t.start()
            t.join()
        tm.gauge("g", 2)
        tm.observe("o", 1.5)
        n = rec.export_chrome(str(path))
    stats = tm.validate_chrome_trace(str(path))
    assert stats["events"] == n > 0
    assert stats["threads"] >= 2           # main + worker
    assert stats["spans"] >= 2
    lines = path.read_text().splitlines()
    assert all(json.loads(ln) for ln in lines)
    names = {json.loads(ln).get("name") for ln in lines}
    assert {"main/outer", "worker/job"} <= names


def test_validate_rejects_unbalanced_trace(tmp_path):
    bad = tmp_path / "bad.jsonl"
    bad.write_text(
        '{"ph":"M","pid":1,"tid":1,"name":"thread_name",'
        '"args":{"name":"t"}}\n'
        '{"ph":"B","pid":1,"tid":1,"ts":0,"name":"open"}\n')
    with pytest.raises(ValueError):
        tm.validate_chrome_trace(str(bad))


# ------------------------------------------------------------ zero churn

def test_traced_run_grid_bitident_keys_bank_and_compiles():
    clear_sim_caches()
    res_off = E.run_grid(GRID, n_stores=N)
    keys_off = [_plane_keys(s, PAPER_CLUSTER) for s in GRID]
    skey_off = _specs_key(tuple(GRID), N, PAPER_CLUSTER)
    bank_off = get_trace_bank(GRID, N, PAPER_CLUSTER).nbytes
    tc = E.trace_count()

    with tm.recording() as rec:
        res_on = E.run_grid(GRID, n_stores=N)
        summ = rec.summary()

    assert E.trace_count() == tc, "tracing a warm grid must compile 0"
    assert all(a == b for a, b in zip(res_off, res_on))
    assert [_plane_keys(s, PAPER_CLUSTER) for s in GRID] == keys_off
    assert _specs_key(tuple(GRID), N, PAPER_CLUSTER) == skey_off
    assert get_trace_bank(GRID, N, PAPER_CLUSTER).nbytes == bank_off
    # and the traced run actually observed the pipeline
    assert summ["spans"]["tile/dispatch"]["count"] >= 1
    assert summ["counters"]["proto/cells"] == len(GRID)
    assert res_on[0].meta["telemetry"] is not None
    # tracing may annotate meta, but == ignores it by contract
    assert "telemetry" not in (res_off[0].meta or {})


def test_pipeline_spans_nest_and_balance_per_thread(tmp_path):
    clear_sim_caches()
    path = tmp_path / "grid.jsonl"
    with tm.recording() as rec:
        E.run_grid(GRID, n_stores=N)
        rec.export_chrome(str(path))
        summ = rec.summary()
    for name in ("tile/prep", "tile/h2d", "tile/dispatch", "tile/drain",
                 "bank/place", "compile/warm"):
        assert summ["spans"][name]["count"] >= 1, name
    assert summ["gauges"]["engine/in_flight_tiles"] >= 0
    assert "engine/prefetch_queue_depth" in summ["gauges"]
    # prefetch + warm threads record off the main thread
    assert summ["threads"] >= 2
    stats = tm.validate_chrome_trace(str(path))   # raises on bad nesting
    assert stats["threads"] == summ["threads"]
    # per-thread B/E balance, explicitly
    depth = {}
    for ln in path.read_text().splitlines():
        ev = json.loads(ln)
        if ev["ph"] == "B":
            depth[ev["tid"]] = depth.get(ev["tid"], 0) + 1
        elif ev["ph"] == "E":
            depth[ev["tid"]] = depth[ev["tid"]] - 1
            assert depth[ev["tid"]] >= 0
    assert all(v == 0 for v in depth.values())


def test_daemon_spans_and_latency_histograms():
    clear_sim_caches()
    with ScenarioServer(n_stores=N, batch_cells=8,
                        batch_window_ms=1.0) as srv:
        srv.warm(GRID[:8])
        with tm.recording() as rec:
            srv.query_batch(GRID)                     # hits + misses
            for f in [srv.submit(s) for s in GRID[:4]]:
                f.result(timeout=120)
            st = srv.stats()
            summ = rec.summary()
    assert summ["spans"]["serve/flush"]["count"] >= 2
    assert summ["spans"]["serve/bank_sync"]["count"] >= 1
    q = summ["dists"]["serve/query_ms"]
    assert q["count"] == len(GRID) + 4
    assert summ["dists"]["serve/queue_wait_ms"]["count"] >= 4
    assert summ["dists"]["serve/window_wait_ms"]["count"] >= 1
    hits = summ["counters"]["serve/lane_hits"]
    misses = summ["counters"]["serve/lane_misses"]
    assert hits + misses == len(GRID) + 4
    assert st["telemetry"]["spans"].keys() == summ["spans"].keys()


def test_chaos_recovery_timeline_span_order():
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 host devices for a shard loss")
    # 24 cells / 8-cell tiles => several dispatches, so the fault armed
    # at dispatch 2 fires mid-grid with work in flight
    grid = chaos_grid()[:24]
    clear_sim_caches()
    base = E.run_grid(grid, n_stores=N, tile_cells=8, n_shards=2)
    with chaos.inject(chaos.ChaosConfig(lose_shard=1,
                                        lose_at_dispatch=2)):
        with tm.recording() as rec:
            res = E.run_grid(grid, n_stores=N, tile_cells=8, n_shards=2)
            evs = rec.span_events("recover")
            summ = rec.summary()
    assert all(a == b for a, b in zip(res, base))
    begins = [nm for ph, _t, nm, _tid in evs if ph == "B"]
    assert begins == ["recover", "recover/detect", "recover/rollback",
                      "recover/rebuild", "recover/replace",
                      "recover/redispatch"]
    # nested spans: children are contained in the parent duration
    parent = summ["spans"]["recover"]["total"]
    for child in ("recover/detect", "recover/rollback",
                  "recover/rebuild", "recover/replace"):
        assert summ["spans"][child]["total"] <= parent + 1e-6
    assert summ["counters"]["chaos/faults_detected"] == 1
    assert summ["counters"]["chaos/shard_loss"] == 1
    assert summ["spans"]["chaos/replica_rebuild"]["count"] + \
        summ["spans"].get("chaos/journal_rebuild",
                          {"count": 0})["count"] >= 1


def test_protocol_counters_flow_from_finish_result():
    clear_sim_caches()
    with tm.recording() as rec:
        res = E.run_grid(GRID, n_stores=N)
        summ = rec.summary()
    assert summ["counters"]["proto/cells"] == len(GRID)
    assert summ["counters"]["proto/repl_msgs"] == \
        sum(r.n_repl_msgs for r in res)
    assert summ["counters"]["proto/log_unit_bytes"] == \
        sum(r.max_log_bytes for r in res)
    for dist in ("proto/dump_bw_gbps", "proto/cxl_mem_bw_gbps",
                 "proto/dir_queue_occupancy"):
        assert summ["dists"][dist]["count"] == len(GRID), dist
