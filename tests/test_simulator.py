"""Protocol simulator vs. the paper's published claims (SS VII).

Acceptance bands are generous-but-meaningful: the paper's exact numbers
come from SST + Pin traces we cannot replay, so the reproduction target
is the headline geomeans and every qualitative ordering the paper reports.
"""

import numpy as np
import pytest

from repro.configs.recxl_paper import PAPER_CLAIMS, WORKLOADS
from repro.core.simulator import (
    geomean_slowdowns,
    simulate,
    slowdown_table,
)

N = 20_000


@pytest.fixture(scope="module")
def table():
    return slowdown_table(n_stores=N)


@pytest.fixture(scope="module")
def gm(table):
    return geomean_slowdowns(table)


def test_wt_slowdown_band(gm):
    """Paper: WT = 7.6x geomean."""
    assert 6.0 <= gm["wt"] <= 9.5, gm


def test_baseline_slowdown_band(gm):
    """Paper: ReCXL-baseline = 2.88x geomean."""
    assert 2.3 <= gm["baseline"] <= 3.5, gm


def test_proactive_slowdown_band(gm):
    """Paper: ReCXL-proactive = 1.30x geomean (the headline claim)."""
    assert 1.1 <= gm["proactive"] <= 1.55, gm


def test_parallel_close_to_baseline(gm):
    """Paper: parallel only ~3% better than baseline (exclusive prefetch
    hides the coherence transaction)."""
    gain = 1.0 - gm["parallel"] / gm["baseline"]
    assert 0.0 <= gain <= 0.10, gm


def test_ordering_invariants(table):
    """WB <= proactive <= parallel <= baseline <= WT for every workload."""
    for w, row in table.items():
        assert row["proactive"] <= row["parallel"] * 1.02, (w, row)
        assert row["parallel"] <= row["baseline"] * 1.001, (w, row)
        assert row["baseline"] <= row["wt"] * 1.001, (w, row)


def test_write_intensive_worst(table):
    """Paper: oceans are the WT/baseline-worst workloads."""
    wt = {w: row["wt"] for w, row in table.items()}
    worst = sorted(wt, key=wt.get)[-2:]
    assert set(worst) == {"ocean_ncp", "ocean_cp"}
    assert table["streamcluster"]["wt"] < 2.0     # all schemes fine (Fig 10)


def test_repl_at_head_fraction_fig11():
    """Paper Fig 11: raytrace & fluidanimate send most REPLs at the SB
    head (short bursts) -- that is why proactive barely helps them."""
    fracs = {w: simulate(w, "proactive", n_stores=N).repl_at_head_frac
             for w in WORKLOADS}
    assert fracs["raytrace"] > fracs["ocean_ncp"]
    assert fracs["fluidanimate"] > fracs["ycsb"]


def test_log_sizes_fig13():
    """Paper Fig 13: per-CN log demand varies widely, max ~18 MB
    (the DRAM log size chosen in Table II)."""
    sizes = [simulate(w, "proactive", n_stores=N).max_log_bytes
             for w in WORKLOADS]
    assert max(sizes) < 18e6 * 1.5
    assert min(sizes) < 3e6                        # wide spread
    assert max(sizes) > 5e6


def test_dump_bandwidth_fig14():
    """Paper Fig 14: log-dump bandwidth < 5 GB/s for every app."""
    for w in WORKLOADS:
        r = simulate(w, "proactive", n_stores=N)
        assert r.log_dump_bw_gbps < 5.0 * 4.0      # cluster-wide, slack 4x


def test_nr_sensitivity_fig17():
    """Paper Fig 17: execution time increases slowly with N_r
    (N_r=4 ~2% slower than N_r=3 on average)."""
    ratios = []
    for w in ("bodytrack", "canneal", "ycsb"):
        t3 = simulate(w, "proactive", n_stores=N, n_replicas=3).exec_time_ns
        t4 = simulate(w, "proactive", n_stores=N, n_replicas=4).exec_time_ns
        ratios.append(t4 / t3)
    mean = float(np.mean(ratios))
    assert 0.99 <= mean <= 1.15


def test_link_bw_sensitivity_fig16():
    """Paper Fig 16: low link bandwidth hurts ReCXL-proactive more than
    WB on average; streamcluster unaffected."""
    w = "ycsb"
    pro_hi = simulate(w, "proactive", n_stores=N, link_bw_gbps=160).exec_time_ns
    pro_lo = simulate(w, "proactive", n_stores=N, link_bw_gbps=20).exec_time_ns
    wb_hi = simulate(w, "wb", n_stores=N, link_bw_gbps=160).exec_time_ns
    wb_lo = simulate(w, "wb", n_stores=N, link_bw_gbps=20).exec_time_ns
    assert pro_lo / pro_hi >= wb_lo / wb_hi * 0.999
    sc_hi = simulate("streamcluster", "proactive", n_stores=N,
                     link_bw_gbps=160).exec_time_ns
    sc_lo = simulate("streamcluster", "proactive", n_stores=N,
                     link_bw_gbps=20).exec_time_ns
    assert sc_lo / sc_hi < 1.25


def test_cn_scaling_fig18():
    """Paper Fig 18: 4 -> 16 CNs cuts execution ~3x for both WB and
    ReCXL-proactive (weak-scaling model)."""
    for cfgname in ("wb", "proactive"):
        t4 = simulate("barnes", cfgname, n_stores=N, n_cns=4).exec_time_ns
        t16 = simulate("barnes", cfgname, n_stores=N, n_cns=16).exec_time_ns
        assert 2.5 <= t4 / t16 <= 4.5


def test_coalescing_mixed_effect_fig12():
    """Paper Fig 12: coalescing helps some apps, hurts others (no clear
    trend). We assert both directions exist OR the effect is tiny."""
    deltas = []
    for w in WORKLOADS:
        t_on = simulate(w, "proactive", n_stores=N, coalescing=True).exec_time_ns
        t_off = simulate(w, "proactive", n_stores=N, coalescing=False).exec_time_ns
        deltas.append(t_off / t_on - 1.0)
    assert max(deltas) > -0.02       # coalescing not uniformly harmful
    assert min(deltas) < 0.25        # nor a uniform disaster off
