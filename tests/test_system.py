"""End-to-end system behaviour: fault-tolerant training with failure
injection + recovery, checkpoint/restart, WB-vs-ReCXL loss equivalence,
and straggler handling."""

import dataclasses
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.config import (
    MeshConfig,
    ReplicationConfig,
    RunConfig,
    ShapeConfig,
    TrainConfig,
)
from repro.core.failures import FailureEvent, FailureInjector
from repro.training.trainer import Trainer

SMOKE = ShapeConfig("smoke", seq_len=32, global_batch=8, kind="train")


def _run_cfg(variant="proactive", **kw):
    return RunConfig(
        model=repro.get_reduced_config("qwen3-0.6b"),
        shape=SMOKE,
        mesh=MeshConfig((4, 2), ("data", "model")),
        replication=ReplicationConfig(
            variant=variant, n_replicas=2, n_buckets=4, log_capacity=2,
            dump_interval=6, **kw),
        train=TrainConfig(total_steps=30, warmup_steps=2,
                          learning_rate=1e-3),
    )


@pytest.fixture
def workdir():
    d = tempfile.mkdtemp()
    yield d
    shutil.rmtree(d, ignore_errors=True)


def test_training_survives_node_failure(mesh8, workdir):
    """The paper's end-to-end claim: a fail-stop node mid-run, recovery
    from replica Logging Units, training continues with consistent state."""
    inj = FailureInjector([FailureEvent(step=8, node=2)])
    tr = Trainer(_run_cfg(), mesh8, workdir, injector=inj)
    hist = tr.train(16)
    events = {e["event"] for e in tr.events}
    assert "recovery" in events
    rec = next(e for e in tr.events if e["event"] == "recovery")
    assert rec["stats"]["unrecoverable"] == 0
    assert rec["stats"]["recovered_from_replicas"] > 0
    # loss stays finite and trends down through the failure
    losses = [h["loss"] for h in hist]
    assert all(np.isfinite(losses))
    assert np.mean(losses[-4:]) < np.mean(losses[:4])


def test_recovery_state_identical_to_unfailed_run(mesh8, workdir):
    """Stronger than 'keeps training': with snapshot-mode logs the
    recovered params must BIT-match an identical run without failure."""
    cfg = _run_cfg()
    t1 = Trainer(cfg, mesh8, workdir + "/a")
    t1.train(10)
    truth = jax.tree.leaves(t1.state.params)

    inj = FailureInjector([FailureEvent(step=5, node=1)])
    t2 = Trainer(cfg, mesh8, workdir + "/b", injector=inj)
    t2.train(10)
    got = jax.tree.leaves(t2.state.params)
    for a, b in zip(truth, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_wb_crash_is_fatal(mesh8, workdir):
    """variant='none' (the paper's WB): node failure must be unrecoverable
    -- that is exactly the gap ReCXL closes."""
    inj = FailureInjector([FailureEvent(step=4, node=1)])
    tr = Trainer(_run_cfg(variant="none"), mesh8, workdir, injector=inj)
    with pytest.raises(RuntimeError, match="data loss|state is lost"):
        tr.train(8)


def test_checkpoint_restart(mesh8, workdir):
    cfg = _run_cfg()
    tr = Trainer(cfg, mesh8, workdir)
    tr.train(13)          # dumps at steps 5 and 11 (dump_interval=6)
    tr.ckpt.wait()
    step = tr.ckpt.latest_step()
    assert step is not None
    template = {"params": tr.state.params, "opt": tr.state.opt_state}
    restored, extra = tr.ckpt.restore(template)
    assert extra["pipeline_step"] >= step
    n = sum(x.size for x in jax.tree.leaves(restored["params"]))
    assert n == sum(x.size for x in jax.tree.leaves(tr.state.params))


def test_variants_agree_on_loss(mesh8, workdir):
    """Replication is off the numerical path: the three ReCXL variants
    must produce IDENTICAL losses (they differ only in collective
    scheduling), and all must match WB up to compilation-level bf16
    reassociation (the barrier changes XLA fusion decisions)."""
    losses = {}
    for variant in ("none", "baseline", "parallel", "proactive"):
        tr = Trainer(_run_cfg(variant=variant), mesh8,
                     workdir + "/" + variant)
        hist = tr.train(5)
        losses[variant] = np.array([h["loss"] for h in hist])
    np.testing.assert_array_equal(losses["baseline"], losses["parallel"])
    np.testing.assert_array_equal(losses["baseline"], losses["proactive"])
    np.testing.assert_allclose(losses["none"], losses["proactive"],
                               atol=5e-4)


def test_straggler_detection(mesh8, workdir):
    inj = FailureInjector([FailureEvent(step=10, node=3, kind="straggler",
                                        delay_s=0.5)])
    tr = Trainer(_run_cfg(), mesh8, workdir, injector=inj)
    tr.monitor.factor = 2.0
    tr.monitor.window = 2
    tr.train(16)
    assert any(e["event"] == "straggler" for e in tr.events)


def test_multi_failure_sequential(mesh8, workdir):
    """Two failures at different steps, both recovered (N_r=2 tolerates
    one failure at a time; sequential failures re-replicate in between)."""
    inj = FailureInjector([FailureEvent(step=5, node=1),
                           FailureEvent(step=10, node=3)])
    tr = Trainer(_run_cfg(), mesh8, workdir, injector=inj)
    hist = tr.train(14)
    recs = [e for e in tr.events if e["event"] == "recovery"]
    assert len(recs) == 2
    assert all(r["stats"]["unrecoverable"] == 0 for r in recs)
    assert all(np.isfinite([h["loss"] for h in hist]))
