"""Quickstart: fault-tolerant training in ~40 lines.

Trains a reduced qwen3-family model on 8 simulated devices (4 data x 2
model) with ReCXL-proactive replication, injects a node failure halfway,
and shows recovery from the replica Logging Units.

    PYTHONPATH=src python examples/quickstart.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

import repro
from repro.config import (
    MeshConfig,
    ReplicationConfig,
    RunConfig,
    ShapeConfig,
    TrainConfig,
)
from repro.core.failures import FailureEvent, FailureInjector
from repro.distributed.context import make_mesh
from repro.training.trainer import Trainer


def main() -> None:
    run = RunConfig(
        model=repro.get_reduced_config("qwen3-0.6b"),
        shape=ShapeConfig("quickstart", seq_len=64, global_batch=8,
                          kind="train"),
        mesh=MeshConfig((4, 2), ("data", "model")),
        replication=ReplicationConfig(variant="proactive", n_replicas=2,
                                      n_buckets=4, dump_interval=10),
        train=TrainConfig(total_steps=40, warmup_steps=4,
                          learning_rate=1e-3),
    )
    mesh = make_mesh((4, 2), ("data", "model"))
    injector = FailureInjector([FailureEvent(step=20, node=2)])
    trainer = Trainer(run, mesh, "/tmp/recxl_quickstart", injector=injector)

    print(f"model: {run.model.name} "
          f"({run.model.param_count() / 1e3:.0f}K params), "
          f"mesh 4x2, variant=proactive, N_r=2")
    trainer.train(40, on_metrics=lambda s, m: print(
        f"  step {s:3d}  loss {m['loss']:.4f}  {m['wall_s']*1e3:.0f} ms"))

    print("\nevents:")
    for e in trainer.events:
        print(f"  {e}")


if __name__ == "__main__":
    main()
