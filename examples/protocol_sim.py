"""Reproduce the paper's headline evaluation (Figs. 2 and 10) with the
trace-driven protocol simulator and compare against the published claims.

    PYTHONPATH=src python examples/protocol_sim.py
"""

from repro.configs.recxl_paper import PAPER_CLAIMS
from repro.core.simulator import geomean_slowdowns, slowdown_table


def main() -> None:
    print("simulating 9 workloads x 5 configurations "
          "(16 CN / 16 MN cluster, Table II parameters)...")
    table = slowdown_table(n_stores=30_000)
    gm = geomean_slowdowns(table)

    print(f"\n{'workload':14s}" + "".join(
        f"{c:>11s}" for c in ("wb", "wt", "baseline", "parallel",
                              "proactive")))
    for w, row in table.items():
        print(f"{w:14s}" + "".join(f"{row[c]:11.2f}" for c in row))

    print("\nheadline comparison (slowdown vs WB, geomean):")
    rows = [
        ("write-through (WT)", gm["wt"], PAPER_CLAIMS["wt_slowdown_geomean"]),
        ("ReCXL-baseline", gm["baseline"],
         PAPER_CLAIMS["baseline_slowdown_geomean"]),
        ("ReCXL-parallel", gm["parallel"],
         PAPER_CLAIMS["baseline_slowdown_geomean"] * 0.97),
        ("ReCXL-proactive", gm["proactive"],
         PAPER_CLAIMS["proactive_slowdown_geomean"]),
    ]
    print(f"  {'configuration':22s}{'reproduced':>12s}{'paper':>8s}")
    for name, got, paper in rows:
        print(f"  {name:22s}{got:12.2f}{paper:8.2f}")


if __name__ == "__main__":
    main()
