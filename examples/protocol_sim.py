"""Reproduce the paper's headline evaluation (Figs. 2 and 10) with the
trace-driven protocol simulator, compare against the published claims,
and estimate post-failure downtime (SS VII-E).

The whole 9-workload x 5-configuration grid runs as ONE batched
``simulate_batch`` call through the blocked-scan engine (see the
ScenarioSpec API in repro/core/simulator.py); the PR-1 per-step engine
is timed alongside for reference, and a batched ``recovery_sweep``
reports estimated downtime per workload across the dump interval.

    PYTHONPATH=src python examples/protocol_sim.py
"""

import time

from repro.configs.recxl_paper import PAPER_CLAIMS, WORKLOADS
from repro.core.scenarios import recovery_sweep
from repro.core.simulator import (
    CONFIGS,
    ScenarioSpec,
    geomean_slowdowns,
    simulate_batch,
    slowdowns_from_results,
)

N_STORES = 30_000


def main() -> None:
    print("simulating 9 workloads x 5 configurations "
          "(16 CN / 16 MN cluster, Table II parameters)...")
    specs = [ScenarioSpec(w, c) for w in WORKLOADS for c in CONFIGS]
    t0 = time.perf_counter()
    results = simulate_batch(specs, n_stores=N_STORES)
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    results = simulate_batch(specs, n_stores=N_STORES)
    warm = time.perf_counter() - t0
    t0 = time.perf_counter()
    simulate_batch(specs, n_stores=N_STORES, chunk_size=0)
    perstep = time.perf_counter() - t0
    table = slowdowns_from_results(results)
    gm = geomean_slowdowns(table)
    print(f"...{len(specs)} cells: {cold:.2f}s cold, {warm*1e3:.0f} ms warm "
          f"(blocked scan; per-step engine: {perstep*1e3:.0f} ms)")

    print(f"\n{'workload':14s}" + "".join(
        f"{c:>11s}" for c in CONFIGS))
    for w, row in table.items():
        print(f"{w:14s}" + "".join(f"{row[c]:11.2f}" for c in row))

    print("\nheadline comparison (slowdown vs WB, geomean):")
    rows = [
        ("write-through (WT)", gm["wt"], PAPER_CLAIMS["wt_slowdown_geomean"]),
        ("ReCXL-baseline", gm["baseline"],
         PAPER_CLAIMS["baseline_slowdown_geomean"]),
        ("ReCXL-parallel", gm["parallel"],
         PAPER_CLAIMS["baseline_slowdown_geomean"] * 0.97),
        ("ReCXL-proactive", gm["proactive"],
         PAPER_CLAIMS["proactive_slowdown_geomean"]),
    ]
    print(f"  {'configuration':22s}{'reproduced':>12s}{'paper':>8s}")
    for name, got, paper in rows:
        print(f"  {name:22s}{got:12.2f}{paper:8.2f}")

    print("\nestimated downtime after a CN fail-stop (SS VII-E model,")
    print("failure at 10% / 50% / 90% of the Logging-Unit dump interval):")
    sweep = recovery_sweep(cn_counts=(16,))
    print(f"  {'workload':14s}{'early':>9s}{'mid':>9s}{'late':>9s}   (ms)")
    for w in sweep.workloads:
        cells = [sweep.total_ms(w, t, 16) for t in sweep.fail_times_ms]
        print(f"  {w:14s}" + "".join(f"{ms:9.3f}" for ms in cells))


if __name__ == "__main__":
    main()
