"""YCSB-style replicated key-value store (the paper's SS VI workload),
built directly on the fine-grained ReCXL Logging Unit.

* records partitioned over nodes by key hash (the CXL-memory analogue);
* every PUT runs the full REPL -> REPL_ACK -> VAL transaction into the
  N_r=3 hash-selected replica Logging Units (word... here row granularity,
  paper Fig. 4/5 semantics);
* periodic dumps snapshot each store to the MN tier;
* halfway through, a node fail-stops: its shard is reconstructed from the
  replica DRAM logs (latest validated version per key, Algorithms 1-2)
  on top of the last dump -- then verified against the lost truth.

    PYTHONPATH=src python examples/ycsb_kv.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import logging_unit as lu
from repro.core.replica_groups import line_replicas

N_NODES = 4
N_RECORDS = 1024                  # paper: 500K x 1KB; scaled for the demo
WIDTH = 8                         # words per record
N_REPLICAS = 3
N_OPS = 4000
READ_FRAC = 0.8
DUMP_EVERY = 1000


def owner_of(key: int) -> int:
    return key % N_NODES


def main() -> None:
    rng = np.random.default_rng(0)
    stores = [np.zeros((N_RECORDS, WIDTH), np.float32)
              for _ in range(N_NODES)]
    units = [lu.init_state(sram_entries=128, dram_entries=4096,
                           n_sources=N_NODES, value_width=WIDTH)
             for _ in range(N_NODES)]
    next_ts = np.zeros((N_NODES, N_NODES), np.int64)   # (src, dst) counters
    dumps = [s.copy() for s in stores]                 # MN tier
    dump_ts = np.full((N_NODES,), -1, np.int64)

    recv_repl = jax.jit(lu.receive_repl)
    recv_val = jax.jit(lu.receive_val)
    drain = jax.jit(lambda s: lu.drain(s, 8))

    def put(key: int, value: np.ndarray) -> None:
        owner = owner_of(key)
        reps = line_replicas(key, N_REPLICAS, N_NODES)
        # REPL fan-out; ACKs are immediate in-process
        for r in reps:
            units[r] = recv_repl(units[r], owner, key, jnp.asarray(value))
        # all ACKs received -> VAL with per-(src, dst) logical timestamps
        for r in reps:
            units[r] = recv_val(units[r], owner, key,
                                int(next_ts[owner, r]))
            next_ts[owner, r] += 1
            units[r] = drain(units[r])
        # commit
        stores[owner][key // N_NODES] = value

    def get(key: int) -> np.ndarray:
        return stores[owner_of(key)][key // N_NODES]

    # ---- run the workload -------------------------------------------------
    n_reads = n_writes = 0
    fail_at = N_OPS // 2 + DUMP_EVERY // 2   # mid dump-interval
    failed = None
    truth_at_failure = None

    for op in range(N_OPS):
        if op == fail_at:
            failed = 2
            truth_at_failure = stores[failed].copy()
            stores[failed] = None          # fail-stop: shard gone
            print(f"op {op}: node {failed} FAILED (shard lost)")
            # --- recovery (Algorithms 1-2) --------------------------------
            recovered = dumps[failed].copy()
            n_from_log = 0
            for key in range(failed, N_RECORDS * N_NODES, N_NODES):
                reps = line_replicas(key, N_REPLICAS, N_NODES)
                best_ts, best_val = -1, None
                for r in reps:
                    if r == failed:
                        continue           # switch never asks the dead node
                    found, ts, val = lu.latest_version(
                        units[r], failed, key)
                    if bool(found) and int(ts) > best_ts:
                        best_ts, best_val = int(ts), np.asarray(val)
                if best_val is not None:
                    recovered[key // N_NODES] = best_val
                    n_from_log += 1
            stores[failed] = recovered
            ok = np.allclose(recovered, truth_at_failure)
            print(f"  recovered {n_from_log} records from replica logs "
                  f"(+ dump base); exact match: {ok}")
            assert ok, "recovery mismatch!"

        key = int(rng.integers(0, N_RECORDS * N_NODES))
        key = key - key % 1                       # uniform keys (paper)
        if key // N_NODES >= N_RECORDS:
            key = key % (N_RECORDS * N_NODES)
        if rng.random() < READ_FRAC:
            _ = get(key)
            n_reads += 1
        else:
            put(key, rng.standard_normal(WIDTH).astype(np.float32))
            n_writes += 1

        if (op + 1) % DUMP_EVERY == 0:
            for node in range(N_NODES):
                if stores[node] is not None:
                    dumps[node] = stores[node].copy()
                    units[node] = jax.jit(lu.clear_dram)(units[node])
            print(f"op {op + 1}: MN dump + log clear")

    print(f"\ndone: {n_reads} reads, {n_writes} writes "
          f"({100 * READ_FRAC:.0f}/{100 - 100 * READ_FRAC:.0f} mix), "
          f"N_r={N_REPLICAS}")
    drops = sum(int(u.dropped) for u in units)
    print(f"logging-unit drops: {drops} (must be 0)")
    assert drops == 0


if __name__ == "__main__":
    main()
