"""End-to-end driver: train a ~100M-parameter qwen3-family model for a
few hundred steps with full ReCXL fault tolerance, killing a node a third
of the way through.

    PYTHONPATH=src python examples/train_100m_ft.py --steps 300

CPU note: ~100M params at seq 128 is ~0.3 TFLOP/step; expect a few
seconds per step on a laptop-class CPU. Reduce --steps for a quick look.
"""

import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

from repro.config import (
    MeshConfig,
    ModelConfig,
    ReplicationConfig,
    RunConfig,
    ShapeConfig,
    TrainConfig,
)
from repro.core.failures import FailureEvent, FailureInjector
from repro.distributed.context import make_mesh
from repro.training.trainer import Trainer

MODEL_100M = ModelConfig(
    name="qwen3-100m",
    family="dense",
    n_layers=14,
    d_model=640,
    n_heads=10,
    n_kv_heads=2,
    d_ff=2560,
    vocab_size=32768,
    head_dim=64,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--fail-step", type=int, default=None)
    ap.add_argument("--variant", default="proactive")
    args = ap.parse_args()
    fail_step = args.fail_step or args.steps // 3

    print(f"{MODEL_100M.name}: {MODEL_100M.param_count()/1e6:.1f}M params")
    run = RunConfig(
        model=MODEL_100M,
        shape=ShapeConfig("train", seq_len=args.seq_len,
                          global_batch=args.batch, kind="train"),
        mesh=MeshConfig((4, 2), ("data", "model")),
        replication=ReplicationConfig(variant=args.variant, n_replicas=2,
                                      n_buckets=8, dump_interval=50,
                                      # ring capacity 2: the log ring is
                                      # params x N_r x capacity of HBM --
                                      # keep the CPU demo lean
                                      log_capacity=2),
        train=TrainConfig(total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 1),
                          learning_rate=6e-4),
    )
    mesh = make_mesh((4, 2), ("data", "model"))
    injector = FailureInjector([FailureEvent(step=fail_step, node=1)])
    trainer = Trainer(run, mesh, "/tmp/recxl_100m", injector=injector)

    hist = trainer.train(args.steps, on_metrics=lambda s, m: print(
        f"step {s:4d}  loss {m['loss']:.4f}  gnorm {m['grad_norm']:.2f}  "
        f"{m['wall_s']*1e3:.0f} ms"))

    first = sum(h["loss"] for h in hist[:10]) / 10
    last = sum(h["loss"] for h in hist[-10:]) / 10
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps")
    for e in trainer.events:
        if e["event"] in ("fail", "recovery"):
            print("event:", e)


if __name__ == "__main__":
    main()
