"""Logical-axis sharding rules.

Parameters: FSDP over (``pod``, ``data``) x tensor-parallel over ``model``
(2-D sharded weights). The rules are keyed on parameter path + shape and
handle the awkward cases explicitly:

* GQA KV projections whose head count does not divide the model axis
  (deepseek kv=8, starcoder2 kv=4, ...) fall back to FSDP-only storage --
  still fully sharded in HBM, all-gathered just-in-time by GSPMD.
* hymba's 25 attention heads do not divide 16; its attention weights are
  FSDP-only while its SSD branch (d_inner % 16 == 0) stays
  tensor-parallel.
* MoE expert stacks match the ``shard_map`` specs in models/moe.py
  (EP when n_experts % model == 0, ff-sliced TP otherwise).

Activations: batch over (``pod``, ``data``); the TP-sharded dim (heads /
ff / vocab) over ``model``.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import MeshConfig, ModelConfig
from repro.distributed.context import MeshContext, get_mesh_context


# ---------------------------------------------------------------------------
# Activation constraints (no-ops without a mesh context)
# ---------------------------------------------------------------------------

# "batch": residual stream sharded over (pod, data) only -- the baseline,
#   which makes GSPMD emit Megatron-style per-layer all-reduces of the
#   full residual for TP partial sums.
# "seq_model": additionally shard the sequence dim over `model` between
#   blocks (Megatron sequence parallelism): the TP partial-sum all-reduce
#   becomes reduce-scatter(+ all-gather before the next block's matmuls),
#   halving collective bytes and sharding the norm compute. A beyond-paper
#   perf knob recorded in EXPERIMENTS.md SSPerf.
_ACTIVATION_POLICY = "batch"


def set_activation_policy(policy: str) -> None:
    global _ACTIVATION_POLICY
    if policy not in ("batch", "seq_model"):
        raise ValueError(policy)
    _ACTIVATION_POLICY = policy


def get_activation_policy() -> str:
    return _ACTIVATION_POLICY


def constrain_batch(x: jax.Array) -> jax.Array:
    """(B, S, d) or (B, S): shard batch over (pod, data); under the
    seq_model policy 3-D activations also shard S over `model`."""
    ctx = get_mesh_context()
    if ctx is None:
        return x
    if (_ACTIVATION_POLICY == "seq_model" and x.ndim == 3
            and ctx.model_axis is not None
            and x.shape[1] % ctx.model_size == 0):
        spec = P(ctx.batch_axes, ctx.model_axis, None)
    else:
        spec = P(ctx.batch_axes, *([None] * (x.ndim - 1)))
    spec = sanitize_spec(spec, x.shape, ctx.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def constrain_gathered(x: jax.Array) -> jax.Array:
    """(B, S, d): force the sequence dim UNSHARDED (batch-only sharding).

    Under sequence parallelism the residual stream lives seq-sharded
    between blocks; calling this once on the post-norm activation makes
    GSPMD emit a single all-gather per block instead of one per
    projection matmul (the Megatron-SP gather point)."""
    ctx = get_mesh_context()
    if ctx is None or _ACTIVATION_POLICY != "seq_model":
        return x
    spec = sanitize_spec(P(ctx.batch_axes, *([None] * (x.ndim - 1))),
                         x.shape, ctx.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def constrain_logits(x: jax.Array) -> jax.Array:
    """(B, S, V): batch over (pod, data), vocab over model."""
    ctx = get_mesh_context()
    if ctx is None or ctx.model_axis is None:
        return x
    spec = P(ctx.batch_axes, None, ctx.model_axis)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def constrain_heads(x: jax.Array) -> jax.Array:
    """(B, S, H, hd): heads over model when divisible."""
    ctx = get_mesh_context()
    if ctx is None or ctx.model_axis is None:
        return x
    if x.shape[2] % ctx.model_size != 0:
        return constrain_batch(x)
    spec = P(ctx.batch_axes, None, ctx.model_axis, None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding rules
# ---------------------------------------------------------------------------

def _path_str(path: Tuple[Any, ...]) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _leaf_spec(path: str, leaf: jax.Array, cfg: ModelConfig,
               n_model: int, fsdp: Tuple[str, ...],
               model_ax: Optional[str], stacked: bool) -> P:
    """PartitionSpec for one parameter leaf (without the layer-stack dim)."""
    name = path.split("/")[-1]
    ndim = leaf.ndim - (1 if stacked else 0)
    m = model_ax

    def spec(*dims):
        return P(*( [None] + list(dims) if stacked else list(dims) ))

    if ndim <= 1:
        return spec(*([None] * ndim))             # scales/biases replicated

    # --- embeddings -------------------------------------------------------
    if name == "tok":
        return spec(m, fsdp)                      # vocab TP, d FSDP
    if name == "out":
        return spec(fsdp, m)

    # --- MoE expert stacks (E, d, ff) / (E, ff, d) -------------------------
    if path.endswith("moe/w_gate") or path.endswith("moe/w_up"):
        if m and cfg.n_experts % n_model == 0 and cfg.n_experts >= n_model:
            return spec(m, None, fsdp)
        return spec(None, None, ((m,) if m else ()) + fsdp)
    if path.endswith("moe/w_down"):
        if m and cfg.n_experts % n_model == 0 and cfg.n_experts >= n_model:
            return spec(m, fsdp, None)
        return spec(None, ((m,) if m else ()) + fsdp, None)
    if path.endswith("moe/router"):
        return spec(None, None)
    if "moe/shared" in path:
        if name == "w_down":
            return spec(((m,) if m else ()) + fsdp, None)
        return spec(None, ((m,) if m else ()) + fsdp)

    # --- attention ---------------------------------------------------------
    heads_tp = m is not None and cfg.n_heads % n_model == 0
    kv_tp = m is not None and cfg.n_kv_heads % n_model == 0
    if name == "wq":
        return spec(fsdp, m if heads_tp else None)
    if name in ("wk", "wv"):
        return spec(fsdp, m if kv_tp else None)
    if name == "wo":
        return spec(m, fsdp) if heads_tp else spec(fsdp, None)

    # --- SSD mixer ----------------------------------------------------------
    ssm_tp = m is not None and cfg.ssm_state > 0 and cfg.d_inner % n_model == 0
    if name in ("w_z", "w_x"):
        return spec(fsdp, m if ssm_tp else None)
    if name in ("w_B", "w_C", "w_dt"):
        return spec(fsdp, None)
    if name == "out_proj":
        return spec(m, fsdp) if ssm_tp else spec(fsdp, None)
    if name.startswith("conv_w"):
        return spec(None, m if (ssm_tp and name == "conv_wx") else None)

    # --- dense MLP -----------------------------------------------------------
    ff_tp = m is not None and (cfg.d_ff % n_model == 0) and cfg.d_ff > 0
    if name in ("w_gate", "w_up"):
        return spec(fsdp, m if ff_tp else None)
    if name == "w_down":
        return spec(m, fsdp) if ff_tp else spec(fsdp, None)

    # default: FSDP the largest dim
    dims = [None] * ndim
    dims[0] = fsdp
    return spec(*dims)


def sanitize_spec(spec: P, shape: Tuple[int, ...],
                  mesh: jax.sharding.Mesh) -> P:
    """Reduce sharding on dims the mesh axes do not divide evenly.

    jit input/output shardings require even divisibility (uneven sharding
    only works for in-jit constraints). For tuple entries the longest
    dividing *prefix* is kept (axes are ordered most-important-first by
    the rules), e.g. moonshot's shared-expert ff of 2816 cannot go over
    (model, pod, data) = 512 ways but keeps (model, pod) = 32.
    """
    import numpy as _np
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for d, ax in enumerate(dims):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        chosen = None
        for k in range(len(axes), 0, -1):
            n = int(_np.prod([mesh.shape[a] for a in axes[:k]]))
            if shape[d] % n == 0:
                chosen = axes[:k] if k > 1 else axes[0]
                break
        out.append(chosen)
    return P(*out)


def param_specs(params: Any, cfg: ModelConfig,
                ctx: Optional[MeshContext] = None) -> Any:
    """PartitionSpec pytree matching ``params``.

    Leaves under a ``layers`` subtree are treated as layer-stacked (leading
    L dim replicated).
    """
    ctx = ctx or get_mesh_context()
    if ctx is None:
        raise ValueError("param_specs requires a mesh context")
    fsdp = ctx.fsdp_axes
    n_model = ctx.model_size
    model_ax = ctx.model_axis

    def rule(path, leaf):
        ps = _path_str(path)
        stacked = "layers" in ps.split("/") or "enc_layers" in ps.split("/")
        spec = _leaf_spec(ps, leaf, cfg, n_model, fsdp, model_ax, stacked)
        return sanitize_spec(spec, leaf.shape, ctx.mesh)

    return jax.tree_util.tree_map_with_path(rule, params)


def named_shardings(params: Any, cfg: ModelConfig,
                    ctx: Optional[MeshContext] = None) -> Any:
    ctx = ctx or get_mesh_context()
    specs = param_specs(params, cfg, ctx)
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), specs)


# ---------------------------------------------------------------------------
# Serving-cache sharding rules
# ---------------------------------------------------------------------------

def cache_specs(cache: Any, cfg: ModelConfig,
                ctx: Optional[MeshContext] = None) -> Any:
    """PartitionSpecs for KV / SSM caches.

    * k/v/cross_k/cross_v: (L, B, S, K_heads, hd) -- batch over
      (pod, data); KV heads over ``model`` when divisible.
    * conv: (L, B, K-1, ch); ssd: (L, B, H, P, N) -- batch sharded, the
      channel/head dim over ``model`` when divisible.
    * length: replicated scalar.
    """
    ctx = ctx or get_mesh_context()
    if ctx is None:
        raise ValueError("cache_specs requires a mesh context")
    m, nm, batch = ctx.model_axis, ctx.model_size, ctx.batch_axes

    import numpy as _np
    nb = int(_np.prod([ctx.mesh.shape[a] for a in batch]))

    def rule(path, leaf):
        name = _path_str(path).split("/")[-1]
        if leaf.ndim == 0:
            return P()
        if name in ("k", "v", "cross_k", "cross_v"):
            heads = leaf.shape[-2]
            htp = m if (m and heads % nm == 0) else None
            if leaf.shape[1] % nb == 0:
                spec = P(None, batch, None, htp, None)
            else:
                # batch too small (long_500k, B=1): shard the sequence dim
                spec = P(None, None, batch, htp, None)
        elif name == "conv":
            ch = leaf.shape[-1]
            ctp = m if (m and ch % nm == 0) else None
            spec = P(None, batch, None, ctp)
        elif name == "ssd":
            h = leaf.shape[2]
            htp = m if (m and h % nm == 0) else None
            spec = P(None, batch, htp, None, None)
        else:
            # tokens / misc: batch-sharded on dim 0
            spec = P(batch, *([None] * (leaf.ndim - 1)))
        return sanitize_spec(spec, leaf.shape, ctx.mesh)

    return jax.tree_util.tree_map_with_path(rule, cache)


# ---------------------------------------------------------------------------
# Protocol-simulator tile sharding (cells axis of the streaming engine)
# ---------------------------------------------------------------------------

#: PartitionSpecs for one simulator tile, matching the engine's tile
#: layout: five cell-major ``(B, n_stores)`` per-store arrays (stacked
#: row-contiguous on the host -- a plain memcpy per cell -- and
#: transposed to the scan's time-major layout on device, where the
#: transpose is a fast local reshuffle), then the per-cell
#: ``config_idx`` / ``sb_size`` vectors. Only the cell axis is sharded;
#: the store axis stays local, so the blocked scan runs communication-
#: free on every device.
TILE_CELL_MAJOR_SPEC = P("cells", None)
TILE_PER_CELL_SPEC = P("cells")


def tile_specs() -> Tuple[P, ...]:
    """In/out PartitionSpecs for the 7 tile input arrays (spec order =
    the engine's ``_stack_tile`` order)."""
    return (TILE_CELL_MAJOR_SPEC,) * 5 + (TILE_PER_CELL_SPEC,) * 2


def tile_shardings(mesh: jax.sharding.Mesh) -> Tuple[NamedSharding, ...]:
    """NamedShardings for ``jax.device_put`` of one tile's input arrays
    onto a :func:`repro.distributed.context.cells_mesh` -- placing tiles
    explicitly (instead of letting jit reshard) lets the streaming loop
    overlap the host->device copy of tile k+1 with tile k's compute."""
    return tuple(NamedSharding(mesh, s) for s in tile_specs())


#: Banked data plane (the default): the four store-contiguous
#: ``(rows, n_stores)`` trace-bank arrays are REPLICATED across the
#: ``cells`` mesh -- any shard's cells may gather any row, and a
#: replicated bank keeps the in-kernel gather local (sharding the row
#: axis would force collectives and break the engine's
#: zero-communication contract). The per-cell ``int32`` row-index
#: vectors are the only sharded tile inputs.
BANK_COLUMN_SPEC = P(None, None)
TILE_INDEX_SPEC = P("cells")


def bank_tile_specs() -> Tuple[P, ...]:
    """In PartitionSpecs for a banked tile program: 4 replicated bank
    columns, then the 2 cell-sharded row-index vectors."""
    return (BANK_COLUMN_SPEC,) * 4 + (TILE_INDEX_SPEC,) * 2


def bank_shardings(mesh: jax.sharding.Mesh) -> Tuple[NamedSharding, ...]:
    """NamedShardings replicating the 4 bank columns over ``mesh`` (one
    explicit ``device_put`` per mega-grid -- the bank is device-resident
    across every tile that gathers from it)."""
    return (NamedSharding(mesh, BANK_COLUMN_SPEC),) * 4


#: Per-shard sub-bank plane (``bank_partition="sub"``, the default): the
#: three max-plus columns are stacked ``(n_shards, local_rows,
#: n_stores)`` with the SHARD axis cell-sharded -- one copy of each wv
#: row fleet-wide instead of one per shard. Global wv row ``r`` lives in
#: stack entry ``r % n_shards`` at local row ``r // n_shards``, and the
#: tile scheduler places every scan lane in its owning shard's slot
#: block, so the in-jit gather (with LOCAL indices) never leaves the
#: shard: still zero cross-device communication on the scan path. The
#: tiny arrivals plane stays replicated (``BANK_COLUMN_SPEC``): a lane's
#: trace row and wv row can be owned by different shards, and arrivals
#: are ~1% of the bank's bytes -- partitioning them would buy nothing
#: and force a second ownership constraint on the scheduler.
#:
#: **Replicated sub-banks** (``k_replicas > 1``, resolved by
#: :func:`repro.core.chaos.resolve_k_replicas`): the local axis grows to
#: ``k * local_rows`` and block ``j`` of shard ``s`` holds the rows OWNED
#: by shard ``(s - j) % n_shards`` -- ReCXL-style Logging Units, so wv
#: row ``r`` is resident on its owner ``r % n`` (block 0) and on the
#: next shard over (block 1), and losing any single shard leaves a full
#: replica of its rows one hop away for
#: :func:`repro.core.chaos.replica_rebuild`. The gather path always
#: indexes block 0, so the tile programs, their signatures, and the
#: scan-lane scheduler are IDENTICAL at every ``k`` -- replication costs
#: bytes (reported by ``bank_stats()["sub_bank_bytes"]``), never
#: compiles; this same spec shards the wider stack unchanged.
SUB_BANK_SPEC = P("cells", None, None)


def sub_bank_tile_specs() -> Tuple[P, ...]:
    """In PartitionSpecs for a sub-banked tile program: the replicated
    arrivals column, 3 shard-partitioned sub-bank stacks, then the 2
    cell-sharded row-index vectors (trace indices global, wv indices
    shard-local)."""
    return (BANK_COLUMN_SPEC,) + (SUB_BANK_SPEC,) * 3 + (TILE_INDEX_SPEC,) * 2


def sub_bank_shardings(mesh: jax.sharding.Mesh) -> Tuple[NamedSharding, ...]:
    """NamedShardings partitioning the 3 sub-bank stacks over ``mesh``
    (shard axis 0 over ``cells``: ``device_put`` slices the host stack
    per device, so upload bytes are the bank's, not bank x shards --
    times ``k_replicas`` when the chaos tier stacks replica blocks on
    the local axis; the sharding itself is k-agnostic)."""
    return (NamedSharding(mesh, SUB_BANK_SPEC),) * 3


def index_shardings(mesh: jax.sharding.Mesh) -> Tuple[NamedSharding, ...]:
    """NamedShardings for one banked tile's (trace_idx, wv_idx)."""
    return (NamedSharding(mesh, TILE_INDEX_SPEC),) * 2


def batch_specs(batch: Any, ctx: Optional[MeshContext] = None) -> Any:
    ctx = ctx or get_mesh_context()
    return jax.tree.map(
        lambda x: sanitize_spec(
            P(ctx.batch_axes, *([None] * (x.ndim - 1))), x.shape, ctx.mesh),
        batch)
