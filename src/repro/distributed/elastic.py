"""Elastic scaling: rebuilding cluster state after a node failure.

Two post-recovery strategies (both used at scale in production trainers):

* **spare replacement** (default): a hot-spare host takes over the failed
  data-rank; mesh shape is unchanged; the recovered shard (from the
  replica Logging Units, see core/recovery.py) is installed at the failed
  rank's coordinates. This is MegaScale-style and keeps the compiled
  executable valid -- recovery cost is state installation only.
* **degraded mesh**: shrink the data axis by one and reshard everything
  (recompile). Supported for completeness; used when no spare exists.

In this single-process container both reduce to array surgery on the
GSPMD-global state, which is exactly what the real multi-host version
does through per-host device_puts.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.recovery import RecoveryResult, reassemble_shard
from repro.core.replication import ReplicationEngine
from repro.distributed.context import MeshContext


def _block_slices(global_shape: Tuple[int, ...], spec: P,
                  mesh: jax.sharding.Mesh,
                  coords: Dict[str, int]) -> Tuple[slice, ...]:
    """The index slices of the block owned by mesh coordinates ``coords``
    for an array sharded with ``spec`` (only the axes present in coords
    are pinned; others must be fully covered by the slice)."""
    idx: List[slice] = []
    for d, ax in enumerate(tuple(spec) + (None,) * (len(global_shape) - len(spec))):
        dim = global_shape[d]
        if ax is None:
            idx.append(slice(None))
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        sizes = [mesh.shape[a] for a in axes]
        n = int(np.prod(sizes))
        block = dim // n
        # linearized coordinate over the sharding axes (major-to-minor)
        lin = 0
        for a, s in zip(axes, sizes):
            lin = lin * s + coords.get(a, 0)
        if all(a in coords for a in axes):
            idx.append(slice(lin * block, (lin + 1) * block))
        else:
            raise ValueError(
                f"spec axis {axes} not fully pinned by coords {coords}")
    return tuple(idx)


def install_recovered_shard(state: Any, specs: Any, engine: ReplicationEngine,
                            result: RecoveryResult,
                            target_coord: Tuple[int, ...]) -> Any:
    """Write the recovered node shard into ``state`` at ``target_coord``
    (spare replacement: target == failed coordinates; degraded mesh:
    target is the adopting rank).

    Host-side array surgery: gather leaf -> patch block -> device_put back
    with the original sharding. Exact (bit-identical) when the log dtype
    matches the state dtype.
    """
    ctx = engine.ctx
    mesh = ctx.mesh
    per_model = reassemble_shard(engine, result)
    n_model = len(per_model)

    flat_state, treedef = jax.tree.flatten(state)
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    assert len(flat_state) == len(flat_specs)

    # a "node" is identified by its batch-axes coordinates (pod?, data)
    node_axes = list(ctx.batch_axes)
    new_flat = []
    for li, (leaf, spec) in enumerate(zip(flat_state, flat_specs)):
        host = np.array(leaf)          # writable host copy
        for m in range(n_model):
            coords = {"model": m} if "model" in mesh.axis_names else {}
            for a, c in zip(node_axes, target_coord[-len(node_axes):]):
                coords[a] = c
            sl = _block_slices(leaf.shape, spec, mesh, coords)
            patch = per_model[m][li].astype(host.dtype)
            host[sl] = patch.reshape(host[sl].shape)
        sharding = NamedSharding(mesh, spec)
        new_flat.append(jax.device_put(host, sharding))
    return jax.tree.unflatten(treedef, new_flat)


def shrink_data_axis(mesh_shape: Tuple[int, ...], axes: Tuple[str, ...]
                     ) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Degraded-mesh shape after losing one data rank."""
    out = list(mesh_shape)
    di = axes.index("data")
    if out[di] <= 1:
        raise ValueError("cannot shrink a single-rank data axis")
    out[di] -= 1
    return tuple(out), axes


# -- the engine tier's ``cells`` mesh (see repro.core.engine / chaos) -------

def cells_spare_replacement(n_shards: int, lost: int) -> int:
    """Spare-replacement target mesh for the streaming engine's
    ``cells`` axis: the mesh shape is UNCHANGED -- a spare device takes
    the lost shard's coordinates, so every compiled tile program stays
    valid and recovery cost is re-placing the rebuilt rows only (the
    ``run_grid`` recovery path; 0 new compiles, pinned by
    tests/test_chaos.py).  Returns the (unchanged) shard count after
    validating the lost index."""
    if not 0 <= lost < n_shards:
        raise ValueError(f"lost shard {lost} not in [0, {n_shards})")
    return n_shards


def cells_degraded_shards(n_shards: int) -> int:
    """Degraded-mesh ``cells`` shard count after losing one shard with
    no spare available: one fewer -- the caller re-runs on the shrunk
    mesh with ``bank_partition="replicated"`` (per-shard sub-banks
    would need a reshard; the replicated layout only needs the one
    recompile) and keeps serving."""
    if n_shards <= 1:
        raise ValueError("cannot shrink a single-shard cells mesh")
    return n_shards - 1
