"""Ambient mesh context.

Model code that needs *manual* SPMD regions (``shard_map`` for MoE
dispatch and for the ReCXL replication engine) discovers the active mesh
through this context instead of threading it through every call. When no
context is set (CPU unit tests), modules fall back to their pure-local
single-shard path -- same math, no collectives.
"""

from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import jax


@dataclass(frozen=True)
class MeshContext:
    mesh: jax.sharding.Mesh
    batch_axes: Tuple[str, ...]      # axes the batch is sharded over
    model_axis: Optional[str]        # tensor/expert-parallel axis
    fsdp_axes: Tuple[str, ...]       # axes parameters are fully sharded over

    @property
    def data_size(self) -> int:
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def model_size(self) -> int:
        if self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]


_CURRENT: Optional[MeshContext] = None


def set_mesh_context(ctx: Optional[MeshContext]) -> None:
    global _CURRENT
    _CURRENT = ctx


def get_mesh_context() -> Optional[MeshContext]:
    return _CURRENT


@contextlib.contextmanager
def mesh_context(ctx: MeshContext) -> Iterator[MeshContext]:
    prev = get_mesh_context()
    set_mesh_context(ctx)
    try:
        yield ctx
    finally:
        set_mesh_context(prev)


def make_context(mesh: jax.sharding.Mesh) -> MeshContext:
    """Derive the canonical context from a mesh's axis names."""
    names = mesh.axis_names
    batch_axes = tuple(a for a in names if a in ("pod", "data"))
    model_axis = "model" if "model" in names else None
    return MeshContext(mesh=mesh, batch_axes=batch_axes,
                       model_axis=model_axis, fsdp_axes=batch_axes)
