"""Ambient mesh context.

Model code that needs *manual* SPMD regions (``shard_map`` for MoE
dispatch and for the ReCXL replication engine) discovers the active mesh
through this context instead of threading it through every call. When no
context is set (CPU unit tests), modules fall back to their pure-local
single-shard path -- same math, no collectives.
"""

from __future__ import annotations

import contextlib
import functools
from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import jax


@dataclass(frozen=True)
class MeshContext:
    mesh: jax.sharding.Mesh
    batch_axes: Tuple[str, ...]      # axes the batch is sharded over
    model_axis: Optional[str]        # tensor/expert-parallel axis
    fsdp_axes: Tuple[str, ...]       # axes parameters are fully sharded over

    @property
    def data_size(self) -> int:
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def model_size(self) -> int:
        if self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]


_CURRENT: Optional[MeshContext] = None


def set_mesh_context(ctx: Optional[MeshContext]) -> None:
    global _CURRENT
    _CURRENT = ctx


def get_mesh_context() -> Optional[MeshContext]:
    return _CURRENT


@contextlib.contextmanager
def mesh_context(ctx: MeshContext) -> Iterator[MeshContext]:
    prev = get_mesh_context()
    set_mesh_context(ctx)
    try:
        yield ctx
    finally:
        set_mesh_context(prev)


def make_mesh(axis_shapes: Tuple[int, ...], axis_names: Tuple[str, ...],
              devices=None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` across jax versions: newer releases want explicit
    ``axis_types`` (Auto) for the shard_map regions; older ones (<= 0.4.x)
    have neither the kwarg nor ``jax.sharding.AxisType``."""
    kwargs = {} if devices is None else {"devices": devices}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                axis_shapes, axis_names,
                axis_types=(axis_type.Auto,) * len(axis_names), **kwargs)
        except TypeError:
            pass
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(axis_shapes, axis_names, **kwargs)
    # jax < 0.4.35: no jax.make_mesh at all
    from jax.experimental import mesh_utils
    devs = mesh_utils.create_device_mesh(tuple(axis_shapes),
                                         devices=devices)
    return jax.sharding.Mesh(devs, tuple(axis_names))


def shard_map(f, mesh: jax.sharding.Mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions: the top-level API (with
    ``check_vma``) landed after 0.4.x, where the same transform lives in
    ``jax.experimental.shard_map`` and the kwarg is ``check_rep``."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:          # releases where the kwarg is check_rep
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)


@functools.lru_cache(maxsize=8)
def cells_mesh(n_shards: int) -> jax.sharding.Mesh:
    """1-D mesh over the first ``n_shards`` local devices, axis ``cells``.

    The protocol simulator's streaming tier shards the *cell* (grid
    batch) axis of its time-major ``(n_stores, B)`` tiles over it --
    each device scans its own slice of cells with zero cross-device
    communication. Cached per shard count: tiles of every signature
    share one mesh, so ``jit`` cache keys stay stable across tiles.
    """
    if not 1 <= n_shards <= len(jax.devices()):
        raise ValueError(
            f"n_shards must be in [1, {len(jax.devices())}], got {n_shards}")
    return make_mesh((n_shards,), ("cells",),
                     devices=jax.devices()[:n_shards])


def make_context(mesh: jax.sharding.Mesh) -> MeshContext:
    """Derive the canonical context from a mesh's axis names."""
    names = mesh.axis_names
    batch_axes = tuple(a for a in names if a in ("pod", "data"))
    model_axis = "model" if "model" in names else None
    return MeshContext(mesh=mesh, batch_axes=batch_axes,
                       model_axis=model_axis, fsdp_axes=batch_axes)
