"""Distribution layer: mesh context, sharding rules, collective helpers,
elastic resharding."""

from repro.distributed.context import (  # noqa: F401
    MeshContext,
    get_mesh_context,
    mesh_context,
    set_mesh_context,
)
