"""Minimal ``hypothesis`` fallback so the tier-1 suite collects and runs
in environments without the real package.

The real library is always preferred: ``install_hypothesis_shim()`` is a
no-op when ``import hypothesis`` succeeds. Otherwise it registers a tiny
deterministic stand-in under ``sys.modules['hypothesis']`` implementing
the subset this repo's property tests use:

* ``@given(*strategies)`` -- runs the test for a fixed, seeded sample of
  examples (seeded by the test's qualified name, so failures reproduce);
* ``@settings(max_examples=..., deadline=...)`` -- ``max_examples`` is
  respected up to a cap (the shim samples fixed examples, it does not
  shrink or search, so huge example counts buy nothing);
* ``strategies``: ``integers, floats, booleans, just, sampled_from,
  lists, tuples, one_of, permutations, composite`` and ``assume``.

This is NOT a property-testing engine -- no shrinking, no coverage
guidance, no database. It exists so `pytest` stays green and the
properties still get exercised on a spread of deterministic inputs.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib
from typing import Any, Callable, List, Optional, Sequence

_MAX_EXAMPLES_CAP = 20
_DEFAULT_EXAMPLES = 10


class _Unsatisfied(Exception):
    """Raised by ``assume(False)``: skip this example."""


def assume(condition: Any) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class SearchStrategy:
    def example_from(self, rng: random.Random) -> Any:
        raise NotImplementedError

    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        return _Mapped(self, fn)

    def filter(self, pred: Callable[[Any], bool]) -> "SearchStrategy":
        return _Filtered(self, pred)


class _Mapped(SearchStrategy):
    def __init__(self, base: SearchStrategy, fn: Callable):
        self.base, self.fn = base, fn

    def example_from(self, rng):
        return self.fn(self.base.example_from(rng))


class _Filtered(SearchStrategy):
    def __init__(self, base: SearchStrategy, pred: Callable):
        self.base, self.pred = base, pred

    def example_from(self, rng):
        for _ in range(100):
            x = self.base.example_from(rng)
            if self.pred(x):
                return x
        raise _Unsatisfied()


class _Integers(SearchStrategy):
    def __init__(self, min_value: int = -(2 ** 31), max_value: int = 2 ** 31):
        self.lo, self.hi = min_value, max_value

    def example_from(self, rng):
        # hit the boundaries sometimes -- they are the classic bug nests
        r = rng.random()
        if r < 0.05:
            return self.lo
        if r < 0.10:
            return self.hi
        return rng.randint(self.lo, self.hi)


class _Floats(SearchStrategy):
    def __init__(self, min_value: float = 0.0, max_value: float = 1.0,
                 **_ignored):
        self.lo, self.hi = min_value, max_value

    def example_from(self, rng):
        r = rng.random()
        if r < 0.05:
            return self.lo
        if r < 0.10:
            return self.hi
        return rng.uniform(self.lo, self.hi)


class _Booleans(SearchStrategy):
    def example_from(self, rng):
        return rng.random() < 0.5


class _Just(SearchStrategy):
    def __init__(self, value):
        self.value = value

    def example_from(self, rng):
        return self.value


class _SampledFrom(SearchStrategy):
    def __init__(self, elements: Sequence[Any]):
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from requires a non-empty sequence")

    def example_from(self, rng):
        return rng.choice(self.elements)


class _Lists(SearchStrategy):
    def __init__(self, elements: SearchStrategy, min_size: int = 0,
                 max_size: Optional[int] = None, unique: bool = False):
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 10
        self.unique = unique

    def example_from(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        out: List[Any] = []
        tries = 0
        while len(out) < n and tries < 100 * (n + 1):
            x = self.elements.example_from(rng)
            tries += 1
            if self.unique and x in out:
                continue
            out.append(x)
        if len(out) < self.min_size:
            # element strategy cannot yield enough distinct values --
            # never hand the test an input hypothesis would forbid
            raise _Unsatisfied()
        return out


class _Tuples(SearchStrategy):
    def __init__(self, *strategies: SearchStrategy):
        self.strategies = strategies

    def example_from(self, rng):
        return tuple(s.example_from(rng) for s in self.strategies)


class _OneOf(SearchStrategy):
    def __init__(self, *strategies: SearchStrategy):
        self.strategies = strategies

    def example_from(self, rng):
        return rng.choice(self.strategies).example_from(rng)


class _Permutations(SearchStrategy):
    def __init__(self, values: Sequence[Any]):
        self.values = list(values)

    def example_from(self, rng):
        out = list(self.values)
        rng.shuffle(out)
        return out


class _Composite(SearchStrategy):
    def __init__(self, fn: Callable, args, kwargs):
        self.fn, self.args, self.kwargs = fn, args, kwargs

    def example_from(self, rng):
        def draw(strategy: SearchStrategy) -> Any:
            return strategy.example_from(rng)
        return self.fn(draw, *self.args, **self.kwargs)


def composite(fn: Callable) -> Callable:
    @functools.wraps(fn)
    def builder(*args, **kwargs):
        return _Composite(fn, args, kwargs)
    return builder


class settings:
    """Decorator recording (a subset of) hypothesis settings."""

    def __init__(self, max_examples: int = _DEFAULT_EXAMPLES,
                 deadline: Any = None, **_ignored):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._hypothesis_shim_settings = self
        return fn


class HealthCheck:
    """Placeholder namespace (the shim never raises health checks)."""
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
    data_too_large = "data_too_large"
    function_scoped_fixture = "function_scoped_fixture"

    @staticmethod
    def all():
        return []


def given(*strategies: SearchStrategy, **kw_strategies: SearchStrategy):
    def decorate(fn):
        base_settings = getattr(fn, "_hypothesis_shim_settings", None)

        @functools.wraps(fn)
        def wrapper():
            cfg = getattr(wrapper, "_hypothesis_shim_settings",
                          base_settings)
            n = min(cfg.max_examples if cfg else _DEFAULT_EXAMPLES,
                    _MAX_EXAMPLES_CAP)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            ran = 0
            for _ in range(4 * n):
                if ran >= n:
                    break
                try:
                    args = [s.example_from(rng) for s in strategies]
                    kwargs = {k: s.example_from(rng)
                              for k, s in kw_strategies.items()}
                except _Unsatisfied:
                    continue
                try:
                    fn(*args, **kwargs)
                except _Unsatisfied:
                    continue
                ran += 1
            if ran == 0:
                raise _Unsatisfied(
                    f"{fn.__qualname__}: no example satisfied assume()")

        # pytest must see a zero-arg function (all inputs come from the
        # strategies), not the wrapped test's parameter list
        wrapper.__signature__ = inspect.Signature()
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        wrapper.hypothesis_shim = True
        return wrapper
    return decorate


def _build_modules() -> types.ModuleType:
    hyp = types.ModuleType("hypothesis")
    hyp.__doc__ = __doc__
    st = types.ModuleType("hypothesis.strategies")
    st.integers = _Integers
    st.floats = _Floats
    st.booleans = _Booleans
    st.just = _Just
    st.sampled_from = _SampledFrom
    st.lists = _Lists
    st.tuples = _Tuples
    st.one_of = _OneOf
    st.permutations = _Permutations
    st.composite = composite
    st.SearchStrategy = SearchStrategy
    hyp.strategies = st
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = HealthCheck
    hyp.__version__ = "0.0-repro-shim"
    return hyp


def install_hypothesis_shim() -> bool:
    """Register the shim iff the real hypothesis is unavailable.

    Returns True when the shim was installed, False when the real
    package (or an already-installed shim) is in use.
    """
    try:
        import hypothesis  # noqa: F401
        return False
    except ImportError:
        pass
    hyp = _build_modules()
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = hyp.strategies
    return True
