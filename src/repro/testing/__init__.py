"""Test-support utilities (hypothesis fallback shim)."""

from repro.testing.hypothesis_compat import install_hypothesis_shim

__all__ = ["install_hypothesis_shim"]
