"""ReCXL-JAX: a fault-tolerant distributed training/serving framework.

Reproduction + TPU adaptation of "Towards CXL Resilience to CPU Failures"
(Psistakis et al., CS.DC 2026). See DESIGN.md for the paper->TPU mapping.
"""

__version__ = "1.0.0"

from repro.config import (  # noqa: F401
    MeshConfig,
    ModelConfig,
    MULTI_POD,
    ReplicationConfig,
    RunConfig,
    SHAPES,
    SINGLE_POD,
    ShapeConfig,
    TrainConfig,
    get_model_config,
    get_reduced_config,
    list_models,
    make_run_config,
    shape_applicable,
)
