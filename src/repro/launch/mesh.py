"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches JAX device
state (jax locks the device count at first backend init -- see
launch/dryrun.py, which must set XLA_FLAGS before any jax import).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.config import MeshConfig, MULTI_POD, SINGLE_POD
from repro.distributed.context import make_mesh as _make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """The assignment's production meshes: 16x16 (256 chips, one pod) or
    2x16x16 (512 chips, two pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig) -> jax.sharding.Mesh:
    return _make_mesh(cfg.shape, cfg.axes)


def make_local_mesh(model_parallel: int = 1) -> jax.sharding.Mesh:
    """Best-effort mesh over whatever devices exist (examples / tests)."""
    n = jax.device_count()
    if n % model_parallel:
        raise ValueError(f"{n} devices not divisible by mp={model_parallel}")
    return _make_mesh((n // model_parallel, model_parallel),
                      ("data", "model"))
