import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape) cell and both production meshes
(16x16 single-pod, 2x16x16 multi-pod), lower + compile the appropriate
step function from ShapeDtypeStructs (no allocation), then record:

* ``compiled.memory_analysis()``  -- per-device bytes (does it fit HBM);
* ``compiled.cost_analysis()``    -- FLOPs / bytes for the roofline;
* collective bytes parsed from the optimized HLO (all-gather /
  all-reduce / reduce-scatter / all-to-all / collective-permute), split
  into model-collectives vs ReCXL replication traffic (collective-permute
  from the engine);

and dump one JSON record per cell into ``benchmarks/artifacts/``.

NOTE the XLA_FLAGS line above MUST run before any other import (jax locks
the device count on first init). Only this entry point forces 512 host
devices -- tests and benches see the real device count.
"""

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import (
    ReplicationConfig,
    RunConfig,
    SHAPES,
    TrainConfig,
    get_model_config,
    shape_applicable,
)
from repro.configs import ASSIGNED_ARCHS
from repro.core.replication import ReplicationEngine
from repro.distributed.context import (make_context,
                                        make_mesh as make_compat_mesh,
                                        mesh_context)
from repro.distributed.sharding import (
    batch_specs,
    cache_specs,
    named_shardings,
    param_specs,
)
from repro.launch.costing import collective_bytes, jaxpr_cost
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models.model_zoo import batch_struct
from repro.training.steps import init_train_state, make_serve_fns, make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts")

# TPU v5e-like constants (roofline)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

def train_config_for(arch: str) -> TrainConfig:
    """AdamW by default; Adafactor for models whose AdamW state cannot fit
    16 GB/chip HBM at 256 chips (>=60B params; DESIGN.md S8)."""
    cfg = get_model_config(arch)
    if cfg.param_count() > 60e9:
        return TrainConfig(optimizer="adafactor")
    return TrainConfig(optimizer="adamw")


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def _eval_struct(tree: Any) -> Any:
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               variant: str = "proactive",
               rep_overrides: Optional[Dict[str, Any]] = None,
               train_overrides: Optional[Dict[str, Any]] = None,
               act_policy: str = "batch",
               mesh_shape: Optional[Tuple[int, ...]] = None,
               blockwise_threshold: Optional[int] = None,
               ) -> Tuple[Any, Any, Dict[str, Any]]:
    """Build + lower one cell. Returns (lowered, mesh_ctx, meta).

    ``act_policy``: activation sharding policy ('batch' | 'seq_model' --
    sequence parallelism, SSPerf). ``mesh_shape``: reshape the same chips
    into different logical axes (e.g. (4, 64) for serving cells)."""
    model_cfg = get_model_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(model_cfg, shape)
    if not ok:
        raise ValueError(f"cell skipped by design: {why}")

    rep_kw: Dict[str, Any] = dict(variant=variant, log_capacity=2)
    if rep_overrides:
        rep_kw.update(rep_overrides)
    rep = ReplicationConfig(**rep_kw)
    tc = train_config_for(arch)
    if train_overrides:
        tc = dataclasses.replace(tc, **train_overrides)
    run = RunConfig(model=model_cfg, shape=shape, replication=rep, train=tc)

    if mesh_shape is not None:
        axes = ("pod", "data", "model")[-len(mesh_shape):]
        mesh = make_compat_mesh(mesh_shape, axes)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = make_context(mesh)
    from repro.distributed.sharding import set_activation_policy
    set_activation_policy(act_policy)
    if blockwise_threshold is not None:
        from repro.models import attention as _attn
        _attn.set_blockwise_threshold(blockwise_threshold)
    model = build_model(model_cfg)
    meta: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                            "mesh_shape": list(mesh.devices.shape),
                            "variant": variant}

    with mesh_context(ctx):
        key = jax.random.PRNGKey(0)
        params_struct = jax.eval_shape(model.init, key)
        p_specs = param_specs(params_struct, model_cfg, ctx)
        p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs)

        if shape.kind == "train":
            engine = (ReplicationEngine(rep, ctx, p_specs, params_struct)
                      if rep.is_replicating else None)
            state_struct = jax.eval_shape(
                lambda k: init_train_state(run, model, k, engine), key)
            opt_shard = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                param_specs(state_struct.opt_state, model_cfg, ctx))
            log_shard = engine.log_shardings() if engine else {}
            state_shard = state_struct._replace(
                params=p_shard, opt_state=opt_shard, logs=log_shard,
                step=NamedSharding(mesh, P()),
                wt_buffer=None)
            b_struct = batch_struct(model_cfg, shape)
            b_shard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                   batch_specs(b_struct, ctx))
            step_fn = make_train_step(run, model, engine)
            lowered = jax.jit(
                step_fn,
                in_shardings=(state_shard, b_shard),
                donate_argnums=(0,),
            ).lower(state_struct, b_struct)
            meta["step"] = "train_step"
            meta["_cost_fn"] = (step_fn, (state_struct, b_struct))

        elif shape.kind == "prefill":
            prefill_fn, _ = make_serve_fns(run, model)
            b_struct = batch_struct(model_cfg, shape)
            b_shard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                   batch_specs(b_struct, ctx))
            lowered = jax.jit(
                prefill_fn, in_shardings=(p_shard, b_shard),
            ).lower(params_struct, b_struct)
            meta["step"] = "prefill_step"
            meta["_cost_fn"] = (prefill_fn, (params_struct, b_struct))

        else:  # decode
            _, decode_fn = make_serve_fns(run, model)
            from repro.training.steps import ServeState
            if model_cfg.is_encdec:
                pre_batch = batch_struct(model_cfg, dataclasses.replace(
                    shape, kind="prefill"))
                _, cache_struct = jax.eval_shape(
                    lambda p, b: model.prefill(p, b, max_len=shape.seq_len),
                    params_struct, pre_batch)
            else:
                cache_struct = jax.eval_shape(
                    lambda: model.init_cache(shape.global_batch, shape.seq_len))
            tok_struct = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
            serve_struct = ServeState(cache=cache_struct, tokens=tok_struct)
            c_specs = cache_specs(cache_struct, model_cfg, ctx)
            serve_shard = ServeState(
                cache=jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs),
                tokens=NamedSharding(
                    mesh, batch_specs(tok_struct, ctx)))
            lowered = jax.jit(
                decode_fn,
                in_shardings=(p_shard, serve_shard),
                donate_argnums=(1,),
            ).lower(params_struct, serve_struct)
            meta["step"] = "serve_step"
            meta["_cost_fn"] = (decode_fn, (params_struct, serve_struct))

    return lowered, ctx, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant: str = "proactive",
             save: bool = True,
             rep_overrides: Optional[Dict[str, Any]] = None,
             train_overrides: Optional[Dict[str, Any]] = None,
             act_policy: str = "batch",
             mesh_shape: Optional[Tuple[int, ...]] = None,
             flash_accounting: bool = False,
             blockwise_threshold: Optional[int] = None,
             tag: str = "") -> Dict[str, Any]:
    """Lower + compile + analyze one cell; returns (and saves) the record.

    ``flash_accounting``: account the blockwise-attention pair scans as
    VMEM-resident (the Pallas flash kernel on real TPUs) -- FLOPs counted,
    intermediate HBM bytes not (launch/costing.py)."""
    t0 = time.time()
    model_cfg = get_model_config(arch)
    shape = SHAPES[shape_name]
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": variant, "tag": tag,
    }
    ok, why = shape_applicable(model_cfg, shape)
    if not ok:
        record["status"] = "skipped"
        record["reason"] = why
        if save:
            _save(record)
        return record

    try:
        lowered, ctx, meta = lower_cell(
            arch, shape_name, multi_pod, variant,
            rep_overrides=rep_overrides, train_overrides=train_overrides,
            act_policy=act_policy, mesh_shape=mesh_shape,
            blockwise_threshold=blockwise_threshold)
        cost_fn, cost_args = meta.pop("_cost_fn")
        record.update(meta)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        n_dev = ctx.mesh.size
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo, n_dev)
        vmem_lengths = frozenset()
        if flash_accounting:
            from repro.models.attention import n_pair_scan_lengths
            vmem_lengths = n_pair_scan_lengths(model_cfg, shape)
        from repro.distributed.sharding import set_activation_policy
        set_activation_policy(act_policy)
        try:
            with mesh_context(ctx):
                jcost = jaxpr_cost(cost_fn, cost_args, n_dev,
                                   vmem_scan_lengths=vmem_lengths)
        finally:
            set_activation_policy("batch")
            if blockwise_threshold is not None:
                from repro.models import attention as _attn
                _attn.set_blockwise_threshold(4096)
        record["act_policy"] = act_policy
        record["flash_accounting"] = flash_accounting

        record.update({
            "status": "ok",
            "n_devices": n_dev,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_size_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
            },
            "cost": {
                # raw XLA numbers (while bodies counted once -- kept for
                # reference only)
                "hlo_flops_per_device": cost.get("flops"),
                "hlo_bytes_per_device": cost.get("bytes accessed"),
                # trip-corrected logical cost (global), see launch/costing.py
                "flops_global": jcost["flops"],
                "bytes_global": jcost["bytes"],
                "transcendentals_global": jcost["transcendentals"],
            },
            "collectives": coll,
            "model_params": model_cfg.param_count(),
            "active_params": model_cfg.active_param_count(),
            "tokens": shape.tokens if shape.kind != "decode"
            else shape.global_batch,
        })
    except Exception as e:  # noqa: BLE001 -- a failed cell IS the finding
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
    record["wall_s"] = round(time.time() - t0, 1)
    if save:
        _save(record)
    return record


def _save(record: Dict[str, Any]) -> None:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    tag = f"_{record['tag']}" if record.get("tag") else ""
    name = (f"dryrun_{record['arch']}_{record['shape']}_"
            f"{record['mesh'].replace('x', '-')}{tag}.json")
    with open(os.path.join(ARTIFACT_DIR, name), "w") as f:
        json.dump(record, f, indent=1)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape cell name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="proactive")
    ap.add_argument("--no-save", action="store_true")
    args = ap.parse_args()

    archs = list(ASSIGNED_ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                r = run_cell(arch, shape, mp, args.variant,
                             save=not args.no_save)
                status = r["status"]
                extra = ""
                if status == "ok":
                    flops = (r["cost"]["flops_global"] or 0) / r["n_devices"]
                    extra = (f"flops/dev={flops:.3e} "
                             f"coll={r['collectives']['total_bytes']:.3e}B "
                             f"compile={r['compile_s']}s")
                elif status == "error":
                    extra = r["error"][:120]
                else:
                    extra = r["reason"][:80]
                print(f"[{status:7s}] {arch:22s} {shape:12s} "
                      f"{r['mesh']:8s} {extra}", flush=True)
                results.append(r)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped-by-design, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
