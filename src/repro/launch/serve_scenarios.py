"""Scenario-serving daemon launcher: warm a :class:`ScenarioServer`
on a sweep grid, then drive a mixed query stream against it and report
serve-side latency/cache statistics.

Example::

    PYTHONPATH=src python -m repro.launch.serve_scenarios \
        --stores 5000 --queries 200 --batch-cells 32 --shards 4

The driver warms the server on a mixed-SB sweep grid, then issues a
query stream that interleaves lane-cache hits (cells of the warm grid),
novel cells (diff-upload misses), a grid-delta request and a couple of
downtime queries -- the daemon's three query shapes -- and prints
p50/p99 latency, throughput, cache-hit ratio and the marginal
host->device bytes per query. ``--check`` re-runs every served cell
through the cold ``simulate_grid`` oracle and asserts bit-identity
(the same pin tests/test_serving.py holds under hypothesis).
"""

import argparse
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--stores", type=int, default=5_000,
                    help="stores per timeline (n_stores)")
    ap.add_argument("--queries", type=int, default=200,
                    help="live queries to issue after warmup")
    ap.add_argument("--batch-cells", type=int, default=32,
                    help="canonical serve-tile size")
    ap.add_argument("--window-ms", type=float, default=2.0,
                    help="async batching window (submit path)")
    ap.add_argument("--shards", type=int, default=1,
                    help="cells-mesh shards for flush tiles")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="assert every answer == the cold oracle")
    ap.add_argument("--host-devices", type=int, default=8)
    ap.add_argument("--k-replicas", type=int, default=None,
                    help="sub-bank replica blocks per shard (default: 1, "
                         "or 2 inside a chaos scope; see docs/resilience.md)")
    ap.add_argument("--submit-timeout-ms", type=float, default=None,
                    help="default deadline on submit() futures; the "
                         "watchdog fails them with a diagnostic past it")
    ap.add_argument("--watchdog-ms", type=float, default=None,
                    help="fail a wedged daemon flush after this long")
    ap.add_argument("--lose-shard", type=int, default=None,
                    help="inject a shard loss mid-stream (chaos demo: the "
                         "server must recover bit-identical, 0 recompiles)")
    ap.add_argument("--trace-out", type=str, default=None, metavar="PATH",
                    help="enable the flight recorder and export the run "
                         "as Chrome trace-event JSONL to PATH (load at "
                         "https://ui.perfetto.dev; docs/observability.md)")
    args = ap.parse_args()

    if "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}")

    import contextlib

    import numpy as np

    from repro.core import chaos
    from repro.core import telemetry
    from repro.core.engine import simulate_grid, trace_count
    from repro.core.scenarios import grid_delta, sweep_grid
    from repro.core.serving import ScenarioServer

    if args.trace_out:
        telemetry.enable()

    warm_grid = sweep_grid(seeds=(0, 1), sb_sizes=(None, 48),
                           link_bw_gbps=(None, 40.0))
    novel = grid_delta(warm_grid, workloads=("ycsb", "canneal", "barnes"),
                       configs=("proactive", "baseline"),
                       n_replicas=(2, 4), sb_sizes=(None, 48))

    rng = np.random.default_rng(args.seed)
    stream = [warm_grid[rng.integers(len(warm_grid))] if rng.random() < 0.7
              else novel[rng.integers(len(novel))]
              for _ in range(args.queries)]

    # arm far out so the warm phase runs clean, then re-arm a couple of
    # dispatches into the query stream once warm's dispatch count is known
    scope = (chaos.inject(chaos.ChaosConfig(lose_shard=args.lose_shard,
                                            lose_at_dispatch=1 << 30))
             if args.lose_shard is not None else contextlib.nullcontext())
    with scope as chaos_state, \
         ScenarioServer(n_stores=args.stores, batch_cells=args.batch_cells,
                        batch_window_ms=args.window_ms,
                        n_shards=args.shards, k_replicas=args.k_replicas,
                        submit_timeout_ms=args.submit_timeout_ms,
                        watchdog_ms=args.watchdog_ms) as srv:
        t0 = time.perf_counter()
        srv.warm(warm_grid)
        t_warm = time.perf_counter() - t0
        print(f"warm: {len(warm_grid)} cells, "
              f"{srv.stats()['bank_rows']} bank rows, "
              f"{srv.stats()['compiled_programs']} programs, "
              f"{t_warm * 1e3:.1f} ms")

        if chaos_state is not None:
            chaos_state.arm_after(2)

        if args.trace_out:
            telemetry.reset()   # trace the live stream, not the warm flush
        srv.reset_stats()
        tc0 = trace_count()
        lat = []
        t0 = time.perf_counter()
        for spec in stream:
            t1 = time.perf_counter()
            srv.query(spec)
            lat.append(time.perf_counter() - t1)
        wall = time.perf_counter() - t0
        st = srv.stats()
        lat_ms = np.sort(np.asarray(lat)) * 1e3
        print(f"served {len(stream)} queries in {wall:.3f} s "
              f"({len(stream) / wall:.0f} q/s)")
        print(f"latency p50 {lat_ms[len(lat_ms) // 2]:.3f} ms  "
              f"p99 {lat_ms[int(len(lat_ms) * 0.99)]:.3f} ms")
        print(f"cache-hit ratio {st['hit_ratio']:.3f}  "
              f"steady-state compiles {trace_count() - tc0}")
        print(f"marginal h2d {st['h2d_bytes'] / len(stream):.0f} B/query "
              f"(cold full-bank upload {st['bank_bytes']} B)")

        # async path: a submit() burst exercises the daemon thread (and,
        # traced, the queue-wait / batching-window histograms)
        for f in [srv.submit(s) for s in stream[:16]]:
            f.result()

        if chaos_state is not None:
            rep = chaos_state.report()
            for r in rep["recoveries"]:
                print(f"chaos: shard {r['shard']} lost, recovered from "
                      f"{r['source']} in {r['ms']:.1f} ms ({r['mode']})")
            print(f"chaos: k_replicas={srv.k_replicas}, "
                  f"upload retries {rep['upload_retries']}, "
                  f"post-recovery compiles {trace_count() - tc0}")

        # the other two query shapes
        added = srv.query_grid(workloads=("streamcluster",),
                               configs=("proactive",), n_replicas=(2, 4))
        est = srv.query_downtime("ycsb", fail_time_ms=50.0, n_cns=8)
        print(f"grid-delta query: {len(added)} cells; "
              f"downtime(ycsb, 50ms, 8 CNs) = {est.total_ns / 1e6:.2f} ms")

        if args.check:
            served = srv.query_batch(stream)
            oracle = simulate_grid(stream, n_stores=args.stores,
                                   engine="blocked")
            for a, b in zip(served, oracle):
                assert a == b, (a.meta, a, b)
            print(f"oracle check: {len(stream)} answers bit-identical")

        if args.trace_out:
            summ = telemetry.summary()
            n = telemetry.export_chrome(args.trace_out)
            q = summ["dists"].get("serve/query_ms", {})
            print(f"telemetry: {n} trace events -> {args.trace_out} "
                  f"({summ['threads']} threads, "
                  f"serve/query_ms p50 {q.get('p50', 0.0):.3f} ms "
                  f"p99 {q.get('p99', 0.0):.3f} ms)")


if __name__ == "__main__":
    main()
