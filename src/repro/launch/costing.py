"""Cost accounting for the dry-run.

XLA's ``compiled.cost_analysis()`` famously counts ``while``-loop bodies
ONCE, so anything inside a ``lax.scan`` (the layer stack, the blockwise
attention pair walk, the SSD chunk scan) is undercounted by its trip
count. Two complementary fixes:

* :func:`jaxpr_cost` -- walk the traced jaxpr, multiplying by scan trip
  counts and ``shard_map`` device counts: exact *logical* global FLOPs
  (dot/conv), plus an HBM-traffic estimate under a
  producer-consumer-fusion model (every tensor written once; inputs read
  once by non-fusable consumers).
* :func:`collective_bytes` -- parse the compiled HLO, build the
  computation call graph, extract each ``while`` condition's trip
  constant, and multiply collective payloads by their computation's trip
  multiplier. Ring-algorithm effective volumes per participant.
"""

from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.extend.core as jcore
import numpy as np

# ---------------------------------------------------------------------------
# Jaxpr walking
# ---------------------------------------------------------------------------

_EXPENSIVE = {
    "dot_general", "conv_general_dilated", "reduce_sum", "reduce_max",
    "reduce_min", "reduce_and", "reduce_or", "argmax", "argmin",
    "sort", "top_k", "cumsum", "cumlogsumexp",
}

# layout/view ops that XLA fuses away (no HBM traffic of their own)
_FREE = {
    "convert_element_type", "broadcast_in_dim", "reshape", "transpose",
    "squeeze", "expand_dims", "copy", "bitcast_convert_type",
    "stop_gradient", "optimization_barrier",
}

_TRANSCENDENTAL = {"exp", "log", "tanh", "logistic", "erf", "rsqrt", "sqrt",
                   "sin", "cos", "pow"}


def _nbytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _dot_flops(eqn) -> int:
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    m = int(np.prod([a.shape[i] for i in range(a.ndim)
                     if i not in lc and i not in lb]))
    k = int(np.prod([a.shape[i] for i in lc]))
    batch = int(np.prod([a.shape[i] for i in lb]))
    n = int(np.prod([b.shape[i] for i in range(b.ndim)
                     if i not in rc and i not in rb]))
    return 2 * batch * m * n * k


def _conv_flops(eqn) -> int:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    groups = eqn.params.get("feature_group_count", 1)
    dnums = eqn.params["dimension_numbers"]
    rhs_spec = dnums.rhs_spec  # (out_feat, in_feat/groups, *spatial)
    kernel_spatial = int(np.prod([rhs.shape[i] for i in rhs_spec[2:]]))
    in_per_group = rhs.shape[rhs_spec[1]]
    return 2 * int(np.prod(out.shape)) * kernel_spatial * in_per_group


def _sub_jaxprs(eqn) -> List[Tuple[Any, float]]:
    """(jaxpr, multiplier) pairs nested under an eqn."""
    p = eqn.primitive.name
    params = eqn.params
    out: List[Tuple[Any, float]] = []
    if p == "scan":
        out.append((params["jaxpr"].jaxpr, float(params["length"])))
    elif p == "while":
        # unknown trips; our code only uses scan-backed whiles
        out.append((params["body_jaxpr"].jaxpr, 1.0))
    elif p == "cond":
        brs = params.get("branches", ())
        if brs:
            out.append((brs[0].jaxpr, 1.0))
    elif "jaxpr" in params:
        j = params["jaxpr"]
        out.append((getattr(j, "jaxpr", j), 1.0))
    elif "call_jaxpr" in params:
        j = params["call_jaxpr"]
        out.append((getattr(j, "jaxpr", j), 1.0))
    elif "fun_jaxpr" in params:
        j = params["fun_jaxpr"]
        out.append((getattr(j, "jaxpr", j), 1.0))
    return out


def _shard_map_mult(eqn, mesh_size: int) -> Optional[float]:
    if eqn.primitive.name in ("shard_map", "smap"):
        return float(mesh_size)
    return None


def _walk(jaxpr, mult: float, mesh_size: int, acc: Dict[str, float],
          vmem_scan_lengths: frozenset = frozenset(),
          in_vmem: bool = False) -> None:
    bscale = 0.0 if in_vmem else 1.0
    for eqn in jaxpr.eqns:
        p = eqn.primitive.name
        if p == "dot_general":
            acc["flops"] += mult * _dot_flops(eqn)
            acc["bytes"] += mult * bscale * (
                sum(_nbytes(v.aval) for v in eqn.invars)
                + _nbytes(eqn.outvars[0].aval))
            continue
        if p == "conv_general_dilated":
            acc["flops"] += mult * _conv_flops(eqn)
            acc["bytes"] += mult * bscale * (
                sum(_nbytes(v.aval) for v in eqn.invars)
                + _nbytes(eqn.outvars[0].aval))
            continue
        # slicing/indexed ops touch only the moved slice, not the operand
        if p == "dynamic_update_slice":
            acc["bytes"] += mult * bscale * 2 * _nbytes(eqn.invars[1].aval)
            continue
        if p in ("dynamic_slice", "slice"):
            acc["bytes"] += mult * bscale * 2 * _nbytes(eqn.outvars[0].aval)
            continue
        if p == "gather":
            acc["bytes"] += mult * bscale * (
                2 * _nbytes(eqn.outvars[0].aval)
                + _nbytes(eqn.invars[1].aval))
            continue
        if p in ("scatter", "scatter-add", "scatter_add", "scatter-update"):
            acc["bytes"] += mult * bscale * (
                2 * _nbytes(eqn.invars[2].aval)
                + _nbytes(eqn.invars[1].aval))
            continue
        subs = _sub_jaxprs(eqn)
        sm = _shard_map_mult(eqn, mesh_size)
        if sm is not None and "jaxpr" in eqn.params:
            j = eqn.params["jaxpr"]
            _walk(getattr(j, "jaxpr", j), mult * sm, mesh_size, acc,
                  vmem_scan_lengths, in_vmem)
            continue
        if subs:
            for j, m in subs:
                # flash-kernel accounting: scans whose trip count matches a
                # registered attention pair walk keep their intermediates
                # (scores/probs/acc) in VMEM -- no HBM traffic inside.
                vmem = in_vmem or (p == "scan"
                                   and m in vmem_scan_lengths)
                _walk(j, mult * m, mesh_size, acc, vmem_scan_lengths, vmem)
            continue
        if p in _FREE:
            continue
        # leaf op: fusion model -- outputs written once; inputs re-read
        # only by non-fusable ops
        out_b = sum(_nbytes(v.aval) for v in eqn.outvars)
        acc["bytes"] += mult * bscale * out_b
        if p in _EXPENSIVE:
            acc["bytes"] += mult * bscale * sum(
                _nbytes(v.aval) for v in eqn.invars
                if not isinstance(v, jcore.Literal))
        if p in _TRANSCENDENTAL:
            acc["transcendentals"] += mult * int(
                np.prod(eqn.outvars[0].aval.shape))
        # elementwise flops are negligible next to matmuls but keep a tally
        if p in ("add", "mul", "sub", "div", "max", "min"):
            acc["eltwise_flops"] += mult * int(
                np.prod(eqn.outvars[0].aval.shape))


def jaxpr_cost(fn, args, mesh_size: int,
               vmem_scan_lengths: frozenset = frozenset()) -> Dict[str, float]:
    """Global logical cost of ``fn(*args)``.

    ``flops``: dot/conv FLOPs (x2 MAC), scan-trip and shard_map corrected.
    ``bytes``: estimated global HBM traffic under the fusion model.
    ``vmem_scan_lengths``: trip counts of scans whose bodies are
    VMEM-resident on the target (the Pallas flash-attention pair walk) --
    their FLOPs count but their intermediate bytes do not.
    Per-device numbers are these / n_devices (even sharding).
    """
    closed = jax.make_jaxpr(fn)(*args)
    acc = {"flops": 0.0, "bytes": 0.0, "transcendentals": 0.0,
           "eltwise_flops": 0.0}
    # top-level constants/args read once
    acc["bytes"] += sum(_nbytes(v.aval) for v in closed.jaxpr.invars)
    _walk(closed.jaxpr, 1.0, mesh_size, acc, vmem_scan_lengths)
    return acc


# ---------------------------------------------------------------------------
# HLO collective parsing (while-trip aware)
# ---------------------------------------------------------------------------

_COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(
    r"\b(f64|s64|f32|s32|u32|bf16|f16|s8|u8|pred)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w\.\-]+)")

_DTYPE_BYTES = {"f64": 8, "s64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1}


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if line and not line[0].isspace():
            m = _COMP_HDR_RE.match(line.strip())
            if m and "{" in line:
                cur = m.group(1)
                if line.strip().startswith("ENTRY"):
                    cur = "__entry__"
                comps[cur] = []
                continue
            if line.strip() == "}":
                cur = None
                continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _line_bytes(line: str) -> int:
    m = _SHAPE_RE.search(line)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def _trip_count(cond_lines: List[str]) -> float:
    consts = [int(m.group(1)) for l in cond_lines
              for m in _CONST_RE.finditer(l)]
    return float(max(consts)) if consts else 1.0


def computation_multipliers(hlo: str) -> Dict[str, float]:
    """Multiplier (product of enclosing while trip counts) per computation."""
    comps = _split_computations(hlo)
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    mult["__entry__"] = 1.0

    # edges: computation -> [(child, factor)]
    edges: Dict[str, List[Tuple[str, float]]] = {n: [] for n in comps}
    for name, lines in comps.items():
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.groups()
                trips = _trip_count(comps.get(cond, []))
                edges[name].append((body, trips))
                edges[name].append((cond, trips))
                continue
            cm = _CALL_RE.search(line)
            if cm and cm.group(1) in comps:
                edges[name].append((cm.group(1), 1.0))

    # propagate (call graph is a DAG; a few sweeps suffice)
    for _ in range(12):
        changed = False
        for parent, kids in edges.items():
            pm = mult.get(parent, 0.0)
            if pm <= 0:
                continue
            for child, f in kids:
                nm = pm * f
                if nm > mult.get(child, 0.0):
                    mult[child] = nm
                    changed = True
        if not changed:
            break
    return mult


def collective_bytes(hlo: str, total_devices: int) -> Dict[str, Any]:
    """Per-device link bytes per step, ring-effective, trip-corrected.

    collective-permute payloads are (almost entirely) ReCXL replication
    traffic in this framework and are reported separately.
    """
    comps = _split_computations(hlo)
    mult = computation_multipliers(hlo)
    per_kind: Dict[str, float] = {}
    n_ops: Dict[str, int] = {}
    permute = 0.0
    f32_bytes = 0.0
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for line in lines:
            cm = _COLLECTIVE_RE.search(line)
            if not cm:
                continue
            lhs = line.split("=")[0]
            if "-done" in lhs or "-update" in lhs:
                continue
            kind = cm.group(1)
            out_bytes = _line_bytes(line)
            n = max(_group_size(line, total_devices), 1)
            if kind == "all-gather":
                eff = out_bytes * (n - 1) / n
            elif kind == "reduce-scatter":
                eff = out_bytes * (n - 1)
            elif kind == "all-reduce":
                eff = out_bytes * 2 * (n - 1) / n
            elif kind == "all-to-all":
                eff = out_bytes * (n - 1) / n
            else:
                eff = out_bytes
                permute += eff * m
            per_kind[kind] = per_kind.get(kind, 0.0) + eff * m
            n_ops[kind] = n_ops.get(kind, 0) + int(m)
            dm = _SHAPE_RE.search(line)
            if dm and dm.group(1) in ("f32", "s32", "u32"):
                f32_bytes += eff * m
    total = float(sum(per_kind.values()))
    return {
        "per_kind_bytes": per_kind,
        "n_ops": n_ops,
        "total_bytes": total,
        "replication_bytes": float(permute),
        # XLA's CPU FloatNormalization promotes bf16 collectives to f32;
        # on the TPU target they run native bf16 -- the adjusted total
        # halves the f32-wide payloads (activations/grads/params are all
        # bf16 by construction in this framework). Both are reported.
        "f32_bytes": float(f32_bytes),
        "total_bytes_bf16adj": total - 0.5 * float(f32_bytes),
    }
