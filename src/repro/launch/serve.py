"""Serving launcher: batched prefill + decode with a KV/SSM cache.

Example::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --reduced --batch 4 --prompt-len 64 --gen 32 --mesh 4x2
"""

import argparse
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="4x2")
    ap.add_argument("--host-devices", type=int, default=8)
    args = ap.parse_args()

    if "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}")

    import jax
    import jax.numpy as jnp

    from repro.config import (
        MeshConfig,
        RunConfig,
        ShapeConfig,
        get_model_config,
        get_reduced_config,
    )
    from repro.distributed.context import make_context, mesh_context
    from repro.distributed.sharding import named_shardings
    from repro.launch.mesh import make_mesh
    from repro.models import build_model
    from repro.models.model_zoo import make_batch
    from repro.training.steps import make_serve_fns

    model_cfg = (get_reduced_config(args.arch) if args.reduced
                 else get_model_config(args.arch))
    mesh_shape = tuple(int(x) for x in args.mesh.split("x"))
    mesh_cfg = MeshConfig(mesh_shape, ("data", "model"))
    mesh = make_mesh(mesh_cfg)
    ctx = make_context(mesh)

    shape = ShapeConfig("serve", seq_len=args.prompt_len,
                        global_batch=args.batch, kind="prefill")
    run = RunConfig(model=model_cfg, shape=shape, mesh=mesh_cfg)
    model = build_model(model_cfg)
    prefill_fn, decode_fn = make_serve_fns(run, model)
    max_len = args.prompt_len + args.gen

    with mesh_context(ctx):
        params = model.init(jax.random.PRNGKey(0))
        params = jax.tree.map(
            jax.device_put, params, named_shardings(params, model_cfg, ctx))
        batch = make_batch(model_cfg, shape)
        batch.pop("labels", None)

        t0 = time.perf_counter()
        toks, state = jax.jit(
            lambda p, b: prefill_fn(p, b, max_len=max_len))(params, batch)
        toks.block_until_ready()
        t_prefill = time.perf_counter() - t0

        decode = jax.jit(decode_fn)
        out = [toks]
        t0 = time.perf_counter()
        for _ in range(args.gen - 1):
            toks, state = decode(params, state)
            out.append(toks)
        jax.block_until_ready(toks)
        t_decode = time.perf_counter() - t0

    gen = jnp.stack(out, axis=1)
    print(f"{model_cfg.name}: prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill*1e3:.1f} ms; {args.gen-1} decode steps in "
          f"{t_decode*1e3:.1f} ms "
          f"({(args.gen-1)*args.batch/max(t_decode,1e-9):.0f} tok/s)")
    print("sample generation (seq 0):", gen[0].tolist())


if __name__ == "__main__":
    main()
