"""Training launcher.

Examples::

    # fault-tolerant training of a reduced qwen3 on the local devices
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --reduced --steps 100 --mesh 4x2 --variant proactive

    # inject a node failure at step 50 and watch recovery
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --reduced --steps 100 --mesh 4x2 --fail-node 2 --fail-step 50

On a real TPU pod this entry point is launched once per host (JAX
distributed init is keyed off the cluster env); on CPU it simulates the
mesh with --host-devices fake devices.
"""

import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--mesh", default="4x2", help="DATAxMODEL, e.g. 4x2")
    ap.add_argument("--host-devices", type=int, default=8)
    ap.add_argument("--variant", default="proactive",
                    choices=["none", "writethrough", "baseline", "parallel",
                             "proactive"])
    ap.add_argument("--n-replicas", type=int, default=3)
    ap.add_argument("--n-buckets", type=int, default=8)
    ap.add_argument("--dump-interval", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--workdir", default="/tmp/recxl_train")
    ap.add_argument("--fail-node", type=int, default=-1)
    ap.add_argument("--fail-step", type=int, default=-1)
    args = ap.parse_args()

    # must run before jax init
    if "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}")

    import jax

    from repro.config import (
        MeshConfig,
        ReplicationConfig,
        RunConfig,
        ShapeConfig,
        TrainConfig,
        get_model_config,
        get_reduced_config,
    )
    from repro.core.failures import FailureEvent, FailureInjector
    from repro.launch.mesh import make_mesh
    from repro.training.trainer import Trainer

    model_cfg = (get_reduced_config(args.arch) if args.reduced
                 else get_model_config(args.arch))
    mesh_shape = tuple(int(x) for x in args.mesh.split("x"))
    axes = ("data", "model")[:len(mesh_shape)] if len(mesh_shape) == 2 else \
        ("pod", "data", "model")
    mesh_cfg = MeshConfig(mesh_shape, axes)
    n_rep = min(args.n_replicas, mesh_shape[axes.index("data")] - 1)

    run = RunConfig(
        model=model_cfg,
        shape=ShapeConfig("cli", seq_len=args.seq_len,
                          global_batch=args.global_batch, kind="train"),
        mesh=mesh_cfg,
        replication=ReplicationConfig(
            variant=args.variant, n_replicas=max(n_rep, 1),
            n_buckets=args.n_buckets, dump_interval=args.dump_interval),
        train=TrainConfig(total_steps=args.steps, learning_rate=args.lr,
                          warmup_steps=max(args.steps // 10, 1)),
    )
    mesh = make_mesh(mesh_cfg)
    injector = FailureInjector(
        [FailureEvent(step=args.fail_step, node=args.fail_node)]
        if args.fail_node >= 0 and args.fail_step >= 0 else [])

    trainer = Trainer(run, mesh, args.workdir, injector=injector)
    print(f"training {model_cfg.name} ({model_cfg.param_count()/1e6:.1f}M "
          f"params) on mesh {mesh_shape}, variant={args.variant}")

    def log(step: int, m: dict) -> None:
        print(f"step {step:5d} loss {m['loss']:.4f} "
              f"gnorm {m['grad_norm']:.3f} {m['wall_s']*1e3:.0f} ms")

    trainer.train(args.steps, on_metrics=log)
    for e in trainer.events:
        print("event:", e)


if __name__ == "__main__":
    main()
