"""Learning-rate schedules."""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


def make_schedule(cfg: TrainConfig) -> Callable[[jax.Array], jax.Array]:
    base = cfg.learning_rate
    warmup = max(cfg.warmup_steps, 1)
    total = max(cfg.total_steps, warmup + 1)

    def schedule(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = base * jnp.minimum(step / warmup, 1.0)
        if cfg.schedule == "constant":
            return warm
        frac = jnp.clip((step - warmup) / (total - warmup), 0.0, 1.0)
        if cfg.schedule == "linear":
            decay = 1.0 - frac
        else:  # cosine
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, base * decay)

    return schedule
