"""Optimizers as pure (init, update) pairs over parameter pytrees.

* AdamW  -- fp32 moments (+ optional fp32 master copy), the default.
* Adafactor -- factored second moment, for the >=67B configs whose AdamW
  state would not fit 16 GB/chip HBM at 256 chips (DESIGN.md S8).
* SGD-momentum -- for completeness / ablations.

Optimizer state tensors inherit the parameter sharding (FSDP x TP), so
ZeRO-style partitioning falls out of the sharding rules for free.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig

OptState = Dict[str, Any]


def _tree_zeros_like(tree: Any, dtype: Optional[jnp.dtype] = None) -> Any:
    return jax.tree.map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-6))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params: Any, cfg: TrainConfig) -> OptState:
    master = jnp.dtype(cfg.master_dtype)
    state: OptState = {
        "m": _tree_zeros_like(params, master),
        "v": _tree_zeros_like(params, master),
        "count": jnp.zeros((), jnp.int32),
    }
    if cfg.master_dtype != cfg.param_dtype:
        state["master"] = jax.tree.map(lambda x: x.astype(master), params)
    return state


def adamw_update(grads: Any, state: OptState, params: Any, lr: jax.Array,
                 cfg: TrainConfig) -> Tuple[Any, OptState]:
    b1, b2, eps, wd = cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay
    count = state["count"] + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)
    ref = state.get("master", params)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mh = m / c1
        vh = v / c2
        step = mh / (jnp.sqrt(vh) + eps) + wd * p.astype(jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr * step)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_p = jax.tree.leaves(ref)
    new_m, new_v, new_ref = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        m2, v2, p2 = upd(g, m, v, p)
        new_m.append(m2)
        new_v.append(v2)
        new_ref.append(p2)
    param_dtype = jax.tree.leaves(params)[0].dtype
    new_params = jax.tree.unflatten(
        treedef, [p.astype(param_dtype) for p in new_ref])
    new_state: OptState = {
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
        "count": count,
    }
    if "master" in state:
        new_state["master"] = jax.tree.unflatten(
            treedef, [p.astype(jnp.dtype(cfg.master_dtype)) for p in new_ref])
    return new_params, new_state


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no first moment by default)
# ---------------------------------------------------------------------------

def adafactor_init(params: Any, cfg: TrainConfig) -> OptState:
    def factored(x):
        if x.ndim >= 2:
            return {
                "vr": jnp.zeros(x.shape[:-1], jnp.float32),
                "vc": jnp.zeros(x.shape[:-2] + x.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(x.shape, jnp.float32)}

    return {
        "vs": jax.tree.map(factored, params,
                           is_leaf=lambda x: isinstance(x, jax.Array)),
        "count": jnp.zeros((), jnp.int32),
    }


def adafactor_update(grads: Any, state: OptState, params: Any, lr: jax.Array,
                     cfg: TrainConfig) -> Tuple[Any, OptState]:
    eps = 1e-30
    d = 1.0 - cfg.beta2          # decay toward running stat
    count = state["count"] + 1
    beta2t = 1.0 - (count.astype(jnp.float32) + 1.0) ** -0.8

    def upd(g, v, p):
        g32 = jnp.square(g.astype(jnp.float32)) + eps
        if g.ndim >= 2:
            vr = beta2t * v["vr"] + (1 - beta2t) * jnp.mean(g32, axis=-1)
            vc = beta2t * v["vc"] + (1 - beta2t) * jnp.mean(g32, axis=-2)
            denom = jnp.sqrt(
                vr[..., :, None] * vc[..., None, :]
                / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)[..., None],
                              eps))
            newv = {"vr": vr, "vc": vc}
        else:
            newv = {"v": beta2t * v["v"] + (1 - beta2t) * g32}
            denom = jnp.sqrt(newv["v"])
        step = g.astype(jnp.float32) / jnp.maximum(denom, 1e-12)
        # update clipping (Adafactor's RMS-1 rule)
        rms = jnp.sqrt(jnp.mean(jnp.square(step)) + 1e-12)
        step = step / jnp.maximum(1.0, rms)
        p32 = p.astype(jnp.float32)
        return newv, (p32 - lr * (step + cfg.weight_decay * p32)).astype(p.dtype)

    flat_g = jax.tree.leaves(grads)
    flat_p, treedef = jax.tree.flatten(params)
    is_v = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)  # noqa: E731
    flat_v = jax.tree.leaves(state["vs"], is_leaf=is_v)
    new_v, new_p = [], []
    for g, v, p in zip(flat_g, flat_v, flat_p):
        v2, p2 = upd(g, v, p)
        new_v.append(v2)
        new_p.append(p2)
    return (jax.tree.unflatten(treedef, new_p),
            {"vs": jax.tree.unflatten(treedef, new_v), "count": count})


# ---------------------------------------------------------------------------
# SGD momentum
# ---------------------------------------------------------------------------

def sgd_init(params: Any, cfg: TrainConfig) -> OptState:
    return {"mom": _tree_zeros_like(params, jnp.float32),
            "count": jnp.zeros((), jnp.int32)}


def sgd_update(grads: Any, state: OptState, params: Any, lr: jax.Array,
               cfg: TrainConfig) -> Tuple[Any, OptState]:
    def upd(g, mo, p):
        mo = cfg.beta1 * mo + g.astype(jnp.float32)
        return mo, (p.astype(jnp.float32) - lr * mo).astype(p.dtype)

    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["mom"])
    flat_p, treedef = jax.tree.flatten(params)
    new_m, new_p = [], []
    for g, mo, p in zip(flat_g, flat_m, flat_p):
        m2, p2 = upd(g, mo, p)
        new_m.append(m2)
        new_p.append(p2)
    return (jax.tree.unflatten(treedef, new_p),
            {"mom": jax.tree.unflatten(treedef, new_m),
             "count": state["count"] + 1})


# ---------------------------------------------------------------------------
# Factory
# ---------------------------------------------------------------------------

def make_optimizer(cfg: TrainConfig) -> Tuple[Callable, Callable]:
    if cfg.optimizer == "adamw":
        return (lambda p: adamw_init(p, cfg),
                lambda g, s, p, lr: adamw_update(g, s, p, lr, cfg))
    if cfg.optimizer == "adafactor":
        return (lambda p: adafactor_init(p, cfg),
                lambda g, s, p, lr: adafactor_update(g, s, p, lr, cfg))
    if cfg.optimizer == "sgd":
        return (lambda p: sgd_init(p, cfg),
                lambda g, s, p, lr: sgd_update(g, s, p, lr, cfg))
    raise ValueError(f"unknown optimizer {cfg.optimizer}")
