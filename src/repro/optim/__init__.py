"""Optimizers + LR schedules (sharded-state friendly)."""

from repro.optim.optimizers import (  # noqa: F401
    OptState,
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    make_optimizer,
)
from repro.optim.schedules import make_schedule  # noqa: F401
