"""Sharded streaming mega-grid engine (the tier above ``simulate_batch``).

``simulate_batch`` runs a whole grid as ONE blocked-scan call: perfect
up to a few thousand cells, but a mega-grid (>10^4 cells -- the full
(workload x config x N_r x bw x CN x SB) sensitivity space of Figs.
10/16-18, times seeds) hits three walls:

* **one device** -- the time-major ``(n_stores, B)`` layout makes the
  cell axis embarrassingly parallel, yet the whole batch scans on a
  single device;
* **one giant allocation + one compile per batch shape** -- every grid
  size stacks fresh ``(n_stores, B)`` arrays and jits a program for
  that exact ``B``;
* **serialized host prep** -- trace synthesis / per-cell cost
  derivation for the *whole* grid completes before the first scan step
  runs.

This module is the streaming tier that removes all three:

1. **Tile scheduler** (:func:`plan_tiles`). The grid is split into
   tiles of at most :data:`DEFAULT_TILE_CELLS` cells, grouped by
   store-buffer depth first, so every tile is SB-uniform and runs the
   tuple-history fast path of the blocked scan -- a mixed-SB mega-grid
   never falls back to the gather path the way a one-shot batch must.
   Every tile is padded to a small set of canonical cell counts
   (:func:`_canonical_sizes`), so an entire mega-grid executes with a
   handful of compiled programs (:class:`TileSignature` ->
   :func:`_tile_fn` cache), not one compile per ragged tail.

2. **``shard_map`` over a ``cells`` mesh axis.** Each tile's arrays are
   ``device_put`` with the cell axis sharded over all local devices
   (``repro.distributed.context.cells_mesh`` /
   ``repro.distributed.sharding.tile_shardings``) and the blocked scan
   runs per shard with ZERO cross-device communication -- cells are
   independent timelines, sharding is a pure partition. Elementwise
   lane arithmetic is unchanged, so results stay bit-identical to the
   single-device path and the serial oracle (tests/test_engine.py
   asserts ``==``).

3. **Double-buffered streaming.** A single worker thread prepares tile
   k+1 (``_prepare_cell`` + cell-major ``_stack_tile`` host numpy --
   a row memcpy per cell, transposed to time-major on device) while the
   devices compute tile k; dispatch is async and runs ahead of the
   devices by at most :data:`MAX_IN_FLIGHT_TILES` tiles before the
   oldest is drained, bounding live memory. Host prep cost
   is further collapsed by the reduced-key ``_cell_arrays`` memo
   (cells differing only in config class / SB / CN share one
   derivation), and everything is dropped by
   ``repro.core.simulator.clear_sim_caches()`` -- including this
   module's compiled-tile cache, registered via
   ``register_cache_clearer``.

:func:`simulate_grid` is the tier selector: grids below
:data:`STREAM_THRESHOLD` cells go to the blocked one-shot batch, larger
grids stream; ``engine=`` forces a tier. ``SimResult.meta`` records
which tier ran, the chunk used, and the tile/shard geometry.
"""

from __future__ import annotations

import dataclasses
import math
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.recxl_paper import ClusterConfig, PAPER_CLUSTER
from repro.core.simulator import (
    ScenarioSpec,
    SimResult,
    _CellInputs,
    _commit_cost_ns,
    _finish_result,
    _pad_len,
    _prepare_cell,
    _timeline_batch_blocked,
    _trace_cached,
    auto_chunk,
    register_cache_clearer,
    simulate,
    simulate_batch,
)
from repro.distributed.context import cells_mesh, shard_map
from repro.distributed.sharding import tile_shardings, tile_specs

#: Cells per tile (before canonical padding) at the default byte
#: budget. Large enough that one scan amortizes dispatch overhead,
#: small enough that a tile's five (B_tile, n_stores) arrays stream
#: through cache instead of RAM.
DEFAULT_TILE_CELLS = 1024

#: Byte budget for one tile's five per-store input arrays (~4+1+4+4+4
#: bytes per cell-store). Long traces shrink the tile cell count so the
#: double-buffered ring (tile k on device, tile k+1 on the prep thread)
#: stays at ~2x this footprint regardless of ``n_stores``. 128 MB
#: measured fastest end-to-end at paper-scale store counts (the sweet
#: spot between per-tile dispatch overhead and cache-resident scans).
DEFAULT_TILE_BYTES = 128 << 20


def _default_tile_cells(n_stores: int) -> int:
    per_cell = max(1, 17 * n_stores)
    return int(min(DEFAULT_TILE_CELLS,
                   max(64, DEFAULT_TILE_BYTES // per_cell)))


#: Grid size at which ``simulate_grid(engine="auto")`` switches from the
#: one-shot blocked batch to the streaming sharded tier.
STREAM_THRESHOLD = 2048

#: Dispatched-but-undrained tile bound. Dispatch runs ahead of device
#: compute, so this -- together with the prep thread's one-tile
#: lookahead -- is what actually caps the engine's live memory at a few
#: tile footprints regardless of grid size.
MAX_IN_FLIGHT_TILES = 3


@dataclasses.dataclass(frozen=True)
class TileSignature:
    """Everything that selects a compiled tile program.

    Two tiles with equal signatures reuse one XLA executable: ``b_pad``
    is the canonical padded cell count, ``chunk`` the blocked-scan block
    length, ``sb_uniform`` the tile's (uniform, by scheduling) SB depth,
    ``sb_max`` its padded ring width, ``n_shards`` the ``cells`` mesh
    size. A whole mega-grid runs with a handful of distinct signatures.
    """
    b_pad: int
    n_stores: int
    chunk: int
    sb_max: int
    sb_uniform: int
    n_shards: int


@dataclasses.dataclass(frozen=True)
class Tile:
    """One scheduled slice of a grid: original positions + specs + sig."""
    indices: Tuple[int, ...]
    specs: Tuple[ScenarioSpec, ...]
    sig: TileSignature


def _align(n_shards: int) -> int:
    """Cell-count alignment: a multiple of 8 (batch padding contract of
    ``_stack_cells``) and of the shard count (shard_map divisibility)."""
    return 8 * n_shards // math.gcd(8, n_shards)


def _canonical_sizes(tile_cells: int, align: int) -> List[int]:
    """The canonical padded cell counts: the full tile and a 1/8 tile
    (rounded up to ``align``). Ragged last tiles pad UP to the smallest
    canonical size that fits, so at most two batch shapes -- and
    therefore compiled programs -- exist per SB signature of a
    mega-grid. The set is deliberately tiny: a compile costs ~50x more
    than scanning the padding cells it would avoid, so only genuinely
    small groups (<= tile/8 cells) get their own shape."""
    small = -(-max(1, tile_cells // 8) // align) * align
    return sorted({small, tile_cells})


def plan_tiles(specs: Sequence[ScenarioSpec],
               cluster: ClusterConfig = PAPER_CLUSTER,
               n_stores: int = 50_000,
               chunk_size: Optional[int] = None,
               tile_cells: int = DEFAULT_TILE_CELLS,
               n_shards: int = 1) -> List[Tile]:
    """Schedule a grid into canonically-shaped, SB-uniform tiles.

    Cells are grouped by resolved store-buffer depth (preserving order
    within a group -- results are scattered back to original positions
    by :func:`run_grid`), so every tile runs the tuple-history fast
    path with its chunk clamped only by its OWN depth, not the
    narrowest cell of the whole grid. Each group is cut into
    ``tile_cells``-sized tiles padded to canonical sizes.
    """
    align = _align(n_shards)
    tile_cells = max(align, -(-tile_cells // align) * align)
    sizes = _canonical_sizes(tile_cells, align)

    groups: Dict[int, List[Tuple[int, ScenarioSpec]]] = {}
    for i, s in enumerate(specs):
        sb = s.sb_size if s.sb_size is not None else cluster.store_buffer
        groups.setdefault(sb, []).append((i, s))

    tiles: List[Tile] = []
    for sb, members in groups.items():
        chunk = auto_chunk(n_stores, sb, tile_cells) if chunk_size is None \
            else max(1, min(chunk_size, n_stores, sb))
        for off in range(0, len(members), tile_cells):
            part = members[off:off + tile_cells]
            b_pad = next(c for c in sizes if c >= len(part))
            sig = TileSignature(b_pad=b_pad, n_stores=n_stores, chunk=chunk,
                                sb_max=_pad_len(sb), sb_uniform=sb,
                                n_shards=n_shards)
            tiles.append(Tile(indices=tuple(i for i, _ in part),
                              specs=tuple(s for _, s in part), sig=sig))
    return tiles


# ---------------------------------------------------------------------------
# Signature-keyed compile cache
# ---------------------------------------------------------------------------

_TILE_FNS: Dict[TileSignature, Callable] = {}
_TRACE_COUNT = 0


def trace_count() -> int:
    """Tile-program traces since import (monotone; compile-cache
    diagnostics -- tests assert it does NOT grow across same-signature
    tiles, benchmarks report the per-run delta)."""
    return _TRACE_COUNT


def _build_tile_fn(sig: TileSignature) -> Callable:
    def run(arrivals, coalesce, exposed, t_repl_i, svc_i,
            config_idx, sb_size, t_l1, t_wt):
        global _TRACE_COUNT
        _TRACE_COUNT += 1          # runs once per trace, not per call
        # tiles arrive cell-major (host stacking is then a row memcpy
        # per cell); the transpose to the scan's time-major layout is a
        # cheap local device op, fused ahead of the block reshapes
        return _timeline_batch_blocked(
            arrivals.T, coalesce.T, exposed.T, t_repl_i.T, svc_i.T,
            config_idx, sb_size, sig.sb_max, sig.chunk, sig.sb_uniform,
            t_l1, t_wt)

    if sig.n_shards > 1:
        # every op in the blocked scan is lane-wise over the cell axis,
        # so partitioning cells over the mesh needs no collectives and
        # cannot change a single lane's arithmetic
        run = shard_map(run, cells_mesh(sig.n_shards),
                        in_specs=tile_specs() + (P(), P()),
                        out_specs=(P("cells"),) * 3)
    return jax.jit(run)


def _tile_fn(sig: TileSignature) -> Callable:
    fn = _TILE_FNS.get(sig)
    if fn is None:
        fn = _TILE_FNS.setdefault(sig, _build_tile_fn(sig))
    return fn


@register_cache_clearer
def _clear_engine_caches() -> None:
    _TILE_FNS.clear()


# ---------------------------------------------------------------------------
# Double-buffered streaming executor
# ---------------------------------------------------------------------------

def _stack_tile(cells: List[_CellInputs], b_pad: int) -> tuple:
    """Stack one tile's cells **cell-major** ``(B, n_stores)``.

    Unlike the one-shot batch's time-major stacking (a strided scatter
    per cell), cell-major stacking is a contiguous row memcpy per cell;
    the device transposes to time-major inside the tile program, where
    it costs a fraction of the host scatter. Padding repeats cell 0.
    """
    padded = cells + [cells[0]] * (b_pad - len(cells))
    return (
        np.stack([c.arrivals for c in padded], axis=0),
        np.stack([c.coalesce for c in padded], axis=0),
        np.stack([c.exposed for c in padded], axis=0),
        np.stack([c.t_repl_i for c in padded], axis=0),
        np.stack([c.svc_i for c in padded], axis=0),
        np.asarray([c.config_idx for c in padded], np.int32),
        np.asarray([c.sb_size for c in padded], np.int32),
    )


def _prep_tile(tile: Tile, n_stores: int, cluster: ClusterConfig
               ) -> Tuple[List[_CellInputs], tuple]:
    """Host-side prep for one tile (runs on the prefetch thread)."""
    cells = [_prepare_cell(s, _trace_cached(s.workload, n_stores, s.seed,
                                            cluster), n_stores, cluster)
             for s in tile.specs]
    return cells, _stack_tile(cells, tile.sig.b_pad)


def _place_tile(np_args: tuple, sig: TileSignature) -> tuple:
    """Put one tile's host arrays on the mesh, cell axis sharded.

    All callers (the streaming loop AND the compile-warming thread) go
    through here so every call of a tile program sees identically
    committed/sharded inputs -- jit specializes on input shardings, so
    a mismatch would silently compile each program twice."""
    if sig.n_shards == 1:
        return np_args
    return jax.device_put(np_args, tile_shardings(cells_mesh(sig.n_shards)))


def _warm_signatures(sigs: List[TileSignature], t_l1, t_wt) -> None:
    """Compile every distinct tile program with zero inputs (runs on the
    compile thread, so XLA compilation -- which releases the GIL --
    overlaps the first tiles' host prep and device compute; jax's
    per-program lock keeps a racing main-thread call from compiling the
    same program twice).

    Warming MUST go through a real call: on the jax versions this repo
    targets (0.4.x), AOT ``jit(f).lower(shapes).compile()`` does not
    populate the jit call cache (measured -- the first real call pays
    the compile again), so shape-only warming would double every
    compile. The zeros are calloc'd and one discarded tile execution
    per signature (a handful per mega-grid) is the price of the
    overlap."""
    for sig in sigs:
        args = (np.zeros((sig.b_pad, sig.n_stores), np.float32),
                np.zeros((sig.b_pad, sig.n_stores), bool),
                np.zeros((sig.b_pad, sig.n_stores), np.float32),
                np.zeros((sig.b_pad, sig.n_stores), np.float32),
                np.zeros((sig.b_pad, sig.n_stores), np.float32),
                np.zeros((sig.b_pad,), np.int32),
                np.full((sig.b_pad,), sig.sb_uniform, np.int32))
        _tile_fn(sig)(*_place_tile(args, sig), t_l1, t_wt)


def run_grid(specs: Sequence[ScenarioSpec],
             cluster: ClusterConfig = PAPER_CLUSTER,
             n_stores: int = 50_000,
             chunk_size: Optional[int] = None,
             tile_cells: Optional[int] = None,
             n_shards: Optional[int] = None) -> List[SimResult]:
    """Stream a (mega-)grid through the sharded tile engine.

    Results come back in ``specs`` order, bit-identical to
    ``simulate_batch`` and the serial oracle. ``chunk_size=None`` uses
    the :func:`auto_chunk` heuristic per SB group; ``tile_cells``
    defaults to the :data:`DEFAULT_TILE_BYTES` budget (capped at
    :data:`DEFAULT_TILE_CELLS`); ``n_shards`` defaults to every local
    device (1 falls back to single-device streaming -- still tiled,
    cached and double-buffered).

    The loop overlaps three stages: the prefetch thread derives tile
    k+1's host arrays while tile k's arrays are placed cell-sharded on
    the mesh and its (asynchronously dispatched) scan runs. Dispatch
    runs ahead of the devices by at most :data:`MAX_IN_FLIGHT_TILES`
    tiles: past that the loop drains the oldest tile (blocking until
    its compute finishes and releasing its input buffers), which is
    what caps live memory at a few tile footprints however large the
    grid is.
    """
    if not specs:
        return []
    if chunk_size is not None and chunk_size < 1:
        raise ValueError(
            f"chunk_size must be >= 1 (or None for auto), got {chunk_size}")
    n_dev = len(jax.devices())
    if n_shards is None:
        # all local devices: even oversubscribed virtual CPU devices
        # measured faster than matching the physical core count (each
        # shard's scan body is single-threaded in XLA; more shards =
        # more concurrent executions for the host threadpool to fill)
        n_shards = n_dev
    if not 1 <= n_shards <= n_dev:
        raise ValueError(f"n_shards must be in [1, {n_dev}], got {n_shards}")
    for s in specs:
        s.validate(cluster)

    tiles = plan_tiles(specs, cluster=cluster, n_stores=n_stores,
                       chunk_size=chunk_size,
                       tile_cells=tile_cells or _default_tile_cells(n_stores),
                       n_shards=n_shards)
    costs = _commit_cost_ns("proactive", cluster)
    t_l1 = np.float32(costs["t_l1"])
    t_wt = np.float32(costs["t_wt"])

    results: List[Optional[SimResult]] = [None] * len(specs)

    def finish(entry) -> None:
        """Drain one dispatched tile: blocks until its device compute is
        done, releasing its input buffers, and scatters the per-cell
        results back to original grid positions."""
        tile, cells, (exec_ns, at_head, sb_full) = entry
        exec_ns = np.asarray(exec_ns)
        at_head = np.asarray(at_head)
        sb_full = np.asarray(sb_full)
        for j, (i, cell) in enumerate(zip(tile.indices, cells)):
            meta = {"engine": ("sharded" if tile.sig.n_shards > 1
                               else "streamed"),
                    "chunk": tile.sig.chunk, "auto_chunk": chunk_size is None,
                    "tile_cells": tile.sig.b_pad,
                    "n_shards": tile.sig.n_shards}
            results[i] = _finish_result(cell, exec_ns[j], int(at_head[j]),
                                        int(sb_full[j]), meta=meta)

    in_flight = []
    prep_pool = ThreadPoolExecutor(max_workers=1)
    compile_pool = ThreadPoolExecutor(max_workers=1)
    try:
        sigs = list(dict.fromkeys(t.sig for t in tiles))
        warm = compile_pool.submit(_warm_signatures, sigs, t_l1, t_wt)
        fut = prep_pool.submit(_prep_tile, tiles[0], n_stores, cluster)
        for k, tile in enumerate(tiles):
            cells, np_args = fut.result()
            if k + 1 < len(tiles):
                fut = prep_pool.submit(_prep_tile, tiles[k + 1], n_stores,
                                       cluster)
            out = _tile_fn(tile.sig)(*_place_tile(np_args, tile.sig),
                                     t_l1, t_wt)
            in_flight.append((tile, cells, out))
            # backpressure: dispatch runs ahead of the devices, so
            # without a bound every dispatched tile's input buffers
            # stay alive at once; draining the oldest keeps at most
            # MAX_IN_FLIGHT_TILES tiles of device memory pinned while
            # still overlapping prep/compute/drain
            if len(in_flight) >= MAX_IN_FLIGHT_TILES:
                finish(in_flight.pop(0))
        warm.result()      # surface compile-thread exceptions
    finally:
        prep_pool.shutdown(wait=True)
        compile_pool.shutdown(wait=True)

    for entry in in_flight:
        finish(entry)
    return results


# ---------------------------------------------------------------------------
# Tier selection
# ---------------------------------------------------------------------------

def simulate_grid(specs: Sequence[ScenarioSpec],
                  cluster: ClusterConfig = PAPER_CLUSTER,
                  n_stores: int = 50_000,
                  engine: str = "auto",
                  chunk_size: Optional[int] = None,
                  tile_cells: Optional[int] = None,
                  n_shards: Optional[int] = None) -> List[SimResult]:
    """Run a scenario grid on the right engine tier.

    ``engine``:

    * ``"auto"`` (default) -- blocked one-shot batch below
      :data:`STREAM_THRESHOLD` cells, streaming sharded tier at or
      above it;
    * ``"serial"`` -- the per-cell oracle loop (differential testing);
    * ``"perstep"`` -- the PR-1 per-step batched scan;
    * ``"blocked"`` -- one-shot blocked batch (``simulate_batch``);
    * ``"stream"`` -- the tiled sharded/streaming engine
      (:func:`run_grid`).

    All tiers return bit-identical results in ``specs`` order;
    ``SimResult.meta['engine']`` records what actually ran.
    """
    if engine == "auto":
        engine = "stream" if len(specs) >= STREAM_THRESHOLD else "blocked"
    if engine == "serial":
        for s in specs:
            s.validate(cluster)
        return [simulate(s.workload, s.config, cluster=cluster,
                         n_stores=n_stores, seed=s.seed,
                         n_replicas=s.n_replicas,
                         link_bw_gbps=s.link_bw_gbps, n_cns=s.n_cns,
                         sb_size=s.sb_size, coalescing=s.coalescing)
                for s in specs]
    if engine == "perstep":
        return simulate_batch(specs, cluster=cluster, n_stores=n_stores,
                              chunk_size=0)
    if engine == "blocked":
        return simulate_batch(specs, cluster=cluster, n_stores=n_stores,
                              chunk_size=chunk_size)
    if engine == "stream":
        return run_grid(specs, cluster=cluster, n_stores=n_stores,
                        chunk_size=chunk_size, tile_cells=tile_cells,
                        n_shards=n_shards)
    raise ValueError(f"unknown engine {engine!r}")
