"""Sharded streaming mega-grid engine (the tier above ``simulate_batch``).

``simulate_batch`` runs a whole grid as ONE blocked-scan call: perfect
up to a few thousand cells, but a mega-grid (>10^4 cells -- the full
(workload x config x N_r x bw x CN x SB) sensitivity space of Figs.
10/16-18, times seeds) hits three walls:

* **one device** -- the time-major ``(n_stores, B)`` layout makes the
  cell axis embarrassingly parallel, yet the whole batch scans on a
  single device;
* **one giant allocation + one compile per batch shape** -- every grid
  size stacks fresh ``(n_stores, B)`` arrays and jits a program for
  that exact ``B``;
* **serialized host prep** -- trace synthesis / per-cell cost
  derivation for the *whole* grid completes before the first scan step
  runs.

This module is the streaming tier that removes all three:

1. **Tile scheduler** (:func:`plan_tiles`). The grid is split into
   tiles of at most :data:`DEFAULT_TILE_CELLS` cells, grouped by
   store-buffer depth first, so every tile is SB-uniform and runs the
   tuple-history fast path of the blocked scan -- a mixed-SB mega-grid
   never falls back to the gather path the way a one-shot batch must.
   Every tile is padded to a small set of canonical cell counts
   (:func:`_canonical_sizes`), so an entire mega-grid executes with a
   handful of compiled programs (:class:`TileSignature` ->
   :func:`_tile_fn` cache), not one compile per ragged tail.

2. **``shard_map`` over a ``cells`` mesh axis.** Each tile's arrays are
   ``device_put`` with the cell axis sharded over all local devices
   (``repro.distributed.context.cells_mesh`` /
   ``repro.distributed.sharding.tile_shardings``) and the blocked scan
   runs per shard with ZERO cross-device communication -- cells are
   independent timelines, sharding is a pure partition. Elementwise
   lane arithmetic is unchanged, so results stay bit-identical to the
   single-device path and the serial oracle (tests/test_engine.py
   asserts ``==``).

3. **Double-buffered streaming.** A single worker thread prepares tile
   k+1 while the devices compute tile k; dispatch is async and runs
   ahead of the devices by at most :data:`MAX_IN_FLIGHT_TILES` tiles
   before the oldest is drained, bounding live memory. Host prep cost
   is further collapsed by the reduced-key ``_cell_arrays`` memo
   (cells differing only in config class / SB / CN share one
   derivation), and everything is dropped by
   ``repro.core.simulator.clear_sim_caches()`` -- including this
   module's compiled-tile cache, registered via
   ``register_cache_clearer``.

4. **The columnar bank data plane** (``data_plane="bank"``, the
   default). Host prep materializes each unique trace / max-plus
   column exactly once in a :class:`~repro.core.simulator.TraceBank`,
   uploads it ONCE per mega-grid as a device-resident bank (columns
   replicated across the ``cells`` mesh -- any shard's cells may
   gather any row, and a replicated bank keeps the gather local and
   communication-free), and tiles carry only two ``int32`` row-index
   vectors. The tile program gathers its columns *inside* the jitted /
   ``shard_map``'d kernel -- through the fused Pallas kernel
   (``repro.kernels.bank_scan``) on TPU, through an XLA gather
   everywhere else -- so H2D bytes and host stacking scale with
   ``unique_rows`` instead of ``cells``. And because a timeline
   consumes nothing but (arrivals row, max-plus row, SB depth), cells
   sharing that triple are one **scan lane**: the engine scans each
   unique lane once and scatters the outputs to member cells, so
   device compute too scales with unique lanes (the 12 960-cell
   mega-grid scans ~2 700). ``data_plane="stacked"``
   keeps the PR-3 plane (full per-cell copies, ``_stack_tile``) as the
   measured baseline; both planes are bit-identical.
   :func:`bank_stats` reports the last run's data-plane accounting
   (H2D bytes, bank rows, dedup ratio, device-memory high-water mark).

:func:`simulate_grid` is the tier selector: grids below
:data:`STREAM_THRESHOLD` cells go to the blocked one-shot batch, larger
grids stream; ``engine=`` forces a tier. ``SimResult.meta`` records
which tier ran, the chunk used, the tile/shard geometry and the data
plane.

Two notes on axes and threads that this module gets for free:

* **Coupled axes ride the plane keys.** Lane and bank-row dedup both
  key on ``simulator._plane_keys``, which already appends the resolved
  ``ContentionParams`` / ``DirectoryParams`` tails for coupled cells
  (the two-level directory recurrence is folded into the wv row on the
  host, before the bank ever sees it). The engine therefore needs no
  knowledge of either axis: coupled cells that share a (shard,
  epoch-profile) still collapse to one scan lane, and axis-off grids
  produce byte-identical keys -- and rows -- to the legacy plane.
* **Memo caches are shared with worker threads.** The prefetch and
  compile-warm executors mutate the same :class:`BoundedCache` memos
  (`_cell_arrays`, trace synthesis, compiled tiles) as the caller;
  ``hostcache.BoundedCache`` serializes per-cache, so each key is
  built exactly once even when a warm thread and the dispatch loop
  race on it.
"""

from __future__ import annotations

import dataclasses
import math
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import wait as _futures_wait
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.recxl_paper import ClusterConfig, PAPER_CLUSTER
from repro.core import chaos as _chaos
from repro.core import telemetry as _tm
from repro.core.chaos import (
    ChaosError,
    IntegrityError,
    ShardLossError,
    ThreadDeathError,
    UploadError,
)
from repro.core.retry import PLACEMENT_RETRY, retry_call
from repro.core.simulator import (
    ScenarioSpec,
    SimResult,
    TraceBank,
    _bank_gather,
    _CellInputs,
    _commit_cost_ns,
    _finish_result,
    _pad_len,
    _prepare_cell,
    _scan_wv,
    _timeline_batch_blocked,
    _trace_cached,
    auto_chunk,
    get_trace_bank,
    register_cache_clearer,
    simulate_batch,
    simulate_spec,
    sub_bank_rows,
)
from repro.distributed.context import cells_mesh, shard_map
from repro.distributed.sharding import (
    bank_shardings,
    bank_tile_specs,
    index_shardings,
    sub_bank_shardings,
    sub_bank_tile_specs,
    tile_shardings,
    tile_specs,
)
from repro.kernels.bank_scan import bank_scan, bank_scan_backend

#: Cells per tile (before canonical padding) at the default byte
#: budget. Large enough that one scan amortizes dispatch overhead,
#: small enough that a tile's five (B_tile, n_stores) arrays stream
#: through cache instead of RAM.
DEFAULT_TILE_CELLS = 1024

#: Byte budget for one tile's five per-store input arrays (~4+1+4+4+4
#: bytes per cell-store). Long traces shrink the tile cell count so the
#: double-buffered ring (tile k on device, tile k+1 on the prep thread)
#: stays at ~2x this footprint regardless of ``n_stores``. 128 MB
#: measured fastest end-to-end at paper-scale store counts (the sweet
#: spot between per-tile dispatch overhead and cache-resident scans).
DEFAULT_TILE_BYTES = 128 << 20


def _default_tile_cells(n_stores: int) -> int:
    per_cell = max(1, 17 * n_stores)
    return int(min(DEFAULT_TILE_CELLS,
                   max(64, DEFAULT_TILE_BYTES // per_cell)))


#: Grid size at which ``simulate_grid(engine="auto")`` switches from the
#: one-shot blocked batch to the streaming sharded tier.
STREAM_THRESHOLD = 2048

#: Dispatched-but-undrained tile bound. Dispatch runs ahead of device
#: compute, so this -- together with the prep thread's one-tile
#: lookahead -- is what actually caps the engine's live memory at a few
#: tile footprints regardless of grid size.
MAX_IN_FLIGHT_TILES = 3

#: Spare-replacement recovery attempts per :func:`run_grid` call before
#: the fault propagates (a second independent failure mid-recovery is
#: out of the modeled scope -- bounded like every retry here).
MAX_RECOVERIES = 3

#: Gather-path integrity sampling cap: at most this many of a tile's
#: wv rows are CRC-checked against the host bank before dispatch (only
#: under an active chaos scope that wants verification -- see
#: ``chaos.ChaosConfig.verify_rows``; the production path never reads
#: rows back).
VERIFY_ROWS_PER_TILE = 16


class EngineWorkerError(RuntimeError):
    """A streaming-engine worker thread (prefetch / compile-warm)
    failed or stalled.  Carries the tile / signature context so the
    caller sees *which* unit of work died instead of a bare exception
    surfacing tiles later (or, for a stalled worker, never)."""

    def __init__(self, stage: str, tile_no: Optional[int],
                 sig: Optional[TileSignature] = None, note: str = ""):
        msg = f"{stage} worker failed"
        if tile_no is not None:
            msg += f" on tile {tile_no}"
        if sig is not None:
            msg += (f" (sig: b_pad={sig.b_pad} sb={sig.sb_uniform}"
                    f" chunk={sig.chunk} plane={sig.data_plane})")
        if note:
            msg += f": {note}"
        super().__init__(msg)
        self.stage = stage
        self.tile_no = tile_no
        self.sig = sig


_HEARTBEATS: Dict[str, float] = {}


def worker_heartbeats() -> Dict[str, float]:
    """``time.monotonic()`` of each engine worker thread's last unit of
    work (``"prefetch"`` / ``"compile-warm"``) -- the liveness signal
    ``run_grid(worker_timeout_s=...)`` and external watchdogs check a
    stalled worker against."""
    return dict(_HEARTBEATS)


def _h2d_hook(nbytes: int = 0) -> None:
    """Chaos injection point for one host->device placement (no-op
    without an active scope)."""
    st = _chaos.active()
    if st is not None:
        st.on_upload(nbytes)


def _retried(fn: Callable[[], object], describe: str):
    """Bounded jittered retry around a placement/dispatch callable:
    only transient :class:`~repro.core.chaos.UploadError` is retried --
    shard loss and integrity faults must reach the recovery path."""
    st = _chaos.active()
    return retry_call(fn, policy=PLACEMENT_RETRY, retryable=(UploadError,),
                      describe=describe,
                      on_retry=st.note_retry if st is not None else None)


@dataclasses.dataclass(frozen=True)
class TileSignature:
    """Everything that selects a compiled tile program.

    Two tiles with equal signatures reuse one XLA executable: ``b_pad``
    is the canonical padded cell count, ``chunk`` the blocked-scan block
    length, ``sb_uniform`` the tile's (uniform, by scheduling) SB depth,
    ``sb_max`` its padded ring width, ``n_shards`` the ``cells`` mesh
    size, ``data_plane`` which input plane the program consumes, and
    ``bank_shape`` the ``(trace_rows, wv_rows)`` of the grid's bank
    (``(0, 0)`` on the stacked plane) -- jit specializes on the bank's
    shape, so it is part of the program key. ``bank_sub=True`` selects
    the per-shard sub-bank layout (the default banked plane): the three
    max-plus columns arrive as a ``(n_shards, local_rows, n_stores)``
    shard-partitioned stack, wv indices are shard-LOCAL, and
    ``bank_shape[1]`` is the local (per-shard) row count. A whole
    mega-grid runs with a handful of distinct signatures.
    """
    b_pad: int
    n_stores: int
    chunk: int
    sb_max: int
    sb_uniform: int
    n_shards: int
    data_plane: str = "stacked"
    bank_shape: Tuple[int, int] = (0, 0)
    bank_sub: bool = False


@dataclasses.dataclass(frozen=True)
class Tile:
    """One scheduled slice of a grid: original positions + specs + sig.

    ``slots`` (sub-bank scheduling only) maps entry ``j`` of
    ``indices``/``specs`` to its padded position in the tile's index
    vectors and outputs: the vector is laid out as ``n_shards``
    contiguous blocks of ``b_pad // n_shards`` slots, and lane ``j``
    sits inside the block of the shard that OWNS its wv row, so the
    in-jit gather under ``shard_map`` stays shard-local. ``None`` means
    the identity layout (entry ``j`` at position ``j``), as on the
    stacked and replicated-bank planes."""
    indices: Tuple[int, ...]
    specs: Tuple[ScenarioSpec, ...]
    sig: TileSignature
    slots: Optional[Tuple[int, ...]] = None


def _align(n_shards: int) -> int:
    """Cell-count alignment: a multiple of 8 (batch padding contract of
    ``_stack_cells``) and of the shard count (shard_map divisibility)."""
    return 8 * n_shards // math.gcd(8, n_shards)


def _canonical_sizes(tile_cells: int, align: int) -> List[int]:
    """The canonical padded cell counts: the full tile and a 1/8 tile
    (rounded up to ``align``). Ragged last tiles pad UP to the smallest
    canonical size that fits, so at most two batch shapes -- and
    therefore compiled programs -- exist per SB signature of a
    mega-grid. The set is deliberately tiny: a compile costs ~50x more
    than scanning the padding cells it would avoid, so only genuinely
    small groups (<= tile/8 cells) get their own shape."""
    small = -(-max(1, tile_cells // 8) // align) * align
    return sorted({small, tile_cells})


def plan_tiles(specs: Sequence[ScenarioSpec],
               cluster: ClusterConfig = PAPER_CLUSTER,
               n_stores: int = 50_000,
               chunk_size: Optional[int] = None,
               tile_cells: int = DEFAULT_TILE_CELLS,
               n_shards: int = 1,
               small_pad: bool = True,
               owners: Optional[Sequence[int]] = None) -> List[Tile]:
    """Schedule a grid into canonically-shaped, SB-uniform tiles.

    Cells are grouped by resolved store-buffer depth (preserving order
    within a group -- results are scattered back to original positions
    by :func:`run_grid`), so every tile runs the tuple-history fast
    path with its chunk clamped only by its OWN depth, not the
    narrowest cell of the whole grid. Each group is cut into
    ``tile_cells``-sized tiles padded to canonical sizes.
    ``small_pad=False`` drops the 1/8-tile canonical size, so every
    tile pads to the FULL tile: one compiled program per SB group --
    the banked plane uses this, because its deduplicated scan lanes
    leave few tiles per group and a ragged tail's own program costs
    ~50x the padding lanes it would avoid.

    ``owners`` (sub-bank scheduling) gives each cell's owning shard
    (``wv_row % n_shards``, aligned with ``specs``): each tile's index
    vector is then laid out as ``n_shards`` blocks of ``b_pad //
    n_shards`` slots (``_align`` guarantees divisibility) and every
    lane lands in its owner's block, recorded in :attr:`Tile.slots` --
    the layout under which a ``shard_map`` over the ``cells`` axis
    hands each shard exactly the lanes whose wv rows it holds. Tiles
    per group become ``ceil(max_per_shard_lanes / block)`` instead of
    ``ceil(lanes / tile_cells)``; round-robin row ownership keeps the
    shard blocks balanced to within one lane on real grids.
    """
    align = _align(n_shards)
    tile_cells = max(align, -(-tile_cells // align) * align)
    sizes = _canonical_sizes(tile_cells, align) if small_pad \
        else [tile_cells]

    groups: Dict[int, List[Tuple[int, ScenarioSpec]]] = {}
    for i, s in enumerate(specs):
        sb = s.sb_size if s.sb_size is not None else cluster.store_buffer
        groups.setdefault(sb, []).append((i, s))

    tiles: List[Tile] = []
    for sb, members in groups.items():
        chunk = auto_chunk(n_stores, sb, tile_cells) if chunk_size is None \
            else max(1, min(chunk_size, n_stores, sb))

        def sig_for(b_pad: int) -> TileSignature:
            return TileSignature(b_pad=b_pad, n_stores=n_stores, chunk=chunk,
                                 sb_max=_pad_len(sb), sb_uniform=sb,
                                 n_shards=n_shards)

        if owners is not None and n_shards > 1:
            by_shard: List[List[Tuple[int, ScenarioSpec]]] = \
                [[] for _ in range(n_shards)]
            for i, s in members:
                by_shard[owners[i]].append((i, s))
            block = tile_cells // n_shards
            n_tiles = max(1, -(-max(len(b) for b in by_shard) // block))
            for t in range(n_tiles):
                part: List[Tuple[int, ScenarioSpec]] = []
                blocks = [b[t * block:(t + 1) * block] for b in by_shard]
                widest = max(len(b) for b in blocks)
                b_pad = next(c for c in sizes if c // n_shards >= widest)
                per = b_pad // n_shards
                slots: List[int] = []
                for sh, blk in enumerate(blocks):
                    for q, (i, s) in enumerate(blk):
                        part.append((i, s))
                        slots.append(sh * per + q)
                tiles.append(Tile(indices=tuple(i for i, _ in part),
                                  specs=tuple(s for _, s in part),
                                  sig=sig_for(b_pad), slots=tuple(slots)))
            continue
        for off in range(0, len(members), tile_cells):
            part = members[off:off + tile_cells]
            b_pad = next(c for c in sizes if c >= len(part))
            tiles.append(Tile(indices=tuple(i for i, _ in part),
                              specs=tuple(s for _, s in part),
                              sig=sig_for(b_pad)))
    return tiles


# ---------------------------------------------------------------------------
# Signature-keyed compile cache
# ---------------------------------------------------------------------------

_TILE_FNS: Dict[TileSignature, Callable] = {}
_TRACE_COUNT = 0


def trace_count() -> int:
    """Tile-program traces since import (monotone; compile-cache
    diagnostics -- tests assert it does NOT grow across same-signature
    tiles, benchmarks report the per-run delta)."""
    return _TRACE_COUNT


_BANK_STATS: Dict[str, object] = {}


def bank_stats() -> Dict[str, object]:
    """Data-plane accounting of the most recent :func:`run_grid` call
    (``trace_count()``-style observability; benchmarks turn it into the
    ``fig10/megagrid/*`` data-plane rows). Keys:

    * ``data_plane`` -- ``"bank"`` or ``"stacked"``; ``cells`` /
      ``n_shards`` -- run geometry; ``scan_lanes`` -- unique timelines
      actually scanned (== ``cells`` on the stacked plane);
    * ``bank_partition`` -- ``"sub"`` (per-shard sub-banks, the
      default) or ``"replicated"`` on the bank plane, ``None`` on the
      stacked plane;
    * ``trace_rows`` / ``wv_rows`` / ``bank_rows`` -- deduplicated bank
      columns (0 on the stacked plane); ``bank_bytes`` -- host bytes of
      one bank copy; ``bank_dev_bytes_per_shard`` / ``bank_dev_bytes``
      -- **measured** resident device bytes of the placed bank (summed
      from the live buffers' addressable shards: max per device, and
      fleet total). Replicated placement measures ~``bank x n_shards``
      total; the sub-bank placement holds one copy of each max-plus
      row fleet-wide (arrivals stay replicated -- they are ~1% of the
      bytes and a lane's trace/wv rows may have different owners), so
      the total stays ~``bank_bytes`` and per-shard drops to
      ~``1/n_shards``;
    * ``h2d_bytes`` -- bytes that actually crossed host->device this
      run (one bank upload iff it was not already device-resident,
      plus every tile's payload); ``bank_fabric_bytes`` -- the
      device-to-device bytes of replicating staged arrays to the other
      shards (NOT host bandwidth; the whole bank under the replicated
      placement, only the arrivals column under sub-banks);
      ``stacked_h2d_bytes`` -- what the stacked plane would have
      shipped host->device for the same grid; ``dedup_ratio`` -- their
      ratio (>= 1; 1.0 on the stacked plane);
    * ``dev_mem_hwm_bytes`` -- engine-accounted device-memory
      high-water mark: the measured resident bank bytes plus the
      in-flight tiles' input payloads at their peak.

    Empty until the first ``run_grid`` of the process."""
    return dict(_BANK_STATS)


def _build_tile_fn(sig: TileSignature) -> Callable:
    if sig.data_plane == "bank":
        return _build_bank_tile_fn(sig)

    def run(arrivals, coalesce, exposed, t_repl_i, svc_i,
            config_idx, sb_size, t_l1, t_wt):
        global _TRACE_COUNT
        _TRACE_COUNT += 1          # runs once per trace, not per call
        # tiles arrive cell-major (host stacking is then a row memcpy
        # per cell); the transpose to the scan's time-major layout is a
        # cheap local device op, fused ahead of the block reshapes
        return _timeline_batch_blocked(
            arrivals.T, coalesce.T, exposed.T, t_repl_i.T, svc_i.T,
            config_idx, sb_size, sig.sb_max, sig.chunk, sig.sb_uniform,
            t_l1, t_wt)

    if sig.n_shards > 1:
        # every op in the blocked scan is lane-wise over the cell axis,
        # so partitioning cells over the mesh needs no collectives and
        # cannot change a single lane's arithmetic
        run = shard_map(run, cells_mesh(sig.n_shards),
                        in_specs=tile_specs() + (P(), P()),
                        out_specs=(P("cells"),) * 3)
    return jax.jit(run)


def _build_bank_tile_fn(sig: TileSignature) -> Callable:
    """Banked tile program: in-kernel gather from the device-resident
    bank columns, then the blocked scan -- fused into one Pallas kernel
    on TPU, an XLA gather + the shared ``_scan_wv`` core elsewhere.
    Tiles ship only the two ``int32`` row-index vectors.

    ``sig.bank_sub`` selects the per-shard sub-bank layout: the three
    max-plus planes arrive stacked ``(n_shards, local_rows, n_stores)``
    with the shard axis partitioned over the ``cells`` mesh, so under
    ``shard_map`` each shard's view is ``(1, local_rows, n_stores)``
    and ``[0]`` IS its local sub-bank -- the gather (wv indices are
    pre-remapped to local rows, and the scheduler put every lane in its
    owner's slot block) runs against shard-resident rows with zero
    cross-shard communication, through the SAME kernel as the
    replicated layout. Gathering a local row moves the identical bits
    the global gather would, so the planes stay ``==``."""
    fused = bank_scan_backend() == "pallas"

    def run(a_bank, w_bank, v_bank, p_bank, trace_idx, wv_idx):
        global _TRACE_COUNT
        _TRACE_COUNT += 1          # runs once per trace, not per call
        if sig.bank_sub:
            # per-shard view of the shard-partitioned stacks (a no-op
            # reshape on device: axis 0 is size 1 inside shard_map, and
            # the full local plane at n_shards=1)
            w_bank, v_bank, p_bank = w_bank[0], v_bank[0], p_bank[0]
        if fused:
            # gathered rows stream HBM->VMEM inside the kernel; no
            # stacked (B, n_stores) intermediate ever exists in HBM
            return bank_scan(a_bank, w_bank, v_bank, p_bank,
                             trace_idx, wv_idx,
                             chunk=sig.chunk, sb=sig.sb_uniform,
                             force="pallas")
        # the shared gather (one row memcpy per cell + the same cheap
        # device transpose as the stacked plane) -- and NO per-tile
        # precompute: w/v were collapsed on the host, once per unique
        # row
        a, w, v, p = _bank_gather(a_bank, w_bank, v_bank, p_bank,
                                  trace_idx, wv_idx)
        return _scan_wv(a, w, v, p, None, sig.sb_max, sig.chunk,
                        sig.sb_uniform)

    if sig.n_shards > 1:
        # replicated: banks replicated (gathers stay local), indices
        # cell-sharded. sub: max-plus stacks shard-partitioned, every
        # lane scheduled onto its owner shard -- either way zero
        # cross-device communication
        run = shard_map(run, cells_mesh(sig.n_shards),
                        in_specs=(sub_bank_tile_specs() if sig.bank_sub
                                  else bank_tile_specs()),
                        out_specs=(P("cells"),) * 3)
    return jax.jit(run)


def _tile_fn(sig: TileSignature) -> Callable:
    fn = _TILE_FNS.get(sig)
    if fn is None:
        fn = _TILE_FNS.setdefault(sig, _build_tile_fn(sig))
    return fn


#: Public alias of the signature-keyed tile-program cache lookup. The
#: scenario-serving daemon (``repro.core.serving``) batches queries
#: into the SAME canonical tile shapes as the streaming engine and
#: calls the programs through this entry, so steady-state serving adds
#: zero compiles beyond the signatures :func:`warm_signatures` warmed
#: (``trace_count()`` counts serve-path traces too -- tests pin it).
tile_fn = _tile_fn


@register_cache_clearer
def _clear_engine_caches() -> None:
    _TILE_FNS.clear()


# ---------------------------------------------------------------------------
# Double-buffered streaming executor
# ---------------------------------------------------------------------------

def _stack_tile(cells: List[_CellInputs], b_pad: int) -> tuple:
    """Stack one tile's cells **cell-major** ``(B, n_stores)``.

    Unlike the one-shot batch's time-major stacking (a strided scatter
    per cell), cell-major stacking is a contiguous row memcpy per cell;
    the device transposes to time-major inside the tile program, where
    it costs a fraction of the host scatter. Padding repeats cell 0.
    """
    padded = cells + [cells[0]] * (b_pad - len(cells))
    return (
        np.stack([c.arrivals for c in padded], axis=0),
        np.stack([c.coalesce for c in padded], axis=0),
        np.stack([c.exposed for c in padded], axis=0),
        np.stack([c.t_repl_i for c in padded], axis=0),
        np.stack([c.svc_i for c in padded], axis=0),
        np.asarray([c.config_idx for c in padded], np.int32),
        np.asarray([c.sb_size for c in padded], np.int32),
    )


def _prep_tile(tile: Tile, n_stores: int, cluster: ClusterConfig
               ) -> Tuple[List[_CellInputs], tuple]:
    """Host-side prep for one stacked-plane tile (runs on the prefetch
    thread): ``_prepare_cell`` per cell + the PR-3 cell-major array
    stacking. The banked plane's prep lives in :func:`run_grid` (it
    needs the lane->cells map) and ships only index vectors."""
    cells = [_prepare_cell(s, _trace_cached(s.workload, n_stores, s.seed,
                                            cluster), n_stores, cluster)
             for s in tile.specs]
    return cells, _stack_tile(cells, tile.sig.b_pad)


def _place_tile(np_args: tuple, sig: TileSignature) -> tuple:
    """Put one tile's per-tile host arrays on the mesh, cell axis
    sharded (index vectors on the banked plane, the five stacked arrays
    plus per-cell vectors on the stacked plane).

    All callers (the streaming loop AND the compile-warming thread) go
    through here so every call of a tile program sees identically
    committed/sharded inputs -- jit specializes on input shardings, so
    a mismatch would silently compile each program twice."""
    if sig.n_shards == 1:
        return np_args
    mesh = cells_mesh(sig.n_shards)
    shardings = index_shardings(mesh) if sig.data_plane == "bank" \
        else tile_shardings(mesh)
    return jax.device_put(np_args, shardings)


def _place_bank(bank: TraceBank, n_shards: int) -> Tuple[int, tuple]:
    """Device-resident bank columns for one mesh size: replicated over
    the ``cells`` mesh (gathers stay shard-local), plain committed
    arrays on a single device. Memoized on the bank -- one upload per
    (bank, mesh), shared by every tile and engine that sweeps the grid.

    Replication is staged: the host arrays cross to device 0 ONCE (the
    only host->device transfer -- what ``h2d_bytes`` counts), and the
    other shards' copies are made from that committed buffer, i.e.
    device-fabric traffic (``bank_stats()['bank_fabric_bytes']``), not
    host bandwidth. Returns ``(bytes_uploaded_now, device_arrays)``."""
    if n_shards == 1:
        def place1(host: tuple) -> tuple:
            # same commitment as the memo's default path -- the hook is
            # the only addition, so shardings (and jit keys) match PR-8
            _h2d_hook(sum(int(x.nbytes) for x in host))
            return tuple(jax.numpy.asarray(x) for x in host)
        return bank.device_args(1, place1)
    mesh = cells_mesh(n_shards)

    def place(host: tuple) -> tuple:
        _h2d_hook(sum(int(x.nbytes) for x in host))
        staged = jax.device_put(host, jax.devices()[0])   # host -> dev0
        return jax.device_put(staged, bank_shardings(mesh))  # dev -> dev

    return bank.device_args(("cells", n_shards), place)


def _place_sub_bank(bank: TraceBank, n_shards: int,
                    k_replicas: int = 1) -> Tuple[int, tuple]:
    """Device-resident PER-SHARD sub-bank (``bank_partition="sub"``,
    the default): arrivals replicated as in :func:`_place_bank` (tiny
    -- ~1% of the bank's bytes -- and a lane's trace row may be owned
    by a different shard than its wv row), the three max-plus planes
    shard-partitioned via ``TraceBank.sub_bank_host`` -- ONE copy of
    each wv row fleet-wide, so resident device bytes drop to
    ~``1/n_shards`` of the replicated layout. The sub stacks
    ``device_put`` straight to their sharded layout (each device
    receives only its slice: host->device bytes stay at bank scale,
    no fabric replication); only the arrivals staging replicates.
    Memoized on the bank like :func:`_place_bank`.

    ``k_replicas > 1`` (chaos/recovery runs only) places the
    :meth:`TraceBank.sub_bank_host` Replica-set layout: each shard's
    stack carries ``k`` local-row blocks, block ``j`` holding the rows
    owned by shard ``(s - j) % n_shards`` -- single-shard loss then
    never loses a row (``chaos.replica_rebuild``). Gathers still target
    block 0, so the compiled programs only see the wider local axis."""
    if n_shards == 1:
        def place1(host: tuple) -> tuple:
            # same commitment as the memo's default path -- the hook is
            # the only addition, so shardings (and jit keys) match PR-8
            _h2d_hook(sum(int(x.nbytes) for x in host))
            return tuple(jax.numpy.asarray(x) for x in host)
        return bank.sub_device_args(1, place1, k_replicas)
    mesh = cells_mesh(n_shards)

    def place(host: tuple) -> tuple:
        _h2d_hook(sum(int(x.nbytes) for x in host))
        a = jax.device_put(host[0], jax.devices()[0])     # host -> dev0
        a = jax.device_put(a, bank_shardings(mesh)[0])    # dev -> dev
        subs = jax.device_put(tuple(host[1:]), sub_bank_shardings(mesh))
        return (a,) + tuple(subs)

    return bank.sub_device_args(n_shards, place, k_replicas)


def _measured_device_bytes(arrays: Sequence[jax.Array]) -> Tuple[int, int]:
    """Resident device bytes of ``arrays``, MEASURED from the live
    buffers: ``(total_bytes, max_bytes_on_one_device)`` summed over
    every array's addressable shards. A replicated array contributes
    one full copy per device, a shard-partitioned one only its slices
    -- so this reports what the placement actually holds, not an
    analytic ``bank x n_shards`` model (``bank_stats()`` satellite of
    the sub-bank PR; the old product over-reported sub placements
    n_shards-fold)."""
    per_dev: Dict[object, int] = {}
    for arr in arrays:
        for sh in arr.addressable_shards:
            dev = sh.device
            per_dev[dev] = per_dev.get(dev, 0) + int(sh.data.nbytes)
    if not per_dev:
        return 0, 0
    return sum(per_dev.values()), max(per_dev.values())


def warm_signatures(sigs: List[TileSignature], t_l1, t_wt,
                    bank_dev: Optional[tuple] = None) -> None:
    """Compile every distinct tile program with zero inputs (runs on the
    compile thread, so XLA compilation -- which releases the GIL --
    overlaps the first tiles' host prep and device compute; jax's
    per-program lock keeps a racing main-thread call from compiling the
    same program twice). Public: the scenario-serving daemon's warm
    pool calls it at startup against its own device-resident bank, so
    the first live query never pays a compile.

    Warming MUST go through a real call: on the jax versions this repo
    targets (0.4.x), AOT ``jit(f).lower(shapes).compile()`` does not
    populate the jit call cache (measured -- the first real call pays
    the compile again), so shape-only warming would double every
    compile. Banked programs warm against the REAL device-resident
    bank (placed on the main thread before this runs -- a zero bank of
    the right shape would hit the same program but duplicating the
    replicated placement measured slower than the compile it hides)
    with zero index vectors: row 0 is a valid gather everywhere, and
    the warm call sees exactly the shardings of the streaming loop's
    calls."""
    for sig in sigs:
        if sig.data_plane == "bank":
            idx = (np.zeros((sig.b_pad,), np.int32),
                   np.zeros((sig.b_pad,), np.int32))
            _tile_fn(sig)(*bank_dev, *_place_tile(idx, sig))
            continue
        args = (np.zeros((sig.b_pad, sig.n_stores), np.float32),
                np.zeros((sig.b_pad, sig.n_stores), bool),
                np.zeros((sig.b_pad, sig.n_stores), np.float32),
                np.zeros((sig.b_pad, sig.n_stores), np.float32),
                np.zeros((sig.b_pad, sig.n_stores), np.float32),
                np.zeros((sig.b_pad,), np.int32),
                np.full((sig.b_pad,), sig.sb_uniform, np.int32))
        _tile_fn(sig)(*_place_tile(args, sig), t_l1, t_wt)


_warm_signatures = warm_signatures        # internal alias (streaming loop)


def _stacked_tile_bytes(sig: TileSignature) -> int:
    """Host bytes of one stacked tile's payload (5 per-store arrays at
    ~17 B per cell-store + the two per-cell i32 vectors)."""
    return sig.b_pad * (17 * sig.n_stores + 8)


def _stacked_plane_h2d(specs: Sequence[ScenarioSpec],
                       cluster: ClusterConfig, n_stores: int,
                       tile_cells: int, n_shards: int) -> int:
    """Bytes the stacked plane would ship for this grid: the cell-tiling
    byte sum of :func:`plan_tiles`, computed from the per-SB group
    sizes alone (same alignment + canonical-pad rules, no Tile
    objects). The banked plane's accounting baseline."""
    align = _align(n_shards)
    tile_cells = max(align, -(-tile_cells // align) * align)
    sizes = _canonical_sizes(tile_cells, align)
    groups: Dict[int, int] = {}
    for s in specs:
        sb = s.sb_size if s.sb_size is not None else cluster.store_buffer
        groups[sb] = groups.get(sb, 0) + 1
    per_cell = 17 * n_stores + 8
    total = 0
    for m in groups.values():
        full, rem = divmod(m, tile_cells)
        total += full * tile_cells * per_cell
        if rem:
            total += next(c for c in sizes if c >= rem) * per_cell
    return total


def run_grid(specs: Sequence[ScenarioSpec],
             cluster: ClusterConfig = PAPER_CLUSTER,
             n_stores: int = 50_000,
             chunk_size: Optional[int] = None,
             tile_cells: Optional[int] = None,
             n_shards: Optional[int] = None,
             data_plane: Optional[str] = None,
             bank_partition: Optional[str] = None,
             k_replicas: Optional[int] = None,
             worker_timeout_s: Optional[float] = None) -> List[SimResult]:
    """Stream a (mega-)grid through the sharded tile engine.

    Results come back in ``specs`` order, bit-identical to
    ``simulate_batch`` and the serial oracle. ``chunk_size=None`` uses
    the :func:`auto_chunk` heuristic per SB group; ``tile_cells``
    defaults to the :data:`DEFAULT_TILE_BYTES` budget (capped at
    :data:`DEFAULT_TILE_CELLS`); ``n_shards`` defaults to every local
    device (1 falls back to single-device streaming -- still tiled,
    cached and double-buffered). ``data_plane`` is ``"bank"`` by
    default -- one device-resident columnar bank per grid, tiles ship
    index vectors, the kernel gathers, and only unique *scan lanes*
    (cells with distinct ``(SB, trace, max-plus row)`` triples -- the
    only inputs a timeline consumes) are scanned, with lane outputs
    scattered to member cells -- or ``"stacked"`` for the PR-3
    per-cell-copies plane (the measured baseline); results are
    bit-identical either way.

    ``bank_partition`` picks the banked plane's device layout:
    ``"sub"`` (the default) partitions the three max-plus columns into
    per-shard sub-banks -- one copy of each row fleet-wide, scan lanes
    scheduled onto their owning shard with shard-local wv indices, so
    resident bank device bytes are ~``1/n_shards`` of the replicated
    layout with the gather still shard-local -- while ``"replicated"``
    keeps the PR-4 one-copy-per-shard layout (the measured baseline).
    Both partitions are bit-identical: they gather the same rows.

    The loop overlaps three stages: the prefetch thread derives tile
    k+1's host payload while tile k's is placed cell-sharded on the
    mesh and its (asynchronously dispatched) scan runs. Dispatch runs
    ahead of the devices by at most :data:`MAX_IN_FLIGHT_TILES` tiles:
    past that the loop drains the oldest tile (blocking until its
    compute finishes and releasing its input buffers), which -- with
    the bank resident -- caps live memory at the bank plus a few tile
    payloads however large the grid is. :func:`bank_stats` reports the
    run's H2D / memory accounting (measured from the live buffers).

    **Resilience** (docs/resilience.md). ``k_replicas`` widens the
    sub-bank placement with the paper's Replica set (default: 2 under
    an active ``chaos.inject`` scope, else 1 -- the exact PR-8
    layout); ``worker_timeout_s`` bounds how long the dispatch loop
    waits on a silent prefetch worker before raising
    :class:`EngineWorkerError`. Under an active chaos scope the loop
    detects injected shard loss / corrupt rows / upload faults and
    recovers in place: in-flight tiles are cancelled, the lost shard's
    rows are rebuilt from the surviving replica block (or the bank's
    Logging-Unit journal), digest-verified against the host truth, and
    the bank is re-placed -- same shapes and shardings, so the
    spare-replacement path adds ZERO compiles and the recovered run's
    results stay bit-identical (tests/test_chaos.py pins ``==``).
    ``ChaosConfig(recovery="degraded")`` instead finishes the
    unfinished cells on a mesh shrunk by one shard with the bank
    replicated (one recompile, kept serving).
    """
    if not specs:
        return []
    if chunk_size is not None and chunk_size < 1:
        raise ValueError(
            f"chunk_size must be >= 1 (or None for auto), got {chunk_size}")
    plane = data_plane or "bank"
    if plane not in ("bank", "stacked"):
        raise ValueError(f"unknown data_plane {data_plane!r}")
    partition = bank_partition or "sub"
    if partition not in ("sub", "replicated"):
        raise ValueError(f"unknown bank_partition {bank_partition!r}")
    if k_replicas is not None and k_replicas != 1 and \
            (plane != "bank" or partition != "sub"):
        raise ValueError("k_replicas > 1 applies to the sub-partitioned "
                         f"bank plane only (got plane={plane!r}, "
                         f"partition={partition!r})")
    n_dev = len(jax.devices())
    if n_shards is None:
        # all local devices: even oversubscribed virtual CPU devices
        # measured faster than matching the physical core count (each
        # shard's scan body is single-threaded in XLA; more shards =
        # more concurrent executions for the host threadpool to fill)
        n_shards = n_dev
    if not 1 <= n_shards <= n_dev:
        raise ValueError(f"n_shards must be in [1, {n_dev}], got {n_shards}")
    for s in specs:
        s.validate(cluster)

    from repro.core.simulator import _plane_keys, bank_row_maps

    plan_kw = dict(cluster=cluster, n_stores=n_stores, chunk_size=chunk_size,
                   tile_cells=tile_cells or _default_tile_cells(n_stores),
                   n_shards=n_shards)
    bank = bank_dev = None
    bank_fresh = 0
    sub = False
    k_eff = 1
    local_rows = 0
    lane_members: List[List[int]] = []
    if plane == "bank":
        # --- scan-lane dedup -------------------------------------------
        # A cell's timeline consumes exactly (arrivals row, max-plus
        # row, SB depth) -- nothing else. Cells sharing that triple
        # (e.g. the whole CN axis of a sweep, or WB/WT cells across
        # replication knobs) therefore have bit-identical timelines:
        # the engine scans each unique LANE once and scatters the lane
        # outputs to every member cell (work_scale and the bandwidth /
        # log metrics are per-cell host math in ``_finish_result``, as
        # on every other tier). The mega-grid's 12 960 cells collapse
        # to ~2 700 scanned lanes.
        lane_of: Dict[tuple, int] = {}
        lane_specs: List[ScenarioSpec] = []
        lane_wv_keys: List[tuple] = []
        for i, s in enumerate(specs):
            sb = s.sb_size if s.sb_size is not None else cluster.store_buffer
            key = (sb,) + _plane_keys(s, cluster)
            j = lane_of.setdefault(key, len(lane_specs))
            if j == len(lane_specs):
                lane_specs.append(s)
                lane_wv_keys.append(key[2])
                lane_members.append([i])
            else:
                lane_members[j].append(i)
        # the bank's SHAPE comes from a cheap key pass, so the tile
        # signatures -- and therefore compile warming -- do not wait
        # for the heavy row materialization below
        trace_map, wv_map = bank_row_maps(specs, cluster)
        sub = partition == "sub"
        if sub:
            # per-shard sub-banks: the signature carries the LOCAL
            # (per-shard) wv row count, and the scheduler places each
            # lane in the slot block of the shard owning its wv row.
            # k_eff > 1 (chaos/recovery runs only) appends the Replica
            # set blocks along the local axis -- the signature sees
            # the widened stack (jit specializes on the bank shape),
            # while indices keep targeting the primary block
            k_eff = _chaos.resolve_k_replicas(k_replicas, n_shards)
            local_rows = sub_bank_rows(len(wv_map), n_shards)
            shape = (len(trace_map), k_eff * local_rows)
            owners = [wv_map[wk] % n_shards for wk in lane_wv_keys]
        else:
            shape = (len(trace_map), len(wv_map))
            owners = None
        tiles = [dataclasses.replace(
            t, sig=dataclasses.replace(t.sig, data_plane="bank",
                                       bank_shape=shape, bank_sub=sub))
            for t in plan_tiles(lane_specs, small_pad=False, owners=owners,
                                **plan_kw)]
    else:
        tiles = plan_tiles(specs, **plan_kw)
    costs = _commit_cost_ns("proactive", cluster)
    t_l1 = np.float32(costs["t_l1"])
    t_wt = np.float32(costs["t_wt"])

    results: List[Optional[SimResult]] = [None] * len(specs)

    # --- data-plane accounting (bank_stats / SimResult.meta) -----------
    def tile_payload_bytes(sig: TileSignature) -> int:
        return 8 * sig.b_pad if plane == "bank" else _stacked_tile_bytes(sig)

    # what the stacked plane would ship for the SAME grid (it tiles
    # cells, not lanes) -- the dedup_ratio baseline, counted from the
    # per-SB group sizes without materializing a throwaway tiling
    if plane == "bank":
        stacked_h2d = _stacked_plane_h2d(specs, cluster, n_stores,
                                         plan_kw["tile_cells"], n_shards)
    else:
        stacked_h2d = sum(_stacked_tile_bytes(t.sig) for t in tiles)
    h2d_bytes = sum(tile_payload_bytes(t.sig) for t in tiles)
    live_bytes = 0
    hwm_bytes = 0
    fabric_bytes = 0
    bank_dev_total = bank_dev_per = 0

    def prep_banked(tile: Tile):
        """Banked tile prep (prefetch thread): the two padded int32
        row-index vectors, plus per-MEMBER-cell result metadata grouped
        by lane (the scatter targets -- ``_prepare_cell``'s array
        fields are memo references, not copies, so this stays cheap).
        Sub-banked tiles remap wv rows to their SHARD-LOCAL index
        (``row // n_shards``) and scatter each lane into its
        :attr:`Tile.slots` position; unfilled slots stay 0 -- trace
        row 0 and local row 0 are valid gather targets on every shard
        (sub-banks are padded to at least one row), and padding
        outputs are discarded."""
        trace_idx = np.zeros(tile.sig.b_pad, np.int32)
        wv_idx = np.zeros(tile.sig.b_pad, np.int32)
        slots = tile.slots if tile.slots is not None \
            else range(len(tile.specs))
        wv_div = n_shards if tile.sig.bank_sub else 1
        for s, pos in zip(tile.specs, slots):
            tr, wr = bank.rows_for(s)
            trace_idx[pos] = tr
            wv_idx[pos] = wr // wv_div
        groups = [[(i, _prepare_cell(
            specs[i], _trace_cached(specs[i].workload, n_stores,
                                    specs[i].seed, cluster),
            n_stores, cluster)) for i in lane_members[lane]]
            for lane in tile.indices]
        return groups, (trace_idx, wv_idx)

    def prep_stacked(tile: Tile):
        cells, np_args = _prep_tile(tile, n_stores, cluster)
        return [[(i, c)] for i, c in zip(tile.indices, cells)], np_args

    prep = prep_banked if plane == "bank" else prep_stacked

    def finish(entry) -> None:
        """Drain one dispatched tile: blocks until its device compute is
        done, releasing its input buffers, and scatters each lane's
        outputs back to its member cells' original grid positions
        (through :attr:`Tile.slots` when the sub-bank scheduler placed
        lanes in shard-owner blocks). Marks the tile done -- the
        recovery loop re-dispatches exactly the tiles that never
        drained."""
        nonlocal live_bytes
        kt, tile, groups, (exec_ns, at_head, sb_full) = entry
        with _tm.span("tile/drain", tile=kt):
            # blocks on the device compute + ships the outputs back
            exec_ns = np.asarray(exec_ns)
            at_head = np.asarray(at_head)
            sb_full = np.asarray(sb_full)
        live_bytes -= tile_payload_bytes(tile.sig)
        slots = tile.slots if tile.slots is not None \
            else range(len(tile.indices))
        for group, pos in zip(groups, slots):
            for i, cell in group:
                meta = {"engine": ("sharded" if tile.sig.n_shards > 1
                                   else "streamed"),
                        "chunk": tile.sig.chunk,
                        "auto_chunk": chunk_size is None,
                        "tile_cells": tile.sig.b_pad,
                        "n_shards": tile.sig.n_shards,
                        "data_plane": plane,
                        "bank_partition": (partition if plane == "bank"
                                           else None),
                        "bank_rows": bank.n_rows if bank is not None else 0,
                        "h2d_bytes": h2d_bytes,
                        "bank_fabric_bytes": fabric_bytes}
                results[i] = _finish_result(cell, exec_ns[pos],
                                            int(at_head[pos]),
                                            int(sb_full[pos]), meta=meta)
        done[kt] = True

    # --- resilience plumbing (inert without an active chaos scope) -----
    st = _chaos.active()

    def prep_guarded(tile: Tile, no: int):
        """Prefetch-thread unit of work: heartbeat + chaos kill point +
        context-wrapping -- a poisoned tile surfaces as an
        :class:`EngineWorkerError` naming the tile, not as an opaque
        error tiles later."""
        _HEARTBEATS["prefetch"] = time.monotonic()
        if st is not None:
            st.on_thread("prefetch")
        try:
            with _tm.span("tile/prep", tile=no):
                return prep(tile)
        except ChaosError:
            raise
        except Exception as e:
            raise EngineWorkerError("prefetch", no, tile.sig,
                                    repr(e)) from e

    def warm_guarded():
        _HEARTBEATS["compile-warm"] = time.monotonic()
        if st is not None:
            st.on_thread("warm")
        try:
            with _tm.span("compile/warm", signatures=len(sigs)):
                _warm_signatures(sigs, t_l1, t_wt, bank_dev)
        except ChaosError:
            raise
        except Exception as e:
            raise EngineWorkerError("compile-warm", None,
                                    sigs[0] if sigs else None,
                                    repr(e)) from e

    def wait_prep(fut, no: int, sig: TileSignature):
        """Prefetch result with a stall bound: ``worker_timeout_s``
        turns a silently wedged worker into a prompt, attributed
        :class:`EngineWorkerError` instead of a hang."""
        if worker_timeout_s is None:
            return fut.result()
        deadline = time.monotonic() + worker_timeout_s
        while True:
            _futures_wait([fut], timeout=min(0.05, worker_timeout_s))
            if fut.done():
                return fut.result()
            if time.monotonic() > deadline:
                raise EngineWorkerError(
                    "prefetch", no, sig,
                    f"no result within worker_timeout_s={worker_timeout_s}")

    def check_warm() -> None:
        """Surface compile-thread failures promptly (each dispatch
        iteration), respawning the warm worker if chaos killed it --
        compiles then happen lazily on first call, which is slower but
        correct."""
        nonlocal warm
        if warm.done() and warm.exception() is not None:
            if isinstance(warm.exception(), ThreadDeathError):
                warm = compile_pool.submit(warm_guarded)
            else:
                raise warm.exception()

    def verify_tile(tile: Tile) -> None:
        """Gather-path integrity sampling: CRC-check (a sample of) the
        tile's wv rows against the host truth before dispatch. Chaos
        verification runs only -- the production path never reads
        device rows back."""
        if st is None or not st.wants_verify() or bank is None:
            return
        rows = sorted({bank.rows_for(sp)[1] for sp in tile.specs})
        _chaos.verify_rows(bank, bank_dev, rows[:VERIFY_ROWS_PER_TILE],
                           n_shards=n_shards if sub else 1,
                           local_cap=local_rows if sub else 0,
                           where="tile gather sample")

    def bank_place_key():
        if sub:
            return ("sub", n_shards) if k_eff == 1 \
                else ("sub", n_shards, k_eff)
        return 1 if n_shards == 1 else ("cells", n_shards)

    def place_bank_now() -> None:
        nonlocal bank_fresh, bank_dev, fabric_bytes, h2d_bytes
        nonlocal bank_dev_total, bank_dev_per
        with _tm.span("bank/place", rows=bank.n_rows):
            _place_bank_body()

    def _place_bank_body() -> None:
        nonlocal bank_fresh, bank_dev, fabric_bytes, h2d_bytes
        nonlocal bank_dev_total, bank_dev_per
        if sub:
            bank_fresh, bank_dev = _retried(
                lambda: _place_sub_bank(bank, n_shards, k_eff),
                "bank placement")
            # only the replicated arrivals staging crosses the
            # device fabric; the partitioned max-plus stacks ship
            # each shard's slice straight from the host
            fabric_bytes += (bank.arrivals.nbytes * (n_shards - 1)
                             if bank_fresh else 0)
        else:
            bank_fresh, bank_dev = _retried(
                lambda: _place_bank(bank, n_shards), "bank placement")
            fabric_bytes += (bank.nbytes * (n_shards - 1)
                             if bank_fresh else 0)
        h2d_bytes += bank_fresh
        bank_dev_total, bank_dev_per = _measured_device_bytes(bank_dev)

    def recover(err: Exception) -> None:
        """Spare-replacement recovery: rebuild the lost rows from the
        surviving replica block (or the Logging-Unit journal),
        digest-verify the rebuild against the host truth, drop the
        stale placement and re-place -- same shapes and shardings, so
        every compiled program still hits (the 0-recompile invariant
        tests/test_chaos.py pins)."""
        nonlocal bank_dev
        t0 = time.monotonic()
        lost = err.shard if isinstance(err, ShardLossError) else None
        if lost is not None:
            # spare replacement: the mesh shape is unchanged (a spare
            # takes the lost shard's coordinates) -- validate via the
            # elastic-scaling policy it shares with the trainer tier
            from repro.distributed.elastic import cells_spare_replacement
            cells_spare_replacement(n_shards, lost)
        source = "redispatch"
        if bank is not None and sub and lost is not None:
            with _tm.span("recover/rebuild", shard=lost):
                if k_eff >= 2:
                    rebuilt = _chaos.replica_rebuild(
                        bank_dev, lost, n_shards=n_shards,
                        k_replicas=k_eff, local_cap=local_rows,
                        wv_rows=bank.wv_rows)
                    source = "replica"
                elif bank.journal_enabled:
                    rebuilt = _chaos.journal_rebuild(bank, lost, n_shards)
                    source = "journal"
                else:
                    rebuilt = None
                    source = "host"
                if rebuilt is not None:
                    _chaos.verify_rebuild(bank, rebuilt, lost, n_shards)
        elif bank is not None:
            source = "host"
        if bank is not None:
            with _tm.span("recover/replace", source=source):
                bank.drop_placement(bank_place_key())
                place_bank_now()
        if st is not None:
            st.note_recovery(source, (time.monotonic() - t0) * 1e3,
                             lost, "spare")

    in_flight: List[tuple] = []
    done = [False] * len(tiles)
    recover_attempts = 0
    redispatch_pending = False
    degraded_from: Optional[int] = None
    prep_pool = ThreadPoolExecutor(max_workers=1)
    compile_pool = ThreadPoolExecutor(max_workers=1)
    try:
        if plane == "bank":
            # materialize + upload the bank before warming: the warm
            # calls (and every tile call) gather from the one resident
            # placement, and compilation overlaps the first tiles' loop
            bank = get_trace_bank(specs, n_stores, cluster)
            place_bank_now()
            if st is not None:
                # chaos row corruption lands on the DEVICE copy only
                # (the host columns stay the truth the CRC digests and
                # rebuilds verify against)
                bank_dev = st.tamper_bank(
                    bank_dev, n_shards=n_shards,
                    k_replicas=k_eff if sub else 1,
                    local_cap=local_rows if sub else 0,
                    wv_rows=bank.wv_rows)
            live_bytes = hwm_bytes = bank_dev_total
        sigs = list(dict.fromkeys(t.sig for t in tiles))
        warm = compile_pool.submit(warm_guarded)
        while not all(done):
            pending = [k for k, d in enumerate(done) if not d]
            try:
                fut = prep_pool.submit(prep_guarded, tiles[pending[0]],
                                       pending[0])
                for pi, kt in enumerate(pending):
                    tile = tiles[kt]
                    try:
                        groups, np_args = wait_prep(fut, kt, tile.sig)
                    except ThreadDeathError:
                        # prefetch worker killed mid-grid: rebuild this
                        # tile inline on the caller thread and keep
                        # streaming (the injected death was confined to
                        # the future; later submits run normally)
                        groups, np_args = prep(tile)
                    if pi + 1 < len(pending):
                        nxt = pending[pi + 1]
                        fut = prep_pool.submit(prep_guarded, tiles[nxt],
                                               nxt)
                    check_warm()
                    verify_tile(tile)

                    def place_dispatch(args=np_args, sig=tile.sig):
                        _h2d_hook(tile_payload_bytes(sig))
                        return _place_tile(args, sig)

                    with _tm.span("tile/h2d", tile=kt):
                        placed = _retried(place_dispatch,
                                          f"tile {kt} placement")
                    if st is not None:
                        st.on_dispatch(f"tile {kt}")
                    # first dispatch after a recovery is the timeline's
                    # re-dispatch leg; name its span accordingly
                    dispatch_span = ("recover/redispatch"
                                     if redispatch_pending
                                     else "tile/dispatch")
                    redispatch_pending = False
                    with _tm.span(dispatch_span, tile=kt):
                        out = _tile_fn(tile.sig)(*bank_dev, *placed) \
                            if bank is not None \
                            else _tile_fn(tile.sig)(*placed, t_l1, t_wt)
                    in_flight.append((kt, tile, groups, out))
                    _tm.gauge("engine/in_flight_tiles",
                              len(in_flight))
                    _tm.gauge("engine/prefetch_queue_depth",
                              len(pending) - pi - 1)
                    live_bytes += tile_payload_bytes(tile.sig)
                    hwm_bytes = max(hwm_bytes, live_bytes)
                    # backpressure: dispatch runs ahead of the devices,
                    # so without a bound every dispatched tile's input
                    # buffers stay alive at once; draining the oldest
                    # keeps at most MAX_IN_FLIGHT_TILES tiles of device
                    # memory pinned (plus the resident bank) while
                    # still overlapping prep/compute/drain
                    if len(in_flight) >= MAX_IN_FLIGHT_TILES:
                        finish(in_flight.pop(0))
                while in_flight:
                    finish(in_flight.pop(0))
            except (ShardLossError, IntegrityError) as e:
                with _tm.span("recover", error=type(e).__name__):
                    with _tm.span("recover/detect",
                                  error=type(e).__name__):
                        _tm.count("chaos/faults_detected")
                    # cancel in-flight tiles: their outputs may involve
                    # the lost/corrupt placement, and their tiles
                    # re-dispatch (done[] is only set by finish)
                    with _tm.span("recover/rollback",
                                  tiles=len(in_flight)):
                        for (_kt, t_, _g, _o) in in_flight:
                            live_bytes -= tile_payload_bytes(t_.sig)
                        in_flight.clear()
                    recover_attempts += 1
                    if st is None or recover_attempts > MAX_RECOVERIES:
                        raise
                    if (isinstance(e, ShardLossError) and n_shards > 1
                            and plane == "bank"
                            and st.cfg.recovery == "degraded"):
                        degraded_from = e.shard
                        break
                    recover(e)
                redispatch_pending = True
        if degraded_from is None:
            try:
                warm.result()  # surface compile-thread exceptions
            except ThreadDeathError:
                pass           # injected kill, already respawned/absorbed
    finally:
        prep_pool.shutdown(wait=True)
        compile_pool.shutdown(wait=True)

    if degraded_from is not None:
        # degraded-mesh fallback: finish the unfinished cells on a mesh
        # shrunk by the lost shard with the bank replicated -- ONE
        # recompile set, but no spare needed (elastic.py's shrink
        # semantics; the spare path above is the default)
        from repro.distributed.elastic import cells_degraded_shards
        t0 = time.monotonic()
        left = [i for i, r in enumerate(results) if r is None]
        sub_res = run_grid([specs[i] for i in left], cluster=cluster,
                           n_stores=n_stores, chunk_size=chunk_size,
                           tile_cells=tile_cells,
                           n_shards=cells_degraded_shards(n_shards),
                           data_plane="bank",
                           bank_partition="replicated")
        for i, r in zip(left, sub_res):
            results[i] = r
        if st is not None:
            st.note_recovery("degraded-mesh",
                             (time.monotonic() - t0) * 1e3,
                             degraded_from, "degraded")

    _BANK_STATS.clear()
    _BANK_STATS.update({
        "data_plane": plane, "cells": len(specs), "n_shards": n_shards,
        "bank_partition": partition if plane == "bank" else None,
        "scan_lanes": len(lane_members) if plane == "bank" else len(specs),
        "trace_rows": bank.trace_rows if bank is not None else 0,
        "wv_rows": bank.wv_rows if bank is not None else 0,
        "bank_rows": bank.n_rows if bank is not None else 0,
        "bank_bytes": bank.nbytes if bank is not None else 0,
        "bank_dev_bytes_per_shard": bank_dev_per,
        "bank_dev_bytes": bank_dev_total,
        "h2d_bytes": h2d_bytes,
        "bank_fabric_bytes": fabric_bytes,
        "stacked_h2d_bytes": stacked_h2d,
        "dedup_ratio": stacked_h2d / max(h2d_bytes, 1),
        "dev_mem_hwm_bytes": hwm_bytes,
        "k_replicas": k_eff,
        "degraded": degraded_from is not None,
        "chaos": st.report() if st is not None else None,
    })
    rec = _tm.active()
    if rec is not None:
        # one merged per-run summary, shared (by reference) between
        # bank_stats() and every cell's meta -- the summarized dict the
        # flight recorder exports alongside the Chrome trace
        summ = rec.summary()
        _BANK_STATS["telemetry"] = summ
        for r in results:
            if r is not None and r.meta is not None:
                r.meta.setdefault("telemetry", summ)
    return results


# ---------------------------------------------------------------------------
# Tier selection
# ---------------------------------------------------------------------------

def simulate_grid(specs: Sequence[ScenarioSpec],
                  cluster: ClusterConfig = PAPER_CLUSTER,
                  n_stores: int = 50_000,
                  engine: str = "auto",
                  chunk_size: Optional[int] = None,
                  tile_cells: Optional[int] = None,
                  n_shards: Optional[int] = None,
                  data_plane: Optional[str] = None,
                  bank_partition: Optional[str] = None,
                  k_replicas: Optional[int] = None,
                  worker_timeout_s: Optional[float] = None
                  ) -> List[SimResult]:
    """Run a scenario grid on the right engine tier.

    ``engine``:

    * ``"auto"`` (default) -- blocked one-shot batch below
      :data:`STREAM_THRESHOLD` cells, streaming sharded tier at or
      above it;
    * ``"serial"`` -- the per-cell oracle loop (differential testing);
    * ``"perstep"`` -- the PR-1 per-step batched scan;
    * ``"blocked"`` -- one-shot blocked batch (``simulate_batch``);
    * ``"stream"`` -- the tiled sharded/streaming engine
      (:func:`run_grid`).

    ``data_plane`` (blocked and stream tiers) selects the columnar bank
    (default) or the stacked per-cell-copies baseline;
    ``bank_partition`` (stream tier only -- the one with a sharded
    placement) selects per-shard sub-banks (default) or the replicated
    layout, see :func:`run_grid`. All tiers and planes return
    bit-identical results in ``specs`` order; ``SimResult.meta``
    records what actually ran.
    """
    if engine == "auto":
        engine = "stream" if len(specs) >= STREAM_THRESHOLD else "blocked"
    if bank_partition is not None and engine != "stream":
        raise ValueError(
            f"bank_partition applies to the stream tier only, not {engine!r}")
    if (k_replicas is not None or worker_timeout_s is not None) \
            and engine != "stream":
        raise ValueError("k_replicas / worker_timeout_s apply to the "
                         f"stream tier only, not {engine!r}")
    if engine == "serial":
        for s in specs:
            s.validate(cluster)
        return [simulate_spec(s, cluster=cluster, n_stores=n_stores)
                for s in specs]
    if engine == "perstep":
        # forwarded so an explicit data_plane="bank" raises (the
        # per-step engine has no banked plane) instead of silently
        # running stacked
        return simulate_batch(specs, cluster=cluster, n_stores=n_stores,
                              chunk_size=0, data_plane=data_plane)
    if engine == "blocked":
        return simulate_batch(specs, cluster=cluster, n_stores=n_stores,
                              chunk_size=chunk_size, data_plane=data_plane)
    if engine == "stream":
        return run_grid(specs, cluster=cluster, n_stores=n_stores,
                        chunk_size=chunk_size, tile_cells=tile_cells,
                        n_shards=n_shards, data_plane=data_plane,
                        bank_partition=bank_partition,
                        k_replicas=k_replicas,
                        worker_timeout_s=worker_timeout_s)
    raise ValueError(f"unknown engine {engine!r}")
