"""Shard directory -- the framework's analogue of the CXL coherence
directory that recovery repairs (paper SS V.C).

The CXL directory tracks, per cache line, which CNs cache it and who owns
the dirty copy. Our directory tracks, per (node, bucket) state shard:

* ``owner``      -- the data-rank that owns (writes) the shard,
* ``replicas``   -- the N_r ranks whose Logging Units hold its updates,
* ``dump_step``  -- the last step whose version is safe in the MN tier,
* ``commit_step``-- the last step whose replication was validated,
* ``state``      -- OWNED / SHARED / UNOWNED (post-recovery).

It is deliberately a host-side structure (numpy): the paper's directory
lives in MN memory and is repaired by *software* handlers; keeping it off
the device state also means its consistency survives device failures by
construction. Benchmarks read it for the Fig. 15 analogue (owned shards
of a crashed node).

Queueing model (``directory_load`` axis)
----------------------------------------
The bottom of this module is the *capacity* side of the directory: the
simulator's two-level max-plus recurrence (docs/simulator.md) treats
each (node, bucket) shard as an M/D/1-style server shared by every CN
that appears in the shard's replica set. Two resolved quantities feed
it:

* :func:`sharer_pool` -- the **real** sharer census: the union of
  node 0's per-bucket replica peers under :class:`ShardDirectory`,
  clamped to ``n_cns - 1``. This replaces ``contention.SHARER_POOL``'s
  fixed 15-peer binomial when the directory model is active (the
  small-cluster overcount bugfix).
* :class:`DirectoryParams` via :func:`resolve_directory_load` -- the
  frozen per-cell coupling knobs the simulator folds into each epoch's
  ``w`` side and the dedup keys. ``directory_load=None`` keeps the
  axis fully inert (bit-identical legacy outputs AND keys).
"""

from __future__ import annotations

import dataclasses
import enum
import functools
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import replica_groups


class ShardState(enum.Enum):
    OWNED = "owned"          # owner holds the newest (dirty) version
    SHARED = "shared"        # replicated, clean vs. MN tier
    UNOWNED = "unowned"      # post-recovery: memory holds newest version


@dataclasses.dataclass
class DirEntry:
    owner: int
    replicas: Tuple[int, ...]
    state: ShardState = ShardState.OWNED
    dump_step: int = -1          # newest version safe in MN tier
    commit_step: int = -1        # newest validated replicated version


class ShardDirectory:
    """Directory over all (node, bucket) shards."""

    def __init__(self, n_nodes: int, n_buckets: int, n_replicas: int):
        self.n_nodes = n_nodes
        self.n_buckets = n_buckets
        self.n_replicas = n_replicas
        self.entries: Dict[Tuple[int, int], DirEntry] = {}
        for node in range(n_nodes):
            for b in range(n_buckets):
                reps = replica_groups.replica_targets(
                    node, b, n_replicas, n_nodes)
                self.entries[(node, b)] = DirEntry(owner=node, replicas=reps)

    # ------------------------------------------------------------------
    def entry(self, node: int, bucket: int) -> DirEntry:
        return self.entries[(node, bucket)]

    def record_commit(self, step: int) -> None:
        for e in self.entries.values():
            e.commit_step = step
            e.state = ShardState.OWNED

    def record_dump(self, step: int) -> None:
        for e in self.entries.values():
            e.dump_step = step

    # ------------------------------------------------------------------
    # Recovery queries (Algorithm 1 inputs)
    # ------------------------------------------------------------------

    def owned_by(self, node: int) -> List[Tuple[int, int]]:
        """Shards whose dirty version lived on ``node``."""
        return [k for k, e in self.entries.items()
                if e.owner == node and e.state == ShardState.OWNED]

    def replicated_on(self, node: int) -> List[Tuple[int, int]]:
        """Shards whose Logging-Unit entries live on ``node``
        (the SHARED analogue: what must be dropped when ``node`` dies)."""
        return [k for k, e in self.entries.items() if node in e.replicas]

    def replicas_of(self, node: int, bucket: int) -> Tuple[int, ...]:
        return self.entries[(node, bucket)].replicas

    # ------------------------------------------------------------------
    # Recovery mutations (Algorithm 1 effects)
    # ------------------------------------------------------------------

    def remove_failed_replica(self, failed: int) -> int:
        """Drop ``failed`` from every replica set (sharer-bit clearing)."""
        n = 0
        for e in self.entries.values():
            if failed in e.replicas:
                e.replicas = tuple(r for r in e.replicas if r != failed)
                n += 1
        return n

    def reassign(self, node: int, bucket: int, new_owner: int,
                 n_nodes: Optional[int] = None) -> None:
        e = self.entries[(node, bucket)]
        e.owner = new_owner
        e.state = ShardState.UNOWNED
        # recompute a full replica set for the new owner
        e.replicas = replica_groups.replica_targets(
            new_owner, bucket, self.n_replicas, n_nodes or self.n_nodes)

    # ------------------------------------------------------------------
    def stats(self, failed: int) -> Dict[str, int]:
        """Fig. 15 analogue: shard-entry census for a crashed node."""
        owned = len(self.owned_by(failed))
        shared = len(self.replicated_on(failed))
        return {"owned": owned, "shared": shared,
                "total": len(self.entries)}

    def to_json(self) -> str:
        return json.dumps({
            f"{k[0]}:{k[1]}": {
                "owner": e.owner, "replicas": list(e.replicas),
                "state": e.state.value, "dump_step": e.dump_step,
                "commit_step": e.commit_step,
            } for k, e in self.entries.items()
        })

    @classmethod
    def from_json(cls, blob: str, n_nodes: int, n_buckets: int,
                  n_replicas: int) -> "ShardDirectory":
        d = cls(n_nodes, n_buckets, n_replicas)
        data = json.loads(blob)
        for key, v in data.items():
            node, b = map(int, key.split(":"))
            e = d.entries[(node, b)]
            e.owner = v["owner"]
            e.replicas = tuple(v["replicas"])
            e.state = ShardState(v["state"])
            e.dump_step = v["dump_step"]
            e.commit_step = v["commit_step"]
        return d


# ---------------------------------------------------------------------------
# Queueing-coupled directory model (the simulator's level-2 recurrence)
# ---------------------------------------------------------------------------

#: Buckets per node in the canonical coupling directory. Matches the
#: recovery benches' shard granularity; a shard therefore serves
#: ``1/DIR_BUCKETS`` of a node's line traffic.
DIR_BUCKETS = 16

#: Stores per directory epoch in the level-2 service-rate recurrence.
#: Coarser than ``contention.EPOCH_LEN`` (64): the directory queue
#: drains on dump-period timescales, not store-buffer timescales.
DIR_EPOCH_LEN = 128


@functools.lru_cache(maxsize=256)
def sharer_pool(n_cns: int, n_replicas: int,
                n_buckets: int = DIR_BUCKETS) -> int:
    """Real sharer census for one CN: the union of node 0's per-bucket
    replica peers under :class:`ShardDirectory`, self excluded.

    This is the directory-derived replacement for the fixed
    ``contention.SHARER_POOL`` binomial pool: by construction it never
    exceeds ``n_cns - 1``, so a 4-CN cluster stops drawing invalidation
    storms from 15 phantom peers. Returns 0 for single-node clusters
    (nobody to invalidate)."""
    if n_cns <= 1:
        return 0
    nr_eff = max(1, min(int(n_replicas), n_cns - 1))
    peers = set()
    for bucket in range(n_buckets):
        peers.update(replica_groups.replica_targets(
            0, bucket, nr_eff, n_cns))
    peers.discard(0)
    return len(peers)


@dataclasses.dataclass(frozen=True)
class DirectoryParams:
    """Resolved directory-coupling knobs for one cell.

    Frozen + hashable: appended verbatim to the simulator's
    ``_plane_keys`` wv key (and hence the bank-row / scan-lane dedup
    keys), so two cells couple through the same (shard, epoch-profile)
    iff their params compare equal. ``rho_bg`` is the *background*
    utilization this cell's shard sees from its sharer pool; the cell's
    own offered work is added per epoch by the level-2 recurrence.
    """

    sharer_pool: int
    rho_bg: float
    epoch: int = DIR_EPOCH_LEN
    buckets: int = DIR_BUCKETS


def resolve_directory_load(load: Optional[float], n_cns: int,
                           n_replicas: int) -> Optional[DirectoryParams]:
    """Resolve the ``directory_load`` axis to frozen params (or None).

    ``None`` means the coupling is OFF: no params, no key component,
    bit-identical legacy behavior. ``load`` is the offered utilization
    in [0, 1) each *sharer* contributes to the shared shard;
    ``rho_bg`` scales it by the real pool over the peer count.
    ``load == 0.0`` canonicalizes to a pool-free zero-load cell so the
    in-grid normalization cell dedups across CN counts (the delays are
    exactly zero either way)."""
    if load is None:
        return None
    load = float(load)
    if not 0.0 <= load < 1.0:
        raise ValueError(
            f"directory_load must be in [0, 1) or None, got {load!r}")
    if load == 0.0:
        return DirectoryParams(sharer_pool=0, rho_bg=0.0)
    pool = sharer_pool(n_cns, n_replicas)
    rho_bg = load * pool / max(n_cns - 1, 1)
    return DirectoryParams(sharer_pool=pool, rho_bg=rho_bg)


def directory_service_scale(dirp: Optional[DirectoryParams]) -> float:
    """Mean service-rate dilation ``1 / (1 - rho)`` of a shard under
    background load (utilization capped below saturation). Scales the
    recovery walk's directory phase; 1.0 when the coupling is off."""
    if dirp is None:
        return 1.0
    rho = min(float(dirp.rho_bg), 0.95)
    return 1.0 / (1.0 - rho)
