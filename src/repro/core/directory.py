"""Shard directory -- the framework's analogue of the CXL coherence
directory that recovery repairs (paper SS V.C).

The CXL directory tracks, per cache line, which CNs cache it and who owns
the dirty copy. Our directory tracks, per (node, bucket) state shard:

* ``owner``      -- the data-rank that owns (writes) the shard,
* ``replicas``   -- the N_r ranks whose Logging Units hold its updates,
* ``dump_step``  -- the last step whose version is safe in the MN tier,
* ``commit_step``-- the last step whose replication was validated,
* ``state``      -- OWNED / SHARED / UNOWNED (post-recovery).

It is deliberately a host-side structure (numpy): the paper's directory
lives in MN memory and is repaired by *software* handlers; keeping it off
the device state also means its consistency survives device failures by
construction. Benchmarks read it for the Fig. 15 analogue (owned shards
of a crashed node).
"""

from __future__ import annotations

import dataclasses
import enum
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import replica_groups


class ShardState(enum.Enum):
    OWNED = "owned"          # owner holds the newest (dirty) version
    SHARED = "shared"        # replicated, clean vs. MN tier
    UNOWNED = "unowned"      # post-recovery: memory holds newest version


@dataclasses.dataclass
class DirEntry:
    owner: int
    replicas: Tuple[int, ...]
    state: ShardState = ShardState.OWNED
    dump_step: int = -1          # newest version safe in MN tier
    commit_step: int = -1        # newest validated replicated version


class ShardDirectory:
    """Directory over all (node, bucket) shards."""

    def __init__(self, n_nodes: int, n_buckets: int, n_replicas: int):
        self.n_nodes = n_nodes
        self.n_buckets = n_buckets
        self.n_replicas = n_replicas
        self.entries: Dict[Tuple[int, int], DirEntry] = {}
        for node in range(n_nodes):
            for b in range(n_buckets):
                reps = replica_groups.replica_targets(
                    node, b, n_replicas, n_nodes)
                self.entries[(node, b)] = DirEntry(owner=node, replicas=reps)

    # ------------------------------------------------------------------
    def entry(self, node: int, bucket: int) -> DirEntry:
        return self.entries[(node, bucket)]

    def record_commit(self, step: int) -> None:
        for e in self.entries.values():
            e.commit_step = step
            e.state = ShardState.OWNED

    def record_dump(self, step: int) -> None:
        for e in self.entries.values():
            e.dump_step = step

    # ------------------------------------------------------------------
    # Recovery queries (Algorithm 1 inputs)
    # ------------------------------------------------------------------

    def owned_by(self, node: int) -> List[Tuple[int, int]]:
        """Shards whose dirty version lived on ``node``."""
        return [k for k, e in self.entries.items()
                if e.owner == node and e.state == ShardState.OWNED]

    def replicated_on(self, node: int) -> List[Tuple[int, int]]:
        """Shards whose Logging-Unit entries live on ``node``
        (the SHARED analogue: what must be dropped when ``node`` dies)."""
        return [k for k, e in self.entries.items() if node in e.replicas]

    def replicas_of(self, node: int, bucket: int) -> Tuple[int, ...]:
        return self.entries[(node, bucket)].replicas

    # ------------------------------------------------------------------
    # Recovery mutations (Algorithm 1 effects)
    # ------------------------------------------------------------------

    def remove_failed_replica(self, failed: int) -> int:
        """Drop ``failed`` from every replica set (sharer-bit clearing)."""
        n = 0
        for e in self.entries.values():
            if failed in e.replicas:
                e.replicas = tuple(r for r in e.replicas if r != failed)
                n += 1
        return n

    def reassign(self, node: int, bucket: int, new_owner: int,
                 n_nodes: Optional[int] = None) -> None:
        e = self.entries[(node, bucket)]
        e.owner = new_owner
        e.state = ShardState.UNOWNED
        # recompute a full replica set for the new owner
        e.replicas = replica_groups.replica_targets(
            new_owner, bucket, self.n_replicas, n_nodes or self.n_nodes)

    # ------------------------------------------------------------------
    def stats(self, failed: int) -> Dict[str, int]:
        """Fig. 15 analogue: shard-entry census for a crashed node."""
        owned = len(self.owned_by(failed))
        shared = len(self.replicated_on(failed))
        return {"owned": owned, "shared": shared,
                "total": len(self.entries)}

    def to_json(self) -> str:
        return json.dumps({
            f"{k[0]}:{k[1]}": {
                "owner": e.owner, "replicas": list(e.replicas),
                "state": e.state.value, "dump_step": e.dump_step,
                "commit_step": e.commit_step,
            } for k, e in self.entries.items()
        })

    @classmethod
    def from_json(cls, blob: str, n_nodes: int, n_buckets: int,
                  n_replicas: int) -> "ShardDirectory":
        d = cls(n_nodes, n_buckets, n_replicas)
        data = json.loads(blob)
        for key, v in data.items():
            node, b = map(int, key.split(":"))
            e = d.entries[(node, b)]
            e.owner = v["owner"]
            e.replicas = tuple(v["replicas"])
            e.state = ShardState(v["state"])
            e.dump_step = v["dump_step"]
            e.commit_step = v["commit_step"]
        return d
