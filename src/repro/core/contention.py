"""Directory-contention & crash-consistency scenario axes (beyond-paper).

The paper's slowdown model (Figs. 10/16-18) lets every store's coherence
transaction proceed *uncontended*: the RFO wins ownership on the first
try and no other node holds the line. Real shared-memory workloads
stress the same directory/fabric that ReCXL's replication messages
ride: "Enabling Efficient Transaction Processing on CXL-Based Memory
Sharing" (arXiv:2502.11046) shows directory conflict rates dominate
OLTP-style behaviour, and "CXL Shared Memory Programming"
(arXiv:2405.19626) shows the read/write interleaving -- what a crash
can expose -- changes recovery-relevant state. This module makes both
first-class, batched scenario axes on top of the existing engines:

* ``conflict_rate`` -- fraction of remote stores that hit a *directory
  conflict* (another writer raced them to the line). Conflicts cluster
  in hot-spot episodes, modeled exactly like PR 1's trace synthesis: a
  two-state Markov chain over stores materialized as alternating
  geometric run lengths (:func:`conflict_draws` -- no per-store Python
  loops). A conflicted store retries its ownership acquisition; the
  retry count is geometric (each attempt re-races the conflictors), and
  every failed attempt costs a directory round trip.

* ``read_share`` -- how read-heavy the interleaved access mix is.
  Reads create Shared copies at peer CNs, so a store to a read-shared
  line must invalidate the sharers before it owns the line: per
  contended store, a sharer census is drawn from the cluster peer pool
  and each sharer adds a serialized invalidation leg at the directory.

* ``consistency_schedule`` -- where the software places persist
  ordering points (the crash-consistency discipline of 2405.19626):
  ``"lazy"`` (no ordering -- the paper's implicit schedule; maximal
  crash exposure), ``"epoch"`` (a persist barrier every
  :data:`EPOCH_LEN` stores), ``"eager"`` (every store is an ordering
  point). Barriers stall the commit pipeline for the durable-media
  persist latency, and -- the flip side -- shrink the dirty state a
  crash can expose (:func:`dirty_line_scale` /
  :func:`undumped_log_scale` feed the SS VII-E recovery-time model).

The delays are **collapsed into the existing per-store cost arrays**
(:func:`contention_arrays` returns per-store ``(delay_ns, flush_ns)``
rows; ``simulator._make_cell_arrays`` adds ``delay`` to the exposed
coherence latency and ``flush`` to the REPL-ack / drain-service terms),
so the max-plus recurrence ``c_i = max(r_i + w_i, c_{i-1} + v_i)`` is
extended without touching a single scan kernel: a contended store's
ready time absorbs the conflict backoff through ``w_i``, persist
barriers ride ``v_i``, and the banked data plane / scan-lane dedup /
streaming mega-grid engine work unchanged (the contention parameters
become a new component of the bank's max-plus row key -- see
``simulator._plane_keys``). WB/WT commit locally without a directory
transaction on the modeled path, so their constant bank rows stay
constant and contention-axis slowdowns normalize against an unchanged
WB baseline.

Semantics contract: with every axis ``None`` the subsystem is inert --
bit-identical outputs AND unchanged bank dedup keys (no row churn on
legacy grids). With axes *set to their neutral values* (``0.0``,
``0.0``, ``"lazy"``) the delays are exactly zero, so outputs equal the
uncontended ones bit-for-bit while the dedup key (and therefore the
bank row) differs -- the natural in-grid normalization cell.

:func:`serial_oracle` is the differential-testing reference for the new
semantics: a pure-Python per-store loop (numpy f32 scalar arithmetic --
IEEE add/max are exactly defined, so Python and XLA produce identical
bits) applying the *pre-collapse* commit rules of ``simulator
._timeline``. ``tests/test_contention.py`` pins oracle == serial jax ==
blocked == banked == streaming with ``==``, the same discipline as
``simulate()``.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.configs.recxl_paper import ClusterConfig, PAPER_CLUSTER
from repro.core.hostcache import BoundedCache

#: Recognised crash-consistency schedules, weakest ordering first.
CONSISTENCY_SCHEDULES = ("lazy", "epoch", "eager")

#: Stores between persist barriers under the ``"epoch"`` schedule.
EPOCH_LEN = 64

#: Mean directory hot-spot episode length, in stores (conflicts cluster:
#: a contended line stays contended for a burst of accesses).
CONFLICT_RUN_LEN = 8.0

#: DEFAULT peer pool that can hold a Shared copy of a line (the paper's
#: 16-CN cluster minus the writer). This is only the *fallback* for a
#: bare :class:`ContentionParams`: the simulator's ``_resolve_coupling``
#: replaces it with the **directory-derived** census
#: (``directory.sharer_pool(n_cns, n_replicas)`` -- the union of the
#: real ``ShardDirectory`` replica peers, never more than ``n_cns - 1``)
#: whenever ``read_share > 0``, and canonicalizes it to 0 when
#: ``read_share == 0`` (the binomial census is identically zero then, so
#: the CN weak-scaling axis keeps sharing bank rows and scan lanes).
#: The old behavior -- Binomial(15, read_share) even on a 4-CN cluster
#: -- overcounted invalidations on small clusters.
SHARER_POOL = 15

#: RNG salt decorrelating conflict draws from the trace synthesis rng
#: (both are seeded from the spec's ``seed``).
_RNG_SALT = 0x5EEDC0F1


@dataclasses.dataclass(frozen=True)
class ContentionParams:
    """Resolved contention axes of one scenario cell.

    ``read_share`` in [0, 1): fraction of the remote mix that is reads
    (drives the sharer census a store must invalidate);
    ``conflict_rate`` in [0, 1): fraction of stores hitting a directory
    conflict; ``schedule`` one of :data:`CONSISTENCY_SCHEDULES`;
    ``sharer_pool`` the peer census the invalidation binomial draws
    from (the simulator canonicalizes it via ``_resolve_coupling``:
    directory-derived when ``read_share > 0``, 0 otherwise).
    Hashable -- used verbatim as the contention component of the bank's
    max-plus row dedup key."""
    read_share: float = 0.0
    conflict_rate: float = 0.0
    schedule: str = "lazy"
    sharer_pool: int = SHARER_POOL


def resolve_contention(read_share: Optional[float],
                       conflict_rate: Optional[float],
                       consistency_schedule: Optional[str]
                       ) -> Optional[ContentionParams]:
    """Resolve the three ``ScenarioSpec`` axes into one params value.

    Returns ``None`` iff all three are ``None`` (contention modeling
    off -- the legacy semantics, with unchanged dedup keys). If ANY
    axis is set, the others default to their neutral values (0.0 /
    ``"lazy"``). Raises ``ValueError`` on out-of-range axes."""
    if read_share is None and conflict_rate is None \
            and consistency_schedule is None:
        return None
    rs = 0.0 if read_share is None else float(read_share)
    cr = 0.0 if conflict_rate is None else float(conflict_rate)
    sched = "lazy" if consistency_schedule is None else consistency_schedule
    if not 0.0 <= rs < 1.0:
        raise ValueError(f"read_share must be in [0, 1), got {rs}")
    if not 0.0 <= cr < 1.0:
        raise ValueError(f"conflict_rate must be in [0, 1), got {cr}")
    if sched not in CONSISTENCY_SCHEDULES:
        raise ValueError(f"unknown consistency_schedule {sched!r} "
                         f"(know {CONSISTENCY_SCHEDULES})")
    return ContentionParams(read_share=rs, conflict_rate=cr, schedule=sched)


# ---------------------------------------------------------------------------
# Sharer / conflict synthesis (vectorized, memoized)
# ---------------------------------------------------------------------------

#: Raw conflict/sharer draws, keyed ``(n_stores, seed, conflict_rate,
#: read_share, pool)`` -- ~8 bytes x n_stores per entry (two int32
#: census columns). The draws do NOT depend on congestion / cluster constants
#: (those scale the delays deterministically afterwards), so one entry
#: serves every N_r/bw knob of a sweep. ``clear_sim_caches`` drops both
#: caches via :func:`clear_contention_caches`.
_DRAW_CACHE = BoundedCache(maxsize=256)
#: Finished per-store ``(delay, flush)`` rows, keyed by the full
#: contention row key -- the contention counterpart of ``_WV_ROW_CACHE``.
_DELAY_CACHE = BoundedCache(maxsize=512)


def clear_contention_caches() -> None:
    """Drop the conflict-draw and delay-row memos (called by
    ``repro.core.simulator.clear_sim_caches``)."""
    _DRAW_CACHE.clear()
    _DELAY_CACHE.clear()


def contention_cache_sizes() -> Tuple[int, int]:
    """(draw entries, delay entries) currently memoized -- test hook."""
    return len(_DRAW_CACHE), len(_DELAY_CACHE)


def _make_conflict_draws(n_stores: int, seed: int, conflict_rate: float,
                         read_share: float,
                         pool: int = SHARER_POOL) -> Dict[str, np.ndarray]:
    """Draw the per-store conflict structure for one trace.

    Same run-length technique as ``simulator.synthesize_trace``:
    conflict episodes are a two-state chain over stores with stationary
    hot fraction ``conflict_rate`` and mean hot run
    :data:`CONFLICT_RUN_LEN`, materialized as alternating geometric run
    lengths + ``np.repeat``. Per store:

    * ``retries`` (i32) -- extra ownership attempts of a conflicted
      store: attempts are geometric (each re-races the conflictors with
      win probability ``1 - conflict_rate``), zero outside episodes;
    * ``sharers`` (i32) -- Shared copies to invalidate before owning
      the line: a Binomial(``pool``, read_share) census -- ``pool`` is
      the resolved sharer pool (directory-derived under
      ``_resolve_coupling``, :data:`SHARER_POOL` for a bare params) --
      zero outside episodes (an uncontended line was prefetched
      exclusive long before the SB head -- Fig. 7).
    """
    rng = np.random.default_rng([_RNG_SALT, seed])
    m = max(n_stores, 1)
    frac = float(np.clip(conflict_rate, 0.0, 0.98))
    if frac <= 0.0:
        hot = np.zeros(m, bool)
    else:
        p_leave_hot = 1.0 / CONFLICT_RUN_LEN
        cold_len = CONFLICT_RUN_LEN * (1.0 - frac) / max(frac, 1e-3)
        p_leave_cold = min(1.0 / max(cold_len, 1.0), 1.0)
        state0 = bool(rng.random() < frac)
        run_hot = rng.geometric(p_leave_hot, m)
        run_cold = rng.geometric(p_leave_cold, m)
        runs = np.empty(2 * m, dtype=np.int64)
        states = np.empty(2 * m, dtype=bool)
        first, second = (run_hot, run_cold) if state0 else (run_cold, run_hot)
        runs[0::2], runs[1::2] = first, second
        states[0::2], states[1::2] = state0, not state0
        k = int(np.searchsorted(np.cumsum(runs), m)) + 1
        hot = np.repeat(states[:k], runs[:k])[:m]

    retries = rng.geometric(max(1.0 - frac, 0.02), m) - 1
    retries = np.where(hot, retries, 0).astype(np.int32)
    sharers = rng.binomial(max(int(pool), 0),
                           np.clip(read_share, 0.0, 1.0), m)
    sharers = np.where(hot, sharers, 0).astype(np.int32)
    return {"retries": retries[:n_stores], "sharers": sharers[:n_stores]}


def conflict_draws(n_stores: int, seed: int, conflict_rate: float,
                   read_share: float,
                   pool: int = SHARER_POOL) -> Dict[str, np.ndarray]:
    """Memoized :func:`_make_conflict_draws` (read-only arrays)."""
    key = (n_stores, seed, conflict_rate, read_share, pool)
    return _DRAW_CACHE.get_or_put(
        key, lambda: _make_conflict_draws(*key))


def schedule_flush_ns(schedule: str, n_stores: int,
                      cluster: ClusterConfig) -> np.ndarray:
    """Per-store persist-barrier stall of a consistency schedule (f32 ns).

    ``"lazy"`` is all zeros (no ordering points); ``"eager"`` persists
    every store to the durable MN tier before the next may commit;
    ``"epoch"`` pays the same persist once per :data:`EPOCH_LEN` stores
    (at the epoch's last store). The stall rides the ``v`` side of the
    max-plus recurrence (REPL-ack / drain service), so barriers
    serialize the commit pipeline exactly as a persist fence would.
    """
    if schedule == "lazy":
        return np.zeros(n_stores, np.float32)
    t_flush = cluster.pmem_lat_ns
    if schedule == "eager":
        return np.full(n_stores, t_flush, np.float32)
    if schedule == "epoch":
        idx = np.arange(n_stores, dtype=np.int64)
        return np.where(idx % EPOCH_LEN == EPOCH_LEN - 1,
                        t_flush, 0.0).astype(np.float32)
    raise ValueError(f"unknown consistency_schedule {schedule!r}")


def _make_contention_arrays(params: ContentionParams, n_stores: int,
                            seed: int, cluster: ClusterConfig,
                            congestion: float
                            ) -> Tuple[np.ndarray, np.ndarray]:
    d = conflict_draws(n_stores, seed, params.conflict_rate,
                       params.read_share, params.sharer_pool)
    # one failed ownership attempt = a directory round trip + the
    # directory's DRAM state access; sharer invalidations serialize at
    # the home directory port (half an RTT each: INV out, ACK back,
    # overlapped across the return legs). Both scale with the same
    # link-congestion factor the base coherence latencies use.
    t_retry = cluster.cxl_rtt_ns + cluster.dram_lat_ns
    t_inval = 0.5 * cluster.cxl_rtt_ns
    delay = (d["retries"] * t_retry + d["sharers"] * t_inval) * congestion
    flush = schedule_flush_ns(params.schedule, n_stores, cluster)
    return delay.astype(np.float32), flush


def contention_arrays(params: ContentionParams, n_stores: int, seed: int,
                      cluster: ClusterConfig, congestion: float
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-store contention rows for one cell: ``(delay_ns, flush_ns)``,
    each ``(n_stores,)`` f32.

    ``delay`` (conflict retry backoff + sharer invalidations) is added
    to the exposed coherence latency -- the store's *ready* time
    absorbs it through the ``w`` side of the max-plus recurrence;
    ``flush`` (persist barriers of the consistency schedule) is added
    to the REPL-ack and drain-service terms -- the ``v`` side. With
    neutral params both rows are exactly zero, so ``x + row == x``
    bit-for-bit and the contended semantics degrade to the paper's.
    Memoized on the full row key (rows recur across every cell sharing
    the reduced derivation knobs)."""
    key = (params, n_stores, seed, cluster, congestion)
    return _DELAY_CACHE.get_or_put(
        key, lambda: _make_contention_arrays(params, n_stores, seed,
                                             cluster, congestion))


# ---------------------------------------------------------------------------
# Crash-exposure coupling into the SS VII-E recovery-time model
# ---------------------------------------------------------------------------

#: Dirty-state scale of each schedule: eager persists promptly (small
#: owned/dirty census at the crash point), epoch bounds it to one
#: epoch, lazy leaves the paper's full exposure.
_DIRTY_SCHED_SCALE = {"eager": 0.6, "epoch": 0.85, "lazy": 1.0}
#: Undumped-log scale: ordering points force the Logging Unit to flush
#: its pending entries at each barrier, so less log awaits replay.
_LOG_SCHED_SCALE = {"eager": 0.25, "epoch": 0.6, "lazy": 1.0}


def dirty_line_scale(params: ContentionParams) -> float:
    """Scale on the failed node's owned/dirty-line census.

    Conflicted ownership ping-pongs lines through the Owned state
    faster than they are written back (more dirty lines per node);
    read-heavy mixes keep more lines in Shared -- clean -- state;
    persist barriers shrink the window. Monotone increasing in
    ``conflict_rate``, decreasing in ``read_share`` and in schedule
    strictness; 1.0 at the neutral params."""
    return ((1.0 + 1.5 * params.conflict_rate)
            * (1.0 - 0.5 * params.read_share)
            * _DIRTY_SCHED_SCALE[params.schedule])


def undumped_log_scale(params: ContentionParams) -> float:
    """Scale on the undumped Logging-Unit volume at the failure point.

    Aborted-then-retried replication attempts of conflicted stores
    leave superseded entries the replay must still walk past; ordering
    points dump pending log early. 1.0 at the neutral params."""
    return (1.0 + 0.5 * params.conflict_rate) \
        * _LOG_SCHED_SCALE[params.schedule]


# ---------------------------------------------------------------------------
# Serial Python oracle for the contended semantics
# ---------------------------------------------------------------------------

def serial_oracle(spec, n_stores: int = 50_000,
                  cluster: ClusterConfig = PAPER_CLUSTER):
    """Differential-testing reference for the contended commit rules.

    A pure-Python per-store loop over the same prepared cell arrays the
    engines consume, applying the PRE-collapse commit rules of
    ``simulator._timeline`` (e.g. proactive
    ``c = max(max(r + t_repl, r + coh), c_prev + svc)``) in numpy f32
    scalar arithmetic -- IEEE add/max are exactly defined, so the loop
    and XLA produce identical bits. It therefore independently
    validates BOTH the contended cost derivation and the max-plus
    collapse the batched/banked engines rely on; every ``SimResult``
    field must match every engine tier ``==``
    (tests/test_contention.py). Returns a ``SimResult`` with
    ``meta={"engine": "contention-oracle"}``.
    """
    from repro.core import simulator as S   # deferred: no import cycle

    spec.validate(cluster)
    trace = S._trace_cached(spec.workload, n_stores, spec.seed, cluster)
    cell = S._prepare_cell(spec, trace, n_stores, cluster)
    costs = S._commit_cost_ns(spec.config, cluster)
    f32 = np.float32
    t_l1, t_wt = f32(costs["t_l1"]), f32(costs["t_wt"])
    a = np.asarray(cell.arrivals, np.float32)
    co = np.asarray(cell.coalesce, bool)
    coh = np.asarray(cell.exposed, np.float32)
    tr = np.asarray(cell.t_repl_i, np.float32)
    sv = np.asarray(cell.svc_i, np.float32)
    cfg = spec.config

    ring = collections.deque([f32(0.0)] * cell.sb_size)
    last = f32(0.0)
    at_head = sb_full = 0
    for i in range(n_stores):
        a_i = a[i]
        oldest = ring[0]
        r = np.maximum(a_i, oldest)
        if oldest > a_i:
            sb_full += 1
        if cfg == "wb":
            c = np.maximum(r, last) + t_l1
        elif cfg == "wt":
            c = np.maximum(r, last) + t_wt
        elif cfg == "baseline":
            extra = t_l1 if co[i] else coh[i] + tr[i]
            c = np.maximum(r, last) + extra
        elif cfg == "parallel":
            extra = t_l1 if co[i] else np.maximum(coh[i], tr[i])
            c = np.maximum(r, last) + extra
        elif cfg == "proactive":
            if co[i]:
                c = np.maximum(r, last) + t_l1
            else:
                c = np.maximum(np.maximum(r + tr[i], r + coh[i]),
                               last + sv[i])
                if r >= last:
                    at_head += 1
        else:
            raise ValueError(cfg)
        ring.popleft()
        ring.append(c)
        last = c
    return S._finish_result(cell, last, at_head, sb_full,
                            meta={"engine": "contention-oracle"})
