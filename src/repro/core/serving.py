"""Scenario-serving daemon: the engine as a long-lived service.

Every batch tier (``simulate_batch``, ``run_grid``) is call-oriented:
one grid in, results out, state dropped. The ROADMAP's north star is
the opposite shape -- millions of small "what if" queries against ONE
persistent platform, the way the paper's SS VIII evaluation amortizes
one cluster model across many workload x config x failure-time points.
:class:`ScenarioServer` is that layer: a stateful, latency-oriented
daemon over the banked engine that keeps everything expensive resident
and makes the marginal query cost proportional to what is genuinely
new about it.

How a query is served (docs/serving.md has the lifecycle diagram):

1. **Lane cache.** A query resolves to its scan lane -- ``(SB depth,
   trace row, max-plus row)``, via ``simulator._plane_keys``, the same
   dedup key the streaming engine scans by. If the lane was ever
   scanned before (by any earlier query or the warm grid), the answer
   is pure host math over the cached lane outputs: no device work, no
   upload, bit-identical to a cold run because ``_finish_result`` is
   the same code every other tier ends with.

2. **Incremental bank diffs.** A miss extends the server's
   :class:`~repro.core.simulator.TraceBank` in place
   (:meth:`TraceBank.extend` -- append-only, first-seen order, so the
   grown bank stays byte-identical to a from-scratch build of the
   merged grid) and ships ONLY the appended rows host->device: the
   device bank is **capacity-padded** (rows rounded up to
   :data:`SERVE_ROW_PAD`), so in-capacity appends splice the new rows
   into the resident buffers without changing the array shapes. The
   resident bank uses the engine's PER-SHARD SUB-BANK layout
   (``engine._place_sub_bank`` shape): arrivals replicated, the three
   max-plus planes stacked ``(n_shards, local_capacity, n_stores)``
   and partitioned over the ``cells`` mesh -- one padded copy of each
   wv row fleet-wide, row ``r`` owned by shard ``r % n_shards`` at
   local index ``r // n_shards``. Capacity is therefore PER SHARD:
   in-capacity wv appends splice a rectangular local-row window (at
   most ``n_shards - 1`` old rows re-ship) with one shard-local
   ``concatenate``, no cross-device traffic.

3. **Canonical batching.** Miss lanes are grouped and padded by
   ``engine.plan_tiles(small_pad=False)`` into the SAME canonical
   SB-uniform tile shapes the streaming engine compiles, and executed
   through ``engine.tile_fn`` -- so the compiled-program cache, the
   ``trace_count()`` accounting and the capacity-shape trick together
   give **zero new compiles in steady state**: once :meth:`warm` has
   compiled the (SB x capacity-shape) signatures, novel queries reuse
   them verbatim (tests/test_serving.py pins this at 100 mixed
   queries).

4. **Async batching window.** :meth:`submit` enqueues a query and
   returns a ``Future``; a daemon thread coalesces everything arriving
   within ``batch_window_ms`` (or up to ``batch_cells``) into one
   flush, so concurrent callers share tiles instead of paying one
   dispatch each.

5. **Bounded uptime state.** Both the lane-answer cache and the bank
   grow monotonically with the query universe by default; for
   week-long daemons ``max_lanes`` LRU-bounds the lane cache (least
   recently *asked* lane evicted first) and ``max_bank_rows`` triggers
   a bank **compaction** -- rebuild from the live cached lanes' specs,
   drop the device bank for a fresh capacity placement. Evicted lanes
   re-asked later take the ordinary miss path (extend + scan) and stay
   bit-identical; ``stats()`` counts ``lane_evictions`` /
   ``bank_compactions``.

Recovery questions ("what's my downtime if CN 3 dies mid-interval?")
bypass the store-level scan entirely: :meth:`query_downtime` delegates
to the closed-form SS VII-E model via
:func:`repro.core.scenarios.downtime_query`.

Thread safety: all serve state is guarded by one re-entrant lock, and
the shared host memos the flush path touches (`_trace_cached`,
`_cell_arrays`, `_wv_row`) are the PR-6 thread-safe caches. A racing
``clear_sim_caches()`` may drop compiled tile programs (the next flush
recompiles) and host memos (rebuilt on demand), but never the server's
bank handle or lane cache -- answers stay bit-identical throughout
(tests/test_serving.py races exactly this).
"""

from __future__ import annotations

import copy
import dataclasses
import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future
from typing import Deque, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.recxl_paper import ClusterConfig, PAPER_CLUSTER
from repro.core import chaos as _chaos
from repro.core import engine as _engine
from repro.core import telemetry as _tm
from repro.core.chaos import IntegrityError, ShardLossError, ThreadDeathError
from repro.core.recovery import RecoveryEstimate
from repro.core.scenarios import downtime_query, sweep_grid
from repro.core.simulator import (
    ScenarioSpec,
    SimResult,
    _commit_cost_ns,
    _finish_result,
    _plane_keys,
    _prepare_cell,
    _trace_cached,
    get_trace_bank,
)
from repro.distributed.context import cells_mesh
from repro.distributed.sharding import bank_shardings, sub_bank_shardings

#: Device-bank rows are padded up to the next multiple of this (with at
#: least one full spare block of headroom), so appending a novel
#: query's rows keeps the resident arrays' SHAPES -- and therefore the
#: tile signatures and compiled programs -- unchanged. 256 rows of
#: headroom absorb thousands of single-row queries between the (rare,
#: recompiling) capacity growths.
SERVE_ROW_PAD = 256

#: Default cells per serve tile: small enough that a single query's
#: flush stays cheap (the other ``b_pad - 1`` lanes are padding), large
#: enough that a burst amortizes one dispatch across many lanes.
SERVE_BATCH_CELLS = 64


def _row_capacity(rows: int, pad: int) -> int:
    """Smallest multiple of ``pad`` that is STRICTLY greater than
    ``rows`` -- the strict inequality guarantees spare rows, so a
    freshly-grown bank can always absorb at least one more append
    before the next capacity step."""
    return (rows // pad + 1) * pad


def _pad_rows(col: np.ndarray, cap: int) -> np.ndarray:
    """``col`` zero-padded along axis 0 to ``cap`` rows."""
    out = np.zeros((cap,) + col.shape[1:], col.dtype)
    out[:col.shape[0]] = col
    return out


class ScenarioServer:
    """Persistent in-process scenario-query daemon over the banked engine.

    Synchronous entry points (:meth:`query`, :meth:`query_batch`,
    :meth:`query_grid`, :meth:`query_downtime`) serve in the caller's
    thread; :meth:`submit` returns a ``concurrent.futures.Future`` and
    lets the daemon thread batch concurrent queries within
    ``batch_window_ms``. Every protocol answer is bit-identical
    (``==`` on every physics field) to the cold
    ``simulate_grid``/``simulate_spec`` oracle for the same spec --
    the server only ever reorganizes *which compiled program computes
    which lane when*, never the arithmetic.

    ``batch_cells`` is the canonical serve-tile size (every flush pads
    to it -- one compiled program per store-buffer depth);
    ``row_pad`` the device-bank capacity quantum (:data:`SERVE_ROW_PAD`;
    the wv capacity is PER-SHARD local rows, so the global headroom is
    ``~n_shards x row_pad``); ``n_shards`` > 1 shards flush tiles over
    the ``cells`` mesh exactly like the streaming engine's sub-bank
    layout (arrivals replicated, max-plus planes shard-partitioned,
    every miss lane scheduled onto the shard owning its wv row).
    ``max_lanes`` / ``max_bank_rows`` (both unbounded by default)
    LRU-bound the lane-answer cache and trigger bank compaction for
    long uptimes -- see the module docstring. Use as a context manager
    or call :meth:`close` to stop the daemon thread; a closed server
    still answers synchronous queries.

    **Resilience** (docs/resilience.md). ``k_replicas`` widens every
    wv capacity block with the paper's Replica set (default: 2 under an
    active ``chaos.inject`` scope, else 1 -- the exact PR-8 layout),
    and turns on the bank's Logging-Unit journal (un-dumped ``extend``
    diffs retained until the device dump is acknowledged at the end of
    each flush). A detected shard loss / corrupt row mid-flush is
    recovered IN PLACE: the lost rows are rebuilt from the surviving
    replica block or the journal, digest-verified, and the device bank
    re-placed at the SAME capacity -- same signatures, zero new
    compiles, answers stay bit-identical; pending ``submit`` futures
    fail only if recovery itself fails. ``submit_timeout_ms`` bounds
    how long a queued future may wait (per-call override on
    :meth:`submit`), ``watchdog_ms`` bounds one flush: a watchdog
    thread expires timed-out futures with a diagnostic, respawns a
    dead daemon thread, and fails a wedged flush's futures instead of
    blocking callers forever. The server always recovers on the
    spare-replacement path (its mesh never shrinks); the degraded-mesh
    fallback is the batch engine's.
    """

    def __init__(self, cluster: ClusterConfig = PAPER_CLUSTER,
                 n_stores: int = 50_000,
                 batch_cells: int = SERVE_BATCH_CELLS,
                 batch_window_ms: float = 2.0,
                 chunk_size: Optional[int] = None,
                 n_shards: int = 1,
                 row_pad: int = SERVE_ROW_PAD,
                 max_lanes: Optional[int] = None,
                 max_bank_rows: Optional[int] = None,
                 k_replicas: Optional[int] = None,
                 submit_timeout_ms: Optional[float] = None,
                 watchdog_ms: Optional[float] = None):
        n_dev = len(jax.devices())
        if not 1 <= n_shards <= n_dev:
            raise ValueError(f"n_shards must be in [1, {n_dev}], "
                             f"got {n_shards}")
        if batch_cells < 1:
            raise ValueError(f"batch_cells must be >= 1, got {batch_cells}")
        if row_pad < 1:
            raise ValueError(f"row_pad must be >= 1, got {row_pad}")
        if max_lanes is not None and max_lanes < 1:
            raise ValueError(f"max_lanes must be >= 1, got {max_lanes}")
        if max_bank_rows is not None and max_bank_rows < 2:
            raise ValueError("max_bank_rows must be >= 2 (one lane needs "
                             f"a trace and a wv row), got {max_bank_rows}")
        if submit_timeout_ms is not None and submit_timeout_ms <= 0:
            raise ValueError("submit_timeout_ms must be > 0, got "
                             f"{submit_timeout_ms}")
        if watchdog_ms is not None and watchdog_ms <= 0:
            raise ValueError(f"watchdog_ms must be > 0, got {watchdog_ms}")
        self.cluster = cluster
        self.n_stores = int(n_stores)
        self.batch_cells = int(batch_cells)
        self.batch_window_ms = float(batch_window_ms)
        self.chunk_size = chunk_size
        self.n_shards = int(n_shards)
        self.row_pad = int(row_pad)
        self.max_lanes = max_lanes
        self.max_bank_rows = max_bank_rows
        # resolved at construction: explicit k wins, else 2 under an
        # active chaos scope, else 1 (byte- and signature-identical to
        # the pre-resilience layout)
        self.k_replicas = _chaos.resolve_k_replicas(k_replicas,
                                                    self.n_shards)
        self.submit_timeout_ms = submit_timeout_ms
        self.watchdog_ms = watchdog_ms

        # serve state (all guarded by _lock)
        self._lock = threading.RLock()
        self._bank = None                               # TraceBank handle
        self._dev: Optional[tuple] = None               # capacity arrays
        self._cap: Tuple[int, int] = (0, 0)             # (trace, LOCAL wv)
        self._dev_rows: Tuple[int, int] = (0, 0)        # real rows resident
        # lane key -> (exec_ns, at_head, sb_full, representative spec);
        # insertion order IS recency order (move_to_end on every hit),
        # so eviction pops the least recently asked lane first and
        # compaction rebuilds the bank from exactly the live specs
        self._lanes: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._sigs: Set[_engine.TileSignature] = set()
        self._compact_floor = 0        # rows after the last compaction
        self._stats: Dict[str, int] = {
            "queries": 0, "lane_hits": 0, "lane_misses": 0,
            "scanned_lanes": 0, "flushes": 0, "batches": 0,
            "h2d_bytes": 0, "bank_uploads": 0, "bank_builds": 0,
            "appended_trace_rows": 0, "appended_wv_rows": 0,
            "compiled_programs": 0, "downtime_queries": 0,
            "lane_evictions": 0, "bank_compactions": 0,
            "recoveries": 0, "recovery_ms": 0,
        }

        # async queue (guarded by _cond; the worker serves via the
        # synchronous path, so _cond is never held across device work).
        # Queue entries are (spec, future, deadline-or-None); the
        # watchdog thread expires deadlines, respawns a dead worker and
        # fails a wedged flush -- its counters live in _wd_stats, also
        # guarded by _cond (the watchdog never takes _lock, so there is
        # no _cond/_lock ordering between the two threads)
        self._cond = threading.Condition()
        self._queue: Deque[Tuple[ScenarioSpec, Future,
                                 Optional[float]]] = deque()
        self._worker: Optional[threading.Thread] = None
        self._watchdog: Optional[threading.Thread] = None
        self._flush_started: Optional[float] = None
        self._flush_batch: List[tuple] = []
        self._wd_stats: Dict[str, int] = {
            "submit_timeouts": 0, "worker_restarts": 0,
            "watchdog_flush_failures": 0,
        }
        self._worker_spawned = False
        self._closed = False

    # -- context manager ---------------------------------------------------

    def __enter__(self) -> "ScenarioServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Stop the daemon thread after draining pending submissions.
        Synchronous queries still work on a closed server; further
        :meth:`submit` calls raise.

        Deterministic under concurrent submitters and worker death:
        racing ``submit`` calls either enqueued before the close (their
        futures are served or failed below, never left hanging) or
        raise. After the worker and watchdog exit, anything still
        queued (e.g. the worker died and no watchdog was there to
        respawn it) is failed with a diagnostic."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
            worker = self._worker
            watchdog = self._watchdog
        if worker is not None:
            worker.join()
        if watchdog is not None:
            watchdog.join()
        with self._cond:
            leftovers = list(self._queue)
            self._queue.clear()
        for e in leftovers:
            if not e[1].done():
                e[1].set_exception(RuntimeError(
                    "ScenarioServer closed with the query still pending "
                    "(daemon thread dead or never scheduled)"))

    # -- query -> lane plumbing -------------------------------------------

    def _lane_key(self, spec: ScenarioSpec) -> tuple:
        sb = spec.sb_size if spec.sb_size is not None \
            else self.cluster.store_buffer
        return (sb,) + _plane_keys(spec, self.cluster)

    def _journal_wanted(self) -> bool:
        """Logging-Unit journaling is on whenever resilience is: with a
        replica set placed, or under an active chaos scope (so a 1-shard
        server still has a rebuild source)."""
        return self.k_replicas > 1 or _chaos.active() is not None

    def _ensure_bank(self, specs: Sequence[ScenarioSpec]) -> None:
        """First call adopts the digest-memoized grid bank (shared with
        any engine sweeping the same grid); later calls append-extend
        it. The server keeps its own handle, so a racing
        ``clear_sim_caches()`` never forces a rebuild. Under a
        resilience config the bank journals its ``extend`` diffs (the
        Logging Unit) -- enabled BEFORE the extend so the diff itself
        is retained until :meth:`TraceBank.ack_journal`."""
        if self._bank is None:
            self._bank = get_trace_bank(specs, self.n_stores, self.cluster)
            if self._journal_wanted():
                self._bank.enable_journal()
            self._stats["bank_builds"] += 1
            return
        if self._journal_wanted():
            self._bank.enable_journal()
        nt, nw = self._bank.extend(specs)
        self._stats["appended_trace_rows"] += nt
        self._stats["appended_wv_rows"] += nw

    def _place_rows(self, host: tuple) -> tuple:
        _engine._h2d_hook(sum(int(x.nbytes) for x in host))
        if self.n_shards == 1:
            return tuple(jnp.asarray(x) for x in host)
        # replicate over the cells mesh the way _place_bank does: one
        # host->device crossing to device 0, fabric copies to the rest
        mesh = cells_mesh(self.n_shards)
        staged = jax.device_put(host, jax.devices()[0])
        sharding = bank_shardings(mesh)[0]
        return tuple(jax.device_put(x, sharding) for x in staged)

    def _place_sub(self, host: tuple) -> tuple:
        """Place ``(n_shards, local_rows, ...)`` stacks shard-partitioned
        on axis 0 (each device receives ONLY its slice straight from the
        host -- no fabric replication), plain arrays at one shard."""
        _engine._h2d_hook(sum(int(x.nbytes) for x in host))
        if self.n_shards == 1:
            return tuple(jnp.asarray(x) for x in host)
        mesh = cells_mesh(self.n_shards)
        sharding = sub_bank_shardings(mesh)[0]
        return tuple(jax.device_put(x, sharding) for x in host)

    def _sub_stack(self, col: np.ndarray, cap: int) -> np.ndarray:
        """Host sub-bank stack of ``col`` at local capacity ``cap``:
        ``out[s, q] = col[q * n_shards + s]`` (owner ``r % n_shards``,
        local index ``r // n_shards``), zero-padded per shard.

        With a replica set (``k_replicas > 1``) the local axis carries
        ``k`` capacity blocks: block ``j`` of shard ``s`` holds the
        rows owned by shard ``(s - j) % n_shards`` (the
        ``TraceBank.sub_bank_host`` layout at capacity), so global row
        ``r`` is resident on shards ``r % n`` AND ``(r % n + 1) % n``
        and one lost shard never loses a row. Gathers (and the compiled
        programs' shapes at ``k=1``) only ever touch block 0."""
        n = self.n_shards
        out = np.zeros((n, self.k_replicas * cap) + col.shape[1:],
                       col.dtype)
        for s in range(n):
            for j in range(self.k_replicas):
                rows = col[(s - j) % n::n]
                out[s, j * cap:j * cap + rows.shape[0]] = rows
        return out

    def _splice(self, dev, rows: np.ndarray, r0: int):
        """Splice ``rows`` into the replicated capacity array at row
        ``r0`` device-side (the only host->device bytes are ``rows``
        itself; the surrounding capacity rows never recross the link)."""
        delta = self._place_rows((np.ascontiguousarray(rows),))[0]
        return jnp.concatenate([dev[:r0], delta, dev[r0 + rows.shape[0]:]],
                               axis=0)

    def _sub_window(self, col: np.ndarray, lo: int, hi: int,
                    p: int) -> np.ndarray:
        """The ``(n_shards, hi - lo, ...)`` sub-stack window covering
        global rows ``[lo * n_shards, p)`` of ``col`` -- the local-row
        span ``[lo, hi)`` every shard splices in one rectangular block.
        Global row ``r = (q - lo) * n_shards + s + lo * n_shards`` lands
        at ``[s, q - lo]``; slots past ``p`` stay zero (unowned tail of
        the ragged last local row)."""
        n = self.n_shards
        span = np.zeros(((hi - lo) * n,) + col.shape[1:], col.dtype)
        span[:p - lo * n] = col[lo * n:p]
        return np.ascontiguousarray(
            span.reshape((hi - lo, n) + col.shape[1:]).swapaxes(0, 1))

    def _sync_device(self) -> int:
        """Bring the capacity-padded device sub-bank up to date with
        the host bank. Returns the bytes that crossed host->device: the
        whole padded bank on first placement or a capacity growth;
        otherwise just the appended arrivals rows plus the spliced
        local-row window (at most ``n_shards - 1`` old wv rows re-ship
        -- the rectangle is the price of one shard-uniform splice)."""
        bank = self._bank
        n = self.n_shards
        k = self.k_replicas
        t, p = bank.trace_rows, bank.wv_rows
        t_cap = _row_capacity(t, self.row_pad)
        p_cap = _row_capacity(-(-p // n), self.row_pad)   # per-shard local
        if self._dev is None or t_cap > self._cap[0] or p_cap > self._cap[1]:
            cap = (max(t_cap, self._cap[0]), max(p_cap, self._cap[1]))
            a_host = _pad_rows(bank.arrivals, cap[0])
            subs = (self._sub_stack(bank.w, cap[1]),
                    self._sub_stack(bank.v, cap[1]),
                    self._sub_stack(bank.pr_nc, cap[1]))
            self._dev = self._place_rows((a_host,)) + self._place_sub(subs)
            self._cap = cap
            self._dev_rows = (t, p)
            self._stats["bank_uploads"] += 1
            self._tamper()
            return int(a_host.nbytes) + sum(int(x.nbytes) for x in subs)
        h2d = 0
        a, w, v, pnc = self._dev
        t0, p0 = self._dev_rows
        if t > t0:
            a = self._splice(a, bank.arrivals[t0:t], t0)
            h2d += int(bank.arrivals[t0:t].nbytes)
        if p > p0:
            # local rows touched by global rows [p0, p): splice the
            # rectangular window [lo, hi) on every shard at once --
            # axis 1 of an axis-0-sharded array, so the concatenate is
            # shard-local (zero cross-device traffic). With a replica
            # set, block j's window is the block-0 window rolled j
            # shards along axis 0 (block j of shard s holds the rows
            # block 0 of shard (s - j) % n holds), spliced at its own
            # axis-1 offset -- every replica of an appended row ships
            # in the same flush, so a loss right after the splice
            # still rebuilds from the survivor
            lo, hi = p0 // n, -(-p // n)
            win0 = tuple(self._sub_window(c, lo, hi, p)
                         for c in (bank.w, bank.v, bank.pr_nc))
            for j in range(k):
                deltas = win0 if j == 0 else tuple(
                    np.ascontiguousarray(np.roll(d, j, axis=0))
                    for d in win0)
                dw, dv, dp = self._place_sub(deltas)
                o = j * self._cap[1]
                w = jnp.concatenate([w[:, :o + lo], dw, w[:, o + hi:]],
                                    axis=1)
                v = jnp.concatenate([v[:, :o + lo], dv, v[:, o + hi:]],
                                    axis=1)
                pnc = jnp.concatenate([pnc[:, :o + lo], dp,
                                       pnc[:, o + hi:]], axis=1)
                h2d += sum(int(d.nbytes) for d in deltas)
        if h2d:
            self._dev = (a, w, v, pnc)
            self._dev_rows = (t, p)
            self._tamper()
        return h2d

    def _tamper(self) -> None:
        """Chaos corruption point: bit-flip the configured wv row's
        resident device copy (fires once per scope; no-op otherwise)."""
        st = _chaos.active()
        if st is not None and self._dev is not None:
            self._dev = st.tamper_bank(self._dev, n_shards=self.n_shards,
                                       k_replicas=self.k_replicas,
                                       local_cap=self._cap[1],
                                       wv_rows=self._bank.wv_rows)

    def _serve_sigs(self, lane_specs: Sequence[ScenarioSpec]
                    ) -> List[Tuple[_engine.Tile, _engine.TileSignature]]:
        """Plan miss lanes into canonical serve tiles: the streaming
        engine's own scheduler at the serve-tile size, retargeted at
        the banked SUB layout with the CAPACITY shape (the signature
        the compiled programs are keyed on, stable across in-capacity
        appends). At more than one shard each lane is scheduled into
        the slot block of the shard owning its wv row, so the in-jit
        gather stays shard-local against the partitioned stacks."""
        owners = None
        if self.n_shards > 1:
            owners = [self._bank.rows_for(s)[1] % self.n_shards
                      for s in lane_specs]
        tiles = _engine.plan_tiles(lane_specs, cluster=self.cluster,
                                   n_stores=self.n_stores,
                                   chunk_size=self.chunk_size,
                                   tile_cells=self.batch_cells,
                                   n_shards=self.n_shards, small_pad=False,
                                   owners=owners)
        # the signature sees the DEVICE local axis: k_replicas capacity
        # blocks (identical to self._cap at k=1 -- the resilient and
        # plain layouts share programs only with themselves)
        shape = (self._cap[0], self.k_replicas * self._cap[1])
        return [(t, dataclasses.replace(t.sig, data_plane="bank",
                                        bank_shape=shape,
                                        bank_sub=True))
                for t in tiles]

    def _scan_lanes(self, miss: Dict[tuple, ScenarioSpec]) -> int:
        """Scan every miss lane once through ``engine.tile_fn`` and
        cache its raw outputs. Returns the index-vector h2d bytes."""
        lane_keys = list(miss)
        bank = self._bank
        st = _chaos.active()
        h2d = 0
        for tile, sig in self._serve_sigs([miss[k] for k in lane_keys]):
            trace_idx = np.zeros(sig.b_pad, np.int32)
            wv_idx = np.zeros(sig.b_pad, np.int32)
            slots = list(tile.slots) if tile.slots is not None \
                else list(range(len(tile.specs)))
            for s, pos in zip(tile.specs, slots):
                tr, wr = bank.rows_for(s)
                trace_idx[pos] = tr
                wv_idx[pos] = wr // self.n_shards    # shard-LOCAL row
            idx = (trace_idx, wv_idx)
            h2d += idx[0].nbytes + idx[1].nbytes
            if st is not None:
                if st.wants_verify():
                    # gather-path integrity sampling against the host
                    # truth, before this tile's rows are served
                    rows = sorted({bank.rows_for(s)[1]
                                   for s in tile.specs})
                    _chaos.verify_rows(
                        bank, self._dev,
                        rows[:_engine.VERIFY_ROWS_PER_TILE],
                        n_shards=self.n_shards, local_cap=self._cap[1],
                        where="serve gather sample")
                st.on_dispatch("serve flush")

            def place(args=idx, s=sig):
                _engine._h2d_hook(args[0].nbytes + args[1].nbytes)
                return _engine._place_tile(args, s)

            out = _engine.tile_fn(sig)(*self._dev,
                                       *_engine._retried(
                                           place, "serve tile placement"))
            exec_ns, at_head, sb_full = (np.asarray(o) for o in out)
            for i, pos in zip(tile.indices, slots):
                key = lane_keys[i]
                self._lanes[key] = (exec_ns[pos], int(at_head[pos]),
                                    int(sb_full[pos]), miss[key])
            self._sigs.add(sig)
        return h2d

    def _evict(self) -> None:
        """LRU-bound the serve state (end of every flush, under _lock):
        pop least-recently-asked lanes past ``max_lanes``, and when the
        append-only bank has outgrown ``max_bank_rows``, COMPACT it --
        rebuild from the live cached lanes' specs and drop the device
        bank so the next flush re-places at the compacted capacity (a
        rare recompile if the capacity shape shrank). ``_compact_floor``
        stops back-to-back rebuilds when the live lanes alone exceed
        the bound: another compaction only fires after real growth."""
        st = self._stats
        if self.max_lanes is not None:
            while len(self._lanes) > self.max_lanes:
                self._lanes.popitem(last=False)
                st["lane_evictions"] += 1
        if (self.max_bank_rows is not None and self._bank is not None
                and self._bank.n_rows > max(self.max_bank_rows,
                                            self._compact_floor)
                and self._lanes):
            live = [entry[3] for entry in self._lanes.values()]
            self._bank = get_trace_bank(live, self.n_stores, self.cluster)
            self._dev = None
            self._cap = (0, 0)
            self._dev_rows = (0, 0)
            self._compact_floor = self._bank.n_rows
            st["bank_compactions"] += 1

    def _recover(self, err: Exception) -> None:
        """Spare-replacement recovery of the serve bank (under _lock):
        rebuild the lost shard's rows from the surviving replica block
        (or the Logging-Unit journal at ``k_replicas=1``),
        digest-verify them against the host truth, then drop ONLY the
        device placement -- capacity is KEPT, so the next
        :meth:`_sync_device` re-places identical shapes and signatures
        and post-recovery serving adds zero compiles
        (tests/test_chaos.py pins both)."""
        t0 = time.monotonic()
        lost = err.shard if isinstance(err, ShardLossError) else None
        source = "replace"
        with _tm.span("recover", error=type(err).__name__):
            with _tm.span("recover/detect", error=type(err).__name__):
                _tm.count("chaos/faults_detected")
            if lost is not None:
                # the serve mesh never shrinks: validate the spare
                # takeover through the elastic-scaling policy shared
                # with run_grid
                from repro.distributed.elastic import \
                    cells_spare_replacement
                cells_spare_replacement(self.n_shards, lost)
                with _tm.span("recover/rebuild", shard=lost):
                    if self.k_replicas >= 2 and self._dev is not None:
                        rebuilt = _chaos.replica_rebuild(
                            self._dev, lost, n_shards=self.n_shards,
                            k_replicas=self.k_replicas,
                            local_cap=self._cap[1],
                            wv_rows=self._bank.wv_rows)
                        source = "replica"
                    elif self._bank.journal_enabled:
                        rebuilt = _chaos.journal_rebuild(
                            self._bank, lost, self.n_shards)
                        source = "journal"
                    else:
                        rebuilt = None
                        source = "host"
                    if rebuilt is not None:
                        _chaos.verify_rebuild(self._bank, rebuilt, lost,
                                              self.n_shards)
            with _tm.span("recover/replace", source=source):
                # drop only the placement; the next _sync_device
                # re-places identical shapes (the re-place leg)
                self._dev = None
                self._dev_rows = (0, 0)
        ms = (time.monotonic() - t0) * 1e3
        self._stats["recoveries"] += 1
        self._stats["recovery_ms"] += ms
        st = _chaos.active()
        if st is not None:
            st.note_recovery(source, ms, lost, "spare")

    # -- synchronous serving ----------------------------------------------

    def query(self, spec: ScenarioSpec) -> SimResult:
        """Serve one scenario cell (bit-identical to the cold oracle)."""
        return self.query_batch([spec])[0]

    def query_batch(self, specs: Sequence[ScenarioSpec]) -> List[SimResult]:
        """Serve a batch of cells in one flush, in ``specs`` order.

        Hits are answered from the lane cache; the distinct miss lanes
        are scanned once through the canonical serve tiles after the
        bank diff (new rows only) is spliced into the resident device
        bank. ``SimResult.meta`` records the serve provenance per cell:
        ``cache`` (``"hit"``/``"miss"``), the flush's marginal
        ``h2d_bytes``, and the bank geometry that answered it."""
        specs = list(specs)
        if not specs:
            return []
        t_flush0 = time.perf_counter()
        for s in specs:
            s.validate(self.cluster)
        with self._lock, _tm.span("serve/flush", queries=len(specs)):
            self._ensure_bank(specs)
            compiled0 = _engine.trace_count()
            attempts = 0
            while True:
                # one serve attempt: bank dump (diff splice), miss
                # resolution, lane scan. A detected fault recovers the
                # device bank in place and re-enters -- lanes scanned
                # before the fault are cache hits on the retry, so no
                # lane is ever served from a suspect placement twice
                try:
                    with _tm.span("serve/bank_sync"):
                        h2d = _engine._retried(self._sync_device,
                                               "serve bank sync")
                    keys = [self._lane_key(s) for s in specs]
                    miss: Dict[tuple, ScenarioSpec] = {}
                    for s, k in zip(specs, keys):
                        if k in self._lanes:
                            self._lanes.move_to_end(k)      # LRU touch
                        else:
                            miss.setdefault(k, s)
                    if miss:
                        with _tm.span("serve/scan", lanes=len(miss)):
                            h2d += self._scan_lanes(miss)
                    break
                except (ShardLossError, IntegrityError) as e:
                    attempts += 1
                    if (_chaos.active() is None
                            or attempts > _engine.MAX_RECOVERIES):
                        raise
                    self._recover(e)
            if self._bank.journal_enabled:
                # the device dump (capacity bank + this flush's diffs)
                # is resident: the Logging Unit's retained copies are
                # acknowledged away
                self._bank.ack_journal()
            st = self._stats
            st["queries"] += len(specs)
            st["lane_misses"] += sum(k in miss for k in keys)
            st["lane_hits"] += sum(k not in miss for k in keys)
            st["scanned_lanes"] += len(miss)
            st["h2d_bytes"] += h2d
            st["compiled_programs"] += _engine.trace_count() - compiled0
            st["flushes"] += 1
            results = []
            for s, k in zip(specs, keys):
                exec_ns, at_head, sb_full, _ = self._lanes[k]
                cell = _prepare_cell(
                    s, _trace_cached(s.workload, self.n_stores, s.seed,
                                     self.cluster),
                    self.n_stores, self.cluster)
                meta = {"engine": "serving", "data_plane": "bank",
                        "bank_partition": "sub",
                        "cache": "miss" if k in miss else "hit",
                        "h2d_bytes": h2d,
                        "bank_rows": self._bank.n_rows,
                        "bank_capacity": self._cap,
                        "n_shards": self.n_shards}
                results.append(_finish_result(cell, exec_ns, at_head,
                                              sb_full, meta=meta))
            self._evict()       # after results: this flush's lanes live
            rec = _tm.active()
            if rec is not None:
                # each query's serve-side latency is its flush's wall
                # time (sync callers see exactly this); hits and misses
                # feed separate histograms so the lane-cache fast path
                # stays attributable
                dt_ms = (time.perf_counter() - t_flush0) * 1e3
                rec.count("serve/lane_hits",
                          sum(k not in miss for k in keys))
                rec.count("serve/lane_misses",
                          sum(k in miss for k in keys))
                for k in keys:
                    rec.observe("serve/query_ms", dt_ms)
                    rec.observe("serve/query_miss_ms" if k in miss
                                else "serve/query_hit_ms", dt_ms)
            return results

    def query_grid(self, **axes) -> List[SimResult]:
        """Serve a whole :func:`~repro.core.scenarios.sweep_grid`
        cross-product (the *grid delta* query shape: cells already
        served are lane-cache hits, genuinely new cells ride the
        diff-upload path; :func:`repro.core.scenarios.grid_delta`
        computes just the novel cells if the caller wants them alone).
        """
        return self.query_batch(sweep_grid(**axes))

    def query_downtime(self, workload: str, fail_time_ms: float,
                       **knobs) -> RecoveryEstimate:
        """Answer a "what's my downtime if ..." request through the
        closed-form SS VII-E model (no store-level scan involved);
        ``knobs`` are :func:`repro.core.scenarios.downtime_query`
        keywords (``n_cns``, ``n_replicas``, ``link_bw_gbps``, the
        contention axes, ``directory_load``)."""
        with self._lock:
            self._stats["downtime_queries"] += 1
        return downtime_query(workload, fail_time_ms,
                              cluster=self.cluster, **knobs)

    # -- warm pool ---------------------------------------------------------

    def warm(self, specs: Sequence[ScenarioSpec],
             populate: bool = True) -> None:
        """Make the server hot for a grid: build/extend the bank, place
        the capacity device bank, and compile every serve-tile program
        the grid's store-buffer depths need (``engine.warm_signatures``
        against the resident capacity bank, so warm calls see exactly
        the live flush shardings). With ``populate=True`` (default) the
        whole grid is additionally served once, so every lane of it is
        a cache hit afterwards; ``populate=False`` only compiles."""
        specs = list(specs)
        if not specs:
            return
        if populate:
            self.query_batch(specs)
            return
        for s in specs:
            s.validate(self.cluster)
        with self._lock:
            self._ensure_bank(specs)
            self._sync_device()
            lanes: Dict[tuple, ScenarioSpec] = {}
            for s in specs:
                lanes.setdefault(self._lane_key(s), s)
            sigs = list(dict.fromkeys(
                sig for _, sig in self._serve_sigs(list(lanes.values()))))
            costs = _commit_cost_ns("proactive", self.cluster)
            compiled0 = _engine.trace_count()
            _engine.warm_signatures(sigs, np.float32(costs["t_l1"]),
                                    np.float32(costs["t_wt"]),
                                    bank_dev=self._dev)
            self._sigs.update(sigs)
            self._stats["compiled_programs"] += \
                _engine.trace_count() - compiled0

    # -- async batching ----------------------------------------------------

    def submit(self, spec: ScenarioSpec,
               timeout_ms: Optional[float] = None) -> "Future[SimResult]":
        """Enqueue one query; the daemon thread coalesces everything
        arriving within ``batch_window_ms`` (or up to ``batch_cells``
        entries) into one flush and resolves each Future with its
        :class:`SimResult`.

        ``timeout_ms`` (default: the server's ``submit_timeout_ms``)
        bounds the future: if it is still pending past the deadline --
        queued behind a dead daemon, or inside a wedged flush -- the
        watchdog fails it with a :class:`TimeoutError` carrying the
        queue diagnostics instead of blocking the caller forever."""
        spec.validate(self.cluster)
        if timeout_ms is None:
            timeout_ms = self.submit_timeout_ms
        elif timeout_ms <= 0:
            raise ValueError(f"timeout_ms must be > 0, got {timeout_ms}")
        deadline = (time.monotonic() + timeout_ms / 1e3
                    if timeout_ms is not None else None)
        fut: Future = Future()
        with self._cond:
            if self._closed:
                raise RuntimeError("ScenarioServer is closed")
            # 4th slot: enqueue time, so the daemon can attribute queue
            # wait vs batching-window wait per entry (telemetry)
            self._queue.append((spec, fut, deadline, time.monotonic()))
            if self._worker is None or not self._worker.is_alive():
                self._start_worker_locked()
            self._cond.notify_all()
        return fut

    def _start_worker_locked(self) -> None:
        """Spawn the daemon (and its watchdog) -- caller holds _cond.
        Any spawn after the first replaces a dead worker, so it counts
        as a ``worker_restarts`` no matter which path noticed the body
        (the watchdog sweep or a racing ``submit``)."""
        if self._worker_spawned:
            self._wd_stats["worker_restarts"] += 1
        self._worker_spawned = True
        self._worker = threading.Thread(
            target=self._serve_loop, name="scenario-server", daemon=True)
        self._worker.start()
        if self._watchdog is None or not self._watchdog.is_alive():
            self._watchdog = threading.Thread(
                target=self._watchdog_loop, name="scenario-server-watchdog",
                daemon=True)
            self._watchdog.start()

    def _serve_loop(self) -> None:
        try:
            while True:
                with self._cond:
                    while not self._queue and not self._closed:
                        self._cond.wait()
                    if not self._queue:          # closed and drained
                        return
                    # chaos kill point BEFORE the queue is popped: a
                    # killed daemon leaves every pending entry intact
                    # for the respawned worker (or close()) to serve
                    st = _chaos.active()
                    if st is not None:
                        st.on_thread("daemon")
                    # batching window: linger for stragglers so
                    # concurrent submitters share one flush instead of
                    # paying one each
                    t_win0 = time.monotonic()
                    deadline = t_win0 + self.batch_window_ms / 1e3
                    while (not self._closed
                           and len(self._queue) < self.batch_cells):
                        left = deadline - time.monotonic()
                        if left <= 0:
                            break
                        self._cond.wait(left)
                    # expired/cancelled futures never reach a flush
                    batch = [e for e in self._queue if not e[1].done()]
                    self._queue.clear()
                    now = time.monotonic()
                    self._flush_started = now
                    self._flush_batch = batch
                    rec = _tm.active()
                    if rec is not None and batch:
                        # batching-window linger, plus each entry's time
                        # spent queued before this flush picked it up
                        rec.observe("serve/window_wait_ms",
                                    (now - t_win0) * 1e3)
                        for e in batch:
                            if len(e) > 3:
                                rec.observe("serve/queue_wait_ms",
                                            (now - e[3]) * 1e3)
                if not batch:
                    continue
                with self._lock:
                    self._stats["batches"] += 1
                try:
                    results = self.query_batch([e[0] for e in batch])
                except BaseException as e:   # surface to every waiter
                    for entry in batch:
                        if not entry[1].done():
                            entry[1].set_exception(e)
                    continue
                finally:
                    with self._cond:
                        self._flush_started = None
                        self._flush_batch = []
                for entry, res in zip(batch, results):
                    if not entry[1].done():
                        entry[1].set_result(res)
        except ThreadDeathError:
            pass          # injected death: the watchdog/submit respawns
        finally:
            with self._cond:
                if self._worker is threading.current_thread():
                    self._worker = None
                self._flush_started = None
                self._flush_batch = []
                self._cond.notify_all()

    def _watchdog_loop(self) -> None:
        """Liveness sidecar of the serve loop (runs whenever a worker
        does; only ever takes _cond). Three duties: fail futures past
        their ``submit`` deadline with a diagnostic; respawn a daemon
        thread that died with work queued; fail a wedged flush's
        futures after ``watchdog_ms`` so callers never block on a hung
        device instead of an answer."""
        while True:
            with self._cond:
                if self._closed and not self._queue \
                        and self._flush_started is None:
                    self._watchdog = None
                    return
                now = time.monotonic()
                expired = [e for e in self._queue
                           if e[2] is not None and now > e[2]]
                for e in expired:
                    self._queue.remove(e)
                    self._wd_stats["submit_timeouts"] += 1
                    if not e[1].done():
                        e[1].set_exception(TimeoutError(
                            f"submit({e[0].workload!r}, {e[0].config!r}) "
                            f"timed out awaiting flush (queue depth "
                            f"{len(self._queue)}, daemon "
                            f"{'alive' if self._worker is not None else 'dead'})"))
                # a deadline can also expire mid-flush (entry already
                # popped into the in-flight batch but the flush is stuck
                # behind a wedged device/lock) -- fail the future in
                # place; the serve loop's set_result is done()-guarded
                for e in self._flush_batch:
                    if e[2] is not None and now > e[2] and not e[1].done():
                        self._wd_stats["submit_timeouts"] += 1
                        e[1].set_exception(TimeoutError(
                            f"submit({e[0].workload!r}, {e[0].config!r}) "
                            f"timed out mid-flush (flush running "
                            f"{(now - (self._flush_started or now)) * 1e3:.0f}"
                            f" ms, batch of {len(self._flush_batch)})"))
                if self._queue and (self._worker is None
                                    or not self._worker.is_alive()):
                    self._start_worker_locked()
                if (self.watchdog_ms is not None
                        and self._flush_started is not None
                        and (now - self._flush_started) * 1e3
                        > self.watchdog_ms):
                    stuck = self._flush_batch
                    self._flush_started = None
                    self._flush_batch = []
                    self._wd_stats["watchdog_flush_failures"] += 1
                    for e in stuck:
                        if not e[1].done():
                            e[1].set_exception(TimeoutError(
                                f"serve flush exceeded watchdog_ms="
                                f"{self.watchdog_ms} (daemon wedged; "
                                f"{len(stuck)} queries failed)"))
                self._cond.wait(0.02)

    # -- observability -----------------------------------------------------

    def stats(self) -> Dict[str, object]:
        """Serve counters plus derived state: ``hit_ratio`` (lane-cache
        hits over queries), ``lanes_cached``, bank geometry
        (``bank_rows`` real rows, ``bank_bytes`` -- the cost of one
        COLD full-bank upload, the baseline the marginal ``h2d_bytes``
        is measured against -- ``bank_capacity`` as ``(trace rows,
        per-shard local wv rows)``, and MEASURED resident device bytes
        ``bank_dev_bytes`` / ``bank_dev_bytes_per_shard`` summed from
        the live capacity buffers), the LRU counters
        (``lane_evictions`` / ``bank_compactions``), and ``pending``
        queue depth.

        The returned dict is a DEEP-COPIED snapshot taken under the
        server lock: callers can hold it across later queries (or
        mutate it) without ever observing -- or perturbing -- the live
        counters mid-update (tests/test_serving.py races exactly this).
        When telemetry is on (``repro.core.telemetry``), a
        ``"telemetry"`` sub-dict carries the flight-recorder summary
        (per-stage span histograms incl. ``serve/query_ms`` p50/p99,
        queue/window waits, protocol counters)."""
        with self._lock:
            st: Dict[str, object] = copy.deepcopy(self._stats)
            q = self._stats["queries"]
            st["hit_ratio"] = self._stats["lane_hits"] / q if q else 0.0
            st["lanes_cached"] = len(self._lanes)
            st["bank_rows"] = self._bank.n_rows if self._bank else 0
            st["bank_bytes"] = self._bank.nbytes if self._bank else 0
            st["bank_capacity"] = self._cap
            st["dev_rows"] = self._dev_rows
            st["bank_partition"] = "sub"
            st["k_replicas"] = self.k_replicas
            st["journal_entries"] = (self._bank.journal_entries
                                     if self._bank is not None else 0)
            total, per = _engine._measured_device_bytes(
                self._dev if self._dev is not None else ())
            st["bank_dev_bytes"] = total
            st["bank_dev_bytes_per_shard"] = per
        with self._cond:
            st["pending"] = len(self._queue)
            st.update(copy.deepcopy(self._wd_stats))
        rec = _tm.active()
        if rec is not None:
            st["telemetry"] = rec.summary()
        return st

    def reset_stats(self) -> None:
        """Zero the counters (bank, lane cache and compiled programs
        stay hot) -- benchmarks call this after :meth:`warm` so the
        reported ratios describe live traffic only."""
        with self._lock:
            for k in self._stats:
                self._stats[k] = 0
