"""Failure detection + injection (paper SS V.A adapted).

The paper's switch keeps one Viral_Status bit per CN, never answers on a
failed CN's behalf, and MSIs a live core to start recovery. The trainer's
control plane mirrors that:

* :class:`FailureDetector` -- lease-based heartbeats; a node whose lease
  expires gets its viral bit set and is never "answered for" (its device
  state is treated as gone, not as zeros);
* :class:`FailureInjector` -- deterministic fault schedule for tests,
  examples and benchmarks (fail node f at step s; also straggler
  injection: delay node f by d seconds for straggler-mitigation tests).

On this single-process container, "nodes" are data-axis ranks of the
simulated mesh; injection marks ranks failed and recovery must not read
their shards.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Set, Tuple


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    step: int
    node: int
    kind: str = "fail-stop"          # fail-stop | straggler
    delay_s: float = 0.0             # straggler delay


class FailureInjector:
    """Deterministic failure schedule."""

    def __init__(self, events: Sequence[FailureEvent] = ()):  # noqa: D401
        self.events = sorted(events, key=lambda e: e.step)
        self.fired: List[FailureEvent] = []

    def poll(self, step: int) -> List[FailureEvent]:
        out = []
        while self.events and self.events[0].step <= step:
            ev = self.events.pop(0)
            self.fired.append(ev)
            out.append(ev)
        return out


class FailureDetector:
    """Lease-based detector with per-node Viral_Status bits.

    ``heartbeat(node)`` renews a lease; ``check(now)`` expires leases and
    returns newly-failed nodes. The trainer heartbeats every live rank
    each step; injected failures simply stop heartbeating (fail-stop).
    """

    def __init__(self, n_nodes: int, lease_s: float = 5.0):
        self.n_nodes = n_nodes
        self.lease_s = lease_s
        now = time.monotonic()
        self.last_seen: Dict[int, float] = {n: now for n in range(n_nodes)}
        self.viral_status: List[bool] = [False] * n_nodes
        self.stragglers: Dict[int, float] = {}

    # ------------------------------------------------------------------
    def heartbeat(self, node: int, now: Optional[float] = None) -> None:
        if self.viral_status[node]:
            return                    # failed nodes never come back (fail-stop)
        self.last_seen[node] = time.monotonic() if now is None else now

    def mark_failed(self, node: int) -> None:
        """Immediate viral-bit set (switch-detected failure)."""
        self.viral_status[node] = True

    def mark_straggler(self, node: int, delay_s: float) -> None:
        self.stragglers[node] = delay_s

    def check(self, now: Optional[float] = None) -> List[int]:
        """Expire leases; returns newly failed nodes."""
        now = time.monotonic() if now is None else now
        newly = []
        for n in range(self.n_nodes):
            if self.viral_status[n]:
                continue
            if now - self.last_seen[n] > self.lease_s:
                self.viral_status[n] = True
                newly.append(n)
        return newly

    # ------------------------------------------------------------------
    @property
    def live_nodes(self) -> List[int]:
        return [n for n in range(self.n_nodes) if not self.viral_status[n]]

    @property
    def failed_nodes(self) -> List[int]:
        return [n for n in range(self.n_nodes) if self.viral_status[n]]

    def configuration_manager(self) -> int:
        """The live core the MSI lands on: lowest live rank (SS V.A)."""
        live = self.live_nodes
        if not live:
            raise RuntimeError("no live nodes: cluster lost")
        return live[0]
