"""Flight-recorder telemetry: spans, counters, gauges, trace export.

The repo's engine tiers, serving daemon, and chaos subsystem all need
per-stage time attribution (where do the 12 960-cell mega-grid seconds
go? what fraction of a served query is queue wait vs flush?) without
perturbing the numbers they measure.  This module is that recorder:

* ``span(name)`` — a nested-span context manager.  Spans record Chrome
  trace-event ``B``/``E`` pairs into a per-thread ring buffer and feed
  a per-name duration histogram (count / total / p50 / p99).
* ``count(name, n)`` — monotonic counters (protocol messages, cache
  hits, retries).
* ``gauge(name, value)`` — last-value-wins instantaneous readings
  (prefetch queue depth, in-flight tiles).
* ``observe(name, value)`` — one sample of an arbitrary-unit
  distribution (per-query latency in ms, directory occupancy).

**Off by default, near-zero cost.**  The module-level fast path is one
global load + ``None`` check; ``span()`` returns a shared no-op context
manager when disabled.  Enable with ``RECXL_TELEMETRY=1`` in the
environment, ``telemetry.enable()``, or the scoped
``with telemetry.recording() as rec:``.  Telemetry NEVER changes
numerical results, memo keys, bank bytes, or compile counts — pinned by
``tests/test_telemetry.py`` (the zero-churn discipline of PRs 5/6/9).

**Lock-free-ish rings.**  Each thread appends to its own ``_ThreadLog``
(created once under the recorder lock, then touched only by its owner
thread), so steady-state recording takes no locks.  Rings are bounded:
when full, the oldest half is dropped in one slice — a flight recorder
keeps the most recent window.  Aggregates (histograms, counters) are
kept separately and survive ring wrap.

**Export.**  ``export_chrome(path)`` writes Chrome trace-event JSONL —
one event object per line — loadable at https://ui.perfetto.dev.
``summary()`` merges every thread into one plain dict (the thing that
flows into ``ScenarioServer.stats()``, streamed ``SimResult.meta``, and
BENCH rows).  ``validate_chrome_trace(path)`` is the schema check CI
and tests share: every ``B`` has a matching ``E``, thread ids resolve
to thread-name metadata.

Span taxonomy and counter units are documented in
``docs/observability.md``.
"""

from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import Any, Dict, IO, List, Optional, Tuple, Union

__all__ = [
    "Recorder",
    "active",
    "count",
    "disable",
    "enable",
    "enabled",
    "export_chrome",
    "gauge",
    "observe",
    "recording",
    "reset",
    "span",
    "summary",
    "validate_chrome_trace",
]

#: Default per-thread ring capacity, in events (a span costs two).
DEFAULT_RING_EVENTS = 65536

#: Per-(thread, name) duration/value samples kept for percentiles.
#: Beyond this the histogram keeps count/total/max exactly but stops
#: collecting new percentile samples (first-window reservoir).
MAX_SAMPLES = 8192


class _NoopSpan:
    """The disabled-path span: a shared, stateless context manager."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc: object) -> bool:
        return False


_NOOP_SPAN = _NoopSpan()


class _ThreadLog:
    """One thread's ring buffer + aggregates.  Owner-thread-only writes."""

    __slots__ = ("tid", "os_tid", "name", "cap", "events", "n_dropped",
                 "stack", "spans", "dists", "counters", "gauges")

    def __init__(self, tid: int, os_tid: Optional[int], name: str,
                 cap: int) -> None:
        self.tid = tid          # stable export tid (registration order)
        self.os_tid = os_tid    # threading ident, informational
        self.name = name
        self.cap = cap
        # Ring events are tuples (ph, t_ns, name, payload):
        #   ("B", t, name, args-dict-or-None)   span open
        #   ("E", t, name, None)                span close
        #   ("C", t, name, value)               counter/gauge sample
        #   ("X", t, name, dur_ns)              complete event (observe)
        self.events: List[Tuple[str, int, str, Any]] = []
        self.n_dropped = 0
        self.stack: List[str] = []
        # name -> [count, total_ns, max_ns, samples]
        self.spans: Dict[str, List[Any]] = {}
        # name -> [count, total, max, samples]  (raw units)
        self.dists: Dict[str, List[Any]] = {}
        self.counters: Dict[str, float] = {}
        # name -> (t_ns, value): last-wins merged by timestamp
        self.gauges: Dict[str, Tuple[int, float]] = {}

    def push(self, ev: Tuple[str, int, str, Any]) -> None:
        if len(self.events) >= self.cap:
            drop = max(1, self.cap // 2)
            del self.events[:drop]
            self.n_dropped += drop
        self.events.append(ev)


def _obs(table: Dict[str, List[Any]], name: str, value: float) -> None:
    st = table.get(name)
    if st is None:
        st = table[name] = [0, 0.0, 0.0, []]
    st[0] += 1
    st[1] += value
    if value > st[2]:
        st[2] = value
    if len(st[3]) < MAX_SAMPLES:
        st[3].append(value)


class _Span:
    """Live span: records B/E events and feeds the duration histogram."""

    __slots__ = ("_rec", "_name", "_args", "_log", "_t0")

    def __init__(self, rec: "Recorder", name: str,
                 args: Optional[Dict[str, Any]]) -> None:
        self._rec = rec
        self._name = name
        self._args = args

    def __enter__(self) -> "_Span":
        log = self._rec._log()
        self._log = log
        t0 = time.perf_counter_ns()
        self._t0 = t0
        log.push(("B", t0, self._name, self._args))
        log.stack.append(self._name)
        return self

    def __exit__(self, *exc: object) -> bool:
        t1 = time.perf_counter_ns()
        log = self._log
        # Context managers unwind LIFO, so the top of the stack is us.
        if log.stack and log.stack[-1] == self._name:
            log.stack.pop()
        log.push(("E", t1, self._name, None))
        _obs(log.spans, self._name, t1 - self._t0)
        return False


class Recorder:
    """A telemetry session: per-thread logs plus merge/export views."""

    def __init__(self, ring_events: int = DEFAULT_RING_EVENTS) -> None:
        self.ring_events = int(ring_events)
        self.pid = os.getpid()
        self.t0_ns = time.perf_counter_ns()
        self._lock = threading.Lock()
        self._logs: List[_ThreadLog] = []
        self._tls = threading.local()

    # -- recording (hot path) -------------------------------------------

    def _log(self) -> _ThreadLog:
        log = getattr(self._tls, "log", None)
        if log is None:
            t = threading.current_thread()
            with self._lock:
                log = _ThreadLog(len(self._logs) + 1, t.ident, t.name,
                                 self.ring_events)
                self._logs.append(log)
            self._tls.log = log
        return log

    def span(self, name: str,
             args: Optional[Dict[str, Any]] = None) -> _Span:
        return _Span(self, name, args)

    def count(self, name: str, n: float = 1, ev: bool = True) -> None:
        """``ev=False`` updates the aggregate only (no ring event):
        the cheap mode for per-cell hot paths -- a counter sampled tens
        of thousands of times per run would wrap the event tape anyway,
        and its ``summary()`` total is what consumers read."""
        log = self._log()
        total = log.counters.get(name, 0) + n
        log.counters[name] = total
        if ev:
            log.push(("C", time.perf_counter_ns(), name, total))

    def gauge(self, name: str, value: float) -> None:
        log = self._log()
        t = time.perf_counter_ns()
        log.gauges[name] = (t, value)
        log.push(("C", t, name, value))

    def observe(self, name: str, value: float, ev: bool = True) -> None:
        log = self._log()
        _obs(log.dists, name, value)
        if ev:
            log.push(("X", time.perf_counter_ns(), name, value))

    # -- merge / export --------------------------------------------------

    def _snapshot_logs(self) -> List[_ThreadLog]:
        with self._lock:
            return list(self._logs)

    def summary(self) -> Dict[str, Any]:
        """Merge every thread into one plain-dict summary.

        ``spans`` durations are reported in milliseconds; ``dists``
        (from :meth:`observe`) keep their caller's raw units.
        """
        logs = self._snapshot_logs()
        spans: Dict[str, List[Any]] = {}
        dists: Dict[str, List[Any]] = {}
        counters: Dict[str, float] = {}
        gauges: Dict[str, Tuple[int, float]] = {}
        n_events = 0
        n_dropped = 0
        for log in logs:
            n_events += len(log.events)
            n_dropped += log.n_dropped
            for table, merged in ((log.spans, spans), (log.dists, dists)):
                for name, st in list(table.items()):
                    dst = merged.get(name)
                    if dst is None:
                        merged[name] = [st[0], st[1], st[2], list(st[3])]
                    else:
                        dst[0] += st[0]
                        dst[1] += st[1]
                        dst[2] = max(dst[2], st[2])
                        dst[3].extend(st[3])
            for name, v in list(log.counters.items()):
                counters[name] = counters.get(name, 0) + v
            for name, tv in list(log.gauges.items()):
                if name not in gauges or tv[0] > gauges[name][0]:
                    gauges[name] = tv

        def _stats(st: List[Any], scale: float) -> Dict[str, float]:
            n, total, mx, samples = st
            out = {
                "count": n,
                "total": round(total * scale, 6),
                "mean": round(total * scale / max(n, 1), 6),
                "max": round(mx * scale, 6),
            }
            if samples:
                xs = sorted(samples)
                out["p50"] = round(_pct(xs, 0.50) * scale, 6)
                out["p99"] = round(_pct(xs, 0.99) * scale, 6)
            return out

        return {
            "spans": {k: _stats(v, 1e-6) for k, v in sorted(spans.items())},
            "dists": {k: _stats(v, 1.0) for k, v in sorted(dists.items())},
            "counters": {k: counters[k] for k in sorted(counters)},
            "gauges": {k: gauges[k][1] for k in sorted(gauges)},
            "threads": len(logs),
            "events": n_events,
            "events_dropped": n_dropped,
        }

    def span_events(self, name: Optional[str] = None
                    ) -> List[Tuple[str, int, str, int]]:
        """Flat, time-ordered ``(ph, t_ns, name, tid)`` event view.

        Handy for tests asserting ordering (e.g. the chaos-recovery
        detection -> rebuild -> re-dispatch timeline).
        """
        out: List[Tuple[str, int, str, int]] = []
        for log in self._snapshot_logs():
            for ph, t, nm, _payload in list(log.events):
                if ph in ("B", "E") and (name is None or nm == name
                                         or nm.startswith(name)):
                    out.append((ph, t, nm, log.tid))
        out.sort(key=lambda ev: ev[1])
        return out

    def export_chrome(self, path_or_file: Union[str, IO[str]]) -> int:
        """Write Chrome trace-event JSONL (one event per line).

        Returns the number of event lines written.  Load the file at
        https://ui.perfetto.dev or chrome://tracing.
        """
        if isinstance(path_or_file, str):
            with open(path_or_file, "w", encoding="utf-8") as fh:
                return self.export_chrome(fh)
        fh = path_or_file
        t0 = self.t0_ns
        n = 0
        for log in self._snapshot_logs():
            meta = {"ph": "M", "name": "thread_name", "pid": self.pid,
                    "tid": log.tid,
                    "args": {"name": log.name or f"thread-{log.tid}"}}
            fh.write(json.dumps(meta) + "\n")
            n += 1
            for ph, t, name, payload in list(log.events):
                ev: Dict[str, Any] = {
                    "ph": ph, "ts": (t - t0) / 1e3, "pid": self.pid,
                    "tid": log.tid, "name": name, "cat": "recxl",
                }
                if ph == "B" and payload:
                    ev["args"] = payload
                elif ph == "C":
                    ev["args"] = {"value": payload}
                elif ph == "X":
                    # observe(): a zero-extent sample rendered as a
                    # complete event so it shows on the track.
                    ev["dur"] = 0.0
                    ev["args"] = {"value": payload}
                fh.write(json.dumps(ev, default=str) + "\n")
                n += 1
        return n


def _pct(sorted_xs: List[float], q: float) -> float:
    """Nearest-rank percentile of an already-sorted sample list."""
    if not sorted_xs:
        return 0.0
    idx = min(len(sorted_xs) - 1, int(q * len(sorted_xs)))
    return float(sorted_xs[idx])


# -- module-level switch + conveniences ---------------------------------

_RECORDER: Optional[Recorder] = None


def active() -> Optional[Recorder]:
    """The live :class:`Recorder`, or ``None`` when telemetry is off."""
    return _RECORDER


def enabled() -> bool:
    return _RECORDER is not None


def enable(ring_events: int = DEFAULT_RING_EVENTS) -> Recorder:
    """Turn telemetry on (idempotent); returns the recorder."""
    global _RECORDER
    if _RECORDER is None:
        _RECORDER = Recorder(ring_events)
    return _RECORDER


def disable() -> None:
    global _RECORDER
    _RECORDER = None


def reset(ring_events: int = DEFAULT_RING_EVENTS) -> Recorder:
    """Drop all recorded data and start a fresh (enabled) recorder."""
    global _RECORDER
    _RECORDER = Recorder(ring_events)
    return _RECORDER


@contextlib.contextmanager
def recording(ring_events: int = DEFAULT_RING_EVENTS):
    """Scoped enable: fresh recorder inside, previous state restored."""
    global _RECORDER
    prev = _RECORDER
    rec = Recorder(ring_events)
    _RECORDER = rec
    try:
        yield rec
    finally:
        _RECORDER = prev


def span(name: str, **args: Any) -> Union[_Span, _NoopSpan]:
    """``with telemetry.span("tile/h2d", tile=3): ...``"""
    rec = _RECORDER
    if rec is None:
        return _NOOP_SPAN
    return _Span(rec, name, args or None)


def count(name: str, n: float = 1) -> None:
    rec = _RECORDER
    if rec is not None:
        rec.count(name, n)


def gauge(name: str, value: float) -> None:
    rec = _RECORDER
    if rec is not None:
        rec.gauge(name, value)


def observe(name: str, value: float) -> None:
    rec = _RECORDER
    if rec is not None:
        rec.observe(name, value)


def summary() -> Dict[str, Any]:
    rec = _RECORDER
    return rec.summary() if rec is not None else {}


def export_chrome(path_or_file: Union[str, IO[str]]) -> int:
    rec = _RECORDER
    return rec.export_chrome(path_or_file) if rec is not None else 0


def validate_chrome_trace(path: str) -> Dict[str, int]:
    """Validate an exported JSONL trace against the trace-event schema.

    Checks (raising ``ValueError`` with a specific message on the first
    violation):

    * every line parses as a JSON object with ``ph``, and timed events
      carry numeric ``ts`` + integer ``pid``/``tid``;
    * every ``B`` has a matching same-name ``E`` on the same
      ``(pid, tid)`` track, properly nested (LIFO);
    * every ``tid`` seen on an event resolves to a ``thread_name``
      metadata (``M``) record.

    Returns ``{"events", "threads", "spans"}`` counts for reporting.
    """
    stacks: Dict[Tuple[int, int], List[str]] = {}
    named_tids: set = set()
    seen_tids: set = set()
    n_events = 0
    n_spans = 0
    with open(path, "r", encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except ValueError as e:
                raise ValueError(f"line {lineno}: not JSON: {e}") from e
            if not isinstance(ev, dict) or "ph" not in ev:
                raise ValueError(f"line {lineno}: no 'ph' field")
            ph = ev["ph"]
            n_events += 1
            if ph == "M":
                if ev.get("name") == "thread_name":
                    named_tids.add((ev.get("pid"), ev.get("tid")))
                continue
            for field in ("pid", "tid"):
                if not isinstance(ev.get(field), int):
                    raise ValueError(
                        f"line {lineno}: missing int '{field}'")
            if not isinstance(ev.get("ts"), (int, float)):
                raise ValueError(f"line {lineno}: missing numeric 'ts'")
            key = (ev["pid"], ev["tid"])
            seen_tids.add(key)
            if ph == "B":
                stacks.setdefault(key, []).append(ev.get("name", ""))
            elif ph == "E":
                stack = stacks.get(key)
                if not stack:
                    raise ValueError(
                        f"line {lineno}: 'E' {ev.get('name')!r} with no "
                        f"open 'B' on tid {ev['tid']}")
                top = stack.pop()
                if top != ev.get("name"):
                    raise ValueError(
                        f"line {lineno}: 'E' {ev.get('name')!r} closes "
                        f"open span {top!r} (bad nesting)")
                n_spans += 1
    for key, stack in stacks.items():
        if stack:
            raise ValueError(
                f"tid {key[1]}: {len(stack)} unclosed 'B' events "
                f"({stack[-1]!r} still open)")
    unnamed = seen_tids - named_tids
    if unnamed:
        raise ValueError(
            f"tids without thread_name metadata: "
            f"{sorted(t for _, t in unnamed)}")
    return {"events": n_events, "threads": len(seen_tids),
            "spans": n_spans}


# Environment opt-in: RECXL_TELEMETRY=1 enables at import time.
if os.environ.get("RECXL_TELEMETRY", "") not in ("", "0"):
    enable()
