"""Bounded, hash-keyed host-side memo (shared cache primitive).

One tiny LRU used by every host-side memo layer (``repro.core.
simulator`` and ``repro.core.contention``). Lives in its own module so
``contention`` -- which ``simulator`` imports -- can use the same
implementation without an import cycle.

Thread-safe: the streaming engine mutates these memos from its prefetch
and compile-warm worker threads concurrently with the caller's thread,
so every cache carries its own ``threading.RLock``. The lock is held
across ``make()`` inside :meth:`get_or_put` -- two threads racing on
the same key must not build the (potentially device-resident) value
twice, and an OrderedDict mutated mid-``move_to_end`` can corrupt.
``make()`` for one cache may populate *another* cache (cell arrays pull
trace rows), which is fine: each cache has its own lock and the nesting
order is acyclic; the RLock additionally tolerates same-cache
re-entrancy.
"""

from __future__ import annotations

import collections
import threading
from typing import Callable


class BoundedCache:
    """Hash-keyed LRU memo with a hard entry bound.

    Unlike ``functools.lru_cache`` over the raw arguments, callers pass
    a small *key* (a digest tuple for batches, a scalar-knob tuple for
    cell arrays), so a 10^4-spec batch key costs bytes instead of
    pinning a copy of the spec tuple; ``maxsize`` bounds how many
    values (which may hold large host/device arrays) stay alive.

    All public methods are thread-safe; ``get_or_put`` guarantees a
    single ``make()`` call per key even under concurrent lookups."""

    def __init__(self, maxsize: int):
        self.maxsize = maxsize
        self._data: "collections.OrderedDict" = collections.OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0

    def get_or_put(self, key, make: Callable[[], object]):
        with self._lock:
            try:
                val = self._data[key]
                self._data.move_to_end(key)
                self.hits += 1
                return val
            except KeyError:
                self.misses += 1
            val = make()
            self._data[key] = val
            while len(self._data) > self.maxsize:
                self._data.popitem(last=False)
            return val

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self.hits = self.misses = 0
