"""Bounded, hash-keyed host-side memo (shared cache primitive).

One tiny LRU used by every host-side memo layer (``repro.core.
simulator`` and ``repro.core.contention``). Lives in its own module so
``contention`` -- which ``simulator`` imports -- can use the same
implementation without an import cycle.
"""

from __future__ import annotations

import collections
from typing import Callable


class BoundedCache:
    """Hash-keyed LRU memo with a hard entry bound.

    Unlike ``functools.lru_cache`` over the raw arguments, callers pass
    a small *key* (a digest tuple for batches, a scalar-knob tuple for
    cell arrays), so a 10^4-spec batch key costs bytes instead of
    pinning a copy of the spec tuple; ``maxsize`` bounds how many
    values (which may hold large host/device arrays) stay alive."""

    def __init__(self, maxsize: int):
        self.maxsize = maxsize
        self._data: "collections.OrderedDict" = collections.OrderedDict()
        self.hits = 0
        self.misses = 0

    def get_or_put(self, key, make: Callable[[], object]):
        try:
            val = self._data[key]
            self._data.move_to_end(key)
            self.hits += 1
            return val
        except KeyError:
            self.misses += 1
        val = make()
        self._data[key] = val
        while len(self._data) > self.maxsize:
            self._data.popitem(last=False)
        return val

    def __len__(self) -> int:
        return len(self._data)

    def clear(self) -> None:
        self._data.clear()
        self.hits = self.misses = 0
