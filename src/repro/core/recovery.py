"""ReCXL recovery (paper SS V.B-D, Algorithms 1-2, Table I).

Software-driven, coordinated by a Configuration Manager on a live node.
Correctness over speed, exactly as the paper prescribes ("recovery speed
is not the main concern").

Sequence (mirrors Fig. 9):

1. ``Interrupt`` -> all live nodes pause, complete outstanding work,
   ``InterruptResp``.
2. ``InitRecov`` -> directory repair (Algorithm 1): drop the failed node
   from every replica set; for every shard the failed node *owned*,
   ``FetchLatestVers`` asks the replica Logging Units for their newest
   validated version (Algorithm 2 walks each log newest-to-earliest);
   the newest version across replicas -- or, failing that, the MN-tier
   dump -- is applied to memory and the entry marked UNOWNED.
3. ``RecovEnd`` -> resume (the trainer re-admits a spare node or shrinks
   the mesh; see distributed/elastic.py).

This module is deliberately host-side numpy/python: the paper's recovery
is software handlers reading hardware logs, and host-side recovery code
survives device failures by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.directory import ShardDirectory, ShardState
from repro.core.protocol import (
    FetchLatestVers,
    FetchLatestVersResp,
    MsgType,
    RecoveryStats,
)
from repro.core.replication import ReplicationEngine


@dataclasses.dataclass
class RecoveredShard:
    """One recovered (node, bucket) shard, per model-axis coordinate."""
    bucket: int
    ts: int
    source: str                       # "replica:<rank>" | "mn_dump"
    values: np.ndarray                # (n_model, bucket_len)


@dataclasses.dataclass
class RecoveryResult:
    failed: Tuple[int, ...]           # (pod?, data) coordinates
    shards: Dict[int, RecoveredShard] # bucket -> shard
    stats: RecoveryStats
    message_log: List[Tuple[MsgType, Any]]


# ---------------------------------------------------------------------------
# Algorithm 2: replica log traversal
# ---------------------------------------------------------------------------

def algorithm2_versions(engine: ReplicationEngine, logs_np: Dict[str, np.ndarray],
                        replica_coord: Tuple[int, ...], rank: int,
                        bucket: int) -> List[Tuple[int, np.ndarray]]:
    """All logged versions of (failed-owner, bucket) held by the Logging
    Unit at ``replica_coord``, sorted latest-to-earliest.

    Returns [(ts, values (n_model, bucket_len))]. Only *validated* entries
    count (un-VALed entries were never committed by the source)."""
    mesh = engine.ctx.mesh
    axes = engine.mesh_axes
    n_model = mesh.shape["model"] if "model" in axes else 1
    out: List[Tuple[int, np.ndarray]] = []
    cap = engine.rep.log_capacity
    for slot in range(cap):
        # index: lead coords (pod?, data, model) then [rank, slot, bucket]
        vals, ok, ts = [], True, -1
        for m in range(n_model):
            coord = _lead_index(axes, replica_coord, m)
            if not logs_np["valid"][coord + (rank, slot, bucket)]:
                ok = False
                break
            ts = int(logs_np["ts"][coord + (rank, slot, bucket)])
            vals.append(logs_np["values"][coord + (rank, slot, bucket)])
        if ok and ts >= 0:
            out.append((ts, np.stack(vals)))
    out.sort(key=lambda p: -p[0])
    return out


def _lead_index(axes: Sequence[str], node_coord: Tuple[int, ...],
                model_idx: int) -> Tuple[int, ...]:
    """Build the leading index tuple (pod?, data, model) for log arrays."""
    out: List[int] = []
    ni = 0
    for ax in axes:
        if ax == "model":
            out.append(model_idx)
        else:
            out.append(node_coord[ni])
            ni += 1
    return tuple(out)


# ---------------------------------------------------------------------------
# Algorithm 1: directory + memory repair
# ---------------------------------------------------------------------------

def recover_node(engine: ReplicationEngine,
                 logs: Dict[str, jax.Array],
                 directory: ShardDirectory,
                 failed_coord: Tuple[int, ...],
                 mn_dump: Optional[Dict[int, Tuple[int, np.ndarray]]] = None,
                 ) -> RecoveryResult:
    """Run Algorithms 1-2 for one failed node.

    ``failed_coord``: (data,) or (pod, data) coordinate of the failed
    node. ``mn_dump``: bucket -> (step, values) from the MN tier (the
    dumped-log fallback). Returns the recovered shard contents; the
    trainer applies them to a rebuilt state (elastic.py).
    """
    msg_log: List[Tuple[MsgType, Any]] = []
    logs_np = {k: np.asarray(v) for k, v in logs.items()}
    failed_data = failed_coord[-1]
    n_nodes = engine.n_nodes

    # -- Algorithm 1, part 1: clear the failed node as a "sharer"
    # (drop it from every replica set in the directory).
    cleared = directory.remove_failed_replica(failed_data)

    # -- Algorithm 1, part 2: for every shard the failed node owned,
    # fetch the latest logged version from its replicas.
    owned = directory.owned_by(failed_data)
    msg_log.append((MsgType.INIT_RECOV, {"failed": failed_coord}))

    shards: Dict[int, RecoveredShard] = {}
    n_from_replicas = n_from_dump = n_unrec = 0

    for (node, bucket) in owned:
        reps = directory.replicas_of(node, bucket)
        fetch = FetchLatestVers(addrs=(bucket,))
        msg_log.append((MsgType.FETCH_LATEST_VERS,
                        {"to": reps, "msg": fetch}))
        candidates: List[Tuple[int, np.ndarray, str]] = []
        # engine offsets define which rank r maps to which replica node
        offs = engine._offsets(bucket)
        for r, off in enumerate(offs):
            t = (failed_data + off) % n_nodes
            if t == failed_data or t not in reps:
                continue              # never ask the failed node (SS V.A)
            t_coord = failed_coord[:-1] + (t,)
            versions = algorithm2_versions(engine, logs_np, t_coord, r, bucket)
            msg_log.append((MsgType.FETCH_LATEST_VERS_RESP,
                            {"from": t, "n_versions": len(versions)}))
            if versions:
                ts, vals = versions[0]
                candidates.append((ts, vals, f"replica:{r}@node{t}"))
        if candidates:
            # paper: replicas normally agree; on mid-replication failure
            # the latest across any replica wins.
            candidates.sort(key=lambda c: -c[0])
            ts, vals, src = candidates[0]
            shards[bucket] = RecoveredShard(bucket, ts, src, vals)
            n_from_replicas += 1
        elif mn_dump is not None and bucket in mn_dump:
            step, vals = mn_dump[bucket]
            shards[bucket] = RecoveredShard(bucket, step, "mn_dump",
                                            np.asarray(vals))
            n_from_dump += 1
        else:
            n_unrec += 1
        directory.entries[(node, bucket)].state = ShardState.UNOWNED

    msg_log.append((MsgType.INIT_RECOV_RESP, {"buckets": len(shards)}))
    msg_log.append((MsgType.RECOV_END, {}))

    stats = RecoveryStats(
        failed_node=failed_data,
        shared_entries_cleared=cleared,
        owned_entries=len(owned),
        recovered_from_replicas=n_from_replicas,
        recovered_from_mn_dump=n_from_dump,
        unrecoverable=n_unrec,
    )
    return RecoveryResult(failed=failed_coord, shards=shards, stats=stats,
                          message_log=msg_log)


# ---------------------------------------------------------------------------
# Parity (erasure-coded) recovery -- beyond-paper mode
# ---------------------------------------------------------------------------

def recover_node_parity(engine: ReplicationEngine,
                        logs: Dict[str, jax.Array],
                        state: Any, specs: Any,
                        failed_coord: Tuple[int, ...],
                        ) -> RecoveryResult:
    """Erasure-coded recovery: lost = parity - sum(survivors' payloads).

    ``state``/``specs``: the live global state (survivors still hold
    their shards) and its PartitionSpecs. Exact when log_dtype is f32.
    Tolerates one failure per parity group (vs. N_r-1 anywhere for copy
    mode) at G x N_r less log memory.
    """
    from repro.distributed.elastic import _block_slices

    assert engine.rep.mode == "parity"
    G = engine.rep.parity_group
    logs_np = {k: np.asarray(v) for k, v in logs.items()}
    failed = failed_coord[-1]
    group = failed // G
    members = [m for m in range(group * G, (group + 1) * G) if m != failed]
    mesh = engine.ctx.mesh
    axes = engine.mesh_axes
    n_model = mesh.shape["model"] if "model" in axes else 1
    node_axes = list(engine.ctx.batch_axes)

    flat_state, _ = jax.tree.flatten(state)
    flat_specs = jax.tree.leaves(
        specs, is_leaf=lambda s: hasattr(s, "_normalized_spec")
        or type(s).__name__ == "PartitionSpec")
    host = [np.asarray(l) for l in flat_state]

    def local_leaves(node: int, m: int) -> List[np.ndarray]:
        coords = {"model": m} if "model" in axes else {}
        coord_tuple = failed_coord[:-1] + (node,)
        for a, c in zip(node_axes, coord_tuple[-len(node_axes):]):
            coords[a] = c
        out = []
        for h, spec in zip(host, flat_specs):
            sl = _block_slices(h.shape, spec, mesh, coords)
            out.append(h[sl])
        return out

    shards: Dict[int, RecoveredShard] = {}
    msg_log: List[Tuple[MsgType, Any]] = [
        (MsgType.INIT_RECOV, {"failed": failed_coord, "mode": "parity"})]
    nb = engine.layout.n_buckets
    cap = engine.rep.log_capacity
    n_unrec = 0
    for b in range(nb):
        holder = engine.parity_holder(group, b)
        best_ts, best = -1, None
        for slot in range(cap):
            vals, ok, ts = [], True, -1
            for m in range(n_model):
                coord = _lead_index(axes, failed_coord[:-1] + (holder,), m)
                if not logs_np["valid"][coord + (0, slot, b)]:
                    ok = False
                    break
                ts = int(logs_np["ts"][coord + (0, slot, b)])
                vals.append(logs_np["values"][coord + (0, slot, b)])
            if ok and ts > best_ts:
                best_ts, best = ts, np.stack(vals)
        if best is None:
            n_unrec += 1
            continue
        # subtract the survivors' contributions
        lost = best.astype(np.float64)
        for node in members:
            for m in range(n_model):
                leaves = [jnp.asarray(x) for x in local_leaves(node, m)]
                contrib = np.asarray(engine.pack_bucket(leaves, b),
                                     np.float64)
                lost[m] -= contrib
        shards[b] = RecoveredShard(b, best_ts, f"parity@node{holder}",
                                   lost.astype(np.float32))
        msg_log.append((MsgType.FETCH_LATEST_VERS_RESP,
                        {"from": holder, "bucket": b, "ts": best_ts}))
    msg_log.append((MsgType.RECOV_END, {}))
    stats = RecoveryStats(
        failed_node=failed, shared_entries_cleared=0,
        owned_entries=nb, recovered_from_replicas=len(shards),
        recovered_from_mn_dump=0, unrecoverable=n_unrec)
    return RecoveryResult(failed=failed_coord, shards=shards, stats=stats,
                          message_log=msg_log)


# ---------------------------------------------------------------------------
# Reassembling the failed node's state shard
# ---------------------------------------------------------------------------

def reassemble_shard(engine: ReplicationEngine, result: RecoveryResult
                     ) -> List[np.ndarray]:
    """Stitch recovered buckets back into the per-model-coordinate leaf
    list of the failed node's local state shard.

    Returns a list over model coordinates; each element is the leaf list
    (matching ``engine.layout.local_shapes``)."""
    nb, bl = engine.layout.n_buckets, engine.layout.bucket_len
    if len(result.shards) != nb:
        missing = sorted(set(range(nb)) - set(result.shards))
        raise ValueError(f"buckets unrecovered: {missing}")
    n_model = result.shards[0].values.shape[0]
    per_model = []
    for m in range(n_model):
        flat = np.concatenate([
            np.asarray(result.shards[b].values[m], np.float32).reshape(-1)
            for b in range(nb)])
        per_model.append([np.asarray(x) for x in
                          engine.unpack(jax.numpy.asarray(flat.reshape(nb, bl)))])
    return per_model
