"""ReCXL recovery (paper SS V.B-D, Algorithms 1-2, Table I).

Software-driven, coordinated by a Configuration Manager on a live node.
Correctness over speed, exactly as the paper prescribes ("recovery speed
is not the main concern").

Sequence (mirrors Fig. 9):

1. ``Interrupt`` -> all live nodes pause, complete outstanding work,
   ``InterruptResp``.
2. ``InitRecov`` -> directory repair (Algorithm 1): drop the failed node
   from every replica set; for every shard the failed node *owned*,
   ``FetchLatestVers`` asks the replica Logging Units for their newest
   validated version (Algorithm 2 walks each log newest-to-earliest);
   the newest version across replicas -- or, failing that, the MN-tier
   dump -- is applied to memory and the entry marked UNOWNED.
3. ``RecovEnd`` -> resume (the trainer re-admits a spare node or shrinks
   the mesh; see distributed/elastic.py).

This module is deliberately host-side numpy/python: the paper's recovery
is software handlers reading hardware logs, and host-side recovery code
survives device failures by construction.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.recxl_paper import PAPER_CLUSTER, WORKLOADS, ClusterConfig
from repro.core.contention import (
    ContentionParams,
    dirty_line_scale,
    undumped_log_scale,
)
from repro.core.directory import ShardDirectory, ShardState
from repro.core.protocol import (
    FetchLatestVers,
    FetchLatestVersResp,
    MsgType,
    RecoveryStats,
)
from repro.core.replication import ReplicationEngine


@dataclasses.dataclass
class RecoveredShard:
    """One recovered (node, bucket) shard, per model-axis coordinate."""
    bucket: int
    ts: int
    source: str                       # "replica:<rank>" | "mn_dump"
    values: np.ndarray                # (n_model, bucket_len)


@dataclasses.dataclass
class RecoveryResult:
    failed: Tuple[int, ...]           # (pod?, data) coordinates
    shards: Dict[int, RecoveredShard] # bucket -> shard
    stats: RecoveryStats
    message_log: List[Tuple[MsgType, Any]]


# ---------------------------------------------------------------------------
# Algorithm 2: replica log traversal
# ---------------------------------------------------------------------------

def algorithm2_versions(engine: ReplicationEngine, logs_np: Dict[str, np.ndarray],
                        replica_coord: Tuple[int, ...], rank: int,
                        bucket: int) -> List[Tuple[int, np.ndarray]]:
    """All logged versions of (failed-owner, bucket) held by the Logging
    Unit at ``replica_coord``, sorted latest-to-earliest.

    Returns [(ts, values (n_model, bucket_len))]. Only *validated* entries
    count (un-VALed entries were never committed by the source)."""
    mesh = engine.ctx.mesh
    axes = engine.mesh_axes
    n_model = mesh.shape["model"] if "model" in axes else 1
    out: List[Tuple[int, np.ndarray]] = []
    cap = engine.rep.log_capacity
    for slot in range(cap):
        # index: lead coords (pod?, data, model) then [rank, slot, bucket]
        vals, ok, ts = [], True, -1
        for m in range(n_model):
            coord = _lead_index(axes, replica_coord, m)
            if not logs_np["valid"][coord + (rank, slot, bucket)]:
                ok = False
                break
            ts = int(logs_np["ts"][coord + (rank, slot, bucket)])
            vals.append(logs_np["values"][coord + (rank, slot, bucket)])
        if ok and ts >= 0:
            out.append((ts, np.stack(vals)))
    out.sort(key=lambda p: -p[0])
    return out


def _lead_index(axes: Sequence[str], node_coord: Tuple[int, ...],
                model_idx: int) -> Tuple[int, ...]:
    """Build the leading index tuple (pod?, data, model) for log arrays."""
    out: List[int] = []
    ni = 0
    for ax in axes:
        if ax == "model":
            out.append(model_idx)
        else:
            out.append(node_coord[ni])
            ni += 1
    return tuple(out)


# ---------------------------------------------------------------------------
# Algorithm 1: directory + memory repair
# ---------------------------------------------------------------------------

def recover_node(engine: ReplicationEngine,
                 logs: Dict[str, jax.Array],
                 directory: ShardDirectory,
                 failed_coord: Tuple[int, ...],
                 mn_dump: Optional[Dict[int, Tuple[int, np.ndarray]]] = None,
                 ) -> RecoveryResult:
    """Run Algorithms 1-2 for one failed node.

    ``failed_coord``: (data,) or (pod, data) coordinate of the failed
    node. ``mn_dump``: bucket -> (step, values) from the MN tier (the
    dumped-log fallback). Returns the recovered shard contents; the
    trainer applies them to a rebuilt state (elastic.py).
    """
    msg_log: List[Tuple[MsgType, Any]] = []
    logs_np = {k: np.asarray(v) for k, v in logs.items()}
    failed_data = failed_coord[-1]
    n_nodes = engine.n_nodes

    # -- Algorithm 1, part 1: clear the failed node as a "sharer"
    # (drop it from every replica set in the directory).
    cleared = directory.remove_failed_replica(failed_data)

    # -- Algorithm 1, part 2: for every shard the failed node owned,
    # fetch the latest logged version from its replicas.
    owned = directory.owned_by(failed_data)
    msg_log.append((MsgType.INIT_RECOV, {"failed": failed_coord}))

    shards: Dict[int, RecoveredShard] = {}
    n_from_replicas = n_from_dump = n_unrec = 0

    for (node, bucket) in owned:
        reps = directory.replicas_of(node, bucket)
        fetch = FetchLatestVers(addrs=(bucket,))
        msg_log.append((MsgType.FETCH_LATEST_VERS,
                        {"to": reps, "msg": fetch}))
        candidates: List[Tuple[int, np.ndarray, str]] = []
        # engine offsets define which rank r maps to which replica node
        offs = engine._offsets(bucket)
        for r, off in enumerate(offs):
            t = (failed_data + off) % n_nodes
            if t == failed_data or t not in reps:
                continue              # never ask the failed node (SS V.A)
            t_coord = failed_coord[:-1] + (t,)
            versions = algorithm2_versions(engine, logs_np, t_coord, r, bucket)
            msg_log.append((MsgType.FETCH_LATEST_VERS_RESP,
                            {"from": t, "n_versions": len(versions)}))
            if versions:
                ts, vals = versions[0]
                candidates.append((ts, vals, f"replica:{r}@node{t}"))
        if candidates:
            # paper: replicas normally agree; on mid-replication failure
            # the latest across any replica wins.
            candidates.sort(key=lambda c: -c[0])
            ts, vals, src = candidates[0]
            shards[bucket] = RecoveredShard(bucket, ts, src, vals)
            n_from_replicas += 1
        elif mn_dump is not None and bucket in mn_dump:
            step, vals = mn_dump[bucket]
            shards[bucket] = RecoveredShard(bucket, step, "mn_dump",
                                            np.asarray(vals))
            n_from_dump += 1
        else:
            n_unrec += 1
        directory.entries[(node, bucket)].state = ShardState.UNOWNED

    msg_log.append((MsgType.INIT_RECOV_RESP, {"buckets": len(shards)}))
    msg_log.append((MsgType.RECOV_END, {}))

    stats = RecoveryStats(
        failed_node=failed_data,
        shared_entries_cleared=cleared,
        owned_entries=len(owned),
        recovered_from_replicas=n_from_replicas,
        recovered_from_mn_dump=n_from_dump,
        unrecoverable=n_unrec,
    )
    return RecoveryResult(failed=failed_coord, shards=shards, stats=stats,
                          message_log=msg_log)


# ---------------------------------------------------------------------------
# Parity (erasure-coded) recovery -- beyond-paper mode
# ---------------------------------------------------------------------------

def recover_node_parity(engine: ReplicationEngine,
                        logs: Dict[str, jax.Array],
                        state: Any, specs: Any,
                        failed_coord: Tuple[int, ...],
                        ) -> RecoveryResult:
    """Erasure-coded recovery: lost = parity - sum(survivors' payloads).

    ``state``/``specs``: the live global state (survivors still hold
    their shards) and its PartitionSpecs. Exact when log_dtype is f32.
    Tolerates one failure per parity group (vs. N_r-1 anywhere for copy
    mode) at G x N_r less log memory.
    """
    from repro.distributed.elastic import _block_slices

    assert engine.rep.mode == "parity"
    G = engine.rep.parity_group
    logs_np = {k: np.asarray(v) for k, v in logs.items()}
    failed = failed_coord[-1]
    group = failed // G
    members = [m for m in range(group * G, (group + 1) * G) if m != failed]
    mesh = engine.ctx.mesh
    axes = engine.mesh_axes
    n_model = mesh.shape["model"] if "model" in axes else 1
    node_axes = list(engine.ctx.batch_axes)

    flat_state, _ = jax.tree.flatten(state)
    flat_specs = jax.tree.leaves(
        specs, is_leaf=lambda s: hasattr(s, "_normalized_spec")
        or type(s).__name__ == "PartitionSpec")
    host = [np.asarray(l) for l in flat_state]

    def local_leaves(node: int, m: int) -> List[np.ndarray]:
        coords = {"model": m} if "model" in axes else {}
        coord_tuple = failed_coord[:-1] + (node,)
        for a, c in zip(node_axes, coord_tuple[-len(node_axes):]):
            coords[a] = c
        out = []
        for h, spec in zip(host, flat_specs):
            sl = _block_slices(h.shape, spec, mesh, coords)
            out.append(h[sl])
        return out

    shards: Dict[int, RecoveredShard] = {}
    msg_log: List[Tuple[MsgType, Any]] = [
        (MsgType.INIT_RECOV, {"failed": failed_coord, "mode": "parity"})]
    nb = engine.layout.n_buckets
    cap = engine.rep.log_capacity
    n_unrec = 0
    for b in range(nb):
        holder = engine.parity_holder(group, b)
        best_ts, best = -1, None
        for slot in range(cap):
            vals, ok, ts = [], True, -1
            for m in range(n_model):
                coord = _lead_index(axes, failed_coord[:-1] + (holder,), m)
                if not logs_np["valid"][coord + (0, slot, b)]:
                    ok = False
                    break
                ts = int(logs_np["ts"][coord + (0, slot, b)])
                vals.append(logs_np["values"][coord + (0, slot, b)])
            if ok and ts > best_ts:
                best_ts, best = ts, np.stack(vals)
        if best is None:
            n_unrec += 1
            continue
        # subtract the survivors' contributions
        lost = best.astype(np.float64)
        for node in members:
            for m in range(n_model):
                leaves = [jnp.asarray(x) for x in local_leaves(node, m)]
                contrib = np.asarray(engine.pack_bucket(leaves, b),
                                     np.float64)
                lost[m] -= contrib
        shards[b] = RecoveredShard(b, best_ts, f"parity@node{holder}",
                                   lost.astype(np.float32))
        msg_log.append((MsgType.FETCH_LATEST_VERS_RESP,
                        {"from": holder, "bucket": b, "ts": best_ts}))
    msg_log.append((MsgType.RECOV_END, {}))
    stats = RecoveryStats(
        failed_node=failed, shared_entries_cleared=0,
        owned_entries=nb, recovered_from_replicas=len(shards),
        recovered_from_mn_dump=0, unrecoverable=n_unrec)
    return RecoveryResult(failed=failed_coord, shards=shards, stats=stats,
                          message_log=msg_log)


# ---------------------------------------------------------------------------
# Recovery-time (downtime) model -- paper SS VII-E
# ---------------------------------------------------------------------------
#
# The paper prioritizes correctness over recovery speed, but SS VII-E still
# quantifies the dominant cost: replaying the Logging-Unit logs to rebuild
# directory + memory. Downtime is modeled as the Fig. 9 sequence of
# sequential phases; the replay phase scales with the log volume that had
# not yet been dumped at the failure point (it grows with the position
# inside the dump interval) and the owned-line fetch volume, divided by the
# CXL link bandwidth.


@dataclasses.dataclass(frozen=True)
class RecoveryTimeParams:
    """Cost constants of the downtime model (units in field names).

    ``line_bytes``/``header_bytes`` size one FetchLatestVers payload;
    ``log_entry_bytes`` (Fig. 5: ~97 bits -> 12 B) converts undumped log
    bytes to entries for the Logging-Unit walk; ``scan_cycles_per_entry``
    is the per-entry cost of Algorithm 2's newest-to-earliest traversal
    at the Logging-Unit clock.
    """
    detect_us: float = 50.0          # failure-detection lease timeout
    dir_entry_ns: float = 8.0        # per owned directory entry (Alg. 1)
    line_bytes: int = 64             # recovered payload per owned line
    header_bytes: int = 8            # CXL message header
    log_entry_bytes: float = 12.0    # Fig. 5 log-entry footprint
    scan_cycles_per_entry: float = 2.0


DEFAULT_RECOVERY_PARAMS = RecoveryTimeParams()


@dataclasses.dataclass(frozen=True)
class RecoveryEstimate:
    """Estimated downtime breakdown for one fail-stop event.

    Phase fields are ns and sum (sequentially, as in Fig. 9) to
    ``total_ns``; ``replay_bytes`` is the total log-replay volume
    (undumped log + fetched versions + memory writeback) in bytes.
    """
    detect_ns: float                 # lease expiry until CM reacts
    quiesce_ns: float                # Interrupt -> InterruptResp drain
    directory_ns: float              # Algorithm 1 walk + replica clears
    log_scan_ns: float               # Algorithm 2 Logging-Unit traversal
    fetch_ns: float                  # FetchLatestVers payloads over CXL
    writeback_ns: float              # applying versions to MN memory
    resume_ns: float                 # RecovEnd broadcast
    owned_lines: float               # lines the failed node owned
    undumped_log_bytes: float        # log bytes pending at failure point
    replay_bytes: float              # total replayed volume (bytes)

    @property
    def total_ns(self) -> float:
        return (self.detect_ns + self.quiesce_ns + self.directory_ns +
                self.log_scan_ns + self.fetch_ns + self.writeback_ns +
                self.resume_ns)

    @property
    def total_ms(self) -> float:
        return self.total_ns / 1e6


def estimate_recovery_time(owned_lines: float,
                           undumped_log_bytes: float,
                           cluster: ClusterConfig = PAPER_CLUSTER,
                           link_bw_gbps: Optional[float] = None,
                           params: RecoveryTimeParams =
                           DEFAULT_RECOVERY_PARAMS,
                           dir_service_scale: float = 1.0
                           ) -> RecoveryEstimate:
    """Closed-form downtime estimate for one failed CN.

    ``owned_lines``: cache lines (or shard entries) the failed node
    owned -- each needs a FetchLatestVers + memory writeback.
    ``undumped_log_bytes``: Logging-Unit bytes accumulated since the
    last dump at the failure point (bounded by the dump interval);
    Algorithm 2 walks these to find the newest validated versions.
    ``link_bw_gbps``: CXL link bandwidth in GB/s (1 GB/s == 1 byte/ns,
    so transfer ns == bytes / GB/s); defaults to the cluster's.
    ``dir_service_scale`` (>= 1.0) dilates the directory-walk phase
    when the surviving directory shards serve recovery under background
    load (``directory.directory_service_scale`` -- 1.0 = uncoupled).

    The estimate is monotone increasing in both volumes and monotone
    decreasing in the bandwidth (tests/test_recovery_time.py holds this
    under hypothesis).
    """
    bw = cluster.cxl_link_bw_gbps if link_bw_gbps is None else link_bw_gbps
    if bw <= 0.0:
        raise ValueError(f"link_bw_gbps must be > 0, got {bw}")
    if owned_lines < 0 or undumped_log_bytes < 0:
        raise ValueError("volumes must be >= 0")
    if dir_service_scale < 1.0:
        raise ValueError(
            f"dir_service_scale must be >= 1.0, got {dir_service_scale}")
    fetch_bytes = owned_lines * (params.line_bytes + params.header_bytes)
    wb_bytes = owned_lines * params.line_bytes
    entries = undumped_log_bytes / params.log_entry_bytes
    lu_cycle_ns = 1e3 / cluster.logging_unit_freq_mhz
    return RecoveryEstimate(
        detect_ns=params.detect_us * 1e3,
        quiesce_ns=cluster.cxl_rtt_ns
        + cluster.store_buffer * 2.0 * cluster.cycle_ns,
        directory_ns=owned_lines * params.dir_entry_ns * dir_service_scale,
        log_scan_ns=entries * params.scan_cycles_per_entry * lu_cycle_ns,
        fetch_ns=fetch_bytes / bw,
        writeback_ns=wb_bytes / bw,
        resume_ns=cluster.cxl_rtt_ns,
        owned_lines=owned_lines,
        undumped_log_bytes=undumped_log_bytes,
        replay_bytes=undumped_log_bytes + fetch_bytes + wb_bytes,
    )


def workload_recovery_inputs(workload: str, fail_time_ms: float,
                             cluster: ClusterConfig = PAPER_CLUSTER,
                             n_cns: Optional[int] = None,
                             n_replicas: Optional[int] = None,
                             params: RecoveryTimeParams =
                             DEFAULT_RECOVERY_PARAMS,
                             contention: Optional[ContentionParams] = None
                             ) -> Tuple[float, float]:
    """Derive ``(owned_lines, undumped_log_bytes)`` for a workload at a
    given failure time.

    ``fail_time_ms`` is wall-clock since the last Logging-Unit dump
    epoch; only its position inside the dump interval matters (the dump
    resets the pending log), so the undumped volume is periodic in
    ``cluster.dump_period_ms``. With fewer CNs each node runs more of
    the fixed total work (weak scaling, Fig. 18), so both the owned-line
    census (Fig. 15) and the per-node store rate scale by
    ``cluster.n_cns / n_cns``. Coalesced stores never reach the log.

    ``contention`` (``repro.core.contention``) scales what a crash can
    expose: conflicted ownership churn inflates the owned-line census
    and leaves superseded log entries (``dirty_line_scale`` /
    ``undumped_log_scale``), read-heavy mixes keep lines clean, and
    persist-ordering schedules shrink both volumes -- so downtime now
    varies with the contention regime (docs/contention.md).
    """
    wl = WORKLOADS[workload]
    ncn = cluster.n_cns if n_cns is None else n_cns
    if ncn < 1:
        raise ValueError(f"n_cns must be >= 1, got {ncn}")
    del n_replicas  # every replica holds a full copy of the node's log
    scale = cluster.n_cns / ncn
    owned = wl.working_lines * scale
    ipc = 2.0
    stores_per_s = (wl.remote_store_rate / 1e3) * ipc \
        * cluster.cpu_freq_ghz * 1e9 * cluster.cores_per_cn * scale
    entries_per_s = stores_per_s * (1.0 - wl.coalesce_rate)
    phase_ms = fail_time_ms % cluster.dump_period_ms
    undumped = entries_per_s * (phase_ms * 1e-3) * params.log_entry_bytes
    if contention is not None:
        owned *= dirty_line_scale(contention)
        undumped *= undumped_log_scale(contention)
    return owned, undumped


@functools.partial(jax.jit, static_argnames=("cluster", "params"))
def recovery_time_batch(owned_lines: jax.Array,
                        undumped_log_bytes: jax.Array,
                        link_bw_gbps: jax.Array,
                        dir_service_scale: jax.Array = 1.0,
                        cluster: ClusterConfig = PAPER_CLUSTER,
                        params: RecoveryTimeParams =
                        DEFAULT_RECOVERY_PARAMS) -> Dict[str, jax.Array]:
    """Vectorized :func:`estimate_recovery_time` over broadcastable
    arrays (one jitted call for a whole failure-time x node grid).

    Inputs broadcast together to the grid shape; returns a dict of
    arrays of that shape: every phase field of :class:`RecoveryEstimate`
    plus ``total_ns`` and ``replay_bytes``. ``dir_service_scale``
    broadcasts like the volumes (``recovery_sweep`` passes a per-CN
    vector of directory service dilations; the scalar default 1.0
    reproduces the uncoupled model bit-for-bit). Same arithmetic as the
    scalar model (tests/test_recovery_time.py checks them against each
    other).
    """
    owned = jnp.asarray(owned_lines, jnp.float64 if jax.config.jax_enable_x64
                        else jnp.float32)
    undumped = jnp.asarray(undumped_log_bytes, owned.dtype)
    bw = jnp.asarray(link_bw_gbps, owned.dtype)
    dscale = jnp.asarray(dir_service_scale, owned.dtype)
    fetch_bytes = owned * (params.line_bytes + params.header_bytes)
    wb_bytes = owned * params.line_bytes
    entries = undumped / params.log_entry_bytes
    lu_cycle_ns = 1e3 / cluster.logging_unit_freq_mhz
    out = {
        "detect_ns": jnp.broadcast_to(params.detect_us * 1e3,
                                      jnp.broadcast_shapes(
                                          owned.shape, undumped.shape,
                                          bw.shape, dscale.shape)),
        "quiesce_ns": jnp.broadcast_to(
            cluster.cxl_rtt_ns + cluster.store_buffer * 2.0
            * cluster.cycle_ns,
            jnp.broadcast_shapes(owned.shape, undumped.shape, bw.shape,
                                 dscale.shape)),
        "directory_ns": owned * params.dir_entry_ns * dscale,
        "log_scan_ns": entries * params.scan_cycles_per_entry * lu_cycle_ns,
        "fetch_ns": fetch_bytes / bw,
        "writeback_ns": wb_bytes / bw,
        "resume_ns": jnp.broadcast_to(cluster.cxl_rtt_ns,
                                      jnp.broadcast_shapes(
                                          owned.shape, undumped.shape,
                                          bw.shape, dscale.shape)),
        "replay_bytes": undumped + fetch_bytes + wb_bytes,
    }
    out["total_ns"] = (out["detect_ns"] + out["quiesce_ns"]
                       + out["directory_ns"] + out["log_scan_ns"]
                       + out["fetch_ns"] + out["writeback_ns"]
                       + out["resume_ns"])
    return out


# ---------------------------------------------------------------------------
# Reassembling the failed node's state shard
# ---------------------------------------------------------------------------

def reassemble_shard(engine: ReplicationEngine, result: RecoveryResult
                     ) -> List[np.ndarray]:
    """Stitch recovered buckets back into the per-model-coordinate leaf
    list of the failed node's local state shard.

    Returns a list over model coordinates; each element is the leaf list
    (matching ``engine.layout.local_shapes``)."""
    nb, bl = engine.layout.n_buckets, engine.layout.bucket_len
    if len(result.shards) != nb:
        missing = sorted(set(range(nb)) - set(result.shards))
        raise ValueError(f"buckets unrecovered: {missing}")
    n_model = result.shards[0].values.shape[0]
    per_model = []
    for m in range(n_model):
        flat = np.concatenate([
            np.asarray(result.shards[b].values[m], np.float32).reshape(-1)
            for b in range(nb)])
        per_model.append([np.asarray(x) for x in
                          engine.unpack(jax.numpy.asarray(flat.reshape(nb, bl)))])
    return per_model
