"""ReCXL protocol messages (paper Figures 4-5 and Table I).

These dataclasses are the *control-plane* representation, used by the
fine-grained Logging Unit, the recovery orchestrator, and the protocol
simulator. The data-plane (training replication engine) encodes the same
information as packed device arrays for jit-compatibility.

Bit-widths follow the paper exactly; ``wire_bits`` methods are used by the
bandwidth benchmarks (Fig. 14/16).
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple


class MsgType(enum.Enum):
    REPL = "REPL"
    REPL_ACK = "REPL_ACK"
    VAL = "VAL"
    # recovery control plane (Table I)
    INTERRUPT = "Interrupt"
    INTERRUPT_RESP = "InterruptResp"
    INIT_RECOV = "InitRecov"
    FETCH_LATEST_VERS = "FetchLatestVers"
    FETCH_LATEST_VERS_RESP = "FetchLatestVersResp"
    INIT_RECOV_RESP = "InitRecovResp"
    RECOV_END = "RecovEnd"
    RECOV_END_RESP = "RecovEndResp"


# --- field widths from Fig. 4/5 (bits) --------------------------------------
REQUESTER_ID_BITS = 10          # {CN, core}
WORD_MASK_BITS = 16             # words per 64B line (word = 4B)
LINE_ADDR_BITS = 44
WORD_ADDR_BITS = 46
WORD_VALUE_BITS = 32
LOGICAL_TS_BITS = 7
VALID_BITS = 1
WORDS_PER_LINE = 16


@dataclass(frozen=True)
class ReplMsg:
    """REPL (Fig. 4a): replicate one (possibly coalesced) line update."""
    requester_cn: int
    requester_core: int
    line_addr: int
    word_mask: int                        # bit i set => word i updated
    word_values: Tuple[int, ...]          # len == popcount(word_mask)

    def __post_init__(self) -> None:
        n = bin(self.word_mask).count("1")
        if n != len(self.word_values):
            raise ValueError(
                f"word_mask has {n} set bits but {len(self.word_values)} values")
        if not 0 < n <= WORDS_PER_LINE:
            raise ValueError("REPL must carry 1..16 words")

    @property
    def requester_id(self) -> Tuple[int, int]:
        return (self.requester_cn, self.requester_core)

    def wire_bits(self) -> int:
        return (REQUESTER_ID_BITS + WORD_MASK_BITS + LINE_ADDR_BITS
                + WORD_VALUE_BITS * len(self.word_values))

    def split_words(self) -> List[Tuple[int, int]]:
        """(word_addr, value) pairs -- one log entry each (paper SS IV.B)."""
        out, vi = [], 0
        for w in range(WORDS_PER_LINE):
            if self.word_mask >> w & 1:
                out.append((self.line_addr * WORDS_PER_LINE + w,
                            self.word_values[vi]))
                vi += 1
        return out


@dataclass(frozen=True)
class ReplAckMsg:
    replica_cn: int
    requester_cn: int
    requester_core: int
    line_addr: int

    def wire_bits(self) -> int:
        return REQUESTER_ID_BITS + LINE_ADDR_BITS


@dataclass(frozen=True)
class ValMsg:
    """VAL (Fig. 4b): all replicas updated; carries the logical TS."""
    requester_cn: int
    requester_core: int
    logical_ts: int
    line_addr: int

    def wire_bits(self) -> int:
        return REQUESTER_ID_BITS + LOGICAL_TS_BITS + LINE_ADDR_BITS


@dataclass(frozen=True)
class LogEntry:
    """Fig. 5: one store's worth of logged state."""
    requester_cn: int
    requester_core: int
    logical_ts: int
    word_addr: int
    value: int
    valid: bool = False

    def wire_bits(self) -> int:
        return (REQUESTER_ID_BITS + LOGICAL_TS_BITS + WORD_ADDR_BITS
                + WORD_VALUE_BITS + VALID_BITS)


# --- recovery control plane (Table I) ---------------------------------------

@dataclass(frozen=True)
class FetchLatestVers:
    addrs: Tuple[int, ...]                # line addrs owned by the failed CN


@dataclass(frozen=True)
class FetchLatestVersResp:
    replica_cn: int
    # addr -> versions, sorted latest-to-earliest (Algorithm 2)
    versions: Tuple[Tuple[int, Tuple[Tuple[int, int], ...]], ...]


@dataclass(frozen=True)
class RecoveryStats:
    """Bookkeeping the benchmarks read (Fig. 15 analogue)."""
    failed_node: int
    shared_entries_cleared: int
    owned_entries: int
    recovered_from_replicas: int
    recovered_from_mn_dump: int
    unrecoverable: int = 0
