"""Trace-driven ReCXL protocol simulator (paper SS VI-VII).

The paper evaluates ReCXL with SST + Pin traces of PARSEC / SPLASH-2 /
YCSB on a 16-CN / 16-MN cluster (Table II). We reproduce that evaluation
with a vectorized store-timeline simulator: per application class, a
synthetic remote-store trace (arrival times, coalescability) is pushed
through a store-buffer model that implements the exact commit rules of
the five configurations (Fig. 6):

* WB            c_i = max(r_i, c_{i-1}) + t_l1
* WT            c_i = max(r_i, c_{i-1}) + t_rtt + t_pmem     (TSO serial)
* baseline      c_i = max(r_i, c_{i-1}) + t_coh_exposed + t_repl
* parallel      c_i = max(r_i, c_{i-1}) + max(t_coh_exposed, t_repl)
* proactive     c_i = max(c_{i-1} + t_drain, ack_i, coh_i)
                with ack_i = r_i + t_repl issued at *retire* time, so
                REPL->ACK cycles of queued stores overlap (Fig. 8)

where r_i (retire into SB) stalls when the SB is full:
r_i = max(a_i, c_{i-SB}) -- the SB-occupancy recurrence is carried through
one ``lax.scan`` with a ring of the last SB commit times.

Exclusive prefetch (Fig. 7) is modeled by drawing the *exposed* coherence
latency: the RFO is issued at address resolution (lead time ~ SB queueing
delay), so at the SB head the transaction has usually completed --
matching the paper's finding that ReCXL-parallel barely beats
ReCXL-baseline.

Everything is deterministic given (workload, seed). Calibration targets
are the paper's headline numbers (PAPER_CLAIMS in configs/recxl_paper.py);
tests assert the reproduced geomeans land inside acceptance bands.

Batched sweeps -- the ScenarioSpec API
--------------------------------------

A whole evaluation grid (Figs. 10-18: workload x config x sensitivity
knob) is ONE jitted call:

    specs = [ScenarioSpec(w, c) for w in WORKLOADS for c in CONFIGS]
    results = simulate_batch(specs)          # List[SimResult], same order

:class:`ScenarioSpec` names one grid cell: ``(workload, config, seed,
n_replicas, link_bw_gbps, n_cns, sb_size, coalescing)``; ``None`` knobs
default to the :class:`ClusterConfig`. ``simulate_batch`` synthesizes
each unique ``(workload, seed)`` trace once, derives the per-cell cost
arrays on the host, pads the batch (size to a multiple of 8, store-buffer
rings to the widest cell), and runs one branch-free ``lax.scan`` over the
stacked ``(B, n_stores)`` arrays in which all five commit rules are
computed and the per-cell rule selected by config index.

The blocked scan
----------------

The per-step batched scan (PR 1) is CPU-bound on ``lax.scan`` step
overhead: every store is one scan step of a handful of tiny ``(B,)``
ops. ``simulate_batch`` therefore defaults to a **blocked** formulation
(``chunk_size`` stores per block -- the :func:`auto_chunk` heuristic
when ``None``, always clamped to the narrowest SB in the batch: the SB
depth bounds how far back the retire recurrence can look, so within a
block every ``c_{i-sb}`` read refers to a *previous* block):

* everything that does not feed back into the commit recurrence is
  precomputed **vectorized over the whole (B, n_stores) arrays** before
  the scan: arrival times (one host-side ``np.cumsum`` per trace,
  shared verbatim with the serial oracle), and the coalesce-mask
  selects / exposed-latency terms of all five commit rules collapsed --
  exactly, because IEEE-754 addition is monotone, so ``max(r, c) + e ==
  max(r + e, c + e)`` and ``max(r + a, r + b) == r + max(a, b)`` hold
  bit-for-bit -- into one shared max-plus recurrence
  ``c_i = max(r_i + w_i, c_{i-1} + v_i)`` (see ``_blocked_precompute``);
* ``lax.scan`` runs only over **chunk boundaries** (``n_stores /
  chunk_size`` steps); within a block, the SB-ring reads collapse to a
  single vectorized gather from the carried commit history, retire
  times and both censuses (SB-full, Fig. 11 REPL-at-head) are computed
  as ``(B, K)`` block ops, and only the irreducible 2-op max-plus core
  runs per store (an unrolled, fully fusible chain of ``(B,)`` ops);
* a ragged tail (``n_stores % chunk_size``) is processed once after the
  scan with the same step function, so every chunk size is exact.

The result is **bit-identical** to the per-step scan and to the serial
oracle, for every chunk size (tests/test_batch_sim.py enforces ``==``).

The columnar trace-bank data plane
----------------------------------

Stacking per-cell copies of the five per-store arrays scales host prep,
H2D transfer and device memory with ``cells x n_stores`` even though
arrivals are identical across every cell of one trace and the
reduced-key :func:`_cell_arrays` memo already shares most derivations.
The **bank** data plane (default for the blocked engine and the
streaming tier) collapses that to ``unique_rows x n_stores``:

* one ``arrivals`` column per unique ``(workload, seed)`` trace;
* one ``(w, v, pr_nc)`` column per unique *max-plus row key* --
  ``(config-rule, workload, seed, N_r, bw, coalescing)``, with the
  constant WB/WT rules collapsing to a single constant column each.
  The max-plus collapse of :func:`_blocked_precompute` is applied **on
  the host, once per unique row** (IEEE add/max/select are exactly
  defined, so host numpy and XLA produce identical bits), so the device
  never re-derives ``w``/``v`` per cell;
* cells carry only two ``int32`` row indices; the jitted timeline
  gathers its columns on device (:func:`_timeline_banked`), and the
  streaming engine keeps one device-resident bank per mega-grid.

:func:`get_trace_bank` builds (and memoizes) the bank;
``tests/test_trace_bank.py`` property-tests that bank-gathered inputs
reconstruct the stacked inputs bit-exactly.

Batched-vs-serial contract: ``simulate()`` (the differential-testing
oracle) and ``simulate_batch`` share trace synthesis and the per-cell
cost derivation, and their timelines apply identical arithmetic -- every
``SimResult`` field from the batched paths (blocked and per-step) must
match the serial path bit-for-bit (tests/test_batch_sim.py enforces
this across chunk sizes, including ragged tails). The serial path stays
the readable reference; new commit rules must be added to
``_timeline``, ``_timeline_batch`` and ``_blocked_precompute``/
``_blocked_steps``.

Contention & crash-consistency axes
-----------------------------------

``ScenarioSpec`` carries three ``None``-defaulted axes -- ``read_share``,
``conflict_rate``, ``consistency_schedule`` -- modeled by
``repro.core.contention`` (docs/contention.md): conflict retry backoff
and sharer invalidations are added to the exposed coherence latency
(the ``w`` side of the max-plus recurrence absorbs them through the
store's ready time), persist barriers to the REPL-ack / drain terms
(the ``v`` side), all inside :func:`_make_cell_arrays` BEFORE the
collapse -- so every engine tier, both data planes and the Pallas
kernel work unchanged and stay bit-identical. Active axes append the
resolved params to the bank's max-plus row key; all-``None`` axes
change neither outputs nor dedup keys, bit-for-bit.

Two-level recurrence (queueing-coupled directory)
-------------------------------------------------

The ``directory_load`` axis nests the per-store max-plus recurrence
inside a **per-epoch service-rate recurrence** over the shared
``ShardDirectory`` shard (docs/simulator.md): stores are grouped into
``DirectoryParams.epoch``-long directory epochs; per epoch the shard's
backlog follows the Lindley recurrence

    q_e = max(q_{e-1} + own_e + bg_e - span_e, 0)

where ``own_e`` is this cell's offered directory work in the epoch,
``bg_e`` the background utilization from the cell's *real* sharer pool
(``directory.sharer_pool`` -- the union of the shard's replica peers,
never the fixed 15-peer census), and ``span_e`` the epoch's wall-clock
span on the arrival clock. Each epoch's waiting time (carried backlog
+ an M/D/1 in-epoch wait) is folded into every directory-transacting
store's ``w`` side (:func:`_directory_delay_row`, host-side inside
:func:`_make_cell_arrays` BEFORE the collapse) -- so the level-1
collapse, every engine tier, both data planes and the Pallas kernel
again work unchanged. ``directory_load=None`` keeps outputs AND dedup
keys bit-identical; active coupling appends the resolved
:class:`~repro.core.directory.DirectoryParams` to the wv key, so cells
sharing a (shard, epoch-profile) still dedup to one bank row / scan
lane. :func:`_resolve_coupling` is the single resolution point shared
by :func:`_prepare_cell` and :func:`_plane_keys`, so data and keys
cannot drift.

Failure/recovery scenario sweeps and the recovery-time (downtime) model
build on this API in ``repro.core.scenarios`` / ``repro.core.recovery``.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.recxl_paper import (
    ClusterConfig,
    PAPER_CLUSTER,
    WORKLOADS,
    WorkloadProfile,
)
from repro.core.contention import (
    ContentionParams,
    clear_contention_caches,
    contention_arrays,
    resolve_contention,
)
from repro.core.directory import (
    DirectoryParams,
    resolve_directory_load,
    sharer_pool,
)
from repro.core.hostcache import BoundedCache
from repro.core import telemetry as _tm

CONFIGS = ("wb", "wt", "baseline", "parallel", "proactive")
_CONFIG_IDX = {c: i for i, c in enumerate(CONFIGS)}
_REPLICATING = ("baseline", "parallel", "proactive")


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Per-cell simulation outputs (one store-buffer timeline).

    Field units: ``exec_time_ns`` ns (commit time of the last store,
    work-scaled for CN-count sweeps); ``max_log_bytes`` bytes (per CN
    per dump period, Fig. 13); ``*_bw_gbps`` GB/s cluster-wide (Fig.
    14); ``repl_at_head_frac`` / ``sb_full_frac`` are fractions of
    ``n_stores`` in [0, 1].
    """
    workload: str
    config: str
    exec_time_ns: float              # ns
    n_stores: int
    n_repl_msgs: int                 # REPL messages after coalescing
    repl_at_head_frac: float         # Fig. 11: REPLs issued at SB head
    max_log_bytes: float             # Fig. 13: bytes/CN/dump period
    cxl_mem_bw_gbps: float           # Fig. 14: memory traffic (GB/s)
    log_dump_bw_gbps: float          # Fig. 14: log dump traffic (GB/s)
    sb_full_frac: float              # stores that stalled on a full SB
    #: Engine metadata (not part of the simulated physics): which engine
    #: produced the cell, the blocked-scan ``chunk`` actually used (the
    #: auto heuristic's pick when ``chunk_size=None``), tile/shard info
    #: from the streaming tier. Excluded from equality comparisons.
    meta: Optional[Dict[str, object]] = dataclasses.field(
        default=None, compare=False)


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One cell of an evaluation grid (Figs. 10-18 sensitivity space).

    ``None`` knobs resolve to the ClusterConfig defaults at simulation
    time, so a spec is portable across cluster configs. Knob units:
    ``n_replicas`` peer replicas (Fig. 17), ``link_bw_gbps`` CXL link
    bandwidth in GB/s (Fig. 16), ``n_cns`` compute nodes (Fig. 18),
    ``sb_size`` store-buffer entries, ``coalescing`` enables same-line
    SB coalescing (Fig. 12).

    Contention / crash-consistency axes (``repro.core.contention``;
    docs/contention.md): ``read_share`` fraction of the remote mix that
    is reads (sharer census, [0, 1)), ``conflict_rate`` fraction of
    stores hitting a directory conflict ([0, 1)),
    ``consistency_schedule`` persist-ordering discipline (``"lazy"`` /
    ``"epoch"`` / ``"eager"``). All three default to ``None`` --
    contention modeling off, outputs and bank dedup keys unchanged; if
    any is set, the others resolve to their neutral values.

    ``directory_load`` ([0, 1) or ``None``) is the queueing-coupled
    directory axis (``repro.core.directory``): the offered utilization
    each sharer contributes to the cell's shared ``ShardDirectory``
    shard, folded into the max-plus ``w`` side per directory epoch by
    the level-2 recurrence. ``None`` = coupling off (bit-identical
    outputs and keys); ``0.0`` = the in-grid normalization cell (zero
    delays, own bank row).
    """
    workload: str
    config: str
    seed: int = 0
    n_replicas: Optional[int] = None
    link_bw_gbps: Optional[float] = None
    n_cns: Optional[int] = None
    sb_size: Optional[int] = None
    coalescing: bool = True
    read_share: Optional[float] = None
    conflict_rate: Optional[float] = None
    consistency_schedule: Optional[str] = None
    directory_load: Optional[float] = None

    def contention(self) -> Optional[ContentionParams]:
        """The cell's resolved contention params (``None`` = axes off;
        raises ``ValueError`` on out-of-range axes)."""
        return resolve_contention(self.read_share, self.conflict_rate,
                                  self.consistency_schedule)

    def validate(self, cluster: ClusterConfig) -> None:
        if self.config not in CONFIGS:
            raise ValueError(f"unknown config {self.config!r}")
        if self.workload not in WORKLOADS:
            raise ValueError(f"unknown workload {self.workload!r}")
        sb = self.sb_size if self.sb_size is not None else cluster.store_buffer
        if sb < 1:
            raise ValueError(f"sb_size must be >= 1, got {sb}")
        nr = self.n_replicas if self.n_replicas is not None else cluster.n_replicas
        if nr < 1:
            raise ValueError(f"n_replicas must be >= 1, got {nr}")
        ncn = self.n_cns if self.n_cns is not None else cluster.n_cns
        if ncn < 1:
            raise ValueError(f"n_cns must be >= 1, got {ncn}")
        bw = self.link_bw_gbps if self.link_bw_gbps is not None \
            else cluster.cxl_link_bw_gbps
        if bw <= 0.0:
            raise ValueError(f"link_bw_gbps must be > 0, got {bw}")
        self.contention()        # raises on out-of-range contention axes
        resolve_directory_load(self.directory_load, ncn, nr)


# ---------------------------------------------------------------------------
# Trace synthesis (fully vectorized -- no per-store Python loops)
# ---------------------------------------------------------------------------

def synthesize_trace(wl: WorkloadProfile, n_stores: int, seed: int,
                     cluster: ClusterConfig) -> Dict[str, np.ndarray]:
    """Synthesize one deterministic remote-store trace.

    Returns per-store arrays, each of shape ``(n_stores,)``:

    * ``gaps``        -- inter-arrival gap to the previous store (ns, f32)
    * ``arrivals``    -- absolute arrival time ``cumsum(gaps)`` (ns, f32;
      a single host-side ``np.cumsum`` shared by the serial oracle and
      both batched engines, so all three consume bit-identical inputs)
    * ``coalesce``    -- store coalesces with the previous SB entry (bool)
    * ``in_burst``    -- store is inside a flush burst (bool)
    * ``burst_pos``   -- index distance into the current burst (f32)
    * ``exposed_coh`` -- coherence latency still exposed at the SB head
      after the exclusive prefetch (ns, f32)

    Arrivals follow a two-state Markov burst process: inside a store
    burst (flush phases of the SPMD apps) gaps are ~1 cycle and runs are
    ``burst_len`` stores long on average; between bursts, exponential
    compute gaps keep the trace-wide mean store rate at the profile's
    value. Burst runs longer than the SB depth are what separate
    ReCXL-proactive from ReCXL-parallel (Fig. 8): only there does commit
    latency back-pressure the core.

    The chain is materialized by its run-length representation: burst /
    calm run lengths are geometric (exactly the two-state chain's
    sojourn distribution), drawn for the whole trace at once and
    expanded with ``np.repeat`` -- there is no per-store Python loop, so
    a batch of traces costs a handful of array ops per cell.
    """
    rng = np.random.default_rng(seed)
    ipc = 2.0
    ns_per_instr = 1.0 / (ipc * cluster.cpu_freq_ghz)
    instr_per_store = 1000.0 / wl.remote_store_rate
    mean_gap = instr_per_store * ns_per_instr

    # two-state Markov chain over stores, as alternating geometric runs
    burst_len = max(wl.burst_len, 1.0)
    p_leave_burst = 1.0 / burst_len
    frac = np.clip(wl.burstiness, 0.0, 0.98)     # fraction of stores in bursts
    calm_len = burst_len * (1.0 - frac) / max(frac, 1e-3)
    p_leave_calm = min(1.0 / max(calm_len, 1.0), 1.0)
    state0 = bool(rng.random() < frac)
    # each run is >= 1 store, so n_stores runs of each state always cover
    # the trace; trim to the first run crossing n_stores before expanding.
    m = max(n_stores, 1)
    run_burst = rng.geometric(p_leave_burst, m)
    run_calm = rng.geometric(p_leave_calm, m)
    runs = np.empty(2 * m, dtype=np.int64)
    states = np.empty(2 * m, dtype=bool)
    first, second = (run_burst, run_calm) if state0 else (run_calm, run_burst)
    runs[0::2], runs[1::2] = first, second
    states[0::2], states[1::2] = state0, not state0
    k = int(np.searchsorted(np.cumsum(runs), n_stores)) + 1
    in_burst = np.repeat(states[:k], runs[:k])[:n_stores]

    burst_gap = cluster.cycle_ns
    n_burst = int(in_burst.sum())
    n_calm = n_stores - n_burst
    calm_gap = ((mean_gap * n_stores - burst_gap * n_burst)
                / max(n_calm, 1))
    calm_gap = max(calm_gap, burst_gap)
    gaps = np.where(in_burst, burst_gap,
                    rng.exponential(calm_gap, n_stores))

    # position within the current burst (Logging-Unit backlog ramps with
    # it): index distance to the latest calm store at or before i.
    idx = np.arange(n_stores, dtype=np.int64)
    last_calm = np.maximum.accumulate(np.where(~in_burst, idx, -1))
    pos = np.where(in_burst, idx - last_calm, 0).astype(np.float32)

    coalesce = rng.random(n_stores) < wl.coalesce_rate

    # Exposed coherence at the SB head: the exclusive prefetch is issued
    # at address resolution, so by SB-head time the RFO has almost always
    # completed (the paper's explanation for parallel ~= baseline). A
    # small tail of stores (conflicted / Shared-elsewhere lines) exposes
    # part of the round trip.
    base_rtt = cluster.cxl_rtt_ns + cluster.dram_lat_ns
    tail = rng.random(n_stores) < 0.12
    exposed = np.where(tail, rng.exponential(0.15 * base_rtt, n_stores), 0.0)

    gaps32 = gaps.astype(np.float32)
    return {"gaps": gaps32,
            "arrivals": np.cumsum(gaps32, dtype=np.float32),
            "coalesce": coalesce,
            "in_burst": in_burst,
            "burst_pos": pos,
            "exposed_coh": exposed.astype(np.float32)}


@functools.lru_cache(maxsize=64)
def _trace_cached(workload: str, n_stores: int, seed: int,
                  cluster: ClusterConfig) -> Dict[str, np.ndarray]:
    """Memoized :func:`synthesize_trace` (traces are deterministic in
    the key, and sweeps re-scan the same trace for many cells and many
    calls). Callers must treat the arrays as read-only."""
    return synthesize_trace(WORKLOADS[workload], n_stores, seed, cluster)


# ---------------------------------------------------------------------------
# Host-side memoization (bounded, hash-keyed, centrally clearable)
# ---------------------------------------------------------------------------

#: The shared cache primitive (repro.core.hostcache -- contention.py
#: uses the same class for its memos without an import cycle).
_BoundedCache = BoundedCache


#: Reduced-key per-store array derivations (see :func:`_cell_arrays`).
_CELL_ARRAY_CACHE = _BoundedCache(maxsize=512)
#: Whole-batch stacked device inputs (see :func:`_batch_inputs`). One
#: entry holds five ``(n_stores, B)`` f32 arrays plus the host cells
#: (~50 MB for the Fig. 10 grid at the default store count), so the
#: bound stays small.
_BATCH_INPUT_CACHE = _BoundedCache(maxsize=4)
#: Precollapsed max-plus rows (see :func:`_wv_row`): one ``(w, v,
#: pr_nc)`` triple per unique row key, ~9 bytes x n_stores each.
_WV_ROW_CACHE = _BoundedCache(maxsize=1024)
#: Whole-grid columnar banks (see :func:`get_trace_bank`). One mega-grid
#: bank is a few hundred MB of host columns plus its device placements,
#: so at most two stay alive.
_BANK_CACHE = _BoundedCache(maxsize=2)
#: Banked per-batch index vectors + prepared cells (the banked
#: counterpart of :data:`_BATCH_INPUT_CACHE`; entries are tiny).
_BANKED_INPUT_CACHE = _BoundedCache(maxsize=8)

_CACHE_CLEARERS: List[Callable[[], None]] = []


def register_cache_clearer(fn: Callable[[], None]) -> Callable[[], None]:
    """Register a cache-dropping callback with :func:`clear_sim_caches`
    (the streaming engine registers its compiled-tile cache here, so one
    call resets every layer without import cycles)."""
    _CACHE_CLEARERS.append(fn)
    return fn


def clear_sim_caches() -> None:
    """Drop every host-side simulator memo: synthesized traces, reduced-
    key cell arrays, stacked batch inputs, and any registered engine
    caches (compiled tile programs, tile rings). Benchmarks call this
    between engines so no engine's timing rides on caches another
    engine warmed; long-lived processes can call it to release pinned
    memory after a mega-grid sweep."""
    _trace_cached.cache_clear()
    _CELL_ARRAY_CACHE.clear()
    _BATCH_INPUT_CACHE.clear()
    _WV_ROW_CACHE.clear()
    _BANK_CACHE.clear()       # drops host columns AND device placements
    _BANKED_INPUT_CACHE.clear()
    clear_contention_caches()   # conflict draws + delay rows
    for fn in list(_CACHE_CLEARERS):
        fn()


# ---------------------------------------------------------------------------
# Per-cell cost derivation (shared by the serial and batched paths)
# ---------------------------------------------------------------------------

def _commit_cost_ns(config: str, cluster: ClusterConfig) -> Dict[str, float]:
    rtt = cluster.cxl_rtt_ns
    return {
        "t_l1": cluster.cycle_ns * 2.0,
        "t_wt": rtt + cluster.pmem_lat_ns,
        # REPL->ACK round trip to peer CNs + SRAM log write at the replica.
        # N_r REPLs go out in parallel; ack time = slowest ~ one RTT + log.
        "t_repl": rtt + cluster.sram_log_lat_ns,
        # VAL is one-way, off the commit path
        "t_drain": cluster.cycle_ns,
    }


@dataclasses.dataclass
class _CellInputs:
    """Everything _timeline{,_batch} and result assembly need for one cell."""
    spec: ScenarioSpec
    n_stores: int
    sb_size: int
    config_idx: int
    work_scale: float
    # per-store timeline inputs, each (n_stores,)
    arrivals: np.ndarray
    coalesce: np.ndarray
    exposed: np.ndarray
    t_repl_i: np.ndarray
    svc_i: np.ndarray
    # derived bandwidth / log metrics (timeline-independent)
    n_repl_msgs: int
    max_log_bytes: float
    cxl_mem_bw_gbps: float
    log_dump_bw_gbps: float
    # background utilization of this cell's shared directory shard
    # (DirectoryParams.rho_bg; 0.0 with the directory axis off) --
    # surfaced as the paper-facing queue-occupancy telemetry counter
    dir_occupancy: float = 0.0


@dataclasses.dataclass(frozen=True)
class _CellArrays:
    """Heavy per-store derivations shared across grid cells (read-only)."""
    coalesce: np.ndarray             # (n_stores,) bool
    exposed: np.ndarray              # (n_stores,) f32 ns
    t_repl_i: np.ndarray             # (n_stores,) f32 ns
    svc_i: np.ndarray                # (n_stores,) f32 ns
    n_coalesced: int
    store_rate_per_core: float       # stores/s/core
    mem_demand: float                # GB/s per CN


def _directory_delay_row(arrivals: np.ndarray, tx_mask: np.ndarray,
                         dirp: DirectoryParams, cluster: ClusterConfig,
                         congestion: float) -> np.ndarray:
    """Level-2 recurrence: per-store directory-queue delay (f32 ns).

    Stores are grouped into ``dirp.epoch``-long directory epochs on the
    arrival clock. Per epoch ``e`` the shared shard sees

    * ``own_e``  -- this cell's offered service: its directory
      transactions (the non-coalesced stores) times the directory's
      DRAM state-access service time, spread over the node's
      ``dirp.buckets`` shards (each shard serves 1/buckets of the
      node's lines);
    * ``bg_e``   -- the sharer pool's background utilization
      ``rho_bg * span_e``;

    and carries the Lindley backlog ``q_e = max(q_{e-1} + own_e + bg_e
    - span_e, 0)`` -- the service-rate recurrence the per-store
    max-plus recurrence nests inside. Every directory-transacting
    store of epoch ``e`` then waits the backlog carried INTO the epoch
    plus the M/D/1 in-epoch queueing wait ``rho * s / (2 (1 - rho))``,
    scaled by the cell's link-congestion factor like every other
    latency. Host numpy (f64 recurrence, f32 result): the delays are
    folded into the ``w`` side before the collapse, so no scan kernel
    changes. Exactly all-zero when ``rho_bg == 0`` (the load-0
    normalization cell); monotone in ``rho_bg``.
    """
    n = int(arrivals.shape[0])
    if dirp.rho_bg <= 0.0 or n == 0:
        return np.zeros(n, np.float32)
    e_len = int(dirp.epoch)
    a = np.asarray(arrivals, np.float64)
    starts = a[::e_len]
    ends = np.concatenate([starts[1:], a[-1:] + cluster.cycle_ns])
    span = np.maximum(ends - starts, cluster.cycle_ns)
    tx = np.add.reduceat(np.asarray(tx_mask, np.float64),
                         np.arange(0, n, e_len))
    s_dir = float(cluster.dram_lat_ns)
    own = tx * s_dir / dirp.buckets
    bg = float(dirp.rho_bg) * span
    x = own + bg - span
    cs = np.cumsum(x)
    backlog = cs - np.minimum(np.minimum.accumulate(cs), 0.0)
    b_prev = np.concatenate([[0.0], backlog[:-1]])
    rho = np.minimum((own + bg) / span, 0.95)
    wq = rho * s_dir / (2.0 * (1.0 - rho))
    d_e = (b_prev + wq) * congestion
    delay = np.repeat(d_e, e_len)[:n]
    return np.where(tx_mask, delay, 0.0).astype(np.float32)


def _make_cell_arrays(workload: str, n_stores: int, seed: int,
                      cluster: ClusterConfig, nr: int, bw: float,
                      replicating: bool, coalesce_on: bool,
                      contention: Optional[ContentionParams] = None,
                      directory: Optional[DirectoryParams] = None
                      ) -> _CellArrays:
    wl = WORKLOADS[workload]
    trace = _trace_cached(workload, n_stores, seed, cluster)
    costs = _commit_cost_ns("proactive", cluster)   # config-independent

    # --- replication fan-out cost scaling -------------------------------
    # N_r REPLs leave in parallel but share the CN's CXL port: serialization
    # grows mildly with N_r; congestion scales latencies when offered load
    # nears the link bandwidth (Fig. 16/17 behaviour).
    repl_bytes = 8 + 64  # header + payload (coalesced line worst case)
    mean_gap = float(np.mean(trace["gaps"]))
    store_rate_per_core = 1e9 / max(mean_gap, 1e-3)          # stores/s/core
    cores = cluster.cores_per_cn
    repl_demand = store_rate_per_core * cores * nr * repl_bytes / 1e9  # GB/s
    mem_bytes = 64 + 16
    read_rate = (wl.remote_read_rate / wl.remote_store_rate) * store_rate_per_core
    mem_demand = (store_rate_per_core + read_rate) * cores * mem_bytes / 1e9
    total_demand = mem_demand + (repl_demand if replicating else 0.0)
    congestion = max(1.0, total_demand / bw)
    port_serial = 1.0 + 0.08 * (nr - 1)

    coalesce = trace["coalesce"] if coalesce_on else \
        np.zeros_like(trace["coalesce"])
    exposed = trace["exposed_coh"] * congestion

    # Per-store REPL latency: inflated inside cluster-wide bursts (the
    # SPMD apps' flush phases align across CNs, so every Logging Unit is
    # absorbing its peers' REPL streams at once). The ACK backlog ramps
    # with position in the burst, capped when the SRAM Log Buffer
    # backpressures into DRAM-speed handling; the *sustained* drain floor
    # is the DRAM-log write path (~2 DRAM accesses per entry), which is
    # what bounds ReCXL-proactive during long flushes.
    svc_entry_ns = 2.0 * (1e3 / cluster.logging_unit_freq_mhz)  # SRAM path
    # saturated drain: log-entry write + log-metadata RMW at DRAM speed
    dram_svc_ns = 4.0 * cluster.dram_lat_ns
    qslope = (svc_entry_ns * cores * nr * (1.0 - wl.coalesce_rate)
              - cluster.cycle_ns)
    qcap = 195.0                 # SRAM buffer backpressure bound (ns)
    queue_i = np.minimum(trace["burst_pos"] * max(qslope, 0.0), qcap) \
        * trace["in_burst"] * congestion
    t_repl_base = costs["t_repl"] * congestion * port_serial
    t_repl_i = t_repl_base + queue_i
    # commit-drain service floor inside bursts (proactive path)
    svc_floor = dram_svc_ns * (1.0 - wl.coalesce_rate) * congestion \
        * (1.0 + 0.1 * (nr - cluster.n_replicas))
    svc_i = np.where(trace["in_burst"], svc_floor,
                     costs["t_drain"]).astype(np.float32)

    if contention is not None:
        # conflict backoff + sharer invalidations delay the coherence
        # transaction (the store's ready time absorbs them through the
        # exposed latency -> the w side of the max-plus recurrence);
        # persist barriers ride the REPL-ack and drain-service terms
        # (the v side). Neutral params yield all-zero rows, so x + 0.0
        # keeps every output bit-identical to the uncontended cell.
        delay, flush = contention_arrays(contention, n_stores, seed,
                                         cluster, congestion)
        exposed = exposed + delay
        t_repl_i = t_repl_i + flush
        svc_i = (svc_i + flush).astype(np.float32)

    if directory is not None:
        # the level-2 (per-epoch service-rate) recurrence: the shared
        # directory shard's queueing delay rides the w side exactly
        # like the contention backoff -- zero rows at load 0, so the
        # normalization cell stays bit-identical to the axis-off cell.
        dir_delay = _directory_delay_row(
            np.asarray(trace["arrivals"], np.float32),
            ~np.asarray(coalesce, bool), directory, cluster, congestion)
        exposed = exposed + dir_delay

    return _CellArrays(
        coalesce=np.asarray(coalesce, bool),
        exposed=np.asarray(exposed, np.float32),
        t_repl_i=np.asarray(t_repl_i, np.float32),
        svc_i=svc_i,
        n_coalesced=int(coalesce.sum()),
        store_rate_per_core=store_rate_per_core,
        mem_demand=mem_demand,
    )


def _cell_arrays(workload: str, n_stores: int, seed: int,
                 cluster: ClusterConfig, nr: int, bw: float,
                 replicating: bool, coalesce_on: bool,
                 contention: Optional[ContentionParams] = None,
                 directory: Optional[DirectoryParams] = None
                 ) -> _CellArrays:
    """Memoized :func:`_make_cell_arrays` on the *reduced* key.

    The per-store arrays depend on the spec only through ``(workload,
    seed, n_replicas, link_bw, replicating-config?, coalescing
    effective?, contention, directory)`` -- NOT on ``config`` itself
    (beyond the replicating / wt-coalescing classes), ``sb_size`` or
    ``n_cns`` (the directory coupling sees the CN count only through
    the already-resolved :class:`DirectoryParams`). On a mega-grid
    whose axes include config/SB/CN sweeps, one derivation therefore
    serves many cells; the bound (:data:`_CELL_ARRAY_CACHE`) keeps
    pinned host memory at ~16 bytes x n_stores per entry."""
    key = (workload, n_stores, seed, cluster, nr, bw, replicating,
           coalesce_on, contention, directory)
    return _CELL_ARRAY_CACHE.get_or_put(
        key, lambda: _make_cell_arrays(*key))


def _resolve_coupling(spec: ScenarioSpec, cluster: ClusterConfig
                      ) -> Tuple[Optional[ContentionParams],
                                 Optional[DirectoryParams]]:
    """Resolve one cell's shared-resource coupling, canonically.

    The SINGLE resolution point for both the per-store data
    (:func:`_prepare_cell`) and the dedup keys (:func:`_plane_keys`),
    so the two cannot drift. Returns ``(contention, directory)``:

    * WB/WT commit locally without a directory transaction, so both
      components are ``None`` (their constant bank rows survive any
      coupling axis);
    * active contention gets the **directory-derived** sharer census:
      ``sharer_pool(n_cns, n_replicas)`` when ``read_share > 0`` (the
      small-cluster overcount bugfix -- never more than ``n_cns - 1``
      peers), canonical 0 when ``read_share == 0`` (the census is
      identically zero either way, so the CN weak-scaling axis keeps
      sharing lanes);
    * ``directory_load`` resolves through
      :func:`~repro.core.directory.resolve_directory_load`.
    """
    if spec.config not in _REPLICATING:
        return None, None
    nr = cluster.n_replicas if spec.n_replicas is None else spec.n_replicas
    ncn = cluster.n_cns if spec.n_cns is None else spec.n_cns
    con = spec.contention()
    if con is not None:
        pool = sharer_pool(ncn, nr) if con.read_share > 0.0 else 0
        if pool != con.sharer_pool:
            con = dataclasses.replace(con, sharer_pool=pool)
    dirp = resolve_directory_load(spec.directory_load, ncn, nr)
    return con, dirp


# ---------------------------------------------------------------------------
# Columnar trace bank (deduplicated data plane)
# ---------------------------------------------------------------------------

def _plane_keys(spec: ScenarioSpec, cluster: ClusterConfig
                ) -> Tuple[tuple, tuple]:
    """The two dedup keys of one cell's per-store inputs.

    ``trace_key`` selects the arrivals column (identical across every
    cell that scans the same trace); ``wv_key`` selects the
    precollapsed max-plus ``(w, v, pr_nc)`` column. WB/WT rows are
    constants (``t_l1`` / ``t_wt`` everywhere -- they commit locally
    without a directory transaction, so contention never touches them),
    so their key is just the rule name; the replicating rules depend on
    the reduced derivation knobs but NOT on ``sb_size`` / ``n_cns`` --
    the same reduction :func:`_cell_arrays` exploits, now visible to
    the device data plane. Active coupling axes append their resolved
    params (via :func:`_resolve_coupling`) in fixed order --
    :class:`ContentionParams` first, then
    :class:`~repro.core.directory.DirectoryParams` -- so coupled cells
    sharing a (shard, epoch-profile) still dedup to one row / lane;
    all-``None`` axes append NOTHING, so legacy grids keep
    byte-identical keys (and therefore identical bank rows -- no dedup
    churn)."""
    trace_key = (spec.workload, spec.seed)
    if spec.config in ("wb", "wt"):
        return trace_key, (spec.config,)
    nr = cluster.n_replicas if spec.n_replicas is None else spec.n_replicas
    bw = cluster.cxl_link_bw_gbps if spec.link_bw_gbps is None \
        else spec.link_bw_gbps
    wv_key = (spec.config, spec.workload, spec.seed, nr, bw,
              spec.coalescing)
    con, dirp = _resolve_coupling(spec, cluster)
    if con is not None:
        wv_key = wv_key + (con,)
    if dirp is not None:
        wv_key = wv_key + (dirp,)
    return trace_key, wv_key


def sub_bank_rows(rows: int, n_shards: int) -> int:
    """Local (per-shard) row count of a ``rows``-row wv plane
    partitioned round-robin over ``n_shards`` sub-banks: global row
    ``r`` is owned by shard ``r % n_shards`` at local row
    ``r // n_shards``, so the widest shard holds ``ceil(rows /
    n_shards)`` rows (floored at 1 so an empty or tiny plane still
    yields a valid gather target at local row 0). The ownership rule is
    a pure function of the global row index, so the append-only
    :meth:`TraceBank.extend` contract carries over: appending global
    rows only ever APPENDS to each shard's local sub-bank, never
    reshuffles it."""
    return max(1, -(-rows // n_shards))


def _make_wv_row(wv_key: tuple, n_stores: int, cluster: ClusterConfig
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One precollapsed max-plus column: host-side
    :func:`_blocked_precompute` for a single unique row.

    Applies the exact arithmetic of the device precompute -- f32 add /
    maximum / select are exactly-defined IEEE ops, so numpy and XLA
    produce identical bits -- once per unique row instead of once per
    cell. Returns ``(w, v, pr_nc)``, each ``(n_stores,)`` (f32, f32,
    bool)."""
    costs = _commit_cost_ns("proactive", cluster)
    t_l1 = np.float32(costs["t_l1"])
    t_wt = np.float32(costs["t_wt"])
    config = wv_key[0]
    if config in ("wb", "wt"):
        w = np.full(n_stores, t_l1 if config == "wb" else t_wt, np.float32)
        return w, w, np.zeros(n_stores, bool)
    _, workload, seed, nr, bw, coalescing = wv_key[:6]
    # trailing coupling components are typed, not positional: a key may
    # carry contention, directory params, both (contention first), or
    # neither -- see _plane_keys
    con = dirp = None
    for extra in wv_key[6:]:
        if isinstance(extra, ContentionParams):
            con = extra
        elif isinstance(extra, DirectoryParams):
            dirp = extra
    arr = _cell_arrays(workload, n_stores, seed, cluster, nr, bw, True,
                       coalescing, contention=con, directory=dirp)
    if config == "baseline":
        w = np.where(arr.coalesce, t_l1, arr.exposed + arr.t_repl_i)
        return w, w, np.zeros(n_stores, bool)
    if config == "parallel":
        w = np.where(arr.coalesce, t_l1,
                     np.maximum(arr.exposed, arr.t_repl_i))
        return w, w, np.zeros(n_stores, bool)
    if config == "proactive":
        pr_nc = ~arr.coalesce
        w = np.where(pr_nc, np.maximum(arr.t_repl_i, arr.exposed), t_l1)
        v = np.where(pr_nc, arr.svc_i, t_l1)
        return w, v, pr_nc
    raise ValueError(config)


def _wv_row(wv_key: tuple, n_stores: int, cluster: ClusterConfig):
    """Memoized :func:`_make_wv_row` (rows recur across banks and across
    engines sweeping the same grid)."""
    return _WV_ROW_CACHE.get_or_put(
        (wv_key, n_stores, cluster),
        lambda: _make_wv_row(wv_key, n_stores, cluster))


@dataclasses.dataclass
class TraceBank:
    """Columnar, deduplicated per-store inputs for one grid.

    Rows are **store-contiguous** (``(rows, n_stores)``, C-contiguous):
    a device gather along axis 0 is then one row memcpy per cell (XLA
    lowers whole-row gathers to copies -- measured ~3x faster on CPU
    than a column gather out of a time-major bank), and the transpose
    into the scan's time-major layout is a cheap local device op, as on
    the stacked plane. ``arrivals[trace_row[k]]`` is the arrivals row
    of trace key ``k``; ``w / v / pr_nc[wv_row[k]]`` the precollapsed
    max-plus row of row key ``k``. Host rows are built once per grid
    (memoized by :func:`get_trace_bank`) and placed on device at most
    once per placement key (:meth:`device_args`);
    :func:`clear_sim_caches` drops both.

    Banks are **append-only**: :meth:`extend` adds the rows of new
    specs in first-seen order -- exactly the order a from-scratch build
    of the merged grid would assign -- so an extended bank is
    byte-identical to :func:`get_trace_bank` of the concatenated spec
    list (tests/test_trace_bank.py pins this), existing row indices
    stay valid forever, and :meth:`device_args` uploads only the
    **diff** (the appended rows) for placements that already hold the
    old rows. The scenario-serving daemon (``repro.core.serving``)
    lives on this: the marginal H2D cost of a novel query is its new
    rows, not the bank."""
    n_stores: int
    cluster: ClusterConfig
    arrivals: np.ndarray             # (T, n_stores) f32 ns
    w: np.ndarray                    # (P, n_stores) f32 ns
    v: np.ndarray                    # (P, n_stores) f32 ns
    pr_nc: np.ndarray                # (P, n_stores) bool
    trace_row: Dict[tuple, int]
    wv_row: Dict[tuple, int]
    _device: Dict[object, tuple] = dataclasses.field(
        default_factory=dict, repr=False)
    # Logging-Unit journal: un-acknowledged extend() diffs (None = off;
    # see enable_journal / ack_journal / replay_journal below)
    _journal: Optional[List[Dict[str, np.ndarray]]] = dataclasses.field(
        default=None, repr=False)

    @property
    def trace_rows(self) -> int:
        return self.arrivals.shape[0]

    @property
    def wv_rows(self) -> int:
        return self.w.shape[0]

    @property
    def n_rows(self) -> int:
        return self.trace_rows + self.wv_rows

    @property
    def nbytes(self) -> int:
        """Host bytes of all four columns (= H2D bytes of one upload)."""
        return (self.arrivals.nbytes + self.w.nbytes + self.v.nbytes
                + self.pr_nc.nbytes)

    def rows_for(self, spec: ScenarioSpec) -> Tuple[int, int]:
        """(trace_row, wv_row) indices of one cell of the build grid."""
        tk, wk = _plane_keys(spec, self.cluster)
        return self.trace_row[tk], self.wv_row[wk]

    def device_args(self, key: object = 1,
                    place: Optional[Callable[[tuple], tuple]] = None
                    ) -> Tuple[int, tuple]:
        """Device-resident ``(arrivals, w, v, pr_nc)`` for one placement.

        ``place`` maps the host tuple onto devices (the streaming engine
        passes a replicating ``device_put`` over its ``cells`` mesh);
        the default commits to the default device. Placements are
        memoized by ``key``, so a grid swept by several engines uploads
        once. Returns ``(bytes_uploaded_now, arrays)`` --
        ``bytes_uploaded_now`` is 0 on a placement-cache hit, which is
        what the engines' ``h2d_bytes`` accounting reports.

        After :meth:`extend` grew the bank, a resident placement is
        refreshed **incrementally**: only the appended row slices cross
        host->device (``place`` sees just the diff) and are concatenated
        onto the resident buffers device-side, so
        ``bytes_uploaded_now`` is the diff's bytes, not the bank's."""
        dev = self._device.get(key)
        if dev is not None:
            t_res, p_res = int(dev[0].shape[0]), int(dev[1].shape[0])
            if t_res == self.trace_rows and p_res == self.wv_rows:
                return 0, dev
            # diff upload: ship only the rows appended since placement
            host = (self.arrivals[t_res:], self.w[p_res:],
                    self.v[p_res:], self.pr_nc[p_res:])
            fresh = place(host) if place is not None else \
                tuple(jnp.asarray(x) for x in host)
            dev = tuple(jnp.concatenate([d, f], axis=0)
                        for d, f in zip(dev, fresh))
            self._device[key] = dev
            return sum(int(x.nbytes) for x in host), dev
        host = (self.arrivals, self.w, self.v, self.pr_nc)
        dev = place(host) if place is not None else \
            tuple(jnp.asarray(x) for x in host)
        self._device[key] = dev
        return self.nbytes, dev

    def sub_bank_host(self, n_shards: int, k_replicas: int = 1) -> tuple:
        """Host arrays of the per-shard sub-bank layout: ``(arrivals,
        w_sub, v_sub, pr_nc_sub)`` with the three max-plus planes
        stacked ``(n_shards, k_replicas * local_rows, n_stores)`` --
        shard ``s``'s PRIMARY sub-bank (local rows ``[0, local)``) is
        rows ``s::n_shards`` of the global plane, zero-padded to the
        widest shard's :func:`sub_bank_rows` count.  Arrivals stay the
        global 2-D plane (they are replicated on device; see
        ``distributed.sharding.SUB_BANK_SPEC``).

        ``k_replicas > 1`` appends the paper's **Replica set** along
        the local-row axis: replica block ``j`` (local rows ``[j *
        local, (j + 1) * local)``) of shard ``s`` holds the rows owned
        by shard ``(s - j) % n_shards`` -- so global row ``r`` is
        resident on shards ``r % n`` (primary) and ``(r % n + 1) % n``
        (first replica), and losing ONE shard never loses a row
        (``repro.core.chaos.replica_rebuild`` reads the survivor's
        block back).  Gathers always target the primary block, so the
        scan arithmetic -- and at ``k_replicas=1`` the bytes -- are
        unchanged from the PR-8 layout; the replica blocks cost
        ``(k - 1)/n_shards`` extra resident bytes per max-plus plane."""
        if not 1 <= k_replicas <= n_shards:
            raise ValueError(f"k_replicas must be in [1, {n_shards}], "
                             f"got {k_replicas}")
        p_loc = sub_bank_rows(self.wv_rows, n_shards)

        def sub(col: np.ndarray) -> np.ndarray:
            out = np.zeros((n_shards, k_replicas * p_loc) + col.shape[1:],
                           col.dtype)
            for s in range(n_shards):
                for j in range(k_replicas):
                    rows = col[(s - j) % n_shards::n_shards]
                    out[s, j * p_loc:j * p_loc + rows.shape[0]] = rows
            return out

        return self.arrivals, sub(self.w), sub(self.v), sub(self.pr_nc)

    def sub_device_args(self, n_shards: int,
                        place: Optional[Callable[[tuple], tuple]] = None,
                        k_replicas: int = 1) -> Tuple[int, tuple]:
        """Device-resident sub-bank placement (:meth:`sub_bank_host`
        layout), memoized like :meth:`device_args` under the key
        ``("sub", n_shards)`` (``("sub", n_shards, k_replicas)`` for a
        replicated layout, so resilient and plain placements of one
        bank coexist). Returns ``(bytes_uploaded_now, arrays)``.
        Growth re-places the whole sub-bank (no diff path: the
        streaming engine never extends a bank mid-run, and the serving
        daemon keeps its own capacity-padded device state with
        per-shard splices)."""
        key = ("sub", n_shards) if k_replicas == 1 \
            else ("sub", n_shards, k_replicas)
        entry = self._device.get(key)
        rows_now = (self.trace_rows, self.wv_rows)
        if entry is not None:
            rows_placed, dev = entry
            if rows_placed == rows_now:
                return 0, dev
        host = self.sub_bank_host(n_shards, k_replicas)
        dev = place(host) if place is not None else \
            tuple(jnp.asarray(x) for x in host)
        self._device[key] = (rows_now, dev)
        return sum(int(x.nbytes) for x in host), dev

    def drop_placement(self, key: object) -> None:
        """Forget one memoized device placement (recovery re-admission:
        after a shard loss the stale arrays must not be served from the
        memo -- the next ``device_args``/``sub_device_args`` call
        re-places from the host truth)."""
        self._device.pop(key, None)

    # -- Logging-Unit journal (resilience; see repro.core.chaos) ----------

    @property
    def journal_enabled(self) -> bool:
        return self._journal is not None

    @property
    def journal_entries(self) -> int:
        """Un-acknowledged ``extend()`` diffs currently retained."""
        return len(self._journal) if self._journal is not None else 0

    def enable_journal(self) -> None:
        """Start journaling ``extend()`` diffs (the paper's Logging
        Unit, host-side): every append records a COPY of its new rows,
        retained until :meth:`ack_journal` confirms the device dump.
        Idempotent; off by default (the copies cost memory), enabled by
        the serving daemon when chaos/recovery is requested."""
        if self._journal is None:
            self._journal = []

    def ack_journal(self) -> None:
        """Acknowledge the device dump: every journaled diff is now
        resident device-side, so the retained copies are dropped (the
        host columns remain the durable truth)."""
        if self._journal is not None:
            self._journal.clear()

    def replay_journal(self) -> Dict[str, np.ndarray]:
        """Concatenate the un-acknowledged diffs in append order --
        what a recovering node would replay on top of the last
        acknowledged dump.  ``chaos.journal_rebuild`` digest-checks
        this against the bank's tail rows before using it."""
        if self._journal is None:
            raise RuntimeError("journal not enabled")
        empty = {"arrivals": np.zeros((0,), np.float32),
                 "w": np.zeros((0,), np.float32),
                 "v": np.zeros((0,), np.float32),
                 "pr_nc": np.zeros((0,), bool)}
        if not self._journal:
            return empty
        return {name: (np.concatenate([e[name] for e in self._journal
                                       if e[name].shape[0]], axis=0)
                       if any(e[name].shape[0] for e in self._journal)
                       else empty[name])
                for name in ("arrivals", "w", "v", "pr_nc")}

    def extend(self, specs: Sequence[ScenarioSpec]) -> Tuple[int, int]:
        """Append the rows of ``specs`` not yet in the bank, in place.

        New ``(trace, wv)`` keys get rows in **first-seen order over
        ``specs``** -- the same order :func:`_make_trace_bank` assigns
        when building the merged grid from scratch, so after
        ``bank.extend(delta)`` the bank's columns and row maps are
        byte-identical to ``get_trace_bank(base + delta)``
        (tests/test_trace_bank.py pins ``==`` on the bytes). Existing
        rows and indices are never reordered, so handles, cached index
        vectors and resident device placements of the old grid all stay
        valid; stale placements are refreshed by the next
        :meth:`device_args` call via a diff upload of just these rows.

        Returns ``(new_trace_rows, new_wv_rows)`` -- ``(0, 0)`` when
        every spec's rows were already present. Not thread-safe on its
        own; the serving daemon serializes extends under its lock.

        With the Logging-Unit journal enabled (:meth:`enable_journal`),
        every append additionally retains a COPY of its new rows until
        :meth:`ack_journal` confirms the device dump -- the host-side
        replay source ``repro.core.chaos.journal_rebuild`` recovers a
        lost shard from."""
        t0, p0 = self.trace_rows, self.wv_rows
        new_trace: List[tuple] = []
        new_wv: List[tuple] = []
        for s in specs:
            tk, wk = _plane_keys(s, self.cluster)
            if tk not in self.trace_row:
                self.trace_row[tk] = len(self.trace_row)
                new_trace.append(tk)
            if wk not in self.wv_row:
                self.wv_row[wk] = len(self.wv_row)
                new_wv.append(wk)
        if new_trace:
            rows = [_trace_cached(w, self.n_stores, seed, self.cluster)
                    ["arrivals"] for (w, seed) in new_trace]
            self.arrivals = np.concatenate(
                [self.arrivals, np.stack(rows, axis=0)], axis=0)
        if new_wv:
            cols = [_wv_row(k, self.n_stores, self.cluster) for k in new_wv]
            self.w = np.concatenate(
                [self.w, np.stack([c[0] for c in cols], axis=0)], axis=0)
            self.v = np.concatenate(
                [self.v, np.stack([c[1] for c in cols], axis=0)], axis=0)
            self.pr_nc = np.concatenate(
                [self.pr_nc, np.stack([c[2] for c in cols], axis=0)], axis=0)
        if self._journal is not None and (new_trace or new_wv):
            self._journal.append({
                "arrivals": self.arrivals[t0:].copy(),
                "w": self.w[p0:].copy(),
                "v": self.v[p0:].copy(),
                "pr_nc": self.pr_nc[p0:].copy()})
        return len(new_trace), len(new_wv)


def bank_row_maps(specs: Sequence[ScenarioSpec],
                  cluster: ClusterConfig = PAPER_CLUSTER
                  ) -> Tuple[Dict[tuple, int], Dict[tuple, int]]:
    """The (trace, wv) row maps of a grid WITHOUT materializing columns
    -- one cheap dict pass over the specs. The streaming engine uses
    this to know the bank's shape (and so its tile signatures) before
    the heavy row materialization starts, so compile warming overlaps
    the bank build."""
    trace_row: Dict[tuple, int] = {}
    wv_row: Dict[tuple, int] = {}
    for s in specs:
        tk, wk = _plane_keys(s, cluster)
        trace_row.setdefault(tk, len(trace_row))
        wv_row.setdefault(wk, len(wv_row))
    return trace_row, wv_row


def _make_trace_bank(specs: Tuple[ScenarioSpec, ...], n_stores: int,
                     cluster: ClusterConfig) -> TraceBank:
    trace_row, wv_row = bank_row_maps(specs, cluster)
    a_rows = [_trace_cached(w, n_stores, seed, cluster)["arrivals"]
              for (w, seed) in trace_row]
    wv_rows = [_wv_row(k, n_stores, cluster) for k in wv_row]
    return TraceBank(
        n_stores=n_stores, cluster=cluster,
        arrivals=np.stack(a_rows, axis=0),
        w=np.stack([c[0] for c in wv_rows], axis=0),
        v=np.stack([c[1] for c in wv_rows], axis=0),
        pr_nc=np.stack([c[2] for c in wv_rows], axis=0),
        trace_row=trace_row, wv_row=wv_row)


def get_trace_bank(specs: Sequence[ScenarioSpec], n_stores: int,
                   cluster: ClusterConfig = PAPER_CLUSTER) -> TraceBank:
    """Build (or fetch) the memoized columnar bank of a grid.

    Digest-keyed like :func:`_batch_inputs`, so ``simulate_batch`` and
    the streaming engine running the same grid share ONE bank handle
    (and therefore one device upload per placement) across engine
    switches. :func:`clear_sim_caches` drops it."""
    key = ("bank",) + _specs_key(tuple(specs), n_stores, cluster)
    return _BANK_CACHE.get_or_put(
        key, lambda: _make_trace_bank(tuple(specs), n_stores, cluster))


def _prepare_cell(spec: ScenarioSpec, trace: Dict[str, np.ndarray],
                  n_stores: int, cluster: ClusterConfig) -> _CellInputs:
    """Resolve a ScenarioSpec against a synthesized trace into the exact
    per-store arrays the timeline consumes. Pure host-side numpy; used
    verbatim by ``simulate``, ``simulate_batch`` and the streaming
    engine (which validate the specs up front) so the paths cannot
    drift. The heavy array work lives in :func:`_cell_arrays` and is
    shared across every cell with the same reduced key."""
    config = spec.config
    nr = cluster.n_replicas if spec.n_replicas is None else spec.n_replicas
    bw = cluster.cxl_link_bw_gbps if spec.link_bw_gbps is None else spec.link_bw_gbps
    ncn = cluster.n_cns if spec.n_cns is None else spec.n_cns
    sb = cluster.store_buffer if spec.sb_size is None else spec.sb_size
    replicating = config in _REPLICATING

    # contention and directory coupling only touch the directory/
    # replication transactions of the replicating configs (WB/WT commit
    # locally on the modeled path), keeping the WB normalization
    # baseline -- and the constant WB/WT bank rows -- unchanged;
    # _resolve_coupling is shared with _plane_keys so the per-store
    # data and the dedup keys cannot drift.
    con, dirp = _resolve_coupling(spec, cluster)
    arr = _cell_arrays(spec.workload, n_stores, spec.seed, cluster, nr, bw,
                       replicating, spec.coalescing and config != "wt",
                       contention=con, directory=dirp)

    # --- scaling with CN count: fewer CNs -> each runs more of the fixed
    # total work (weak scaling of the cluster as in Fig. 18).
    work_scale = cluster.n_cns / ncn

    n_repl = int(n_stores - arr.n_coalesced) if replicating else 0

    # --- log sizing (Fig. 13): entries accumulated per dump period ------
    entry_bytes = 12                       # Fig. 5: ~97 bits
    stores_per_s = arr.store_rate_per_core * cluster.cores_per_cn * nr
    log_bytes = stores_per_s * (cluster.dump_period_ms * 1e-3) * entry_bytes
    dump_bw = (log_bytes / cluster.gzip_factor) / (cluster.dump_period_ms * 1e-3) / 1e9

    return _CellInputs(
        spec=spec, n_stores=n_stores, sb_size=sb,
        config_idx=_CONFIG_IDX[config], work_scale=work_scale,
        arrivals=trace["arrivals"],
        coalesce=arr.coalesce,
        exposed=arr.exposed,
        t_repl_i=arr.t_repl_i,
        svc_i=arr.svc_i,
        n_repl_msgs=n_repl,
        max_log_bytes=log_bytes,
        cxl_mem_bw_gbps=arr.mem_demand * ncn,
        log_dump_bw_gbps=(dump_bw * ncn if replicating else 0.0),
        dir_occupancy=float(dirp.rho_bg) if dirp is not None else 0.0,
    )


def _finish_result(cell: _CellInputs, exec_ns: float, at_head: int,
                   sb_full: int,
                   meta: Optional[Dict[str, object]] = None) -> SimResult:
    n = cell.n_stores
    rec = _tm.active()
    if rec is not None:
        # paper-facing simulated protocol counters: every tier funnels
        # its cells through this epilogue, so a traced run reports the
        # same per-cell quantities the paper's figures plot (SS VII/
        # VIII), regardless of which engine produced the timeline.
        # Units: messages / bytes per dump period / GB/s / utilization.
        # ev=False: aggregate-only -- at mega-grid scale this path runs
        # tens of thousands of times per traced run, and per-cell ring
        # events would both wrap the tape and dominate the recorder's
        # overhead budget (the <= 1.05 bench pin).
        rec.count("proto/cells", 1, ev=False)
        rec.count("proto/repl_msgs", cell.n_repl_msgs, ev=False)
        rec.count("proto/log_unit_bytes", cell.max_log_bytes, ev=False)
        rec.observe("proto/dump_bw_gbps", cell.log_dump_bw_gbps, ev=False)
        rec.observe("proto/cxl_mem_bw_gbps", cell.cxl_mem_bw_gbps,
                    ev=False)
        rec.observe("proto/dir_queue_occupancy", cell.dir_occupancy,
                    ev=False)
    return SimResult(
        workload=cell.spec.workload,
        config=cell.spec.config,
        exec_time_ns=float(exec_ns) * cell.work_scale,
        n_stores=n,
        n_repl_msgs=cell.n_repl_msgs,
        repl_at_head_frac=float(at_head) / max(n, 1),
        max_log_bytes=cell.max_log_bytes,
        cxl_mem_bw_gbps=cell.cxl_mem_bw_gbps,
        log_dump_bw_gbps=cell.log_dump_bw_gbps,
        sb_full_frac=float(sb_full) / max(n, 1),
        meta=meta,
    )


# ---------------------------------------------------------------------------
# Store-buffer timeline -- serial oracle (one lax.scan per cell)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("config", "sb_size"))
def _timeline(arrivals: jax.Array, coalesce: jax.Array, exposed: jax.Array,
              t_repl_i: jax.Array, svc_i: jax.Array,
              config: str, sb_size: int, t_l1: float, t_wt: float,
              t_drain: float) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (exec_time_ns, repl_at_head_count, sb_full_count).

    ``arrivals``: absolute store arrival times (ns), precomputed on the
    host so all engines share one bit-identical input.
    ``t_repl_i``: per-store REPL->ACK latency (congestion/N_r adjusted).
    ``svc_i``: per-store replica Logging-Unit service time -- the
    throughput floor of commit draining during cluster-wide bursts (every
    CN's unit is absorbing the other CNs' REPL streams at the same time).
    """
    def body(carry, inp):
        ring, last_c, at_head, sb_full = carry
        a_i, co_i, coh_i, tr_i, sv_i = inp
        # retire: wait for a free SB slot (commit of store i - sb_size)
        oldest = ring[0]
        r_i = jnp.maximum(a_i, oldest)
        sb_full = sb_full + (oldest > a_i)

        if config == "wb":
            c_i = jnp.maximum(r_i, last_c) + t_l1
        elif config == "wt":
            c_i = jnp.maximum(r_i, last_c) + t_wt
        elif config == "baseline":
            extra = jnp.where(co_i, t_l1, coh_i + tr_i)
            c_i = jnp.maximum(r_i, last_c) + extra
        elif config == "parallel":
            extra = jnp.where(co_i, t_l1, jnp.maximum(coh_i, tr_i))
            c_i = jnp.maximum(r_i, last_c) + extra
        elif config == "proactive":
            # REPL issued at retire; ack returns tr_i later; REPL->ACK
            # cycles of queued stores overlap (Fig. 8). Commits drain no
            # faster than the replica units can log (sv_i floor).
            ack_i = r_i + tr_i
            coh_done = r_i + coh_i
            c_raw = jnp.maximum(jnp.maximum(ack_i, coh_done),
                                last_c + sv_i)
            c_i = jnp.where(co_i, jnp.maximum(r_i, last_c) + t_l1, c_raw)
            # Fig. 11: the REPL went out "at the SB head" if nothing was
            # queued ahead of the store when it retired.
            at_head = at_head + jnp.where(~co_i & (r_i >= last_c), 1, 0)
        else:
            raise ValueError(config)

        ring = jnp.roll(ring, -1).at[-1].set(c_i)
        return (ring, c_i, at_head, sb_full), None

    ring0 = jnp.zeros((sb_size,), jnp.float32)
    (ring, last_c, at_head, sb_full), _ = jax.lax.scan(
        body, (ring0, jnp.float32(0.0), jnp.int32(0), jnp.int32(0)),
        (arrivals, coalesce, exposed, t_repl_i, svc_i))
    return last_c, at_head, sb_full


# ---------------------------------------------------------------------------
# Store-buffer timeline -- batched (one lax.scan for the whole grid)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("sb_max",))
def _timeline_batch(arrivals: jax.Array, coalesce: jax.Array,
                    exposed: jax.Array,
                    t_repl_i: jax.Array, svc_i: jax.Array,
                    config_idx: jax.Array, sb_size: jax.Array, sb_max: int,
                    t_l1: float, t_wt: float
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-step batched timeline over time-major ``(n_stores, B)`` cell
    arrays (the PR-1 engine; kept as the ``chunk_size=0`` differential
    path and the speedup baseline for ``fig10/sweep/*`` bench rows).

    All five commit rules are evaluated per step (they share the retire
    recurrence and are each a couple of flops on a (B,)-vector) and the
    per-cell rule is selected by ``config_idx`` -- cheaper and simpler
    than a ``lax.switch`` which would lower to the same selects under
    batching anyway. The SB ring is a circular (B, sb_max) buffer with a
    per-cell read offset, so cells with different ``sb_size`` share one
    scan: slot ``(i - sb) % sb_max`` was last written at step ``i - sb``
    (or never, for i < sb, where it still holds the zero init), which is
    exactly the serial oracle's ``c_{i-sb}``.

    Returns per-cell (exec_time_ns, repl_at_head_count, sb_full_count).
    """
    n_b = arrivals.shape[1]
    # loop-invariant per-cell config masks, hoisted out of the scan body
    is_wt = config_idx == _CONFIG_IDX["wt"]
    is_bl = config_idx == _CONFIG_IDX["baseline"]
    is_pl = config_idx == _CONFIG_IDX["parallel"]
    is_pr = config_idx == _CONFIG_IDX["proactive"]

    def body(carry, inp):
        ring, last_c, at_head, sb_full, i = carry
        a_i, co_i, coh_i, tr_i, sv_i = inp            # each (B,)
        read = (i - sb_size) % sb_max                  # (B,)
        oldest = jnp.take_along_axis(ring, read[:, None], axis=1)[:, 0]
        r_i = jnp.maximum(a_i, oldest)
        sb_full = sb_full + (oldest > a_i).astype(jnp.int32)

        serial = jnp.maximum(r_i, last_c)
        c_wb = serial + t_l1
        c_wt = serial + t_wt
        c_bl = serial + jnp.where(co_i, t_l1, coh_i + tr_i)
        c_pl = serial + jnp.where(co_i, t_l1, jnp.maximum(coh_i, tr_i))
        c_pr_raw = jnp.maximum(jnp.maximum(r_i + tr_i, r_i + coh_i),
                               last_c + sv_i)
        c_pr = jnp.where(co_i, serial + t_l1, c_pr_raw)
        c_i = jnp.where(is_pr, c_pr,
                        jnp.where(is_pl, c_pl,
                                  jnp.where(is_bl, c_bl,
                                            jnp.where(is_wt, c_wt, c_wb))))

        at_head = at_head + (is_pr & ~co_i
                             & (r_i >= last_c)).astype(jnp.int32)
        ring = ring.at[:, i % sb_max].set(c_i)
        return (ring, c_i, at_head, sb_full, i + 1), None

    init = (jnp.zeros((n_b, sb_max), jnp.float32),
            jnp.zeros((n_b,), jnp.float32),
            jnp.zeros((n_b,), jnp.int32),
            jnp.zeros((n_b,), jnp.int32),
            jnp.int32(0))
    xs = (arrivals, coalesce, exposed, t_repl_i, svc_i)
    (_, last_c, at_head, sb_full, _), _ = jax.lax.scan(body, init, xs)
    return last_c, at_head, sb_full


# ---------------------------------------------------------------------------
# Store-buffer timeline -- blocked scan (chunk the store stream, scan over
# chunk boundaries, vectorized intra-chunk precomputation)
# ---------------------------------------------------------------------------

#: Hard ceiling on explicit chunk requests' sanity and the PR-2 era
#: default block length (the auto heuristic now caps at
#: :data:`AUTO_CHUNK_CAP`, which measures faster on every axis; the
#: ``fig10/megagrid/pr2_blocked_s`` bench row still runs this value to
#: keep the old path comparable).
DEFAULT_CHUNK_SIZE = 128


def _blocked_precompute(coalesce: jax.Array, exposed: jax.Array,
                        t_repl_i: jax.Array, svc_i: jax.Array,
                        config_idx: jax.Array, t_l1: float, t_wt: float
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Collapse all five commit rules into one max-plus recurrence.

    Every rule is exactly (bit-for-bit) of the form

        c_i = max(r_i + w_i,  c_{i-1} + v_i)

    because IEEE-754 addition is monotone, so ``max(r, c) + e ==
    max(r + e, c + e)`` and ``max(r + a, r + b) == r + max(a, b)``
    hold exactly:

    * WB / WT / baseline / parallel / coalesced-proactive
      (``c_i = max(r_i, c_{i-1}) + extra_i``):  w_i = v_i = extra_i,
      where ``extra_i`` is t_l1, t_wt, or the coalesce-mask select over
      ``exposed``/``t_repl_i`` of the replicating rules;
    * non-coalesced proactive
      (``c_i = max(r_i + max(t_repl_i, coh_i), c_{i-1} + svc_i)``):
      w_i = max(t_repl_i, exposed_i), v_i = svc_i.

    Returns ``(w, v, pr_nc)``, each time-major ``(n_stores, B)``
    (``w``/``v`` f32 ns, ``pr_nc`` bool = proactive-and-not-coalesced,
    the Fig. 11 REPL-at-SB-head candidate mask), computed in one
    vectorized pass.
    """
    is_wt = config_idx == _CONFIG_IDX["wt"]
    is_bl = config_idx == _CONFIG_IDX["baseline"]
    is_pl = config_idx == _CONFIG_IDX["parallel"]
    is_pr = config_idx == _CONFIG_IDX["proactive"]

    ex_bl = jnp.where(coalesce, t_l1, exposed + t_repl_i)
    ex_pl = jnp.where(coalesce, t_l1, jnp.maximum(exposed, t_repl_i))
    # wb and coalesced-proactive both add t_l1
    ex_other = jnp.where(is_wt[None, :], jnp.float32(t_wt),
                         jnp.float32(t_l1))
    extra = jnp.where(is_bl[None, :], ex_bl,
                      jnp.where(is_pl[None, :], ex_pl, ex_other))
    pr_nc = is_pr[None, :] & ~coalesce
    w = jnp.where(pr_nc, jnp.maximum(t_repl_i, exposed), extra)
    v = jnp.where(pr_nc, svc_i, extra)
    return w, v, pr_nc


def _blocked_steps(carry, a_b, w_b, v_b, sb_size: jax.Array):
    """Advance the blocked timeline by one block of ``K`` stores.

    ``carry`` = (hist (H, B) f32 -- the last H commit times, oldest
    first, H = padded max SB depth; last (B,) f32 -- ``c_{i-1}``).
    Block inputs are time-major ``(K, B)`` slices of the precomputed
    arrays, with K <= min(sb_size): the SB depth bounds how far back a
    retire can look, so every ``c_{i-sb}`` a block needs was committed
    in a *previous* block and sits in ``hist``. That makes the SB-ring
    reads for the whole block ONE vectorized gather (``hist[H - sb + k]``
    is exactly the oracle's ``c_{i-sb}``, still the 0.0 init for
    i < sb), leaves ``u = max(a, oldest) + w`` vectorized over the
    block, and reduces the per-store sequential work to the irreducible
    2-op max-plus core ``c = max(u_k, c + v_k)`` -- an unrolled chain of
    contiguous (B,) row ops.

    Returns the new carry and the per-block ``(c, oldest)`` matrices;
    both censuses (SB-full, Fig. 11 REPL-at-head) are recovered
    vectorized from them *outside* the scan.
    """
    hist, last = carry
    k_len = a_b.shape[0]
    h = hist.shape[0]
    idx = (h - sb_size)[None, :] + jnp.arange(k_len)[:, None]      # (K, B)
    oldest = jnp.take_along_axis(hist, idx, axis=0)                # (K, B)
    u = jnp.maximum(a_b, oldest) + w_b

    cs = []
    for k in range(k_len):
        last = jnp.maximum(u[k], last + v_b[k])
        cs.append(last)
    c = jnp.stack(cs, axis=0)                                      # (K, B)
    hist = c if k_len == h else jnp.concatenate([hist[k_len:], c], axis=0)
    return (hist, last), (c, oldest)


def _blocked_steps_uniform(carry, a_b, w_b, v_b, p_b):
    """Uniform-SB fast path for one block of ``K`` stores.

    When every cell shares one store-buffer depth ``sb`` (the common
    case -- Table II fixes SB = 72 unless the sweep varies it), the
    commit history is carried as a *tuple* of ``sb`` ``(B,)`` arrays
    (oldest first), so the SB-ring read for store ``k`` is the plain
    Python indexing ``hist[k]`` (``c_{i-sb}`` exactly, K <= sb) and the
    history shift is static tuple slicing -- no gather, no stacked
    commit matrix, no materialized per-store timeline. Both censuses
    accumulate in-scan (integer adds, order-exact). The per-store work
    is ~7 tiny fusible ``(B,)`` ops; applies the same arithmetic as
    :func:`_blocked_steps` element-for-element, so results stay
    bit-identical across paths.

    ``carry`` = (hist tuple, last (B,), at_head (B,) i32, sb_full (B,)
    i32); block inputs are time-major ``(K, B)`` slices.
    """
    hist, last, at_head, sb_full = carry
    k_len = a_b.shape[0]
    cs = []
    for k in range(k_len):
        old = hist[k]
        r_k = jnp.maximum(a_b[k], old)
        sb_full = sb_full + (old > a_b[k])
        at_head = at_head + (p_b[k] & (r_k >= last))
        last = jnp.maximum(r_k + w_b[k], last + v_b[k])
        cs.append(last)
    return (hist[k_len:] + tuple(cs), last, at_head, sb_full)


@functools.partial(jax.jit,
                   static_argnames=("sb_max", "chunk", "sb_uniform"))
def _timeline_batch_blocked(arrivals: jax.Array, coalesce: jax.Array,
                            exposed: jax.Array, t_repl_i: jax.Array,
                            svc_i: jax.Array, config_idx: jax.Array,
                            sb_size: jax.Array, sb_max: int, chunk: int,
                            sb_uniform: Optional[int],
                            t_l1: float, t_wt: float
                            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Blocked batched timeline: ``lax.scan`` over chunk boundaries only.

    Same inputs/outputs as ``_timeline_batch`` plus two statics:
    ``chunk`` (stores per block; the caller clamps it to
    ``min(sb_size)``) and ``sb_uniform`` (the shared SB depth when every
    cell has the same one, else None). ``n_stores // chunk`` full blocks
    run inside one scan -- in the time-major layout the blocking
    reshape is free -- and the ragged tail (``n_stores % chunk``
    stores) is processed once after the scan with the same step
    function, so results are exact for every chunk size.

    With ``sb_uniform`` set, the tuple-history fast path
    (:func:`_blocked_steps_uniform`) runs with censuses accumulated
    in-scan. The general path (:func:`_blocked_steps`, per-cell SB
    depths) emits the full commit / SB-read timelines and computes both
    censuses vectorized over the whole ``(n_stores, B)`` arrays
    afterwards. Both are bit-identical to the per-step engine and the
    serial oracle by construction (see module docstring).

    Returns per-cell (exec_time_ns, repl_at_head_count, sb_full_count).
    """
    w, v, pr_nc = _blocked_precompute(
        coalesce, exposed, t_repl_i, svc_i, config_idx, t_l1, t_wt)
    return _scan_wv(arrivals, w, v, pr_nc, sb_size, sb_max, chunk,
                    sb_uniform)


def _scan_wv(arrivals: jax.Array, w: jax.Array, v: jax.Array,
             pr_nc: jax.Array, sb_size: Optional[jax.Array], sb_max: int,
             chunk: int, sb_uniform: Optional[int]
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """The blocked scan proper, over already-collapsed max-plus inputs.

    Inputs are time-major ``(n_stores, B)``: ``arrivals`` plus the
    ``(w, v, pr_nc)`` of :func:`_blocked_precompute` -- whether those
    came from the in-jit precompute (stacked plane) or from a bank
    gather of host-precollapsed columns (banked plane), the arithmetic
    from here on is identical, so both planes are bit-identical.
    ``sb_size`` is only read on the general (mixed-SB) path and may be
    ``None`` when ``sb_uniform`` is set. Must be called inside jit
    (shapes/statics as in :func:`_timeline_batch_blocked`).
    """
    n, n_b = arrivals.shape
    n_main = (n // chunk) * chunk
    rem = n - n_main

    def to_blocks(x):
        # time-major blocking is a free reshape: (n_main, B) ->
        # (n_blocks, chunk, B)
        return x[:n_main].reshape(-1, chunk, n_b)

    if sb_uniform is not None:
        carry = (tuple(jnp.zeros((n_b,), jnp.float32)
                       for _ in range(sb_uniform)),
                 jnp.zeros((n_b,), jnp.float32),
                 jnp.zeros((n_b,), jnp.int32),
                 jnp.zeros((n_b,), jnp.int32))
        if n_main:
            xs = tuple(to_blocks(x) for x in (arrivals, w, v, pr_nc))

            def body(c, blk):
                return _blocked_steps_uniform(c, *blk), None

            carry, _ = jax.lax.scan(body, carry, xs)
        if rem:
            tail = tuple(x[n_main:] for x in (arrivals, w, v, pr_nc))
            carry = _blocked_steps_uniform(carry, *tail)
        _, last_c, at_head, sb_full = carry
        return last_c, at_head, sb_full

    carry = (jnp.zeros((sb_max, n_b), jnp.float32),
             jnp.zeros((n_b,), jnp.float32))
    parts_c, parts_old = [], []
    if n_main:
        xs = tuple(to_blocks(x) for x in (arrivals, w, v))

        def body(c, blk):
            return _blocked_steps(c, *blk, sb_size=sb_size)

        carry, (c_blks, old_blks) = jax.lax.scan(body, carry, xs)
        parts_c.append(c_blks.reshape(n_main, n_b))
        parts_old.append(old_blks.reshape(n_main, n_b))
    if rem:
        tail = tuple(x[n_main:] for x in (arrivals, w, v))
        carry, (c_tail, old_tail) = _blocked_steps(carry, *tail,
                                                   sb_size=sb_size)
        parts_c.append(c_tail)
        parts_old.append(old_tail)
    c = parts_c[0] if len(parts_c) == 1 else jnp.concatenate(parts_c, axis=0)
    oldest = parts_old[0] if len(parts_old) == 1 \
        else jnp.concatenate(parts_old, axis=0)

    # post-hoc vectorized censuses (identical f32 ops, so identical bits)
    r = jnp.maximum(arrivals, oldest)
    sb_full = jnp.sum(oldest > arrivals, axis=0, dtype=jnp.int32)
    prev = jnp.concatenate([jnp.zeros((1, n_b), jnp.float32), c[:-1]],
                           axis=0)
    at_head = jnp.sum(pr_nc & (r >= prev), axis=0, dtype=jnp.int32)
    return c[-1], at_head, sb_full


def _bank_gather(a_bank: jax.Array, w_bank: jax.Array, v_bank: jax.Array,
                 p_bank: jax.Array, trace_idx: jax.Array, wv_idx: jax.Array
                 ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """In-jit bank gather into the scan's time-major layout.

    One row memcpy per cell (whole-row gathers lower to copies) plus
    the same cheap device transpose the stacked streaming plane uses.
    The SINGLE definition of how bank rows become scan inputs -- both
    the one-shot banked timeline below and the streaming engine's tile
    programs call it, so the two banked planes cannot drift. Must be
    called inside jit."""
    return (jnp.take(a_bank, trace_idx, axis=0).T,
            jnp.take(w_bank, wv_idx, axis=0).T,
            jnp.take(v_bank, wv_idx, axis=0).T,
            jnp.take(p_bank, wv_idx, axis=0).T)


@functools.partial(jax.jit,
                   static_argnames=("sb_max", "chunk", "sb_uniform"))
def _timeline_banked(a_bank: jax.Array, w_bank: jax.Array, v_bank: jax.Array,
                     p_bank: jax.Array, trace_idx: jax.Array,
                     wv_idx: jax.Array, sb_size: jax.Array, sb_max: int,
                     chunk: int, sb_uniform: Optional[int]
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Blocked timeline over the columnar bank: in-jit gather + scan.

    ``*_bank`` are the store-contiguous :class:`TraceBank` rows; the
    two ``int32`` index vectors select each cell's rows (no stacked
    host copies, no H2D of per-cell arrays). Gathering moves identical
    bits, so results match the stacked plane ``==``.
    """
    a, w, v, p = _bank_gather(a_bank, w_bank, v_bank, p_bank,
                              trace_idx, wv_idx)
    return _scan_wv(a, w, v, p, sb_size, sb_max, chunk, sb_uniform)


# ---------------------------------------------------------------------------
# Public entries
# ---------------------------------------------------------------------------

def simulate(workload: str, config: str,
             cluster: ClusterConfig = PAPER_CLUSTER,
             n_stores: int = 50_000, seed: int = 0,
             n_replicas: Optional[int] = None,
             link_bw_gbps: Optional[float] = None,
             n_cns: Optional[int] = None,
             sb_size: Optional[int] = None,
             coalescing: bool = True,
             read_share: Optional[float] = None,
             conflict_rate: Optional[float] = None,
             consistency_schedule: Optional[str] = None,
             directory_load: Optional[float] = None) -> SimResult:
    """Simulate one (workload, config) pair on one compute node.

    All sensitivity knobs of Figs. 16-18 are exposed as overrides
    (``n_replicas`` replica count, ``link_bw_gbps`` CXL link bandwidth in
    GB/s, ``n_cns`` compute-node count, ``sb_size`` store-buffer
    entries), as are the contention axes (``read_share`` /
    ``conflict_rate`` / ``consistency_schedule`` -- see
    ``repro.core.contention``) and the directory-coupling axis
    (``directory_load`` -- see ``repro.core.directory``). This is the
    serial oracle the batched engines are differentially tested
    against; returns a :class:`SimResult` (times in ns, log sizes in
    bytes, bandwidths in GB/s).
    """
    spec = ScenarioSpec(workload, config, seed=seed, n_replicas=n_replicas,
                        link_bw_gbps=link_bw_gbps, n_cns=n_cns,
                        sb_size=sb_size, coalescing=coalescing,
                        read_share=read_share, conflict_rate=conflict_rate,
                        consistency_schedule=consistency_schedule,
                        directory_load=directory_load)
    spec.validate(cluster)
    trace = _trace_cached(workload, n_stores, seed, cluster)
    cell = _prepare_cell(spec, trace, n_stores, cluster)
    costs = _commit_cost_ns(config, cluster)
    exec_ns, at_head, sb_full = _timeline(
        jnp.asarray(cell.arrivals), jnp.asarray(cell.coalesce),
        jnp.asarray(cell.exposed), jnp.asarray(cell.t_repl_i),
        jnp.asarray(cell.svc_i), config, cell.sb_size,
        costs["t_l1"], costs["t_wt"], costs["t_drain"])
    return _finish_result(cell, exec_ns, int(at_head), int(sb_full),
                          meta={"engine": "serial",
                                "data_plane": "stacked",
                                "bank_partition": None})


def simulate_spec(spec: ScenarioSpec,
                  cluster: ClusterConfig = PAPER_CLUSTER,
                  n_stores: int = 50_000) -> SimResult:
    """Run the serial oracle for one :class:`ScenarioSpec` cell.

    The single place that maps EVERY spec knob -- including the
    contention axes -- onto :func:`simulate`'s keyword surface, so
    differential callers (the engine's ``serial`` tier, benchmark
    oracle checks) cannot silently drop a new axis."""
    return simulate(spec.workload, spec.config, cluster=cluster,
                    n_stores=n_stores, seed=spec.seed,
                    n_replicas=spec.n_replicas,
                    link_bw_gbps=spec.link_bw_gbps, n_cns=spec.n_cns,
                    sb_size=spec.sb_size, coalescing=spec.coalescing,
                    read_share=spec.read_share,
                    conflict_rate=spec.conflict_rate,
                    consistency_schedule=spec.consistency_schedule,
                    directory_load=spec.directory_load)


def _pad_len(n: int, mult: int = 8) -> int:
    return max(((n + mult - 1) // mult) * mult, mult)


def _stack_cells(cells: List[_CellInputs]):
    """Stack prepared cells into time-major batch arrays (host numpy).

    The batch is padded to the next multiple of 8 cells by repeating
    cell 0, and SB rings to the widest cell (multiple of 8). Per-store
    arrays are stacked time-major ``(n_stores, B)``: the natural layout
    for both one-shot scans (xs slices and block reshapes are
    contiguous). The streaming engine does NOT use this -- its tiles
    stack cell-major (``engine._stack_tile``) and transpose on device.

    Returns ``(args, sb_max, sb_min, sb_uniform)`` where ``args`` is
    the 7-tuple the batched timelines consume.
    """
    n_pad = _pad_len(len(cells))
    padded = cells + [cells[0]] * (n_pad - len(cells))
    sb_max = _pad_len(max(c.sb_size for c in padded))
    args = (
        np.stack([c.arrivals for c in padded], axis=1),
        np.stack([c.coalesce for c in padded], axis=1),
        np.stack([c.exposed for c in padded], axis=1),
        np.stack([c.t_repl_i for c in padded], axis=1),
        np.stack([c.svc_i for c in padded], axis=1),
        np.asarray([c.config_idx for c in padded], np.int32),
        np.asarray([c.sb_size for c in padded], np.int32),
    )
    sb_min = min(c.sb_size for c in padded)
    sb_uniform = sb_min if sb_min == max(c.sb_size for c in padded) else None
    return args, sb_max, sb_min, sb_uniform


def _make_batch_inputs(specs: Tuple[ScenarioSpec, ...], n_stores: int,
                       cluster: ClusterConfig):
    cells = [_prepare_cell(s, _trace_cached(s.workload, n_stores, s.seed,
                                            cluster), n_stores, cluster)
             for s in specs]
    np_args, sb_max, sb_min, sb_uniform = _stack_cells(cells)
    args = tuple(jnp.asarray(a) for a in np_args)
    return cells, args, sb_max, sb_min, sb_uniform


def _batch_inputs(specs: Tuple[ScenarioSpec, ...], n_stores: int,
                  cluster: ClusterConfig):
    """Memoized host-side prep for one batch: synthesizes/derives every
    cell and stacks the padded device arrays. Sweeps that re-run the
    same grid (benchmarks, repeated scenario evaluation) skip straight
    to the timeline.

    The memo is digest-keyed (:func:`_specs_key`) and size-bounded
    (:data:`_BATCH_INPUT_CACHE`): a mega-grid's 10^4-spec tuple never
    becomes a dictionary key, and at most ``maxsize`` batches' device
    arrays stay pinned. :func:`clear_sim_caches` drops it."""
    key = _specs_key(specs, n_stores, cluster)
    return _BATCH_INPUT_CACHE.get_or_put(
        key, lambda: _make_batch_inputs(specs, n_stores, cluster))


def _specs_key(specs: Sequence[ScenarioSpec], n_stores: int,
               cluster: ClusterConfig) -> Tuple[int, int, str]:
    """Constant-size digest key for a (specs, n_stores, cluster) batch."""
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((n_stores, cluster)).encode())
    for s in specs:
        h.update(repr(s).encode())
    return (len(specs), n_stores, h.hexdigest())


_batch_inputs.cache_clear = _BATCH_INPUT_CACHE.clear   # lru_cache-compat


def _make_banked_inputs(specs: Tuple[ScenarioSpec, ...], n_stores: int,
                        cluster: ClusterConfig):
    # the bank handle is deliberately NOT part of the returned (cached)
    # tuple: row indices are deterministic (first-seen order over the
    # same specs), so callers re-resolve the bank through
    # get_trace_bank and _BANK_CACHE's small bound stays the ONLY thing
    # keeping multi-hundred-MB banks alive
    bank = get_trace_bank(specs, n_stores, cluster)
    cells = [_prepare_cell(s, _trace_cached(s.workload, n_stores, s.seed,
                                            cluster), n_stores, cluster)
             for s in specs]
    # scan-lane dedup (same reduction as the streaming engine's): a
    # timeline consumes only (arrivals row, max-plus row, SB depth), so
    # cells sharing that triple are ONE lane -- gathered and scanned
    # once, with the lane outputs scattered back to member cells by
    # ``cell_lane``. The one-shot tier no longer gathers (and pads) the
    # full (n_stores, B) batch on device when the grid repeats lanes
    # (e.g. the whole CN axis of a sweep): device gather width, scan
    # width and the shipped index bytes all shrink to unique lanes.
    lane_of: Dict[tuple, int] = {}
    lane_rows: List[Tuple[int, int]] = []
    lane_sb: List[int] = []
    cell_lane: List[int] = []
    for c in cells:
        tr, wv = bank.rows_for(c.spec)
        key = (c.sb_size, tr, wv)
        j = lane_of.setdefault(key, len(lane_rows))
        if j == len(lane_rows):
            lane_rows.append((tr, wv))
            lane_sb.append(c.sb_size)
        cell_lane.append(j)
    n_lanes = len(lane_rows)
    pad = _pad_len(n_lanes) - n_lanes
    trace_idx = np.asarray([r[0] for r in lane_rows]
                           + [lane_rows[0][0]] * pad, np.int32)
    wv_idx = np.asarray([r[1] for r in lane_rows]
                        + [lane_rows[0][1]] * pad, np.int32)
    sb_list = lane_sb + [lane_sb[0]] * pad
    sb_arr = np.asarray(sb_list, np.int32)
    sb_max = _pad_len(max(sb_list))
    sb_min = min(sb_list)
    sb_uniform = sb_min if sb_min == max(sb_list) else None
    return (cells, np.asarray(cell_lane, np.int64), n_lanes, trace_idx,
            wv_idx, sb_arr, sb_max, sb_min, sb_uniform)


def _banked_inputs(specs: Tuple[ScenarioSpec, ...], n_stores: int,
                   cluster: ClusterConfig):
    """Memoized banked host prep for one batch: the padded ``int32``
    lane-index vectors, the cell->lane scatter map, plus prepared cells
    (the banked counterpart of :func:`_batch_inputs` -- entries are a
    few KB instead of stacked array copies, and hold NO reference to
    the bank itself)."""
    key = _specs_key(specs, n_stores, cluster)
    return _BANKED_INPUT_CACHE.get_or_put(
        key, lambda: _make_banked_inputs(specs, n_stores, cluster))


#: Cap for the auto-chunk heuristic on *wide* batches. The per-block
#: unroll is ``chunk`` steps of ~7 row ops and a ``chunk``-long carried
#: history, so past a few dozen stores per block wide batches (rows of
#: hundreds+ cells) lose throughput to carry traffic and compile time;
#: measured fastest around 32-48 at tile widths, vs the full SB depth
#: for narrow batches. Explicit ``chunk_size`` callers can pick
#: anything.
AUTO_CHUNK_CAP = 48

#: Batch width (padded cell count) at which the auto heuristic switches
#: from the deep narrow-batch chunk to the capped wide-batch chunk.
AUTO_CHUNK_WIDE_CELLS = 256


def auto_chunk(n_stores: int, sb_min: int,
               n_cells: Optional[int] = None) -> int:
    """Blocked-scan chunk heuristic (used when ``chunk_size=None``).

    The SB depth bounds how far back the retire recurrence can look
    (``c_{i-sb}``), so a block may never exceed the narrowest SB in the
    batch. Beyond that, two measured regimes (CPU):

    * **narrow** batches (``n_cells`` < :data:`AUTO_CHUNK_WIDE_CELLS`,
      e.g. the 45-cell Fig. 10 grid): ``lax.scan`` step overhead
      dominates the tiny per-store row ops, so the deepest legal block
      wins -- ``min(sb, n_stores, DEFAULT_CHUNK_SIZE)``;
    * **wide** batches (mega-grid tiles, one-shot mega-batches, or
      ``n_cells=None``): the unrolled block body and its carried
      history dominate, so the cap is :data:`AUTO_CHUNK_CAP` -- and a
      chunk that divides ``n_stores`` exactly is preferred, because a
      ragged tail duplicates the whole unrolled block body in the
      compiled program.

    The pick lands in ``SimResult.meta['chunk']``.
    """
    hi = min(sb_min, n_stores)
    if n_cells is not None and n_cells < AUTO_CHUNK_WIDE_CELLS:
        return max(1, min(hi, DEFAULT_CHUNK_SIZE))
    cap = min(hi, AUTO_CHUNK_CAP)
    for c in range(cap, 15, -1):         # largest exact divisor, if any
        if n_stores % c == 0:
            return c
    return max(1, cap)


def simulate_batch(specs: Sequence[ScenarioSpec],
                   cluster: ClusterConfig = PAPER_CLUSTER,
                   n_stores: int = 50_000,
                   chunk_size: Optional[int] = None,
                   data_plane: Optional[str] = None) -> List[SimResult]:
    """Simulate a whole scenario grid in one jitted call.

    Results come back in ``specs`` order (one :class:`SimResult` per
    spec; times in ns, log sizes in bytes, bandwidths in GB/s). Unique
    ``(workload, seed)`` traces are synthesized once and shared across
    every cell that scans them; the batch is padded to a multiple of 8
    cells (and SB rings to the widest cell, rounded to a multiple of 8)
    so sweeps of similar size reuse one compiled program.

    ``chunk_size`` selects the engine: ``None`` (default) runs the
    blocked scan with the :func:`auto_chunk` heuristic deriving the
    block from the narrowest ``sb_size`` in the batch; an explicit
    ``>= 1`` value requests that many stores per block (still clamped
    to ``n_stores`` and the narrowest SB, since a block may not look
    back past the carried commit history); ``0`` runs the PR-1 per-step
    scan. ``data_plane`` selects how per-store inputs reach the device:
    ``"bank"`` (the blocked default) ships the deduplicated columnar
    :class:`TraceBank` plus ``int32`` row indices, gathers in-jit, and
    -- like the streaming tier -- scans only unique **lanes** (cells
    sharing ``(SB, trace row, max-plus row)`` have bit-identical
    timelines, so their outputs are scattered from one scanned lane;
    ``meta["scan_lanes"]`` reports the count); ``"stacked"`` ships one
    full array copy per cell (the pre-bank plane, kept as the
    comparison baseline -- and the only plane of the per-step engine). All engines and planes are bit-identical to each
    other and to the serial :func:`simulate` oracle; the blocked one is
    several times faster on CPU (see ``fig10/sweep/*`` bench rows).
    The engine, chunk and data plane actually used are reported in
    ``SimResult.meta`` (plus ``bank_rows`` / ``h2d_bytes`` -- the
    plane's cold per-call H2D footprint). Grids much larger than a few
    thousand cells should go through the streaming tier
    (``repro.core.engine.simulate_grid``) instead.
    """
    if not specs:
        return []
    if chunk_size is not None and chunk_size < 0:
        raise ValueError(f"chunk_size must be >= 0, got {chunk_size}")
    if data_plane not in (None, "bank", "stacked"):
        raise ValueError(f"unknown data_plane {data_plane!r}")
    if data_plane == "bank" and chunk_size is not None and chunk_size == 0:
        raise ValueError("the per-step engine has no banked plane")
    for s in specs:
        s.validate(cluster)

    costs = _commit_cost_ns("proactive", cluster)   # t_l1/t_wt are shared
    cell_lane = None
    if chunk_size is None or chunk_size:
        plane = data_plane or "bank"
        if plane == "bank":
            (cells, cell_lane, n_lanes, trace_idx, wv_idx, sb_arr, sb_max,
             sb_min, sb_uniform) = _banked_inputs(tuple(specs), n_stores,
                                                  cluster)
            bank = get_trace_bank(specs, n_stores, cluster)
            idx_bytes = trace_idx.nbytes + wv_idx.nbytes + sb_arr.nbytes
            batch_width = len(trace_idx)        # padded unique lanes
        else:
            cells, args, sb_max, sb_min, sb_uniform = _batch_inputs(
                tuple(specs), n_stores, cluster)
            batch_width = _pad_len(len(specs))
        # a block may not reach past the carried history: the SB depth
        # bounds the lookback (c_{i-sb}), so clamp to the narrowest cell
        chunk = auto_chunk(n_stores, sb_min, batch_width) \
            if chunk_size is None else min(chunk_size, n_stores, sb_min)
        meta = {"engine": "blocked", "chunk": chunk,
                "auto_chunk": chunk_size is None, "data_plane": plane,
                "bank_partition": None}   # one device: nothing to shard
        if plane == "bank":
            meta["bank_rows"] = bank.n_rows
            meta["scan_lanes"] = n_lanes
            meta["h2d_bytes"] = bank.nbytes + idx_bytes
            _, bank_dev = bank.device_args()
            exec_ns, at_head, sb_full = _timeline_banked(
                *bank_dev, jnp.asarray(trace_idx), jnp.asarray(wv_idx),
                jnp.asarray(sb_arr), sb_max, chunk, sb_uniform)
        else:
            meta["h2d_bytes"] = sum(int(a.nbytes) for a in args)
            exec_ns, at_head, sb_full = _timeline_batch_blocked(
                *args, sb_max, chunk, sb_uniform, costs["t_l1"],
                costs["t_wt"])
    else:
        cells, args, sb_max, sb_min, sb_uniform = _batch_inputs(
            tuple(specs), n_stores, cluster)
        meta = {"engine": "perstep", "chunk": 0, "auto_chunk": False,
                "data_plane": "stacked", "bank_partition": None,
                "h2d_bytes": sum(int(a.nbytes) for a in args)}
        exec_ns, at_head, sb_full = _timeline_batch(
            *args, sb_max, costs["t_l1"], costs["t_wt"])
    exec_ns = np.asarray(exec_ns)
    at_head = np.asarray(at_head)
    sb_full = np.asarray(sb_full)
    if cell_lane is not None:
        # scatter each deduplicated lane's outputs to its member cells
        exec_ns = exec_ns[cell_lane]
        at_head = at_head[cell_lane]
        sb_full = sb_full[cell_lane]

    # fresh meta per result: SimResult is frozen but a shared dict would
    # alias annotations across the whole batch
    return [_finish_result(c, exec_ns[i], int(at_head[i]), int(sb_full[i]),
                           meta=dict(meta))
            for i, c in enumerate(cells)]


def slowdowns_from_results(results: Sequence[SimResult],
                           baseline: str = "wb"
                           ) -> Dict[str, Dict[str, float]]:
    """Group batched SimResults into a per-workload slowdown table
    normalized to ``baseline`` (one ``baseline`` cell per workload must
    be present; cells are keyed by (workload, config), so pass results
    from a grid that does not repeat a cell with different knobs)."""
    times: Dict[str, Dict[str, float]] = {}
    for r in results:
        times.setdefault(r.workload, {})[r.config] = r.exec_time_ns
    out: Dict[str, Dict[str, float]] = {}
    for w, row in times.items():
        if baseline not in row:
            raise ValueError(f"no {baseline!r} cell for workload {w!r}")
        out[w] = {c: t / row[baseline] for c, t in row.items()}
    return out


def slowdown_table(configs: Tuple[str, ...] = CONFIGS,
                   workloads: Optional[Tuple[str, ...]] = None,
                   n_stores: int = 50_000, batched: bool = True,
                   cluster: ClusterConfig = PAPER_CLUSTER,
                   **kw) -> Dict[str, Dict[str, float]]:
    """Fig. 2 / Fig. 10: per-workload slowdowns normalized to WB.

    ``batched=True`` (default) runs the whole grid as ONE
    ``simulate_batch`` call; ``batched=False`` keeps the serial per-cell
    oracle loop for differential testing. ``kw`` takes any ScenarioSpec
    knob (seed, n_replicas, link_bw_gbps, n_cns, sb_size, coalescing).
    """
    workloads = workloads or tuple(WORKLOADS)
    cfgs = tuple(dict.fromkeys(("wb",) + tuple(configs)))
    if batched:
        specs = [ScenarioSpec(w, c, **kw) for w in workloads for c in cfgs]
        results = simulate_batch(specs, cluster=cluster, n_stores=n_stores)
        table = slowdowns_from_results(results)
        return {w: {c: table[w][c] for c in configs} for w in workloads}
    out: Dict[str, Dict[str, float]] = {}
    for w in workloads:
        base = simulate(w, "wb", cluster=cluster, n_stores=n_stores,
                        **kw).exec_time_ns
        out[w] = {}
        for c in configs:
            t = simulate(w, c, cluster=cluster, n_stores=n_stores,
                         **kw).exec_time_ns
            out[w][c] = t / base
    return out


def geomean_slowdowns(table: Dict[str, Dict[str, float]]) -> Dict[str, float]:
    """Per-config geometric mean over the workloads of a slowdown table
    (the paper's headline aggregation; dimensionless ratios)."""
    out: Dict[str, float] = {}
    for c in next(iter(table.values())):
        vals = [table[w][c] for w in table]
        out[c] = float(np.exp(np.mean(np.log(vals))))
    return out
