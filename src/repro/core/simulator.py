"""Trace-driven ReCXL protocol simulator (paper SS VI-VII).

The paper evaluates ReCXL with SST + Pin traces of PARSEC / SPLASH-2 /
YCSB on a 16-CN / 16-MN cluster (Table II). We reproduce that evaluation
with a vectorized store-timeline simulator: per application class, a
synthetic remote-store trace (arrival times, coalescability) is pushed
through a store-buffer model that implements the exact commit rules of
the five configurations (Fig. 6):

* WB            c_i = max(r_i, c_{i-1}) + t_l1
* WT            c_i = max(r_i, c_{i-1}) + t_rtt + t_pmem     (TSO serial)
* baseline      c_i = max(r_i, c_{i-1}) + t_coh_exposed + t_repl
* parallel      c_i = max(r_i, c_{i-1}) + max(t_coh_exposed, t_repl)
* proactive     c_i = max(c_{i-1} + t_drain, ack_i, coh_i)
                with ack_i = r_i + t_repl issued at *retire* time, so
                REPL->ACK cycles of queued stores overlap (Fig. 8)

where r_i (retire into SB) stalls when the SB is full:
r_i = max(a_i, c_{i-SB}) -- the SB-occupancy recurrence is carried through
one ``lax.scan`` with a ring of the last SB commit times.

Exclusive prefetch (Fig. 7) is modeled by drawing the *exposed* coherence
latency: the RFO is issued at address resolution (lead time ~ SB queueing
delay), so at the SB head the transaction has usually completed --
matching the paper's finding that ReCXL-parallel barely beats
ReCXL-baseline.

Everything is deterministic given (workload, seed). Calibration targets
are the paper's headline numbers (PAPER_CLAIMS in configs/recxl_paper.py);
tests assert the reproduced geomeans land inside acceptance bands.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.recxl_paper import (
    ClusterConfig,
    PAPER_CLUSTER,
    WORKLOADS,
    WorkloadProfile,
)

CONFIGS = ("wb", "wt", "baseline", "parallel", "proactive")


@dataclasses.dataclass(frozen=True)
class SimResult:
    workload: str
    config: str
    exec_time_ns: float
    n_stores: int
    n_repl_msgs: int                 # after coalescing
    repl_at_head_frac: float         # Fig. 11
    max_log_bytes: float             # Fig. 13 (per CN, per dump period)
    cxl_mem_bw_gbps: float           # Fig. 14 (memory traffic component)
    log_dump_bw_gbps: float          # Fig. 14 (log dump component)
    sb_full_frac: float


# ---------------------------------------------------------------------------
# Trace synthesis
# ---------------------------------------------------------------------------

def synthesize_trace(wl: WorkloadProfile, n_stores: int, seed: int,
                     cluster: ClusterConfig) -> Dict[str, np.ndarray]:
    """Per-store arrays: arrival gap (ns), coalescable flag, in-burst
    flag, exposed coherence latency (ns).

    Arrivals follow a two-state Markov burst process: inside a store
    burst (flush phases of the SPMD apps) gaps are ~1 cycle and runs are
    ``burst_len`` stores long on average; between bursts, exponential
    compute gaps keep the trace-wide mean store rate at the profile's
    value. Burst runs longer than the SB depth are what separate
    ReCXL-proactive from ReCXL-parallel (Fig. 8): only there does commit
    latency back-pressure the core.
    """
    rng = np.random.default_rng(seed)
    ipc = 2.0
    ns_per_instr = 1.0 / (ipc * cluster.cpu_freq_ghz)
    instr_per_store = 1000.0 / wl.remote_store_rate
    mean_gap = instr_per_store * ns_per_instr

    # two-state Markov chain over stores
    burst_len = max(wl.burst_len, 1.0)
    p_leave_burst = 1.0 / burst_len
    frac = np.clip(wl.burstiness, 0.0, 0.98)     # fraction of stores in bursts
    calm_len = burst_len * (1.0 - frac) / max(frac, 1e-3)
    p_leave_calm = 1.0 / max(calm_len, 1.0)
    in_burst = np.zeros(n_stores, dtype=bool)
    state = rng.random() < frac
    u = rng.random(n_stores)
    for i in range(n_stores):
        in_burst[i] = state
        if state:
            state = not (u[i] < p_leave_burst)
        else:
            state = (u[i] < p_leave_calm)

    burst_gap = cluster.cycle_ns
    n_burst = int(in_burst.sum())
    n_calm = n_stores - n_burst
    calm_gap = ((mean_gap * n_stores - burst_gap * n_burst)
                / max(n_calm, 1))
    calm_gap = max(calm_gap, burst_gap)
    gaps = np.where(in_burst, burst_gap,
                    rng.exponential(calm_gap, n_stores))

    # position within the current burst (Logging-Unit backlog ramps with it)
    pos = np.zeros(n_stores, dtype=np.float32)
    run = 0
    for i in range(n_stores):
        run = run + 1 if in_burst[i] else 0
        pos[i] = run

    coalesce = rng.random(n_stores) < wl.coalesce_rate

    # Exposed coherence at the SB head: the exclusive prefetch is issued
    # at address resolution, so by SB-head time the RFO has almost always
    # completed (the paper's explanation for parallel ~= baseline). A
    # small tail of stores (conflicted / Shared-elsewhere lines) exposes
    # part of the round trip.
    base_rtt = cluster.cxl_rtt_ns + cluster.dram_lat_ns
    tail = rng.random(n_stores) < 0.12
    exposed = np.where(tail, rng.exponential(0.15 * base_rtt, n_stores), 0.0)

    return {"gaps": gaps.astype(np.float32),
            "coalesce": coalesce,
            "in_burst": in_burst,
            "burst_pos": pos,
            "exposed_coh": exposed.astype(np.float32)}


# ---------------------------------------------------------------------------
# Store-buffer timeline (one lax.scan per run)
# ---------------------------------------------------------------------------

def _commit_cost_ns(config: str, cluster: ClusterConfig) -> Dict[str, float]:
    rtt = cluster.cxl_rtt_ns
    return {
        "t_l1": cluster.cycle_ns * 2.0,
        "t_wt": rtt + cluster.pmem_lat_ns,
        # REPL->ACK round trip to peer CNs + SRAM log write at the replica.
        # N_r REPLs go out in parallel; ack time = slowest ~ one RTT + log.
        "t_repl": rtt + cluster.sram_log_lat_ns,
        # VAL is one-way, off the commit path
        "t_drain": cluster.cycle_ns,
    }


@functools.partial(jax.jit, static_argnames=("config", "sb_size"))
def _timeline(gaps: jax.Array, coalesce: jax.Array, exposed: jax.Array,
              t_repl_i: jax.Array, svc_i: jax.Array,
              config: str, sb_size: int, t_l1: float, t_wt: float,
              t_drain: float) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (exec_time_ns, repl_at_head_count, sb_full_count).

    ``t_repl_i``: per-store REPL->ACK latency (congestion/N_r adjusted).
    ``svc_i``: per-store replica Logging-Unit service time -- the
    throughput floor of commit draining during cluster-wide bursts (every
    CN's unit is absorbing the other CNs' REPL streams at the same time).
    """
    arrivals = jnp.cumsum(gaps)

    def body(carry, inp):
        ring, last_c, at_head, sb_full = carry
        a_i, co_i, coh_i, tr_i, sv_i = inp
        # retire: wait for a free SB slot (commit of store i - sb_size)
        oldest = ring[0]
        r_i = jnp.maximum(a_i, oldest)
        sb_full = sb_full + (oldest > a_i)

        if config == "wb":
            c_i = jnp.maximum(r_i, last_c) + t_l1
        elif config == "wt":
            c_i = jnp.maximum(r_i, last_c) + t_wt
        elif config == "baseline":
            extra = jnp.where(co_i, t_l1, coh_i + tr_i)
            c_i = jnp.maximum(r_i, last_c) + extra
        elif config == "parallel":
            extra = jnp.where(co_i, t_l1, jnp.maximum(coh_i, tr_i))
            c_i = jnp.maximum(r_i, last_c) + extra
        elif config == "proactive":
            # REPL issued at retire; ack returns tr_i later; REPL->ACK
            # cycles of queued stores overlap (Fig. 8). Commits drain no
            # faster than the replica units can log (sv_i floor).
            ack_i = r_i + tr_i
            coh_done = r_i + coh_i
            c_raw = jnp.maximum(jnp.maximum(ack_i, coh_done),
                                last_c + sv_i)
            c_i = jnp.where(co_i, jnp.maximum(r_i, last_c) + t_l1, c_raw)
            # Fig. 11: the REPL went out "at the SB head" if nothing was
            # queued ahead of the store when it retired.
            at_head = at_head + jnp.where(~co_i & (r_i >= last_c), 1, 0)
        else:
            raise ValueError(config)

        ring = jnp.roll(ring, -1).at[-1].set(c_i)
        return (ring, c_i, at_head, sb_full), None

    ring0 = jnp.zeros((sb_size,), jnp.float32)
    (ring, last_c, at_head, sb_full), _ = jax.lax.scan(
        body, (ring0, jnp.float32(0.0), jnp.int32(0), jnp.int32(0)),
        (arrivals, coalesce, exposed, t_repl_i, svc_i))
    return last_c, at_head, sb_full


# ---------------------------------------------------------------------------
# Public entry
# ---------------------------------------------------------------------------

def simulate(workload: str, config: str,
             cluster: ClusterConfig = PAPER_CLUSTER,
             n_stores: int = 50_000, seed: int = 0,
             n_replicas: Optional[int] = None,
             link_bw_gbps: Optional[float] = None,
             n_cns: Optional[int] = None,
             coalescing: bool = True) -> SimResult:
    """Simulate one (workload, config) pair; all sensitivity knobs of
    Figs. 16-18 are exposed as overrides."""
    if config not in CONFIGS:
        raise ValueError(f"unknown config {config}")
    wl = WORKLOADS[workload]
    nr = cluster.n_replicas if n_replicas is None else n_replicas
    bw = cluster.cxl_link_bw_gbps if link_bw_gbps is None else link_bw_gbps
    ncn = cluster.n_cns if n_cns is None else n_cns

    trace = synthesize_trace(wl, n_stores, seed, cluster)
    costs = _commit_cost_ns(config, cluster)

    # --- replication fan-out cost scaling -------------------------------
    # N_r REPLs leave in parallel but share the CN's CXL port: serialization
    # grows mildly with N_r; congestion scales latencies when offered load
    # nears the link bandwidth (Fig. 16/17 behaviour).
    repl_bytes = 8 + 64  # header + payload (coalesced line worst case)
    mean_gap = float(np.mean(trace["gaps"]))
    store_rate_per_core = 1e9 / max(mean_gap, 1e-3)          # stores/s/core
    cores = cluster.cores_per_cn
    repl_demand = store_rate_per_core * cores * nr * repl_bytes / 1e9  # GB/s
    mem_bytes = 64 + 16
    read_rate = (wl.remote_read_rate / wl.remote_store_rate) * store_rate_per_core
    mem_demand = (store_rate_per_core + read_rate) * cores * mem_bytes / 1e9
    total_demand = mem_demand + (repl_demand if config in
                                 ("baseline", "parallel", "proactive") else 0.0)
    congestion = max(1.0, total_demand / bw)
    port_serial = 1.0 + 0.08 * (nr - 1)

    coalesce = trace["coalesce"] if (coalescing and config != "wt") else \
        np.zeros_like(trace["coalesce"])
    exposed = trace["exposed_coh"] * congestion

    # Per-store REPL latency: inflated inside cluster-wide bursts (the
    # SPMD apps' flush phases align across CNs, so every Logging Unit is
    # absorbing its peers' REPL streams at once). The ACK backlog ramps
    # with position in the burst, capped when the SRAM Log Buffer
    # backpressures into DRAM-speed handling; the *sustained* drain floor
    # is the DRAM-log write path (~2 DRAM accesses per entry), which is
    # what bounds ReCXL-proactive during long flushes.
    svc_entry_ns = 2.0 * (1e3 / cluster.logging_unit_freq_mhz)  # SRAM path
    # saturated drain: log-entry write + log-metadata RMW at DRAM speed
    dram_svc_ns = 4.0 * cluster.dram_lat_ns
    qslope = (svc_entry_ns * cores * nr * (1.0 - wl.coalesce_rate)
              - cluster.cycle_ns)
    qcap = 195.0                 # SRAM buffer backpressure bound (ns)
    queue_i = np.minimum(trace["burst_pos"] * max(qslope, 0.0), qcap) \
        * trace["in_burst"] * congestion
    t_repl_base = costs["t_repl"] * congestion * port_serial
    t_repl_i = t_repl_base + queue_i
    # commit-drain service floor inside bursts (proactive path)
    svc_floor = dram_svc_ns * (1.0 - wl.coalesce_rate) * congestion \
        * (1.0 + 0.1 * (nr - cluster.n_replicas))
    svc_i = np.where(trace["in_burst"], svc_floor,
                     costs["t_drain"]).astype(np.float32)

    # --- scaling with CN count: fewer CNs -> each runs more of the fixed
    # total work (weak scaling of the cluster as in Fig. 18).
    work_scale = cluster.n_cns / ncn

    exec_ns, at_head, sb_full = _timeline(
        jnp.asarray(trace["gaps"]), jnp.asarray(coalesce),
        jnp.asarray(exposed), jnp.asarray(t_repl_i, jnp.float32),
        jnp.asarray(svc_i), config, cluster.store_buffer,
        costs["t_l1"], costs["t_wt"], costs["t_drain"])
    exec_ns = float(exec_ns) * work_scale

    n_repl = int(n_stores - coalesce.sum()) if config in (
        "baseline", "parallel", "proactive") else 0

    # --- log sizing (Fig. 13): entries accumulated per dump period ------
    entry_bytes = 12                       # Fig. 5: ~97 bits
    stores_per_s = store_rate_per_core * cores * nr  # logged at N_r peers / N_r srcs
    log_bytes = stores_per_s * (cluster.dump_period_ms * 1e-3) * entry_bytes
    dump_bw = (log_bytes / cluster.gzip_factor) / (cluster.dump_period_ms * 1e-3) / 1e9

    return SimResult(
        workload=workload,
        config=config,
        exec_time_ns=exec_ns,
        n_stores=n_stores,
        n_repl_msgs=n_repl,
        repl_at_head_frac=float(at_head) / max(n_stores, 1),
        max_log_bytes=log_bytes,
        cxl_mem_bw_gbps=mem_demand * ncn,
        log_dump_bw_gbps=(dump_bw * ncn if config in
                          ("baseline", "parallel", "proactive") else 0.0),
        sb_full_frac=float(sb_full) / max(n_stores, 1),
    )


def slowdown_table(configs: Tuple[str, ...] = CONFIGS,
                   workloads: Optional[Tuple[str, ...]] = None,
                   n_stores: int = 50_000, **kw) -> Dict[str, Dict[str, float]]:
    """Fig. 2 / Fig. 10: per-workload slowdowns normalized to WB."""
    workloads = workloads or tuple(WORKLOADS)
    out: Dict[str, Dict[str, float]] = {}
    for w in workloads:
        base = simulate(w, "wb", n_stores=n_stores, **kw).exec_time_ns
        out[w] = {}
        for c in configs:
            t = simulate(w, c, n_stores=n_stores, **kw).exec_time_ns
            out[w][c] = t / base
    return out


def geomean_slowdowns(table: Dict[str, Dict[str, float]]) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for c in next(iter(table.values())):
        vals = [table[w][c] for w in table]
        out[c] = float(np.exp(np.mean(np.log(vals))))
    return out
