"""Bounded retry-with-backoff for transient device/transport faults.

The resilience subsystem (``repro.core.chaos``, docs/resilience.md)
treats host->device placement and tile dispatch as fallible: a real
multi-host deployment sees transient DMA / RPC failures that a single
re-issue fixes, and the chaos harness injects exactly those
(``ChaosConfig.upload_failures``).  :func:`retry_call` is the one retry
primitive both the streaming engine and the serving daemon wrap those
call sites with:

* **bounded** -- at most ``max_attempts`` tries, then the last error is
  re-raised wrapped in :class:`RetryExhausted` (callers must never spin
  forever against a genuinely dead device; shard loss is the recovery
  path's job, not the retry loop's);
* **exponential backoff, capped** -- ``base_delay_s * 2**attempt``
  clamped to ``max_delay_s``;
* **jittered, deterministically** -- the delay is stretched by up to
  ``jitter`` drawn from a ``random.Random`` seeded on ``(policy.seed,
  describe)``, so concurrent retriers decorrelate without making test
  runs irreproducible.

A policy with ``max_attempts=1`` never sleeps and adds one ``try`` to
the call -- the inert fast path when no fault is injected.
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, Optional, Tuple, Type, TypeVar

from repro.core import telemetry as _tm

T = TypeVar("T")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Retry envelope: attempts, backoff shape, jitter seed."""
    max_attempts: int = 3
    base_delay_s: float = 0.001
    max_delay_s: float = 0.050
    jitter: float = 0.5          # max fractional stretch of each delay
    seed: int = 0

    def __post_init__(self) -> None:
        if self.max_attempts < 1:
            raise ValueError(
                f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.base_delay_s < 0 or self.max_delay_s < 0:
            raise ValueError("delays must be >= 0")
        if not 0 <= self.jitter <= 1:
            raise ValueError(f"jitter must be in [0, 1], got {self.jitter}")


#: Default envelope around device placement / tile dispatch: three
#: attempts a few ms apart -- enough to absorb an injected transient
#: upload fault, cheap enough to be always-on.
PLACEMENT_RETRY = RetryPolicy(max_attempts=3, base_delay_s=0.001,
                              max_delay_s=0.020)


class RetryExhausted(RuntimeError):
    """All attempts failed; ``last`` is the final underlying error."""

    def __init__(self, describe: str, attempts: int, last: BaseException):
        super().__init__(
            f"{describe or 'retried call'} failed after {attempts} "
            f"attempt(s): {last!r}")
        self.describe = describe
        self.attempts = attempts
        self.last = last


def backoff_delays(policy: RetryPolicy, describe: str = ""):
    """The (jittered, capped) sleep schedule a ``policy`` would use --
    ``max_attempts - 1`` delays, deterministic for a given
    ``(policy.seed, describe)``. Exposed for tests and for callers that
    drive their own loop."""
    rng = random.Random(f"{policy.seed}|{describe}")
    for attempt in range(policy.max_attempts - 1):
        delay = min(policy.max_delay_s, policy.base_delay_s * (2 ** attempt))
        yield delay * (1.0 + policy.jitter * rng.random())


def retry_call(fn: Callable[[], T], *,
               policy: RetryPolicy = PLACEMENT_RETRY,
               retryable: Tuple[Type[BaseException], ...] = (Exception,),
               describe: str = "",
               on_retry: Optional[Callable[[int, BaseException, float],
                                           None]] = None) -> T:
    """Call ``fn`` with bounded jittered-backoff retries.

    Only exceptions matching ``retryable`` are retried; anything else
    propagates immediately (a shard-loss or integrity fault must reach
    the recovery path, not burn retry attempts).  ``on_retry(attempt,
    error, delay)`` is invoked before each sleep -- the engines use it
    to count retries in their stats."""
    delays = backoff_delays(policy, describe)
    attempt = 0
    while True:
        attempt += 1
        try:
            return fn()
        except retryable as e:
            try:
                delay = next(delays)
            except StopIteration:
                _tm.count("retry/exhausted")
                raise RetryExhausted(describe, attempt, e) from e
            _tm.count("retry/attempts")
            _tm.observe("retry/backoff_ms", delay * 1e3)
            if on_retry is not None:
                on_retry(attempt, e, delay)
            if delay > 0:
                time.sleep(delay)
