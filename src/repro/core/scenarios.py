"""Failure/recovery scenario engine (paper SS V end-to-end, enumerable).

Two scenario families, both first-class values rather than ad-hoc example
code:

* **Sweep scenarios** -- grids of :class:`~repro.core.simulator.ScenarioSpec`
  cells over the paper's sensitivity space (Figs. 10/16/17/18). The grid
  builders here are consumed by ``benchmarks/protocol_benches.py`` and by
  the property tests, and every grid runs as ONE ``simulate_batch`` call.

* **Fault scenarios** -- end-to-end resilience runs on a real device mesh:
  train steps replicate state through the :class:`ReplicationEngine`,
  a :class:`FailureInjector` schedule fails nodes mid-run, the
  :class:`FailureDetector` sets viral bits, and recovery replay
  (``recover_node``, Algorithms 1-2) repairs directory + memory before
  the run resumes. :func:`run_fault_scenario` executes one such scenario
  and returns a checkable :class:`ScenarioOutcome`; the invariants the
  paper's design guarantees (replay idempotence, no directory reference
  to a failed node, exact shard recovery) are computed for every event so
  property tests can assert them under arbitrary fail-stop schedules.

Both families report **downtime**: every :class:`RecoveryCheck` carries a
:class:`~repro.core.recovery.RecoveryEstimate` derived from the volumes
the replay actually moved (SS VII-E model), and :func:`recovery_sweep`
runs the analytic model batched over a whole (workload x failure-time x
node-count) grid in one jitted call (``fig9/recovery/*`` bench rows).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ReplicationConfig
from repro.configs.recxl_paper import PAPER_CLUSTER, WORKLOADS, ClusterConfig
from repro.core.directory import ShardDirectory, ShardState
from repro.core.failures import FailureDetector, FailureEvent, FailureInjector
from repro.core.protocol import MsgType
from repro.core.recovery import (
    DEFAULT_RECOVERY_PARAMS,
    RecoveryEstimate,
    RecoveryResult,
    RecoveryTimeParams,
    estimate_recovery_time,
    recover_node,
    recovery_time_batch,
    reassemble_shard,
    workload_recovery_inputs,
)
from repro.core.replication import ReplicationEngine
from repro.core.simulator import CONFIGS, ScenarioSpec, SimResult
from repro.distributed.context import make_context, make_mesh, mesh_context

# ---------------------------------------------------------------------------
# Sweep scenarios: the paper's evaluation grids as ScenarioSpec lists
# ---------------------------------------------------------------------------


def sweep_grid(workloads: Sequence[str] = tuple(WORKLOADS),
               configs: Sequence[str] = CONFIGS,
               seeds: Sequence[int] = (0,),
               n_replicas: Sequence[Optional[int]] = (None,),
               link_bw_gbps: Sequence[Optional[float]] = (None,),
               n_cns: Sequence[Optional[int]] = (None,),
               sb_sizes: Sequence[Optional[int]] = (None,),
               coalescing: Sequence[bool] = (True,),
               read_share: Sequence[Optional[float]] = (None,),
               conflict_rate: Sequence[Optional[float]] = (None,),
               consistency_schedule: Sequence[Optional[str]] = (None,),
               directory_load: Sequence[Optional[float]] = (None,),
               ) -> List[ScenarioSpec]:
    """Cartesian product of sensitivity knobs as a flat spec list.

    The contention / crash-consistency axes (``read_share``,
    ``conflict_rate``, ``consistency_schedule`` -- see
    docs/contention.md) and the directory-coupling axis
    (``directory_load`` -- the two-level queueing recurrence, see
    docs/simulator.md) default to a single ``None`` value, so every
    pre-existing grid is unchanged cell-for-cell."""
    return [ScenarioSpec(w, c, seed=s, n_replicas=nr, link_bw_gbps=bw,
                         n_cns=ncn, sb_size=sb, coalescing=co,
                         read_share=rs, conflict_rate=cr,
                         consistency_schedule=cs, directory_load=dl)
            for w, c, s, nr, bw, ncn, sb, co, rs, cr, cs, dl
            in itertools.product(
                workloads, configs, seeds, n_replicas, link_bw_gbps,
                n_cns, sb_sizes, coalescing, read_share, conflict_rate,
                consistency_schedule, directory_load)]


def fig10_grid(seeds: Sequence[int] = (0,)) -> List[ScenarioSpec]:
    """All workloads x all five configurations."""
    return sweep_grid(seeds=seeds)


def fig16_grid(bandwidths: Sequence[float] = (160.0, 80.0, 40.0, 20.0),
               workloads: Sequence[str] = ("ycsb", "canneal",
                                           "streamcluster")) -> List[ScenarioSpec]:
    """Link-bandwidth sensitivity (WB vs proactive)."""
    return sweep_grid(workloads=workloads, configs=("wb", "proactive"),
                      link_bw_gbps=bandwidths)


def fig17_grid(replicas: Sequence[int] = (1, 2, 3, 4),
               workloads: Sequence[str] = tuple(WORKLOADS)) -> List[ScenarioSpec]:
    """Replication-factor sensitivity under proactive."""
    return sweep_grid(workloads=workloads, configs=("proactive",),
                      n_replicas=replicas)


def fig18_grid(cn_counts: Sequence[int] = (4, 8, 16),
               workloads: Sequence[str] = ("barnes", "ycsb",
                                           "bodytrack")) -> List[ScenarioSpec]:
    """CN-count weak scaling (WB vs proactive)."""
    return sweep_grid(workloads=workloads, configs=("wb", "proactive"),
                      n_cns=cn_counts)


def mega_grid(seeds: Sequence[int] = (0, 1, 2),
              replicas: Sequence[int] = (1, 2, 3, 4),
              bandwidths: Sequence[float] = (160.0, 80.0, 40.0, 20.0),
              cn_counts: Sequence[int] = (16, 8, 4),
              sb_sizes: Sequence[int] = (72, 48)) -> List[ScenarioSpec]:
    """The full cross-product sensitivity space of Figs. 10/16-18 as one
    grid: (workload x config x seed x N_r x bw x CN x SB). At the
    defaults this is 12 960 cells -- the mega-grid scale the streaming
    engine tier exists for (``fig10/megagrid/*`` bench rows run it)."""
    return sweep_grid(seeds=seeds, n_replicas=replicas,
                      link_bw_gbps=bandwidths, n_cns=cn_counts,
                      sb_sizes=sb_sizes)


def chaos_grid(workloads: Sequence[str] = ("ycsb", "barnes",
                                           "streamcluster"),
               configs: Sequence[str] = ("wb", "proactive"),
               replicas: Sequence[Optional[int]] = (None, 2, 3),
               bandwidths: Sequence[Optional[float]] = (None, 40.0),
               ) -> List[ScenarioSpec]:
    """The fault-injection differential grid (tests/test_chaos.py,
    benchmarks/bench_chaos.py): a small multi-signature sweep -- several
    workloads x configs x sensitivity values so a mid-grid shard loss
    lands between tiles of DIFFERENT compiled signatures -- sized so the
    fault-free oracle plus one run per injected fault stays cheap. The
    grid itself is plain scenarios; the faults come from
    :func:`repro.core.chaos.inject` around the run."""
    return sweep_grid(workloads=workloads, configs=configs,
                      n_replicas=replicas, link_bw_gbps=bandwidths)


def contention_grid(workloads: Sequence[str] = ("ycsb", "canneal",
                                                "streamcluster"),
                    configs: Sequence[str] = ("wb", "proactive"),
                    conflict_rates: Sequence[Optional[float]] =
                    (None, 0.2, 0.5),
                    read_shares: Sequence[Optional[float]] = (None, 0.6),
                    schedules: Sequence[Optional[str]] =
                    (None, "epoch", "eager")) -> List[ScenarioSpec]:
    """Figure-sized contention sweep (the Fig. 17-style sensitivity
    grid for the new axes): contended proactive cells against the
    unchanged WB baseline, with ``None`` axis values mixing legacy
    (axes-off) cells into the same grid for normalization."""
    return sweep_grid(workloads=workloads, configs=configs,
                      conflict_rate=conflict_rates, read_share=read_shares,
                      consistency_schedule=schedules)


def contention_mega_grid(workloads: Sequence[str] = tuple(WORKLOADS),
                         configs: Sequence[str] = ("wb", "proactive"),
                         seeds: Sequence[int] = (0, 1),
                         replicas: Sequence[Optional[int]] = (1, 3),
                         cn_counts: Sequence[Optional[int]] = (16, 8),
                         conflict_rates: Sequence[Optional[float]] =
                         (0.0, 0.2, 0.5),
                         read_shares: Sequence[Optional[float]] =
                         (0.0, 0.6),
                         schedules: Sequence[Optional[str]] =
                         ("lazy", "epoch", "eager")) -> List[ScenarioSpec]:
    """The contention cross-product at streaming-tier scale
    (workload x config x seed x N_r x CN x conflict x read-share x
    schedule -- 2 592 cells at the defaults, >= ``STREAM_THRESHOLD`` so
    ``run_sweep`` picks the banked streaming engine). The neutral
    ``(0.0, 0.0, "lazy")`` cells are bit-identical to the uncontended
    semantics and serve as in-grid normalization; the CN axis exercises
    scan-lane dedup (contention keys deliberately exclude ``n_cns``).
    ``fig17/contention/*`` bench rows run it
    (benchmarks/bench_contention.py)."""
    return sweep_grid(workloads=workloads, configs=configs, seeds=seeds,
                      n_replicas=replicas, n_cns=cn_counts,
                      conflict_rate=conflict_rates, read_share=read_shares,
                      consistency_schedule=schedules)


def directory_mega_grid(workloads: Sequence[str] = tuple(WORKLOADS),
                        configs: Sequence[str] = ("baseline", "parallel",
                                                  "proactive"),
                        seeds: Sequence[int] = (0, 1),
                        replicas: Sequence[Optional[int]] = (1, 3),
                        cn_counts: Sequence[Optional[int]] = (16, 8, 4),
                        loads: Sequence[Optional[float]] =
                        (0.0, 0.2, 0.4, 0.7),
                        sb_sizes: Sequence[Optional[int]] = (72, 48)
                        ) -> List[ScenarioSpec]:
    """The directory-coupling cross-product at streaming-tier scale
    (workload x config x seed x N_r x CN x load x SB -- 2 592 cells at
    the defaults, >= ``STREAM_THRESHOLD``; the 4-CN column exercises
    the clamped directory census). ``directory_load=0.0``
    cells are bit-identical to the axis-off semantics and serve as the
    in-grid normalization baseline of the ``fig17/directory/*``
    slowdown rows; ``baseline`` pays the shard's queueing wait serially
    per store while ``proactive``'s decoupled commit largely hides it
    behind the drain chain -- the capacity-vs-resilience contrast the
    bench reports. The SB and CN axes exercise scan-lane dedup on
    coupled cells (cells sharing a resolved
    :class:`~repro.core.directory.DirectoryParams` + max-plus row are
    one lane). ``fig17/directory/*`` bench rows run it
    (benchmarks/bench_directory.py)."""
    return sweep_grid(workloads=workloads, configs=configs, seeds=seeds,
                      n_replicas=replicas, n_cns=cn_counts,
                      sb_sizes=sb_sizes, directory_load=loads)


def run_sweep(specs: Sequence[ScenarioSpec],
              cluster: ClusterConfig = PAPER_CLUSTER,
              n_stores: int = 50_000,
              engine: str = "auto",
              **engine_kw) -> List[SimResult]:
    """Run a sweep grid on the right engine tier.

    The canonical entry point for every grid this module builds:
    delegates to :func:`repro.core.engine.simulate_grid`, which picks
    the one-shot blocked batch for ordinary figure grids and the
    sharded streaming tier for mega-grids (>=
    ``repro.core.engine.STREAM_THRESHOLD`` cells); ``engine=`` forces a
    tier and ``engine_kw`` passes tile/shard/data-plane knobs through.
    Results are in ``specs`` order and bit-identical across tiers.

    Data plane: both banked tiers resolve the grid's columnar
    :class:`~repro.core.simulator.TraceBank` through one digest-keyed
    memo, so sweeping the same grid through several engines (or
    repeatedly) builds and uploads the bank ONCE -- use
    :func:`grid_bank` to pre-build it (or inspect its dedup) explicitly.
    """
    from repro.core.engine import simulate_grid
    return simulate_grid(specs, cluster=cluster, n_stores=n_stores,
                         engine=engine, **engine_kw)


def grid_bank(specs: Sequence[ScenarioSpec],
              cluster: ClusterConfig = PAPER_CLUSTER,
              n_stores: int = 50_000):
    """The memoized columnar trace bank of a sweep grid.

    Thin alias of :func:`repro.core.simulator.get_trace_bank` at the
    sweep-builder level: pre-building the bank before a timed or
    latency-sensitive sweep moves the one-off column materialization
    out of the measured path, and the returned handle is the SAME
    object every banked engine tier will use (``clear_sim_caches``
    drops it)."""
    from repro.core.simulator import get_trace_bank
    return get_trace_bank(specs, n_stores, cluster)


def grid_delta(base: Sequence[ScenarioSpec],
               **axes) -> List[ScenarioSpec]:
    """The cells of a sweep that are NOT already in ``base``.

    The query->cell translation for the serving daemon's *grid delta*
    requests ("extend my sweep by these axis values"): ``axes`` are
    :func:`sweep_grid` keyword axes describing the requested
    cross-product, and the return value is its cells minus the ones
    ``base`` already contains, in sweep order. Feeding the result to
    :meth:`repro.core.serving.ScenarioServer.query_batch` appends only
    the genuinely new bank rows (the incremental-diff upload path);
    ``base + grid_delta(base, **axes)`` is the merged grid whose
    from-scratch bank the extended bank stays byte-identical to.
    """
    have = set(base)
    return [s for s in sweep_grid(**axes) if s not in have]


# ---------------------------------------------------------------------------
# Recovery-time sweeps: downtime over a failure-time x node grid (SS VII-E)
# ---------------------------------------------------------------------------


#: Default failure times as fractions of the Logging-Unit dump interval
#: (just after a dump, mid-interval, just before the next dump).
DEFAULT_FAIL_FRACS = (0.1, 0.5, 0.9)


@dataclasses.dataclass(frozen=True)
class RecoverySweep:
    """Batched downtime estimates over a (workload x failure-time x
    node-count) grid.

    ``total_ns`` and every phase/volume array in ``components`` have
    shape ``(len(workloads), len(fail_times_ms), len(cn_counts))``;
    times are ns, ``replay_bytes`` is bytes.
    """
    workloads: Tuple[str, ...]
    fail_times_ms: Tuple[float, ...]
    cn_counts: Tuple[int, ...]
    total_ns: np.ndarray
    components: Dict[str, np.ndarray]

    def total_ms(self, workload: str, fail_time_ms: float,
                 n_cns: int) -> float:
        """Downtime of one grid cell in milliseconds."""
        w = self.workloads.index(workload)
        t = self.fail_times_ms.index(fail_time_ms)
        c = self.cn_counts.index(n_cns)
        return float(self.total_ns[w, t, c]) / 1e6


def recovery_sweep(workloads: Sequence[str] = tuple(WORKLOADS),
                   fail_times_ms: Optional[Sequence[float]] = None,
                   cn_counts: Sequence[int] = (4, 8, 16),
                   link_bw_gbps: Optional[float] = None,
                   cluster: ClusterConfig = PAPER_CLUSTER,
                   params: RecoveryTimeParams = DEFAULT_RECOVERY_PARAMS,
                   read_share: Optional[float] = None,
                   conflict_rate: Optional[float] = None,
                   consistency_schedule: Optional[str] = None,
                   directory_load: Optional[float] = None
                   ) -> RecoverySweep:
    """Sweep the SS VII-E downtime model over a (workload x
    failure-time x node-count) grid in ONE jitted call.

    ``fail_times_ms`` defaults to :data:`DEFAULT_FAIL_FRACS` fractions
    of the dump interval -- downtime grows within the interval because
    the undumped log (and so the Algorithm 2 replay volume) accumulates
    until the next dump resets it. ``link_bw_gbps`` (GB/s) defaults to
    the cluster link. The contention axes (all-``None`` = off) scale
    the crash-exposed volumes through
    ``workload_recovery_inputs(contention=...)`` -- conflicted
    ownership churn inflates the replayed state, persist-ordering
    schedules shrink it (docs/contention.md). ``directory_load``
    (``None`` = off) dilates the directory-walk phase per CN count:
    recovery's Algorithm 1 walks the surviving shards while they still
    serve the sharer pool's background load, so each owned entry costs
    ``directory_service_scale`` times its uncoupled service time.
    """
    from repro.core.contention import resolve_contention
    from repro.core.directory import (directory_service_scale,
                                      resolve_directory_load)

    contention = resolve_contention(read_share, conflict_rate,
                                    consistency_schedule)
    bw = cluster.cxl_link_bw_gbps if link_bw_gbps is None else link_bw_gbps
    if bw <= 0.0:
        raise ValueError(f"link_bw_gbps must be > 0, got {bw}")
    if fail_times_ms is None:
        fail_times_ms = tuple(round(f * cluster.dump_period_ms, 6)
                              for f in DEFAULT_FAIL_FRACS)
    workloads = tuple(workloads)
    fail_times_ms = tuple(fail_times_ms)
    cn_counts = tuple(cn_counts)
    shape = (len(workloads), len(fail_times_ms), len(cn_counts))
    owned = np.empty(shape, np.float64)
    undumped = np.empty(shape, np.float64)
    for iw, wname in enumerate(workloads):
        for it, t_ms in enumerate(fail_times_ms):
            for ic, ncn in enumerate(cn_counts):
                owned[iw, it, ic], undumped[iw, it, ic] = \
                    workload_recovery_inputs(wname, t_ms, cluster=cluster,
                                             n_cns=ncn, params=params,
                                             contention=contention)
    # per-CN directory service dilation (1.0s when the coupling is off;
    # the raw load is range-checked once up front so a bad axis value
    # fails before the heavy per-cell loop)
    resolve_directory_load(directory_load, cluster.n_cns,
                           cluster.n_replicas)
    dir_scale = np.asarray(
        [directory_service_scale(resolve_directory_load(
            directory_load, ncn, cluster.n_replicas))
         for ncn in cn_counts], np.float64)
    out = recovery_time_batch(owned, undumped, np.full(shape, bw),
                              dir_service_scale=dir_scale,
                              cluster=cluster, params=params)
    comps = {k: np.asarray(v) for k, v in out.items()}
    return RecoverySweep(workloads=workloads, fail_times_ms=fail_times_ms,
                         cn_counts=cn_counts, total_ns=comps.pop("total_ns"),
                         components=comps)


def downtime_query(workload: str, fail_time_ms: float,
                   n_cns: Optional[int] = None,
                   n_replicas: Optional[int] = None,
                   link_bw_gbps: Optional[float] = None,
                   cluster: ClusterConfig = PAPER_CLUSTER,
                   params: RecoveryTimeParams = DEFAULT_RECOVERY_PARAMS,
                   read_share: Optional[float] = None,
                   conflict_rate: Optional[float] = None,
                   consistency_schedule: Optional[str] = None,
                   directory_load: Optional[float] = None
                   ) -> RecoveryEstimate:
    """One "what's my downtime if ..." cell of the SS VII-E model.

    The single-cell counterpart of :func:`recovery_sweep` and the
    query->estimate translation the serving daemon's recovery queries
    go through (:meth:`repro.core.serving.ScenarioServer.query_downtime`
    delegates here, so the daemon and the batched sweep cannot drift):
    the same contention scaling of the crash-exposed volumes and the
    same ``directory_load`` dilation of the walk phase, evaluated
    closed-form for one (workload, failure time, cluster shape) point.
    ``None`` knobs resolve to the ``cluster`` defaults, as on
    :class:`~repro.core.simulator.ScenarioSpec`.
    """
    from repro.core.contention import resolve_contention
    from repro.core.directory import (directory_service_scale,
                                      resolve_directory_load)

    contention = resolve_contention(read_share, conflict_rate,
                                    consistency_schedule)
    ncn = cluster.n_cns if n_cns is None else n_cns
    nr = cluster.n_replicas if n_replicas is None else n_replicas
    owned, undumped = workload_recovery_inputs(
        workload, fail_time_ms, cluster=cluster, n_cns=ncn, n_replicas=nr,
        params=params, contention=contention)
    scale = directory_service_scale(
        resolve_directory_load(directory_load, ncn, nr))
    return estimate_recovery_time(owned, undumped, cluster=cluster,
                                  link_bw_gbps=link_bw_gbps, params=params,
                                  dir_service_scale=scale)


# ---------------------------------------------------------------------------
# Fault scenarios: fail node f at step s -> replay -> consistent -> resume
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultScenario:
    """One enumerable end-to-end resilience run.

    The contention axes (``None`` = off; ``repro.core.contention``)
    describe the workload regime the failed node was running: they
    scale the crash-exposed volumes feeding each event's downtime
    estimate, so the same fail-stop schedule yields contention-dependent
    downtime numbers. ``directory_load`` (``None`` = off;
    ``repro.core.directory``) dilates the directory-walk phase of each
    estimate -- the surviving shards serve recovery under the sharer
    pool's background load."""
    name: str
    events: Tuple[FailureEvent, ...]
    n_nodes: int = 4
    n_steps: int = 6
    variant: str = "proactive"       # baseline | parallel | proactive
    coalescing: bool = False
    n_replicas: int = 2
    n_buckets: int = 2
    log_capacity: int = 3
    read_share: Optional[float] = None
    conflict_rate: Optional[float] = None
    consistency_schedule: Optional[str] = None
    directory_load: Optional[float] = None

    def contention(self):
        """Resolved :class:`~repro.core.contention.ContentionParams`
        (``None`` when every axis is off)."""
        from repro.core.contention import resolve_contention
        return resolve_contention(self.read_share, self.conflict_rate,
                                  self.consistency_schedule)

    def directory(self):
        """Resolved :class:`~repro.core.directory.DirectoryParams`
        (``None`` when the coupling axis is off)."""
        from repro.core.directory import resolve_directory_load
        return resolve_directory_load(self.directory_load, self.n_nodes,
                                      self.n_replicas)

    def validate(self) -> None:
        if self.variant not in ("baseline", "parallel", "proactive"):
            raise ValueError(f"unknown variant {self.variant!r}")
        if self.n_replicas >= self.n_nodes:
            raise ValueError("n_replicas must be < n_nodes")
        for ev in self.events:
            if not 0 <= ev.node < self.n_nodes:
                raise ValueError(f"event node {ev.node} outside mesh")
        self.contention()        # raises on out-of-range contention axes
        self.directory()         # raises on out-of-range directory_load


@dataclasses.dataclass
class RecoveryCheck:
    """Invariants computed for one fail-stop event's recovery replay."""
    node: int
    step: int
    exact: bool                      # recovered shard == live truth
    newest_ts: int                   # newest recovered logical timestamp
    replay_idempotent: bool          # second replay = identical result
    directory_consistent: bool       # no reference to any failed node
    unrecoverable: int
    downtime: Optional[RecoveryEstimate] = None  # SS VII-E estimate (ns)

    @property
    def downtime_ns(self) -> float:
        """Estimated downtime of this event in ns (0.0 if unmodeled)."""
        return self.downtime.total_ns if self.downtime is not None else 0.0


@dataclasses.dataclass
class ScenarioOutcome:
    scenario: FaultScenario
    steps_run: int
    failed_nodes: Tuple[int, ...]
    stragglers: Dict[int, float]
    checks: List[RecoveryCheck]
    directory: ShardDirectory
    resumed: bool                    # live nodes kept stepping to the end

    @property
    def all_invariants_hold(self) -> bool:
        return all(c.exact and c.replay_idempotent and
                   c.directory_consistent and c.unrecoverable == 0
                   for c in self.checks)

    @property
    def total_downtime_ns(self) -> float:
        """Summed downtime estimate over every recovery event (ns)."""
        return sum(c.downtime_ns for c in self.checks)


def estimate_scenario_downtime(engine: ReplicationEngine,
                               result: RecoveryResult,
                               cluster: ClusterConfig = PAPER_CLUSTER,
                               params: RecoveryTimeParams =
                               DEFAULT_RECOVERY_PARAMS,
                               contention=None,
                               directory=None) -> RecoveryEstimate:
    """Downtime estimate for one executed recovery replay, fed by the
    volumes the replay *actually* moved.

    ``owned_lines`` is the owned-entry census from Algorithm 1, with the
    payload ("line") size set to the engine's bucket footprint in bytes;
    the undumped log volume is the number of log versions Algorithm 2
    walked (the FetchLatestVersResp message log records them), also at
    bucket granularity. ``contention``
    (:class:`~repro.core.contention.ContentionParams` or ``None``)
    scales both volumes for the scenario's contention regime --
    conflicted ownership churn keeps more state dirty at the crash
    point, persist-ordering schedules shrink it. ``directory``
    (:class:`~repro.core.directory.DirectoryParams` or ``None``)
    dilates the directory-walk phase by the shard's service-rate
    dilation under background load. Times in the returned estimate are
    ns.
    """
    from repro.core.contention import dirty_line_scale, undumped_log_scale
    from repro.core.directory import directory_service_scale

    bucket_bytes = engine.layout.bucket_len * engine.log_dtype.itemsize
    n_versions = sum(m[1].get("n_versions", 0) for m in result.message_log
                     if m[0] == MsgType.FETCH_LATEST_VERS_RESP)
    p = dataclasses.replace(params, line_bytes=bucket_bytes,
                            log_entry_bytes=float(
                                bucket_bytes + params.header_bytes))
    owned = float(result.stats.owned_entries)
    undumped = n_versions * p.log_entry_bytes
    if contention is not None:
        owned *= dirty_line_scale(contention)
        undumped *= undumped_log_scale(contention)
    return estimate_recovery_time(
        owned_lines=owned, undumped_log_bytes=undumped,
        cluster=cluster, params=p,
        dir_service_scale=directory_service_scale(directory))


def enumerate_fault_scenarios(n_nodes: int = 4, n_steps: int = 6,
                              variants: Sequence[str] = ("baseline",
                                                         "parallel",
                                                         "proactive"),
                              ) -> List[FaultScenario]:
    """The canonical single- and double-failure schedule grid."""
    out: List[FaultScenario] = []
    for v in variants:
        for step in range(1, n_steps - 1):
            for node in range(n_nodes):
                out.append(FaultScenario(
                    name=f"{v}/fail-n{node}@s{step}",
                    events=(FailureEvent(step=step, node=node),),
                    n_nodes=n_nodes, n_steps=n_steps, variant=v))
        out.append(FaultScenario(
            name=f"{v}/double-failure",
            events=(FailureEvent(step=1, node=0),
                    FailureEvent(step=n_steps - 2, node=n_nodes - 1)),
            n_nodes=n_nodes, n_steps=n_steps, variant=v))
    return out


def directory_references(directory: ShardDirectory,
                         failed: Set[int]) -> bool:
    """True iff the directory still references any failed node: as a
    live replica holder anywhere, or as a still-OWNED owner."""
    for (_, _), e in directory.entries.items():
        if any(f in e.replicas for f in failed):
            return True
        if e.owner in failed and e.state == ShardState.OWNED:
            return True
    return False


def _scenario_params(scn: FaultScenario, mesh) -> Tuple[Dict, Dict]:
    rows = 2 * scn.n_nodes
    params = {
        "w": jnp.arange(rows * 4, dtype=jnp.float32).reshape(rows, 4) * 0.25,
        "scale": jnp.linspace(0.5, 1.5, 6, dtype=jnp.float32),
    }
    specs = {"w": P("data", None), "scale": P(None)}
    params = {k: jax.device_put(v, NamedSharding(mesh, specs[k]))
              for k, v in params.items()}
    return params, specs


def _node_truth(engine: ReplicationEngine, params: Dict,
                node: int) -> Dict[str, np.ndarray]:
    """The failed node's true local shard of the live global state."""
    w = np.asarray(params["w"])
    rows = w.shape[0] // engine.n_nodes
    return {"w": w[rows * node:rows * (node + 1)],
            "scale": np.asarray(params["scale"])}


def _replay(engine: ReplicationEngine, logs, directory_blob: str,
            scn: FaultScenario, node: int) -> Tuple[RecoveryResult,
                                                    ShardDirectory]:
    d = ShardDirectory.from_json(directory_blob, scn.n_nodes,
                                 engine.layout.n_buckets, scn.n_replicas)
    return recover_node(engine, logs, d, failed_coord=(node,)), d


def run_fault_scenario(scn: FaultScenario,
                       mesh: Optional[jax.sharding.Mesh] = None,
                       ) -> ScenarioOutcome:
    """Execute one fault scenario end-to-end (Fig. 9 sequence).

    Steps replicate state; at each injected fail-stop the detector sets
    the viral bit, recovery replays the surviving Logging-Unit logs, the
    repaired shard is checked against the live truth, and the run
    resumes on the remaining schedule. Every :class:`RecoveryCheck` in
    the outcome carries a SS VII-E downtime estimate
    (:func:`estimate_scenario_downtime`, ns) fed by the volumes that
    replay actually moved. Needs ``scn.n_nodes`` devices (use
    ``--xla_force_host_platform_device_count`` on CPU).
    """
    scn.validate()
    if mesh is None:
        if jax.device_count() < scn.n_nodes:
            raise RuntimeError(
                f"scenario needs {scn.n_nodes} devices, "
                f"have {jax.device_count()}")
        mesh = make_mesh((scn.n_nodes,), ("data",),
                         devices=jax.devices()[:scn.n_nodes])
    ctx = make_context(mesh)
    params, specs = _scenario_params(scn, mesh)
    rep = ReplicationConfig(variant=scn.variant, n_replicas=scn.n_replicas,
                            n_buckets=scn.n_buckets,
                            log_capacity=scn.log_capacity,
                            coalescing=scn.coalescing, log_dtype="float32")
    engine = ReplicationEngine(rep, ctx, specs, params)
    logs = engine.init_logs()
    directory = ShardDirectory(scn.n_nodes, engine.layout.n_buckets,
                               scn.n_replicas)
    detector = FailureDetector(scn.n_nodes, lease_s=1e9)
    injector = FailureInjector(scn.events)

    @jax.jit
    def step(p, l, step_no):
        new_p = jax.tree.map(lambda x: x * 1.125 + 0.5, p)
        l, committed = engine.replicate(new_p, l, step_no, new_p)
        return committed, l

    checks: List[RecoveryCheck] = []
    failed: Set[int] = set()
    with mesh_context(ctx):
        for t in range(scn.n_steps):
            params, logs = step(params, logs, jnp.int32(t))
            if not failed:
                # failed owners must stay UNOWNED: only record cluster-wide
                # commits while the directory is undamaged
                directory.record_commit(t)
            for ev in injector.poll(t):
                if ev.kind == "straggler":
                    detector.mark_straggler(ev.node, ev.delay_s)
                    continue
                if ev.node in failed:
                    continue
                detector.mark_failed(ev.node)
                failed.add(ev.node)
                # snapshot the pre-repair directory, then replay on the
                # real one and twice more on copies of the snapshot: all
                # three runs must recover identical shards (idempotence)
                blob = directory.to_json()
                res = recover_node(engine, logs, directory,
                                   failed_coord=(ev.node,))
                r1, _ = _replay(engine, logs, blob, scn, ev.node)
                r2, _ = _replay(engine, logs, blob, scn, ev.node)
                idem = (set(r1.shards) == set(r2.shards) == set(res.shards)
                        and all(r1.shards[b].ts == r2.shards[b].ts
                                and np.array_equal(r1.shards[b].values,
                                                   r2.shards[b].values)
                                and r1.shards[b].ts == res.shards[b].ts
                                and np.array_equal(r1.shards[b].values,
                                                   res.shards[b].values)
                                for b in r1.shards))
                # replaying on the already-repaired directory must be a
                # no-op: every owned entry is UNOWNED, nothing re-fetched
                res_again = recover_node(engine, logs, directory,
                                         failed_coord=(ev.node,))
                idem = idem and not res_again.shards

                exact = res.stats.unrecoverable == 0
                newest = -1
                if exact:
                    truth = _node_truth(engine, params, ev.node)
                    leaves = reassemble_shard(engine, res)[0]
                    got = engine.unflatten(leaves)
                    exact = all(
                        np.allclose(np.asarray(got[k]), truth[k],
                                    rtol=1e-6, atol=1e-6) for k in truth)
                    newest = max(s.ts for s in res.shards.values())
                checks.append(RecoveryCheck(
                    node=ev.node, step=t, exact=exact, newest_ts=newest,
                    replay_idempotent=idem,
                    directory_consistent=not directory_references(
                        directory, failed),
                    unrecoverable=res.stats.unrecoverable,
                    downtime=estimate_scenario_downtime(
                        engine, res, contention=scn.contention(),
                        directory=scn.directory())))

    return ScenarioOutcome(
        scenario=scn, steps_run=scn.n_steps,
        failed_nodes=tuple(sorted(failed)),
        stragglers=dict(detector.stragglers),
        checks=checks, directory=directory,
        resumed=len(detector.live_nodes) > 0)
