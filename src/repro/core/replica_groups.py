"""Hash-based replica group assignment (paper SS III.A).

The paper hashes a cache-line address to pick the N_r replica CNs so all
updates to one address land in the same replica set. Here the replicated
unit is a (node, bucket) state shard; we hash (bucket_id) to a *rotation
schedule* so that:

* every source node has exactly N_r distinct replica targets per bucket,
* every node is a replica for exactly N_r sources per bucket (balanced),
* targets never equal the source,
* the mapping is a pure function of (bucket, N_r, n_nodes) -- recovery can
  recompute it without any metadata.

Targets are expressed as *offsets* so that, inside ``shard_map``, a single
``ppermute`` per (replica_rank, bucket) implements the REPL fan-out.
"""

from __future__ import annotations

import hashlib
from typing import List, Sequence, Tuple


def _hash_int(*xs: int) -> int:
    h = hashlib.sha256(",".join(map(str, xs)).encode()).digest()
    return int.from_bytes(h[:8], "little")


def replica_offsets(bucket_id: int, n_replicas: int, n_nodes: int) -> Tuple[int, ...]:
    """Offsets o_1..o_Nr (each in 1..n_nodes-1, distinct): node s replicates
    bucket ``bucket_id`` onto nodes (s + o_r) % n_nodes."""
    if n_replicas >= n_nodes:
        raise ValueError(
            f"n_replicas={n_replicas} must be < n_nodes={n_nodes}")
    # hash-seeded sample of distinct non-zero offsets
    avail = list(range(1, n_nodes))
    out: List[int] = []
    seed = _hash_int(bucket_id, n_replicas, n_nodes)
    for r in range(n_replicas):
        seed = _hash_int(seed, r)
        pick = seed % len(avail)
        out.append(avail.pop(pick))
    return tuple(out)


def replica_targets(node: int, bucket_id: int, n_replicas: int,
                    n_nodes: int) -> Tuple[int, ...]:
    """The N_r nodes that log ``node``'s updates to ``bucket_id``."""
    return tuple((node + o) % n_nodes
                 for o in replica_offsets(bucket_id, n_replicas, n_nodes))


def replica_sources(node: int, bucket_id: int, n_replicas: int,
                    n_nodes: int) -> Tuple[int, ...]:
    """The N_r source nodes whose ``bucket_id`` updates ``node`` logs.

    Inverse of :func:`replica_targets`; with rotation offsets the r-th
    source is (node - o_r) % n_nodes.
    """
    return tuple((node - o) % n_nodes
                 for o in replica_offsets(bucket_id, n_replicas, n_nodes))


def ppermute_pairs(bucket_id: int, replica_rank: int, n_replicas: int,
                   n_nodes: int) -> List[Tuple[int, int]]:
    """(src, dst) pairs for the ``lax.ppermute`` implementing REPL fan-out
    number ``replica_rank`` of ``bucket_id``."""
    off = replica_offsets(bucket_id, n_replicas, n_nodes)[replica_rank]
    return [(s, (s + off) % n_nodes) for s in range(n_nodes)]


def inverse_ppermute_pairs(bucket_id: int, replica_rank: int, n_replicas: int,
                           n_nodes: int) -> List[Tuple[int, int]]:
    """(src, dst) pairs routing logged entries *back* to the shard owner
    (used by jitted recovery)."""
    off = replica_offsets(bucket_id, n_replicas, n_nodes)[replica_rank]
    return [(s, (s - off) % n_nodes) for s in range(n_nodes)]


def line_replicas(line_addr: int, n_replicas: int,
                  n_nodes: int) -> Tuple[int, ...]:
    """Paper-faithful per-cache-line replica selection (used by the
    fine-grained Logging Unit / KV-store path): hash the line address to
    N_r distinct CNs.

    Note the set depends on the *address only* (paper SS III.A): every
    writer of a line uses the same replica group, and the group may
    contain the writer itself -- the system still tolerates N_r - 1
    failures.
    """
    avail = list(range(n_nodes))
    out: List[int] = []
    seed = _hash_int(line_addr, n_replicas, n_nodes)
    for r in range(n_replicas):
        seed = _hash_int(seed, r)
        out.append(avail.pop(seed % len(avail)))
    return tuple(out)
