"""The ReCXL Logging Unit (paper SS IV.B-C), as a jit-compatible state
machine.

Each node owns one unit:

* an **SRAM Log Buffer** (small, fixed-capacity): entries are *allocated*
  on REPL reception and *validated* on VAL reception (possibly out of
  order -- the CXL fabric reorders messages);
* a **DRAM log** (large, append-only): validated entries drain from SRAM
  to DRAM strictly in per-source logical-timestamp order, so the DRAM log
  order equals program order (SS IV.C) even under fabric reordering. The
  timestamp is stripped on the way (paper: "As entries are pushed into the
  DRAM log, the timestamp is stripped-out"; we keep it in a side array
  purely for test assertions);
* per-source ``next_ts`` counters enforcing the in-order drain.

All operations are pure functions on a :class:`LogUnitState` pytree, so
they jit, vmap (one unit per node), and property-test cleanly. Values are
fixed-width vectors (``value_width`` words) -- word granularity when
``value_width == 1``, row granularity for the KV-store example.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

EMPTY = jnp.int32(-1)


class LogUnitState(NamedTuple):
    # --- SRAM Log Buffer ---
    sram_src: jax.Array        # (S,) int32 source node, -1 = free
    sram_addr: jax.Array       # (S,) int32 word/row address
    sram_val: jax.Array        # (S, W) float32 logged values
    sram_ts: jax.Array         # (S,) int32 logical TS (-1 until VAL)
    sram_valid: jax.Array      # (S,) bool
    sram_seq: jax.Array        # (S,) int32 allocation order (VAL matching)
    alloc_seq: jax.Array       # () int32 global allocation counter
    # --- DRAM log (append-only ring) ---
    dram_src: jax.Array        # (D,) int32
    dram_addr: jax.Array       # (D,) int32
    dram_val: jax.Array        # (D, W) float32
    dram_ts: jax.Array         # (D,) int32 (kept for assertions only)
    dram_ptr: jax.Array        # () int32 append cursor
    # --- ordering ---
    next_ts: jax.Array         # (n_sources,) int32 next TS to drain per src
    dropped: jax.Array         # () int32 count of REPLs dropped (SRAM full)


def init_state(sram_entries: int, dram_entries: int, n_sources: int,
               value_width: int = 1) -> LogUnitState:
    return LogUnitState(
        sram_src=jnp.full((sram_entries,), EMPTY),
        sram_addr=jnp.full((sram_entries,), EMPTY),
        sram_val=jnp.zeros((sram_entries, value_width), jnp.float32),
        sram_ts=jnp.full((sram_entries,), EMPTY),
        sram_valid=jnp.zeros((sram_entries,), bool),
        sram_seq=jnp.zeros((sram_entries,), jnp.int32),
        alloc_seq=jnp.zeros((), jnp.int32),
        dram_src=jnp.full((dram_entries,), EMPTY),
        dram_addr=jnp.full((dram_entries,), EMPTY),
        dram_val=jnp.zeros((dram_entries, value_width), jnp.float32),
        dram_ts=jnp.full((dram_entries,), EMPTY),
        dram_ptr=jnp.zeros((), jnp.int32),
        next_ts=jnp.zeros((n_sources,), jnp.int32),
        dropped=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# REPL reception: allocate an SRAM entry
# ---------------------------------------------------------------------------

def receive_repl(state: LogUnitState, src: jax.Array, addr: jax.Array,
                 value: jax.Array) -> LogUnitState:
    """Allocate one SRAM entry for (src, addr, value).

    Each REPL gets its *own* entry (two same-address stores can be in
    flight under ReCXL-proactive; store coalescing happens in the SB
    before REPLs are sent, never inside the Logging Unit -- the unit only
    *splits* multi-word REPLs into word entries). If SRAM is full the REPL
    is counted as dropped (hardware would NACK + retry; tests assert this
    never fires at paper sizes)."""
    free = state.sram_src == EMPTY
    has_free = jnp.any(free)
    slot = jnp.argmax(free)

    def write(s: LogUnitState) -> LogUnitState:
        return s._replace(
            sram_src=s.sram_src.at[slot].set(jnp.int32(src)),
            sram_addr=s.sram_addr.at[slot].set(jnp.int32(addr)),
            sram_val=s.sram_val.at[slot].set(value),
            sram_ts=s.sram_ts.at[slot].set(EMPTY),
            sram_valid=s.sram_valid.at[slot].set(False),
            sram_seq=s.sram_seq.at[slot].set(s.alloc_seq),
            alloc_seq=s.alloc_seq + 1,
        )

    return jax.lax.cond(
        has_free, write, lambda s: s._replace(dropped=s.dropped + 1), state)


# ---------------------------------------------------------------------------
# VAL reception: validate + stamp
# ---------------------------------------------------------------------------

def receive_val(state: LogUnitState, src: jax.Array, addr: jax.Array,
                ts: jax.Array) -> LogUnitState:
    """Mark the *oldest unvalidated* (src, addr) entry valid and record its
    logical timestamp.

    VALs from different sources / for different addresses can arrive in
    any order (the fabric reorders; draining enforces TS order). Matching
    assumes same-(src, addr) REPLs and VALs are point-to-point ordered --
    the well-definedness assumption the paper's (req_id, addr) matching
    rests on. A VAL always finds its entry: it is only sent after the
    REPL_ACK, so the REPL was already processed here (causality)."""
    match = ((state.sram_src == src) & (state.sram_addr == addr)
             & ~state.sram_valid)
    has = jnp.any(match)
    seq = jnp.where(match, state.sram_seq, jnp.iinfo(jnp.int32).max)
    slot = jnp.argmin(seq)
    return state._replace(
        sram_ts=jnp.where(has, state.sram_ts.at[slot].set(jnp.int32(ts)),
                          state.sram_ts),
        sram_valid=jnp.where(has, state.sram_valid.at[slot].set(True),
                             state.sram_valid),
    )


# ---------------------------------------------------------------------------
# SRAM -> DRAM drain (in per-source TS order)
# ---------------------------------------------------------------------------

def _drain_one(state: LogUnitState) -> Tuple[LogUnitState, jax.Array]:
    """Move at most one eligible entry (valid and ts == next_ts[src])."""
    src_safe = jnp.maximum(state.sram_src, 0)
    eligible = (state.sram_valid
                & (state.sram_src != EMPTY)
                & (state.sram_ts == state.next_ts[src_safe]))
    has = jnp.any(eligible)
    slot = jnp.argmax(eligible)

    def move(s: LogUnitState) -> Tuple[LogUnitState, jax.Array]:
        d = s.dram_ptr % s.dram_src.shape[0]
        src = s.sram_src[slot]
        s = s._replace(
            dram_src=s.dram_src.at[d].set(src),
            dram_addr=s.dram_addr.at[d].set(s.sram_addr[slot]),
            dram_val=s.dram_val.at[d].set(s.sram_val[slot]),
            dram_ts=s.dram_ts.at[d].set(s.sram_ts[slot]),
            dram_ptr=s.dram_ptr + 1,
            next_ts=s.next_ts.at[src].add(1),
            sram_src=s.sram_src.at[slot].set(EMPTY),
            sram_ts=s.sram_ts.at[slot].set(EMPTY),
            sram_valid=s.sram_valid.at[slot].set(False),
        )
        return s, jnp.bool_(True)

    return jax.lax.cond(has, move, lambda s: (s, jnp.bool_(False)), state)


def drain(state: LogUnitState, max_moves: int) -> LogUnitState:
    """Drain up to ``max_moves`` entries (background SRAM->DRAM mover)."""

    def body(s, _):
        s, _moved = _drain_one(s)
        return s, None

    state, _ = jax.lax.scan(body, state, None, length=max_moves)
    return state


# ---------------------------------------------------------------------------
# Queries (recovery + tests)
# ---------------------------------------------------------------------------

def latest_version(state: LogUnitState, src: jax.Array, addr: jax.Array
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Algorithm 2 for one address: newest logged value for (src, addr),
    searching DRAM (newest = highest ts) then unvalidated SRAM is ignored
    (not yet committed). Returns (found, ts, value)."""
    m = (state.dram_src == src) & (state.dram_addr == addr)
    found = jnp.any(m)
    ts = jnp.where(m, state.dram_ts, -1)
    best = jnp.argmax(ts)
    # also consider *validated* SRAM entries not yet drained
    ms = (state.sram_src == src) & (state.sram_addr == addr) & state.sram_valid
    found_s = jnp.any(ms)
    ts_s = jnp.where(ms, state.sram_ts, -1)
    best_s = jnp.argmax(ts_s)
    use_sram = found_s & (ts_s[best_s] > jnp.where(found, ts[best], -1))
    out_ts = jnp.where(use_sram, ts_s[best_s], ts[best])
    out_val = jnp.where(use_sram, state.sram_val[best_s], state.dram_val[best])
    return found | found_s, out_ts, out_val


def occupancy(state: LogUnitState) -> Tuple[jax.Array, jax.Array]:
    """(sram_used, dram_used) -- Fig. 13 instrumentation."""
    return (jnp.sum(state.sram_src != EMPTY),
            jnp.minimum(state.dram_ptr, state.dram_src.shape[0]))


def clear_dram(state: LogUnitState) -> LogUnitState:
    """Post-dump log clear (paper SS IV.E)."""
    return state._replace(
        dram_src=jnp.full_like(state.dram_src, EMPTY),
        dram_addr=jnp.full_like(state.dram_addr, EMPTY),
        dram_ts=jnp.full_like(state.dram_ts, EMPTY),
        dram_ptr=jnp.zeros((), jnp.int32),
    )
