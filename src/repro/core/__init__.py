"""ReCXL core: the paper's contribution.

* :mod:`repro.core.protocol`      -- message types (Fig. 4-5, Table I).
* :mod:`repro.core.replica_groups`-- hash-based replica selection.
* :mod:`repro.core.logging_unit`  -- fine-grained Logging Unit (SRAM
  staging + DRAM log, logical timestamps, in-order commit).
* :mod:`repro.core.replication`   -- the training-framework replication
  engine: 3 variants (baseline / parallel / proactive) as collective
  dependency structures inside the jitted step.
* :mod:`repro.core.directory`     -- shard directory (ownership state).
* :mod:`repro.core.recovery`      -- CM-driven recovery (Algorithms 1-2).
* :mod:`repro.core.failures`      -- failure detection + injection.
* :mod:`repro.core.simulator`     -- trace-driven protocol simulator that
  reproduces the paper's own evaluation (Figs. 2, 10-18).
* :mod:`repro.core.contention`    -- directory-contention & crash-
  consistency scenario axes (beyond-paper; docs/contention.md).
"""

from repro.core.replica_groups import replica_targets, replica_sources  # noqa: F401
from repro.core.replication import ReplicationEngine  # noqa: F401
