"""Fault injection + detection + recovery for the banked engines.

The paper's thesis is that losing a CPU node must not corrupt shared
CXL state: replicas hold a second copy of every cache line, Logging
Units journal un-committed stores, and recovery replays them onto a
spare (SS VI-VII).  Since PR 8 this repo's own platform has exactly the
vulnerability ReCXL fixes -- each wv row of the trace bank is resident
on ONE shard (``bank_partition="sub"``) -- so this module makes the
simulator resilient to the failures it simulates, with the same three
ingredients:

* **Injection** (:class:`ChaosConfig` + :func:`inject`): shard loss
  mid-grid / mid-query-stream, prefetch / compile-warm / daemon thread
  death, a corrupted device bank row, and slow or failed host->device
  uploads.  Every fault fires **once** per injected scope and every
  hook is a no-op when no scope is active, so production paths pay one
  ``None`` check.
* **Detection**: per-row CRC integrity digests (:func:`row_digest`,
  :func:`verify_rows`) checked by gather-path sampling before a tile
  dispatches against the resident bank, heartbeats on the engine worker
  threads (``engine.worker_heartbeats``), and bounded
  retry-with-backoff (``repro.core.retry``) around placement and
  dispatch.
* **Recovery**: rebuild a lost shard's local rows from the surviving
  replica block (:func:`replica_rebuild` -- the paper's Replica set,
  placed by ``TraceBank.sub_bank_host(k_replicas=2)``: row ``r`` is
  resident on shards ``r % n`` AND ``(r + 1) % n``) or from the host
  journal (:func:`journal_rebuild` -- the "Logging Unit":
  ``TraceBank`` retains un-dumped ``extend()`` diffs until the device
  dump is acknowledged), digest-verify the rebuilt rows
  (:func:`verify_rebuild`), then re-place via the elastic
  spare-replacement path (mesh unchanged, compiled programs stay
  valid, steady-state compiles stay 0) or collapse to the degraded
  mesh (``distributed.elastic.cells_degraded_shards``: one shard
  fewer, ``bank_partition="replicated"``, recompile once, keep
  serving).

The recovered results are pinned bit-identical (``==``) to the
fault-free run -- rebuilt rows carry the same bits, the scan is
deterministic IEEE arithmetic, and re-scheduled lanes rerun the same
compiled programs (tests/test_chaos.py; ``serve/chaos/*`` BENCH rows).
docs/resilience.md maps each piece onto the paper's failure model.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import telemetry as _tm

# ---------------------------------------------------------------------------
# Fault taxonomy
# ---------------------------------------------------------------------------


class ChaosError(RuntimeError):
    """Base class of every injected / detected fault."""


class ShardLossError(ChaosError):
    """A mesh shard (device / process) was lost mid-run."""

    def __init__(self, shard: int, where: str = ""):
        super().__init__(f"shard {shard} lost"
                         + (f" during {where}" if where else ""))
        self.shard = shard


class UploadError(ChaosError):
    """A host->device placement failed (transient: retryable)."""


class ThreadDeathError(ChaosError):
    """An engine/daemon worker thread was killed."""

    def __init__(self, thread: str):
        super().__init__(f"worker thread {thread!r} died")
        self.thread = thread


class IntegrityError(ChaosError):
    """Device-resident rows failed their CRC digests."""

    def __init__(self, rows: Sequence[int], where: str = ""):
        super().__init__(f"integrity digest mismatch on wv rows "
                         f"{sorted(rows)}"
                         + (f" ({where})" if where else ""))
        self.rows = tuple(sorted(rows))


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """One injected failure scenario (all faults default-off; a default
    config is inert).  Faults fire at most once per :func:`inject`
    scope:

    * ``lose_shard`` -- shard index to lose on the
      ``lose_at_dispatch``-th tile/flush dispatch (1-based, counted
      across engine tiles and serve flushes alike);
    * ``corrupt_wv_row`` -- global wv row whose resident device copy is
      bit-flipped after placement (detected by gather-path digest
      sampling);
    * ``upload_failures`` -- the first N host->device placements raise
      :class:`UploadError` (absorbed by ``retry.retry_call``);
      ``upload_delay_s`` additionally sleeps every placement (slow-h2d
      injection);
    * ``kill_thread`` -- ``"prefetch"`` | ``"warm"`` | ``"daemon"``:
      the named worker thread dies at its next unit of work (engines
      respawn/inline the work; the daemon's watchdog restarts the
      serve loop);
    * ``recovery`` -- ``"spare"`` (re-place on the unchanged mesh --
      compiled programs stay valid, 0 new compiles) or ``"degraded"``
      (shrink the cells mesh by one shard, collapse to
      ``bank_partition="replicated"``, recompile once);
    * ``verify_rows`` -- force gather-path digest sampling on/off
      (``None``: auto -- on iff ``corrupt_wv_row`` is set).
    """
    lose_shard: Optional[int] = None
    lose_at_dispatch: int = 1
    corrupt_wv_row: Optional[int] = None
    upload_failures: int = 0
    upload_delay_s: float = 0.0
    kill_thread: Optional[str] = None
    recovery: str = "spare"
    verify_rows: Optional[bool] = None

    def __post_init__(self) -> None:
        if self.recovery not in ("spare", "degraded"):
            raise ValueError(f"unknown recovery {self.recovery!r}")
        if self.kill_thread not in (None, "prefetch", "warm", "daemon"):
            raise ValueError(f"unknown kill_thread {self.kill_thread!r}")
        if self.lose_at_dispatch < 1:
            raise ValueError("lose_at_dispatch is 1-based")
        if self.upload_failures < 0 or self.upload_delay_s < 0:
            raise ValueError("upload_failures / upload_delay_s must be >= 0")


class ChaosState:
    """Mutable runtime of one injected scenario: fire-once bookkeeping,
    the event log, and the detection/recovery metrics benches report
    (:meth:`report`).  Thread-safe -- the hooks are called from the
    caller thread, the prefetch/compile pools and the serve daemon."""

    def __init__(self, cfg: ChaosConfig):
        self.cfg = cfg
        self._lock = threading.Lock()
        self.dispatches = 0
        self.uploads = 0
        self.upload_retries = 0
        self.lost: set = set()
        self._uploads_to_fail = cfg.upload_failures
        self._loss_fired = False
        self._corrupted = False
        self._threads_killed: set = set()
        self._corrupt_at: Optional[Tuple[int, float]] = None
        self._detect_at: Optional[Tuple[int, float]] = None
        self.recoveries: List[Dict[str, object]] = []
        self.events: List[Tuple[float, str, object]] = []

    # -- event log ---------------------------------------------------------

    def _note(self, kind: str, detail: object = None) -> None:
        self.events.append((time.monotonic(), kind, detail))
        _tm.count(f"chaos/{kind}")

    # -- re-arming ---------------------------------------------------------

    def arm_after(self, n_dispatches: int) -> None:
        """Re-arm the shard-loss trigger ``n_dispatches`` dispatches from
        *now*.  An absolute ``lose_at_dispatch`` is only meaningful when
        the caller can predict the dispatch count of everything that
        runs before the phase it wants to disrupt; a launcher that warms
        an arbitrary grid first cannot, so it re-arms relative to the
        live counter once the warm phase is done (the trigger still
        fires at most once)."""
        if n_dispatches < 1:
            raise ValueError("n_dispatches is 1-based")
        with self._lock:
            self.cfg = dataclasses.replace(
                self.cfg, lose_at_dispatch=self.dispatches + n_dispatches)

    # -- injection hooks (called by engine/serving) ------------------------

    def on_dispatch(self, where: str = "") -> None:
        """One tile/flush dispatch is about to run.  Raises
        :class:`ShardLossError` once when the configured dispatch count
        is reached."""
        with self._lock:
            self.dispatches += 1
            fire = (self.cfg.lose_shard is not None
                    and not self._loss_fired
                    and self.dispatches >= self.cfg.lose_at_dispatch)
            if fire:
                self._loss_fired = True
                self.lost.add(self.cfg.lose_shard)
                self._note("shard_loss", self.cfg.lose_shard)
        if fire:
            raise ShardLossError(self.cfg.lose_shard, where)

    def on_upload(self, nbytes: int = 0) -> None:
        """One host->device placement is about to run.  Sleeps
        ``upload_delay_s`` and fails the first ``upload_failures``
        placements."""
        if self.cfg.upload_delay_s:
            time.sleep(self.cfg.upload_delay_s)
        with self._lock:
            self.uploads += 1
            fail = self._uploads_to_fail > 0
            if fail:
                self._uploads_to_fail -= 1
                self._note("upload_failure", nbytes)
        if fail:
            raise UploadError(f"injected h2d failure ({nbytes} B)")

    def on_thread(self, name: str) -> None:
        """A worker thread starts a unit of work.  Kills the configured
        thread once."""
        with self._lock:
            fire = (self.cfg.kill_thread == name
                    and name not in self._threads_killed)
            if fire:
                self._threads_killed.add(name)
                self._note("thread_death", name)
        if fire:
            raise ThreadDeathError(name)

    def note_retry(self, attempt: int, err: BaseException,
                   delay: float) -> None:
        """`retry.retry_call` ``on_retry`` callback."""
        with self._lock:
            self.upload_retries += 1
            self._note("upload_retry", (attempt, repr(err)))

    def wants_verify(self) -> bool:
        if self.cfg.verify_rows is not None:
            return self.cfg.verify_rows
        return self.cfg.corrupt_wv_row is not None

    # -- corruption + detection bookkeeping --------------------------------

    def tamper_bank(self, dev: tuple, *, n_shards: int, k_replicas: int = 1,
                    local_cap: int = 0, wv_rows: int = 0) -> tuple:
        """Bit-flip the configured wv row's resident device copy (the
        PRIMARY block only -- the replica block keeps the true bits,
        exactly the partial-corruption case row digests exist for).
        Fires once; returns ``dev`` untouched otherwise.  The
        corruption is applied to a *new* array tuple -- memoized clean
        placements (the simulated durable dump) are never poisoned."""
        r = self.cfg.corrupt_wv_row
        with self._lock:
            fire = (r is not None and not self._corrupted
                    and 0 <= r < max(wv_rows, 1))
            if fire:
                self._corrupted = True
                self._corrupt_at = (self.dispatches, time.monotonic())
                self._note("corrupt_row", r)
        if not fire:
            return dev
        a, w, v, p = dev
        host = np.asarray(w)
        if host.ndim == 3:          # sub stack (n_shards, k*local, S)
            owner, loc = r % n_shards, r // n_shards
            host = host.copy()
            host[owner, loc] = host[owner, loc] + np.float32(1.0)
        else:                        # replicated (rows, S)
            host = host.copy()
            host[r] = host[r] + np.float32(1.0)
        return (a, jax.device_put(host, w.sharding), v, p)

    def note_detection(self, rows: Sequence[int]) -> None:
        with self._lock:
            if self._detect_at is None:
                self._detect_at = (self.dispatches, time.monotonic())
            self._note("integrity_detected", tuple(rows))

    def note_recovery(self, source: str, ms: float, shard: Optional[int],
                      mode: str = "spare") -> None:
        with self._lock:
            rec = {"source": source, "ms": ms, "shard": shard, "mode": mode}
            self.recoveries.append(rec)
            self._note("recovered", rec)

    # -- observability -----------------------------------------------------

    def report(self) -> Dict[str, object]:
        """Detection / recovery metrics of this scenario so far."""
        with self._lock:
            det_disp = det_ms = None
            if self._corrupt_at is not None and self._detect_at is not None:
                det_disp = self._detect_at[0] - self._corrupt_at[0]
                det_ms = (self._detect_at[1] - self._corrupt_at[1]) * 1e3
            return {
                "dispatches": self.dispatches,
                "uploads": self.uploads,
                "upload_retries": self.upload_retries,
                "lost_shards": sorted(self.lost),
                "threads_killed": sorted(self._threads_killed),
                "detection_dispatches": det_disp,
                "detection_ms": det_ms,
                "recoveries": list(self.recoveries),
                "recovery_ms": sum(r["ms"] for r in self.recoveries),
                "events": len(self.events),
            }


_ACTIVE: Optional[ChaosState] = None
_ACTIVE_LOCK = threading.Lock()


def active() -> Optional[ChaosState]:
    """The currently injected chaos scope, or ``None`` (the production
    fast path: every hook site is one call + ``None`` check)."""
    return _ACTIVE


@contextlib.contextmanager
def inject(cfg: ChaosConfig):
    """Activate one failure scenario for the dynamic extent of the
    ``with`` block (process-global: the engine worker threads and the
    serving daemon observe it too).  Yields the :class:`ChaosState`
    whose :meth:`~ChaosState.report` carries the detection/recovery
    metrics.  Scopes do not nest."""
    global _ACTIVE
    state = ChaosState(cfg)
    with _ACTIVE_LOCK:
        if _ACTIVE is not None:
            raise RuntimeError("a chaos scope is already active")
        _ACTIVE = state
    try:
        yield state
    finally:
        with _ACTIVE_LOCK:
            _ACTIVE = None


def resolve_k_replicas(k_replicas: Optional[int], n_shards: int) -> int:
    """The effective sub-bank replication factor: the caller's explicit
    ``k_replicas`` if given, else 2 under an active chaos/recovery
    scope and 1 otherwise (the paper's Replica set costs bytes, so it
    is on by default ONLY when resilience is requested -- ``k=1`` is
    byte-identical to the PR-8 layout).  Clamped to ``[1, n_shards]``:
    a replica on the owner's own shard protects nothing, so at one
    shard the journal is the only rebuild source."""
    k = k_replicas if k_replicas is not None \
        else (2 if active() is not None else 1)
    return max(1, min(int(k), n_shards))


# ---------------------------------------------------------------------------
# Integrity digests (detection)
# ---------------------------------------------------------------------------


def row_digest(row: np.ndarray) -> int:
    """CRC32 of one bank row's raw bytes (exact: the planes are
    deterministic f32/bool bits, so host and device copies of the same
    row digest identically)."""
    return zlib.crc32(np.ascontiguousarray(row).tobytes())


def fetch_wv_row(dev: tuple, r: int, *, n_shards: int,
                 local_cap: int = 0, block: int = 0
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Read global wv row ``r``'s ``(w, v, pr_nc)`` bytes back from a
    placed bank ``(arrivals, w, v, pr_nc)``.  On the sub-bank layout
    replica ``block`` ``j`` of owner ``r % n_shards`` lives on shard
    ``(r % n_shards + j) % n_shards`` at local index ``j * local_cap +
    r // n_shards``; the replicated 2-D layout indexes row ``r``
    directly."""
    _, w, v, p = dev
    if np.asarray(w).ndim == 2:
        return tuple(np.asarray(x[r]) for x in (w, v, p))
    owner, loc = r % n_shards, r // n_shards
    s = (owner + block) % n_shards
    i = block * local_cap + loc
    return tuple(np.asarray(x[s, i]) for x in (w, v, p))


def verify_rows(bank, dev: tuple, rows: Sequence[int], *, n_shards: int,
                local_cap: int = 0, where: str = "") -> None:
    """Gather-path integrity check: CRC-compare the device-resident
    primary copy of each global wv row in ``rows`` against the host
    bank's columns.  Raises :class:`IntegrityError` listing every bad
    row.  Cost is one row readback per checked row -- callers sample
    (the rows the next tile will gather, capped)."""
    bad = []
    for r in rows:
        if not 0 <= r < bank.wv_rows:
            continue
        got = fetch_wv_row(dev, r, n_shards=n_shards, local_cap=local_cap)
        want = (bank.w[r], bank.v[r], bank.pr_nc[r])
        if any(row_digest(g) != row_digest(h) for g, h in zip(got, want)):
            bad.append(r)
    if bad:
        st = active()
        if st is not None:
            st.note_detection(bad)
        raise IntegrityError(bad, where)


# ---------------------------------------------------------------------------
# Shard rebuild (recovery)
# ---------------------------------------------------------------------------


def owned_rows(lost: int, n_shards: int, wv_rows: int) -> List[int]:
    """Global wv rows whose primary copy lived on shard ``lost``."""
    return list(range(lost, wv_rows, n_shards))


def replica_rebuild(dev: tuple, lost: int, *, n_shards: int,
                    k_replicas: int, local_cap: int, wv_rows: int
                    ) -> Dict[str, np.ndarray]:
    """Rebuild the lost shard's local wv rows from the SURVIVING
    replica block: with ``k_replicas >= 2`` row ``r``'s second copy
    lives on shard ``(r % n + 1) % n`` (replica block 1), which by
    construction is a different shard, so losing one shard never loses
    a row.  Reads the survivor's device-resident block back to host and
    returns ``{"w", "v", "pr_nc"}`` arrays of shape ``(owned_rows,
    n_stores)`` in global-row order -- the exact bits
    :func:`verify_rebuild` then digests against the host truth."""
    if k_replicas < 2:
        raise ValueError("replica rebuild needs k_replicas >= 2")
    if n_shards < 2:
        raise ValueError("replica rebuild needs n_shards >= 2")
    with _tm.span("chaos/replica_rebuild", shard=lost):
        rows = owned_rows(lost, n_shards, wv_rows)
        out = {"w": [], "v": [], "pr_nc": []}
        for r in rows:
            w, v, p = fetch_wv_row(dev, r, n_shards=n_shards,
                                   local_cap=local_cap, block=1)
            out["w"].append(w)
            out["v"].append(v)
            out["pr_nc"].append(p)
        return {k: (np.stack(vs, axis=0) if vs
                    else np.zeros((0,), np.float32))
                for k, vs in out.items()}


def journal_rebuild(bank, lost: int, n_shards: int) -> Dict[str, np.ndarray]:
    """Rebuild the lost shard's local wv rows from the host side: the
    acknowledged dump (the bank's own columns -- in a real deployment
    the durable CXL-memory copy) plus the Logging-Unit journal of
    un-dumped ``extend()`` diffs.  When a journal is enabled, its
    replay is first digest-checked against the bank's tail rows (a
    divergent journal would replay corruption), then the owned rows
    are sliced out in global-row order -- byte-identical to what
    :func:`replica_rebuild` reads off the surviving device."""
    with _tm.span("chaos/journal_rebuild", shard=lost):
        return _journal_rebuild(bank, lost, n_shards)


def _journal_rebuild(bank, lost: int, n_shards: int) -> Dict[str, np.ndarray]:
    entries = bank.replay_journal() if getattr(bank, "journal_enabled",
                                               False) else None
    if entries is not None and entries["w"].shape[0]:
        p0 = bank.wv_rows - entries["w"].shape[0]
        for name in ("w", "v", "pr_nc"):
            tail = getattr(bank, name)[p0:]
            if row_digest(entries[name]) != row_digest(tail):
                raise IntegrityError(
                    list(range(p0, bank.wv_rows)),
                    "journal replay diverges from the host bank")
    rows = owned_rows(lost, n_shards, bank.wv_rows)
    return {"w": bank.w[rows].copy(), "v": bank.v[rows].copy(),
            "pr_nc": bank.pr_nc[rows].copy()}


def verify_rebuild(bank, rebuilt: Dict[str, np.ndarray], lost: int,
                   n_shards: int) -> None:
    """Digest-check rebuilt rows against the host truth before they are
    re-placed (recovery must never install corrupt rows -- the second
    place row digests are checked, after gather-path sampling)."""
    with _tm.span("chaos/verify_rebuild", shard=lost):
        _verify_rebuild(bank, rebuilt, lost, n_shards)


def _verify_rebuild(bank, rebuilt: Dict[str, np.ndarray], lost: int,
                    n_shards: int) -> None:
    rows = owned_rows(lost, n_shards, bank.wv_rows)
    bad = [r for i, r in enumerate(rows)
           if any(row_digest(rebuilt[name][i]) !=
                  row_digest(getattr(bank, name)[r])
                  for name in ("w", "v", "pr_nc"))]
    if bad:
        raise IntegrityError(bad, "rebuilt rows fail digests")
