"""The ReCXL replication engine for distributed training/serving.

Maps the paper's write-replication onto the TPU mesh (DESIGN.md S2):

* the "store" is a node's per-step state-shard update, split into
  ``n_buckets`` coalescing buckets (the SB-entry analogue);
* REPL = ``lax.ppermute`` of each bucket along the ``data`` axis to the
  N_r hash-selected replica nodes, which deposit it into their HBM log
  ring (allocation == REPL reception);
* VAL = a second, tiny ppermute carrying the logical timestamp (the step
  number); reception sets the entry's valid bit;
* the three protocol variants are *dependency structures* over these
  collectives -- XLA's latency-hiding scheduler realizes the overlap:

  - ``baseline``:  every REPL is barrier-tied to the completed state
    commit AND to the previous bucket's REPL (fully serialized chain,
    Fig. 6a);
  - ``parallel``:  REPLs consume the update value directly (no tie to the
    commit) but successive buckets stay chained (SB-head serialization,
    Fig. 6b);
  - ``proactive``: all (replica, bucket) REPLs are independent -- they
    issue as soon as each bucket's update exists and their latencies
    overlap (Fig. 6c / Fig. 8).

* ``coalescing=True`` gives all buckets of a replica rank one shared
  offset so the engine can fuse them into a single large ppermute per
  rank (fewer, bigger messages); ``False`` keeps per-bucket hash offsets
  (more, smaller, more overlappable messages) -- the Fig. 12 trade-off.

The log ring lives in the train state (donated each step). Entries hold
the *latest validated version* of each (source, bucket) shard -- exactly
what the paper's recovery extracts from its word-granularity log
(Algorithm 1 applies the newest logged version per address).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ReplicationConfig
from repro.core import replica_groups
from repro.distributed.context import MeshContext, shard_map

LogState = Dict[str, jax.Array]


def _tie(x, *deps):
    """Make ``x`` depend on ``deps`` without changing its value."""
    return jax.lax.optimization_barrier((x,) + tuple(deps))[0]


@dataclasses.dataclass(frozen=True)
class EngineLayout:
    """Static facts about the replicated payload.

    Leaves are assigned to buckets by greedy size-balanced bin packing.
    Crucially each bucket packs a *subset of leaves* (not a slice of the
    fully-concatenated update): under ReCXL-proactive a bucket's REPL
    then depends only on its own leaves' optimizer math, so XLA can
    overlap bucket i's ppermute with bucket j's compute -- the SB-overlap
    of Fig. 8. A flat split would chain every bucket behind the full
    update and destroy the variant distinction.
    """
    local_sizes: Tuple[int, ...]        # flattened size of each local leaf
    treedef: Any
    local_shapes: Tuple[Tuple[int, ...], ...]
    bucket_of_leaf: Tuple[int, ...]     # leaf index -> bucket id
    leaves_in_bucket: Tuple[Tuple[int, ...], ...]
    bucket_len: int                     # max padded bucket payload length
    n_buckets: int

    @property
    def total(self) -> int:
        return sum(self.local_sizes)


class ReplicationEngine:
    """One engine per RunConfig; stateless apart from static layout."""

    def __init__(self, rep: ReplicationConfig, ctx: MeshContext,
                 param_specs: Any, global_params: Any):
        self.rep = rep
        self.ctx = ctx
        mesh = ctx.mesh
        self.mesh_axes = tuple(mesh.axis_names)
        # replication runs along the data axis (pod-local) unless
        # cross_pod_replicas combines (pod, data) into one ring.
        if rep.cross_pod_replicas and "pod" in self.mesh_axes:
            self.repl_axes: Tuple[str, ...] = ("pod", "data")
        else:
            self.repl_axes = ("data",)
        self.n_nodes = int(np.prod([mesh.shape[a] for a in self.repl_axes]))
        if rep.is_replicating and rep.n_replicas >= self.n_nodes:
            raise ValueError("n_replicas must be < replication ring size")
        self.param_specs = param_specs
        self.layout = self._layout(global_params, param_specs)
        self.log_dtype = jnp.dtype(rep.log_dtype)

    # ------------------------------------------------------------------
    def _layout(self, global_params: Any, specs: Any) -> EngineLayout:
        mesh = self.ctx.mesh
        leaves, treedef = jax.tree.flatten(global_params)
        spec_leaves = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
        local_shapes: List[Tuple[int, ...]] = []
        for leaf, spec in zip(leaves, spec_leaves):
            shape = list(leaf.shape)
            for d, ax in enumerate(spec):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                div = int(np.prod([mesh.shape[a] for a in axes]))
                if shape[d] % div:
                    # GSPMD pads uneven dims; the engine replicates the
                    # padded block to keep shard_map blocks uniform.
                    shape[d] = shape[d] + (div - shape[d] % div)
                shape[d] //= div
            local_shapes.append(tuple(shape))
        sizes = tuple(int(np.prod(s)) for s in local_shapes)
        nb = min(self.rep.n_buckets, max(len(sizes), 1))
        # greedy size-balanced bin packing, deterministic
        order = sorted(range(len(sizes)), key=lambda i: -sizes[i])
        loads = [0] * nb
        bucket_of = [0] * len(sizes)
        for i in order:
            b = int(np.argmin(loads))
            bucket_of[i] = b
            loads[b] += sizes[i]
        in_bucket = tuple(tuple(i for i in range(len(sizes))
                                if bucket_of[i] == b) for b in range(nb))
        bucket_len = max(max(loads), 1)
        return EngineLayout(local_sizes=sizes, treedef=treedef,
                            local_shapes=tuple(local_shapes),
                            bucket_of_leaf=tuple(bucket_of),
                            leaves_in_bucket=in_bucket,
                            bucket_len=bucket_len, n_buckets=nb)

    # ------------------------------------------------------------------
    # Log state
    # ------------------------------------------------------------------

    @property
    def _nr(self) -> int:
        """Log-ring replica dim: parity mode stores one shard per group."""
        return 1 if self.rep.mode == "parity" else self.rep.n_replicas

    def log_struct(self) -> Dict[str, jax.ShapeDtypeStruct]:
        """Global ShapeDtypeStructs for the log ring."""
        mesh = self.ctx.mesh
        lead = tuple(mesh.shape[a] for a in self.mesh_axes)
        nr, cap = self._nr, self.rep.log_capacity
        nb, bl = self.layout.n_buckets, self.layout.bucket_len
        return {
            "values": jax.ShapeDtypeStruct(lead + (nr, cap, nb, bl),
                                           self.log_dtype),
            "ts": jax.ShapeDtypeStruct(lead + (nr, cap, nb), jnp.int32),
            "valid": jax.ShapeDtypeStruct(lead + (nr, cap, nb), jnp.bool_),
        }

    def log_specs(self) -> Dict[str, P]:
        n_lead = len(self.mesh_axes)
        def spec(extra: int) -> P:
            return P(*self.mesh_axes, *([None] * extra))
        return {"values": spec(4), "ts": spec(3), "valid": spec(3)}

    def log_shardings(self) -> Dict[str, NamedSharding]:
        return {k: NamedSharding(self.ctx.mesh, s)
                for k, s in self.log_specs().items()}

    def init_logs(self) -> LogState:
        structs = self.log_struct()
        shardings = self.log_shardings()
        def mk(k):
            s = structs[k]
            fill = jnp.zeros if k != "ts" else (lambda sh, dt: jnp.full(sh, -1, dt))
            try:
                return jax.device_put(fill(s.shape, s.dtype), shardings[k])
            except Exception:
                return fill(s.shape, s.dtype)
        return {k: mk(k) for k in structs}

    # ------------------------------------------------------------------
    # Payload packing
    # ------------------------------------------------------------------

    def pack_bucket(self, local_leaves: Sequence[jax.Array],
                    bucket: int) -> jax.Array:
        """Concat bucket ``bucket``'s leaves, padded to bucket_len."""
        lay = self.layout
        idxs = lay.leaves_in_bucket[bucket]
        if not idxs:
            return jnp.zeros((lay.bucket_len,), self.log_dtype)
        flat = [local_leaves[i].reshape(-1).astype(self.log_dtype)
                for i in idxs]
        vec = jnp.concatenate(flat) if len(flat) > 1 else flat[0]
        pad = lay.bucket_len - vec.shape[0]
        return jnp.pad(vec, (0, pad)) if pad else vec

    def unpack_bucket(self, vec: jax.Array, bucket: int) -> Dict[int, jax.Array]:
        """Bucket payload -> {leaf_index: local leaf array}."""
        lay = self.layout
        out: Dict[int, jax.Array] = {}
        off = 0
        for i in lay.leaves_in_bucket[bucket]:
            size, shape = lay.local_sizes[i], lay.local_shapes[i]
            out[i] = vec.reshape(-1)[off:off + size].reshape(shape)
            off += size
        return out

    def unpack(self, buckets: jax.Array) -> List[jax.Array]:
        """(n_buckets, bucket_len) -> local leaf list (host or device)."""
        out: List[Any] = [None] * len(self.layout.local_sizes)
        for b in range(self.layout.n_buckets):
            for i, leaf in self.unpack_bucket(buckets[b], b).items():
                out[i] = leaf
        return out

    def unflatten(self, leaves: Sequence[jax.Array]) -> Any:
        return jax.tree.unflatten(self.layout.treedef, list(leaves))

    # ------------------------------------------------------------------
    # Offsets / perms
    # ------------------------------------------------------------------

    def parity_groups(self) -> List[List[int]]:
        g = self.rep.parity_group
        if self.n_nodes % g:
            raise ValueError(
                f"parity_group {g} must divide ring size {self.n_nodes}")
        return [list(range(i, i + g)) for i in range(0, self.n_nodes, g)]

    def parity_holder(self, group: int, bucket: int) -> int:
        """Node storing group ``group``'s parity for ``bucket`` -- always
        OUTSIDE the group, and collision-free by construction: every
        group rotates by the same bucket-hashed shift, so distinct groups
        always land in distinct target groups (the per-bucket ppermute
        needs unique destinations). Pure function of (group, bucket),
        recomputable by recovery."""
        g = self.rep.parity_group
        n_groups = self.n_nodes // g
        if n_groups < 2:
            raise ValueError("parity mode needs >= 2 groups")
        h = replica_groups._hash_int(bucket, self.n_nodes)
        shift = 1 + h % (n_groups - 1)           # same for all groups
        tgt_group = (group + shift) % n_groups
        return tgt_group * g + (h // 7) % g

    def _offsets(self, bucket: int) -> Tuple[int, ...]:
        b = 0 if self.rep.coalescing else bucket
        return replica_groups.replica_offsets(b, self.rep.n_replicas,
                                              self.n_nodes)

    def _perm(self, off: int) -> List[Tuple[int, int]]:
        n = self.n_nodes
        return [(s, (s + off) % n) for s in range(n)]

    @property
    def _axis(self):
        return self.repl_axes if len(self.repl_axes) > 1 else self.repl_axes[0]

    # ------------------------------------------------------------------
    # In-step replication (call under the mesh, on GSPMD-global arrays)
    # ------------------------------------------------------------------

    def replicate(self, updates: Any, logs: LogState, step: jax.Array,
                  commit_value: Any) -> Tuple[LogState, Any]:
        """Run the REPL/VAL transactions for this step.

        ``updates``: pytree (global arrays) to replicate -- the new state
        shard. ``commit_value``: the pytree whose availability defines the
        paper's "coherence transaction completed" point (the updated
        params, post-collectives). Returns (new_logs, committed_value)
        where ``committed_value`` == commit_value, barrier-tied so the
        store only "commits" after the variant's requirements hold.
        """
        if not self.rep.is_replicating:
            return logs, commit_value

        mesh = self.ctx.mesh
        n_lead = len(self.mesh_axes)
        in_specs = (self.param_specs, self.log_specs(), P(), self.param_specs)
        out_specs = (self.log_specs(), P())

        variant = self.rep.variant
        nr, cap = self._nr, self.rep.log_capacity
        nb = self.layout.n_buckets
        parity = self.rep.mode == "parity"
        if parity:
            groups = self.parity_groups()
            holders = {b: [self.parity_holder(g, b)
                           for g in range(len(groups))]
                       for b in range(nb)}

        def region(upd_local, logs_local, step_, commit_local):
            # strip the leading mesh dims of the log blocks
            lv = logs_local["values"].reshape(logs_local["values"].shape[n_lead:])
            lt = logs_local["ts"].reshape(logs_local["ts"].shape[n_lead:])
            lg = logs_local["valid"].reshape(logs_local["valid"].shape[n_lead:])
            slot = (step_ % cap).astype(jnp.int32)

            upd_leaves = jax.tree.leaves(upd_local)
            commit_leaves = jax.tree.leaves(commit_local)
            payloads = [self.pack_bucket(upd_leaves, b) for b in range(nb)]
            if variant == "baseline":
                # REPL waits for the full commit value (coherence done)
                payloads = [_tie(p, *commit_leaves) for p in payloads]

            chain_dep: Optional[jax.Array] = None
            val_tokens: List[jax.Array] = []
            recvs: List[Tuple[int, int, jax.Array]] = []

            if parity:
                # beyond-paper erasure coding: one parity shard per group,
                # stored outside the group. psum over the group builds the
                # parity on every member; member 0 forwards it to the
                # hash-selected holder.
                my_idx = jax.lax.axis_index(self._axis)
                for b in range(nb):
                    src = payloads[b].astype(jnp.float32)
                    if variant in ("baseline", "parallel") and \
                            chain_dep is not None:
                        src = _tie(src, chain_dep)
                    par = jax.lax.psum(src, self._axis,
                                       axis_index_groups=groups)
                    perm = [(g[0], holders[b][gi])
                            for gi, g in enumerate(groups)]
                    recv = jax.lax.ppermute(par, self._axis, perm)
                    if variant in ("baseline", "parallel"):
                        chain_dep = recv
                    # only holders received real data; zeros elsewhere
                    is_holder = jnp.zeros((), jnp.bool_)
                    for hlist in (holders[b],):
                        for h in hlist:
                            is_holder = is_holder | (my_idx == h)
                    lv = lv.at[0, slot, b].set(recv.astype(lv.dtype))
                    lt = lt.at[0, slot, b].set(
                        jnp.where(is_holder, step_, lt[0, slot, b]))
                    lg = lg.at[0, slot, b].set(is_holder)
                    val_tokens.append(jnp.sum(recv).astype(jnp.int32)[None])
                lead = logs_local["values"].shape[:n_lead]
                new_logs = {
                    "values": lv.reshape(lead + lv.shape),
                    "ts": lt.reshape(lead + lt.shape),
                    "valid": lg.reshape(lead + lg.shape),
                }
                token = jnp.sum(jnp.concatenate(val_tokens))
                return new_logs, token

            if self.rep.coalescing:
                # one big ppermute per replica rank (all buckets share off)
                payload = jnp.stack(payloads)
                for r in range(nr):
                    off = self._offsets(0)[r]
                    src = payload
                    if variant in ("baseline", "parallel") and chain_dep is not None:
                        src = _tie(src, chain_dep)
                    recv = jax.lax.ppermute(src, self._axis, self._perm(off))
                    if variant in ("baseline", "parallel"):
                        chain_dep = recv
                    recvs.append((r, -1, recv))
            else:
                for b in range(nb):
                    offs = self._offsets(b)
                    for r in range(nr):
                        src = payloads[b]
                        if variant in ("baseline", "parallel") and chain_dep is not None:
                            src = _tie(src, chain_dep)
                        recv = jax.lax.ppermute(src, self._axis,
                                                self._perm(offs[r]))
                        if variant in ("baseline", "parallel"):
                            chain_dep = recv
                        recvs.append((r, b, recv))

            # deposit REPL payloads into the ring (allocation)
            for r, b, recv in recvs:
                if b < 0:      # coalesced: whole (nb, bl) block at once
                    lv = lv.at[r, slot].set(recv)
                else:
                    lv = lv.at[r, slot, b].set(recv)

            # VAL: tiny ts ppermute per replica rank, after that rank's
            # REPLs delivered (barrier tie); reception sets valid + ts.
            ts_vec = jnp.full((nb,), step_, jnp.int32)
            for r in range(nr):
                deps = [recv for (rr, _, recv) in recvs if rr == r]
                val_src = _tie(ts_vec, *deps)
                off = self._offsets(0)[r] if self.rep.coalescing else None
                if self.rep.coalescing:
                    val_recv = jax.lax.ppermute(val_src, self._axis,
                                                self._perm(off))
                    lt = lt.at[r, slot].set(val_recv)
                    lg = lg.at[r, slot].set(True)
                    val_tokens.append(val_recv)
                else:
                    for b in range(nb):
                        offb = self._offsets(b)[r]
                        val_recv = jax.lax.ppermute(
                            val_src[b:b + 1], self._axis, self._perm(offb))
                        lt = lt.at[r, slot, b].set(val_recv[0])
                        lg = lg.at[r, slot, b].set(True)
                        val_tokens.append(val_recv)

            lead = logs_local["values"].shape[:n_lead]
            new_logs = {
                "values": lv.reshape(lead + lv.shape),
                "ts": lt.reshape(lead + lt.shape),
                "valid": lg.reshape(lead + lg.shape),
            }
            token = jnp.sum(jnp.concatenate(
                [jnp.ravel(t).astype(jnp.int32) for t in val_tokens]))
            return new_logs, token

        new_logs, token = shard_map(
            region, mesh=mesh, in_specs=in_specs,
            out_specs=out_specs)(updates, logs, step, commit_value)

        # the store commits only once replication finished (all variants)
        committed = jax.tree.map(lambda x: _tie(x, token), commit_value)
        return new_logs, committed
