"""Data pipeline."""

from repro.data.pipeline import SyntheticTokenPipeline, make_pipeline  # noqa: F401
