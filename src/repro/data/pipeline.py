"""Deterministic, host-sharded synthetic token pipeline.

Production shape without production data: the pipeline is seeded per
(epoch, step, host-shard), supports exact resume from a step index (a
fault-tolerance requirement: after recovery the pipeline must replay from
the restored step), and double-buffers batch construction off the
critical path.

Synthetic sequences are Zipf-ish token draws with a repeated-ngram
structure so losses actually decrease in the examples (pure uniform
tokens give a flat loss).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.config import ModelConfig, ShapeConfig
from repro.models.model_zoo import batch_struct


@dataclasses.dataclass
class PipelineState:
    step: int
    seed: int


class SyntheticTokenPipeline:
    def __init__(self, model_cfg: ModelConfig, shape: ShapeConfig,
                 seed: int = 0, prefetch: int = 2):
        self.cfg = model_cfg
        self.shape = shape
        self.seed = seed
        self.state = PipelineState(step=0, seed=seed)
        self._structs = batch_struct(model_cfg, shape)
        self._q: "queue.Queue[Dict[str, np.ndarray]]" = queue.Queue(prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ------------------------------------------------------------------
    def _make(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        out: Dict[str, np.ndarray] = {}
        v = self.cfg.vocab_size
        for name, spec in self._structs.items():
            if name == "labels":
                continue
            if np.issubdtype(spec.dtype, np.integer):
                b, s = spec.shape
                # zipf-flavored draws + embedded repeats for learnability
                base = rng.zipf(1.3, size=(b, s)).astype(np.int64) % v
                ngram = rng.integers(0, v, (b, 8))
                pos = rng.integers(0, max(s - 8, 1), (b,))
                for i in range(b):
                    base[i, pos[i]:pos[i] + 8] = ngram[i, : min(8, s - pos[i])]
                out[name] = base.astype(np.int32)
            else:
                out[name] = (rng.standard_normal(spec.shape) * 0.02).astype(
                    np.dtype(spec.dtype))
        if "labels" in self._structs:
            toks = out["tokens"]
            out["labels"] = np.concatenate(
                [toks[:, 1:], toks[:, :1]], axis=1).astype(np.int32)
        return out

    # ------------------------------------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            return

        def worker():
            step = self.state.step
            while not self._stop.is_set():
                batch = self._make(step)
                while not self._stop.is_set():
                    try:
                        self._q.put(batch, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None
        self._stop.clear()
        while not self._q.empty():
            self._q.get_nowait()

    # ------------------------------------------------------------------
    def next(self) -> Dict[str, np.ndarray]:
        if self._thread is not None:
            batch = self._q.get()
        else:
            batch = self._make(self.state.step)
        self.state.step += 1
        return batch

    def seek(self, step: int) -> None:
        """Exact resume: replay the pipeline from ``step`` (post-recovery)."""
        running = self._thread is not None
        if running:
            self.stop()
        self.state.step = step
        if running:
            self.start()

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next()


def make_pipeline(model_cfg: ModelConfig, shape: ShapeConfig,
                  seed: int = 0) -> SyntheticTokenPipeline:
    return SyntheticTokenPipeline(model_cfg, shape, seed)
