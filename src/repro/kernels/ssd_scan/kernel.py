"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Grid: (batch, heads, n_chunks) with the chunk axis sequential: the
(P, N) recurrent state lives in VMEM scratch across chunk steps. Each
step does three MXU matmuls (the matmul-form SSD of Dao & Gu):

    G       = C_c @ B_c^T                     (Q, Q)  intra-chunk scores
    y_intra = (G . decay . dt) @ x_c          (Q, P)
    S_c     = (x_c . w)^T @ B_c               (P, N)  chunk summary
    y_inter = (C_c . exp(seg)) @ state^T      (Q, P)

VMEM per step at Q=256, P=64, N=128: x/B/C blocks + (Q,Q) scores + state
~ 0.6 MB fp32 -- small; the MXU dims (Q, N, P) are 128/64-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref,
                state_scr, *, chunk: int, n_chunks: int, length: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)            # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)          # (Q,)
    A = a_ref[0, 0]                                # scalar
    Bm = b_ref[0].astype(jnp.float32)              # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)              # (Q, N)

    # zero the dt of padded tail positions (no state contribution)
    pos = ci * chunk + jax.lax.iota(jnp.int32, chunk)
    dt = jnp.where(pos < length, dt, 0.0)

    dA = dt * A                                    # (Q,) <= 0
    seg = jnp.cumsum(dA)                           # (Q,)
    seg_last = seg[-1]

    # intra-chunk
    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, Q)
    diff = seg[:, None] - seg[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, G.shape, 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, G.shape, 1)
    decay = jnp.where(ii >= jj, jnp.exp(diff), 0.0)
    att = G * decay * dt[None, :]
    y_intra = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk from carried state
    state = state_scr[...]                         # (P, N)
    Cexp = Cm * jnp.exp(seg)[:, None]
    y_inter = jax.lax.dot_general(Cexp, state, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # chunk summary + state update
    w = dt * jnp.exp(seg_last - seg)               # (Q,)
    xw = x * w[:, None]                            # (Q, P)
    S_c = jax.lax.dot_general(xw, Bm, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    state_scr[...] = jnp.exp(seg_last) * state + S_c

    @pl.when(ci == n_chunks - 1)
    def _flush():
        state_ref[0, 0] = state_scr[...].astype(state_ref.dtype)


def ssd_scan_pallas(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                    C: jax.Array, chunk: int = 256,
                    interpret: bool = True):
    """x: (b, l, h, p); dt: (b, l, h); A: (h,); B, C: (b, l, n).
    Returns (y (b, l, h, p), final state (b, h, p, n))."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, l)
    nc = -(-l // chunk)
    pad = nc * chunk - l
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))

    # head-major layouts for clean (1, 1, Q, *) blocks
    xh = jnp.moveaxis(x, 2, 1)                     # (b, h, L, p)
    dth = jnp.moveaxis(dt, 2, 1)                   # (b, h, L)
    a2d = A.reshape(h, 1).astype(jnp.float32)      # (h, 1)

    grid = (b, h, nc)
    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc, length=l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1, 1), lambda bi, hi, ci: (hi, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc * chunk, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xh, dth, a2d, B, C)
    y = jnp.moveaxis(y, 1, 2)[:, :l]               # (b, l, h, p)
    return y, state
