"""Oracle for the SSD scan: the naive O(L) sequential recurrence.

    state_t = exp(dt_t * A) * state_{t-1} + dt_t * x_t (outer) B_t
    y_t     = state_t @ C_t

Deliberately independent of the chunked algorithm in models/ssm.py so it
validates both the Pallas kernel and the model's chunked path.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
            C: jax.Array, init_state: Optional[jax.Array] = None,
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (b, l, h, p); dt: (b, l, h) post-softplus; A: (h,) negative;
    B, C: (b, l, n). Returns (y (b, l, h, p), state (b, h, p, n))."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    x32 = x.astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    B32 = B.astype(jnp.float32)
    C32 = C.astype(jnp.float32)

    def step(state, t):
        xt, dtt, Bt, Ct = t
        dA = jnp.exp(dtt * A[None, :])                       # (b, h)
        upd = (dtt[..., None] * xt)[..., None] * Bt[:, None, None, :]
        state = dA[..., None, None] * state + upd            # (b, h, p, n)
        y = jnp.einsum("bhpn,bn->bhp", state, Ct)
        return state, y

    state0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
              else init_state.astype(jnp.float32))
    xs = (jnp.moveaxis(x32, 1, 0), jnp.moveaxis(dt32, 1, 0),
          jnp.moveaxis(B32, 1, 0), jnp.moveaxis(C32, 1, 0))
    state, ys = jax.lax.scan(step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)               # (b, l, h, p)
    return y, state.astype(x.dtype)
