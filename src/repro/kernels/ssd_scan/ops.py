"""Jit'd public wrapper for the SSD scan.

TPU -> compiled Pallas kernel; CPU -> the chunked pure-jnp path from
models/ssm.py (same algorithm); tests sweep both against the sequential
recurrence oracle in ref.py.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax

from repro.kernels.ssd_scan import kernel
from repro.models.ssm import ssd_chunked


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "force"))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, chunk: int = 256,
             force: Optional[str] = None) -> Tuple[jax.Array, jax.Array]:
    """x: (b, l, h, p); dt: (b, l, h); A: (h,); B, C: (b, l, n)."""
    path = force or ("pallas" if _on_tpu() else "jnp")
    if path == "pallas":
        return kernel.ssd_scan_pallas(x, dt, A, B, C, chunk=chunk,
                                      interpret=not _on_tpu())
    if path == "pallas_interpret":
        return kernel.ssd_scan_pallas(x, dt, A, B, C, chunk=chunk,
                                      interpret=True)
    return ssd_chunked(x, dt, A, B, C, chunk)
