"""Pure-jnp oracle: exact (materialized-scores) GQA attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Skv, K, D) with H % K == 0.
    fp32 softmax; output in q.dtype."""
    b, sq, h, d = q.shape
    skv, kh = k.shape[1], k.shape[2]
    if kh != h:
        rep = h // kh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = 1.0 / np.sqrt(d)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        qi = jnp.arange(sq)[:, None] + (skv - sq)
        ki = jnp.arange(skv)[None, :]
        s = jnp.where(ki <= qi, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
