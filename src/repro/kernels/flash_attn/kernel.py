"""Pallas TPU flash attention (forward), GQA-aware.

Grid: (batch, q_heads, n_q_blocks, n_kv_blocks) -- the last axis is the
reduction axis; on TPU the grid is walked sequentially over it, so the
online-softmax running state (m, l, acc) lives in VMEM scratch that
persists across kv steps and is flushed to the output block on the last
step. VMEM working set per step: q (BQ, D) + k/v (BK, D) + acc (BQ, D)
fp32 + scores (BQ, BK) -- with BQ=BK=512, D=128 that is ~2.6 MB, well
under the ~16 MB v5e VMEM budget, and the (BQ, D) x (D, BK) MXU matmuls
are 128-aligned.

GQA is handled in the index maps: q head h reads kv head h // group.
Causality prunes upper-triangle blocks via ``pl.when`` (the block is
skipped entirely, not masked), so compiled work matches the exact causal
cost like the pure-jnp blockwise twin in models/attention.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, block_q: int, block_k: int,
                 n_kv_blocks: int, sq: int, skv: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    offset = skv - sq          # right-aligned causal (decode-style)
    q_lo = iq * block_q + offset
    k_lo = ik * block_k
    # process the block unless it is entirely above the causal diagonal
    live = (not causal) or (k_lo <= q_lo + block_q - 1)

    @pl.when(live)
    def _body():
        q = q_ref[0, :, 0, :]                       # (BQ, D)
        k = k_ref[0, :, 0, :]                       # (BK, D)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (BQ, BK)
        if causal:
            qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        # kv tail padding
        kpos2 = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos2 < skv, s, NEG_INF)

        m_prev = m_scr[...]                          # (BQ, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                       # (BQ, BK)
        corr = jnp.exp(m_prev - m_new)               # (BQ, 1)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)      # (BQ, D)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _flush():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True, block_q: int = 512,
                           block_k: int = 512,
                           interpret: bool = True) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Skv, K, D). Returns (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    skv, kh = k.shape[1], k.shape[2]
    assert h % kh == 0
    group = h // kh
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    nq = -(-sq // block_q)
    nk = -(-skv // block_k)
    pad_q = nq * block_q - sq
    pad_k = nk * block_k - skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    grid = (b, h, nq, nk)
    scale = 1.0 / np.sqrt(d)

    out = pl.pallas_call(
        functools.partial(
            _attn_kernel, scale=scale, causal=causal, block_q=block_q,
            block_k=block_k, n_kv_blocks=nk, sq=sq, skv=skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda bi, hi, qi, ki, g=group: (bi, ki, hi // g, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda bi, hi, qi, ki, g=group: (bi, ki, hi // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nq * block_q, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),   # fp32 accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
