"""Jit'd public wrapper for flash attention.

On TPU, the compiled Pallas kernel; elsewhere the pure-jnp blockwise
twin from models/attention.py (same algorithm, same exact-causal FLOPs)
so the model code is backend-portable. ``force`` pins a path for tests.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attn import kernel
from repro.models.attention import _blockwise_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "force"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_q: int = 512,
                    block_k: int = 512,
                    force: Optional[str] = None) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Skv, K, D) -> (B, Sq, H, D)."""
    path = force or ("pallas" if _on_tpu() else "jnp")
    if path == "pallas":
        return kernel.flash_attention_pallas(
            q, k, v, causal=causal, block_q=block_q, block_k=block_k,
            interpret=not _on_tpu())
    if path == "pallas_interpret":
        return kernel.flash_attention_pallas(
            q, k, v, causal=causal, block_q=block_q, block_k=block_k,
            interpret=True)
    return _blockwise_attention(q, k, v, causal, q_block=block_q,
                                kv_block=block_k)
