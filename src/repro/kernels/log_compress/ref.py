"""Pure-jnp oracle for the log-dump compressor.

Scheme (DESIGN.md S7): the paper gzip-9s its logs (5.8x) before dumping
to the MNs. gzip's variable-rate byte-serial coding has no TPU analogue,
so the TPU-native fixed-rate scheme is:

    delta  = values - base              (base = last dumped version)
    scale  = max(|delta|) / qmax        per block of ``block`` words
    codes  = round(delta / scale)       int8 (or int4 range)

Decompression is ``base + codes * scale``. Fixed rate: 8 (or 4) bits per
word + one f32 scale per block -> 3.88x (7.5x) vs the f32 log-entry
payload, reported next to the paper's 5.8x.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compress_ref(values: jax.Array, base: jax.Array, block: int = 256,
                 bits: int = 8) -> Tuple[jax.Array, jax.Array]:
    """values, base: (n, block) f32. Returns (codes int8 (n, block),
    scales f32 (n, 1))."""
    assert values.ndim == 2 and values.shape == base.shape
    qmax = float(2 ** (bits - 1) - 1)
    delta = values.astype(jnp.float32) - base.astype(jnp.float32)
    amax = jnp.max(jnp.abs(delta), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    codes = jnp.clip(jnp.round(delta / scale), -qmax, qmax).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def decompress_ref(codes: jax.Array, scales: jax.Array,
                   base: jax.Array) -> jax.Array:
    """Inverse of compress_ref. Returns f32 (n, block)."""
    return (base.astype(jnp.float32)
            + codes.astype(jnp.float32) * scales.astype(jnp.float32))
