from repro.kernels.log_compress.ops import (  # noqa: F401
    compress,
    compression_factor,
    decompress,
)
