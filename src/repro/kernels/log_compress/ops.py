"""Jit'd public wrappers for the log compressor.

On TPU the Pallas kernel runs compiled; elsewhere (this CPU container,
unit tests) it runs in interpret mode or falls back to the jnp reference
-- same numerics either way (tests assert bit-equality of codes).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.log_compress import kernel, ref

BLOCK = 256


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to_blocks(flat: jax.Array, block: int) -> Tuple[jax.Array, int]:
    n = flat.shape[0]
    pad = (-n) % (block * kernel.TILE_ROWS)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block), n


@functools.partial(jax.jit, static_argnames=("bits", "use_pallas"))
def compress(values: jax.Array, base: jax.Array, bits: int = 8,
             use_pallas: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Compress a flat f32/bf16 update against its base snapshot.

    Returns (codes int8 (n_blocks, BLOCK), scales f32 (n_blocks, 1)).
    """
    v2d, _ = _pad_to_blocks(values.reshape(-1).astype(jnp.float32), BLOCK)
    b2d, _ = _pad_to_blocks(base.reshape(-1).astype(jnp.float32), BLOCK)
    if use_pallas:
        return kernel.compress_pallas(v2d, b2d, bits=bits,
                                      interpret=not _on_tpu())
    return ref.compress_ref(v2d, b2d, block=BLOCK, bits=bits)


@functools.partial(jax.jit, static_argnames=("n", "use_pallas"))
def decompress(codes: jax.Array, scales: jax.Array, base: jax.Array,
               n: int, use_pallas: bool = True) -> jax.Array:
    """Inverse transform; returns flat f32 of length ``n``."""
    b2d, _ = _pad_to_blocks(base.reshape(-1).astype(jnp.float32), BLOCK)
    if use_pallas:
        out = kernel.decompress_pallas(codes, scales, b2d,
                                       interpret=not _on_tpu())
    else:
        out = ref.decompress_ref(codes, scales, b2d)
    return out.reshape(-1)[:n]


def compression_factor(bits: int = 8, block: int = BLOCK) -> float:
    """Fixed-rate factor vs. the f32 log payload (excludes base storage,
    which recovery already holds as the previous dump)."""
    payload_bits = 32 * block
    compressed_bits = bits * block + 32
    return payload_bits / compressed_bits
