"""Pallas TPU kernel for the ReCXL log-dump compressor.

Tiling: inputs are (n_blocks, block) with ``block`` a multiple of 128
(lane width). Each grid step owns a (TILE_ROWS, block) slab in VMEM:
one VPU pass computes the per-row absmax (the per-block scale), a second
fused pass quantizes. The int8 output halves the store bandwidth of the
dump DMA, which is the point -- the dump competes with training traffic
for HBM (paper Fig. 14 keeps dumps <5 GB/s).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_ROWS = 8


def _compress_kernel(values_ref, base_ref, codes_ref, scales_ref, *,
                     qmax: float):
    v = values_ref[...].astype(jnp.float32)
    b = base_ref[...].astype(jnp.float32)
    delta = v - b
    amax = jnp.max(jnp.abs(delta), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(delta / scale), -qmax, qmax)
    codes_ref[...] = q.astype(jnp.int8)
    scales_ref[...] = scale.astype(jnp.float32)


def _decompress_kernel(codes_ref, scales_ref, base_ref, out_ref):
    c = codes_ref[...].astype(jnp.float32)
    s = scales_ref[...].astype(jnp.float32)
    b = base_ref[...].astype(jnp.float32)
    out_ref[...] = b + c * s


def compress_pallas(values: jax.Array, base: jax.Array, bits: int = 8,
                    interpret: bool = True):
    """values/base: (n_blocks, block) -> (codes int8, scales (n,1) f32)."""
    n, block = values.shape
    assert n % TILE_ROWS == 0, f"n_blocks {n} % {TILE_ROWS} != 0"
    qmax = float(2 ** (bits - 1) - 1)
    grid = (n // TILE_ROWS,)
    return pl.pallas_call(
        functools.partial(_compress_kernel, qmax=qmax),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, block), jnp.int8),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(values, base)


def decompress_pallas(codes: jax.Array, scales: jax.Array, base: jax.Array,
                      interpret: bool = True) -> jax.Array:
    n, block = codes.shape
    assert n % TILE_ROWS == 0
    grid = (n // TILE_ROWS,)
    return pl.pallas_call(
        _decompress_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS, 1), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_ROWS, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, block), jnp.float32),
        interpret=interpret,
    )(codes, scales, base)
