"""Jit'd public wrapper + backend selection for the fused bank scan.

TPU -> compiled Pallas kernel (the streaming engine's banked tile
programs route through here, so gathered rows never round-trip through
HBM-resident stacked intermediates); everywhere else -> the pure-jax
``ref.py`` path, which is gather + the same blocked recurrence (this is
the automatic fallback -- identical bits, no Pallas requirement).
``RECXL_BANK_SCAN=pallas|jax`` overrides the choice; tests run the
kernel in interpreter mode on CPU against ``ref.py``.
"""

from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax

from repro.kernels.bank_scan import kernel
from repro.kernels.bank_scan.ref import bank_scan_ref


def bank_scan_backend() -> str:
    """``"pallas"`` iff the fused kernel should run (TPU backend, or
    forced via ``RECXL_BANK_SCAN``), else ``"jax"``.

    Re-read on every :func:`bank_scan` call, so flipping the env var
    takes effect immediately there. The streaming engine, by contrast,
    captures the backend when it BUILDS a tile program and caches the
    program until ``clear_sim_caches()`` -- flip the var, then clear,
    to re-route an engine that has already compiled tiles."""
    force = os.environ.get("RECXL_BANK_SCAN", "").lower()
    if force in ("pallas", "jax"):
        return force
    return "pallas" if jax.default_backend() == "tpu" else "jax"


@functools.partial(jax.jit, static_argnames=("chunk", "sb", "path"))
def _bank_scan_jit(a_bank, w_bank, v_bank, p_bank, trace_idx, wv_idx,
                   *, chunk: int, sb: int, path: str):
    if path == "pallas":
        return kernel.bank_scan_pallas(
            a_bank, w_bank, v_bank, p_bank, trace_idx, wv_idx,
            chunk=chunk, sb=sb, interpret=jax.default_backend() != "tpu")
    if path == "pallas_interpret":
        return kernel.bank_scan_pallas(
            a_bank, w_bank, v_bank, p_bank, trace_idx, wv_idx,
            chunk=chunk, sb=sb, interpret=True)
    return bank_scan_ref(a_bank, w_bank, v_bank, p_bank, trace_idx, wv_idx,
                         chunk=chunk, sb=sb)


def bank_scan(a_bank: jax.Array, w_bank: jax.Array, v_bank: jax.Array,
              p_bank: jax.Array, trace_idx: jax.Array, wv_idx: jax.Array,
              *, chunk: int, sb: int, force: Optional[str] = None
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused gather + blocked max-plus scan over a columnar trace bank.

    Banks are store-contiguous (``a_bank (T, n)``; ``w/v/p_bank
    (P, n)``); ``trace_idx`` / ``wv_idx`` are ``(B,)`` i32 row
    indices. ``sb`` is the (uniform) store-buffer depth, ``chunk`` the
    block length (clamped to ``sb`` and the trace). The backend is
    resolved OUTSIDE the jitted body (a static of the inner jit), so
    an env-var override applies on the next call instead of being
    frozen into the first compiled program. Returns per-cell
    ``(exec_time_ns, at_head_count, sb_full_count)``, bit-identical
    across backends.
    """
    path = force or bank_scan_backend()
    return _bank_scan_jit(a_bank, w_bank, v_bank, p_bank, trace_idx,
                          wv_idx, chunk=chunk, sb=sb, path=path)
