from repro.kernels.bank_scan.ops import bank_scan, bank_scan_backend
from repro.kernels.bank_scan.ref import bank_scan_ref

__all__ = ["bank_scan", "bank_scan_backend", "bank_scan_ref"]
