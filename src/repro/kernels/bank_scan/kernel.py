"""Pallas TPU kernel fusing the trace-bank gather with the max-plus scan.

One program per (cell, chunk) grid point, the chunk axis sequential.
The *gather* is done by the BlockSpec index maps: the two scalar-
prefetched ``int32`` row-index vectors select which bank row each
cell's ``(1, chunk)`` blocks stream from, so gathered rows go straight
HBM -> VMEM per chunk and never exist as stacked ``(B, n_stores)``
intermediates in HBM -- the whole point of the banked data plane.

Carried state per cell lives in scratch across the sequential chunk
steps: the last ``sb`` commit times (VMEM ``(1, sb)`` ring, oldest
first -- ``hist[0, k]`` is exactly the serial oracle's ``c_{i-sb}`` for
store ``k`` of the chunk, since ``chunk <= sb``), the running commit
time, and both census counters (SMEM scalars). The per-store max-plus
core ``c = max(r + w, c + v)`` is the same irreducible 2-op chain as
the simulator's blocked scan, applied in the same order, so results are
bit-identical to ``ref.py`` and the serial oracle.

The store axis is padded to a chunk multiple by the ops wrapper; padded
positions are masked by the static ``length`` (they update nothing --
the history slots they touch are never read again).

Coupled axes never reach this kernel as extra operands: contention
stalls and the two-level directory recurrence's epoch delays are
precollapsed into ``w_bank`` rows on the host, so a queueing-coupled
mega-grid runs the exact same kernel on more (or the same, when cells
dedup) bank rows.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bank_scan_kernel(tr_ref, wv_ref, a_ref, w_ref, v_ref, p_ref,
                      c_ref, ah_ref, sf_ref, hist_scr, last_scr, cnt_scr,
                      *, chunk: int, sb: int, n_chunks: int, length: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        hist_scr[...] = jnp.zeros_like(hist_scr)
        last_scr[0] = jnp.float32(0.0)
        cnt_scr[0] = jnp.int32(0)
        cnt_scr[1] = jnp.int32(0)

    a = a_ref[0, :]                     # (chunk,) this cell's gathered rows
    w = w_ref[0, :]
    v = v_ref[0, :]
    p = p_ref[0, :]
    last = last_scr[0]
    at_head, sb_full = cnt_scr[0], cnt_scr[1]
    base = ci * chunk

    # read every c_{i-sb} this block needs BEFORE the ring is shifted
    olds = [hist_scr[0, k] for k in range(chunk)]
    cs = []
    for k in range(chunk):
        valid = base + k < length
        r_k = jnp.maximum(a[k], olds[k])
        sb_full = sb_full + jnp.where(valid & (olds[k] > a[k]), 1, 0)
        at_head = at_head + jnp.where(valid & p[k] & (r_k >= last), 1, 0)
        c_k = jnp.maximum(r_k + w[k], last + v[k])
        last = jnp.where(valid, c_k, last)
        cs.append(last)
    cvec = jnp.stack(cs)

    if chunk == sb:
        hist_scr[0, :] = cvec
    else:
        tail = hist_scr[0, chunk:]      # materialize before overwriting
        hist_scr[0, :sb - chunk] = tail
        hist_scr[0, sb - chunk:] = cvec
    last_scr[0] = last
    cnt_scr[0] = at_head
    cnt_scr[1] = sb_full

    @pl.when(ci == n_chunks - 1)
    def _flush():
        c_ref[0, 0] = last
        ah_ref[0, 0] = at_head
        sf_ref[0, 0] = sb_full


def bank_scan_pallas(a_bank: jax.Array, w_bank: jax.Array,
                     v_bank: jax.Array, p_bank: jax.Array,
                     trace_idx: jax.Array, wv_idx: jax.Array, *,
                     chunk: int, sb: int, interpret: bool = True
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Banks: store-contiguous ``(T, n)`` / ``(P, n)``; indices: ``(B,)``
    i32. Returns per-cell ``(exec_time_ns, at_head, sb_full)`` -- (B,)
    each.
    """
    n = a_bank.shape[1]
    n_b = trace_idx.shape[0]
    chunk = max(1, min(chunk, sb, n))
    n_chunks = pl.cdiv(n, chunk)
    pad = n_chunks * chunk - n
    if pad:
        a_bank, w_bank, v_bank, p_bank = (
            jnp.pad(x, ((0, 0), (0, pad))) for x in
            (a_bank, w_bank, v_bank, p_bank))

    def row_block(idx_pos):
        # the in-kernel gather: block (1, chunk) of bank row idx[b]
        return pl.BlockSpec(
            (1, chunk), lambda b, c, tr, wv: ((tr, wv)[idx_pos][b], c))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_b, n_chunks),
        in_specs=[row_block(0), row_block(1), row_block(1), row_block(1)],
        out_specs=[pl.BlockSpec((1, 1), lambda b, c, tr, wv: (b, 0))] * 3,
        scratch_shapes=[
            pltpu.VMEM((1, sb), jnp.float32),      # commit-history ring
            pltpu.SMEM((1,), jnp.float32),         # c_{i-1}
            pltpu.SMEM((2,), jnp.int32),           # at_head, sb_full
        ],
    )
    out_c, out_ah, out_sf = pl.pallas_call(
        functools.partial(_bank_scan_kernel, chunk=chunk, sb=sb,
                          n_chunks=int(n_chunks), length=n),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_b, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_b, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_b, 1), jnp.int32),
        ],
        interpret=interpret,
    )(trace_idx, wv_idx, a_bank, w_bank, v_bank, p_bank)
    return out_c[:, 0], out_ah[:, 0], out_sf[:, 0]
