"""Pure-jax oracle for the fused bank-gather + max-plus scan.

Deliberately self-contained: it re-states the uniform-SB blocked
recurrence of ``repro.core.simulator`` (``_blocked_steps_uniform``)
instead of importing it, so kernel tests differentially pin BOTH
implementations -- a drift in either shows up as a bit mismatch.

Inputs are the store-contiguous :class:`~repro.core.simulator.TraceBank`
rows: ``a_bank (T, n)`` arrivals, ``w_bank / v_bank (P, n)`` the
precollapsed max-plus terms, ``p_bank (P, n)`` the proactive
non-coalesced (Fig. 11 REPL-at-head candidate) mask, plus per-cell
``int32`` row indices. "Precollapsed" includes every host-side
coupling the simulator folds into the ``w`` side -- contention stalls
and the level-2 directory-epoch delays of the two-level recurrence
alike -- so the kernel contract (and its arithmetic) is axis-agnostic:
a directory-coupled wv row scans through the identical code path. The recurrence per store ``i`` of cell ``b``::

    r_i = max(a_i, c_{i-sb})          # retire waits for a free SB slot
    c_i = max(r_i + w_i, c_{i-1} + v_i)

with the SB-full census counting ``c_{i-sb} > a_i`` and the
REPL-at-head census counting ``pr_nc_i and r_i >= c_{i-1}``.

Returns per-cell ``(exec_time_ns, at_head_count, sb_full_count)`` --
(B,) f32 / i32 / i32 -- bit-identical to the simulator's blocked scan
and to the serial oracle.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _steps(carry, a_b, w_b, v_b, p_b):
    """One block of K <= sb stores (the tuple-history fast path)."""
    hist, last, at_head, sb_full = carry
    cs = []
    for k in range(a_b.shape[0]):
        old = hist[k]                      # c_{i-sb}: committed K<=sb ago
        r_k = jnp.maximum(a_b[k], old)
        sb_full = sb_full + (old > a_b[k])
        at_head = at_head + (p_b[k] & (r_k >= last))
        last = jnp.maximum(r_k + w_b[k], last + v_b[k])
        cs.append(last)
    return (hist[a_b.shape[0]:] + tuple(cs), last, at_head, sb_full)


def bank_scan_ref(a_bank: jax.Array, w_bank: jax.Array, v_bank: jax.Array,
                  p_bank: jax.Array, trace_idx: jax.Array,
                  wv_idx: jax.Array, *, chunk: int, sb: int
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Gather each cell's columns, then run the blocked recurrence.

    ``chunk`` must not exceed ``sb`` (a block may not look past the
    carried history) nor the trace length. Call under ``jax.jit`` (the
    ops wrapper does) -- the block loop unrolls ``chunk`` tiny row ops.
    """
    a = jnp.take(a_bank, trace_idx, axis=0).T         # (n, B)
    w = jnp.take(w_bank, wv_idx, axis=0).T
    v = jnp.take(v_bank, wv_idx, axis=0).T
    p = jnp.take(p_bank, wv_idx, axis=0).T

    n, n_b = a.shape
    chunk = max(1, min(chunk, sb, n))
    carry = (tuple(jnp.zeros((n_b,), jnp.float32) for _ in range(sb)),
             jnp.zeros((n_b,), jnp.float32),
             jnp.zeros((n_b,), jnp.int32),
             jnp.zeros((n_b,), jnp.int32))
    n_main = (n // chunk) * chunk
    if n_main:
        xs = tuple(x[:n_main].reshape(-1, chunk, n_b) for x in (a, w, v, p))

        def body(c, blk):
            return _steps(c, *blk), None

        carry, _ = jax.lax.scan(body, carry, xs)
    if n - n_main:
        carry = _steps(carry, *(x[n_main:] for x in (a, w, v, p)))
    _, last, at_head, sb_full = carry
    return last, at_head, sb_full
