"""Pallas TPU kernels for the framework's compute hot-spots.

* ``log_compress``  -- the ReCXL log-dump compressor (delta + blockwise
  int8/int4): the TPU-native analogue of the paper's gzip-9 stage.
* ``flash_attn``    -- blocked online-softmax GQA attention (the memory
  hot-spot of 8/10 assigned archs at 32k context).
* ``ssd_scan``      -- Mamba-2 SSD chunked scan in matmul form.

Each kernel ships ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd wrapper with a pure-jnp fallback for non-TPU backends) and
``ref.py`` (the oracle the tests sweep against). Kernels are validated
with ``interpret=True`` on CPU; on real TPUs ``ops.py`` selects the
compiled kernel.
"""
