"""Configuration system for ReCXL-JAX.

Every run is described by four orthogonal configs:

* :class:`ModelConfig`     -- the architecture (one per assigned arch).
* :class:`ShapeConfig`     -- the input-shape cell (train_4k / prefill_32k /
                              decode_32k / long_500k).
* :class:`MeshConfig`      -- the device mesh (single-pod 16x16 or
                              multi-pod 2x16x16).
* :class:`ReplicationConfig` -- the ReCXL fault-tolerance engine knobs
                              (variant, N_r, bucketing, log sizing, ...).

Configs are plain frozen dataclasses so they hash, print, and serialize
cleanly, and so they can be used as static args to ``jax.jit``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm")


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters.

    The fields cover every family in the assigned pool: dense GQA
    transformers, MoE transformers, Mamba-2 SSD stacks, hybrid
    attention+SSM, encoder-decoder audio backbones, and VLM backbones with
    a stubbed patch-embedding frontend.
    """

    name: str
    family: str                      # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 => d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    mlp: str = "swiglu"              # swiglu (3 mats) | gelu (2 mats)

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0               # 0 => dense FFN
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim (0 => d_ff)
    n_shared_experts: int = 0
    capacity_factor: float = 1.25

    # --- SSM (Mamba-2 / SSD) -------------------------------------------------
    ssm_state: int = 0               # 0 => no SSM branch
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256

    # --- encoder-decoder (audio) --------------------------------------------
    encoder_layers: int = 0          # >0 => enc-dec model
    n_frames: int = 1500             # stubbed audio-frame count (Whisper: 1500)

    # --- VLM ------------------------------------------------------------------
    n_patches: int = 0               # >0 => patch-embedding stub prepended

    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    def __post_init__(self) -> None:
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.family != "ssm" and self.n_heads > 0:
            if self.n_heads % max(self.n_kv_heads, 1) != 0:
                raise ValueError(
                    f"{self.name}: n_heads={self.n_heads} not divisible by "
                    f"n_kv_heads={self.n_kv_heads}")

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads == 0:
            return 0
        return self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def supports_long_context(self) -> bool:
        """True iff the arch has a sub-quadratic sequence-mixing path and can
        therefore run the ``long_500k`` shape."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode_path(self) -> bool:
        """All assigned archs have a decoder; encoder-only archs would not."""
        return True

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Exact parameter count (used for 6*N*D model-FLOPs and memory
        budgeting; cross-checked against HLO byte counts in tests)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d
        out_head = 0 if self.tie_embeddings else self.vocab_size * d
        per_layer = 0
        if self.family == "ssm":
            di = self.d_inner
            nh = self.ssm_n_heads
            # in_proj produces [z, x, B, C, dt]
            zxbcdt = 2 * di + 2 * self.ssm_state + nh
            per_layer += d * zxbcdt                       # in_proj
            per_layer += self.ssm_conv * (di + 2 * self.ssm_state)  # conv1d
            per_layer += nh * 2                           # A_log, D
            per_layer += nh                               # dt_bias
            per_layer += di * d                           # out_proj
            per_layer += d                                # norm
            per_layer += di                               # gated norm
            body = per_layer * self.n_layers
            return emb + out_head + body + d              # final norm
        # attention block (dense / moe / hybrid / audio / vlm)
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qk_norm:
            attn += 2 * hd
        per_layer += attn + 2 * d                         # + 2 norms
        if self.family == "hybrid":
            di = self.d_inner
            nh = self.ssm_n_heads
            zxbcdt = 2 * di + 2 * self.ssm_state + nh
            per_layer += d * zxbcdt + self.ssm_conv * (di + 2 * self.ssm_state)
            per_layer += nh * 3 + di * d + di
        n_ffn_mats = 3 if self.mlp == "swiglu" else 2
        if self.is_moe:
            e_ff = self.expert_d_ff
            per_layer += self.n_experts * n_ffn_mats * d * e_ff
            per_layer += d * self.n_experts               # router
            per_layer += self.n_shared_experts * n_ffn_mats * d * e_ff
        else:
            per_layer += n_ffn_mats * d * self.d_ff
        body = per_layer * self.n_layers
        if self.is_encdec:
            # encoder layers: self-attn + FFN; decoder adds cross-attn
            enc_layer = attn + n_ffn_mats * d * self.d_ff + 2 * d
            cross = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d + d
            body = (enc_layer * self.encoder_layers
                    + (per_layer + cross) * self.n_layers)
        return emb + out_head + body + d

    def active_param_count(self) -> int:
        """Active (per-token) parameters -- differs from total only for MoE."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        e_ff = self.expert_d_ff
        n_ffn_mats = 3 if self.mlp == "swiglu" else 2
        inactive = (self.n_experts - self.top_k) * n_ffn_mats * d * e_ff * self.n_layers
        return self.param_count() - inactive


# ---------------------------------------------------------------------------
# Shape configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell.

    ``kind``:
      * ``train``   -- lowers ``train_step`` (fwd+bwd+opt+replication).
      * ``prefill`` -- lowers ``prefill_step`` (forward, fills KV cache).
      * ``decode``  -- lowers ``serve_step`` (one new token against a KV
        cache of ``seq_len``).
    """

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    def __post_init__(self) -> None:
        if self.kind not in ("train", "prefill", "decode"):
            raise ValueError(f"bad shape kind {self.kind}")

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524288, global_batch=1, kind="decode")

SHAPES: Dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell is runnable; returns (ok, reason)."""
    if shape.name == "long_500k" and not model.supports_long_context:
        return False, ("full quadratic attention at 524288-token context; "
                       "sub-quadratic path required (DESIGN.md S4)")
    if shape.kind == "decode" and not model.has_decode_path:
        return False, "encoder-only architecture has no decode step"
    return True, ""


# ---------------------------------------------------------------------------
# Mesh configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def data_parallel(self) -> int:
        n = 1
        for ax, s in zip(self.axes, self.shape):
            if ax in ("data", "pod"):
                n *= s
        return n

    @property
    def model_parallel(self) -> int:
        n = 1
        for ax, s in zip(self.axes, self.shape):
            if ax == "model":
                n *= s
        return n


SINGLE_POD = MeshConfig(shape=(16, 16), axes=("data", "model"))
MULTI_POD = MeshConfig(shape=(2, 16, 16), axes=("pod", "data", "model"))


# ---------------------------------------------------------------------------
# Replication (ReCXL) configuration
# ---------------------------------------------------------------------------

VARIANTS = ("none", "writethrough", "baseline", "parallel", "proactive")


@dataclass(frozen=True)
class ReplicationConfig:
    """ReCXL fault-tolerance engine knobs (paper SS III-IV).

    ``variant``:
      * ``none``        -- WB in the paper: fast, no fault tolerance.
      * ``writethrough``-- WT: persist every update synchronously to the MN
                            tier (the paper's 7.6x strawman).
      * ``baseline``    -- replication strictly after the coherence
                            transaction (serialized dependency chain).
      * ``parallel``    -- replication overlapped with the coherence
                            transaction; commit waits on both.
      * ``proactive``   -- per-bucket replication issued as each bucket's
                            update becomes available (SB-overlap analogue).
    """

    variant: str = "proactive"
    n_replicas: int = 3              # N_r (paper default 3)
    n_buckets: int = 8               # update coalescing granularity
    coalescing: bool = True
    log_capacity: int = 8            # ring-buffer entries (steps) per node
    dump_interval: int = 50          # steps between MN dumps (2.5ms analogue)
    compression: str = "int8"        # raw | int8 | int4 (MN dump wire format)
    cross_pod_replicas: bool = False
    log_dtype: str = "bfloat16"      # in-HBM log precision (raw = exact)
    # beyond-paper: "copy" = the paper's N_r full copies; "parity" =
    # erasure-coded logs (one parity shard per group of ``parity_group``
    # nodes, stored outside the group): G x N_r less log memory,
    # tolerating one failure per group instead of N_r - 1 anywhere.
    mode: str = "copy"               # copy | parity
    parity_group: int = 4

    def __post_init__(self) -> None:
        if self.variant not in VARIANTS:
            raise ValueError(f"unknown variant {self.variant}")
        if self.compression not in ("raw", "int8", "int4"):
            raise ValueError(f"unknown compression {self.compression}")
        if self.n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        if self.mode not in ("copy", "parity"):
            raise ValueError(f"unknown mode {self.mode}")
        if self.mode == "parity" and self.parity_group < 2:
            raise ValueError("parity_group must be >= 2")

    @property
    def is_replicating(self) -> bool:
        return self.variant in ("baseline", "parallel", "proactive")


# ---------------------------------------------------------------------------
# Optimizer / training configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"         # adamw | adafactor | sgd
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    schedule: str = "cosine"         # cosine | linear | constant
    remat: str = "full"              # full | selective | none
    master_dtype: str = "float32"    # optimizer accumulator dtype
    param_dtype: str = "bfloat16"
    microbatch: int = 0              # 0 => no gradient accumulation
    seed: int = 0


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig = TRAIN_4K
    mesh: MeshConfig = SINGLE_POD
    replication: ReplicationConfig = field(default_factory=ReplicationConfig)
    train: TrainConfig = field(default_factory=TrainConfig)

    def cell_id(self) -> str:
        return f"{self.model.name}::{self.shape.name}::{'x'.join(map(str, self.mesh.shape))}"

    def fingerprint(self) -> str:
        blob = json.dumps(dataclasses.asdict(self), sort_keys=True, default=str)
        return hashlib.sha1(blob.encode()).hexdigest()[:12]


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_MODEL_REGISTRY: Dict[str, ModelConfig] = {}
_REDUCED_REGISTRY: Dict[str, ModelConfig] = {}


def register_model(cfg: ModelConfig, reduced: Optional[ModelConfig] = None) -> ModelConfig:
    if cfg.name in _MODEL_REGISTRY:
        raise ValueError(f"duplicate model registration {cfg.name}")
    _MODEL_REGISTRY[cfg.name] = cfg
    if reduced is not None:
        _REDUCED_REGISTRY[cfg.name] = reduced
    return cfg


def get_model_config(name: str) -> ModelConfig:
    _ensure_configs_imported()
    if name not in _MODEL_REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(_MODEL_REGISTRY)}")
    return _MODEL_REGISTRY[name]


def get_reduced_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    _ensure_configs_imported()
    if name in _REDUCED_REGISTRY:
        return _REDUCED_REGISTRY[name]
    raise KeyError(f"no reduced config registered for {name!r}")


def list_models() -> Tuple[str, ...]:
    _ensure_configs_imported()
    return tuple(sorted(_MODEL_REGISTRY))


def _ensure_configs_imported() -> None:
    # configs self-register on import; import lazily to avoid cycles.
    import repro.configs  # noqa: F401


def make_run_config(arch: str, shape: str = "train_4k",
                    multi_pod: bool = False,
                    replication: Optional[ReplicationConfig] = None,
                    **train_overrides: Any) -> RunConfig:
    model = get_model_config(arch)
    mesh = MULTI_POD if multi_pod else SINGLE_POD
    rep = replication or ReplicationConfig()
    train = TrainConfig(**train_overrides) if train_overrides else TrainConfig()
    return RunConfig(model=model, shape=SHAPES[shape], mesh=mesh,
                     replication=rep, train=train)
