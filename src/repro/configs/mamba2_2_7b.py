"""mamba2-2.7b -- SSD (state-space duality) stack [arXiv:2405.21060].

Assigned cell: [ssm] 64L d_model=2560 (attn-free) d_ff=0 vocab=50280,
ssm_state=128. expand=2 => d_inner=5120, head_dim=64 => 80 SSD heads.
"""

from repro.config import ModelConfig, register_model

FULL = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
)

REDUCED = ModelConfig(
    name="mamba2-2.7b-reduced",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=512,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=32,
)

register_model(FULL, reduced=REDUCED)
