"""grok-1-314b -- 8-expert top-2 MoE [hf:xai-org/grok-1].

Assigned cell: [moe] 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8e top-2.
"""

from repro.config import ModelConfig, register_model

FULL = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    n_experts=8,
    top_k=2,
    rope_theta=10_000.0,
)

REDUCED = ModelConfig(
    name="grok-1-314b-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    n_experts=4,
    top_k=2,
    rope_theta=10_000.0,
)

register_model(FULL, reduced=REDUCED)
