"""whisper-medium -- encoder-decoder audio backbone [arXiv:2212.04356].

Assigned cell: [audio] 24L d_model=1024 16H (kv=16 => MHA) d_ff=4096
vocab=51865. enc-dec; the conv mel frontend is a STUB -- ``input_specs()``
provides precomputed frame embeddings (batch, 1500, d_model).
"""

from repro.config import ModelConfig, register_model

FULL = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,            # decoder layers
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    n_frames=1500,
    mlp="gelu",
    rope_theta=10_000.0,    # backbone uses RoPE in this repro (frontend stubbed)
)

REDUCED = ModelConfig(
    name="whisper-medium-reduced",
    family="audio",
    n_layers=2,
    encoder_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    n_frames=16,
    mlp="gelu",
    rope_theta=10_000.0,
)

register_model(FULL, reduced=REDUCED)
