"""qwen3-0.6b -- [hf:Qwen/Qwen3-8B family; hf].

Assigned cell: [dense] 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936, qk_norm, GQA. head_dim=128 per the HF config (q_proj is
16*128 = 2048 wide, wider than d_model).
"""

from repro.config import ModelConfig, register_model

FULL = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=3072,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="qwen3-0.6b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    head_dim=32,
    qk_norm=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
)

register_model(FULL, reduced=REDUCED)
