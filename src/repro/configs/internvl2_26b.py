"""internvl2-26b -- InternViT-6B + InternLM2-20B backbone [arXiv:2404.16821; hf].

Assigned cell: [vlm] 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.

Per the assignment rules the modality frontend (InternViT) is a STUB:
``input_specs()`` provides precomputed patch embeddings of shape
(batch, n_patches, d_model) that replace the leading token positions. Only
the LM backbone is modeled/lowered.
"""

from repro.config import ModelConfig, register_model

FULL = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    head_dim=128,
    rope_theta=1_000_000.0,
    n_patches=256,
)

REDUCED = ModelConfig(
    name="internvl2-26b-reduced",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    rope_theta=10_000.0,
    n_patches=8,
)

register_model(FULL, reduced=REDUCED)
