"""starcoder2-15b -- GQA + RoPE code LM [arXiv:2402.19173; hf].

Assigned cell: [dense] 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152.
"""

from repro.config import ModelConfig, register_model

FULL = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    mlp="gelu",
    rope_theta=100_000.0,
)

REDUCED = ModelConfig(
    name="starcoder2-15b-reduced",
    family="dense",
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    head_dim=16,
    mlp="gelu",
    rope_theta=10_000.0,
)

register_model(FULL, reduced=REDUCED)
