"""The paper's evaluated system (Table II) -- parameters for the
trace-driven protocol simulator that reproduces the paper's own
evaluation (Figures 2, 10-18).

This is NOT a neural architecture; it is the CXL-DSM cluster config. The
simulator consumes it directly.
"""

from dataclasses import dataclass, field
from typing import Dict, Tuple


@dataclass(frozen=True)
class ClusterConfig:
    """Paper Table II."""

    n_cns: int = 16
    n_mns: int = 16
    cores_per_cn: int = 4
    cpu_freq_ghz: float = 2.4
    logging_unit_freq_mhz: float = 500.0
    load_queue: int = 128
    store_buffer: int = 72           # SB entries (the paper's key resource)
    l1_lat_cycles: int = 5
    l2_lat_cycles: int = 13
    l3_lat_cycles: int = 36
    cache_line_bytes: int = 64
    dram_lat_ns: float = 45.0
    pmem_lat_ns: float = 500.0       # WT persist target latency
    cxl_link_bw_gbps: float = 160.0  # GB/s [Micron '24]
    cxl_rtt_ns: float = 200.0        # network round trip [Pond]
    sram_log_bytes: int = 4096
    sram_log_lat_ns: float = 4.0
    dram_log_bytes: int = 18 * 2**20
    dump_period_ms: float = 2.5
    n_replicas: int = 3
    gzip_factor: float = 5.8         # measured by the paper

    @property
    def cycle_ns(self) -> float:
        return 1.0 / self.cpu_freq_ghz


@dataclass(frozen=True)
class WorkloadProfile:
    """Per-application trace statistics used to synthesize store/compute
    traces for the protocol simulator.

    The paper runs PARSEC/SPLASH-2/YCSB through Pin+SST; we parameterize
    each application class by its store intensity and locality so the
    simulator reproduces the published relative behaviour (DESIGN.md S2).

    * remote_store_rate  -- remote (CXL) stores per 1000 instructions.
    * coalesce_rate      -- fraction of remote stores coalescable with the
                            previous SB entry (same line, no intervening
                            other-line store).
    * burstiness         -- fraction of stores inside store bursts (flush
                            phases); governs how much SB queueing there is
                            to hide replication behind.
    * burst_len          -- mean burst run length in stores; runs longer
                            than the 72-entry SB are what back-pressure
                            the core under ReCXL-proactive.
    * remote_read_rate   -- remote loads per 1000 instructions (bandwidth
                            term; loads are unaffected by ReCXL).
    * working_lines      -- distinct remote cache lines touched (log/dir
                            footprint; drives Figs 13 & 15).
    """

    name: str
    remote_store_rate: float
    coalesce_rate: float
    burstiness: float
    burst_len: float
    remote_read_rate: float
    working_lines: int


# Calibrated so the simulator reproduces the paper's Fig. 2/10 orderings
# and magnitudes (see benchmarks/bench_protocols.py and
# tests/test_simulator.py for the acceptance bands). raytrace /
# fluidanimate get short bursts => high REPL-at-SB-head fraction
# (Fig. 11); the oceans / ycsb get long flush bursts (proactive's cost).
WORKLOADS: Dict[str, WorkloadProfile] = {
    "bodytrack":     WorkloadProfile("bodytrack",     1.1, 0.45, 0.50,  40.0,  3.5, 18_000),
    "fluidanimate":  WorkloadProfile("fluidanimate",  2.1, 0.50, 0.15,  10.0,  5.5, 26_000),
    "streamcluster": WorkloadProfile("streamcluster", 0.33, 0.60, 0.30,  20.0,  6.0, 9_000),
    "canneal":       WorkloadProfile("canneal",       3.0, 0.20, 0.55, 120.0, 10.0, 40_000),
    "raytrace":      WorkloadProfile("raytrace",      0.9, 0.55, 0.10,   6.0,  4.0, 12_000),
    "barnes":        WorkloadProfile("barnes",        3.3, 0.40, 0.55, 150.0,  7.0, 30_000),
    "ocean_ncp":     WorkloadProfile("ocean_ncp",     8.1, 0.35, 0.78, 420.0, 10.0, 55_000),
    "ocean_cp":      WorkloadProfile("ocean_cp",      7.3, 0.35, 0.78, 420.0,  9.5, 50_000),
    "ycsb":          WorkloadProfile("ycsb",          4.8, 0.30, 0.72, 260.0, 14.0, 100_000),
}

PAPER_CLUSTER = ClusterConfig()

# Headline numbers from the paper used as validation targets.
PAPER_CLAIMS: Dict[str, float] = {
    "wt_slowdown_geomean": 7.6,
    "baseline_slowdown_geomean": 2.88,
    "parallel_gain_over_baseline": 0.03,
    "proactive_slowdown_geomean": 1.30,
    "gzip_factor": 5.8,
    "nr4_vs_nr3_overhead": 0.02,
    "scaling_4_to_16_wb": 3.1,
    "scaling_4_to_16_recxl": 3.0,
}
