"""deepseek-67b -- llama-arch dense LM [arXiv:2401.02954; hf].

Assigned cell: [dense] 95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400.
"""

from repro.config import ModelConfig, register_model

FULL = ModelConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    head_dim=128,
    rope_theta=10_000.0,
)

REDUCED = ModelConfig(
    name="deepseek-67b-reduced",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=512,
    head_dim=16,
    rope_theta=10_000.0,
)

register_model(FULL, reduced=REDUCED)
