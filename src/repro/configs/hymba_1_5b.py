"""hymba-1.5b -- parallel attention + mamba heads [arXiv:2411.13676; hf].

Assigned cell: [hybrid] 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16. Each layer runs attention heads and SSM heads
in parallel on the same input and fuses the branch outputs (mean of
per-branch-normalized outputs, per the paper).
"""

from repro.config import ModelConfig, register_model

FULL = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    rope_theta=10_000.0,
)

REDUCED = ModelConfig(
    name="hymba-1.5b-reduced",
    family="hybrid",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    ssm_state=8,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=32,
    rope_theta=10_000.0,
)

register_model(FULL, reduced=REDUCED)
