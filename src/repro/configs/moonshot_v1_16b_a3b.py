"""moonshot-v1-16b-a3b -- kimi/Moonlight fine-grained MoE
[hf:moonshotai/Moonlight-16B-A3B].

Assigned cell: [moe] 48L d_model=2048 16H (GQA kv=16 => MHA) d_ff=1408
(per-expert) vocab=163840, MoE 64e top-6 + 2 shared experts (HF config).
"""

from repro.config import ModelConfig, register_model

FULL = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    head_dim=128,
    n_experts=64,
    top_k=6,
    moe_d_ff=1408,
    n_shared_experts=2,
    rope_theta=50_000.0,
)

REDUCED = ModelConfig(
    name="moonshot-v1-16b-a3b-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=48,
    vocab_size=512,
    head_dim=16,
    n_experts=8,
    top_k=2,
    moe_d_ff=48,
    n_shared_experts=1,
    rope_theta=10_000.0,
)

register_model(FULL, reduced=REDUCED)
