"""Assigned-architecture configs (self-registering).

Each module defines the exact published config plus a reduced same-family
config used by CPU smoke tests. Importing this package registers all of
them with :mod:`repro.config`.
"""

from repro.configs import (  # noqa: F401
    internvl2_26b,
    qwen3_0_6b,
    deepseek_67b,
    stablelm_12b,
    starcoder2_15b,
    mamba2_2_7b,
    grok1_314b,
    moonshot_v1_16b_a3b,
    whisper_medium,
    hymba_1_5b,
    recxl_paper,
)

ASSIGNED_ARCHS = (
    "internvl2-26b",
    "qwen3-0.6b",
    "deepseek-67b",
    "stablelm-12b",
    "starcoder2-15b",
    "mamba2-2.7b",
    "grok-1-314b",
    "moonshot-v1-16b-a3b",
    "whisper-medium",
    "hymba-1.5b",
)
