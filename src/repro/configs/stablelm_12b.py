"""stablelm-12b -- [hf:stabilityai/stablelm-2-12b family; hf].

Assigned cell: [dense] 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352. head_dim = 5120/32 = 160.
"""

from repro.config import ModelConfig, register_model

FULL = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    head_dim=160,
    rope_theta=10_000.0,
)

REDUCED = ModelConfig(
    name="stablelm-12b-reduced",
    family="dense",
    n_layers=2,
    d_model=80,
    n_heads=4,
    n_kv_heads=2,
    d_ff=192,
    vocab_size=512,
    head_dim=20,
    rope_theta=10_000.0,
)

register_model(FULL, reduced=REDUCED)
