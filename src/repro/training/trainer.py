"""Fault-tolerant trainer: the control plane around the jitted step.

Responsibilities (the paper's SS V, operationally):

* drive the data pipeline + jitted train step;
* heartbeat every node; detect failures (lease expiry / injected
  fail-stop) via :class:`FailureDetector`;
* on failure: promote the lowest live rank to Configuration Manager,
  pause, run Algorithm 1-2 recovery out of the replica Logging Units
  (core/recovery.py), install the recovered shard on a spare
  (distributed/elastic.py), clear logs, rewind the pipeline, resume;
* periodic MN dumps (async checkpoint + compressed log dump) every
  ``dump_interval`` steps -- the 2.5 ms analogue;
* straggler mitigation: per-step timing, flag nodes slower than
  ``straggler_factor`` x median over a window; with a spare available the
  straggler is treated as a graceful failure (state read directly, no log
  recovery needed).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.config import RunConfig
from repro.core.directory import ShardDirectory
from repro.core.failures import FailureDetector, FailureEvent, FailureInjector
from repro.core.recovery import recover_node
from repro.core.replication import ReplicationEngine
from repro.data import SyntheticTokenPipeline
from repro.distributed.context import MeshContext, make_context, mesh_context
from repro.distributed.elastic import install_recovered_shard
from repro.distributed.sharding import named_shardings, param_specs
from repro.models import build_model
from repro.training.steps import TrainState, init_train_state, make_train_step


@dataclasses.dataclass
class StragglerMonitor:
    factor: float = 3.0
    window: int = 5
    history: List[float] = dataclasses.field(default_factory=list)
    slow_streak: int = 0

    def observe(self, dt: float) -> bool:
        """Returns True when the current step is straggler-suspect."""
        self.history.append(dt)
        if len(self.history) < max(self.window * 2, 8):
            return False
        median = float(np.median(self.history[-50:]))
        if dt > self.factor * median:
            self.slow_streak += 1
        else:
            self.slow_streak = 0
        return self.slow_streak >= self.window


class Trainer:
    def __init__(self, run: RunConfig, mesh: jax.sharding.Mesh,
                 workdir: str,
                 injector: Optional[FailureInjector] = None,
                 model=None):
        self.run = run
        self.mesh = mesh
        self.ctx: MeshContext = make_context(mesh)
        self.model = model or build_model(run.model)
        self.ckpt = CheckpointManager(workdir)
        self.injector = injector or FailureInjector()
        self.monitor = StragglerMonitor()
        self.events: List[Dict[str, Any]] = []

        with mesh_context(self.ctx):
            key = jax.random.PRNGKey(run.train.seed)
            params_shape = jax.eval_shape(self.model.init, key)
            self.specs = param_specs(params_shape, run.model, self.ctx)
            self.engine: Optional[ReplicationEngine] = None
            if run.replication.is_replicating:
                self.engine = ReplicationEngine(
                    run.replication, self.ctx, self.specs, params_shape)
            self.state = self._init_state(key)
            self._step_fn = jax.jit(
                make_train_step(run, self.model, self.engine),
                donate_argnums=(0,))

        n_nodes = self.engine.n_nodes if self.engine else self.ctx.data_size
        n_buckets = (self.engine.layout.n_buckets if self.engine
                     else run.replication.n_buckets)
        self.directory = ShardDirectory(
            n_nodes, n_buckets, run.replication.n_replicas)
        self.detector = FailureDetector(n_nodes, lease_s=30.0)
        self.pipeline = SyntheticTokenPipeline(
            run.model, run.shape, seed=run.train.seed)
        self._batch_shardings = None

    # ------------------------------------------------------------------
    def _init_state(self, key: jax.Array) -> TrainState:
        state = init_train_state(self.run, self.model, key, self.engine)
        shardings = named_shardings(state.params, self.run.model, self.ctx)
        params = jax.tree.map(jax.device_put, state.params, shardings)
        return state._replace(params=params)

    def _shard_batch(self, batch: Dict[str, np.ndarray]) -> Dict[str, jax.Array]:
        if self._batch_shardings is None:
            self._batch_shardings = {
                k: NamedSharding(
                    self.mesh,
                    P(self.ctx.batch_axes, *([None] * (v.ndim - 1))))
                for k, v in batch.items()}
        return {k: jax.device_put(v, self._batch_shardings[k])
                for k, v in batch.items()}

    # ------------------------------------------------------------------
    def train(self, num_steps: int,
              log_every: int = 10,
              on_metrics: Optional[Callable[[int, Dict], None]] = None
              ) -> List[Dict[str, float]]:
        history: List[Dict[str, float]] = []
        with mesh_context(self.ctx):
            for _ in range(num_steps):
                step_no = int(self.state.step)
                # ---- failure control plane -------------------------------
                for ev in self.injector.poll(step_no):
                    if ev.kind == "fail-stop":
                        self.detector.mark_failed(ev.node)
                        self.events.append({"step": step_no, "event": "fail",
                                            "node": ev.node})
                    else:
                        self.detector.mark_straggler(ev.node, ev.delay_s)
                failed = [n for n in self.detector.failed_nodes
                          if not any(e.get("recovered") == n
                                     for e in self.events)]
                if failed:
                    self._recover(failed[0], step_no)

                # ---- one step --------------------------------------------
                t0 = time.perf_counter()
                batch = self._shard_batch(self.pipeline.next())
                self.state, metrics = self._step_fn(self.state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                # straggler injection: modeled as an artificial delay
                for node, delay in list(self.detector.stragglers.items()):
                    time.sleep(delay)
                    dt += delay
                if self.monitor.observe(dt):
                    self.events.append({"step": step_no, "event": "straggler"})
                    self.detector.stragglers.clear()

                for n in self.detector.live_nodes:
                    self.detector.heartbeat(n)
                self.directory.record_commit(step_no)

                # ---- MN dump ---------------------------------------------
                if (step_no + 1) % self.run.replication.dump_interval == 0:
                    self._dump(step_no)

                m = {k: float(v) for k, v in metrics.items()
                     if jnp.ndim(v) == 0}
                m["step"] = step_no
                m["wall_s"] = dt
                history.append(m)
                if on_metrics and step_no % log_every == 0:
                    on_metrics(step_no, m)
        return history

    # ------------------------------------------------------------------
    def _dump(self, step_no: int) -> None:
        """MN-tier dump: full state async + directory watermark."""
        self.ckpt.save(step_no, {"params": self.state.params,
                                 "opt": self.state.opt_state},
                       extra={"pipeline_step": self.pipeline.state.step,
                              "directory": self.directory.to_json()})
        self.directory.record_dump(step_no)
        self.events.append({"step": step_no, "event": "mn_dump"})

    # ------------------------------------------------------------------
    def _recover(self, failed_node: int, step_no: int) -> None:
        """CM-driven recovery + spare replacement (DESIGN.md S2)."""
        if self.engine is None:
            raise RuntimeError(
                f"node {failed_node} failed but replication variant is "
                f"{self.run.replication.variant!r}: state is lost (this is "
                "the WB data-loss case the paper fixes)")
        cm = self.detector.configuration_manager()
        t0 = time.perf_counter()
        result = recover_node(self.engine, self.state.logs, self.directory,
                              failed_coord=(failed_node,))
        self.state = self.state._replace(
            params=install_recovered_shard(
                self.state.params, self.specs, self.engine, result,
                target_coord=(failed_node,)))
        # spare replacement: the rank is re-admitted with recovered state
        self.detector.viral_status[failed_node] = False
        self.detector.heartbeat(failed_node)
        for bucket in range(self.directory.n_buckets):
            self.directory.reassign(failed_node, bucket, failed_node)
        self.pipeline.seek(int(self.state.step))
        self.events.append({
            "step": step_no, "event": "recovery", "cm": cm,
            "recovered": failed_node,
            "stats": dataclasses.asdict(result.stats),
            "wall_s": time.perf_counter() - t0,
        })
