"""Jitted step functions: train (fwd+bwd+opt+ReCXL replication), eval,
prefill and decode.

The train step is where the paper's mechanism meets the training loop:

    grads  = d(loss)/d(params)           # fwd+bwd (GSPMD collectives)
    update = optimizer(grads)            # the "store"
    logs'  = REPL/VAL of update -> replica Logging Units (variant-shaped)
    commit = params' usable only after replication validated

``writethrough`` (the paper's WT strawman) instead barriers the step on a
synchronous copy into a persistent-tier staging buffer.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import RunConfig
from repro.core.replication import ReplicationEngine, _tie
from repro.distributed.context import get_mesh_context
from repro.models.model_zoo import Model
from repro.optim import make_optimizer, make_schedule
from repro.optim.optimizers import clip_by_global_norm


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    logs: Dict[str, jax.Array]          # ReCXL replica log rings
    step: jax.Array                     # int32
    wt_buffer: Optional[Any] = None     # writethrough staging tier


def init_train_state(run: RunConfig, model: Model, key: jax.Array,
                     engine: Optional[ReplicationEngine]) -> TrainState:
    params = model.init(key)
    opt_init, _ = make_optimizer(run.train)
    logs = engine.init_logs() if engine is not None and \
        run.replication.is_replicating else {}
    wt = None
    if run.replication.variant == "writethrough":
        wt = jax.tree.map(jnp.zeros_like, params)
    return TrainState(params=params, opt_state=opt_init(params), logs=logs,
                      step=jnp.zeros((), jnp.int32), wt_buffer=wt)


def make_train_step(run: RunConfig, model: Model,
                    engine: Optional[ReplicationEngine]
                    ) -> Callable[[TrainState, Dict[str, jax.Array]],
                                  Tuple[TrainState, Dict[str, jax.Array]]]:
    _, opt_update = make_optimizer(run.train)
    schedule = make_schedule(run.train)
    rep = run.replication
    remat = run.train.remat

    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        def loss_fn(p):
            loss, metrics = model.loss_fn(p, batch, remat=remat)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params)
        grads, gnorm = clip_by_global_norm(grads, run.train.grad_clip)
        lr = schedule(state.step)
        new_params, new_opt = opt_update(grads, state.opt_state,
                                         state.params, lr)

        logs = state.logs
        wt_buffer = state.wt_buffer
        if engine is not None and rep.is_replicating:
            logs, new_params = engine.replicate(
                new_params, logs, state.step, new_params)
        elif rep.variant == "writethrough":
            # WT: synchronous persist -- the step's output state is
            # barrier-tied to the staging-buffer copy, serializing every
            # update behind the persistent tier (the paper's 7.6x path;
            # quantified by the protocol simulator).
            wt_buffer = jax.tree.map(
                lambda x: x.astype(jnp.bfloat16), new_params)
            new_params = jax.tree.map(
                lambda p: _tie(p, *jax.tree.leaves(wt_buffer)), new_params)

        metrics = dict(metrics)
        metrics.update({"loss": loss, "grad_norm": gnorm, "lr": lr})
        return TrainState(params=new_params, opt_state=new_opt, logs=logs,
                          step=state.step + 1, wt_buffer=wt_buffer), metrics

    return train_step


def make_eval_step(run: RunConfig, model: Model):
    def eval_step(params: Any, batch: Dict[str, jax.Array]
                  ) -> Dict[str, jax.Array]:
        loss, metrics = model.loss_fn(params, batch, remat="none")
        return {"loss": loss, **metrics}

    return eval_step


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

class ServeState(NamedTuple):
    cache: Dict[str, jax.Array]
    tokens: jax.Array                  # last emitted token per sequence (B,)


def make_serve_fns(run: RunConfig, model: Model):
    """(prefill_fn, decode_fn) for the serving path.

    ``prefill_fn(params, batch)`` consumes the prompt and returns
    (first_tokens, ServeState); ``decode_fn(params, state)`` emits one
    token per sequence against the KV cache (what ``decode_*`` shape
    cells lower as ``serve_step``).
    """
    def prefill_fn(params: Any, batch: Dict[str, jax.Array],
                   max_len: Optional[int] = None):
        logits, cache = model.prefill(params, batch, max_len=max_len)
        toks = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return toks, ServeState(cache=cache, tokens=toks)

    def decode_fn(params: Any, state: ServeState
                  ) -> Tuple[jax.Array, ServeState]:
        logits, cache = model.decode_step(params, state.cache, state.tokens)
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return toks, ServeState(cache=cache, tokens=toks)

    return prefill_fn, decode_fn
