"""Training / serving steps + the fault-tolerant trainer."""

from repro.training.steps import (  # noqa: F401
    TrainState,
    make_eval_step,
    make_serve_fns,
    make_train_step,
    init_train_state,
)
from repro.training.trainer import Trainer  # noqa: F401
