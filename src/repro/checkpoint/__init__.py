"""Checkpoint tier -- the Memory-Node (MN) analogue."""

from repro.checkpoint.manager import CheckpointManager  # noqa: F401
