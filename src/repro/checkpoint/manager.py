"""The Memory-Node (MN) tier: durable checkpoints + periodic log dumps.

Paper mapping (DESIGN.md S2): the MNs are the fault-safe tier the Logging
Units dump compressed logs into every 2.5 ms; here the MN tier is a
directory of npz shards written by a background thread (async, off the
step's critical path), plus the dumped log entries used by recovery when
the in-HBM replica logs do not cover a bucket.

Layout (one manifest per committed checkpoint, written atomically last --
a torn dump is detected by a missing/incomplete manifest):

    <dir>/step_000123/
        manifest.json            # step, leaf names/shapes, directory blob
        state.npz                # flat state leaves
        logdump_b<k>.npz         # per-bucket compressed log dump (optional)
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_names(tree: Any) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        # np.savez cannot serialize ml_dtypes (bfloat16 & friends): store
        # them bit-exactly as a uint16/uint8 view; the manifest keeps the
        # true dtype and restore() views back.
        if arr.dtype.kind == "V" or arr.dtype.name in (
                "bfloat16", "float8_e4m3fn", "float8_e5m2"):
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2
                           else np.uint8)
        out.append((name, arr))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._last_saved_step = -1
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    # Save (async by default -- the MN dump is off the critical path)
    # ------------------------------------------------------------------

    def save(self, step: int, state: Any, *, extra: Optional[Dict[str, Any]] = None,
             log_dump: Optional[Dict[int, np.ndarray]] = None,
             blocking: bool = False) -> None:
        # snapshot to host BEFORE going async (donated buffers may be
        # overwritten by the next step otherwise)
        leaves = _flatten_with_names(state)
        extra = dict(extra or {})

        def write():
            path = os.path.join(self.dir, f"step_{step:09d}")
            tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
            try:
                np.savez(os.path.join(tmp, "state.npz"),
                         **{n: a for n, a in leaves})
                if log_dump:
                    for b, arr in log_dump.items():
                        np.savez(os.path.join(tmp, f"logdump_b{b}.npz"),
                                 values=arr)
                manifest = {
                    "step": step,
                    "leaves": [{"name": n, "shape": list(a.shape),
                                "dtype": str(a.dtype)} for n, a in leaves],
                    "extra": extra,
                    "wall_time": time.time(),
                }
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                if os.path.exists(path):
                    shutil.rmtree(path, ignore_errors=True)
                os.rename(tmp, path)
            finally:
                if os.path.exists(tmp):
                    shutil.rmtree(tmp, ignore_errors=True)
            with self._lock:
                self._last_saved_step = max(self._last_saved_step, step)
            self._gc()

        if blocking:
            write()
        else:
            self.wait()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    # Restore
    # ------------------------------------------------------------------

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template: Any, step: Optional[int] = None
                ) -> Tuple[Any, Dict[str, Any]]:
        """Restore into the structure of ``template`` (shapes must match)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoints found")
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "state.npz"))
        names = [n for n, _ in _flatten_with_names(template)]
        leaves = [data[n] for n in names]
        flat_t, treedef = jax.tree.flatten(template)

        def cast(l: np.ndarray, t) -> np.ndarray:
            tdt = np.dtype(t.dtype)
            if l.dtype != tdt and l.dtype.kind == "u" and \
                    l.dtype.itemsize == tdt.itemsize:
                l = l.view(tdt)          # bit-exact ml_dtypes round trip
            return np.asarray(l, dtype=tdt).reshape(t.shape)

        restored = jax.tree.unflatten(
            treedef, [cast(l, t) for l, t in zip(leaves, flat_t)])
        return restored, manifest.get("extra", {})

    def load_log_dump(self, step: int, bucket: int) -> Optional[np.ndarray]:
        p = os.path.join(self.dir, f"step_{step:09d}", f"logdump_b{bucket}.npz")
        if not os.path.exists(p):
            return None
        return np.load(p)["values"]

    # ------------------------------------------------------------------
    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)
