"""Public model facade: one uniform interface over all families.

``build_model(cfg)`` returns a :class:`Model` with ``init`` / ``loss_fn``
/ ``forward`` / ``prefill`` / ``decode_step`` / ``init_cache`` plus
``input_specs``/``make_batch`` helpers used by the dry-run launcher, the
trainer, and the smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from repro.models import encdec, transformer


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    forward: Callable[..., Tuple[jax.Array, jax.Array]]
    loss_fn: Callable[..., Tuple[jax.Array, Dict[str, jax.Array]]]
    prefill: Callable[..., Tuple[jax.Array, Dict[str, jax.Array]]]
    decode_step: Callable[..., Tuple[jax.Array, Dict[str, jax.Array]]]
    init_cache: Callable[[int, int], Dict[str, jax.Array]]


def build_model(cfg: ModelConfig) -> Model:
    mod = encdec if cfg.is_encdec else transformer
    if cfg.is_encdec:
        def _init_cache(batch: int, max_len: int) -> Dict[str, jax.Array]:
            raise NotImplementedError(
                "enc-dec caches are created by prefill (cross-K/V need the "
                "encoder output); use jax.eval_shape(prefill, ...) for specs")
    else:
        def _init_cache(batch: int, max_len: int) -> Dict[str, jax.Array]:
            return transformer.init_cache(cfg, batch, max_len)

    return Model(
        cfg=cfg,
        init=lambda key: mod.init_params(key, cfg),
        forward=lambda p, b, **kw: mod.forward(p, b, cfg, **kw),
        loss_fn=lambda p, b, **kw: mod.loss_fn(p, b, cfg, **kw),
        prefill=lambda p, b, **kw: mod.prefill(p, b, cfg, **kw),
        decode_step=lambda p, c, t: mod.decode_step(p, c, t, cfg),
        init_cache=_init_cache,
    )


# ---------------------------------------------------------------------------
# Input specs / synthetic batches
# ---------------------------------------------------------------------------

def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStructs for the *batch* inputs of a given shape cell.

    ``train``/``prefill`` kinds get the full-sequence inputs; ``decode``
    gets the one-token inputs (the KV cache is part of the serve state,
    not the batch -- see launch/dryrun.py).
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f = jnp.dtype(cfg.dtype)
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b,), i32)}
    specs: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((b, s), i32),
    }
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    if cfg.family == "vlm":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_patches, cfg.d_model), f)
    if cfg.is_encdec:
        specs["frames"] = jax.ShapeDtypeStruct((b, cfg.n_frames, cfg.d_model), f)
    return specs


def make_batch(cfg: ModelConfig, shape: ShapeConfig,
               key: Optional[jax.Array] = None) -> Dict[str, jax.Array]:
    """Concrete synthetic batch matching :func:`batch_struct` (smoke tests,
    examples, benchmarks)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    kt, kl, kp, kf = jax.random.split(key, 4)
    out: Dict[str, jax.Array] = {}
    for name, spec in batch_struct(cfg, shape).items():
        if spec.dtype == jnp.int32:
            k = kt if name == "tokens" else kl
            out[name] = jax.random.randint(k, spec.shape, 0, cfg.vocab_size,
                                           jnp.int32)
        else:
            k = kp if name == "patch_embeds" else kf
            out[name] = (jax.random.normal(k, spec.shape, jnp.float32) * 0.02
                         ).astype(spec.dtype)
    return out
