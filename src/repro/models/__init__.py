"""Composable model definitions for all assigned architectures.

Pure-functional JAX: parameters are nested dicts of ``jnp`` arrays, every
module is an (init, apply) function pair, and layer stacks use
``lax.scan`` over parameters stacked on a leading layer axis (keeps HLO
small and compile times flat in depth).
"""

from repro.models.model_zoo import build_model, Model  # noqa: F401
