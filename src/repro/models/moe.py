"""Top-k MoE with sort-based (dropping) dispatch.

Two parallel layouts, chosen from the expert count vs. the model-axis
size (DESIGN.md S5):

* **EP** (``n_experts % model_axis == 0``): experts sharded over the
  ``model`` axis; tokens replicated across it; each model rank dispatches
  its local tokens to its local experts and the partial outputs are
  ``psum``-combined. (moonshot: 64 experts / 16 ranks = 4 each.)
* **TP** (otherwise): every rank holds all experts with ``d_ff`` sliced
  over ``model``; the down-projection partial sums are ``psum``-combined.
  (grok: 8 experts on a 16-rank axis.)

Both run inside ``shard_map``; expert weights are additionally FSDP-sharded
over the data axes in HBM and all-gathered just-in-time for compute.
Dispatch is sort-based (argsort by expert id + capacity drop), so compiled
FLOPs track *active* expert FLOPs (x capacity factor) instead of the
dense all-experts product -- the same reason the paper's Logging Unit
logs only updated words instead of whole lines.

Without a mesh context (CPU unit tests) the pure-local path runs: same
math, no collectives.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig
from repro.distributed.context import get_mesh_context, shard_map
from repro.models.layers import Params, dense_init, dtype_of, mlp_apply, mlp_init


def moe_init(key: jax.Array, cfg: ModelConfig) -> Params:
    dt = dtype_of(cfg)
    E, d, ff = cfg.n_experts, cfg.d_model, cfg.expert_d_ff
    kr, kg, ku, kd, ks = jax.random.split(key, 5)

    def stack(k: jax.Array, in_dim: int, out_dim: int, scale: float = 1.0) -> jax.Array:
        keys = jax.random.split(k, E)
        return jnp.stack([dense_init(ki, in_dim, out_dim, dt, scale) for ki in keys])

    p = {
        "router": dense_init(kr, d, E, jnp.float32),
        "w_up": stack(ku, d, ff),
        "w_down": stack(kd, ff, d, scale=1.0 / np.sqrt(2 * cfg.n_layers)),
    }
    if cfg.mlp == "swiglu":
        p["w_gate"] = stack(kg, d, ff)
    if cfg.n_shared_experts:
        # shared experts fused into one wide dense MLP
        p["shared"] = mlp_init(ks, cfg, d_ff=ff * cfg.n_shared_experts)
    return p


# ---------------------------------------------------------------------------
# Local dispatch + expert compute (runs per shard)
# ---------------------------------------------------------------------------

def _dispatch_and_compute(x_flat: jax.Array, params: Params, cfg: ModelConfig,
                          e_start: int, e_count: int,
                          w_gate: Optional[jax.Array], w_up: jax.Array,
                          w_down: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Sort-based dispatch of (T, d) tokens to experts [e_start, e_start+e_count).

    Returns (partial_out (T, d), aux_loss ()). ``w_*`` are the *local*
    (possibly ff-sliced) expert stacks of shape (e_count, d|ff, ff|d).
    """
    T, d = x_flat.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = (x_flat @ params["router"].astype(x_flat.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    gate, idx = jax.lax.top_k(probs, K)                         # (T, K)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # Load-balancing aux loss (Switch): E * sum_e f_e * p_e.
    me = jnp.mean(probs, axis=0)                                # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=1), axis=0)
    aux = E * jnp.sum(me * ce)

    capacity = max(1, int(cfg.capacity_factor * T * K / E))
    flat_e = idx.reshape(-1)                                    # (T*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=sorted_e.dtype))
    pos = jnp.arange(T * K, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    local = (sorted_e >= e_start) & (sorted_e < e_start + e_count)
    valid = (pos < capacity) & local
    slot = (sorted_e.astype(jnp.int32) - e_start) * capacity + pos
    slot = jnp.where(valid, slot, e_count * capacity)           # dropped -> OOB
    tok = (order // K).astype(jnp.int32)

    buf = jnp.zeros((e_count * capacity, d), x_flat.dtype)
    buf = buf.at[slot].set(x_flat[tok], mode="drop")
    h = buf.reshape(e_count, capacity, d)
    up = jnp.einsum("ecd,edf->ecf", h, w_up)
    if w_gate is not None:
        act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, w_gate)) * up
    else:
        act = jax.nn.gelu(up)
    out_buf = jnp.einsum("ecf,efd->ecd", act, w_down)
    out_buf = out_buf.reshape(e_count * capacity, d)

    safe_slot = jnp.where(valid, slot, 0)
    y = out_buf[safe_slot] * valid[:, None]
    w_sorted = gate.reshape(-1)[order].astype(x_flat.dtype)
    out = jnp.zeros((T, d), x_flat.dtype)
    out = out.at[tok].add(y * w_sorted[:, None])
    return out, aux


# ---------------------------------------------------------------------------
# Public apply
# ---------------------------------------------------------------------------

def moe_apply(params: Params, x: jax.Array, cfg: ModelConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss ())."""
    b, s, d = x.shape
    ctx = get_mesh_context()
    E = cfg.n_experts
    has_gate = cfg.mlp == "swiglu"

    if ctx is None or ctx.model_axis is None:
        out, aux = _dispatch_and_compute(
            x.reshape(-1, d), params, cfg, 0, E,
            params.get("w_gate"), params["w_up"], params["w_down"])
        out = out.reshape(b, s, d)
    else:
        from repro.distributed.sharding import sanitize_spec

        mesh = ctx.mesh
        model_ax = ctx.model_axis
        n_model = ctx.model_size
        fsdp = ctx.fsdp_axes
        ep_mode = E % n_model == 0 and E >= n_model
        batch_spec = sanitize_spec(P(ctx.batch_axes, None, None),
                                   x.shape, mesh)
        if ep_mode:
            w_spec = P(model_ax, None, fsdp)       # experts over model, ff FSDP
            wd_spec = P(model_ax, fsdp, None)
        else:
            w_spec = P(None, None, (model_ax,) + fsdp)  # ff over model+FSDP
            wd_spec = P(None, (model_ax,) + fsdp, None)
        specs = {
            "router": P(None, None),
            "w_up": sanitize_spec(w_spec, params["w_up"].shape, mesh),
            "w_down": sanitize_spec(wd_spec, params["w_down"].shape, mesh),
        }
        if has_gate:
            specs["w_gate"] = specs["w_up"]
        if "shared" in params:
            sh_up = sanitize_spec(P(None, (model_ax,) + fsdp),
                                  params["shared"]["w_up"].shape, mesh)
            sh_dn = sanitize_spec(P((model_ax,) + fsdp, None),
                                  params["shared"]["w_down"].shape, mesh)
            specs["shared"] = {"w_up": sh_up, "w_down": sh_dn}
            if has_gate:
                specs["shared"]["w_gate"] = sh_up
        in_specs = (batch_spec, specs)
        out_specs = (batch_spec, P())

        def _axes_in(spec: P, dim: int) -> tuple:
            """Mesh axes sharding dim ``dim`` of a sanitized spec."""
            entry = tuple(spec)[dim] if dim < len(tuple(spec)) else None
            if entry is None:
                return ()
            return entry if isinstance(entry, tuple) else (entry,)

        def _gather(w, spec, dim, keep=()):
            """All-gather the storage-only axes of ``dim`` (all but keep)."""
            axes = tuple(a for a in _axes_in(spec, dim) if a not in keep)
            if axes:
                w = jax.lax.all_gather(w, axes, axis=dim, tiled=True)
            return w

        # does the model axis actually split the compute? (sanitizer may
        # have dropped it in reduced/test configs -> psum would
        # double-count replicated work without the 1/n correction)
        experts_split = model_ax in _axes_in(
            specs["w_up"], 0 if ep_mode else 2)
        shared_split = ("shared" in params and model_ax in _axes_in(
            specs["shared"]["w_up"], 1))

        def sharded(x_blk, p_blk):
            # JIT-time FSDP: gather the storage-sharded dims for compute,
            # keeping only the compute-parallel model axis sharded.
            keep = (model_ax,)
            wg = p_blk.get("w_gate")
            wu = _gather(p_blk["w_up"], specs["w_up"], 2, keep)
            wd = _gather(p_blk["w_down"], specs["w_down"], 1, keep)
            if wg is not None:
                wg = _gather(wg, specs["w_up"], 2, keep)
            if ep_mode:
                e_count = E // n_model
                e_start = jax.lax.axis_index(model_ax) * e_count
            else:
                e_count, e_start = E, 0
            xf = x_blk.reshape(-1, d)
            out, aux = _dispatch_and_compute(
                xf, p_blk, cfg, e_start, e_count, wg, wu, wd)
            if not (ep_mode or experts_split):
                out = out / n_model            # replicated compute
            if "shared" in p_blk:
                sh = p_blk["shared"]
                sw_up = _gather(sh["w_up"], specs["shared"]["w_up"], 1, keep)
                sw_dn = _gather(sh["w_down"], specs["shared"]["w_down"], 0, keep)
                if has_gate:
                    sw_g = _gather(sh["w_gate"], specs["shared"]["w_up"], 1, keep)
                    g = jax.nn.silu(xf @ sw_g)
                    shared_out = (g * (xf @ sw_up)) @ sw_dn
                else:
                    shared_out = jax.nn.gelu(xf @ sw_up) @ sw_dn
                if not shared_split:
                    shared_out = shared_out / n_model
                out = out + shared_out
            out = jax.lax.psum(out, model_ax)
            aux = jax.lax.pmean(aux, model_ax)
            return out.reshape(x_blk.shape), aux

        # EP: e_start differs per model rank -> dispatch masks differ; the
        # psum makes outputs replicated again. check_vma is disabled because
        # x is intentionally replicated over the model axis on entry.
        sm_params = {k: params[k] for k in specs}
        out, aux = shard_map(
            sharded, mesh=mesh, in_specs=in_specs,
            out_specs=out_specs)(x, sm_params)

    if ctx is None and "shared" in params:
        xf = x.reshape(-1, d)
        out = out + mlp_apply(params["shared"], xf, cfg).reshape(b, s, d)
    return out, aux
