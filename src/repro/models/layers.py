"""Shared layer primitives: norms, RoPE, MLPs, embeddings, initializers."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# dtype helpers
# ---------------------------------------------------------------------------

def dtype_of(cfg: ModelConfig) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, in_dim: int, out_dim: int,
               dtype: jnp.dtype, scale: float = 1.0) -> jax.Array:
    """Truncated-normal fan-in init (what the LM-family checkpoints use)."""
    std = scale / np.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, out_dim),
                                        jnp.float32) * std).astype(dtype)


def embed_init(key: jax.Array, vocab: int, dim: int,
               dtype: jnp.dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm / LayerNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype: jnp.dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def head_rmsnorm(scale: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """qk-norm: RMS norm over the head dim of (..., n_heads, head_dim)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,)."""
    exponent = np.arange(0, head_dim, 2, dtype=np.float32) / head_dim
    return jnp.asarray(1.0 / (theta ** exponent))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply RoPE. x: (..., seq, n_heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]                      # (..., seq, 1, hd/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def mlp_init(key: jax.Array, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    dt = dtype_of(cfg)
    d, ff = cfg.d_model, (d_ff or cfg.d_ff)
    if cfg.mlp == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": dense_init(k1, d, ff, dt),
            "w_up": dense_init(k2, d, ff, dt),
            "w_down": dense_init(k3, ff, d, dt, scale=1.0 / np.sqrt(2 * cfg.n_layers)),
        }
    k1, k2 = jax.random.split(key, 2)
    return {
        "w_up": dense_init(k1, d, ff, dt),
        "w_down": dense_init(k2, ff, d, dt, scale=1.0 / np.sqrt(2 * cfg.n_layers)),
    }


def mlp_apply(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.mlp == "swiglu":
        g = jax.nn.silu(x @ params["w_gate"])
        return (g * (x @ params["w_up"])) @ params["w_down"]
    return jax.nn.gelu(x @ params["w_up"]) @ params["w_down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embedding_init(key: jax.Array, cfg: ModelConfig) -> Params:
    dt = dtype_of(cfg)
    k1, k2 = jax.random.split(key)
    p = {"tok": embed_init(k1, cfg.vocab_size, cfg.d_model, dt)}
    if not cfg.tie_embeddings:
        p["out"] = dense_init(k2, cfg.d_model, cfg.vocab_size, dt)
    return p


def embed_tokens(params: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["tok"], tokens, axis=0)


def unembed(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return x @ params["tok"].T
    return x @ params["out"]


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: Optional[jax.Array] = None) -> jax.Array:
    """Token-mean CE. logits (..., V) fp32-accumulated; labels int (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
